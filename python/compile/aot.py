"""AOT bridge: lower every L2 workload to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax>=0.5
emits protos with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids, so text round-trips cleanly (see /opt/xla-example/README).

Also writes ``artifacts/manifest.tsv`` so the Rust runtime knows each
workload's input signature without parsing HLO:

    name<TAB>dtype:shape,dtype:shape<TAB>table4_row

Usage: ``python -m compile.aot --out-dir ../artifacts`` (from python/).
Idempotent: unchanged workloads are skipped unless --force.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import WORKLOADS


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (with return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_workload(spec) -> str:
    lowered = jax.jit(spec.fn).lower(*spec.example_args())
    return to_hlo_text(lowered)


def fmt_inputs(spec) -> str:
    return ",".join(
        f"{dtype}:{'x'.join(str(d) for d in shape)}" for dtype, shape in spec.inputs
    )


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--only", nargs="*", help="subset of workload names")
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    names = args.only or sorted(WORKLOADS)
    manifest_rows = []
    for name in names:
        spec = WORKLOADS[name]
        out_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        manifest_rows.append(f"{name}\t{fmt_inputs(spec)}\t{spec.table4_row}")
        if os.path.exists(out_path) and not args.force:
            print(f"[aot] {name}: exists, skipping")
            continue
        text = lower_workload(spec)
        with open(out_path, "w") as f:
            f.write(text)
        print(f"[aot] {name}: wrote {len(text)} chars -> {out_path}")

    manifest = os.path.join(args.out_dir, "manifest.tsv")
    with open(manifest, "w") as f:
        f.write("\n".join(manifest_rows) + "\n")
    print(f"[aot] manifest -> {manifest}")


if __name__ == "__main__":
    main()
