"""L2: the GCAPS case-study GPU workloads as jitted JAX computations.

Each entry mirrors one benchmark from Table 4 of the paper (Nvidia CUDA
samples on the Jetson testbed) and calls the L1 Pallas kernels where a
hot-spot exists. ``aot.py`` lowers every workload once to HLO text; the
Rust runtime (``rust/src/runtime``) loads the artifacts and executes them
on the PJRT CPU client — one artifact execution is one "kernel launch"
inside a GPU segment of the live executive. Python never runs at runtime.

Workload registry
-----------------
``WORKLOADS`` maps name -> WorkloadSpec(fn, input specs). Shapes are fixed
at AOT time (PJRT executables are shape-specialised, like CUDA kernels
compiled for a fixed launch geometry).
"""

from dataclasses import dataclass
from typing import Callable, Tuple

import jax
import jax.numpy as jnp

from .kernels import dxtc, histogram, matmul, projection


@dataclass(frozen=True)
class WorkloadSpec:
    """An AOT-compilable workload: the jitted fn plus its input signature."""

    name: str
    fn: Callable
    inputs: Tuple[Tuple[str, Tuple[int, ...]], ...]  # (dtype, shape) pairs
    # Paper Table 4 row this workload stands in for (documentation only).
    table4_row: str

    def example_args(self):
        return tuple(
            jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))
            for dtype, shape in self.inputs
        )


# --- Workload bodies (all return 1-tuples: lowered with return_tuple) ----


def histogram_wl(values):
    """Table 4 task 1: 256-bin histogram of an int image."""
    return (histogram(values),)


def mmul_wl(a, b):
    """Table 4 tasks 2/6: tiled Pallas matmul."""
    return (matmul(a, b),)


def projection_wl(points, mat):
    """Table 4 task 4: homogeneous point projection."""
    return (projection(points, mat),)


def dxtc_wl(img):
    """Table 4 task 5: DXT1-style block compression round-trip."""
    return (dxtc(img),)


def texture3d_wl(vol):
    """Table 4 task 7 (simpleTexture3D): 3D 6-neighbour box filter.

    Pure-jnp L2 workload (no Pallas hot-spot) — stands in for the graphics
    app that stresses the GPU from a separate context.
    """
    acc = vol
    for axis in range(3):
        acc = acc + jnp.roll(vol, 1, axis=axis) + jnp.roll(vol, -1, axis=axis)
    return (acc / 7.0,)


def vecadd_wl(x, y):
    """Quickstart workload: elementwise add."""
    return (x + y,)


# MXU-aligned shapes; sizes chosen so one launch is O(ms) on the CPU PJRT
# backend, comparable in spirit to the paper's kernel durations.
WORKLOADS = {
    w.name: w
    for w in [
        WorkloadSpec(
            "histogram",
            histogram_wl,
            (("int32", (65536,)),),
            "task 1: histogram",
        ),
        WorkloadSpec(
            "mmul_small",
            mmul_wl,
            (("float32", (128, 128)), ("float32", (128, 128))),
            "task 2: mmul_gpu_1",
        ),
        WorkloadSpec(
            "mmul_large",
            mmul_wl,
            (("float32", (256, 256)), ("float32", (256, 256))),
            "task 6: mmul_gpu_2",
        ),
        WorkloadSpec(
            "projection",
            projection_wl,
            (("float32", (16384, 4)), ("float32", (4, 4))),
            "task 4: projection",
        ),
        WorkloadSpec(
            "dxtc",
            dxtc_wl,
            (("float32", (256, 256)),),
            "task 5: dxtc",
        ),
        WorkloadSpec(
            "texture3d",
            texture3d_wl,
            (("float32", (32, 64, 64)),),
            "task 7: simpleTexture3D (graphics)",
        ),
        WorkloadSpec(
            "vecadd",
            vecadd_wl,
            (("float32", (16384,)), ("float32", (16384,))),
            "quickstart",
        ),
    ]
}
