"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the CORE correctness baseline: pytest (and hypothesis sweeps)
assert that each Pallas kernel, run in interpret mode, matches the oracle
to float tolerance over random shapes, dtypes and values.

The four workloads mirror the CUDA-sample benchmarks used by the GCAPS
case study (Table 4 of the paper): ``histogram``, ``mmul`` (matrixMul),
``projection`` (a 3D point projection stand-in) and ``dxtc`` (DXT1-style
block texture compression).
"""

import jax.numpy as jnp

NUM_BINS = 256
DXT_BLOCK = 4
DXT_LEVELS = 4


def matmul_ref(x, y):
    """Plain matmul in float32 accumulation."""
    return jnp.matmul(x.astype(jnp.float32), y.astype(jnp.float32))


def histogram_ref(values, num_bins=NUM_BINS):
    """Histogram of integer values in [0, num_bins).

    Returns float32 counts, shape (num_bins,). Out-of-range values are
    clipped, matching the kernel's behaviour.
    """
    v = jnp.clip(values.astype(jnp.int32), 0, num_bins - 1)
    return (
        (v[:, None] == jnp.arange(num_bins, dtype=jnp.int32)[None, :])
        .astype(jnp.float32)
        .sum(axis=0)
    )


def projection_ref(points, mat):
    """Homogeneous 3D point projection: p' = p @ M, then perspective divide.

    points: (N, 4) float32 homogeneous points.
    mat: (4, 4) float32 projection matrix.
    Returns (N, 4): xyz divided by w, with w kept in the last column.
    """
    out = jnp.matmul(points.astype(jnp.float32), mat.astype(jnp.float32))
    w = out[:, 3:4]
    # Guard against w == 0 the same way the kernel does.
    safe_w = jnp.where(jnp.abs(w) < 1e-12, 1.0, w)
    xyz = out[:, :3] / safe_w
    return jnp.concatenate([xyz, out[:, 3:4]], axis=1)


def dxtc_palette(lo, hi):
    """4-level DXT1-style palette between endpoints (broadcast over blocks)."""
    # levels: lo, 2/3 lo + 1/3 hi, 1/3 lo + 2/3 hi, hi
    fracs = jnp.array([0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0], dtype=jnp.float32)
    return lo[..., None] + (hi - lo)[..., None] * fracs


def dxtc_ref(img):
    """DXT1-style compress + decompress of a single-channel image.

    img: (H, W) float32 with H, W multiples of 4. Each 4x4 block is reduced
    to min/max endpoints and a 4-level palette; each pixel is replaced by
    the nearest palette entry. Returns the reconstructed (H, W) image —
    the round-trip makes correctness directly checkable.
    """
    h, w = img.shape
    b = DXT_BLOCK
    x = img.astype(jnp.float32).reshape(h // b, b, w // b, b)
    x = x.transpose(0, 2, 1, 3)  # (H/4, W/4, 4, 4)
    lo = x.min(axis=(2, 3))
    hi = x.max(axis=(2, 3))
    palette = dxtc_palette(lo, hi)  # (H/4, W/4, 4)
    dist = jnp.abs(x[..., None] - palette[:, :, None, None, :])
    idx = jnp.argmin(dist, axis=-1)
    recon = jnp.take_along_axis(
        palette[:, :, None, None, :], idx[..., None], axis=-1
    )[..., 0]
    recon = recon.transpose(0, 2, 1, 3).reshape(h, w)
    return recon


def vecadd_ref(x, y):
    """Element-wise add (quickstart workload)."""
    return x + y
