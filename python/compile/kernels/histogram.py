"""L1 Pallas kernel: 256-bin histogram (the GCAPS ``histogram`` workload).

Hardware adaptation: the CUDA histogram sample uses per-warp shared-memory
sub-histograms merged with atomics. TPUs have no atomics and scatter is
slow, so the kernel is re-thought for the MXU: each grid step turns its
chunk of values into a comparison-generated one-hot matrix and reduces it
to per-bin counts, accumulating into the output block that stays resident
in VMEM across the grid (revisiting output semantics replaces the atomic
merge). See DESIGN.md §Hardware-Adaptation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NUM_BINS

# Values processed per grid step. 2048 int32 = 8 KiB in VMEM; the one-hot
# intermediate (2048 x 256 f32) is materialised in-register/VMEM per step.
CHUNK = 2048


def _histogram_kernel(v_ref, o_ref, *, num_bins):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    v = jnp.clip(v_ref[...].astype(jnp.int32), 0, num_bins - 1)
    bins = jax.lax.broadcasted_iota(jnp.int32, (v.shape[0], num_bins), 1)
    onehot = (v[:, None] == bins).astype(jnp.float32)
    o_ref[...] += onehot.sum(axis=0)


def _pick_chunk(n, pref):
    c = min(pref, n)
    while n % c != 0:
        c -= 1
    return c


@functools.partial(jax.jit, static_argnames=("num_bins", "chunk"))
def histogram(values, num_bins=NUM_BINS, chunk=CHUNK):
    """Histogram of int values in [0, num_bins) -> float32 (num_bins,)."""
    (n,) = values.shape
    chunk = _pick_chunk(n, chunk)
    grid = (n // chunk,)
    return pl.pallas_call(
        functools.partial(_histogram_kernel, num_bins=num_bins),
        grid=grid,
        in_specs=[pl.BlockSpec((chunk,), lambda i: (i,))],
        out_specs=pl.BlockSpec((num_bins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((num_bins,), jnp.float32),
        interpret=True,
    )(values.astype(jnp.int32))
