"""L1 Pallas kernel: homogeneous 3D point projection (GCAPS ``projection``).

Row-tiled: each grid step projects a tile of points through the shared
4x4 matrix and performs the perspective divide. The matrix block is
broadcast to every grid step (index map pins it to block (0, 0)).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 512


def _projection_kernel(p_ref, m_ref, o_ref):
    p = p_ref[...]
    m = m_ref[...]
    out = jnp.dot(p, m, preferred_element_type=jnp.float32)
    w = out[:, 3:4]
    safe_w = jnp.where(jnp.abs(w) < 1e-12, 1.0, w)
    xyz = out[:, :3] / safe_w
    o_ref[...] = jnp.concatenate([xyz, out[:, 3:4]], axis=1)


def _pick_tile(n, pref):
    t = min(pref, n)
    while n % t != 0:
        t -= 1
    return t


@functools.partial(jax.jit, static_argnames=("tile",))
def projection(points, mat, tile=TILE_N):
    """Project (N, 4) points through a (4, 4) matrix with perspective divide."""
    n, four = points.shape
    assert four == 4 and mat.shape == (4, 4)
    tile = _pick_tile(n, tile)
    grid = (n // tile,)
    return pl.pallas_call(
        _projection_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, 4), lambda i: (i, 0)),
            pl.BlockSpec((4, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 4), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 4), jnp.float32),
        interpret=True,
    )(points.astype(jnp.float32), mat.astype(jnp.float32))
