"""L1 Pallas kernel: tiled matrix multiply (the GCAPS ``mmul`` workload).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA matrixMul
sample tiles A/B into shared memory per threadblock. On the TPU-shaped
Pallas model, the same insight — stage operand tiles in fast on-chip
memory and stream the K dimension — is expressed with a 3-D grid
``(M/bm, N/bn, K/bk)`` and ``BlockSpec`` index maps: each (i, j) output
tile stays resident in VMEM while K-tiles of A and B are streamed in from
HBM and accumulated on the MXU. ``interpret=True`` everywhere: the CPU
PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default tile sizes. 128 matches the MXU systolic-array edge; the VMEM
# working set per grid step is bm*bk + bk*bn + bm*bn floats
# (128*128*3*4 B = 192 KiB), far below the ~16 MiB VMEM budget, leaving
# room for double-buffering by the pipeline emitter.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: accumulate x_tile @ y_tile into o_tile.

    The output BlockSpec maps every k to the same (i, j) tile, so o_ref
    acts as the VMEM accumulator across the K loop (revisiting semantics).
    """
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pick_block(dim, pref):
    """Largest divisor of ``dim`` that is <= pref (keeps odd test shapes legal)."""
    b = min(pref, dim)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, bm=BLOCK_M, bn=BLOCK_N, bk=BLOCK_K):
    """Tiled Pallas matmul: (M, K) @ (K, N) -> (M, N) in float32.

    Shapes need not be multiples of the preferred tile sizes; tiles are
    shrunk to the largest divisor (correctness-first — the AOT artifact
    shapes are chosen MXU-aligned so the fast path always uses 128x128).
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {y.shape}"
    bm = _pick_block(m, bm)
    bn = _pick_block(n, bn)
    bk = _pick_block(k, bk)
    k_steps = k // bk
    grid = (m // bm, n // bn, k_steps)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x.astype(jnp.float32), y.astype(jnp.float32))
