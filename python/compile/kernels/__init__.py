# L1: Pallas kernels for the GCAPS case-study workloads (Table 4 of the
# paper). Each kernel has a pure-jnp oracle in ref.py; pytest + hypothesis
# assert kernel == oracle under interpret mode.
from .dxtc import dxtc
from .histogram import histogram
from .matmul import matmul
from .projection import projection

__all__ = ["dxtc", "histogram", "matmul", "projection"]
