"""L1 Pallas kernel: DXT1-style block texture compression (GCAPS ``dxtc``).

Hardware adaptation: the CUDA dxtc sample maps one 4x4 texel block per
warp with intra-warp reductions for the endpoint search. Here a grid step
owns a (4, W) row-strip of the image; the 4x4 blocks inside the strip are
exposed by a reshape, endpoints come from vectorised min/max reductions,
and palette selection is a vectorised nearest-neighbour argmin — no warp
primitives needed, everything lands on the VPU/MXU. Round-trips through
compress + decompress so correctness is a single allclose.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import DXT_BLOCK

FRACS = (0.0, 1.0 / 3.0, 2.0 / 3.0, 1.0)


def _dxtc_kernel(img_ref, o_ref):
    b = DXT_BLOCK
    strip = img_ref[...]  # (4, W)
    four, w = strip.shape
    # (W/4, 4, 4): block index, row-in-block, col-in-block
    blocks = strip.reshape(four, w // b, b).transpose(1, 0, 2)
    lo = blocks.min(axis=(1, 2))
    hi = blocks.max(axis=(1, 2))
    # Pallas kernels may not capture array constants; build the fraction
    # vector [0, 1/3, 2/3, 1] with an iota instead.
    fr = jax.lax.broadcasted_iota(jnp.float32, (4,), 0) / 3.0
    palette = lo[:, None] + (hi - lo)[:, None] * fr[None, :]  # (W/4, 4)
    dist = jnp.abs(blocks[..., None] - palette[:, None, None, :])
    idx = jnp.argmin(dist, axis=-1)  # (W/4, 4, 4)
    recon = jnp.take_along_axis(
        palette[:, None, None, :], idx[..., None], axis=-1
    )[..., 0]
    o_ref[...] = recon.transpose(1, 0, 2).reshape(four, w)


@jax.jit
def dxtc(img):
    """Compress + decompress (H, W) image with 4x4 DXT1-style blocks."""
    h, w = img.shape
    b = DXT_BLOCK
    assert h % b == 0 and w % b == 0, f"image must be 4-aligned, got {img.shape}"
    grid = (h // b,)
    return pl.pallas_call(
        _dxtc_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((b, w), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((b, w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w), jnp.float32),
        interpret=True,
    )(img.astype(jnp.float32))
