"""AOT lowering: every workload lowers to loadable-looking HLO text."""

import numpy as np
import pytest

from compile.aot import fmt_inputs, lower_workload, to_hlo_text
from compile.model import WORKLOADS


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_lowering_produces_hlo_text(name):
    text = lower_workload(WORKLOADS[name])
    assert "ENTRY" in text and "HloModule" in text
    # return_tuple=True => root of entry computation is a tuple
    assert "tuple(" in text or "tuple (" in text


def test_manifest_row_format():
    spec = WORKLOADS["mmul_small"]
    assert fmt_inputs(spec) == "float32:128x128,float32:128x128"


def test_manifest_int_workload():
    assert fmt_inputs(WORKLOADS["histogram"]) == "int32:65536"


def test_no_custom_calls_in_artifacts():
    # interpret=True must lower Pallas to plain HLO the CPU client can run;
    # a Mosaic custom-call would break the Rust runtime.
    for name in ["mmul_small", "histogram", "projection", "dxtc"]:
        text = lower_workload(WORKLOADS[name])
        assert "custom-call" not in text, f"{name} contains a custom-call"


def test_deterministic_lowering():
    a = lower_workload(WORKLOADS["vecadd"])
    b = lower_workload(WORKLOADS["vecadd"])
    assert a == b
