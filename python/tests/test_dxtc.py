"""Pallas dxtc kernel vs oracle + compression-specific invariants."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import dxtc
from compile.kernels import ref

RNG = np.random.default_rng(55)


def assert_matches_ref(img):
    got = np.asarray(dxtc(img))
    want = np.asarray(ref.dxtc_ref(img))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_random_image():
    assert_matches_ref(RNG.normal(size=(64, 128)).astype(np.float32))


def test_min_image():
    assert_matches_ref(RNG.normal(size=(4, 4)).astype(np.float32))


def test_constant_blocks_reconstruct_exactly():
    img = np.full((16, 16), 3.5, np.float32)
    np.testing.assert_array_equal(np.asarray(dxtc(img)), img)


def test_endpoints_preserved():
    # Block min and max are palette endpoints -> reproduced exactly.
    img = RNG.normal(size=(32, 32)).astype(np.float32)
    out = np.asarray(dxtc(img))
    blocks_in = img.reshape(8, 4, 8, 4).transpose(0, 2, 1, 3)
    blocks_out = out.reshape(8, 4, 8, 4).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(
        blocks_in.min(axis=(2, 3)), blocks_out.min(axis=(2, 3)), rtol=1e-6
    )
    np.testing.assert_allclose(
        blocks_in.max(axis=(2, 3)), blocks_out.max(axis=(2, 3)), rtol=1e-6
    )


def test_output_within_block_range():
    img = (RNG.normal(size=(64, 64)) * 10).astype(np.float32)
    out = np.asarray(dxtc(img))
    bi = img.reshape(16, 4, 16, 4).transpose(0, 2, 1, 3)
    bo = out.reshape(16, 4, 16, 4).transpose(0, 2, 1, 3)
    lo = bi.min(axis=(2, 3), keepdims=True)
    hi = bi.max(axis=(2, 3), keepdims=True)
    assert (bo >= lo - 1e-5).all() and (bo <= hi + 1e-5).all()


def test_quantization_error_bounded():
    # Error per pixel <= half a palette step = (hi - lo) / 6.
    img = RNG.normal(size=(32, 32)).astype(np.float32)
    out = np.asarray(dxtc(img))
    bi = img.reshape(8, 4, 8, 4).transpose(0, 2, 1, 3)
    rng_blk = bi.max(axis=(2, 3)) - bi.min(axis=(2, 3))
    err = np.abs(out - img).reshape(8, 4, 8, 4).transpose(0, 2, 1, 3).max(axis=(2, 3))
    assert (err <= rng_blk / 6.0 + 1e-5).all()


def test_idempotent():
    # Re-compressing a reconstructed image is a fixed point.
    img = RNG.normal(size=(16, 32)).astype(np.float32)
    once = np.asarray(dxtc(img))
    twice = np.asarray(dxtc(once))
    np.testing.assert_allclose(twice, once, rtol=1e-5, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    hb=st.integers(1, 16),
    wb=st.integers(1, 32),
    scale=st.floats(0.01, 50.0),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_shapes(hb, wb, scale, seed):
    rng = np.random.default_rng(seed)  # hypothesis-seeded: reproducible examples
    img = (rng.normal(size=(4 * hb, 4 * wb)) * scale).astype(np.float32)
    got = np.asarray(dxtc(img))
    want = np.asarray(ref.dxtc_ref(img))
    # atol scales with the data magnitude: palette entries are computed in a
    # different (but equally valid) fused order than the oracle's.
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-6 * (1 + scale))
