"""Pallas matmul kernel vs pure-jnp oracle — the L1 correctness signal."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul
from compile.kernels.matmul import _pick_block
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _mats(m, k, n, dtype=np.float32, scale=1.0):
    x = (RNG.normal(size=(m, k)) * scale).astype(dtype)
    y = (RNG.normal(size=(k, n)) * scale).astype(dtype)
    return x, y


def assert_matches_ref(x, y, rtol=1e-5, atol=1e-5):
    got = np.asarray(matmul(x, y))
    want = np.asarray(ref.matmul_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


def test_square_aligned():
    assert_matches_ref(*_mats(128, 128, 128))


def test_rect_aligned_multiblock():
    assert_matches_ref(*_mats(256, 128, 384))


def test_small_unaligned():
    assert_matches_ref(*_mats(3, 5, 7))


def test_prime_dims():
    assert_matches_ref(*_mats(13, 17, 19))


def test_single_row_col():
    assert_matches_ref(*_mats(1, 64, 1))


def test_identity():
    x = np.eye(32, dtype=np.float32)
    y = RNG.normal(size=(32, 32)).astype(np.float32)
    np.testing.assert_allclose(np.asarray(matmul(x, y)), y, rtol=1e-6, atol=1e-6)


def test_zeros():
    x, y = _mats(16, 16, 16)
    out = np.asarray(matmul(np.zeros_like(x), y))
    np.testing.assert_array_equal(out, np.zeros((16, 16), np.float32))


def test_int_inputs_upcast():
    x = RNG.integers(-4, 4, size=(8, 8)).astype(np.int32)
    y = RNG.integers(-4, 4, size=(8, 8)).astype(np.int32)
    assert_matches_ref(x, y, rtol=0, atol=0)


def test_explicit_tiny_blocks():
    x, y = _mats(64, 64, 64)
    got = np.asarray(matmul(x, y, bm=16, bn=16, bk=16))
    want = np.asarray(ref.matmul_ref(x, y))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_k_accumulation_order_large_k():
    # Many K steps: exercises the revisiting-output accumulator.
    assert_matches_ref(*_mats(8, 1024, 8))


@pytest.mark.parametrize("pref", [1, 2, 3, 127, 128, 1000])
def test_pick_block_divides(pref):
    for dim in [1, 2, 12, 128, 250, 251]:
        b = _pick_block(dim, pref)
        assert 1 <= b <= dim and dim % b == 0


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(1, 96),
    n=st.integers(1, 96),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_shapes(m, k, n, seed):
    rng = np.random.default_rng(seed)  # hypothesis-seeded: reproducible examples
    x = rng.normal(size=(m, k)).astype(np.float32)
    y = rng.normal(size=(k, n)).astype(np.float32)
    assert_matches_ref(x, y, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.integers(1, 48),
    k=st.integers(1, 48),
    n=st.integers(1, 48),
    dtype=st.sampled_from([np.float32, np.float64, np.int32]),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_dtypes(m, k, n, dtype, seed):
    # All inputs are cast to f32 by the kernel; oracle does the same.
    rng = np.random.default_rng(seed)
    if np.issubdtype(dtype, np.integer):
        x = rng.integers(-3, 3, size=(m, k)).astype(dtype)
        y = rng.integers(-3, 3, size=(k, n)).astype(dtype)
    else:
        x = rng.normal(size=(m, k)).astype(dtype)
        y = rng.normal(size=(k, n)).astype(dtype)
    assert_matches_ref(x, y, rtol=1e-4, atol=1e-4)
