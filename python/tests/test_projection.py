"""Pallas projection kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import projection
from compile.kernels import ref

RNG = np.random.default_rng(7)


def assert_matches_ref(p, m, rtol=1e-5, atol=1e-5):
    got = np.asarray(projection(p, m))
    want = np.asarray(ref.projection_ref(p, m))
    np.testing.assert_allclose(got, want, rtol=rtol, atol=atol)


def _points(n, w_far_from_zero=True):
    p = RNG.normal(size=(n, 4)).astype(np.float32)
    if w_far_from_zero:
        # keep |w| after projection reasonably away from 0 for stable tolerances
        p[:, 3] = np.sign(p[:, 3]) * (np.abs(p[:, 3]) + 0.5)
    return p


def test_identity_matrix():
    p = _points(256)
    m = np.eye(4, dtype=np.float32)
    out = np.asarray(projection(p, m))
    np.testing.assert_allclose(out[:, :3], p[:, :3] / p[:, 3:4], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out[:, 3], p[:, 3], rtol=1e-6)


def test_random_aligned():
    assert_matches_ref(_points(2048), RNG.normal(size=(4, 4)).astype(np.float32))


def test_unaligned_tile():
    assert_matches_ref(_points(777), RNG.normal(size=(4, 4)).astype(np.float32))


def test_single_point():
    assert_matches_ref(_points(1), RNG.normal(size=(4, 4)).astype(np.float32))


def test_zero_w_guard():
    # Points whose transformed w is exactly 0 must not produce inf/nan.
    p = np.array([[1.0, 2.0, 3.0, 0.0]], np.float32)
    m = np.diag([1.0, 1.0, 1.0, 0.0]).astype(np.float32)  # forces w' = 0
    out = np.asarray(projection(p, m))
    assert np.isfinite(out).all()
    assert_matches_ref(p, m)


def test_perspective_matrix():
    # A classic perspective projection: w' = -z
    m = np.zeros((4, 4), np.float32)
    m[0, 0] = m[1, 1] = 1.0
    m[2, 2] = -1.002
    m[2, 3] = -1.0
    m[3, 2] = -0.2
    p = _points(512)
    p[:, 2] = -np.abs(p[:, 2]) - 1.0  # in front of camera
    assert_matches_ref(p, m, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(n=st.integers(1, 3000), seed=st.integers(0, 2**31))
def test_hypothesis_sizes(n, seed):
    rng = np.random.default_rng(seed)  # hypothesis-seeded: reproducible examples
    p = rng.normal(size=(n, 4)).astype(np.float32)
    m = rng.normal(size=(4, 4)).astype(np.float32)
    # Loose tolerance: the perspective divide amplifies dot-product rounding
    # differences by 1/|w'| for near-zero w'.
    assert_matches_ref(p, m, rtol=1e-3, atol=1e-3)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 600), scale=st.floats(0.01, 100.0),
       seed=st.integers(0, 2**31))
def test_hypothesis_scales(n, scale, seed):
    rng = np.random.default_rng(seed)
    p = (rng.normal(size=(n, 4)) * scale).astype(np.float32)
    m = (rng.normal(size=(4, 4)) * scale).astype(np.float32)
    got = np.asarray(projection(p, m))
    want = np.asarray(ref.projection_ref(p, m))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3 * scale)
