"""Pallas histogram kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import histogram
from compile.kernels import ref

RNG = np.random.default_rng(99)


def assert_matches_ref(v, num_bins=256):
    got = np.asarray(histogram(v, num_bins=num_bins))
    want = np.asarray(ref.histogram_ref(v, num_bins=num_bins))
    np.testing.assert_array_equal(got, want)


def test_uniform_values():
    assert_matches_ref(RNG.integers(0, 256, size=(65536,)).astype(np.int32))


def test_all_same_bin():
    v = np.full((4096,), 7, np.int32)
    out = np.asarray(histogram(v))
    assert out[7] == 4096 and out.sum() == 4096


def test_out_of_range_clipped():
    v = np.array([-5, -1, 0, 255, 256, 999], np.int32)
    out = np.asarray(histogram(v))
    # negatives clip to bin 0, overflow clips to bin 255
    assert out[0] == 3 and out[255] == 3


def test_counts_sum_to_n():
    v = RNG.integers(-50, 300, size=(10000,)).astype(np.int32)
    assert np.asarray(histogram(v)).sum() == 10000


def test_small_input_shrinks_chunk():
    assert_matches_ref(RNG.integers(0, 256, size=(17,)).astype(np.int32))


def test_single_element():
    assert_matches_ref(np.array([42], np.int32))


def test_nondefault_bins():
    v = RNG.integers(0, 64, size=(2048,)).astype(np.int32)
    assert_matches_ref(v, num_bins=64)


def test_chunk_boundary_exact_multiple():
    assert_matches_ref(RNG.integers(0, 256, size=(2048 * 3,)).astype(np.int32))


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 5000),
    lo=st.integers(-10, 200),
    width=st.integers(1, 300),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_sizes_and_ranges(n, lo, width, seed):
    rng = np.random.default_rng(seed)  # hypothesis-seeded: reproducible examples
    v = rng.integers(lo, lo + width, size=(n,)).astype(np.int32)
    assert_matches_ref(v)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 2000), bins=st.sampled_from([16, 64, 128, 256]),
       seed=st.integers(0, 2**31))
def test_hypothesis_bin_counts(n, bins, seed):
    rng = np.random.default_rng(seed)
    v = rng.integers(0, bins, size=(n,)).astype(np.int32)
    assert_matches_ref(v, num_bins=bins)
