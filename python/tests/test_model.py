"""L2 workload registry: every workload runs and produces sane shapes."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile.model import WORKLOADS
from compile.kernels import ref

RNG = np.random.default_rng(2)


def materialise(spec):
    args = []
    for dtype, shape in spec.inputs:
        if np.issubdtype(np.dtype(dtype), np.integer):
            args.append(RNG.integers(0, 256, size=shape).astype(dtype))
        else:
            args.append(RNG.normal(size=shape).astype(dtype))
    return tuple(args)


@pytest.mark.parametrize("name", sorted(WORKLOADS))
def test_workload_runs(name):
    spec = WORKLOADS[name]
    out = spec.fn(*materialise(spec))
    assert isinstance(out, tuple) and len(out) == 1
    assert np.isfinite(np.asarray(out[0])).all()


def test_registry_names_match():
    for name, spec in WORKLOADS.items():
        assert spec.name == name


def test_registry_covers_table4():
    rows = " ".join(s.table4_row for s in WORKLOADS.values())
    for token in ["histogram", "mmul_gpu_1", "mmul_gpu_2", "projection", "dxtc",
                  "simpleTexture3D"]:
        assert token in rows, f"Table 4 workload {token} missing"


def test_histogram_workload_matches_ref():
    spec = WORKLOADS["histogram"]
    (v,) = materialise(spec)
    np.testing.assert_array_equal(
        np.asarray(spec.fn(v)[0]), np.asarray(ref.histogram_ref(v))
    )


def test_mmul_workload_matches_ref():
    spec = WORKLOADS["mmul_small"]
    a, b = materialise(spec)
    np.testing.assert_allclose(
        np.asarray(spec.fn(a, b)[0]),
        np.asarray(ref.matmul_ref(a, b)),
        rtol=1e-4, atol=1e-4,
    )


def test_texture3d_preserves_mean():
    # 6-neighbour box filter with wraparound preserves the volume mean.
    spec = WORKLOADS["texture3d"]
    (vol,) = materialise(spec)
    out = np.asarray(spec.fn(vol)[0])
    np.testing.assert_allclose(out.mean(), vol.mean(), rtol=1e-4)


def test_vecadd():
    spec = WORKLOADS["vecadd"]
    x, y = materialise(spec)
    np.testing.assert_allclose(np.asarray(spec.fn(x, y)[0]), x + y, rtol=1e-6)
