//! Driver-model trace explorer: reproduces the paper's motivational
//! schedules (Fig. 3: sync-based vs GCAPS; Fig. 5: separate GPU
//! priorities rescuing a deadline) as ASCII Gantt charts from real
//! simulator traces, then renders a custom three-task scenario under
//! all four policies so the context-switching behaviour (§5.2) is
//! visible.
//!
//! Run with: `cargo run --release --example driver_trace`

use gcaps::experiments::examples_figs::{run_fig3, run_fig5};
use gcaps::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
use gcaps::sim::{simulate, Policy, SimConfig};

fn main() {
    println!("{}", run_fig3());
    println!("{}", run_fig5());

    // A custom scenario: two RT GPU tasks + one best-effort GPU hog.
    let p = Platform { num_cpus: 2, tsg_slice: 1024, theta: 200, epsilon: 1000 };
    let mk = |id, name: &str, core, prio, ge: f64, t: f64, be| Task {
        id,
        name: name.into(),
        period: ms(t),
        deadline: ms(t),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(0.5), ms(ge))],
        core,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: be,
        mode: WaitMode::SelfSuspend,
    };
    let ts = TaskSet::new(
        vec![
            mk(0, "vision", 0, 2, 6.0, 40.0, false),
            mk(1, "lidar", 1, 1, 9.0, 60.0, false),
            mk(2, "render", 1, 0, 25.0, 120.0, true),
        ],
        p,
    );
    for policy in [Policy::Gcaps, Policy::TsgRr, Policy::Mpcp, Policy::FmlpPlus] {
        let sim = simulate(&ts, &SimConfig::new(policy, ms(40.0)).with_trace());
        println!("--- policy: {} ---", policy.label());
        println!("{}", sim.trace.unwrap().gantt(2, 3, 0, ms(40.0), 120));
    }
    println!("note how gcaps keeps 'vision' exclusive on the GPU while tsg_rr");
    println!("interleaves it with the best-effort 'render' context.");
}
