//! Schedulability sweep (a runnable miniature of Fig. 8): generates
//! random tasksets per Table 3 and compares all nine analyses across
//! a utilization sweep through the experiment registry — the ASCII
//! chart plus the CSV and JSONL artifacts of one run.
//!
//! Run with: `cargo run --release --example schedulability_sweep`
//! (optionally `-- --tasksets 500`).

use gcaps::api::{self, SinkSpec};
use gcaps::experiments::{ExpConfig, Opts};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let tasksets = args
        .iter()
        .position(|a| a == "--tasksets")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let cfg = ExpConfig {
        tasksets,
        seed: 2024,
        opts: Opts::default().set("panel", "b"),
        ..ExpConfig::default()
    };
    println!("running Fig. 8b (utilization sweep) with {tasksets} tasksets/point ...\n");
    // dir: None → `$GCAPS_RESULTS` or `./results`, like the CLI.
    let spec = SinkSpec { csv: true, jsonl: true, ascii: true, dir: None };
    let report = api::run("fig8", &cfg, &spec).expect("fig8 run");
    print!("{}", report.ascii);
    println!(
        "{} rows in {:.0} ms -> {:?}",
        report.rows(),
        report.wall.as_secs_f64() * 1e3,
        report.outputs
    );
    println!("\nrun `gcaps exp --list` for every registered experiment.");
}
