//! Schedulability sweep (a runnable miniature of Fig. 8): generates
//! random tasksets per Table 3 and compares all eight analyses across
//! a utilization sweep, printing the ASCII chart + CSV the full
//! experiment harness produces.
//!
//! Run with: `cargo run --release --example schedulability_sweep`
//! (optionally `-- --tasksets 500`).

use gcaps::experiments::fig8::{run_and_report, Panel};
use gcaps::experiments::ExpConfig;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let tasksets = args
        .iter()
        .position(|a| a == "--tasksets")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(100);
    let cfg = ExpConfig { tasksets, seed: 2024 };
    println!("running Fig. 8b (utilization sweep) with {tasksets} tasksets/point ...\n");
    print!("{}", run_and_report(Panel::UtilPerCpu, &cfg));
    println!("\nrun `gcaps exp fig8` for all six panels (a-f).");
}
