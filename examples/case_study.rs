//! End-to-end case study (the paper's §7.2) — the repo's full-stack
//! driver: loads the AOT-compiled Pallas/JAX workloads, profiles them,
//! builds the Table 4 analog taskset, and runs the **live** periodic
//! executive under all four scheduling approaches, reporting MORT per
//! task plus the measured runlist-update (ε) distribution. Results are
//! recorded in EXPERIMENTS.md.
//!
//! Run with: `make artifacts && cargo run --release --example case_study`
//! (optionally `-- --seconds 30 --busy`).

use std::time::Duration;

use gcaps::coordinator::executor::{run, LiveMode};
use gcaps::coordinator::workload::build_case_study;
use gcaps::experiments::overhead::fig12_histogram;
use gcaps::runtime::{artifacts_dir, Runtime};
use gcaps::util::ascii::bar_chart;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let seconds = args
        .iter()
        .position(|a| a == "--seconds")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(10u64);
    let busy = args.iter().any(|a| a == "--busy");

    println!("loading AOT artifacts from {} ...", artifacts_dir().display());
    let rt = Runtime::load_dir(&artifacts_dir())?;
    let (tasks, launch_ms) = build_case_study(&rt, busy)?;

    println!("\n-- Table 4 analog (profiled on this host) --");
    for (t, lm) in tasks.iter().zip(&launch_ms) {
        let g: f64 = t.gpu_segments.iter().map(|s| s.launches as f64 * lm).sum();
        println!(
            "  {:12} T = {:>5.0} ms  C = {:>5.1} ms  G = {:>6.1} ms  {}",
            t.name,
            t.period.as_secs_f64() * 1e3,
            t.cpu_segments.iter().map(|d| d.as_secs_f64() * 1e3).sum::<f64>(),
            g,
            if t.rt { format!("prio {}", t.gpu_prio) } else { "best-effort".into() }
        );
    }

    let mut eps_us: Vec<f64> = Vec::new();
    for mode in [LiveMode::Gcaps, LiveMode::TsgRr, LiveMode::FmlpPlus, LiveMode::Mpcp] {
        println!(
            "\n-- live run: {} ({} s, {} waiting) --",
            mode.label(),
            seconds,
            if busy { "busy" } else { "suspending" }
        );
        let res = run(&tasks, &rt, mode, Duration::from_secs(seconds));
        let rows: Vec<(String, f64)> = tasks
            .iter()
            .zip(&res.per_task)
            .map(|(t, m)| {
                (
                    format!("{}{}", t.name, if t.rt { "" } else { " (BE)" }),
                    m.mort().map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0),
                )
            })
            .collect();
        print!("{}", bar_chart(&format!("MORT under {} (Fig. 10 analog)", mode.label()), &rows, "ms"));
        let misses: u64 = res
            .per_task
            .iter()
            .zip(&tasks)
            .filter(|(_, t)| t.rt)
            .map(|(m, _)| m.misses)
            .sum();
        println!("   RT deadline misses: {misses}, kernel launches: {}", res.launches);
        if mode == LiveMode::Gcaps {
            eps_us = res.eps_samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
        }
    }

    println!("\n{}", fig12_histogram(&eps_us, "live"));
    println!("done — headline metric: GCAPS keeps the highest-priority task's MORT");
    println!("near its isolated response while lock-based baselines inflate it by");
    println!("whole lower-priority GPU segments (compare the bars above).");
    Ok(())
}
