//! Quickstart: the three layers in one file.
//!
//! 1. Build a small taskset (the paper's §4 model).
//! 2. Run the GCAPS response-time analysis (§6.3) and its baselines.
//! 3. Simulate the same taskset on the device model and check the
//!    bounds hold.
//! 4. If `artifacts/` is built (`make artifacts`), run a real AOT
//!    kernel through the PJRT runtime — the same path the live
//!    executive uses.
//!
//! Run with: `cargo run --release --example quickstart`

use gcaps::analysis::{analyze, Approach};
use gcaps::model::{ms, to_ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
use gcaps::runtime::{artifacts_dir, Runtime};
use gcaps::sim::{simulate, Policy, SimConfig};

fn main() {
    // -- 1. A three-task system: camera (GPU), planner (CPU), logger (GPU).
    let platform = Platform { num_cpus: 2, tsg_slice: 1024, theta: 200, epsilon: 1000 };
    let gpu_task = |id, name: &str, core, prio, c1: f64, gm: f64, ge: f64, c2: f64, t: f64| Task {
        id,
        name: name.into(),
        period: ms(t),
        deadline: ms(t),
        cpu_segments: vec![ms(c1), ms(c2)],
        gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
        core,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let tasks = vec![
        gpu_task(0, "camera", 0, 3, 1.0, 0.5, 8.0, 1.0, 50.0),
        Task::cpu_only(1, 0, 2, ms(10.0), ms(100.0)),
        gpu_task(2, "logger", 1, 1, 2.0, 1.0, 20.0, 2.0, 200.0),
    ];
    let ts = TaskSet::new(tasks, platform);
    ts.validate().expect("valid taskset");

    // -- 2. Analysis: GCAPS vs the default driver vs the lock baselines.
    println!("WCRT bounds (ms):");
    for approach in [
        Approach::GcapsSuspend,
        Approach::TsgRrSuspend,
        Approach::MpcpSuspend,
        Approach::FmlpSuspend,
    ] {
        let res = analyze(&ts, approach);
        let bounds: Vec<String> = ts
            .tasks
            .iter()
            .map(|t| {
                res.response[t.id]
                    .map(|r| format!("{}={:.1}", t.name, to_ms(r)))
                    .unwrap_or_else(|| format!("{}=FAIL", t.name))
            })
            .collect();
        println!("  {:16} {}", approach.label(), bounds.join("  "));
    }

    // -- 3. Simulation: bounds must dominate observed response times.
    println!("\nSimulated MORT (ms), 10 s horizon:");
    for policy in [Policy::Gcaps, Policy::TsgRr, Policy::Mpcp] {
        let sim = simulate(&ts, &SimConfig::new(policy, ms(10_000.0)));
        let morts: Vec<String> = ts
            .tasks
            .iter()
            .map(|t| {
                format!(
                    "{}={:.1}",
                    t.name,
                    sim.per_task[t.id].mort().map(to_ms).unwrap_or(0.0)
                )
            })
            .collect();
        println!("  {:16} {}", policy.label(), morts.join("  "));
    }

    // -- 4. One real kernel launch through the AOT artifacts (L1+L2+L3).
    match Runtime::load_dir(&artifacts_dir()) {
        Ok(rt) => {
            let dt = rt.exec("vecadd").expect("vecadd launch");
            println!(
                "\nPJRT launch of the vecadd artifact: {:.3} ms (all three layers compose)",
                dt.as_secs_f64() * 1e3
            );
        }
        Err(_) => println!("\n(artifacts/ not built — run `make artifacts` for the PJRT demo)"),
    }
}
