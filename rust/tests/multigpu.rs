//! Multi-GPU platform invariants, end-to-end:
//!
//! 1. **Per-engine isolation** — two tasks on different cores AND
//!    different GPU engines must show zero mutual GPU blocking under
//!    all 9 analysis approaches and all DES policies: each one's
//!    response equals its response when analysed/simulated alone.
//! 2. **Single-GPU golden anchors** — with num_gpus = 1 the redesigned
//!    pipeline must be indistinguishable from the pre-redesign code:
//!    the memo key is pinned, the text export carries no multi-GPU
//!    keys, and the `Analysis`-trait dispatch equals the direct
//!    family-function calls task-for-task.

use gcaps::analysis::{analyze, Analysis, Approach};
use gcaps::model::{config, ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::sweep::memo;
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;

fn gpu_task(id: usize, core: usize, gpu: usize, prio: u32, mode: WaitMode) -> Task {
    Task {
        id,
        name: format!("t{id}"),
        period: ms(100.0),
        deadline: ms(100.0),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(1.0), ms(20.0))],
        core,
        gpu,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode,
    }
}

/// Re-id a single task to index 0 so it can be analysed alone.
fn alone(t: &Task, platform: Platform) -> TaskSet {
    let mut t = t.clone();
    t.id = 0;
    t.core = 0;
    t.gpu = 0;
    TaskSet::new(vec![t], platform)
}

#[test]
fn cross_engine_pairs_have_zero_mutual_blocking_in_all_9_approaches() {
    for approach in Approach::ALL {
        let mode = approach.wait_mode();
        let p2 = Platform::default().with_num_gpus(2);
        let a = gpu_task(0, 0, 0, 2, mode);
        let b = gpu_task(1, 1, 1, 1, mode);
        let pair = TaskSet::new(vec![a.clone(), b.clone()], p2.clone());
        pair.validate().unwrap();
        let res = analyze(&pair, approach);

        let solo_a = analyze(&alone(&a, Platform::default()), approach);
        let solo_b = analyze(&alone(&b, Platform::default()), approach);
        assert_eq!(
            res.response[0],
            solo_a.response[0],
            "{}: task 0 sees cross-engine interference",
            approach.label()
        );
        assert_eq!(
            res.response[1],
            solo_b.response[0],
            "{}: task 1 sees cross-engine interference",
            approach.label()
        );
    }
}

#[test]
fn cross_engine_pairs_have_zero_mutual_blocking_in_the_des() {
    for policy in [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ] {
        let p2 = Platform::default().with_num_gpus(2);
        let a = gpu_task(0, 0, 0, 2, WaitMode::SelfSuspend);
        let b = gpu_task(1, 1, 1, 1, WaitMode::SelfSuspend);
        let pair = TaskSet::new(vec![a.clone(), b.clone()], p2);
        let horizon = ms(1000.0);
        let res = simulate(&pair, &SimConfig::new(policy, horizon));
        let solo_a = simulate(&alone(&a, Platform::default()), &SimConfig::new(policy, horizon));
        let solo_b = simulate(&alone(&b, Platform::default()), &SimConfig::new(policy, horizon));
        assert_eq!(
            res.per_task[0].response_times, solo_a.per_task[0].response_times,
            "{policy:?}: task 0 responses shifted by the cross-engine rival"
        );
        assert_eq!(
            res.per_task[1].response_times, solo_b.per_task[0].response_times,
            "{policy:?}: task 1 responses shifted by the cross-engine rival"
        );
    }
}

#[test]
fn analysis_trait_dispatch_equals_direct_family_calls() {
    // The `Approach` registry is a thin veneer: trait-object dispatch
    // must return bit-identical responses to the direct module calls,
    // single- and multi-GPU alike.
    forall("trait dispatch = direct calls", 20, |rng| {
        for num_gpus in [1usize, 2] {
            let p = GenParams {
                platform: Platform::default().with_num_gpus(num_gpus),
                ..Default::default()
            };
            let ts = generate(rng, &p);
            for a in Approach::ALL {
                let via_trait = a.analysis().analyze(&ts);
                let direct = match a {
                    Approach::GcapsBusy => gcaps::analysis::gcaps::analyze(
                        &ts,
                        true,
                        &gcaps::analysis::gcaps::Options::default(),
                    ),
                    Approach::GcapsSuspend => gcaps::analysis::gcaps::analyze(
                        &ts,
                        false,
                        &gcaps::analysis::gcaps::Options::default(),
                    ),
                    Approach::TsgRrBusy => gcaps::analysis::rr::analyze(&ts, true),
                    Approach::TsgRrSuspend => gcaps::analysis::rr::analyze(&ts, false),
                    Approach::MpcpBusy => gcaps::analysis::mpcp::analyze(&ts, true),
                    Approach::MpcpSuspend => gcaps::analysis::mpcp::analyze(&ts, false),
                    Approach::FmlpBusy => gcaps::analysis::fmlp::analyze(&ts, true),
                    Approach::FmlpSuspend => gcaps::analysis::fmlp::analyze(&ts, false),
                };
                if via_trait.response != direct.response {
                    return Err(format!(
                        "{} (g = {num_gpus}): trait dispatch diverged",
                        a.label()
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn approach_registry_labels_and_modes_are_stable() {
    // CSV schemas depend on these labels; pin them.
    let labels: Vec<&str> = Approach::ALL.iter().map(|a| a.label()).collect();
    assert_eq!(
        labels,
        vec![
            "gcaps_busy",
            "gcaps_suspend",
            "tsg_rr_busy",
            "tsg_rr_suspend",
            "mpcp_busy",
            "mpcp_suspend",
            "fmlp_busy",
            "fmlp_suspend"
        ]
    );
    for a in Approach::ALL {
        assert_eq!(Approach::from_label(a.label()), Some(a));
        assert_eq!(a.is_busy(), a.wait_mode() == WaitMode::BusyWait);
        assert_eq!(a.analysis().wait_mode(), a.wait_mode());
    }
}

#[test]
fn single_gpu_golden_anchors_hold() {
    // (a) The memoized-generator key for the default (1-GPU) params is
    // pinned — this is the value every legacy sweep derived its PCG32
    // streams from, so byte-identical CSVs hinge on it.
    assert_eq!(memo::params_hash(&GenParams::default()), 0x35a4b0478165014b);

    // (b) A 1-GPU export carries none of the new keys (legacy format
    // bytes), and a legacy file parses to a 1-GPU platform with every
    // task on engine 0.
    let mut rng = gcaps::util::rng::Pcg32::seeded(3);
    let ts = generate(&mut rng, &GenParams::default());
    let text = config::to_text(&ts);
    assert!(!text.contains("num_gpus") && !text.contains("[gpu]"));
    let back = config::parse(&text).unwrap();
    assert_eq!(back.platform.num_gpus(), 1);
    assert!(back.tasks.iter().all(|t| t.gpu == 0));
    assert_eq!(back.tasks, ts.tasks);
}

#[test]
fn multigpu_sweep_g1_column_equals_fig8_default_point() {
    // End-to-end: the multigpu experiment's g = 1 column goes through
    // the new trait machinery and the memoized generator, and must land
    // exactly on the Fig. 8 procedure's numbers.
    use gcaps::experiments::{fig8, multigpu, ExpConfig};
    let cfg = ExpConfig { tasksets: 8, seed: 2024, jobs: 2, ..ExpConfig::default() };
    let (xticks, series) = multigpu::run_sweep(&cfg);
    assert_eq!(xticks[0], "1");
    for (k, a) in Approach::ALL.iter().enumerate() {
        let fig8_ratio = fig8::schedulability(*a, &|_| {}, &cfg);
        assert_eq!(series[k].1[0], fig8_ratio, "{} diverged at g = 1", a.label());
    }
}
