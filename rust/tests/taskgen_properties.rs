//! Property tests for the Table 3 taskset generator (taskgen/):
//! structural invariants that must hold for every seed, checked over 200
//! deterministic seeds each (failures reproduce from the printed seed).

use gcaps::model::WaitMode;
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;

/// Largest single-task utilization — the WFD balance slack: worst-fit
/// placement can push a core away from its drawn budget by at most one
/// task's worth of load.
fn max_task_util(ts: &gcaps::model::TaskSet) -> f64 {
    ts.tasks.iter().map(|t| t.utilization()).fold(0.0, f64::max)
}

#[test]
fn validate_holds_for_200_seeds() {
    forall("taskset validity (default params)", 200, |rng| {
        generate(rng, &GenParams::default()).validate()
    });
}

#[test]
fn validate_holds_under_parameter_variations() {
    let variants = [
        GenParams { best_effort_ratio: 0.4, ..Default::default() },
        GenParams { num_cpus: 8, tasks_per_cpu: (2, 3), ..Default::default() },
        GenParams { gpu_task_ratio: (1.0, 1.0), ..Default::default() },
        GenParams { mode: WaitMode::BusyWait, util_per_cpu: (0.2, 0.3), ..Default::default() },
        GenParams { gpu_segments: (3, 3), g_to_c_ratio: (2.0, 2.0), ..Default::default() },
    ];
    for (vi, p) in variants.iter().enumerate() {
        forall(&format!("taskset validity (variant {vi})"), 200, |rng| {
            generate(rng, p).validate()
        });
    }
}

#[test]
fn per_cpu_utilization_lands_in_band_after_wfd() {
    forall("per-CPU utilization band", 200, |rng| {
        let p = GenParams::default();
        let (lo, hi) = p.util_per_cpu;
        let ts = generate(rng, &p);
        let n = ts.platform.num_cpus;

        // The mean per-CPU load equals the mean of the drawn budgets, so
        // it must sit inside the band (small slack: the 100 µs demand
        // floor and µs rounding can only nudge it).
        let total: f64 = (0..n).map(|c| ts.core_utilization(c)).sum();
        let mean = total / n as f64;
        if !(lo - 0.02..=hi + 0.02).contains(&mean) {
            return Err(format!("mean per-CPU util {mean:.3} outside [{lo}, {hi}]"));
        }

        // After WFD re-allocation each core stays within the band up to
        // one task's utilization (worst-fit places every task on the
        // least-loaded core, so no core overshoots by more than the task
        // that landed last, nor undershoots by more).
        let slack = max_task_util(&ts) + 0.02;
        for c in 0..n {
            let u = ts.core_utilization(c);
            if !(lo - slack..=hi + slack).contains(&u) {
                return Err(format!(
                    "core {c} util {u:.3} outside [{lo} - {slack:.3}, {hi} + {slack:.3}]"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn gpu_task_ratio_within_band() {
    forall("GPU-task ratio band", 200, |rng| {
        let p = GenParams::default();
        let (lo, hi) = p.gpu_task_ratio;
        let ts = generate(rng, &p);
        let ratio = ts.num_gpu_tasks() as f64 / ts.len() as f64;
        // The drawn ratio is rounded to a task count per CPU: with ≥3
        // tasks per CPU the rounding error is < 0.5/3 per core.
        let slack = 0.5 / p.tasks_per_cpu.0 as f64;
        if !(lo - slack..=hi + slack).contains(&ratio) {
            return Err(format!(
                "gpu ratio {ratio:.3} outside [{lo} ± {slack:.3} ± {hi}]"
            ));
        }
        Ok(())
    });
}

#[test]
fn gpu_segment_counts_within_band() {
    forall("GPU segment-count band", 200, |rng| {
        let p = GenParams::default();
        let (lo, hi) = p.gpu_segments;
        let ts = generate(rng, &p);
        for t in &ts.tasks {
            if t.uses_gpu() {
                let k = t.eta_g();
                if !(lo..=hi).contains(&k) {
                    return Err(format!("task {}: η_g = {k} outside [{lo}, {hi}]", t.id));
                }
                // Alternation: a GPU job starts and ends on the CPU.
                if t.eta_c() != k + 1 {
                    return Err(format!("task {}: η_c = {} ≠ η_g + 1", t.id, t.eta_c()));
                }
            } else if t.eta_c() != 1 {
                return Err(format!("CPU-only task {} has {} segments", t.id, t.eta_c()));
            }
        }
        Ok(())
    });
}

#[test]
fn wait_mode_and_best_effort_stamping() {
    forall("mode/BE stamping", 100, |rng| {
        let p = GenParams {
            mode: WaitMode::BusyWait,
            best_effort_ratio: 0.3,
            ..Default::default()
        };
        let ts = generate(rng, &p);
        if !ts.tasks.iter().all(|t| t.mode == WaitMode::BusyWait) {
            return Err("wait mode not stamped on every task".into());
        }
        let be = ts.be_tasks().count();
        let expect = (ts.len() as f64 * 0.3).round() as usize;
        if be != expect.min(ts.len().saturating_sub(1)) {
            return Err(format!("{be} best-effort tasks, expected {expect}"));
        }
        if ts.be_tasks().any(|t| t.cpu_prio != 0 || t.gpu_prio != 0) {
            return Err("best-effort task kept an RT priority".into());
        }
        Ok(())
    });
}
