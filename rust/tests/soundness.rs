//! End-to-end soundness: the analytic WCRT bounds (§6) must dominate
//! every simulated execution of the matching policy. This is the
//! strongest cross-validation in the repo: it exercises the taskset
//! generator, all four analyses and all four simulator policies
//! against each other over hundreds of random tasksets.

use gcaps::analysis::{analyze, Approach};
use gcaps::model::{ms, to_ms, TaskSet, Time, WaitMode};
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;
use gcaps::util::rng::Pcg32;

fn policy_of(a: Approach) -> Policy {
    match a {
        Approach::GcapsBusy | Approach::GcapsSuspend => Policy::Gcaps,
        Approach::TsgRrBusy | Approach::TsgRrSuspend => Policy::TsgRr,
        Approach::MpcpBusy | Approach::MpcpSuspend => Policy::Mpcp,
        Approach::FmlpBusy | Approach::FmlpSuspend => Policy::FmlpPlus,
    }
}

/// Simulate `ts` under several release-offset patterns and check every
/// observed response time against the per-task bound.
fn check_sim_under_bound(
    ts: &TaskSet,
    approach: Approach,
    bounds: &[Option<Time>],
    rng: &mut Pcg32,
) -> Result<(), String> {
    let horizon = ts.tasks.iter().map(|t| t.period).max().unwrap() * 6;
    let mut offset_patterns: Vec<Vec<Time>> = vec![vec![0; ts.len()]]; // synchronous
    for _ in 0..2 {
        offset_patterns
            .push(ts.tasks.iter().map(|t| rng.range_u64(0, t.period)).collect());
    }
    for offsets in offset_patterns {
        let cfg = SimConfig::new(policy_of(approach), horizon).with_offsets(offsets.clone());
        let res = simulate(ts, &cfg);
        for t in ts.rt_tasks() {
            let bound = match bounds[t.id] {
                Some(b) => b,
                None => continue, // task not deemed schedulable: no claim
            };
            if let Some(mort) = res.per_task[t.id].mort() {
                if mort > bound {
                    return Err(format!(
                        "{}: task {} ({}): simulated MORT {:.3} ms > WCRT {:.3} ms \
                         (offsets {:?})",
                        approach.label(),
                        t.id,
                        t.name,
                        to_ms(mort),
                        to_ms(bound),
                        offsets
                    ));
                }
            }
            if res.per_task[t.id].deadline_misses > 0 && bound <= t.deadline {
                return Err(format!(
                    "{}: task {} missed a deadline though analysis bounds R at {:.3} ms",
                    approach.label(),
                    t.id,
                    to_ms(bound)
                ));
            }
        }
    }
    Ok(())
}

fn soundness_for(approach: Approach, cases: u64) {
    forall(&format!("sim ≤ WCRT ({})", approach.label()), cases, |rng| {
        let p = GenParams {
            mode: if approach.is_busy() { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
            // Moderate load so a good fraction of sets is schedulable.
            util_per_cpu: (0.25, 0.45),
            ..Default::default()
        };
        let ts = generate(rng, &p);
        let res = analyze(&ts, approach);
        check_sim_under_bound(&ts, approach, &res.response, rng)
    });
}

#[test]
fn gcaps_suspend_bounds_dominate_simulation() {
    soundness_for(Approach::GcapsSuspend, 60);
}

#[test]
fn gcaps_busy_bounds_dominate_simulation() {
    soundness_for(Approach::GcapsBusy, 60);
}

#[test]
fn tsg_rr_suspend_bounds_dominate_simulation() {
    soundness_for(Approach::TsgRrSuspend, 60);
}

#[test]
fn tsg_rr_busy_bounds_dominate_simulation() {
    soundness_for(Approach::TsgRrBusy, 60);
}

#[test]
fn mpcp_suspend_bounds_dominate_simulation() {
    soundness_for(Approach::MpcpSuspend, 40);
}

#[test]
fn mpcp_busy_bounds_dominate_simulation() {
    soundness_for(Approach::MpcpBusy, 40);
}

#[test]
fn fmlp_suspend_bounds_dominate_simulation() {
    soundness_for(Approach::FmlpSuspend, 40);
}

#[test]
fn fmlp_busy_bounds_dominate_simulation() {
    soundness_for(Approach::FmlpBusy, 40);
}

#[test]
fn gcaps_with_audsley_assignment_bounds_dominate() {
    forall("sim ≤ WCRT (gcaps + Audsley)", 40, |rng| {
        let p = GenParams { util_per_cpu: (0.3, 0.5), ..Default::default() };
        let ts = generate(rng, &p);
        let (res, prios) = gcaps::analysis::analyze_with_gpu_prio(&ts, false);
        if !res.schedulable {
            return Ok(());
        }
        // Apply the assignment (if any) to the simulated taskset too.
        let mut ts2 = ts.clone();
        if let Some(prios) = prios {
            for (t, p) in ts2.tasks.iter_mut().zip(prios) {
                t.gpu_prio = p;
            }
        }
        check_sim_under_bound(&ts2, Approach::GcapsSuspend, &res.response, rng)
    });
}

#[test]
fn paper_fig3_shape_gcaps_beats_sync() {
    // Example 1 (Fig. 3): under GCAPS the high-priority task's response
    // is bounded by its own demand + 2ε; under the sync-based approach
    // it additionally eats a lower-priority GPU segment. We reproduce
    // the *shape*: R1(gcaps) + lp_gcs ≤ R1(mpcp_worst_alignment).
    let p = gcaps::model::Platform::single(2, 1024, 50, 250);
    let mk = |id, core, prio, cpu: Vec<f64>, gm: f64, ge: f64, period: f64| gcaps::model::Task {
        id,
        name: format!("tau{}", id + 1),
        period: ms(period),
        deadline: ms(period),
        cpu_segments: cpu.into_iter().map(ms).collect(),
        gpu_segments: vec![gcaps::model::GpuSegment::new(ms(gm), ms(ge))],
        core,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let tasks = vec![
        mk(0, 0, 3, vec![1.0, 1.0], 0.25, 1.5, 20.0),
        mk(1, 1, 2, vec![0.5, 0.5], 0.25, 2.0, 20.0),
        mk(2, 1, 1, vec![0.2, 0.5], 0.25, 2.5, 20.0),
    ];
    let ts = TaskSet::new(tasks, p);
    // τ3 starts its 2.5 ms gcs at t = 0.2; τ1's GPU request lands at
    // t = 1.0, well inside it — the sync approach must wait out the
    // remainder (~1.7 ms), GCAPS preempts within ~ε.
    let offsets = vec![0, ms(5.0), 0];
    let g = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(20.0)).with_offsets(offsets.clone()));
    let m = simulate(&ts, &SimConfig::new(Policy::Mpcp, ms(20.0)).with_offsets(offsets));
    let r_gcaps = g.per_task[0].mort().unwrap();
    let r_mpcp = m.per_task[0].mort().unwrap();
    assert!(
        r_gcaps + ms(1.0) <= r_mpcp,
        "gcaps R1 = {} µs should undercut sync R1 = {} µs by ≥ 1 ms",
        r_gcaps,
        r_mpcp
    );
}
