//! `gcaps lint` end-to-end: fixture trees through `lint_tree`, the
//! allow-comment and `#[cfg(test)]` escape hatches, baseline round-
//! tripping, and — the teeth — the self-clean check: linting this
//! crate's own `src/` must reproduce the committed
//! `lint_baseline.txt` byte-for-byte. A new violation anywhere in the
//! tree fails `cargo test` before it ever reaches CI's lint job.

use std::fs;
use std::path::{Path, PathBuf};

use gcaps::lint::{self, baseline, diff_baseline, Finding};

/// Build a throwaway source tree under the OS temp dir.
struct Fixture {
    root: PathBuf,
}

impl Fixture {
    fn new(name: &str) -> Fixture {
        let root = std::env::temp_dir().join(format!("gcaps_lint_{name}_{}", std::process::id()));
        if root.exists() {
            fs::remove_dir_all(&root).unwrap();
        }
        fs::create_dir_all(&root).unwrap();
        Fixture { root }
    }

    fn write(&self, rel: &str, text: &str) -> &Self {
        let path = self.root.join(rel);
        fs::create_dir_all(path.parent().unwrap()).unwrap();
        fs::write(path, text).unwrap();
        self
    }

    fn lint(&self) -> Vec<Finding> {
        lint::lint_all(&self.root).unwrap()
    }
}

impl Drop for Fixture {
    fn drop(&mut self) {
        let _ = fs::remove_dir_all(&self.root);
    }
}

fn keys(findings: &[Finding]) -> Vec<(String, u32, &'static str)> {
    findings.iter().map(|f| (f.file.clone(), f.line, f.rule)).collect()
}

#[test]
fn each_rule_catches_its_fixture() {
    let fx = Fixture::new("catch");
    // The exact regression that motivated time-arith: PR 4's bare
    // `release + deadline` back in sim/engine.rs.
    fx.write(
        "sim/engine.rs",
        "fn f(release: Time, deadline: Time) -> Time {\n    release + deadline\n}\n",
    );
    fx.write("serve/server.rs", "fn g(v: &[u32]) -> u32 {\n    v[0]\n}\n");
    fx.write(
        "sweep/cells.rs",
        "fn h() {\n    let mut m = HashMap::new();\n    for (k, v) in &m {\n        use_it(k, v);\n    }\n}\n",
    );
    fx.write("runtime/pjrt.rs", "fn i() {\n    let g = m.lock().unwrap();\n}\n");
    fx.write("experiments/sweeps.rs", "fn j() {\n    let t = Instant::now();\n}\n");
    let found = keys(&fx.lint());
    assert_eq!(
        found,
        vec![
            ("experiments/sweeps.rs".to_string(), 2, "wall-clock"),
            ("runtime/pjrt.rs".to_string(), 2, "lock-hygiene"),
            ("serve/server.rs".to_string(), 2, "panic-path"),
            ("sim/engine.rs".to_string(), 2, "time-arith"),
            ("sweep/cells.rs".to_string(), 3, "det-iter"),
        ]
    );
}

#[test]
fn allow_comment_suppresses_each_rule() {
    let fx = Fixture::new("allow");
    fx.write(
        "sim/engine.rs",
        "fn f(release: Time, deadline: Time) -> Time {\n    \
         // gcaps-lint: allow(time-arith) -- proven bounded by validate()\n    \
         release + deadline\n}\n",
    );
    fx.write(
        "serve/server.rs",
        "fn g(v: &[u32]) -> u32 {\n    v[0] // gcaps-lint: allow(panic-path) -- len checked above\n}\n",
    );
    fx.write(
        "sweep/cells.rs",
        "fn h() {\n    let mut m = HashMap::new();\n    \
         // gcaps-lint: allow(det-iter) -- order folded through a commutative sum\n    \
         for (k, v) in &m {\n        use_it(k, v);\n    }\n}\n",
    );
    fx.write(
        "runtime/pjrt.rs",
        "fn i() {\n    let g = m.lock().unwrap(); // gcaps-lint: allow(lock-hygiene) -- single-threaded\n}\n",
    );
    fx.write(
        "experiments/sweeps.rs",
        "fn j() {\n    let t = Instant::now(); // gcaps-lint: allow(wall-clock) -- progress only\n}\n",
    );
    assert_eq!(keys(&fx.lint()), Vec::<(String, u32, &str)>::new());
}

#[test]
fn allow_comment_without_reason_does_not_suppress() {
    let fx = Fixture::new("noreason");
    fx.write(
        "experiments/sweeps.rs",
        "fn j() {\n    let t = Instant::now(); // gcaps-lint: allow(wall-clock)\n}\n",
    );
    let found = fx.lint();
    assert_eq!(found.len(), 1, "an allow without `-- reason` must not count");
    assert_eq!(found[0].rule, "wall-clock");
}

#[test]
fn cfg_test_code_is_exempt() {
    let fx = Fixture::new("cfgtest");
    fx.write(
        "serve/server.rs",
        "fn live() -> u32 { 0 }\n\
         #[cfg(test)]\n\
         mod tests {\n    \
         fn g(v: &[u32]) -> u32 {\n        v[0] + h().unwrap() + i().lock().unwrap()\n    }\n\
         }\n",
    );
    fx.write(
        "sim/engine.rs",
        "fn live() -> u32 { 0 }\n\
         #[test]\n\
         fn t(release: Time, deadline: Time) -> Time {\n    release + deadline\n}\n",
    );
    assert_eq!(keys(&fx.lint()), Vec::<(String, u32, &str)>::new());
}

#[test]
fn rule_filter_runs_only_the_selected_rule() {
    let fx = Fixture::new("filter");
    fx.write(
        "sim/engine.rs",
        "fn f(release: Time, deadline: Time) {\n    let x = release + deadline;\n    let t = Instant::now();\n}\n",
    );
    let only: Vec<Box<dyn lint::Rule>> = lint::all_rules()
        .into_iter()
        .filter(|r| r.id() == "wall-clock")
        .collect();
    let found = lint::lint_tree(&fx.root, &only).unwrap();
    assert_eq!(keys(&found), vec![("sim/engine.rs".to_string(), 3, "wall-clock")]);
}

#[test]
fn baseline_round_trip_is_exact() {
    let fx = Fixture::new("baseline");
    fx.write("serve/server.rs", "fn g(v: &[u32]) -> u32 {\n    v[0]\n}\n");
    fx.write(
        "sim/engine.rs",
        "fn f(release: Time, deadline: Time) -> Time {\n    release + deadline\n}\n",
    );
    let findings = fx.lint();
    assert_eq!(findings.len(), 2);

    let path = fx.root.join("lint_baseline.txt");
    baseline::write(&path, &findings).unwrap();
    let loaded = baseline::load(&path).unwrap();
    let (new, stale) = diff_baseline(&findings, &loaded);
    assert!(new.is_empty(), "round-tripped baseline missed {new:?}");
    assert!(stale.is_empty(), "round-tripped baseline grew {stale:?}");

    // Byte-level: rendering the same findings reproduces the file.
    assert_eq!(fs::read_to_string(&path).unwrap(), baseline::render(&findings));

    // A brand-new finding is NOT absorbed...
    fx.write("serve/extra.rs", "fn h() { boom().unwrap(); }\n");
    let (new, stale) = diff_baseline(&fx.lint(), &loaded);
    assert_eq!(new.len(), 1);
    assert_eq!(new[0].file, "serve/extra.rs");
    assert!(stale.is_empty());

    // ...and a fixed finding turns stale instead of lingering silently.
    fx.write("serve/server.rs", "fn g(v: &[u32]) -> Option<u32> {\n    v.first().copied()\n}\n");
    fx.write("serve/extra.rs", "fn h() {}\n");
    let (new, stale) = diff_baseline(&fx.lint(), &loaded);
    assert!(new.is_empty());
    assert_eq!(stale.len(), 1, "{stale:?}");
    assert!(stale[0].starts_with("serve/server.rs:"));
}

/// The tentpole contract: this crate's own sources lint clean against
/// the committed baseline, and the baseline is exactly what
/// `--write-baseline` would regenerate — no drift in either direction.
#[test]
fn src_tree_is_lint_clean_and_baseline_is_current() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = lint::lint_all(&manifest.join("src")).unwrap();
    let committed_path = manifest.join("lint_baseline.txt");
    let committed = baseline::load(&committed_path).unwrap();

    let (new, stale) = diff_baseline(&findings, &committed);
    assert!(
        new.is_empty(),
        "new lint findings not in lint_baseline.txt — fix them, add a \
         `// gcaps-lint: allow(rule) -- reason`, or regenerate with \
         `gcaps lint --write-baseline`:\n{}",
        new.iter().map(|f| f.render()).collect::<Vec<_>>().join("\n")
    );
    assert!(
        stale.is_empty(),
        "stale lint_baseline.txt entries (already fixed — regenerate with \
         `gcaps lint --write-baseline`):\n{}",
        stale.join("\n")
    );
    assert_eq!(
        fs::read_to_string(&committed_path).unwrap(),
        baseline::render(&findings),
        "lint_baseline.txt is not byte-identical to a fresh --write-baseline run"
    );
}
