//! Smoke tests: every registered experiment runs end-to-end at tiny
//! scale through the `Experiment` registry and produces its artifacts.
//! Keeps `gcaps exp all` from bit-rotting.

use gcaps::api::{self, SinkSpec};
use gcaps::experiments::casestudy::{fig10_render, fig11_render, table5_render, Board};
use gcaps::experiments::examples_figs::{run_fig3, run_fig5, run_fig6, run_fig7};
use gcaps::experiments::fig8::Panel;
use gcaps::experiments::{results_dir, ExpConfig, Opts};

fn tiny() -> ExpConfig {
    ExpConfig { tasksets: 5, seed: 123, ..ExpConfig::default() }
}

fn run_csv(name: &str, cfg: &ExpConfig) -> gcaps::api::ExpReport {
    let spec = SinkSpec { csv: true, ascii: true, dir: None, ..SinkSpec::default() };
    api::run(name, cfg, &spec).expect(name)
}

#[test]
fn schedule_examples_render() {
    for out in [run_fig3(), run_fig5(), run_fig6(), run_fig7()] {
        assert!(out.contains("Fig."), "missing header in: {out}");
        assert!(out.contains('|'), "no gantt rows rendered");
    }
}

#[test]
fn schedule_example_experiments_emit_ascii_only() {
    for name in ["fig3", "fig5", "fig6", "fig7"] {
        let report = run_csv(name, &tiny());
        assert!(report.tables.is_empty(), "{name} should emit no tables");
        assert!(report.ascii.contains("Fig."), "{name}: {}", report.ascii);
    }
}

#[test]
fn fig8_all_panels_produce_csv() {
    let report = run_csv("fig8", &tiny());
    assert!(report.ascii.contains("Fig. 8"));
    assert_eq!(report.tables.len(), Panel::ALL.len(), "one table per panel");
    for panel in Panel::ALL {
        let path = results_dir().join(format!("fig8{}.csv", panel.letter()));
        let csv = std::fs::read_to_string(&path).expect("csv written");
        // Header + 9 approaches × #points rows.
        assert!(csv.lines().count() > 9, "{path:?} too small");
    }
}

#[test]
fn fig8_single_panel_option_narrows_the_run() {
    let cfg = ExpConfig { opts: Opts::default().set("panel", "b"), ..tiny() };
    let report = run_csv("fig8", &cfg);
    assert_eq!(report.tables.len(), 1);
    assert_eq!(report.tables[0].name, "fig8b");
}

#[test]
fn fig9_produces_csv() {
    let report = run_csv("fig9", &tiny());
    assert!(report.ascii.contains("Fig. 9"));
    assert!(results_dir().join("fig9.csv").exists());
    assert_eq!(report.tables[0].columns, vec!["series", "util_per_cpu", "schedulable_ratio"]);
}

#[test]
fn case_study_harnesses_run() {
    let cfg = ExpConfig { tasksets: 0, seed: 1, ..ExpConfig::default() };
    let (stem, _, f10) = fig10_render(Board::XavierNx, &cfg);
    assert_eq!(stem, "fig10_xavier");
    assert!(f10.contains("MORT under gcaps_busy"));
    let (_, f11) = fig11_render(&cfg);
    assert!(f11.contains("average relative range"));
    let (t5_csv, t5) = table5_render(&cfg);
    assert!(t5.contains("Table 5"));
    assert!(t5.contains("histogram"));
    assert!(!t5_csv.rows.is_empty());
}

#[test]
fn fig10_experiment_covers_both_boards_by_default() {
    let cfg = ExpConfig { tasksets: 0, seed: 1, ..ExpConfig::default() };
    let report = run_csv("fig10", &cfg);
    let names: Vec<&str> = report.tables.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["fig10_xavier", "fig10_orin"]);

    let orin_only = ExpConfig { opts: Opts::default().set("board", "orin"), ..cfg };
    let report = run_csv("fig10", &orin_only);
    let names: Vec<&str> = report.tables.iter().map(|t| t.name.as_str()).collect();
    assert_eq!(names, vec!["fig10_orin"]);
}

#[test]
fn overhead_harnesses_run() {
    let cfg = ExpConfig { tasksets: 0, seed: 1, ..tiny() };
    let f12 = run_csv("fig12", &cfg);
    assert!(f12.ascii.contains("Fig. 12"));
    assert_eq!(f12.tables[0].name, "fig12_sim");
    let f13 = run_csv("fig13", &tiny());
    assert!(f13.ascii.contains("Fig. 13"));
    assert!(results_dir().join("fig13.csv").exists());
}

#[test]
fn examples_aggregate_runs() {
    let report = run_csv("examples", &tiny());
    for fig in ["Fig. 3", "Fig. 5", "Fig. 6", "Fig. 7"] {
        assert!(report.ascii.contains(fig), "{fig} missing from examples aggregate");
    }
}

#[test]
fn ablation_harness_runs() {
    let report = run_csv("ablation", &tiny());
    assert!(report.ascii.contains("Lemma 12"));
    assert!(report.ascii.contains("EDF"));
    assert!(results_dir().join("ablations.csv").exists());
}

#[test]
fn multigpu_harness_runs() {
    let report = run_csv("multigpu", &tiny());
    assert!(report.ascii.contains("Multi-GPU"));
    let path = results_dir().join("multigpu.csv");
    let csv = std::fs::read_to_string(&path).expect("csv written");
    // Header + 9 approaches × 3 GPU counts.
    assert_eq!(csv.lines().count(), 1 + 9 * 3, "unexpected row count:\n{csv}");
    assert!(csv.lines().next().unwrap().contains("num_gpus"));
    assert_eq!(report.tables[0].rows, 9 * 3);
}

#[test]
fn scenarios_harness_produces_all_three_csvs() {
    let report = run_csv(
        "scenarios",
        &ExpConfig { tasksets: 3, seed: 77, ..ExpConfig::default() },
    );
    assert!(report.ascii.contains("Scenarios (a)"));
    assert!(report.ascii.contains("Scenarios (b)"));
    assert!(report.ascii.contains("Scenarios (c)"));
    for (file, min_lines) in [
        ("scenarios_epstheta.csv", 24),
        ("scenarios_edfvfp.csv", 16),
        ("scenarios_hetero.csv", 27),
    ] {
        let path = results_dir().join(file);
        let csv = std::fs::read_to_string(&path).expect("csv written");
        assert!(csv.lines().count() > min_lines, "{path:?} too small:\n{csv}");
    }
    assert_eq!(report.tables.len(), 3);
    assert_eq!(report.outputs.len(), 3);
}
