//! Smoke tests: every experiment harness runs end-to-end at tiny scale
//! and produces its CSV. Keeps `gcaps exp all` from bit-rotting.

use gcaps::experiments::ablation;
use gcaps::experiments::casestudy::{run_fig10, run_fig11, run_table5, Board};
use gcaps::experiments::examples_figs::{run_fig3, run_fig5, run_fig6, run_fig7};
use gcaps::experiments::fig8::{run_and_report as fig8, Panel};
use gcaps::experiments::fig9;
use gcaps::experiments::overhead::{run_fig12_sim, run_fig13};
use gcaps::experiments::{results_dir, ExpConfig};

fn tiny() -> ExpConfig {
    ExpConfig { tasksets: 5, seed: 123, ..ExpConfig::default() }
}

#[test]
fn schedule_examples_render() {
    for out in [run_fig3(), run_fig5(), run_fig6(), run_fig7()] {
        assert!(out.contains("Fig."), "missing header in: {out}");
        assert!(out.contains('|'), "no gantt rows rendered");
    }
}

#[test]
fn fig8_all_panels_produce_csv() {
    for panel in Panel::ALL {
        let out = fig8(panel, &tiny());
        assert!(out.contains("Fig. 8"));
        let path = results_dir().join(format!("fig8{}.csv", panel.letter()));
        let csv = std::fs::read_to_string(&path).expect("csv written");
        // Header + 8 approaches × #points rows.
        assert!(csv.lines().count() > 8, "{path:?} too small");
    }
}

#[test]
fn fig9_produces_csv() {
    let out = fig9::run_and_report(&tiny());
    assert!(out.contains("Fig. 9"));
    assert!(results_dir().join("fig9.csv").exists());
}

#[test]
fn case_study_harnesses_run() {
    let cfg = ExpConfig { tasksets: 0, seed: 1, ..ExpConfig::default() };
    let f10 = run_fig10(Board::XavierNx, &cfg);
    assert!(f10.contains("MORT under gcaps_busy"));
    let f11 = run_fig11(&cfg);
    assert!(f11.contains("average relative range"));
    let t5 = run_table5(&cfg);
    assert!(t5.contains("Table 5"));
    assert!(t5.contains("histogram"));
}

#[test]
fn overhead_harnesses_run() {
    assert!(run_fig12_sim().contains("Fig. 12"));
    assert!(run_fig13(&tiny()).contains("Fig. 13"));
}

#[test]
fn examples_aggregate_runs() {
    use gcaps::experiments::examples_figs::run_examples;
    let out = run_examples(&tiny());
    for fig in ["Fig. 3", "Fig. 5", "Fig. 6", "Fig. 7"] {
        assert!(out.contains(fig), "{fig} missing from examples aggregate");
    }
}

#[test]
fn ablation_harness_runs() {
    let out = ablation::run_and_report(&tiny());
    assert!(out.contains("Lemma 12"));
    assert!(out.contains("EDF"));
    assert!(results_dir().join("ablations.csv").exists());
}

#[test]
fn multigpu_harness_runs() {
    let out = gcaps::experiments::multigpu::run_and_report(&tiny());
    assert!(out.contains("Multi-GPU"));
    let path = results_dir().join("multigpu.csv");
    let csv = std::fs::read_to_string(&path).expect("csv written");
    // Header + 8 approaches × 3 GPU counts.
    assert_eq!(csv.lines().count(), 1 + 8 * 3, "unexpected row count:\n{csv}");
    assert!(csv.lines().next().unwrap().contains("num_gpus"));
}

#[test]
fn scenarios_harness_produces_all_three_csvs() {
    let out = gcaps::experiments::scenarios::run_and_report(
        &ExpConfig { tasksets: 3, seed: 77, ..ExpConfig::default() },
        None,
    );
    assert!(out.contains("Scenarios (a)"));
    assert!(out.contains("Scenarios (b)"));
    assert!(out.contains("Scenarios (c)"));
    for (file, min_lines) in [
        ("scenarios_epstheta.csv", 24),
        ("scenarios_edfvfp.csv", 16),
        ("scenarios_hetero.csv", 27),
    ] {
        let path = results_dir().join(file);
        let csv = std::fs::read_to_string(&path).expect("csv written");
        assert!(csv.lines().count() > min_lines, "{path:?} too small:\n{csv}");
    }
}
