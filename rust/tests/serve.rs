//! `gcaps serve` protocol robustness and transcript stability.
//!
//! - The committed golden transcript (`tests/data/serve_golden.jsonl`)
//!   is pinned byte-for-byte against the scripted query stream — the
//!   same pair of files the CI `serve-smoke` job pipes through the
//!   release binary.
//! - Hostile-input properties: the JSON parser and the full request
//!   loop never panic on malformed, truncated, mutated or oversized
//!   input — every bad line yields an `{"ok":false,...}` response
//!   line (exit code 2 is reserved for startup errors, which never
//!   arise here).

use gcaps::analysis::Approach;
use gcaps::model::Platform;
use gcaps::serve::json::{parse, Value};
use gcaps::serve::{run, ServeConfig, Session, MAX_LINE};
use gcaps::util::check::forall;
use gcaps::util::rng::Pcg32;
use std::io::Cursor;

const SCRIPT: &str = include_str!("data/serve_script.jsonl");
const GOLDEN: &str = include_str!("data/serve_golden.jsonl");
const OVERLOAD_SCRIPT: &str = include_str!("data/serve_overload_script.jsonl");
const OVERLOAD_GOLDEN: &str = include_str!("data/serve_overload_golden.jsonl");

fn default_config() -> ServeConfig {
    ServeConfig {
        platform: Platform::default(),
        approach: Approach::GcapsSuspend,
        timing: false,
    }
}

fn serve_bytes(cfg: &ServeConfig, input: &[u8]) -> String {
    let mut session = cfg.session();
    let mut out = Vec::new();
    run(&mut session, Cursor::new(input), &mut out).unwrap();
    String::from_utf8(out).unwrap()
}

#[test]
fn golden_transcript_is_byte_stable() {
    // Same comparison the CI serve-smoke job makes against the release
    // binary; any analysis or wire-format drift must update the golden
    // file (and is therefore reviewed).
    let out = serve_bytes(&default_config(), SCRIPT.as_bytes());
    for (i, (got, want)) in out.lines().zip(GOLDEN.lines()).enumerate() {
        assert_eq!(got, want, "transcript line {} diverged", i + 1);
    }
    assert_eq!(out, GOLDEN);
}

#[test]
fn overload_golden_transcript_is_byte_stable() {
    // Degraded-mode ops (admit_best_effort, report_overload) and the
    // conditional overload stats block, pinned the same way the CI
    // overload-smoke job pins them against the release binary.
    let out = serve_bytes(&default_config(), OVERLOAD_SCRIPT.as_bytes());
    for (i, (got, want)) in out.lines().zip(OVERLOAD_GOLDEN.lines()).enumerate() {
        assert_eq!(got, want, "overload transcript line {} diverged", i + 1);
    }
    assert_eq!(out, OVERLOAD_GOLDEN);
}

#[test]
fn every_response_line_is_valid_json() {
    let out = serve_bytes(&default_config(), SCRIPT.as_bytes());
    for line in out.lines() {
        let v = parse(line).unwrap_or_else(|e| panic!("unparsable response {line:?}: {e}"));
        assert!(v.get("ok").and_then(Value::as_bool).is_some(), "{line}");
    }
}

#[test]
fn parser_never_panics_on_random_bytes() {
    // Charset biased toward JSON structure so the fuzz actually reaches
    // the deep parser paths (strings, escapes, numbers, nesting).
    const CHARS: &[u8] = br#"{}[]":,0123456789eE+-.\ anulltrefsopxu"#;
    forall("json parser total on random bytes", 500, |rng| {
        let len = rng.range_u64(0, 64) as usize;
        let bytes: Vec<u8> = (0..len)
            .map(|_| CHARS[rng.range_usize(0, CHARS.len() - 1)])
            .collect();
        let text = String::from_utf8_lossy(&bytes);
        // Ok or Err both fine; what is forbidden is a panic.
        let _ = parse(&text);
        Ok(())
    });
}

#[test]
fn mutated_valid_requests_never_panic_the_session() {
    // Take each scripted line, flip a few random bytes, and drive the
    // full session: the response must still be a single JSON line.
    let lines: Vec<&str> = SCRIPT.lines().collect();
    forall("session total on mutated requests", 300, |rng| {
        let mut session = default_config().session();
        let base = lines[rng.range_usize(0, lines.len() - 1)];
        let mut bytes = base.as_bytes().to_vec();
        for _ in 0..=rng.range_u64(0, 3) {
            let i = rng.range_usize(0, bytes.len() - 1);
            bytes[i] = rng.range_u64(0x20, 0x7f) as u8;
        }
        let text = String::from_utf8_lossy(&bytes).into_owned();
        let (resp, _) = session.handle_line(&text);
        let line = resp.to_json();
        if parse(&line).is_err() || line.contains('\n') {
            return Err(format!("bad response {line:?} for input {text:?}"));
        }
        Ok(())
    });
}

#[test]
fn parser_roundtrips_generated_values() {
    fn gen(rng: &mut Pcg32, depth: usize) -> Value {
        // Leaves only at depth ≥ 3 so trees stay small.
        match if depth >= 3 { rng.range_u64(0, 3) } else { rng.range_u64(0, 5) } {
            0 => Value::Null,
            1 => Value::Bool(rng.range_u64(0, 1) == 0),
            2 => Value::Num((rng.range_u64(0, 2_000_000) as f64 - 1_000_000.0) / 8.0),
            3 => {
                let n = rng.range_u64(0, 8) as usize;
                Value::Str(
                    (0..n)
                        .map(|_| {
                            char::from_u32(rng.range_u64(1, 0xD7FF) as u32).unwrap_or('?')
                        })
                        .collect(),
                )
            }
            4 => Value::Arr((0..rng.range_u64(0, 3)).map(|_| gen(rng, depth + 1)).collect()),
            _ => Value::Obj(
                (0..rng.range_u64(0, 3))
                    .map(|i| (format!("k{i}"), gen(rng, depth + 1)))
                    .collect(),
            ),
        }
    }
    forall("parse(to_json(v)) == v", 300, |rng| {
        let v = gen(rng, 0);
        let text = v.to_json();
        match parse(&text) {
            Ok(back) if back == v => Ok(()),
            Ok(back) => Err(format!("{text}: reparsed as {back:?}")),
            Err(e) => Err(format!("{text}: {e}")),
        }
    });
}

#[test]
fn oversized_request_line_is_rejected_and_recovered() {
    let mut input = Vec::new();
    input.extend_from_slice(b"{\"op\":\"admit\",\"pad\":\"");
    input.extend_from_slice(&vec![b'x'; MAX_LINE + 10]);
    input.extend_from_slice(b"\"}\n{\"op\":\"stats\"}\n");
    let out = serve_bytes(&default_config(), &input);
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 2, "{out}");
    assert!(lines[0].starts_with(r#"{"ok":false"#) && lines[0].contains("exceeds"), "{}", lines[0]);
    assert!(lines[1].contains(r#""errors":1"#), "oversize filed as an error: {}", lines[1]);
}

#[test]
fn non_utf8_input_is_an_error_response_not_a_panic() {
    let input = b"{\"op\":\xff\xfe}\n{\"op\":\"check\"}\n".to_vec();
    let out = serve_bytes(&default_config(), &input);
    let lines: Vec<&str> = out.lines().collect();
    assert_eq!(lines.len(), 2);
    assert!(lines[0].starts_with(r#"{"ok":false"#), "{}", lines[0]);
    assert!(lines[1].contains(r#""schedulable":true"#), "{}", lines[1]);
}

#[test]
fn transcript_is_identical_across_repeat_runs() {
    // A fresh session must reproduce the transcript exactly — no hidden
    // global state (the sweep memo cache is keyed elsewhere).
    let a = serve_bytes(&default_config(), SCRIPT.as_bytes());
    let b = serve_bytes(&default_config(), SCRIPT.as_bytes());
    assert_eq!(a, b);
}

#[test]
fn shutdown_is_honored_mid_stream_for_every_approach() {
    for approach in Approach::ALL {
        let cfg = ServeConfig { platform: Platform::default(), approach, timing: false };
        let input = format!(
            "{}\n{}\n{}\n",
            r#"{"op":"admit","task":{"name":"t","period_ms":100,"cpu_ms":[1],"prio":1}}"#,
            r#"{"op":"shutdown"}"#,
            r#"{"op":"stats"}"#
        );
        let out = serve_bytes(&cfg, input.as_bytes());
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2, "{}: {out}", approach.label());
        assert!(lines[0].contains(r#""admitted":true"#), "{}", approach.label());
        assert_eq!(lines[1], r#"{"ok":true,"op":"shutdown"}"#, "{}", approach.label());
    }
}

#[test]
fn pipelined_read_queries_answer_in_submission_order() {
    // A burst of buffered check/headroom queries fans out through the
    // sharded sweep pool, yet the transcript must be byte-identical to
    // serving the same lines one at a time on the live session —
    // answers in submission order, counters included.
    let script = [
        r#"{"op":"admit","task":{"name":"a","period_ms":100,"cpu_ms":[1,1],"gpu_ms":[[0.5,2]],"core":0,"prio":1}}"#,
        r#"{"op":"admit","task":{"name":"b","period_ms":50,"cpu_ms":[2],"core":1,"prio":2}}"#,
        // Interleaved read burst: all buffered before the server acts,
        // so they batch through Session::answer_reads.
        r#"{"op":"check"}"#,
        r#"{"op":"headroom","task":"a","param":"c"}"#,
        r#"{"op":"headroom","task":"a","param":"ge"}"#,
        r#"{"op":"headroom","task":"b","param":"c"}"#,
        r#"{"op":"headroom","task":"ghost","param":"c"}"#,
        r#"{"op":"headroom","task":"b","param":"ge"}"#,
        r#"{"op":"check"}"#,
        // A commit serializes, then a second burst.
        r#"{"op":"remove","task":"a"}"#,
        r#"{"op":"check"}"#,
        r#"{"op":"headroom","task":"b","param":"c"}"#,
        r#"{"op":"stats"}"#,
    ];
    let input = script.join("\n") + "\n";
    let batched = serve_bytes(&default_config(), input.as_bytes());

    // Serial oracle: one handle_line per request, no batching.
    let mut session = default_config().session();
    let mut serial = String::new();
    for line in script {
        let (v, _) = session.handle_line(line);
        serial.push_str(&v.to_json());
        serial.push('\n');
    }
    assert_eq!(batched, serial, "batched reads drifted from serial service");

    // Submission order is visible in the response tags themselves.
    let resp: Vec<&str> = batched.lines().collect();
    assert_eq!(resp.len(), script.len());
    assert!(resp[2].contains(r#""op":"check""#), "{}", resp[2]);
    for (i, task, param) in [(3, "a", "c"), (4, "a", "ge"), (5, "b", "c")] {
        assert!(
            resp[i].contains(&format!(r#""task":"{task}""#))
                && resp[i].contains(&format!(r#""param":"{param}""#)),
            "response {i} out of order: {}",
            resp[i]
        );
    }
    assert!(resp[6].starts_with(r#"{"ok":false"#) && resp[6].contains("ghost"), "{}", resp[6]);
    assert!(resp[7].starts_with(r#"{"ok":false"#) && resp[7].contains("no GPU"), "{}", resp[7]);
    assert!(resp[9].contains(r#""removed":true"#), "{}", resp[9]);
    // The in-batch errors were folded back into the shared counters.
    assert!(resp[12].contains(r#""errors":2"#), "{}", resp[12]);
}

#[test]
fn fine_grain_admit_flows_through_the_session() {
    let mut session = default_config().session();
    let (v, _) = session.handle_line(
        r#"{"op":"admit","task":{"name":"cam","period_ms":100,"cpu_ms":[1,1],"gpu_ms":[[0.5,5]],"par":[40],"prio":10}}"#,
    );
    assert!(v.to_json().contains(r#""admitted":true"#), "{}", v.to_json());
    let (v, _) = session.handle_line(r#"{"op":"check"}"#);
    assert!(v.to_json().contains(r#""schedulable":true"#), "{}", v.to_json());
    let (v, _) = session.handle_line(r#"{"op":"headroom","task":"cam","param":"ge"}"#);
    assert!(v.to_json().contains(r#""ok":true"#), "{}", v.to_json());
    // A hostile fraction on a later admit answers on-stream and leaves
    // the session serving.
    let (v, _) = session.handle_line(
        r#"{"op":"admit","task":{"name":"bad","period_ms":100,"cpu_ms":[1,1],"gpu_ms":[[0.5,5]],"par":[0],"prio":11}}"#,
    );
    assert!(v.to_json().starts_with(r#"{"ok":false"#), "{}", v.to_json());
    let (v, _) = session.handle_line(r#"{"op":"stats"}"#);
    assert!(v.to_json().contains(r#""ok":true"#), "{}", v.to_json());
}

#[test]
fn fuzzed_par_arrays_never_panic_the_session() {
    // Hostile fine-grain fractions: random lengths and value shapes
    // (in-range, out-of-range, fractional, negative, non-numeric).
    // Every admit must answer one JSON line — accepted or refused.
    forall("session total on fuzzed par", 300, |rng| {
        let mut session = default_config().session();
        let n_seg = rng.range_usize(1, 3);
        let gpu_ms: Vec<String> = (0..n_seg).map(|_| "[0.5,2]".to_string()).collect();
        let n_par = rng.range_usize(0, 5);
        let par: Vec<String> = (0..n_par)
            .map(|_| match rng.range_u64(0, 5) {
                0 => rng.range_u64(1, 100).to_string(),
                1 => "0".to_string(),
                2 => rng.range_u64(101, 1_000_000).to_string(),
                3 => format!("{:.2}", rng.range_f64(-50.0, 150.0)),
                4 => format!("-{}", rng.range_u64(1, 100)),
                _ => "\"full\"".to_string(),
            })
            .collect();
        let line = format!(
            r#"{{"op":"admit","task":{{"name":"t","period_ms":100,"cpu_ms":[{}],"gpu_ms":[{}],"par":[{}],"prio":1}}}}"#,
            vec!["1"; n_seg + 1].join(","),
            gpu_ms.join(","),
            par.join(",")
        );
        let (resp, _) = session.handle_line(&line);
        let out = resp.to_json();
        if parse(&out).is_err() || out.contains('\n') {
            return Err(format!("bad response {out:?} for input {line:?}"));
        }
        // The session must keep serving afterwards.
        let (v, _) = session.handle_line(r#"{"op":"check"}"#);
        if parse(&v.to_json()).is_err() {
            return Err(format!("check broke after {line:?}"));
        }
        Ok(())
    });
}

#[test]
fn session_survives_a_panicking_sibling_thread() {
    // The server is long-running: a panic on another thread (e.g. a
    // background sweep poisoning the memo cache) must not take future
    // queries down with it. Session state is thread-local by design,
    // so this pins the zero-shared-state property end to end.
    let mut session = default_config().session();
    let (v, _) = session.handle_line(
        r#"{"op":"admit","task":{"name":"t","period_ms":100,"cpu_ms":[1],"prio":1}}"#,
    );
    assert!(v.to_json().contains(r#""admitted":true"#));
    let t = std::thread::spawn(|| panic!("sibling dies"));
    assert!(t.join().is_err());
    let (v, _) = session.handle_line(r#"{"op":"check"}"#);
    assert!(v.to_json().contains(r#""schedulable":true"#));
}
