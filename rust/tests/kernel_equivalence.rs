//! The perf-kernel contract, enforced end-to-end:
//!
//! 1. **RTA kernel ≡ naive reference** — every analysis family (and the
//!    full Fig. 8 GCAPS procedure incl. the Audsley search) must return
//!    bit-identical responses through the precomputed `Prepared` kernel
//!    and through the retained iterator-chain reference path, over
//!    hundreds of random tasksets spanning 1/2/4 GPU engines, both wait
//!    modes and all 9 approaches.
//! 2. **Event-calendar DES ≡ seed engine** — the heap-calendar engine
//!    must reproduce the seed engine's runs event-for-event: identical
//!    trace intervals, releases, completions, per-task metrics and run
//!    aggregates, across all 6 policies and random offset patterns.
//!
//! Together these pin every experiment CSV byte across the perf
//! refactor: the sweeps consume exactly the outputs compared here.

use gcaps::analysis::{analyze, analyze_with_gpu_prio, reference, Approach, Prepared};
use gcaps::model::{Platform, TaskSet, Time, WaitMode};
use gcaps::sim::{simulate, simulate_reference, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;
use gcaps::util::rng::Pcg32;

const GPU_COUNTS: [usize; 3] = [1, 2, 4];

fn params(num_gpus: usize, mode: WaitMode) -> GenParams {
    GenParams {
        mode,
        platform: Platform::default().with_num_gpus(num_gpus),
        ..GenParams::default()
    }
}

#[test]
fn kernel_matches_naive_reference_for_all_9_approaches() {
    // ≥ 200 random tasksets: 204 cases cycling the engine count, each
    // generating a suspend and a busy variant and running all 9
    // approaches through both paths.
    let mut case = 0usize;
    forall("RTA kernel = naive reference", 204, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        let suspend = generate(rng, &params(g, WaitMode::SelfSuspend));
        let busy = generate(rng, &params(g, WaitMode::BusyWait));
        for a in Approach::ALL {
            let ts = if a.is_busy() { &busy } else { &suspend };
            let kernel = analyze(ts, a);
            let naive = reference::analyze(ts, a);
            if kernel.response != naive.response {
                return Err(format!(
                    "{} (g = {g}): kernel {:?} != naive {:?}",
                    a.label(),
                    kernel.response,
                    naive.response
                ));
            }
            if kernel.schedulable != naive.schedulable {
                return Err(format!("{} (g = {g}): schedulable bit diverged", a.label()));
            }
        }
        Ok(())
    });
}

#[test]
fn server_kernel_matches_naive_reference() {
    // Dedicated sweep for the server-based family (Kim et al.): the
    // prepared-kernel path must be bit-equal to the naive spec over
    // ≥ 200 random tasksets × 1/2/4 GPU engines × both generated wait
    // modes (the analysis itself is suspension-based regardless of the
    // taskset's wait mode — CPU-only tasks see zero request blocking).
    let mut case = 0usize;
    forall("server RTA kernel = naive reference", 204, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        for mode in [WaitMode::SelfSuspend, WaitMode::BusyWait] {
            let ts = generate(rng, &params(g, mode));
            let kernel = analyze(&ts, Approach::ServerSuspend);
            let naive = reference::analyze(&ts, Approach::ServerSuspend);
            if kernel.response != naive.response {
                return Err(format!(
                    "server (g = {g}, mode = {mode:?}): kernel {:?} != naive {:?}",
                    kernel.response, naive.response
                ));
            }
            if kernel.schedulable != naive.schedulable {
                return Err(format!(
                    "server (g = {g}, mode = {mode:?}): schedulable bit diverged"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn full_gcaps_procedure_matches_reference_incl_audsley() {
    // The Fig. 8 GCAPS cells go through analyze_with_gpu_prio (base RM
    // run + Audsley retry). The kernel-backed search shares one
    // Prepared across levels — its placements and final responses must
    // match the naive search exactly.
    let mut case = 0usize;
    forall("gcaps+audsley kernel = reference", 60, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        for (busy, mode) in [(false, WaitMode::SelfSuspend), (true, WaitMode::BusyWait)] {
            let ts = generate(rng, &params(g, mode));
            let (res_k, prios_k) = analyze_with_gpu_prio(&ts, busy);
            let (res_n, prios_n) = reference::analyze_with_gpu_prio(&ts, busy);
            if res_k.response != res_n.response {
                return Err(format!(
                    "busy = {busy}, g = {g}: procedure responses diverged"
                ));
            }
            if prios_k != prios_n {
                return Err(format!(
                    "busy = {busy}, g = {g}: Audsley assignment diverged \
                     ({prios_k:?} vs {prios_n:?})"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn calendar_engine_matches_seed_engine_traces() {
    const POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];
    let mut case = 0usize;
    forall("calendar DES = seed DES", 30, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        let ts = generate(rng, &params(g, WaitMode::SelfSuspend));
        let horizon = ts.tasks.iter().map(|t| t.period).max().unwrap() * 4;
        // Synchronous release plus one random offset pattern.
        let mut patterns: Vec<Vec<Time>> = vec![vec![0; ts.len()]];
        patterns.push(ts.tasks.iter().map(|t| rng.range_u64(0, t.period)).collect());
        for policy in POLICIES {
            for offsets in &patterns {
                let cfg = SimConfig::new(policy, horizon)
                    .with_offsets(offsets.clone())
                    .with_trace();
                let new = simulate(&ts, &cfg);
                let old = simulate_reference(&ts, &cfg);
                if new.per_task != old.per_task {
                    return Err(format!("{policy:?}: per-task metrics diverged"));
                }
                if new.run != old.run {
                    return Err(format!("{policy:?}: run aggregates diverged"));
                }
                if new.trace != old.trace {
                    let (a, b) = (new.trace.unwrap(), old.trace.unwrap());
                    let detail = if a.releases != b.releases {
                        "releases"
                    } else if a.completions != b.completions {
                        "completions"
                    } else {
                        "event intervals"
                    };
                    return Err(format!("{policy:?}: traces diverged in {detail}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn calendar_engine_matches_seed_engine_under_faults() {
    // The overload stack end-to-end: WCET-overrun ramps, an injected
    // GPU hang, a disable/re-enable mode change, every deadline-miss
    // action, and (under TsgRr) the adaptive RR↔EDF governor — the two
    // engines must stay bit-equal through all of it, traces included.
    use gcaps::model::{AdaptivePolicy, DeadlineMissAction, Fault, FaultPlan};
    const POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];
    let mut case = 0usize;
    forall("faulted DES = seed DES", 20, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        let ts = generate(rng, &params(g, WaitMode::SelfSuspend));
        let horizon = ts.tasks.iter().map(|t| t.period).max().unwrap() * 4;
        let mut plan = FaultPlan::ramp(&ts, horizon / 4, horizon / 2, 250, 300);
        let victim = rng.range_usize(0, ts.len() - 1);
        if ts.tasks[victim].uses_gpu() {
            plan.faults.push(Fault::GpuHang { task: victim, job: 1, seg: 0 });
        }
        let flip = rng.range_usize(0, ts.len() - 1);
        plan.faults.push(Fault::ModeChange {
            at: horizon / 3,
            disable: vec![flip],
            enable: vec![],
        });
        plan.faults.push(Fault::ModeChange {
            at: 2 * (horizon / 3),
            disable: vec![],
            enable: vec![flip],
        });
        for (k, policy) in POLICIES.iter().enumerate() {
            let action = DeadlineMissAction::ALL[k % DeadlineMissAction::ALL.len()];
            let mut cfg = SimConfig::new(*policy, horizon)
                .with_faults(plan.clone())
                .with_miss_actions(vec![action; ts.len()])
                .with_trace();
            if *policy == Policy::TsgRr {
                cfg = cfg.with_adaptive(AdaptivePolicy::default());
            }
            let new = simulate(&ts, &cfg);
            let old = simulate_reference(&ts, &cfg);
            if new.per_task != old.per_task {
                return Err(format!("{policy:?}/{action:?}: per-task metrics diverged"));
            }
            if new.run != old.run {
                return Err(format!("{policy:?}/{action:?}: run aggregates diverged"));
            }
            if new.trace != old.trace {
                return Err(format!("{policy:?}/{action:?}: traces diverged"));
            }
        }
        Ok(())
    });
}

#[test]
fn calendar_engine_handles_zero_length_edges_like_seed() {
    // The dirty completion list's hardest inputs: zero-length CPU and
    // GPU segments chain zero-time transitions. Both engines must agree
    // on them too (mirrors the engine's own edge-case suite).
    use gcaps::model::{ms, GpuSegment, Task, TaskSet};
    let mk = |id: usize, core: usize, prio: u32| Task {
        id,
        name: format!("t{id}"),
        period: ms(20.0),
        deadline: ms(20.0),
        cpu_segments: vec![0, 0],
        gpu_segments: vec![GpuSegment::new(0, ms(2.0))],
        core,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let mut zero_gpu = mk(1, 1, 1);
    zero_gpu.gpu_segments = vec![GpuSegment::new(0, 0)];
    zero_gpu.cpu_segments = vec![ms(1.0), 0];
    let ts = TaskSet::new(vec![mk(0, 0, 2), zero_gpu], Platform::single(2, 1024, 200, 1000));
    for policy in [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ] {
        let cfg = SimConfig::new(policy, ms(200.0)).with_trace();
        let new = simulate(&ts, &cfg);
        let old = simulate_reference(&ts, &cfg);
        assert_eq!(new.per_task, old.per_task, "{policy:?}: metrics diverged");
        assert_eq!(new.trace, old.trace, "{policy:?}: traces diverged");
        assert!(new.per_task[0].jobs > 0, "{policy:?}: no jobs completed");
    }
}

#[test]
fn incremental_kernel_matches_cold_rebuild_over_random_admit_remove_sequences() {
    // The admission server's contract (ISSUE 6): maintaining `Prepared`
    // by admit_task/remove_task deltas — and warm-starting GCAPS fixed
    // points from the previously committed response table — must be
    // bit-equal to rebuilding the kernel cold at every step. ≥ 200
    // random admit/remove sequences, cycling 1/2/4 GPU engines and both
    // wait modes; every step cross-checks GCAPS (incremental + warm vs
    // cold) plus one of the other three families over the delta kernel.
    use gcaps::analysis::gcaps::{analyze_prepared, analyze_prepared_warm, Options};
    use gcaps::analysis::{fmlp, mpcp, rr, server};

    let mut case = 0usize;
    forall("incremental prep + warm = cold rebuild", 204, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        let mode = if (case / GPU_COUNTS.len()) % 2 == 0 {
            WaitMode::SelfSuspend
        } else {
            WaitMode::BusyWait
        };
        let busy = mode == WaitMode::BusyWait;
        case += 1;
        let pool = generate(rng, &params(g, mode));
        let opts = Options::default();

        let mut ts = TaskSet::new(Vec::new(), pool.platform.clone());
        let mut prep = Prepared::new(&ts);
        // Committed warm-start table: previous responses after an admit
        // (maps grew pointwise — old lfp lower-bounds the new one);
        // cleared after a removal (maps shrank — must restart cold).
        let mut warm: Vec<Option<Time>> = Vec::new();
        let mut next = 0usize;

        let steps = pool.len() + pool.len() / 2;
        for step in 0..steps {
            let can_admit = next < pool.len();
            let can_remove = !ts.tasks.is_empty();
            if can_admit && (!can_remove || rng.range_u64(0, 2) != 0) {
                let mut t = pool.tasks[next].clone();
                next += 1;
                t.id = ts.tasks.len();
                ts.tasks.push(t);
                prep.admit_task(&ts);
                warm.push(None);
            } else if can_remove {
                let k = rng.range_usize(0, ts.tasks.len() - 1);
                ts.tasks.remove(k);
                for i in k..ts.tasks.len() {
                    ts.tasks[i].id = i;
                }
                prep.remove_task(k);
                warm.clear();
                warm.resize(ts.tasks.len(), None);
            } else {
                break;
            }

            let cold = Prepared::new(&ts);
            if prep.order != cold.order {
                return Err(format!(
                    "g = {g}, step {step}: order {:?} != cold {:?}",
                    prep.order, cold.order
                ));
            }
            if prep.gpu_users != cold.gpu_users {
                return Err(format!(
                    "g = {g}, step {step}: gpu_users {:?} != cold {:?}",
                    prep.gpu_users, cold.gpu_users
                ));
            }

            let inc = analyze_prepared_warm(&ts, &prep, busy, &opts, &warm);
            let ref_cold = analyze_prepared(&ts, &cold, busy, &opts);
            if inc.response != ref_cold.response || inc.schedulable != ref_cold.schedulable {
                return Err(format!(
                    "g = {g}, mode = {mode:?}, step {step}: gcaps incremental+warm \
                     {:?} != cold {:?}",
                    inc.response, ref_cold.response
                ));
            }
            warm.clone_from(&inc.response);

            // The other families run cold over the shared delta kernel;
            // rotate one per step to keep the sweep fast.
            let (label, a, b) = match step % 4 {
                0 => (
                    "rr",
                    rr::analyze_prepared(&ts, &prep, busy),
                    rr::analyze_prepared(&ts, &cold, busy),
                ),
                1 => (
                    "mpcp",
                    mpcp::analyze_prepared(&ts, &prep, busy),
                    mpcp::analyze_prepared(&ts, &cold, busy),
                ),
                2 => (
                    "fmlp",
                    fmlp::analyze_prepared(&ts, &prep, busy),
                    fmlp::analyze_prepared(&ts, &cold, busy),
                ),
                _ => (
                    "server",
                    server::analyze_prepared(&ts, &prep),
                    server::analyze_prepared(&ts, &cold),
                ),
            };
            if a.response != b.response {
                return Err(format!(
                    "g = {g}, mode = {mode:?}, step {step}: {label} over delta kernel \
                     diverged from cold rebuild"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn fine_grain_kernel_matches_naive_reference() {
    // The fine-grain inflation charge through the Prepared kernel vs the
    // naive task-level spec: ≥ 200 random fine-grain tasksets (204 cases
    // × both wait modes), cycling 1/2/4 GPU engines.
    use gcaps::analysis::gcaps::{analyze_fine, Options};
    let mut case = 0usize;
    forall("fine-grain RTA kernel = naive reference", 204, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        for mode in [WaitMode::SelfSuspend, WaitMode::BusyWait] {
            let p = GenParams { par_range: (20, 80), ..params(g, mode) };
            let ts = generate(rng, &p);
            let busy = mode == WaitMode::BusyWait;
            let kernel = analyze_fine(&ts, busy);
            let naive = reference::gcaps_analyze(
                &ts,
                busy,
                &Options { fine_grain: true, ..Options::default() },
            );
            if kernel.response != naive.response {
                return Err(format!(
                    "fine (g = {g}, mode = {mode:?}): kernel {:?} != naive {:?}",
                    kernel.response, naive.response
                ));
            }
            if kernel.schedulable != naive.schedulable {
                return Err(format!(
                    "fine (g = {g}, mode = {mode:?}): schedulable bit diverged"
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn all_full_fractions_are_bit_equal_to_serial_everywhere() {
    // The degenerate fine-grain case: a taskset whose every GPU segment
    // explicitly declares par = 100% must be indistinguishable from the
    // plain serial taskset — across all 9 analysis approaches, the fine
    // analysis itself, and all 6 DES policies (traces included).
    use gcaps::analysis::gcaps::{self, analyze_fine};
    const POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];
    let full_par = |ts: &TaskSet| -> TaskSet {
        let mut out = ts.clone();
        for t in &mut out.tasks {
            t.gpu_segments =
                t.gpu_segments.iter().map(|g| g.with_par(100)).collect();
        }
        out
    };
    let mut case = 0usize;
    forall("par = 100 everywhere = serial model", 24, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        let suspend = generate(rng, &params(g, WaitMode::SelfSuspend));
        let busy = generate(rng, &params(g, WaitMode::BusyWait));
        for a in Approach::ALL {
            let ts = if a.is_busy() { &busy } else { &suspend };
            let full = full_par(ts);
            let x = analyze(ts, a);
            let y = analyze(&full, a);
            if x.response != y.response || x.schedulable != y.schedulable {
                return Err(format!("{} (g = {g}): par=100 shifted the analysis", a.label()));
            }
        }
        // The fine analysis collapses to the serial one on par = 100.
        for (ts, busy_flag) in [(&suspend, false), (&busy, true)] {
            let full = full_par(ts);
            let fine = analyze_fine(&full, busy_flag);
            let serial = gcaps::analyze(ts, busy_flag, &gcaps::Options::default());
            if fine.response != serial.response {
                return Err(format!(
                    "g = {g}, busy = {busy_flag}: fine(par=100) != serial analysis"
                ));
            }
        }
        let full = full_par(&suspend);
        let horizon = suspend.tasks.iter().map(|t| t.period).max().unwrap() * 3;
        for policy in POLICIES {
            let cfg = SimConfig::new(policy, horizon).with_trace();
            let x = simulate(&suspend, &cfg);
            let y = simulate(&full, &cfg);
            if x.per_task != y.per_task || x.run != y.run || x.trace != y.trace {
                return Err(format!("{policy:?} (g = {g}): par=100 shifted the DES"));
            }
        }
        Ok(())
    });
}

#[test]
fn calendar_engine_matches_seed_engine_under_co_running() {
    // Tentpole acceptance: the two DES engines stay bit-equal when
    // fractional segments actually co-run — all 6 policies, random
    // fraction bands, synchronous plus random offsets, traces included.
    const POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];
    let mut case = 0usize;
    forall("co-running DES = seed DES", 30, |rng| {
        let g = GPU_COUNTS[case % GPU_COUNTS.len()];
        case += 1;
        let p = GenParams {
            par_range: (20, 80),
            ..params(g, WaitMode::SelfSuspend)
        };
        let ts = generate(rng, &p);
        let horizon = ts.tasks.iter().map(|t| t.period).max().unwrap() * 4;
        let mut patterns: Vec<Vec<Time>> = vec![vec![0; ts.len()]];
        patterns.push(ts.tasks.iter().map(|t| rng.range_u64(0, t.period)).collect());
        for policy in POLICIES {
            for offsets in &patterns {
                let cfg = SimConfig::new(policy, horizon)
                    .with_offsets(offsets.clone())
                    .with_trace();
                let new = simulate(&ts, &cfg);
                let old = simulate_reference(&ts, &cfg);
                if new.per_task != old.per_task {
                    return Err(format!("{policy:?}: fine per-task metrics diverged"));
                }
                if new.run != old.run {
                    return Err(format!("{policy:?}: fine run aggregates diverged"));
                }
                if new.trace != old.trace {
                    return Err(format!("{policy:?}: fine traces diverged"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn kernel_survives_deterministic_reruns() {
    // Same taskset, two kernel runs: identical (guards against hidden
    // state in the Prepared/Scratch reuse path).
    let mut rng = Pcg32::seeded(7);
    let ts = generate(&mut rng, &params(2, WaitMode::SelfSuspend));
    for a in Approach::ALL {
        let r1 = analyze(&ts, a);
        let r2 = analyze(&ts, a);
        assert_eq!(r1.response, r2.response, "{}", a.label());
    }
}
