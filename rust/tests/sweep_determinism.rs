//! The sweep engine's core guarantee, enforced end-to-end: experiment
//! results — including the exact CSV bytes — are identical for every
//! worker count. A violation here means some random stream or merge
//! order leaked execution-order dependence into the sweeps.

use gcaps::experiments::fig8::{panel_csv, run_panel, Panel};
use gcaps::experiments::{ablation, casestudy, fig9, multigpu, ExpConfig};

fn cfg_with_jobs(jobs: usize) -> ExpConfig {
    ExpConfig { tasksets: 8, seed: 2024, jobs, ..ExpConfig::default() }
}

#[test]
fn fig8_panel_a_identical_across_worker_counts() {
    let (x1, s1) = run_panel(Panel::TasksPerCpu, &cfg_with_jobs(1));
    let (x2, s2) = run_panel(Panel::TasksPerCpu, &cfg_with_jobs(2));
    let (x8, s8) = run_panel(Panel::TasksPerCpu, &cfg_with_jobs(8));
    assert_eq!(x1, x2, "xticks diverged at jobs = 2");
    assert_eq!(x1, x8, "xticks diverged at jobs = 8");
    assert_eq!(s1, s2, "merged series diverged at jobs = 2");
    assert_eq!(s1, s8, "merged series diverged at jobs = 8");

    // The emitted CSV must be byte-identical, not merely numerically
    // equal — this is what `gcaps exp fig8 --jobs N` writes to disk.
    let b1 = panel_csv(Panel::TasksPerCpu, &x1, &s1).to_string();
    let b2 = panel_csv(Panel::TasksPerCpu, &x2, &s2).to_string();
    let b8 = panel_csv(Panel::TasksPerCpu, &x8, &s8).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "CSV bytes diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "CSV bytes diverged at jobs = 8");
    assert!(b1.lines().count() > 8, "CSV suspiciously small:\n{b1}");
}

#[test]
fn fig9_point_identical_across_worker_counts() {
    for busy in [false, true] {
        let a = fig9::point(busy, 0.5, &cfg_with_jobs(1));
        let b = fig9::point(busy, 0.5, &cfg_with_jobs(4));
        assert_eq!(a, b, "fig9 point (busy = {busy}) diverged");
    }
}

#[test]
fn ablation_sweeps_identical_across_worker_counts() {
    let a = ablation::lemma12_ablation(&cfg_with_jobs(1), 0.4);
    let b = ablation::lemma12_ablation(&cfg_with_jobs(8), 0.4);
    assert_eq!(a, b, "lemma12 ablation diverged");
    let a = ablation::epsilon_sensitivity(&cfg_with_jobs(1), 2000);
    let b = ablation::epsilon_sensitivity(&cfg_with_jobs(3), 2000);
    assert_eq!(a, b, "epsilon sensitivity diverged");
    let a = ablation::miss_ratio(gcaps::sim::Policy::Gcaps, 0.6, &cfg_with_jobs(1));
    let b = ablation::miss_ratio(gcaps::sim::Policy::Gcaps, 0.6, &cfg_with_jobs(4));
    assert_eq!(a, b, "simulated miss ratio diverged");
}

#[test]
fn casestudy_morts_identical_across_worker_counts() {
    let a = casestudy::morts(casestudy::Board::XavierNx, &cfg_with_jobs(1));
    let b = casestudy::morts(casestudy::Board::XavierNx, &cfg_with_jobs(8));
    assert_eq!(a, b, "fig10 MORTs diverged across worker counts");
}

#[test]
fn multigpu_sweep_identical_across_worker_counts() {
    let (x1, s1) = multigpu::run_sweep(&cfg_with_jobs(1));
    let (x4, s4) = multigpu::run_sweep(&cfg_with_jobs(4));
    assert_eq!(x1, x4, "multigpu xticks diverged");
    assert_eq!(s1, s4, "multigpu series diverged");
    let b1 = multigpu::sweep_csv(&x1, &s1).to_string();
    let b4 = multigpu::sweep_csv(&x4, &s4).to_string();
    assert_eq!(b1.as_bytes(), b4.as_bytes(), "multigpu CSV bytes diverged");
}
