//! Integration tests of the live stack: PJRT runtime + Alg. 1 arbiter +
//! periodic executive with real AOT kernels. Skipped (with a notice)
//! when `artifacts/` has not been built — run `make artifacts` first.

use std::time::Duration;

use gcaps::coordinator::executor::{run, LiveGpuSegment, LiveMode, LiveTask};
use gcaps::runtime::{artifacts_dir, Runtime};

fn runtime_or_skip() -> Option<Runtime> {
    match Runtime::load_dir(&artifacts_dir()) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping live test (artifacts not built): {e}");
            None
        }
    }
}

fn mk_task(id: usize, name: &str, workload: &str, period_ms: u64, prio: u32, rt: bool) -> LiveTask {
    let _ = id;
    LiveTask {
        name: name.into(),
        period: Duration::from_millis(period_ms),
        cpu_segments: vec![Duration::from_micros(200); 2],
        gpu_segments: vec![LiveGpuSegment { workload: workload.into(), launches: 2 }],
        gpu_prio: prio,
        rt,
        busy: false,
    }
}

// The three phases share one #[test]: they are timing-sensitive on the
// single-core host and must not run concurrently with each other.
#[test]
fn live_stack_end_to_end() {
    runtime_phase();
    executive_phase();
    gcaps_phase();
    server_phase();
}

fn runtime_phase() {
    let Some(rt) = runtime_or_skip() else { return };
    let names = rt.workloads();
    assert!(names.len() >= 7, "expected ≥7 workloads, got {names:?}");
    for name in &names {
        let a = rt.exec_values(name).expect("exec");
        let b = rt.exec_values(name).expect("exec");
        assert!(!a.is_empty());
        assert_eq!(a, b, "{name}: nondeterministic output");
        assert!(a.iter().all(|v| v.is_finite()), "{name}: non-finite output");
    }
}

fn executive_phase() {
    let Some(rt) = runtime_or_skip() else { return };
    let tasks = vec![
        mk_task(0, "hp", "mmul_small", 100, 2, true),
        mk_task(1, "lp", "projection", 200, 1, true),
        mk_task(2, "be", "mmul_large", 250, 0, false),
    ];
    for mode in [
        LiveMode::Gcaps,
        LiveMode::TsgRr,
        LiveMode::FmlpPlus,
        LiveMode::Mpcp,
        LiveMode::Server,
    ] {
        let res = run(&tasks, &rt, mode, Duration::from_secs(2));
        for (t, m) in tasks.iter().zip(&res.per_task) {
            assert!(
                !m.responses.is_empty(),
                "{}: task {} completed no jobs",
                mode.label(),
                t.name
            );
        }
        assert!(res.launches > 0, "{}: no kernel launches", mode.label());
    }
}

fn gcaps_phase() {
    let Some(rt) = runtime_or_skip() else { return };
    // hp small task vs a GPU-hogging lp task: under GCAPS the hp MORT
    // must stay well under the hog's full segment length.
    let tasks = vec![
        mk_task(0, "hp", "mmul_small", 80, 2, true),
        LiveTask {
            name: "hog".into(),
            period: Duration::from_millis(400),
            cpu_segments: vec![Duration::from_micros(200); 2],
            gpu_segments: vec![LiveGpuSegment { workload: "mmul_large".into(), launches: 40 }],
            gpu_prio: 1,
            rt: true,
            busy: false,
        },
    ];
    let res = run(&tasks, &rt, LiveMode::Gcaps, Duration::from_secs(3));
    // Two ε samples per segment per job.
    let jobs: usize = res.per_task.iter().map(|m| m.responses.len()).sum();
    assert!(
        res.eps_samples.len() >= jobs,
        "ε samples {} < jobs {jobs}",
        res.eps_samples.len()
    );
    let hp_mort = res.per_task[0].mort().unwrap();
    // The hog's segment is ~40 × 1.3 ms ≈ 52 ms; GCAPS preempts at
    // kernel granularity so hp should stay well below it. Generous
    // bound: half the hog segment (the 1-core host adds CPU noise).
    assert!(
        hp_mort < Duration::from_millis(40),
        "hp MORT {hp_mort:?} suggests no GPU preemption"
    );
}

fn server_phase() {
    let Some(rt) = runtime_or_skip() else { return };
    // Same hp-vs-hog shape as gcaps_phase, but under the server-based
    // mode: the priority-queue server serves hp's pending launch ahead
    // of the hog's queued ones, so hp waits at most one in-flight
    // kernel per launch, not a whole hog segment.
    let tasks = vec![
        mk_task(0, "hp", "mmul_small", 80, 2, true),
        LiveTask {
            name: "hog".into(),
            period: Duration::from_millis(400),
            cpu_segments: vec![Duration::from_micros(200); 2],
            gpu_segments: vec![LiveGpuSegment { workload: "mmul_large".into(), launches: 40 }],
            gpu_prio: 1,
            rt: true,
            busy: false,
        },
    ];
    let res = run(&tasks, &rt, LiveMode::Server, Duration::from_secs(3));
    assert!(res.launches > 0, "server: no kernel launches");
    let hp_mort = res.per_task[0].mort().unwrap();
    assert!(
        hp_mort < Duration::from_millis(40),
        "server mode: hp MORT {hp_mort:?} suggests requests were not priority-ordered"
    );
}
