//! The experiment registry's core guarantee, enforced end-to-end:
//!
//! 1. **Registry-vs-direct equality** — dispatching an experiment
//!    through the `Experiment` trait + `CsvSink` produces CSV bytes
//!    identical to the pre-redesign direct call (the pure
//!    `run_*`/`*_csv` functions each harness kept as its compute
//!    path). A violation means the sink/registry plumbing altered an
//!    artifact the paper-comparison files pin.
//! 2. **JSONL schema** — the machine-readable face: every emitted
//!    line parses as a flat JSON object, the key set is exactly
//!    `table` + the CSV column schema in order, and every value
//!    round-trips against the CSV cell.

use std::path::PathBuf;

use gcaps::api::{self, SinkSpec};
use gcaps::experiments::sink::is_json_number;
use gcaps::experiments::{ablation, casestudy, fig8, fig9, multigpu, scenarios};
use gcaps::experiments::{ExpConfig, Opts};
use gcaps::util::csv::CsvTable;

fn tmp(label: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("gcaps_registry_{label}"));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Dispatch `name` through the registry into a scratch CSV sink and
/// return the written bytes per table stem.
fn registry_csv(name: &str, cfg: &ExpConfig, stems: &[&str]) -> Vec<String> {
    let dir = tmp(name);
    let report = api::run(name, cfg, &SinkSpec::csv_only(&dir)).expect(name);
    assert_eq!(report.name, name);
    let out = stems
        .iter()
        .map(|stem| {
            std::fs::read_to_string(dir.join(format!("{stem}.csv")))
                .unwrap_or_else(|e| panic!("{stem}: {e}"))
        })
        .collect();
    let _ = std::fs::remove_dir_all(&dir);
    out
}

#[test]
fn fig8_registry_matches_direct_call() {
    let cfg = ExpConfig {
        tasksets: 6,
        seed: 2024,
        opts: Opts::default().set("panel", "b"),
        ..ExpConfig::default()
    };
    let via_registry = registry_csv("fig8", &cfg, &["fig8b"]);
    let (xticks, series) = fig8::run_panel(fig8::Panel::UtilPerCpu, &cfg);
    let direct = fig8::panel_csv(fig8::Panel::UtilPerCpu, &xticks, &series).to_string();
    assert_eq!(via_registry[0].as_bytes(), direct.as_bytes(), "fig8b bytes diverged");
}

#[test]
fn fig9_registry_matches_direct_call() {
    let cfg = ExpConfig { tasksets: 5, seed: 7, ..ExpConfig::default() };
    let via_registry = registry_csv("fig9", &cfg, &["fig9"]);
    let (xticks, series) = fig9::sweep(&cfg);
    let direct = fig9::fig9_csv(&xticks, &series).to_string();
    assert_eq!(via_registry[0].as_bytes(), direct.as_bytes(), "fig9 bytes diverged");
}

#[test]
fn multigpu_registry_matches_direct_call() {
    let cfg = ExpConfig { tasksets: 4, seed: 17, ..ExpConfig::default() };
    let via_registry = registry_csv("multigpu", &cfg, &["multigpu"]);
    let (xticks, series) = multigpu::run_sweep(&cfg);
    let direct = multigpu::sweep_csv(&xticks, &series).to_string();
    assert_eq!(via_registry[0].as_bytes(), direct.as_bytes(), "multigpu bytes diverged");
}

#[test]
fn ablation_registry_matches_direct_call() {
    let cfg = ExpConfig { tasksets: 4, seed: 9, ..ExpConfig::default() };
    let via_registry = registry_csv("ablation", &cfg, &["ablations"]);
    let (direct, _) = ablation::ablation_render(&cfg);
    assert_eq!(
        via_registry[0].as_bytes(),
        direct.to_string().as_bytes(),
        "ablations bytes diverged"
    );
}

#[test]
fn casestudy_registry_matches_direct_calls() {
    // tasksets is unused by the case study (fixed Table 4 set); 0 keeps
    // the DES replica count at its pinned 5/8.
    let cfg = ExpConfig { tasksets: 0, seed: 1, ..ExpConfig::default() };

    let via_registry = registry_csv("fig10", &cfg, &["fig10_xavier", "fig10_orin"]);
    for (i, board) in [casestudy::Board::XavierNx, casestudy::Board::OrinNano]
        .into_iter()
        .enumerate()
    {
        let (_, direct, _) = casestudy::fig10_render(board, &cfg);
        assert_eq!(
            via_registry[i].as_bytes(),
            direct.to_string().as_bytes(),
            "fig10 bytes diverged for {board:?}"
        );
    }

    let via_registry = registry_csv("fig11", &cfg, &["fig11"]);
    let (direct, _) = casestudy::fig11_render(&cfg);
    assert_eq!(via_registry[0].as_bytes(), direct.to_string().as_bytes());

    let via_registry = registry_csv("table5", &cfg, &["table5"]);
    let (direct, _) = casestudy::table5_render(&cfg);
    assert_eq!(via_registry[0].as_bytes(), direct.to_string().as_bytes());
}

#[test]
fn scenarios_registry_matches_direct_calls() {
    let cfg = ExpConfig { tasksets: 2, seed: 19, ..ExpConfig::default() };
    let via_registry = registry_csv(
        "scenarios",
        &cfg,
        &["scenarios_epstheta", "scenarios_edfvfp", "scenarios_hetero"],
    );
    let direct = [
        scenarios::epstheta_csv(&scenarios::epstheta_sweep(&cfg)).to_string(),
        scenarios::edfvfp_csv(&scenarios::edfvfp_sweep(&cfg)).to_string(),
        scenarios::hetero_csv(&scenarios::hetero_sweep(&cfg)).to_string(),
    ];
    for (got, want) in via_registry.iter().zip(&direct) {
        assert_eq!(got.as_bytes(), want.as_bytes(), "scenario bytes diverged");
    }
}

// ---------------------------------------------------------------------
// JSONL schema
// ---------------------------------------------------------------------

/// Parse one flat JSON object (`{"k":"v","n":1.5,...}`) into ordered
/// (key, decoded value) pairs. Restricted to the grammar the JSONL
/// sink can emit: string keys, string-or-number values, no nesting.
fn parse_flat_object(line: &str) -> Vec<(String, String)> {
    let inner = line
        .strip_prefix('{')
        .and_then(|s| s.strip_suffix('}'))
        .unwrap_or_else(|| panic!("not an object: {line}"));
    let mut out = Vec::new();
    let mut chars = inner.chars().peekable();
    loop {
        let key = parse_string(&mut chars).unwrap_or_else(|| panic!("bad key in {line}"));
        assert_eq!(chars.next(), Some(':'), "missing ':' in {line}");
        let value = if chars.peek() == Some(&'"') {
            parse_string(&mut chars).unwrap_or_else(|| panic!("bad string in {line}"))
        } else {
            let mut tok = String::new();
            while let Some(&c) = chars.peek() {
                if c == ',' {
                    break;
                }
                tok.push(c);
                chars.next();
            }
            assert!(is_json_number(&tok), "bad number token {tok:?} in {line}");
            tok
        };
        out.push((key, value));
        match chars.next() {
            None => break,
            Some(',') => continue,
            Some(c) => panic!("unexpected {c:?} in {line}"),
        }
    }
    out
}

/// Parse a JSON string literal off the front of `chars` (consumes both
/// quotes), decoding the escapes the sink can emit.
fn parse_string(chars: &mut std::iter::Peekable<std::str::Chars<'_>>) -> Option<String> {
    if chars.next()? != '"' {
        return None;
    }
    let mut out = String::new();
    loop {
        match chars.next()? {
            '"' => return Some(out),
            '\\' => match chars.next()? {
                '"' => out.push('"'),
                '\\' => out.push('\\'),
                'n' => out.push('\n'),
                'r' => out.push('\r'),
                't' => out.push('\t'),
                'u' => {
                    let hex: String = (0..4).filter_map(|_| chars.next()).collect();
                    let code = u32::from_str_radix(&hex, 16).ok()?;
                    out.push(char::from_u32(code)?);
                }
                other => panic!("unexpected escape \\{other}"),
            },
            c => out.push(c),
        }
    }
}

/// Every JSONL row of `stem` must parse, carry exactly `table` + the
/// CSV columns in order, and agree cell-for-cell with the CSV table.
fn assert_jsonl_matches(stem: &str, jsonl: &str, csv: &CsvTable) {
    let lines: Vec<&str> = jsonl.lines().collect();
    assert_eq!(lines.len(), csv.rows.len(), "{stem}: row count");
    for (line, row) in lines.iter().zip(&csv.rows) {
        let fields = parse_flat_object(line);
        assert_eq!(fields[0], ("table".to_string(), stem.to_string()), "{stem}: {line}");
        let keys: Vec<&str> = fields[1..].iter().map(|(k, _)| k.as_str()).collect();
        let header: Vec<&str> = csv.header.iter().map(|s| s.as_str()).collect();
        assert_eq!(keys, header, "{stem}: column set/order");
        for ((_, got), want) in fields[1..].iter().zip(row) {
            assert_eq!(got, want, "{stem}: cell diverged in {line}");
        }
    }
}

#[test]
fn jsonl_rows_parse_and_match_the_csv_schema() {
    let dir = tmp("jsonl");
    let cfg = ExpConfig { tasksets: 3, seed: 11, ..ExpConfig::default() };
    api::run("fig9", &cfg, &SinkSpec::csv_jsonl(&dir)).unwrap();
    api::run("multigpu", &cfg, &SinkSpec::csv_jsonl(&dir)).unwrap();

    let (xticks, series) = fig9::sweep(&cfg);
    let fig9_table = fig9::fig9_csv(&xticks, &series);
    let jsonl = std::fs::read_to_string(dir.join("fig9.jsonl")).unwrap();
    assert_jsonl_matches("fig9", &jsonl, &fig9_table);

    let (xticks, series) = multigpu::run_sweep(&cfg);
    let mg_table = multigpu::sweep_csv(&xticks, &series);
    let jsonl = std::fs::read_to_string(dir.join("multigpu.jsonl")).unwrap();
    assert_jsonl_matches("multigpu", &jsonl, &mg_table);

    // Numeric cells must have landed as JSON numbers, not strings.
    let line = std::fs::read_to_string(dir.join("fig9.jsonl")).unwrap();
    let first = line.lines().next().unwrap().to_string();
    assert!(
        first.contains("\"schedulable_ratio\":0.") || first.contains("\"schedulable_ratio\":1."),
        "ratio not numeric: {first}"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn one_run_feeds_csv_and_jsonl_identically() {
    // `--format all` semantics: both artifacts from a single sweep —
    // the CSV written alongside the JSONL must equal the CSV-only run.
    let dir_both = tmp("both");
    let dir_csv = tmp("csvonly");
    let cfg = ExpConfig { tasksets: 3, seed: 23, ..ExpConfig::default() };
    let both = api::run("fig9", &cfg, &SinkSpec::csv_jsonl(&dir_both)).unwrap();
    api::run("fig9", &cfg, &SinkSpec::csv_only(&dir_csv)).unwrap();
    assert_eq!(both.outputs.len(), 2);
    let a = std::fs::read_to_string(dir_both.join("fig9.csv")).unwrap();
    let b = std::fs::read_to_string(dir_csv.join("fig9.csv")).unwrap();
    assert_eq!(a.as_bytes(), b.as_bytes());
    let _ = std::fs::remove_dir_all(&dir_both);
    let _ = std::fs::remove_dir_all(&dir_csv);
}

#[test]
fn report_carries_rows_outputs_and_wall_clock() {
    let dir = tmp("report");
    let cfg = ExpConfig { tasksets: 2, seed: 3, ..ExpConfig::default() };
    let report = api::run("multigpu", &cfg, &SinkSpec::csv_jsonl(&dir).with_ascii()).unwrap();
    assert_eq!(report.rows(), 27, "9 approaches x 3 GPU counts");
    assert_eq!(report.outputs, vec![dir.join("multigpu.csv"), dir.join("multigpu.jsonl")]);
    assert!(report.ascii.contains("Multi-GPU"));
    assert_eq!(report.tables[0].columns, vec!["approach", "num_gpus", "schedulable_ratio"]);
    let _ = std::fs::remove_dir_all(&dir);
}
