//! `gcaps exp scenarios` determinism + golden anchors.
//!
//! 1. **Worker-count invariance** — each sub-sweep's CSV bytes are
//!    identical at `--jobs 1 / 2 / 8` (the sweep engine's core
//!    guarantee, extended to the new harness family).
//! 2. **Anchors** — one small grid cell per sub-sweep is pinned against
//!    an independent recomputation: the ε×θ cell against a from-scratch
//!    serial pass through the documented memo seeding recipe (no cache
//!    path, no worker pool), the EDF-vs-FP point against direct
//!    simulation calls, the heterogeneous platform against a
//!    handcrafted taskset with *exact* per-engine response times
//!    (distinct ε/θ/L end-to-end, optimised engine bit-equal to the
//!    seed reference), the overload (300 %, abort) cell against a
//!    serial pass with a hand-built `FaultPlan` ramp, and the
//!    fine-grain family against both a direct serial recomputation of
//!    one grid point and a handcrafted co-runnable pair with *exact*
//!    hand-computed serial/fine response times (32 ms vs 28 ms).

use gcaps::analysis::gcaps as gcaps_rta;
use gcaps::analysis::{analyze, approach_schedulable, Approach};
use gcaps::experiments::scenarios::{
    adaptive_csv, adaptive_sweep, edfvfp_csv, edfvfp_params, edfvfp_sweep, epstheta_csv,
    epstheta_sweep, finegrain_csv, finegrain_params, finegrain_sweep, hetero_csv,
    hetero_params, hetero_platforms, hetero_sweep, overload_csv, overload_params,
    overload_sweep, ramp_window,
};
use gcaps::experiments::ExpConfig;
use gcaps::model::{
    config, ms, DeadlineMissAction, FaultPlan, GpuContext, GpuSegment, Platform, Task, TaskSet,
    WaitMode,
};
use gcaps::sim::{simulate, simulate_reference, Policy, SimConfig};
use gcaps::sweep::{cell_hash, cell_rng, memo};
use gcaps::taskgen::{generate, GenParams};

fn cfg(tasksets: usize, jobs: usize) -> ExpConfig {
    ExpConfig { tasksets, seed: 2024, jobs, ..ExpConfig::default() }
}

// ---------------------------------------------------------------------
// worker-count invariance (CSV bytes)
// ---------------------------------------------------------------------

#[test]
fn epstheta_csv_identical_across_worker_counts() {
    let b1 = epstheta_csv(&epstheta_sweep(&cfg(6, 1))).to_string();
    let b2 = epstheta_csv(&epstheta_sweep(&cfg(6, 2))).to_string();
    let b8 = epstheta_csv(&epstheta_sweep(&cfg(6, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "epstheta CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "epstheta CSV diverged at jobs = 8");
    assert!(b1.lines().count() > 24, "epstheta CSV suspiciously small:\n{b1}");
}

#[test]
fn edfvfp_csv_identical_across_worker_counts() {
    let b1 = edfvfp_csv(&edfvfp_sweep(&cfg(4, 1))).to_string();
    let b2 = edfvfp_csv(&edfvfp_sweep(&cfg(4, 2))).to_string();
    let b8 = edfvfp_csv(&edfvfp_sweep(&cfg(4, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "edfvfp CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "edfvfp CSV diverged at jobs = 8");
    assert!(b1.lines().count() > 16, "edfvfp CSV suspiciously small:\n{b1}");
}

#[test]
fn hetero_csv_identical_across_worker_counts() {
    let b1 = hetero_csv(&hetero_sweep(&cfg(4, 1))).to_string();
    let b2 = hetero_csv(&hetero_sweep(&cfg(4, 2))).to_string();
    let b8 = hetero_csv(&hetero_sweep(&cfg(4, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "hetero CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "hetero CSV diverged at jobs = 8");
    assert!(b1.lines().count() > 27, "hetero CSV suspiciously small:\n{b1}");
}

#[test]
fn overload_csv_identical_across_worker_counts_and_shows_overload() {
    let b1 = overload_csv(&overload_sweep(&cfg(4, 1))).to_string();
    let b2 = overload_csv(&overload_sweep(&cfg(4, 2))).to_string();
    let b8 = overload_csv(&overload_sweep(&cfg(4, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "overload CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "overload CSV diverged at jobs = 8");
    assert!(b1.lines().count() == 13, "overload CSV wrong shape:\n{b1}");
    // Acceptance: the ramp produces real overload — some row carries a
    // nonzero miss ratio and nonzero pooled tardiness.
    let rows = overload_sweep(&cfg(4, 2));
    assert!(
        rows.iter().any(|r| r.miss_ratio > 0.0),
        "no cell shows misses under a 3x WCET ramp:\n{b1}"
    );
    assert!(
        rows.iter().any(|r| r.tardy_p99_ms > 0.0),
        "no cell shows tardiness under a 3x WCET ramp:\n{b1}"
    );
}

#[test]
fn adaptive_csv_identical_across_worker_counts() {
    let b1 = adaptive_csv(&adaptive_sweep(&cfg(4, 1))).to_string();
    let b2 = adaptive_csv(&adaptive_sweep(&cfg(4, 2))).to_string();
    let b8 = adaptive_csv(&adaptive_sweep(&cfg(4, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "adaptive CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "adaptive CSV diverged at jobs = 8");
    assert!(b1.lines().count() == 10, "adaptive CSV wrong shape:\n{b1}");
}

#[test]
fn finegrain_csv_identical_across_worker_counts() {
    let b1 = finegrain_csv(&finegrain_sweep(&cfg(4, 1))).to_string();
    let b2 = finegrain_csv(&finegrain_sweep(&cfg(4, 2))).to_string();
    let b8 = finegrain_csv(&finegrain_sweep(&cfg(4, 8))).to_string();
    assert_eq!(b1.as_bytes(), b2.as_bytes(), "finegrain CSV diverged at jobs = 2");
    assert_eq!(b1.as_bytes(), b8.as_bytes(), "finegrain CSV diverged at jobs = 8");
    // 3 bands × 3 utilizations × 2 GPU ratios + header.
    assert!(b1.lines().count() == 19, "finegrain CSV wrong shape:\n{b1}");
}

// ---------------------------------------------------------------------
// anchors
// ---------------------------------------------------------------------

#[test]
fn overload_anchor_point_matches_direct_simulation() {
    // The (300%, abort) cell against a from-scratch serial pass: same
    // memoized tasksets, a hand-built ramp plan, direct simulate calls.
    let c = cfg(4, 2);
    let rows = overload_sweep(&c);
    let row = rows
        .iter()
        .find(|r| r.overrun_pct == 300 && r.action == DeadlineMissAction::AbortJob)
        .expect("the (300, abort) cell exists");
    let (start, end) = ramp_window();
    let (mut m, mut j, mut a) = (0u64, 0u64, 0u64);
    let mut rec = 0u64;
    for i in 0..c.tasksets {
        let ts = memo::taskset(c.seed, &overload_params(), i);
        let sim_cfg = SimConfig::new(Policy::Gcaps, ms(3_000.0))
            .with_faults(FaultPlan::ramp(&ts, start, end, 300, 300))
            .with_miss_actions(vec![DeadlineMissAction::AbortJob; ts.len()]);
        let res = simulate(&ts, &sim_cfg);
        for t in ts.rt_tasks() {
            m += res.per_task[t.id].deadline_misses;
            j += res.per_task[t.id].jobs;
            a += res.per_task[t.id].aborted;
        }
        rec = rec.max(res.run.last_tardy.saturating_sub(end));
    }
    let done = (j + a).max(1) as f64;
    assert_eq!(row.miss_ratio, (m + a) as f64 / done);
    assert_eq!(row.abort_ratio, a as f64 / done);
    assert_eq!(row.recovery_ms, rec as f64 / 1000.0);
}

#[test]
fn epstheta_anchor_cell_matches_manual_generation_recipe() {
    // The (xavier_nx, 1×ε, 1×θ) cell must equal a from-scratch serial
    // recomputation through the documented seeding recipe: per-taskset
    // PRNG = cell_rng(seed, cell_hash([params_hash, index])), canonical
    // suspend-mode generation, no memo cache, no worker pool.
    let c = cfg(6, 2);
    let rows = epstheta_sweep(&c);
    let base = config::gpu_profile("xavier_nx").unwrap();
    let (_, ys) = rows
        .iter()
        .find(|((b, ctx), _)| {
            *b == "xavier_nx" && ctx.epsilon == base.epsilon && ctx.theta == base.theta
        })
        .expect("the 1x grid cell exists");
    let p = GenParams {
        platform: Platform::default().with_gpu(0, base),
        ..GenParams::default()
    };
    for (k, a) in Approach::ALL.iter().enumerate() {
        let mode = a.wait_mode();
        let mut ok = 0usize;
        for i in 0..c.tasksets {
            let h = memo::params_hash(&p);
            let mut rng = cell_rng(c.seed, cell_hash(&[h, i as u64]));
            let canon = GenParams { mode: WaitMode::SelfSuspend, ..p.clone() };
            let mut ts = generate(&mut rng, &canon);
            for t in &mut ts.tasks {
                t.mode = mode;
            }
            if approach_schedulable(&ts, *a) {
                ok += 1;
            }
        }
        assert_eq!(
            ys[k],
            ok as f64 / c.tasksets as f64,
            "{}: harness cell diverged from the manual recipe",
            a.label()
        );
    }
}

#[test]
fn edfvfp_anchor_point_matches_direct_simulation() {
    let c = cfg(4, 2);
    let rows = edfvfp_sweep(&c);
    let (u, r) = (0.5, 0.4);
    let row = rows
        .iter()
        .find(|row| row.util == u && row.gpu_ratio == r)
        .expect("the (0.5, 0.4) point exists");
    let p = edfvfp_params(u, r);
    let horizon = ms(3_000.0);
    let (mut sched, mut mf, mut jf, mut me, mut je) = (0usize, 0u64, 0u64, 0u64, 0u64);
    for i in 0..c.tasksets {
        let ts = memo::taskset(c.seed, &p, i);
        if approach_schedulable(&ts, Approach::GcapsSuspend) {
            sched += 1;
        }
        let fp = simulate(&ts, &SimConfig::new(Policy::Gcaps, horizon));
        let edf = simulate(&ts, &SimConfig::new(Policy::GcapsEdf, horizon));
        for t in ts.rt_tasks() {
            mf += fp.per_task[t.id].deadline_misses;
            jf += fp.per_task[t.id].jobs;
            me += edf.per_task[t.id].deadline_misses;
            je += edf.per_task[t.id].jobs;
        }
    }
    assert_eq!(row.sched_fp, sched as f64 / c.tasksets as f64);
    assert_eq!(row.miss_fp, mf as f64 / jf.max(1) as f64);
    assert_eq!(row.miss_edf, me as f64 / je.max(1) as f64);
}

#[test]
fn hetero_anchor_engines_carry_distinct_overheads_end_to_end() {
    // Exact golden values on a handcrafted 2-task / 2-engine platform
    // with distinct per-engine ε/θ/L. Each task is alone on its core
    // AND its engine, so its DES response is the closed-form lone-task
    // bound R = C + 2α_g + max(G^m, θ_g + G^e) — with the *task's own
    // engine's* α and θ. A platform-wide overhead model would collapse
    // the two values.
    let fast = GpuContext { tsg_slice: 1024, theta: 100, epsilon: 500 }; // α = 400 µs
    let slow = GpuContext { tsg_slice: 2048, theta: 400, epsilon: 2000 }; // α = 1600 µs
    let platform = Platform::heterogeneous(2, vec![fast, slow]);
    let mk = |id: usize, core: usize, gpu: usize, prio: u32| Task {
        id,
        name: format!("t{id}"),
        period: ms(100.0),
        deadline: ms(100.0),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
        core,
        gpu,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let ts = TaskSet::new(vec![mk(0, 0, 0, 2), mk(1, 1, 1, 1)], platform);
    ts.validate().unwrap();
    let sim_cfg = SimConfig::new(Policy::Gcaps, ms(1000.0));
    let res = simulate(&ts, &sim_cfg);
    // fast engine: 2 + 2·0.4 + max(0.5, 0.1 + 5) = 7.9 ms
    assert_eq!(res.per_task[0].mort(), Some(ms(7.9)));
    // slow engine: 2 + 2·1.6 + max(0.5, 0.4 + 5) = 10.6 ms
    assert_eq!(res.per_task[1].mort(), Some(ms(10.6)));
    assert_eq!(res.per_task[0].deadline_misses, 0);
    assert_eq!(res.per_task[1].deadline_misses, 0);
    // Optimised engine bit-equal to the seed reference on the hetero
    // platform.
    let seed_res = simulate_reference(&ts, &sim_cfg);
    assert_eq!(res.per_task, seed_res.per_task);
    // The analyses see the same asymmetry (per-engine ε/θ in the RTA).
    for a in [Approach::GcapsSuspend, Approach::TsgRrSuspend] {
        let r = analyze(&ts, a);
        let (r0, r1) = (r.response[0].unwrap(), r.response[1].unwrap());
        assert!(r0 < r1, "{}: fast-engine task not faster ({r0} vs {r1})", a.label());
    }
}

#[test]
fn hetero_sweep_point_exercises_generated_hetero_tasksets() {
    // End-to-end through taskgen: the wide hetero platform's memoized
    // tasksets carry the hetero platform, validate, and (whenever at
    // least 2 GPU tasks exist) populate both engines via WFD.
    let (name, platform) = hetero_platforms().into_iter().last().unwrap();
    assert_eq!(name, "hetero_wide");
    let p = hetero_params(&platform, 0.5);
    for i in 0..5 {
        let ts = memo::taskset(2024, &p, i);
        assert_eq!(ts.platform, platform);
        ts.validate().unwrap();
        if ts.num_gpu_tasks() >= 2 {
            assert!(ts.on_gpu(0).count() >= 1, "taskset {i}: engine 0 empty");
            assert!(ts.on_gpu(1).count() >= 1, "taskset {i}: engine 1 empty");
        }
        // And the DES accepts the hetero platform (smoke, short run).
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(500.0)));
        assert!(res.run.horizon >= ms(500.0));
    }
}

#[test]
fn finegrain_anchor_point_matches_direct_recomputation() {
    // The (wide, 0.5, 0.4) cell against a from-scratch serial pass: the
    // same memoized tasksets, direct serial/fine analysis calls, and
    // direct gcaps DES runs — no cache path, no worker pool.
    let c = cfg(4, 2);
    let rows = finegrain_sweep(&c);
    let row = rows
        .iter()
        .find(|r| r.band == "wide" && r.util == 0.5 && r.gpu_ratio == 0.4)
        .expect("the (wide, 0.5, 0.4) cell exists");
    let p = finegrain_params(0.5, 0.4, (25, 75));
    let (mut ss, mut sf, mut m, mut j) = (0usize, 0usize, 0u64, 0u64);
    for i in 0..c.tasksets {
        let ts = memo::taskset(c.seed, &p, i);
        assert!(ts.has_fine_grain(), "taskset {i}: wide band drew no fraction < 100%");
        if gcaps_rta::analyze(&ts, false, &gcaps_rta::Options::default()).schedulable {
            ss += 1;
        }
        if gcaps_rta::analyze_fine(&ts, false).schedulable {
            sf += 1;
        }
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(3_000.0)));
        for t in ts.rt_tasks() {
            m += res.per_task[t.id].deadline_misses;
            j += res.per_task[t.id].jobs;
        }
    }
    assert_eq!(row.sched_serial, ss as f64 / c.tasksets as f64);
    assert_eq!(row.sched_fine, sf as f64 / c.tasksets as f64);
    assert_eq!(row.miss_des, m as f64 / j.max(1) as f64);
    // Paired on the same tasksets, the fine charge is pointwise ≤ the
    // serial one, so acceptance can only move one way.
    assert!(row.sched_fine >= row.sched_serial);
}

#[test]
fn finegrain_anchor_handcrafted_pair_has_exact_responses() {
    // Hand-computed golden cell for the serial-vs-fine columns. Platform
    // ε = 1 ms; hp task (core 0, prio 2): C = 2, G^m = 1, G^e = 20 at
    // 40 %; victim (core 1, prio 1): C = 2, G^m = 1, G^e = 5 at 50 %,
    // deadline 30 ms. Both on engine 0, self-suspending.
    //
    //   hp:     own C + G + 2ε·η = 2 + 21 + 2 = 25; Lemma 8 blocking
    //           (η+1)·ε = 2 → R = 27 ms (either model: nothing below it
    //           on the engine co-runs into its window).
    //   victim: own 2 + 6 + 2 = 10, no blocking. Serial charge per hp
    //           job: G^e* = 22 → R = 32 ms > D = 30 → REJECTED.
    //           Fine charge: 40 ≤ 100 − 50, so
    //           ceil(40·20/50) + (G^e* − G^e) = 16 + 2 = 18 → R = 28 ms
    //           ≤ 30 → ACCEPTED. One hp job in either window (R + J < T).
    let mk = |id: usize, core: usize, prio: u32, ge: f64, par: u32, dl: f64| Task {
        id,
        name: format!("t{id}"),
        period: ms(100.0),
        deadline: ms(dl),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(1.0), ms(ge)).with_par(par)],
        core,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let ts = TaskSet::new(
        vec![mk(0, 0, 2, 20.0, 40, 100.0), mk(1, 1, 1, 5.0, 50, 30.0)],
        Platform::single(2, 1024, 200, 1000),
    );
    ts.validate().unwrap();
    let serial = gcaps_rta::analyze(&ts, false, &gcaps_rta::Options::default());
    let fine = gcaps_rta::analyze_fine(&ts, false);
    assert_eq!(serial.response[0], Some(ms(27.0)));
    assert_eq!(fine.response[0], Some(ms(27.0)));
    assert!(!serial.schedulable, "serial must reject: R = 32 ms > 30 ms");
    assert!(fine.schedulable, "fine must accept: R = 28 ms");
    assert_eq!(fine.response[1], Some(ms(28.0)));
}
