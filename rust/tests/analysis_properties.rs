//! Property tests on the response-time analyses: monotonicity in every
//! platform parameter, dominance relations between approaches, and
//! internal consistency of the GPU-priority assignment. These are the
//! invariants DESIGN.md §6 commits to.

use gcaps::analysis::gcaps::{analyze as gcaps_rta, Options};
use gcaps::analysis::{analyze, analyze_with_gpu_prio, Approach};
use gcaps::model::{Platform, TaskSet, WaitMode};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;

fn gen_default(rng: &mut gcaps::util::rng::Pcg32, busy: bool) -> TaskSet {
    let p = GenParams {
        mode: if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
        util_per_cpu: (0.3, 0.5),
        ..Default::default()
    };
    generate(rng, &p)
}

fn with_platform(ts: &TaskSet, platform: Platform) -> TaskSet {
    let mut out = ts.clone();
    out.platform = Platform { num_cpus: ts.platform.num_cpus, gpus: platform.gpus };
    out
}

/// R_i is monotone non-decreasing in ε for every GCAPS variant.
#[test]
fn gcaps_wcrt_monotone_in_epsilon() {
    forall("monotone in ε", 40, |rng| {
        let ts = gen_default(rng, false);
        let mut prev: Vec<Option<u64>> = vec![Some(0); ts.len()];
        for eps in [0u64, 300, 600, 1000, 1500] {
            let t2 = with_platform(&ts, ts.platform.clone().with_epsilon(eps));
            let res = gcaps_rta(&t2, false, &Options::default());
            for t in t2.rt_tasks() {
                match (prev[t.id], res.response[t.id]) {
                    (Some(a), Some(b)) if b < a => {
                        return Err(format!("task {}: R dropped {a} → {b} as ε grew", t.id))
                    }
                    (None, Some(_)) => {
                        return Err(format!("task {} became schedulable as ε grew", t.id))
                    }
                    _ => {}
                }
            }
            prev = res.response.clone();
        }
        Ok(())
    });
}

/// Round-robin bounds are monotone in θ.
#[test]
fn tsg_rr_wcrt_monotone_in_theta() {
    forall("monotone in θ", 40, |rng| {
        let ts = gen_default(rng, false);
        let mut prev: Vec<Option<u64>> = vec![Some(0); ts.len()];
        for theta in [0u64, 100, 200, 400, 800] {
            let t2 = with_platform(&ts, ts.platform.clone().with_theta(theta));
            let res = analyze(&t2, Approach::TsgRrSuspend);
            for t in t2.rt_tasks() {
                match (prev[t.id], res.response[t.id]) {
                    (Some(a), Some(b)) if b < a => {
                        return Err(format!("task {}: R dropped {a} → {b} as θ grew", t.id))
                    }
                    (None, Some(_)) => {
                        return Err(format!("task {} became schedulable as θ grew", t.id))
                    }
                    _ => {}
                }
            }
            prev = res.response.clone();
        }
        Ok(())
    });
}

/// Scaling every WCET up can never turn an unschedulable set schedulable.
#[test]
fn wcrt_monotone_in_demand() {
    forall("monotone in demand", 30, |rng| {
        let ts = gen_default(rng, false);
        let mut scaled = ts.clone();
        for t in &mut scaled.tasks {
            for c in &mut t.cpu_segments {
                *c += *c / 5; // +20 %
            }
            for g in &mut t.gpu_segments {
                g.exec += g.exec / 5;
            }
        }
        for approach in [Approach::GcapsSuspend, Approach::TsgRrSuspend, Approach::FmlpSuspend] {
            let base = analyze(&ts, approach);
            let more = analyze(&scaled, approach);
            if !base.schedulable && more.schedulable {
                return Err(format!("{}: +20% demand made it schedulable", approach.label()));
            }
            for t in ts.rt_tasks() {
                if let (Some(a), Some(b)) = (base.response[t.id], more.response[t.id]) {
                    if b < a {
                        return Err(format!(
                            "{}: task {} R dropped {a} → {b} with +20% demand",
                            approach.label(),
                            t.id
                        ));
                    }
                }
            }
        }
        Ok(())
    });
}

/// With ε = θ = 0 and a GPU-heavy high-priority task, GCAPS's bound for
/// the highest-priority GPU task never exceeds the lock-based bounds
/// (preemption strictly helps the top task when overheads vanish).
#[test]
fn gcaps_dominates_sync_for_top_task_without_overheads() {
    forall("gcaps top-task dominance (ε=θ=0)", 40, |rng| {
        let ts0 = gen_default(rng, false);
        let ts = with_platform(&ts0, ts0.platform.clone().with_epsilon(0).with_theta(0));
        // Highest-priority GPU-using RT task.
        let top = ts
            .rt_tasks()
            .filter(|t| t.uses_gpu())
            .max_by_key(|t| t.cpu_prio)
            .map(|t| t.id);
        let Some(top) = top else { return Ok(()) };
        let g = gcaps_rta(&ts, false, &Options::default()).response[top];
        for approach in [Approach::MpcpSuspend, Approach::FmlpSuspend] {
            let s = analyze(&ts, approach).response[top];
            match (g, s) {
                (Some(rg), Some(rs)) if rg > rs => {
                    return Err(format!(
                        "{}: top task {top} gcaps R {rg} > sync R {rs}",
                        approach.label()
                    ))
                }
                (None, Some(_)) => {
                    return Err(format!("{}: gcaps fails top task, sync passes", approach.label()))
                }
                _ => {}
            }
        }
        Ok(())
    });
}

/// analyze_with_gpu_prio is a strict improvement procedure: whenever the
/// default assignment already passes, it returns that result unchanged.
#[test]
fn audsley_procedure_never_worse() {
    forall("gcaps+audsley ⊇ gcaps", 60, |rng| {
        let ts = gen_default(rng, false);
        let base = gcaps_rta(&ts, false, &Options::default());
        let (with, prios) = analyze_with_gpu_prio(&ts, false);
        if base.schedulable {
            if !with.schedulable {
                return Err("default passes but procedure fails".into());
            }
            if prios.is_some() {
                return Err("procedure reassigned priorities unnecessarily".into());
            }
        }
        Ok(())
    });
}

/// The paper-exact Lemma 12 ablation is never more pessimistic than the
/// amended (sound) version.
#[test]
fn paper_exact_lemma12_is_optimistic() {
    forall("lemma 12 ablation direction", 40, |rng| {
        let ts = gen_default(rng, true);
        let sound = gcaps_rta(&ts, true, &Options::default());
        let exact = gcaps_rta(
            &ts,
            true,
            &Options { paper_exact_lemma12: true, ..Default::default() },
        );
        for t in ts.rt_tasks() {
            match (sound.response[t.id], exact.response[t.id]) {
                (Some(a), Some(b)) if b > a => {
                    return Err(format!("task {}: paper-exact {b} > sound {a}", t.id))
                }
                (Some(_), None) => {
                    return Err(format!("task {}: paper-exact fails where sound passes", t.id))
                }
                _ => {}
            }
        }
        Ok(())
    });
}

/// Suspension-mode bounds never exceed busy-wait bounds for the same
/// GCAPS taskset (busy-waiting only adds CPU contention).
#[test]
fn gcaps_suspend_bound_not_above_busy() {
    forall("suspend ≤ busy (gcaps)", 40, |rng| {
        let ts = gen_default(rng, false);
        let s = gcaps_rta(&ts, false, &Options::default());
        let b = gcaps_rta(&ts, true, &Options::default());
        for t in ts.rt_tasks() {
            if let (Some(rs), Some(rb)) = (s.response[t.id], b.response[t.id]) {
                if rs > rb {
                    return Err(format!("task {}: suspend R {rs} > busy R {rb}", t.id));
                }
            }
        }
        Ok(())
    });
}

/// CPU-only tasksets: every approach reduces to plain fixed-priority
/// RTA and must agree exactly.
#[test]
fn cpu_only_tasksets_all_approaches_agree() {
    forall("CPU-only agreement", 40, |rng| {
        let p = GenParams { gpu_task_ratio: (0.0, 0.0), ..Default::default() };
        let ts = generate(rng, &p);
        let results: Vec<Vec<Option<u64>>> = Approach::ALL
            .iter()
            .map(|&a| analyze(&ts, a).response)
            .collect();
        for t in ts.rt_tasks() {
            let first = results[0][t.id];
            for (k, r) in results.iter().enumerate() {
                if r[t.id] != first {
                    return Err(format!(
                        "task {}: approach {} gives {:?}, expected {:?}",
                        t.id,
                        Approach::ALL[k].label(),
                        r[t.id],
                        first
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Removing a task never increases anyone's bound (interference is
/// additive over tasks).
#[test]
fn wcrt_monotone_in_taskset_inclusion() {
    forall("monotone in inclusion", 30, |rng| {
        let ts = gen_default(rng, false);
        if ts.len() < 2 {
            return Ok(());
        }
        // Remove the lowest-priority RT task; ids must stay contiguous.
        let victim = ts.rt_tasks().min_by_key(|t| t.cpu_prio).unwrap().id;
        let mut reduced = ts.clone();
        reduced.tasks.remove(victim);
        for (idx, t) in reduced.tasks.iter_mut().enumerate() {
            t.id = idx;
        }
        let base = analyze(&ts, Approach::GcapsSuspend);
        let less = analyze(&reduced, Approach::GcapsSuspend);
        // Map: tasks after `victim` shifted down by one.
        for t in ts.rt_tasks().filter(|t| t.id != victim) {
            let new_id = if t.id > victim { t.id - 1 } else { t.id };
            if let (Some(a), Some(b)) = (base.response[t.id], less.response[new_id]) {
                if b > a {
                    return Err(format!("task {}: R grew {a} → {b} after removing a task", t.id));
                }
            }
        }
        Ok(())
    });
}
