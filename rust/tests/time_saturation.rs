//! Near-`u64::MAX` saturation regression: the `time-arith` lint rule
//! exists because bare `Time` arithmetic *wraps* at the extremes, and
//! a wrapped response time is a tiny — and therefore unsound — bound.
//! These tests drive both the RTA and the DES through the pathological
//! corner and pin three properties:
//!
//! 1. the analysis kernel and the naive reference stay bit-equal even
//!    when every ε-carrying term saturates,
//! 2. saturation lands on the *sound* side: `Time::MAX` fails the
//!    deadline check, so the set reports unschedulable instead of
//!    schedulable-by-wraparound,
//! 3. the event engine and the seed reference engine agree
//!    event-for-event when jobs are released near `u64::MAX`.
//!
//! `gcaps lint --rule time-arith` is the static half of this contract;
//! this file is the dynamic half.

use gcaps::analysis::{analyze, reference, Approach};
use gcaps::model::{ms, GpuSegment, Platform, Task, TaskSet, Time, WaitMode};
use gcaps::sim::{simulate, simulate_reference, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::rng::Pcg32;

/// ε so large that the GCAPS own-term `C + G + 2ε·η` overflows u64
/// for every GPU-using task.
const HUGE_EPS: Time = Time::MAX / 2 + 1_000;

#[test]
fn huge_epsilon_saturates_identically_in_kernel_and_reference() {
    let mut rng = Pcg32::seeded(7);
    let mut ts = generate(&mut rng, &GenParams::default());
    assert!(
        ts.rt_tasks().any(|t| t.uses_gpu()),
        "generator produced no real-time GPU task; the corner would be vacuous"
    );
    for g in &mut ts.platform.gpus {
        g.epsilon = HUGE_EPS;
    }
    for a in Approach::ALL {
        let kernel = analyze(&ts, a);
        let naive = reference::analyze(&ts, a);
        assert_eq!(
            kernel.response,
            naive.response,
            "{}: kernel and reference diverged at the saturation corner",
            a.label()
        );
        assert_eq!(kernel.schedulable, naive.schedulable, "{}", a.label());
    }
}

#[test]
fn huge_epsilon_is_unschedulable_not_schedulable_by_wraparound() {
    // A wrapping build computed `own = C + G + 2ε·η` modulo 2^64 here,
    // got a tiny bound, and declared the set schedulable. Saturating
    // arithmetic pins `own` at Time::MAX, the fixed point starts above
    // the deadline, and the analysis soundly reports unschedulable.
    let mut rng = Pcg32::seeded(7);
    let mut ts = generate(&mut rng, &GenParams::default());
    for g in &mut ts.platform.gpus {
        g.epsilon = HUGE_EPS;
    }
    for a in [Approach::GcapsSuspend, Approach::GcapsBusy] {
        let res = analyze(&ts, a);
        assert!(
            !res.schedulable,
            "{}: huge ε must fail the deadline check, not wrap into range",
            a.label()
        );
        for t in ts.rt_tasks().filter(|t| t.uses_gpu()) {
            assert_eq!(
                res.response[t.id],
                None,
                "{}: GPU task {} got a finite bound from an overflowed own-term",
                a.label(),
                t.name
            );
        }
    }
}

fn gpu_task(id: usize, prio: u32, t_ms: f64) -> Task {
    Task {
        id,
        name: format!("t{id}"),
        period: ms(t_ms),
        deadline: ms(t_ms),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
        core: 0,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    }
}

#[test]
fn near_max_release_offsets_keep_engine_and_reference_bit_equal() {
    // Companion to engine.rs::near_max_deadlines_saturate_instead_of_
    // wrapping: releases near u64::MAX exercise every saturating site
    // in the advance loop (abs_deadline, EDF rank, response, horizon).
    // Both engines must clamp the same way — the equivalence contract
    // holds at the extremes, not just in the comfortable range.
    let ts = TaskSet::new(
        vec![gpu_task(0, 2, 100.0), gpu_task(1, 1, 120.0)],
        Platform::single(2, 1024, 200, 1000),
    );
    let offsets = vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)];
    for policy in [Policy::Gcaps, Policy::GcapsEdf, Policy::TsgRr] {
        let cfg = SimConfig::new(policy, u64::MAX).with_offsets(offsets.clone());
        let fast = simulate(&ts, &cfg);
        let seed = simulate_reference(&ts, &cfg);
        assert_eq!(
            fast.per_task, seed.per_task,
            "{policy:?}: engines diverged on near-MAX releases"
        );
        for i in [0, 1] {
            assert!(fast.per_task[i].jobs >= 1, "{policy:?}: tau{i} never ran");
            assert_eq!(
                fast.per_task[i].deadline_misses, 0,
                "{policy:?}: tau{i} flagged a bogus wrap-around miss"
            );
        }
    }
}
