//! Device-model invariants (DESIGN.md §6): work conservation, GPU busy
//! accounting, runlist exclusivity under GCAPS, round-robin fairness
//! under the default driver, and job-accounting sanity — all checked
//! over random tasksets and release patterns.

use gcaps::model::{
    ms, DeadlineMissAction, FaultPlan, GpuSegment, Platform, Task, TaskSet, Time, WaitMode,
};
use gcaps::sim::trace::{Activity, Resource};
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::check::forall;
use gcaps::util::rng::Pcg32;

fn random_offsets(ts: &TaskSet, rng: &mut Pcg32) -> Vec<Time> {
    ts.tasks.iter().map(|t| rng.range_u64(0, t.period)).collect()
}

/// GPU busy time equals the pure GPU demand of all completed (and
/// in-flight) jobs — the device never invents or loses work.
#[test]
fn gpu_busy_matches_executed_demand() {
    forall("gpu busy accounting", 25, |rng| {
        let ts = generate(rng, &GenParams { util_per_cpu: (0.2, 0.35), ..Default::default() });
        let horizon = ms(10_000.0);
        for policy in [Policy::Gcaps, Policy::TsgRr, Policy::Mpcp, Policy::FmlpPlus, Policy::Server]
        {
            let sim = simulate(&ts, &SimConfig::new(policy, horizon));
            let completed_ge: Time = ts
                .tasks
                .iter()
                .map(|t| sim.per_task[t.id].jobs * t.ge())
                .sum();
            // busy ≥ completed demand; the excess is one in-flight job max
            // per task.
            let max_inflight: Time = ts.tasks.iter().map(|t| t.ge()).sum();
            if sim.run.gpu_busy < completed_ge {
                return Err(format!(
                    "{}: busy {} < completed G^e {}",
                    policy.label(),
                    sim.run.gpu_busy,
                    completed_ge
                ));
            }
            if sim.run.gpu_busy > completed_ge + max_inflight {
                return Err(format!(
                    "{}: busy {} exceeds demand {} + inflight {}",
                    policy.label(),
                    sim.run.gpu_busy,
                    completed_ge,
                    max_inflight
                ));
            }
        }
        Ok(())
    });
}

/// Under GCAPS no two real-time tasks' GPU-execution intervals overlap,
/// and the runlist never interleaves RT work (Lemma 9's premise).
#[test]
fn gcaps_rt_gpu_execution_is_exclusive() {
    forall("gcaps exclusive RT context", 20, |rng| {
        let ts = generate(rng, &GenParams { util_per_cpu: (0.3, 0.5), ..Default::default() });
        let offsets = random_offsets(&ts, rng);
        let sim = simulate(
            &ts,
            &SimConfig::new(Policy::Gcaps, ms(5_000.0)).with_offsets(offsets).with_trace(),
        );
        let tr = sim.trace.unwrap();
        let mut gpu_evs: Vec<_> = tr
            .events
            .iter()
            .filter(|e| e.resource == Resource::Gpu(0) && e.activity == Activity::GpuExec)
            .collect();
        gpu_evs.sort_by_key(|e| e.start);
        for w in gpu_evs.windows(2) {
            if w[1].start < w[0].end {
                return Err(format!(
                    "GPU intervals overlap: task {} [{}, {}) vs task {} [{}, {})",
                    w[0].task, w[0].start, w[0].end, w[1].task, w[1].start, w[1].end
                ));
            }
        }
        Ok(())
    });
}

/// Work conservation of the RR driver: while any TSG has queued GPU
/// work, the GPU is never idle for longer than one context switch θ.
#[test]
fn tsg_rr_work_conserving() {
    forall("tsg_rr work conservation", 15, |rng| {
        // Two GPU-only hogs released together: the GPU must stay busy
        // (exec or switch) until both complete.
        let ge = rng.range_u64(5_000, 20_000);
        let p = Platform { num_cpus: 2, ..Default::default() };
        let mk = |id: usize| Task {
            id,
            name: format!("h{id}"),
            period: ms(1_000.0),
            deadline: ms(1_000.0),
            cpu_segments: vec![10, 10],
            gpu_segments: vec![GpuSegment::new(10, ge)],
            core: id % 2,
            gpu: 0,
            cpu_prio: id as u32 + 1,
            gpu_prio: id as u32 + 1,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![mk(0), mk(1)], p);
        let sim = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(1_000.0)).with_trace());
        let tr = sim.trace.unwrap();
        // Completion of the later task.
        let done = tr.completions.iter().map(|&(_, t)| t).max().unwrap();
        let busy: Time = (0..2).map(|i| tr.occupancy(Resource::Gpu(0), i, 0, done)).sum();
        // From first launch (~20 µs in) to `done`, the GPU must be
        // busy ≥ 95% of the window (idle only during launch setup).
        let window = done - 20;
        if (busy as f64) < window as f64 * 0.95 {
            return Err(format!("GPU busy {busy} over window {window}: not work-conserving"));
        }
        Ok(())
    });
}

/// RR fairness: two identical GPU hogs sharing the driver complete
/// within one time slice + θ of each other.
#[test]
fn tsg_rr_fair_between_equal_hogs() {
    forall("tsg_rr fairness", 15, |rng| {
        let ge = rng.range_u64(10_000, 40_000);
        let p = Platform { num_cpus: 2, ..Default::default() };
        let mk = |id: usize| Task {
            id,
            name: format!("h{id}"),
            period: ms(2_000.0),
            deadline: ms(2_000.0),
            cpu_segments: vec![10, 10],
            gpu_segments: vec![GpuSegment::new(10, ge)],
            core: id % 2,
            gpu: 0,
            cpu_prio: id as u32 + 1,
            gpu_prio: id as u32 + 1,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![mk(0), mk(1)], p);
        let sim = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(2_000.0)));
        let r0 = sim.per_task[0].response_times[0];
        let r1 = sim.per_task[1].response_times[0];
        let gap = r0.abs_diff(r1);
        let bound = ts.platform.gpus[0].tsg_slice + ts.platform.gpus[0].theta + 50;
        if gap > bound {
            return Err(format!("completion gap {gap} > slice+θ {bound} (r0={r0}, r1={r1})"));
        }
        Ok(())
    });
}

/// Job accounting: jobs completed ≈ floor((horizon - offset)/T) when the
/// taskset is lightly loaded (every job finishes within its period).
#[test]
fn job_counts_match_releases_under_light_load() {
    forall("job accounting", 20, |rng| {
        let ts = generate(rng, &GenParams { util_per_cpu: (0.1, 0.2), ..Default::default() });
        let horizon = ms(20_000.0);
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, horizon));
        for t in ts.rt_tasks() {
            let released = horizon.div_ceil(t.period);
            let done = sim.per_task[t.id].jobs;
            // The final job may be cut off by the horizon.
            if done + 2 < released {
                return Err(format!(
                    "task {}: completed {done} of ~{released} released jobs",
                    t.id
                ));
            }
        }
        Ok(())
    });
}

/// Determinism: identical configs give bit-identical metrics.
#[test]
fn simulation_is_deterministic() {
    forall("determinism", 10, |rng| {
        let ts = generate(rng, &GenParams::default());
        let offsets = random_offsets(&ts, rng);
        for policy in [Policy::Gcaps, Policy::TsgRr, Policy::FmlpPlus, Policy::Server] {
            let cfg = SimConfig::new(policy, ms(5_000.0)).with_offsets(offsets.clone());
            let a = simulate(&ts, &cfg);
            let b = simulate(&ts, &cfg);
            for i in 0..ts.len() {
                if a.per_task[i].response_times != b.per_task[i].response_times {
                    return Err(format!("{}: task {i} responses differ", policy.label()));
                }
            }
        }
        Ok(())
    });
}

/// ε accounting: the DES charges exactly 2 runlist updates per completed
/// GPU segment under GCAPS.
#[test]
fn gcaps_two_updates_per_segment() {
    forall("2 updates per segment", 20, |rng| {
        let ts = generate(rng, &GenParams { util_per_cpu: (0.15, 0.3), ..Default::default() });
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(10_000.0)));
        for t in ts.tasks.iter().filter(|t| t.uses_gpu()) {
            let updates = sim.per_task[t.id].runlist_updates.len() as u64;
            let segments_done = sim.per_task[t.id].jobs * t.eta_g() as u64;
            // In-flight segments can add up to 2·η_g extra updates.
            let slack = 2 * t.eta_g() as u64;
            if updates < 2 * segments_done || updates > 2 * segments_done + slack {
                return Err(format!(
                    "task {}: {updates} updates for {segments_done} segments",
                    t.id
                ));
            }
        }
        Ok(())
    });
}

/// The server policy's engine-vs-reference contract over random
/// tasksets and release patterns: per-task metrics, run aggregates and
/// full traces (intervals, releases, completions) must match event for
/// event — including the `ServerMisc` intervals the server policy adds
/// to the engine rows.
#[test]
fn server_policy_engines_match_event_for_event() {
    forall("server DES engine = reference", 15, |rng| {
        let ts = generate(rng, &GenParams::default());
        let offsets = random_offsets(&ts, rng);
        let cfg =
            SimConfig::new(Policy::Server, ms(5_000.0)).with_offsets(offsets).with_trace();
        let fast = simulate(&ts, &cfg);
        let seed = gcaps::sim::simulate_reference(&ts, &cfg);
        if fast.per_task != seed.per_task {
            return Err("server: per-task metrics diverged".into());
        }
        if fast.run != seed.run {
            return Err("server: run aggregates diverged".into());
        }
        if fast.trace != seed.trace {
            return Err("server: traces diverged".into());
        }
        Ok(())
    });
}

/// Server-policy edges: zero-length G^m/G^e segments chained through
/// the server queue, and releases near u64::MAX — both engines must
/// stay bit-equal and make progress.
#[test]
fn server_policy_zero_length_and_near_max_edges_stay_bit_equal() {
    let mk = |id: usize, core: usize, prio: u32| Task {
        id,
        name: format!("t{id}"),
        period: ms(20.0),
        deadline: ms(20.0),
        cpu_segments: vec![0, 0],
        gpu_segments: vec![GpuSegment::new(0, ms(2.0))],
        core,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    // τ1: a fully zero-length request (G^m = G^e = 0) competing with
    // τ0's real requests on the same engine.
    let mut zero_req = mk(1, 1, 1);
    zero_req.gpu_segments = vec![GpuSegment::new(0, 0)];
    zero_req.cpu_segments = vec![ms(1.0), 0];
    let ts = TaskSet::new(vec![mk(0, 0, 2), zero_req], Platform::single(2, 1024, 200, 1000));
    ts.validate().unwrap();
    let patterns: [Vec<Time>; 2] = [
        vec![0, 0],
        vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)],
    ];
    for offsets in patterns {
        let near_max = offsets[0] > 0;
        let horizon = if near_max { u64::MAX } else { ms(200.0) };
        let cfg = SimConfig::new(Policy::Server, horizon).with_offsets(offsets).with_trace();
        let fast = simulate(&ts, &cfg);
        let seed = gcaps::sim::simulate_reference(&ts, &cfg);
        assert_eq!(fast.per_task, seed.per_task, "near_max={near_max}: metrics diverged");
        assert_eq!(fast.trace, seed.trace, "near_max={near_max}: traces diverged");
        for i in [0, 1] {
            assert!(fast.per_task[i].jobs > 0, "near_max={near_max}: tau{i} never completed");
        }
    }
}

/// Fault injection stays deterministic: the same `FaultPlan` and miss
/// action give bit-identical metrics, aggregates and traces on rerun —
/// the same contract the sweep workers rely on for `--jobs` invariance.
#[test]
fn fault_plans_are_deterministic_across_reruns() {
    forall("fault determinism", 10, |rng| {
        let ts = generate(rng, &GenParams::default());
        let horizon = ms(3_000.0);
        let plan = FaultPlan::ramp(&ts, ms(1_000.0), ms(2_000.0), 300, 300);
        for action in DeadlineMissAction::ALL {
            let cfg = SimConfig::new(Policy::Gcaps, horizon)
                .with_faults(plan.clone())
                .with_miss_actions(vec![action; ts.len()])
                .with_trace();
            let a = simulate(&ts, &cfg);
            let b = simulate(&ts, &cfg);
            if a.per_task != b.per_task || a.run != b.run || a.trace != b.trace {
                return Err(format!("{action:?}: faulted rerun diverged"));
            }
        }
        Ok(())
    });
}

/// Regression (wrap-around audit): jobs released near u64::MAX keep the
/// two engines bit-equal and never flag wrap-around deadline misses —
/// `abs_deadline = release + deadline` used to overflow there, inverting
/// the EDF rank and the miss check in both engines.
#[test]
fn near_max_release_offsets_stay_wrap_free_and_bit_equal() {
    let mk = |id: usize, prio: u32, t: f64| Task {
        id,
        name: format!("t{id}"),
        period: ms(t),
        deadline: ms(t),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
        core: 0,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let ts = TaskSet::new(
        vec![mk(0, 2, 100.0), mk(1, 1, 120.0)],
        Platform::single(2, 1024, 200, 1000),
    );
    ts.validate().unwrap();
    let offsets = vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)];
    for policy in [
        Policy::GcapsEdf,
        Policy::Gcaps,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ] {
        let cfg = SimConfig::new(policy, u64::MAX).with_offsets(offsets.clone());
        let fast = simulate(&ts, &cfg);
        let seed = gcaps::sim::simulate_reference(&ts, &cfg);
        assert_eq!(fast.per_task, seed.per_task, "{policy:?}: engines diverged");
        for i in [0, 1] {
            assert!(fast.per_task[i].jobs >= 1, "{policy:?}: tau{i} never ran");
            assert_eq!(
                fast.per_task[i].deadline_misses, 0,
                "{policy:?}: tau{i} flagged a bogus wrap-around miss"
            );
        }
    }
}

/// Regression (overload audit): saturating absolute deadlines near
/// u64::MAX with active miss actions must not wrap into bogus reactions
/// — no aborts, no boosts, and `last_tardy` stays 0 — and the two
/// engines stay bit-equal with the actions armed.
#[test]
fn near_max_offsets_with_miss_actions_stay_wrap_free_and_bit_equal() {
    let mk = |id: usize, prio: u32, t: f64| Task {
        id,
        name: format!("t{id}"),
        period: ms(t),
        deadline: ms(t),
        cpu_segments: vec![ms(1.0), ms(1.0)],
        gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
        core: 0,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    };
    let ts = TaskSet::new(
        vec![mk(0, 2, 100.0), mk(1, 1, 120.0)],
        Platform::single(2, 1024, 200, 1000),
    );
    ts.validate().unwrap();
    let offsets = vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)];
    for action in [DeadlineMissAction::Boost, DeadlineMissAction::AbortJob] {
        for policy in [Policy::GcapsEdf, Policy::Gcaps, Policy::TsgRr] {
            let cfg = SimConfig::new(policy, u64::MAX)
                .with_offsets(offsets.clone())
                .with_miss_actions(vec![action; 2]);
            let fast = simulate(&ts, &cfg);
            let seed = gcaps::sim::simulate_reference(&ts, &cfg);
            assert_eq!(fast.per_task, seed.per_task, "{policy:?}/{action:?}: diverged");
            assert_eq!(fast.run, seed.run, "{policy:?}/{action:?}: aggregates diverged");
            assert_eq!(fast.run.last_tardy, 0, "{policy:?}/{action:?}: phantom tardiness");
            for i in [0, 1] {
                assert!(fast.per_task[i].jobs >= 1, "{policy:?}/{action:?}: tau{i} never ran");
                assert_eq!(fast.per_task[i].aborted, 0, "{policy:?}/{action:?}: bogus abort");
                assert_eq!(fast.per_task[i].boosts, 0, "{policy:?}/{action:?}: bogus boost");
            }
        }
    }
}
