//! Case-study pipeline benchmarks: the Fig. 10 MORT collection, the
//! Table 5 analysis column, and the Fig. 13 θ-estimation procedure.

use gcaps::analysis::{gcaps as gcaps_rta, rr};
use gcaps::experiments::casestudy::{morts, table4_taskset, Board};
use gcaps::experiments::overhead::estimate_theta_sim;
use gcaps::experiments::ExpConfig;
use gcaps::model::{ms, Platform, WaitMode};
use gcaps::util::bench::run;

fn main() {
    // jobs pinned to 1 so the DES throughput numbers stay comparable
    // across hosts (and with pre-sweep-engine baselines).
    let cfg = ExpConfig { tasksets: 0, seed: 1, jobs: 1, ..ExpConfig::default() };
    run("casestudy/fig10_morts_xavier", move || morts(Board::XavierNx, &cfg).len());

    let ts_s = table4_taskset(&Board::XavierNx.platform(), WaitMode::SelfSuspend);
    let ts_b = table4_taskset(&Board::XavierNx.platform(), WaitMode::BusyWait);
    run("casestudy/table5_wcrt_gcaps", {
        let ts_s = ts_s.clone();
        move || gcaps_rta::analyze(&ts_s, false, &gcaps_rta::Options::default()).schedulable
    });
    run("casestudy/table5_wcrt_tsg_rr", move || rr::analyze(&ts_b, true).schedulable);

    run("casestudy/fig13_theta_estimate", move || {
        let p = Platform::single(6, 1024, 250, 1000);
        estimate_theta_sim(&p, ms(40.0), 4)
    });
}
