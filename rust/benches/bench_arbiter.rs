//! Live-arbiter hot-path benchmarks: the runlist update (ε analog) in
//! the uncontended and contended cases, and admission waits. The paper
//! measures ε ≈ 1 ms through the IOCTL + driver path (Fig. 12); the
//! in-process arbiter must sit orders of magnitude below that so the
//! live case study's ε is dominated by design, not implementation.

use std::sync::Arc;

use gcaps::coordinator::arbiter::{Arbiter, TaskReg};
use gcaps::util::bench::run;

fn regs(n: usize) -> Vec<TaskReg> {
    (0..n)
        .map(|i| TaskReg { name: format!("t{i}"), gpu_prio: i as u32 + 1, rt: true })
        .collect()
}

fn main() {
    // Uncontended begin/end pair (the common case in Fig. 12's low mode).
    let a = Arbiter::new(regs(8));
    run("arbiter/begin_end_uncontended", move || {
        a.seg_begin(0);
        a.seg_end(0);
        a.take_eps_samples().len()
    });

    // Preemption path: low-priority task on the runlist, high-priority
    // begin displaces it (the full Alg. 1 add path + promote on end).
    let b = Arbiter::new(regs(8));
    run("arbiter/begin_end_preempting", move || {
        b.seg_begin(0);
        b.seg_begin(7); // preempts 0
        b.seg_end(7); // promotes 0
        b.seg_end(0);
        b.take_eps_samples().len()
    });

    // Contended: 4 threads hammering begin/wait/end concurrently.
    let c = Arc::new(Arbiter::new(regs(4)));
    run("arbiter/storm_4threads_x100", {
        let c = Arc::clone(&c);
        move || {
            let mut handles = vec![];
            for id in 0..4 {
                let c = Arc::clone(&c);
                handles.push(std::thread::spawn(move || {
                    for _ in 0..100 {
                        c.seg_begin(id);
                        c.wait_admitted(id, false);
                        c.seg_end(id);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap();
            }
            c.take_eps_samples().len()
        }
    });
}
