//! Benchmarks of the discrete-event simulator: per-policy throughput on
//! a Table-3 taskset (30 s simulated horizon) and the case-study
//! taskset. These drive Figs. 10-13; the DES must stay far faster than
//! real time for the randomized-offset replicas to be cheap.

use gcaps::experiments::casestudy::{table4_taskset, Board};
use gcaps::model::{ms, WaitMode};
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::bench::run;
use gcaps::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(3);
    let ts = generate(&mut rng, &GenParams::default());
    for policy in [Policy::Gcaps, Policy::TsgRr, Policy::Mpcp, Policy::FmlpPlus] {
        let ts = ts.clone();
        let name = format!("sim/table3_30s/{}", policy.label());
        run(&name, move || {
            simulate(&ts, &SimConfig::new(policy, ms(30_000.0))).run.horizon
        });
    }

    // Busy-wait variant (more CPU contention events).
    let busy = generate(
        &mut rng,
        &GenParams { mode: WaitMode::BusyWait, ..Default::default() },
    );
    run("sim/table3_30s/gcaps_busy", move || {
        simulate(&busy, &SimConfig::new(Policy::Gcaps, ms(30_000.0))).run.horizon
    });

    // The case-study taskset (Fig. 10 inner loop).
    let case = table4_taskset(&Board::XavierNx.platform(), WaitMode::SelfSuspend);
    run("sim/case_study_30s/gcaps", {
        let case = case.clone();
        move || simulate(&case, &SimConfig::new(Policy::Gcaps, ms(30_000.0))).run.horizon
    });
    run("sim/case_study_30s/tsg_rr", move || {
        simulate(&case, &SimConfig::new(Policy::TsgRr, ms(30_000.0))).run.horizon
    });

    // Trace capture overhead.
    let ts2 = generate(&mut rng, &GenParams::default());
    run("sim/table3_5s/gcaps+trace", move || {
        simulate(&ts2, &SimConfig::new(Policy::Gcaps, ms(5_000.0)).with_trace())
            .trace
            .map(|t| t.events.len())
    });
}
