//! PJRT runtime benchmarks: per-workload launch latency (the live GPU
//! segment building block) and artifact load/compile time. Skips
//! gracefully when artifacts/ has not been built.

use gcaps::runtime::{artifacts_dir, Runtime};
use gcaps::util::bench::run;

fn main() {
    let dir = artifacts_dir();
    let rt = match Runtime::load_dir(&dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("bench runtime: skipping ({e}); run `make artifacts` first");
            return;
        }
    };
    for name in rt.workloads() {
        let label = format!("runtime/launch/{name}");
        let rt_ref = &rt;
        let n = name.clone();
        run(&label, move || rt_ref.exec(&n).unwrap());
    }
    run("runtime/load_compile_all", move || Runtime::load_dir(&dir).unwrap().workloads().len());
}
