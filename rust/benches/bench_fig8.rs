//! End-to-end Fig. 8 benchmark: one schedulability data point per
//! approach at the Table 3 default parameters (what the paper plots,
//! at reduced taskset count). `cargo bench` therefore regenerates a
//! miniature of every Fig. 8 panel row and prints the ratios.

use gcaps::analysis::Approach;
use gcaps::experiments::fig8::{run_panel, schedulability, Panel};
use gcaps::experiments::ExpConfig;
use gcaps::sweep::memo;
use gcaps::util::bench::run;

fn main() {
    // jobs pinned to 1 and the taskset memo cleared per iteration: the
    // numbers must measure the cold generation + analysis path (what
    // pre-sweep-engine baselines in EXPERIMENTS.md recorded), not
    // host-dependent thread pools or Arc-clone cache hits.
    let cfg = ExpConfig { tasksets: 25, seed: 2024, jobs: 1, ..ExpConfig::default() };

    for approach in Approach::ALL {
        let name = format!("fig8/point25/{}", approach.label());
        let cfg = cfg.clone();
        let m = run(&name, move || {
            memo::clear();
            schedulability(approach, &|_| {}, &cfg)
        });
        let _ = m;
    }

    // A whole miniature panel (the per-figure regeneration target).
    let small = ExpConfig { tasksets: 10, seed: 1, jobs: 1, ..ExpConfig::default() };
    run("fig8/panel_b_mini", move || {
        memo::clear();
        run_panel(Panel::UtilPerCpu, &small).1.len()
    });

    // Print the actual data point values once, so the bench log doubles
    // as a Fig. 8 sanity row.
    println!("\nfig8 default-point schedulability (25 tasksets):");
    for approach in Approach::ALL {
        let v = schedulability(approach, &|_| {}, &cfg);
        println!("  {:16} {:.2}", approach.label(), v);
    }
}
