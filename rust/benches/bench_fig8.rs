//! End-to-end Fig. 8 benchmark: one schedulability data point per
//! approach at the Table 3 default parameters (what the paper plots,
//! at reduced taskset count). `cargo bench` therefore regenerates a
//! miniature of every Fig. 8 panel row and prints the ratios.

use gcaps::analysis::Approach;
use gcaps::experiments::fig8::{run_panel, schedulability, Panel};
use gcaps::experiments::ExpConfig;
use gcaps::util::bench::run;

fn main() {
    let cfg = ExpConfig { tasksets: 25, seed: 2024 };

    for approach in Approach::ALL {
        let name = format!("fig8/point25/{}", approach.label());
        let m = run(&name, move || schedulability(approach, &|_| {}, &cfg));
        let _ = m;
    }

    // A whole miniature panel (the per-figure regeneration target).
    let small = ExpConfig { tasksets: 10, seed: 1 };
    run("fig8/panel_b_mini", move || run_panel(Panel::UtilPerCpu, &small).1.len());

    // Print the actual data point values once, so the bench log doubles
    // as a Fig. 8 sanity row.
    println!("\nfig8 default-point schedulability (25 tasksets):");
    for approach in Approach::ALL {
        let v = schedulability(approach, &|_| {}, &cfg);
        println!("  {:16} {:.2}", approach.label(), v);
    }
}
