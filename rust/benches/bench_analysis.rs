//! Benchmarks of the response-time analyses (the Fig. 8 inner loop) and
//! the taskset generator. One Fig. 8 data point runs `tasksets` × 8
//! analyses, so these are the sweep's hot path.

use gcaps::analysis::{analyze, analyze_with_gpu_prio, audsley, Approach};
use gcaps::model::WaitMode;
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::bench::run;
use gcaps::util::rng::Pcg32;

fn main() {
    let mut rng = Pcg32::seeded(42);
    let suspend_sets: Vec<_> = (0..64).map(|_| generate(&mut rng, &GenParams::default())).collect();
    let busy_params = GenParams { mode: WaitMode::BusyWait, ..Default::default() };
    let busy_sets: Vec<_> = (0..64).map(|_| generate(&mut rng, &busy_params)).collect();

    run("taskgen/table3_default", {
        let mut rng = Pcg32::seeded(7);
        move || generate(&mut rng, &GenParams::default())
    });

    for approach in Approach::ALL {
        let sets = if approach.is_busy() { &busy_sets } else { &suspend_sets };
        let mut i = 0;
        let name = format!("rta/{}", approach.label());
        run(&name, move || {
            let ts = &sets[i % sets.len()];
            i += 1;
            analyze(ts, approach).schedulable
        });
    }

    // The full Fig. 8 GCAPS procedure (RM first, Audsley on failure).
    let mut i = 0;
    run("rta/gcaps_suspend+audsley", move || {
        let ts = &suspend_sets[i % suspend_sets.len()];
        i += 1;
        analyze_with_gpu_prio(ts, false).0.schedulable
    });

    // Audsley search alone on sets that need it.
    let mut rng2 = Pcg32::seeded(99);
    let hard = GenParams { util_per_cpu: (0.55, 0.65), ..Default::default() };
    let hard_sets: Vec<_> = (0..32).map(|_| generate(&mut rng2, &hard)).collect();
    let mut j = 0;
    run("rta/audsley_search", move || {
        let ts = &hard_sets[j % hard_sets.len()];
        j += 1;
        audsley::assign_gpu_priorities(ts, false).is_some()
    });
}
