//! Response-time analysis for the proposed GCAPS priority-based
//! preemptive GPU context scheduling (paper §6.3).
//!
//! Runlist updates cost ε = α + θ each; a job of τ_i performs up to two
//! per GPU segment, so execution demands are starred:
//!
//! ```text
//!     G*_i = G_i + 2ε·η^g_i,   G^e*_i = G^e_i + 2ε·η^g_i
//! ```
//!
//! Lemma 8:  B^C_i = (η^g_i + 1)·ε            (rt-mutex blocking)
//! Lemma 9:  I^ie_i = 0                        (no interleaving for RT)
//! Busy-waiting (§6.3.1):
//!   Lemma 10: I^dp_i = Σ_{hpp, η^g_h>0} ceil(R/T_h)·G^e*_h
//!                    + Σ_{hp\hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 11: I^id_i = Σ_{hp\hpp, η^g_h>0, η^g_i=0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 12: P^C_i  = Σ_{hpp} ceil(R/T_h)·(C_h + G^m_h)
//! Self-suspension (§6.3.2):
//!   Lemma 13: I^dp_i = Σ_{hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e_h
//!                    + Σ_{hp\hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 14: I^id_i = 0
//!   Lemma 15: P^C_i  = Σ_{hpp, η^g_h=0} ceil(R/T_h)·C_h
//!                    + Σ_{hpp, η^g_h>0} ceil((R+J^c_h)/T_h)·(C_h + G^m*_h)
//!
//! Soundness amendment (busy-waiting, CPU-only τ_i): Lemma 12 as printed
//! charges only C_h + G^m_h for a same-core GPU-using τ_h, but such a
//! τ_h *busy-waits on the CPU* for its whole G^e*_h; for a GPU-using τ_i
//! that time is already charged by Lemma 10's first term, but for a
//! CPU-only τ_i nothing else charges it. We include G^e*_h in the P^C
//! demand for that case — this matches the paper's own Table 5 numbers
//! (gcaps_busy WCRT of the CPU-only task 3 is 111 ms, far above what the
//! printed lemmas yield) and is required for the bound to dominate the
//! simulator. `Options::paper_exact_lemma12` restores the printed
//! version for the ablation bench.
//!
//! §6.4 (separate GPU priorities): with `Options::use_gpu_prio`, the
//! cross-core hp set is taken by GPU-segment priority and jitters use
//! D_h (response times of GPU-priority predecessors are unknown during
//! Audsley's search).
//!
//! Implementation: every lemma sum is lowered, once per analysed task,
//! onto the precomputed [`Prepared`] kernel — the fixed-point closure is
//! a single pass over a flat `Term` slice with zero allocation and zero
//! set derivation per iteration. π^g is read live from the `TaskSet`
//! (never cached in `Prepared`), so Audsley's mutating search reuses one
//! kernel across all levels. The original iterator-chain implementation
//! is retained in [`crate::analysis::reference`] and pinned bit-equal by
//! `rust/tests/kernel_equivalence.rs`.

use crate::analysis::prep::{run_fixed_point_warm, PrepTask, Prepared, Scratch};
use crate::analysis::terms::{AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// Analysis options.
#[derive(Debug, Clone, Copy, Default)]
pub struct Options {
    /// Use the §5.3 separate GPU-segment priorities (π^g) for GPU
    /// interference sets, with D-based jitters (§6.4).
    pub use_gpu_prio: bool,
    /// Reproduce Lemma 12 exactly as printed (drops same-core busy-wait
    /// G^e* for CPU-only tasks) — ablation only, unsound.
    pub paper_exact_lemma12: bool,
    /// Fine-grain co-running (RTGPU-style fractional SM utilization):
    /// charge a co-runnable same-engine hp segment as inflated demand
    /// instead of full serialization. See [`fine_demand`] for the rule
    /// and its soundness argument. Off by default — with every fraction
    /// at the serial 100% the charge is bit-identical either way, so
    /// enabling it on a serial taskset is unobservable.
    pub fine_grain: bool,
}

/// Fine-grain charge for one same-engine hp GPU demand `ge` (the pure
/// execution part, no ε overhead): if τ_h can co-run with τ_i, τ_i's
/// segment is only delayed while the engine lacks `fmax_i` free
/// capacity, and each unit of that delay consumes at least
/// `100 − fmax_i` percent-capacity from co-resident hp work: the DES
/// packs in rank order *with bypass* (non-fitting entries are skipped,
/// never block), so while an RT segment is pending the higher-ranked
/// residents alone jointly exceed `100 − fmax_i` — τ_i was rejected
/// against exactly their sum. Bypassed lower-ranked residents occupy
/// only capacity τ_i could not use anyway and are demoted the instant
/// τ_i fits, so they never extend the wait and the hp charge alone
/// covers it. A job of τ_h supplies at most `fmax_h · ge_h`
/// percent-capacity-work in total (capacity-partitioned SMs progress
/// each resident at full rate, so a resident fraction f occupies f for
/// exactly its ge). Charging `ceil(fmax_h · ge_h / (100 − fmax_i))`
/// per hp job therefore covers every unit of delay τ_h can contribute
/// — sound by pessimism: it assumes every co-resident instant is spent
/// at the *minimum* capacity that still blocks τ_i.
///
/// Co-runnable means `fmax_h ≤ 100 − fmax_i` (τ_h fits next to τ_i
/// even in both tasks' widest segments); then the charge is ≤ `ge_h`,
/// never optimistic past full serialization. Otherwise τ_h may occupy
/// the engine outright and the caller keeps the serial charge.
#[inline]
fn fine_demand(me: &PrepTask, p: &PrepTask, ge: Time) -> Option<Time> {
    let free = (100 as Time).saturating_sub(me.fmax);
    if p.fmax > free {
        return None; // not co-runnable (includes every serial pair)
    }
    Some(crate::analysis::terms::ceil_div(p.fmax.saturating_mul(ge), free))
}

/// J^g_h (Lemma 10), D_h-based under §6.4 (responses unknown during
/// Audsley's search). The formula itself lives on [`Prepared`].
#[inline]
fn jg(prep: &Prepared, h: usize, resp: &[Option<Time>], opts: &Options) -> Time {
    prep.jitter_g_of(h, if opts.use_gpu_prio { None } else { resp[h] })
}

/// J^c_h (Lemma 7), D_h-based under §6.4.
#[inline]
fn jc(prep: &Prepared, h: usize, resp: &[Option<Time>], opts: &Options) -> Time {
    prep.jitter_c_of(h, if opts.use_gpu_prio { None } else { resp[h] })
}

/// Is cross-core task `h` higher-priority than `i` under the active
/// priority scale? π^g is read live from `ts` (not from `Prepared`) so
/// the Audsley search's mutations are always honored.
#[inline]
fn cross_higher(ts: &TaskSet, prep: &Prepared, i: usize, h: usize, opts: &Options) -> bool {
    if opts.use_gpu_prio {
        ts.tasks[h].gpu_prio > ts.tasks[i].gpu_prio
    } else {
        prep.t[h].cpu_prio > prep.t[i].cpu_prio
    }
}

/// Lower Lemmas 10–15 for task `i` into `scratch.terms` (the
/// R-dependent interference terms; one `Term` per charged hp job
/// source). Runs once per analysed task, not per fixed-point iteration.
fn build_terms(
    ts: &TaskSet,
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
    scratch: &mut Scratch,
) {
    scratch.clear();
    let me = prep.t[i];

    // Lemma 12 / 15 (+ soundness amendment): CPU preemption. CPU-side
    // demand couples same-core tasks regardless of engine; only the ε
    // constants are per-engine.
    for &h32 in prep.hpp.get(i) {
        let h = h32 as usize;
        let p = &prep.t[h];
        if busy {
            // Lemma 12 (+ amendments: same-core busy-wait G^e* for a
            // τ_i that Lemma 10 does not already charge — CPU-only, or
            // on a different engine — and carry-in jitter).
            let mut demand = p.c_gm;
            let charged_by_lemma10 = me.uses_gpu && p.gpu == me.gpu;
            if p.uses_gpu && !charged_by_lemma10 && !opts.paper_exact_lemma12 {
                demand = demand.saturating_add(p.ge_star);
            }
            if p.uses_gpu {
                scratch.push(jc(prep, h, resp, opts), p.period, demand);
            } else {
                scratch.push(0, p.period, demand);
            }
        } else if p.uses_gpu {
            // Lemma 15, GPU-using τ_h: jittered, starred misc demand.
            scratch.push(jc(prep, h, resp, opts), p.period, p.c.saturating_add(p.gm_star));
        } else {
            // Lemma 15, CPU-only τ_h.
            scratch.push(0, p.period, p.c);
        }
    }

    if me.uses_gpu {
        // Lemma 10 / 13: direct GPU preemption — same-engine only.
        for &h32 in prep.hpp.get(i) {
            let h = h32 as usize;
            let p = &prep.t[h];
            if p.uses_gpu && p.gpu == me.gpu {
                // Busy: Lemma 10 + carry-in amendment (J^g jitter);
                // suspend: Lemma 13 (plain G^e_h, runlist update
                // overlaps the CPU-side terms). Fine-grain: only the
                // pure G^e part deflates — the 2ε·η^g runlist-update
                // overhead inside G^e* is serial CPU/driver work.
                let serial = if busy { p.ge_star } else { p.ge };
                let demand = match opts.fine_grain {
                    true => fine_demand(&me, p, p.ge)
                        .map(|d| d.saturating_add(serial.saturating_sub(p.ge)))
                        .unwrap_or(serial),
                    false => serial,
                };
                scratch.push(jg(prep, h, resp, opts), p.period, demand);
            }
        }
        for &h32 in prep.cross_gpu.get(i) {
            let h = h32 as usize;
            let p = &prep.t[h];
            if p.gpu == me.gpu && cross_higher(ts, prep, i, h, opts) {
                let demand = match opts.fine_grain {
                    true => fine_demand(&me, p, p.ge)
                        .map(|d| d.saturating_add(p.ge_star.saturating_sub(p.ge)))
                        .unwrap_or(p.ge_star),
                    false => p.ge_star,
                };
                scratch.push(jg(prep, h, resp, opts), p.period, demand);
            }
        }
    } else if busy {
        // Lemma 11: indirect delay for CPU-only tasks, restricted to the
        // engines of same-core busy-waiting carriers (engines ≥ 64 alias
        // mod 64 — conservative, see the reference module).
        let mut carrier_mask: u64 = 0;
        for &h32 in prep.hpp.get(i) {
            let p = &prep.t[h32 as usize];
            if p.uses_gpu {
                carrier_mask |= 1 << (p.gpu & 63);
            }
        }
        if carrier_mask != 0 {
            for &h32 in prep.cross_gpu.get(i) {
                let h = h32 as usize;
                let p = &prep.t[h];
                if carrier_mask & (1 << (p.gpu & 63)) != 0
                    && cross_higher(ts, prep, i, h, opts)
                {
                    scratch.push(jg(prep, h, resp, opts), p.period, p.ge_star);
                }
            }
        }
    }
}

/// Lemma 8 blocking (R-independent; see the module docs of the
/// reference path for the full channel discussion: same-engine ε vs
/// same-core cross-engine α, combined by max).
fn blocking(prep: &Prepared, i: usize) -> Time {
    let me = prep.t[i];
    let lp_gpu = |j: usize, p: &PrepTask| {
        j != i && p.uses_gpu && (p.best_effort || p.cpu_prio < me.cpu_prio)
    };
    if me.uses_gpu {
        let mut same_engine = 0;
        let mut cross_alpha = 0;
        for (j, p) in prep.t.iter().enumerate() {
            if !lp_gpu(j, p) {
                continue;
            }
            if p.gpu == me.gpu {
                same_engine = me.eps;
            } else if p.core == me.core {
                cross_alpha = cross_alpha.max(p.alpha);
            }
        }
        me.eta_g.saturating_add(1).saturating_mul(same_engine.max(cross_alpha))
    } else {
        // CPU-only τ_i: a single stall by an in-flight update on any
        // engine (conservative, core-agnostic).
        prep.t
            .iter()
            .enumerate()
            .filter(|&(j, p)| lp_gpu(j, p))
            .map(|(_, p)| p.eps)
            .max()
            .unwrap_or(0)
    }
}

/// Response time of one RT task under GCAPS (Eq. 1 with §6.3 terms),
/// over a prebuilt kernel. `scratch` is a reusable term buffer.
pub fn response_time_prepared(
    ts: &TaskSet,
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
    scratch: &mut Scratch,
) -> Rta {
    response_time_prepared_warm(ts, prep, i, busy, resp, opts, scratch, None)
}

/// [`response_time_prepared`] with a warm-start hint for the fixed
/// point — see [`run_fixed_point_warm`] for the soundness contract
/// (`hint` must be the task's least fixed point under a
/// pointwise-smaller iteration map, e.g. its response time before one
/// more task was admitted).
#[allow(clippy::too_many_arguments)]
pub fn response_time_prepared_warm(
    ts: &TaskSet,
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
    scratch: &mut Scratch,
    hint: Option<Time>,
) -> Rta {
    let me = prep.t[i];
    // Own demand: C_i + G*_i (the job's own runlist updates, §6.3).
    // Saturating like every demand on this path: crafted ε/η inputs
    // must pin to MAX (failing the deadline check), never wrap small.
    let own = me
        .c
        .saturating_add(me.g)
        .saturating_add(me.eps.saturating_mul(2).saturating_mul(me.eta_g));
    let base = own.saturating_add(blocking(prep, i));
    build_terms(ts, prep, i, busy, resp, opts, scratch);
    run_fixed_point_warm(me.deadline, base, hint, &scratch.terms)
}

/// Response time of one RT task (compatibility entry point: builds a
/// throwaway kernel — use [`response_time_prepared`] in loops).
pub fn response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
) -> Rta {
    let prep = Prepared::new(ts);
    let mut scratch = Scratch::default();
    response_time_prepared(ts, &prep, i, busy, resp, opts, &mut scratch)
}

/// Analyse all RT tasks in decreasing CPU-priority order over an
/// existing kernel.
pub fn analyze_prepared(
    ts: &TaskSet,
    prep: &Prepared,
    busy: bool,
    opts: &Options,
) -> AnalysisResult {
    analyze_prepared_warm(ts, prep, busy, opts, &[])
}

/// [`analyze_prepared`] warm-started from a previous response table —
/// the admission server's incremental re-analysis after one task joins.
///
/// `warm[i]`, when present, must be τ_i's response time from analysing
/// a taskset whose per-task iteration maps were pointwise ≤ the current
/// ones. Admitting one task only *grows* every map — it adds hp
/// interference terms for lower-priority tasks, can only raise the
/// Lemma 8 blocking maxima in the base, and (inductively down the
/// priority order) only raises the hp response times feeding the jitter
/// terms, with the `unwrap_or(deadline)` fallback dominating any
/// schedulable response — so warm results are **bit-equal to the cold
/// analysis** (pinned by `kernel_equivalence`). After a removal the
/// maps shrink and old responses may overshoot: re-analyse cold (empty
/// `warm`). An empty or short `warm` table degrades to cold per task.
pub fn analyze_prepared_warm(
    ts: &TaskSet,
    prep: &Prepared,
    busy: bool,
    opts: &Options,
    warm: &[Option<Time>],
) -> AnalysisResult {
    let mut scratch = Scratch::default();
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for &i in &prep.order {
        let hint = warm.get(i).copied().flatten();
        let r =
            response_time_prepared_warm(ts, prep, i, busy, &resp, opts, &mut scratch, hint);
        resp[i] = r.time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// Analyse all RT tasks in decreasing CPU-priority order.
pub fn analyze(ts: &TaskSet, busy: bool, opts: &Options) -> AnalysisResult {
    let prep = Prepared::new(ts);
    analyze_prepared(ts, &prep, busy, opts)
}

/// GCAPS analysis with the fine-grain co-running charge enabled —
/// the serial-vs-fine comparison entry used by
/// `gcaps exp scenarios --only finegrain`. On an all-serial taskset
/// this is bit-identical to [`analyze`] with default options.
pub fn analyze_fine(ts: &TaskSet, busy: bool) -> AnalysisResult {
    analyze(ts, busy, &Options { fine_grain: true, ..Options::default() })
}

/// [`Analysis`] implementation: GCAPS with paper-default options (RM
/// priorities for GPU segments; the Audsley retry lives in
/// [`crate::analysis::approach_schedulable`]).
#[derive(Debug, Clone, Copy)]
pub struct GcapsAnalysis {
    pub busy: bool,
}

impl Analysis for GcapsAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "gcaps_busy" } else { "gcaps_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy, &Options::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

    fn platform() -> Platform {
        Platform::single(2, 1024, 200, 1000)
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn single_gpu_task_demand_includes_eps() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts, false, &Options::default());
        // R = C + G + 2ε·η = 8 + 2 = 10 ms (no lower-priority GPU task
        // exists, so Lemma 8's blocking term vanishes)
        assert_eq!(res.response[0], Some(ms(10.0)));
    }

    #[test]
    fn highest_priority_unaffected_by_lower() {
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false, &Options::default());
        // GCAPS preempts: lower-priority 60 ms kernel does NOT block the
        // high-priority task beyond ε blocking.
        assert_eq!(res.response[0], Some(ms(12.0)));
    }

    #[test]
    fn cross_core_direct_preemption_counts() {
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false, &Options::default());
        let r_lo = res.response[1].unwrap();
        // τ_1 suffers at least one preemption of G^e*_0 = 22 ms on top
        // of its own starred demand (10 ms; no ε-blocking — no GPU task
        // below it).
        assert!(r_lo >= ms(10.0 + 22.0), "r_lo = {r_lo}");
    }

    #[test]
    fn busy_vs_suspend_cpu_only_victim() {
        // CPU-only task under a same-core GPU-using hp task: busy-waiting
        // charges the full G^e*, suspension only C + G^m*.
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(200.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rb = analyze(&ts, true, &Options::default()).response[1].unwrap();
        let rs = analyze(&ts, false, &Options::default()).response[1].unwrap();
        assert!(rb >= rs + ms(25.0), "busy {rb} suspend {rs}");
    }

    #[test]
    fn paper_exact_lemma12_is_smaller() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(200.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let sound = analyze(&ts, true, &Options::default()).response[1].unwrap();
        let exact = analyze(
            &ts,
            true,
            &Options { paper_exact_lemma12: true, ..Default::default() },
        )
        .response[1]
            .unwrap();
        assert!(exact < sound);
    }

    #[test]
    fn best_effort_gpu_tasks_do_not_interfere() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 200.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = analyze(&ts, false, &Options::default());
        // GCAPS shields RT tasks from best-effort GPU load (ε blocking
        // is already in Lemma 8).
        assert_eq!(res.response[0], Some(ms(12.0)));
        assert!(res.schedulable);
    }

    #[test]
    fn epsilon_zero_matches_plain_demand() {
        let p = platform().with_epsilon(0);
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], p);
        let res = analyze(&ts, false, &Options::default());
        assert_eq!(res.response[0], Some(ms(8.0)));
    }

    #[test]
    fn monotone_in_epsilon() {
        let mk = |eps| {
            let p = platform().with_epsilon(eps);
            TaskSet::new(
                vec![
                    gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0),
                    gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0),
                ],
                p,
            )
        };
        let mut prev = 0;
        for eps in [0, 200, 500, 1000, 2000] {
            let r = analyze(&mk(eps), false, &Options::default()).response[1].unwrap();
            assert!(r >= prev, "not monotone at ε = {eps}");
            prev = r;
        }
    }

    #[test]
    fn cross_engine_tasks_do_not_interfere() {
        // Two GPU-heavy tasks on different cores AND different engines:
        // each analyses exactly as if it were alone (no ε-blocking, no
        // direct preemption). Same taskset on one engine: they couple.
        let mut hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 100.0);
        hi.gpu = 0;
        lo.gpu = 1;
        let p2 = platform().with_num_gpus(2);
        let ts2 = TaskSet::new(vec![hi.clone(), lo.clone()], p2);
        let res2 = analyze(&ts2, false, &Options::default());
        // Isolated demand: C + G + 2ε = 23 + 2 = 25 ms, no blocking.
        assert_eq!(res2.response[0], Some(ms(25.0)));
        assert_eq!(res2.response[1], Some(ms(25.0)));

        lo.gpu = 0;
        let ts1 = TaskSet::new(vec![hi, lo], platform());
        let res1 = analyze(&ts1, false, &Options::default());
        let r_lo = res1.response[1].unwrap();
        assert!(r_lo > ms(25.0), "shared engine must add preemption: {r_lo}");
    }

    #[test]
    fn same_core_cross_engine_driver_call_blocks_alpha() {
        // A same-core lower-priority task on ANOTHER engine still stalls
        // τ_i through its non-preemptible driver-call CPU section: the
        // Lemma 8 term must charge (η+1)·α cross-engine, not zero (the
        // DES exhibits the stall — see sim::Engine::eff_prio).
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lp = gpu_task(1, 0, 1, 2.0, 1.0, 5.0, 100.0);
        hp.gpu = 1;
        let ts = TaskSet::new(vec![hp, lp], platform().with_num_gpus(2));
        let r = analyze(&ts, false, &Options::default()).response[0].unwrap();
        // own 10 ms + (η+1)·α = 2 · 0.8 ms.
        assert_eq!(r, ms(11.6));
    }

    #[test]
    fn busy_cross_engine_hp_charges_busy_wait_on_cpu() {
        // Same core, different engines, busy-waiting: τ_h's spin still
        // occupies the CPU (Lemma 12 amendment extends to the
        // cross-engine case because Lemma 10 no longer charges it).
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let mut lp = gpu_task(1, 0, 1, 2.0, 1.0, 5.0, 200.0);
        hp.gpu = 1;
        lp.gpu = 0;
        let ts = TaskSet::new(vec![hp, lp], platform().with_num_gpus(2));
        let r = analyze(&ts, true, &Options::default()).response[1].unwrap();
        // τ_1 must absorb τ_0's full busy-wait G^e* = 32 ms on top of
        // its own demand.
        assert!(r >= ms(9.0 + 32.0), "r = {r}");
    }

    #[test]
    fn gpu_prio_changes_cross_core_set() {
        // Two GPU tasks on different cores; τ_0 has higher CPU priority.
        // With swapped GPU priorities, τ_0 suffers cross-core preemption
        // from τ_1 instead.
        let mut t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut t1 = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 150.0);
        t0.gpu_prio = 1;
        t1.gpu_prio = 2;
        let ts = TaskSet::new(vec![t0, t1], platform());
        let opts = Options { use_gpu_prio: true, ..Default::default() };
        let res = analyze(&ts, false, &opts);
        let r0 = res.response[0].unwrap();
        // τ_0 now sees τ_1's G^e* = 22 ms as direct preemption.
        assert!(r0 >= ms(12.0 + 22.0), "r0 = {r0}");
    }

    #[test]
    fn prepared_reuse_across_gpu_prio_mutations() {
        // One kernel must serve both before and after a π^g mutation —
        // the property Audsley's search relies on.
        let mut t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let t1 = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 150.0);
        t0.gpu_prio = 2;
        let mut ts = TaskSet::new(vec![t0, t1], platform());
        let opts = Options { use_gpu_prio: true, ..Default::default() };
        let prep = Prepared::new(&ts);
        let mut scratch = Scratch::default();
        let no_resp = vec![None; 2];
        let before =
            response_time_prepared(&ts, &prep, 0, false, &no_resp, &opts, &mut scratch);
        // Swap: τ_1 now outranks τ_0 on the GPU.
        ts.tasks[0].gpu_prio = 1;
        ts.tasks[1].gpu_prio = 2;
        let after =
            response_time_prepared(&ts, &prep, 0, false, &no_resp, &opts, &mut scratch);
        assert_eq!(before, response_time(&ts_with_prio(&ts, 2, 1), 0, false, &no_resp, &opts));
        assert_eq!(after, response_time(&ts, 0, false, &no_resp, &opts));
        assert!(after.time().unwrap() > before.time().unwrap());
    }

    fn ts_with_prio(ts: &TaskSet, p0: u32, p1: u32) -> TaskSet {
        let mut out = ts.clone();
        out.tasks[0].gpu_prio = p0;
        out.tasks[1].gpu_prio = p1;
        out
    }

    #[test]
    fn fine_grain_on_serial_taskset_is_unobservable() {
        // All fractions at 100%: no pair is co-runnable, so the fine
        // charge degenerates to the serial one bit-for-bit.
        let ts = TaskSet::new(
            vec![
                gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0),
                gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0),
            ],
            platform(),
        );
        for busy in [false, true] {
            let serial = analyze(&ts, busy, &Options::default());
            let fine = analyze_fine(&ts, busy);
            assert_eq!(serial.response, fine.response, "busy = {busy}");
            assert_eq!(serial.schedulable, fine.schedulable);
        }
    }

    #[test]
    fn fine_grain_deflates_co_runnable_interference() {
        // hp at 40%, analysed task at 50%: co-runnable (40 ≤ 100−50),
        // so the per-job charge drops from G^e* to
        // ceil(40·G^e/50) + 2ε·η = 0.8·G^e + overhead.
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        hp.gpu_segments[0] = hp.gpu_segments[0].with_par(40);
        lo.gpu_segments[0] = lo.gpu_segments[0].with_par(50);
        let ts = TaskSet::new(vec![hp, lo], platform());
        let serial = analyze(&ts, false, &Options::default()).response[1].unwrap();
        let fine = analyze_fine(&ts, false).response[1].unwrap();
        // Serial charges the full 22 ms G^e* per hp job; fine charges
        // 0.8·20 + 2 = 18 ms. One hp job in the window → exactly 4 ms
        // less.
        assert_eq!(serial - fine, ms(4.0), "serial {serial} fine {fine}");
    }

    #[test]
    fn fine_grain_never_optimistic_past_serial() {
        // The charge is capped at the serial one: a non-co-runnable
        // pair (70% vs 50%) keeps full serialization.
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        hp.gpu_segments[0] = hp.gpu_segments[0].with_par(70);
        lo.gpu_segments[0] = lo.gpu_segments[0].with_par(50);
        let ts = TaskSet::new(vec![hp, lo], platform());
        let serial = analyze(&ts, false, &Options::default());
        let fine = analyze_fine(&ts, false);
        assert_eq!(serial.response, fine.response);
    }

    #[test]
    fn warm_reanalysis_after_admit_is_bit_equal() {
        // The admission server's fast path: analyse a 1-task set, admit
        // a second task via the kernel delta, re-analyse warm from the
        // old response table — must be bit-equal to a cold analysis of
        // the grown set, in both wait modes.
        let t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let t1 = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 150.0);
        let small = TaskSet::new(vec![t0.clone()], platform());
        let grown = TaskSet::new(vec![t0, t1], platform());
        let mut prep = crate::analysis::Prepared::new(&small);
        prep.admit_task(&grown);
        for busy in [false, true] {
            let old = analyze(&small, busy, &Options::default());
            let mut warm = old.response.clone();
            warm.push(None); // the joiner has no previous response
            let cold = analyze(&grown, busy, &Options::default());
            let inc =
                analyze_prepared_warm(&grown, &prep, busy, &Options::default(), &warm);
            assert_eq!(inc.response, cold.response, "busy = {busy}");
            assert_eq!(inc.schedulable, cold.schedulable);
        }
    }
}
