//! Response-time analysis for the proposed GCAPS priority-based
//! preemptive GPU context scheduling (paper §6.3).
//!
//! Runlist updates cost ε = α + θ each; a job of τ_i performs up to two
//! per GPU segment, so execution demands are starred:
//!
//! ```text
//!     G*_i = G_i + 2ε·η^g_i,   G^e*_i = G^e_i + 2ε·η^g_i
//! ```
//!
//! Lemma 8:  B^C_i = (η^g_i + 1)·ε            (rt-mutex blocking)
//! Lemma 9:  I^ie_i = 0                        (no interleaving for RT)
//! Busy-waiting (§6.3.1):
//!   Lemma 10: I^dp_i = Σ_{hpp, η^g_h>0} ceil(R/T_h)·G^e*_h
//!                    + Σ_{hp\hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 11: I^id_i = Σ_{hp\hpp, η^g_h>0, η^g_i=0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 12: P^C_i  = Σ_{hpp} ceil(R/T_h)·(C_h + G^m_h)
//! Self-suspension (§6.3.2):
//!   Lemma 13: I^dp_i = Σ_{hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e_h
//!                    + Σ_{hp\hpp, η^g_h>0} ceil((R+J^g_h)/T_h)·G^e*_h
//!   Lemma 14: I^id_i = 0
//!   Lemma 15: P^C_i  = Σ_{hpp, η^g_h=0} ceil(R/T_h)·C_h
//!                    + Σ_{hpp, η^g_h>0} ceil((R+J^c_h)/T_h)·(C_h + G^m*_h)
//!
//! Soundness amendment (busy-waiting, CPU-only τ_i): Lemma 12 as printed
//! charges only C_h + G^m_h for a same-core GPU-using τ_h, but such a
//! τ_h *busy-waits on the CPU* for its whole G^e*_h; for a GPU-using τ_i
//! that time is already charged by Lemma 10's first term, but for a
//! CPU-only τ_i nothing else charges it. We include G^e*_h in the P^C
//! demand for that case — this matches the paper's own Table 5 numbers
//! (gcaps_busy WCRT of the CPU-only task 3 is 111 ms, far above what the
//! printed lemmas yield) and is required for the bound to dominate the
//! simulator. `Options::paper_exact_lemma12` restores the printed
//! version for the ablation bench.
//!
//! §6.4 (separate GPU priorities): with `Options::use_gpu_prio`, the
//! cross-core hp set is taken by GPU-segment priority and jitters use
//! D_h (response times of GPU-priority predecessors are unknown during
//! Audsley's search).

use crate::analysis::terms::{
    fixed_point, jitter_c, jitter_g, njobs, njobs_jitter, AnalysisResult, Rta,
};
use crate::analysis::Analysis;
use crate::model::{Task, TaskSet, Time, WaitMode};

/// Analysis options.
#[derive(Debug, Clone, Copy, Default)]
pub struct Options {
    /// Use the §5.3 separate GPU-segment priorities (π^g) for GPU
    /// interference sets, with D-based jitters (§6.4).
    pub use_gpu_prio: bool,
    /// Reproduce Lemma 12 exactly as printed (drops same-core busy-wait
    /// G^e* for CPU-only tasks) — ablation only, unsound.
    pub paper_exact_lemma12: bool,
}

/// ε of the engine a task is assigned to (per-GPU overheads: a task's
/// runlist updates go to its own engine's driver lock).
fn eps_of(ts: &TaskSet, t: &Task) -> Time {
    ts.platform.gpus[t.gpu].epsilon
}

/// G^e*_h = G^e_h + 2ε·η^g_h (runlist updates around each segment).
fn ge_star(t: &Task, eps: Time) -> Time {
    t.ge() + 2 * eps * t.eta_g() as Time
}

/// G^m*_h = G^m_h + 2ε·η^g_h.
fn gm_star(t: &Task, eps: Time) -> Time {
    t.gm() + 2 * eps * t.eta_g() as Time
}

/// J^g_h, with D_h replacing R_h under the GPU-priority assignment (§6.4).
fn jg(t: &Task, resp: &[Option<Time>], opts: &Options) -> Time {
    if opts.use_gpu_prio {
        jitter_g(t, None)
    } else {
        jitter_g(t, resp[t.id])
    }
}

fn jc(t: &Task, resp: &[Option<Time>], opts: &Options) -> Time {
    if opts.use_gpu_prio {
        jitter_c(t, None)
    } else {
        jitter_c(t, resp[t.id])
    }
}

/// Cross-core higher-priority GPU-using tasks: by π^g when the separate
/// assignment is active, else by π^c.
fn hp_gpu_cross<'a>(
    ts: &'a TaskSet,
    i: usize,
    opts: &Options,
) -> Box<dyn Iterator<Item = &'a Task> + 'a> {
    if opts.use_gpu_prio {
        Box::new(ts.hp_gpu_other_core(i).filter(|h| h.uses_gpu()))
    } else {
        Box::new(ts.hp_other_core(i).filter(|h| h.uses_gpu()))
    }
}

/// Lemma 10 / 13: direct GPU preemption. Only tasks sharing τ_i's GPU
/// engine can preempt its context — other engines have disjoint
/// runlists (per-GPU interference sets).
fn i_dp(ts: &TaskSet, i: usize, r: Time, busy: bool, resp: &[Option<Time>], opts: &Options) -> Time {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return 0;
    }
    let mut total = 0;
    // Same-core term.
    for h in ts.hpp(i).filter(|h| h.uses_gpu() && h.gpu == me.gpu) {
        total += if busy {
            // Lemma 10 (+ carry-in amendment): the printed lemma uses
            // plain ceil(R/T_h), but cross-core GPU preemption can defer
            // τ_h's GPU execution past its release; the device model
            // exhibits the carry-in, so we add the J^g jitter as in
            // Lemma 13.
            njobs_jitter(r, jg(h, resp, opts), h.period) * ge_star(h, eps_of(ts, h))
        } else {
            // Lemma 13: runlist update overlaps with the CPU-side terms,
            // so plain G^e_h suffices; self-suspension adds the jitter.
            njobs_jitter(r, jg(h, resp, opts), h.period) * h.ge()
        };
    }
    // Cross-core term (identical in both lemmas).
    for h in hp_gpu_cross(ts, i, opts).filter(|h| h.gpu == me.gpu) {
        total += njobs_jitter(r, jg(h, resp, opts), h.period) * ge_star(h, eps_of(ts, h));
    }
    total
}

/// Lemma 11 (busy only): indirect delay for CPU-only tasks. Per §6.1 it
/// cannot exist stand-alone: it requires a same-core higher-priority
/// GPU-using (busy-waiting) task — the carrier. Cross-core GPU
/// execution reaches τ_i only through a carrier busy-waiting on the
/// SAME engine, so the charged set is restricted to the carriers'
/// engines (with one engine this is exactly the printed lemma).
fn i_id_busy(ts: &TaskSet, i: usize, r: Time, resp: &[Option<Time>], opts: &Options) -> Time {
    let me = &ts.tasks[i];
    if me.uses_gpu() {
        return 0; // covered by Lemma 10's cross-core term
    }
    // Carrier-engine set as a bitmask — no allocation in the fixpoint
    // hot path. Engines ≥ 64 alias (mod 64), which can only ADD
    // interference terms, never drop them — conservative, and far
    // beyond any real engine count.
    let mut carrier_mask: u64 = 0;
    for h in ts.hpp(i).filter(|h| h.uses_gpu()) {
        carrier_mask |= 1 << (h.gpu & 63);
    }
    if carrier_mask == 0 {
        return 0; // no same-core busy-waiting carrier (§6.1)
    }
    hp_gpu_cross(ts, i, opts)
        .filter(|h| carrier_mask & (1 << (h.gpu & 63)) != 0)
        .map(|h| njobs_jitter(r, jg(h, resp, opts), h.period) * ge_star(h, eps_of(ts, h)))
        .sum()
}

/// Lemma 12 / 15 (+ soundness amendment): CPU preemption. CPU-side
/// demand couples same-core tasks regardless of engine; only the ε
/// constants are per-engine (τ_h's updates hit τ_h's engine).
fn p_c(ts: &TaskSet, i: usize, r: Time, busy: bool, resp: &[Option<Time>], opts: &Options) -> Time {
    let me = &ts.tasks[i];
    let mut total = 0;
    for h in ts.hpp(i) {
        total += if busy {
            // Lemma 12 (+ amendments: same-core busy-wait G^e* for a
            // τ_i that Lemma 10 does not already charge — CPU-only, or
            // on a different engine — and carry-in jitter; see module
            // docs).
            let mut demand = h.c() + h.gm();
            let charged_by_lemma10 = me.uses_gpu() && h.gpu == me.gpu;
            if h.uses_gpu() && !charged_by_lemma10 && !opts.paper_exact_lemma12 {
                demand += ge_star(h, eps_of(ts, h));
            }
            if h.uses_gpu() {
                njobs_jitter(r, jc(h, resp, opts), h.period) * demand
            } else {
                njobs(r, h.period) * demand
            }
        } else if h.uses_gpu() {
            // Lemma 15, GPU-using τ_h: jittered, starred misc demand.
            njobs_jitter(r, jc(h, resp, opts), h.period) * (h.c() + gm_star(h, eps_of(ts, h)))
        } else {
            // Lemma 15, CPU-only τ_h.
            njobs(r, h.period) * h.c()
        };
    }
    total
}

/// Response time of one RT task under GCAPS (Eq. 1 with §6.3 terms).
pub fn response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
) -> Rta {
    let me = &ts.tasks[i];
    let eps = eps_of(ts, me);
    // Own demand: C_i + G*_i (the job's own runlist updates, §6.3).
    let own = me.c() + me.g() + 2 * eps * me.eta_g() as Time;
    // Lemma 8: blocking from lower-priority runlist updates. Two
    // channels, both bounded per issue point (η^g_i + 1 of them):
    //
    // - SAME engine: an lp (or best-effort) task's in-flight update
    //   holds τ_i's engine's driver lock — the printed lemma's ε.
    // - OTHER engine, SAME core (multi-GPU only): the update doesn't
    //   touch τ_i's lock, but its CPU-side call section is still
    //   non-preemptible on τ_i's core (the DES models exactly this),
    //   stalling τ_i by up to that engine's α = ε − θ.
    //
    // The channels are combined by MAX, not sum. This is exact w.r.t.
    // the device model (the soundness oracle `tests/soundness.rs`
    // checks against): there, the only physical stall is the same-core
    // non-preemptible call section — cross-core driver calls never
    // delay τ_i, and a displaced lp context is charged via I^dp — so
    // one in-flight call per issue point bounds it. On a hypothetical
    // real driver with per-engine locks, a cross-core same-engine
    // lock hold could compound with a same-core cross-engine stall by
    // up to min(ε, α) extra per issue point; we follow the printed
    // Lemma 8 (which also charges one ε per issue point) and treat
    // that as covered by its margin. Max also keeps the bound monotone
    // in the engine count. With one engine this reduces exactly to the
    // printed term.
    let lp_gpu = |t: &&Task| {
        t.id != me.id && t.uses_gpu() && (t.best_effort || t.cpu_prio < me.cpu_prio)
    };
    let blocking = if me.uses_gpu() {
        let same_engine = if ts.tasks.iter().filter(lp_gpu).any(|t| t.gpu == me.gpu) {
            eps
        } else {
            0
        };
        let cross_alpha = ts
            .tasks
            .iter()
            .filter(lp_gpu)
            .filter(|t| t.core == me.core && t.gpu != me.gpu)
            .map(|t| {
                let c = &ts.platform.gpus[t.gpu];
                c.epsilon.saturating_sub(c.theta)
            })
            .max()
            .unwrap_or(0);
        (me.eta_g() as Time + 1) * same_engine.max(cross_alpha)
    } else {
        // CPU-only τ_i: a single stall by an in-flight update on any
        // engine (conservative, core-agnostic — matches the legacy
        // single-GPU charge).
        ts.tasks.iter().filter(lp_gpu).map(|t| eps_of(ts, t)).max().unwrap_or(0)
    };
    fixed_point(me.deadline, own + blocking, |r| {
        own + blocking
            + p_c(ts, i, r, busy, resp, opts)
            + i_dp(ts, i, r, busy, resp, opts)
            + if busy { i_id_busy(ts, i, r, resp, opts) } else { 0 }
    })
}

/// Analyse all RT tasks in decreasing CPU-priority order.
pub fn analyze(ts: &TaskSet, busy: bool, opts: &Options) -> AnalysisResult {
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    let mut order: Vec<usize> =
        ts.tasks.iter().filter(|t| !t.best_effort).map(|t| t.id).collect();
    order.sort_by(|&a, &b| ts.tasks[b].cpu_prio.cmp(&ts.tasks[a].cpu_prio));
    for i in order {
        resp[i] = response_time(ts, i, busy, &resp, opts).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// [`Analysis`] implementation: GCAPS with paper-default options (RM
/// priorities for GPU segments; the Audsley retry lives in
/// [`crate::analysis::approach_schedulable`]).
#[derive(Debug, Clone, Copy)]
pub struct GcapsAnalysis {
    pub busy: bool,
}

impl Analysis for GcapsAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "gcaps_busy" } else { "gcaps_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy, &Options::default())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

    fn platform() -> Platform {
        Platform::single(2, 1024, 200, 1000)
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn single_gpu_task_demand_includes_eps() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts, false, &Options::default());
        // R = C + G + 2ε·η = 8 + 2 = 10 ms (no lower-priority GPU task
        // exists, so Lemma 8's blocking term vanishes)
        assert_eq!(res.response[0], Some(ms(10.0)));
    }

    #[test]
    fn highest_priority_unaffected_by_lower() {
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false, &Options::default());
        // GCAPS preempts: lower-priority 60 ms kernel does NOT block the
        // high-priority task beyond ε blocking.
        assert_eq!(res.response[0], Some(ms(12.0)));
    }

    #[test]
    fn cross_core_direct_preemption_counts() {
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false, &Options::default());
        let r_lo = res.response[1].unwrap();
        // τ_1 suffers at least one preemption of G^e*_0 = 22 ms on top
        // of its own starred demand (10 ms; no ε-blocking — no GPU task
        // below it).
        assert!(r_lo >= ms(10.0 + 22.0), "r_lo = {r_lo}");
    }

    #[test]
    fn busy_vs_suspend_cpu_only_victim() {
        // CPU-only task under a same-core GPU-using hp task: busy-waiting
        // charges the full G^e*, suspension only C + G^m*.
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(200.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rb = analyze(&ts, true, &Options::default()).response[1].unwrap();
        let rs = analyze(&ts, false, &Options::default()).response[1].unwrap();
        assert!(rb >= rs + ms(25.0), "busy {rb} suspend {rs}");
    }

    #[test]
    fn paper_exact_lemma12_is_smaller() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(200.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let sound = analyze(&ts, true, &Options::default()).response[1].unwrap();
        let exact = analyze(
            &ts,
            true,
            &Options { paper_exact_lemma12: true, ..Default::default() },
        )
        .response[1]
            .unwrap();
        assert!(exact < sound);
    }

    #[test]
    fn best_effort_gpu_tasks_do_not_interfere() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 200.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = analyze(&ts, false, &Options::default());
        // GCAPS shields RT tasks from best-effort GPU load (ε blocking
        // is already in Lemma 8).
        assert_eq!(res.response[0], Some(ms(12.0)));
        assert!(res.schedulable);
    }

    #[test]
    fn epsilon_zero_matches_plain_demand() {
        let p = platform().with_epsilon(0);
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], p);
        let res = analyze(&ts, false, &Options::default());
        assert_eq!(res.response[0], Some(ms(8.0)));
    }

    #[test]
    fn monotone_in_epsilon() {
        let mk = |eps| {
            let p = platform().with_epsilon(eps);
            TaskSet::new(
                vec![
                    gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0),
                    gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0),
                ],
                p,
            )
        };
        let mut prev = 0;
        for eps in [0, 200, 500, 1000, 2000] {
            let r = analyze(&mk(eps), false, &Options::default()).response[1].unwrap();
            assert!(r >= prev, "not monotone at ε = {eps}");
            prev = r;
        }
    }

    #[test]
    fn cross_engine_tasks_do_not_interfere() {
        // Two GPU-heavy tasks on different cores AND different engines:
        // each analyses exactly as if it were alone (no ε-blocking, no
        // direct preemption). Same taskset on one engine: they couple.
        let mut hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 100.0);
        hi.gpu = 0;
        lo.gpu = 1;
        let p2 = platform().with_num_gpus(2);
        let ts2 = TaskSet::new(vec![hi.clone(), lo.clone()], p2);
        let res2 = analyze(&ts2, false, &Options::default());
        // Isolated demand: C + G + 2ε = 23 + 2 = 25 ms, no blocking.
        assert_eq!(res2.response[0], Some(ms(25.0)));
        assert_eq!(res2.response[1], Some(ms(25.0)));

        lo.gpu = 0;
        let ts1 = TaskSet::new(vec![hi, lo], platform());
        let res1 = analyze(&ts1, false, &Options::default());
        let r_lo = res1.response[1].unwrap();
        assert!(r_lo > ms(25.0), "shared engine must add preemption: {r_lo}");
    }

    #[test]
    fn same_core_cross_engine_driver_call_blocks_alpha() {
        // A same-core lower-priority task on ANOTHER engine still stalls
        // τ_i through its non-preemptible driver-call CPU section: the
        // Lemma 8 term must charge (η+1)·α cross-engine, not zero (the
        // DES exhibits the stall — see sim::Engine::eff_prio).
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lp = gpu_task(1, 0, 1, 2.0, 1.0, 5.0, 100.0);
        hp.gpu = 1;
        let ts = TaskSet::new(vec![hp, lp], platform().with_num_gpus(2));
        let r = analyze(&ts, false, &Options::default()).response[0].unwrap();
        // own 10 ms + (η+1)·α = 2 · 0.8 ms.
        assert_eq!(r, ms(11.6));
    }

    #[test]
    fn busy_cross_engine_hp_charges_busy_wait_on_cpu() {
        // Same core, different engines, busy-waiting: τ_h's spin still
        // occupies the CPU (Lemma 12 amendment extends to the
        // cross-engine case because Lemma 10 no longer charges it).
        let mut hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 200.0);
        let mut lp = gpu_task(1, 0, 1, 2.0, 1.0, 5.0, 200.0);
        hp.gpu = 1;
        lp.gpu = 0;
        let ts = TaskSet::new(vec![hp, lp], platform().with_num_gpus(2));
        let r = analyze(&ts, true, &Options::default()).response[1].unwrap();
        // τ_1 must absorb τ_0's full busy-wait G^e* = 32 ms on top of
        // its own demand.
        assert!(r >= ms(9.0 + 32.0), "r = {r}");
    }

    #[test]
    fn gpu_prio_changes_cross_core_set() {
        // Two GPU tasks on different cores; τ_0 has higher CPU priority.
        // With swapped GPU priorities, τ_0 suffers cross-core preemption
        // from τ_1 instead.
        let mut t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut t1 = gpu_task(1, 1, 1, 2.0, 1.0, 20.0, 150.0);
        t0.gpu_prio = 1;
        t1.gpu_prio = 2;
        let ts = TaskSet::new(vec![t0, t1], platform());
        let opts = Options { use_gpu_prio: true, ..Default::default() };
        let res = analyze(&ts, false, &opts);
        let r0 = res.response[0].unwrap();
        // τ_0 now sees τ_1's G^e* = 22 ms as direct preemption.
        assert!(r0 >= ms(12.0 + 22.0), "r0 = {r0}");
    }
}
