//! Response-time analysis for the DEFAULT Tegra GPU driver's
//! work-conserving time-sliced round-robin TSG scheduling (paper §6.2).
//!
//! This is, per the paper, the first formal WCRT analysis of the
//! unmodified driver: each process's TSG gets equal time slices of
//! length L on the runlist, GPU execution of concurrent processes is
//! *interleaved* (never preempted mid-slice, never prioritised), and
//! every TSG switch costs θ.
//!
//! Lemma 1: I^ie_i  = Σ_j 𝓘(ν, G^e_{i,j}),  ν = |{k ≠ i, η^g_k > 0}|
//! Lemma 2: I^dp_i  = 0 (no preemption, only interleaving)
//! Lemma 3: B^C_i   = 0 (no runlist-update requests)
//! Busy-waiting (§6.2.1):
//!   Lemma 4: I^id_i = Σ_{τ_h ∈ hpp, η^g_h>0} ceil(R/T_h) ·
//!                       Σ_j 𝓘(|{k ∉ hpp(τ_i), η^g_k>0} ∪ {τ_h}|, G^e_{h,j})
//!   Lemma 5: P^C_i  = Σ_{τ_h ∈ hpp} ceil(R/T_h) · (C_h + G^m_h)
//! Self-suspension (§6.2.2):
//!   Lemma 6: I^id_i = 0
//!   Lemma 7: P^C_i  = Σ_{τ_h ∈ hpp} ceil((R + J^c_h)/T_h) · (C_h + G^m_h)
//!
//! Interpretation note (Lemma 4): the interleaving-set cardinality
//! includes τ_h itself — the busy-wait window of τ_h covers τ_h's own
//! time slices plus one slice + θ per other active TSG per round — which
//! is what makes the busy-waiting bound account for the full wait.
//!
//! Implementation: Eq. (3) is linear in the round count `ceil(G^e/L)`,
//! so [`Prepared`] caches each task's `Σ_j ceil(G^e_{i,j}/L)` once and
//! every 𝓘-sum collapses to one `interleave_rounds` call — no segment
//! walk, no per-iteration ν recount. The per-engine ν bases of Lemma 4
//! come from `Prepared::gpu_users` minus a small hpp pass. The original
//! iterator-chain path lives in [`crate::analysis::reference`].

use crate::analysis::prep::{run_fixed_point, Prepared, Scratch};
use crate::analysis::terms::{interleave_rounds, AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// Lower Lemmas 4/5/7 for task `i` into `scratch.terms`.
fn build_terms(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    scratch: &mut Scratch,
) {
    scratch.clear();

    // Lemmas 5/7: CPU preemption from same-core higher-priority tasks.
    // CPU-only hp tasks never suspend nor get GPU-deferred, so the
    // plain ceil(R/T) count is exact for them; GPU-using hp tasks carry
    // the J^c jitter in both modes (see the reference module docs).
    for &h32 in prep.hpp.get(i) {
        let h = h32 as usize;
        let p = &prep.t[h];
        let jit = if p.uses_gpu { prep.jitter_c(h, resp) } else { 0 };
        scratch.push(jit, p.period, p.c_gm);
    }

    // Lemma 4 (busy-waiting): indirect delay from same-core
    // higher-priority tasks busy-waiting on interleaved GPU execution.
    // Each carrier τ_h waits on its OWN engine's ring: ν_h counts the
    // engine's GPU users outside hpp(τ_i) (incl. best-effort and τ_i
    // itself), plus τ_h's own slices.
    if busy {
        // Per-engine count of GPU-using hpp tasks (reusable buffer — no
        // allocation per analysed task).
        scratch.engines.clear();
        scratch.engines.resize(prep.gpu_users.len(), 0);
        for &h32 in prep.hpp.get(i) {
            let p = &prep.t[h32 as usize];
            if p.uses_gpu {
                scratch.engines[p.gpu] += 1;
            }
        }
        for &h32 in prep.hpp.get(i) {
            let h = h32 as usize;
            let p = &prep.t[h];
            if !p.uses_gpu {
                continue;
            }
            let nu = prep.gpu_users[p.gpu] - scratch.engines[p.gpu] + 1;
            // Whole-job 𝓘 from the cached round sum (Eq. 3 is linear in
            // rounds, so this equals the per-segment sum exactly).
            let per_job = interleave_rounds(nu, p.rounds_sum, p.tsg_slice, p.theta);
            // Carry-in amendment: J^g jitter covers GPU-deferred
            // busy-wait windows (cf. Lemma 10's cross-core term).
            scratch.push(prep.jitter_g(h, resp), p.period, per_job);
        }
    }
}

/// Response time of one task under the default driver (Eq. 1 with the
/// §6.2 terms), over a prebuilt kernel.
pub fn response_time_prepared(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    scratch: &mut Scratch,
) -> Rta {
    let me = prep.t[i];
    let own = me.c.saturating_add(me.g);
    // Lemma 1 (R-independent): interleaving on τ_i's own segments with
    // the ν sharers of its engine.
    let iie = if me.uses_gpu {
        interleave_rounds(prep.nu(i), me.rounds_sum, me.tsg_slice, me.theta)
    } else {
        0
    };
    let base = own.saturating_add(iie);
    build_terms(prep, i, busy, resp, scratch);
    run_fixed_point(me.deadline, base, &scratch.terms)
}

/// Response time of one task (compatibility entry point: builds a
/// throwaway kernel — use [`response_time_prepared`] in loops).
pub fn response_time(ts: &TaskSet, i: usize, busy: bool, resp: &[Option<Time>]) -> Rta {
    let prep = Prepared::new(ts);
    let mut scratch = Scratch::default();
    response_time_prepared(&prep, i, busy, resp, &mut scratch)
}

/// Analyse all RT tasks over an existing kernel.
pub fn analyze_prepared(ts: &TaskSet, prep: &Prepared, busy: bool) -> AnalysisResult {
    let mut scratch = Scratch::default();
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for &i in &prep.order {
        let r = response_time_prepared(prep, i, busy, &resp, &mut scratch);
        resp[i] = r.time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// Analyse all RT tasks (decreasing CPU priority so jitters resolve).
pub fn analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let prep = Prepared::new(ts);
    analyze_prepared(ts, &prep, busy)
}

/// [`Analysis`] implementation: the default driver's time-sliced
/// round-robin TSG scheduling.
#[derive(Debug, Clone, Copy)]
pub struct TsgRrAnalysis {
    pub busy: bool,
}

impl Analysis for TsgRrAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "tsg_rr_busy" } else { "tsg_rr_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, WaitMode};

    fn platform() -> Platform {
        Platform::single(2, 1024, 200, 1000)
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn single_task_no_interference() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts, false);
        // alone on the GPU: R = C + G + own switch-in θ per round
        // (5 rounds of the 1024 µs slice for G^e = 5 ms)
        assert_eq!(res.response[0], Some(ms(8.0) + 5 * 200));
        assert!(res.schedulable);
    }

    #[test]
    fn two_gpu_tasks_interleave() {
        let ts = TaskSet::new(
            vec![
                gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0),
                gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0),
            ],
            platform(),
        );
        let res = analyze(&ts, false);
        // Each sees ν = 1; G^e = 5 ms = 5 slices of 1024 µs (ceil = 5);
        // I_ie = (1024+200)*1*5 + 200*5 (own switch-in) = 7120 µs.
        let expect = ms(8.0) + 7120;
        assert_eq!(res.response[0], Some(expect));
        assert_eq!(res.response[1], Some(expect));
    }

    #[test]
    fn best_effort_counts_toward_interleaving() {
        let mut be = gpu_task(1, 1, 0, 2.0, 1.0, 5.0, 100.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0), be], platform());
        let res = analyze(&ts, false);
        assert_eq!(res.response[0], Some(ms(8.0) + 7120));
        // BE task itself is not analysed.
        assert_eq!(res.response[1], None);
        assert!(res.schedulable);
    }

    #[test]
    fn suspend_mode_no_indirect_delay() {
        // CPU-only task with same-core GPU-using hp task: in suspend mode
        // only C_h + G^m_h preempts.
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 50.0, 100.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = analyze(&ts, false);
        // R_1 = 10 + ceil((R + J)/100) * 3 with one carry-in job: 13 or 16.
        let r1 = res.response[1].unwrap();
        assert!(r1 >= ms(13.0) && r1 <= ms(16.0), "r1 = {r1}");
    }

    #[test]
    fn busy_mode_adds_indirect_delay() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 50.0, 200.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(200.0));
        let mut ts = TaskSet::new(vec![hp, lp], platform());
        ts.tasks[0].mode = WaitMode::BusyWait;
        ts.tasks[1].mode = WaitMode::BusyWait;
        let busy = analyze(&ts, true);
        let susp = analyze(&ts, false);
        // Busy-waiting on a 50 ms kernel (interleaved with ν = 1, i.e.
        // its own slices) must delay the CPU-only task far more.
        let rb = busy.response[1].unwrap();
        let rs = susp.response[1].unwrap();
        assert!(rb > rs + ms(40.0), "busy {rb} vs suspend {rs}");
    }

    #[test]
    fn overload_unschedulable() {
        let ts = TaskSet::new(
            vec![
                gpu_task(0, 0, 2, 2.0, 1.0, 90.0, 100.0),
                gpu_task(1, 1, 1, 2.0, 1.0, 90.0, 100.0),
            ],
            platform(),
        );
        let res = analyze(&ts, false);
        // 90 ms kernels interleaving → > 100 ms response for someone.
        assert!(!res.schedulable);
    }

    #[test]
    fn cross_engine_tasks_do_not_interleave() {
        // Two GPU tasks on different cores and different engines each
        // see ν = 0 — the same bound as running alone.
        let a = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut b = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        b.gpu = 1;
        let ts = TaskSet::new(vec![a, b], platform().with_num_gpus(2));
        let res = analyze(&ts, false);
        // Alone-on-engine bound: C + G + own switch-in θ per round.
        let lone = ms(8.0) + 5 * 200;
        assert_eq!(res.response[0], Some(lone));
        assert_eq!(res.response[1], Some(lone));
    }

    #[test]
    fn theta_increases_interference() {
        let mk = |theta| {
            let p = platform().with_theta(theta);
            TaskSet::new(
                vec![
                    gpu_task(0, 0, 2, 2.0, 1.0, 10.0, 100.0),
                    gpu_task(1, 1, 1, 2.0, 1.0, 10.0, 100.0),
                ],
                p,
            )
        };
        let lo = analyze(&mk(100), false).response[0].unwrap();
        let hi = analyze(&mk(500), false).response[0].unwrap();
        assert!(hi > lo);
    }
}
