//! Precomputed per-taskset interference kernel — the shared hot path of
//! every response-time analysis family.
//!
//! A Fig. 8-style evaluation runs ~1000 tasksets × 9 approaches per
//! sweep point, and each analysis re-enters its fixed-point closure
//! dozens of times per task. Before this module, every one of those
//! entries re-derived the interference sets (`hpp`, cross-core hp,
//! same-engine sharers) through boxed `filter` iterator chains and
//! recomputed the starred-demand constants per element — the dominant
//! cost of the whole sweep.
//!
//! [`Prepared`] is built **once per taskset** and holds:
//!
//! - flat, contiguous index arrays for every partition the analyses
//!   need ([`Slices`]): same-core higher-priority tasks (`hpp`),
//!   cross-core RT GPU-using tasks (`cross_gpu`, priority-filtered at
//!   term-build time so Audsley's mutating π^g search can reuse one
//!   `Prepared`), and same-engine GPU sharers (`sharing`);
//! - pre-starred per-task constants ([`PrepTask`]): `G^e*`, `G^m*`,
//!   `C + G^m`, per-engine ε/α/θ/L, cached `Σ_j ceil(G^e_j / L)` round
//!   counts for Eq. (3), gcs bounds for the lock-based baselines;
//! - per-engine GPU-user counts (the ν bases of Lemmas 1/4) and the
//!   decreasing-CPU-priority analysis order.
//!
//! Each family then lowers its lemma sums, once per analysed task, into
//! a flat [`Term`] list inside a reusable [`Scratch`] buffer; the
//! fixed-point closure is a single branch-light pass over that slice
//! ([`eval`]) with **zero allocation and zero set derivation** per
//! iteration.
//!
//! The kernel is also **incrementally maintainable** for the admission
//! server (`gcaps serve`): [`Prepared::admit_task`] /
//! [`Prepared::remove_task`] delta-update the partitions when one task
//! joins (at the new maximum index) or leaves (with the taskset's own
//! id reindexing), and [`Prepared::update_task`] re-stars one task's
//! constants for demand-only headroom probes. All three are pinned
//! bit-equal to a cold [`Prepared::new`] rebuild — membership
//! predicates read only structural fields of the two tasks they
//! relate, so no other pair's membership can change.
//!
//! The original iterator-chain implementations are retained verbatim in
//! [`crate::analysis::reference`] as the executable specification;
//! `rust/tests/kernel_equivalence.rs` pins bit-identical results across
//! both paths over hundreds of random tasksets, and bit-identical
//! incremental-vs-cold results across hundreds of admit/remove
//! sequences.

use crate::analysis::terms::{ceil_div, eps_of, fixed_point, ge_star, gm_star, Rta};
use crate::model::{TaskSet, Time};

/// One R-dependent interference term of a fixed-point iteration:
/// `ceil((R + jitter) / period) · demand`. Terms with `jitter = 0`
/// reduce exactly to the jitter-free job count `ceil(R / period)`, so
/// one shape covers every lemma in §6.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Term {
    pub jitter: Time,
    pub period: Time,
    pub demand: Time,
}

/// Evaluate `Σ ceil((r + jitter)/period) · demand` over a term slice —
/// the innermost loop of every analysis. Saturating so a pathological
/// demand pins to `Time::MAX` (failing the deadline check, the sound
/// direction) instead of wrapping.
#[inline]
pub fn eval(r: Time, terms: &[Term]) -> Time {
    let mut total: Time = 0;
    for t in terms {
        let n = ceil_div(r.saturating_add(t.jitter), t.period);
        total = total.saturating_add(n.saturating_mul(t.demand));
    }
    total
}

/// Run the Eq. 1 fixed point over a lowered term slice:
/// `R ← base + Σ ceil((R + J)/T)·demand` from `base` — the one shape
/// every family's response-time test reduces to. `saturating_add` so a
/// saturated [`eval`] pins the iterate at `Time::MAX` (failing the
/// deadline check, the sound direction) instead of wrapping back into
/// range; defined once here so the invariant has a single home.
pub fn run_fixed_point(deadline: Time, base: Time, terms: &[Term]) -> Rta {
    fixed_point(deadline, base, |r| base.saturating_add(eval(r, terms)))
}

/// [`run_fixed_point`] warm-started from `hint` — the admission
/// server's fast path. Sound and **bit-equal to the cold start** when
/// `hint` is a previous least fixed point of a pointwise-smaller
/// iteration map `F_old ≤ F` (e.g. the task's response time in the
/// currently-admitted set, before one more task joins): then
/// `hint = F_old(hint) ≤ F(hint)` keeps the Kleene iteration
/// non-decreasing, and `hint ≤ lfp(F)` (the Kleene chains of `F_old`
/// and `F` dominate termwise) pins the limit to the same least fixed
/// point. After a *removal* the map shrinks and an old response may
/// overshoot the new least fixed point — callers must cold-start then
/// (pass `None`).
pub fn run_fixed_point_warm(
    deadline: Time,
    base: Time,
    hint: Option<Time>,
    terms: &[Term],
) -> Rta {
    let init = base.max(hint.unwrap_or(0));
    fixed_point(deadline, init, |r| base.saturating_add(eval(r, terms)))
}

/// Flat index arrays: one contiguous `u32` pool plus per-task ranges.
/// `get(i)` is the partition of task `i` as a plain slice — no
/// per-iteration filtering, no boxed iterators.
#[derive(Debug, Clone, Default)]
pub struct Slices {
    idx: Vec<u32>,
    ranges: Vec<(u32, u32)>,
}

impl Slices {
    /// Build per-task partitions: `member(i, j)` says whether task `j`
    /// belongs to task `i`'s partition. Indices are stored in ascending
    /// task order, matching the order the reference iterator chains
    /// visit them.
    fn build(n: usize, member: impl Fn(usize, usize) -> bool) -> Slices {
        let mut idx = Vec::new();
        let mut ranges = Vec::with_capacity(n);
        for i in 0..n {
            let start = idx.len() as u32;
            for j in 0..n {
                if member(i, j) {
                    idx.push(j as u32);
                }
            }
            ranges.push((start, idx.len() as u32));
        }
        Slices { idx, ranges }
    }

    /// Delta counterpart of [`Slices::build`] for a task joining at the
    /// new maximum index `n`: splice `n` into every existing row where
    /// `member(i, n)` holds (it lands at each row's end, indices being
    /// ascending and `n` the maximum), then append row `n` itself. One
    /// O(pool + n) pass — equivalent to `build(n + 1, member)` because
    /// `member` only reads structural task fields, which an admission
    /// never changes for pre-existing tasks.
    fn admit(&mut self, n: usize, member: impl Fn(usize, usize) -> bool) {
        let mut idx = Vec::with_capacity(self.idx.len() + 2 * n + 2);
        let mut ranges = Vec::with_capacity(n + 1);
        for i in 0..n {
            let start = idx.len() as u32;
            idx.extend_from_slice(self.get(i));
            if member(i, n) {
                idx.push(n as u32);
            }
            ranges.push((start, idx.len() as u32));
        }
        let start = idx.len() as u32;
        for j in 0..=n {
            if member(n, j) {
                idx.push(j as u32);
            }
        }
        ranges.push((start, idx.len() as u32));
        self.idx = idx;
        self.ranges = ranges;
    }

    /// Delta counterpart of [`Slices::build`] for the task at index `k`
    /// leaving: drop row `k`, remove `k` from every other row, and
    /// shift indices above `k` down by one — mirroring the taskset's
    /// own reindexing (ids must equal indices). Equivalent to a full
    /// rebuild because membership between two surviving tasks does not
    /// depend on the removed one.
    fn remove(&mut self, k: usize) {
        let n = self.ranges.len();
        let mut idx = Vec::with_capacity(self.idx.len());
        let mut ranges = Vec::with_capacity(n - 1);
        for i in (0..n).filter(|&i| i != k) {
            let start = idx.len() as u32;
            for &j32 in self.get(i) {
                let j = j32 as usize;
                if j != k {
                    idx.push(if j > k { (j - 1) as u32 } else { j32 });
                }
            }
            ranges.push((start, idx.len() as u32));
        }
        self.idx = idx;
        self.ranges = ranges;
    }

    #[inline]
    pub fn get(&self, i: usize) -> &[u32] {
        let (a, b) = self.ranges[i];
        &self.idx[a as usize..b as usize]
    }
}

/// hpp membership: same-core higher-CPU-priority RT task. The three
/// membership predicates read only *structural* task fields (core,
/// priorities, engine, best-effort, GPU use) — the property the delta
/// updates ([`Prepared::admit_task`], [`Prepared::remove_task`]) rely
/// on: admitting or removing one task never changes membership between
/// two others.
#[inline]
fn member_hpp(t: &[PrepTask], i: usize, j: usize) -> bool {
    i != j && !t[j].best_effort && t[j].core == t[i].core && t[j].cpu_prio > t[i].cpu_prio
}

/// cross_gpu membership: cross-core RT GPU-using task (priority
/// filtering happens at term-build time, see [`Prepared::cross_gpu`]).
#[inline]
fn member_cross_gpu(t: &[PrepTask], i: usize, j: usize) -> bool {
    i != j && !t[j].best_effort && t[j].core != t[i].core && t[j].uses_gpu
}

/// sharing membership: same-engine GPU-using task (RT + best-effort).
#[inline]
fn member_sharing(t: &[PrepTask], i: usize, j: usize) -> bool {
    i != j && t[j].uses_gpu && t[j].gpu == t[i].gpu
}

/// Pre-starred constants of one task (everything R- and
/// assignment-independent that the lemma sums need).
#[derive(Debug, Clone, Copy)]
pub struct PrepTask {
    pub c: Time,
    pub gm: Time,
    pub ge: Time,
    pub g: Time,
    /// C + G^m (Lemma 5/7/12 demand).
    pub c_gm: Time,
    /// ε of the task's engine.
    pub eps: Time,
    /// α = ε − θ of the task's engine.
    pub alpha: Time,
    /// θ of the task's engine.
    pub theta: Time,
    /// L (TSG slice) of the task's engine.
    pub tsg_slice: Time,
    /// G^e* = G^e + 2ε·η^g.
    pub ge_star: Time,
    /// G^m* = G^m + 2ε·η^g.
    pub gm_star: Time,
    pub eta_g: Time,
    /// max_j par_j: the task's largest per-segment SM fraction in
    /// percent (100 for serial / CPU-only tasks). The fine-grain charge
    /// (gcaps `Options::fine_grain`) treats a job as needing this much
    /// engine capacity whenever any of its segments is resident — the
    /// per-job worst case, so one constant covers all segments.
    pub fmax: Time,
    pub period: Time,
    pub deadline: Time,
    pub uses_gpu: bool,
    pub best_effort: bool,
    pub core: usize,
    pub gpu: usize,
    pub cpu_prio: u32,
    /// Σ_j ceil(G^e_{i,j} / L): Eq. (3) round count over the whole job
    /// (zero-length segments contribute zero rounds, exactly as
    /// `interleave` returns 0 for them).
    pub rounds_sum: Time,
    /// max_j (G^m + G^e)_{i,j}: the longest single gcs (lock bounds).
    pub max_gcs: Time,
    /// Σ_j (G^m + G^e)_{i,j}: total gcs demand (MPCP hp term).
    pub gcs_total: Time,
}

/// The per-taskset kernel. Build once with [`Prepared::new`]; valid for
/// any sequence of analyses over the same taskset structure. GPU
/// priorities (π^g) are deliberately **not** cached — the gcaps §6.4
/// path reads them live from the `TaskSet`, so Audsley's search can
/// mutate `gpu_prio` between candidate tests and keep reusing one
/// `Prepared` (cores, CPU priorities, engines and segments never change
/// during the search).
#[derive(Debug, Clone)]
pub struct Prepared {
    pub t: Vec<PrepTask>,
    /// hpp(τ_i): same-core higher-CPU-priority RT tasks.
    pub hpp: Slices,
    /// Cross-core RT GPU-using tasks, *unfiltered by priority*: the
    /// caller compares π^c (default) or live π^g (§6.4) per element at
    /// term-build time — once per analysed task, not per iteration.
    pub cross_gpu: Slices,
    /// Same-engine GPU-using tasks excluding τ_i (RT + best-effort):
    /// the lock-queue / interleaving sharer set.
    pub sharing: Slices,
    /// Per-engine GPU-using task count (RT + best-effort) — the ν
    /// bases of Lemmas 1/4.
    pub gpu_users: Vec<usize>,
    /// RT task ids in decreasing CPU priority: the order every family
    /// analyses tasks in (so higher-priority response times are
    /// available for jitter terms).
    pub order: Vec<usize>,
}

/// Derive one task's pre-starred constants (shared by [`Prepared::new`]
/// and the delta updates, so both paths star identically).
fn prep_task(ts: &TaskSet, task: &crate::model::Task) -> PrepTask {
    let ctx = ts.platform.gpus[task.gpu];
    let eps = eps_of(ts, task);
    PrepTask {
        c: task.c(),
        gm: task.gm(),
        ge: task.ge(),
        g: task.g(),
        c_gm: task.c().saturating_add(task.gm()),
        eps,
        alpha: ctx.epsilon.saturating_sub(ctx.theta),
        theta: ctx.theta,
        tsg_slice: ctx.tsg_slice,
        ge_star: ge_star(task, eps),
        gm_star: gm_star(task, eps),
        eta_g: task.eta_g() as Time,
        fmax: task.fmax_pct() as Time,
        period: task.period,
        deadline: task.deadline,
        uses_gpu: task.uses_gpu(),
        best_effort: task.best_effort,
        core: task.core,
        gpu: task.gpu,
        cpu_prio: task.cpu_prio,
        rounds_sum: task
            .gpu_segments
            .iter()
            .map(|g| ceil_div(g.exec, ctx.tsg_slice))
            .sum(),
        max_gcs: task.max_gpu_segment(),
        gcs_total: task.gpu_segments.iter().map(|g| g.total()).sum(),
    }
}

impl Prepared {
    pub fn new(ts: &TaskSet) -> Prepared {
        let n = ts.tasks.len();
        let t: Vec<PrepTask> = ts.tasks.iter().map(|task| prep_task(ts, task)).collect();

        let hpp = Slices::build(n, |i, j| member_hpp(&t, i, j));
        let cross_gpu = Slices::build(n, |i, j| member_cross_gpu(&t, i, j));
        let sharing = Slices::build(n, |i, j| member_sharing(&t, i, j));

        let mut gpu_users = vec![0usize; ts.platform.num_gpus()];
        for p in t.iter().filter(|p| p.uses_gpu) {
            gpu_users[p.gpu] += 1;
        }

        let mut order: Vec<usize> = (0..n).filter(|&i| !t[i].best_effort).collect();
        order.sort_by(|&a, &b| t[b].cpu_prio.cmp(&t[a].cpu_prio));

        Prepared { t, hpp, cross_gpu, sharing, gpu_users, order }
    }

    /// Delta-update the kernel for a task that joined `ts` at the new
    /// maximum index `n = old len` (the admission server's reindexing
    /// convention: ids equal indices, a joiner goes last). Equivalent
    /// to `Prepared::new(ts)` — pinned by the in-module tests and the
    /// `kernel_equivalence` property sweep — because the membership
    /// predicates only read structural fields of the two tasks they
    /// relate, so pre-existing pairs are unaffected; only the new
    /// task's row and its column entries are computed, in O(pool + n)
    /// instead of O(n²) predicate evaluations.
    pub fn admit_task(&mut self, ts: &TaskSet) {
        let n = self.t.len();
        debug_assert_eq!(ts.tasks.len(), n + 1, "admit_task: ts must have one new last task");
        self.t.push(prep_task(ts, &ts.tasks[n]));
        let Prepared { t, hpp, cross_gpu, sharing, gpu_users, order } = self;
        hpp.admit(n, |i, j| member_hpp(t, i, j));
        cross_gpu.admit(n, |i, j| member_cross_gpu(t, i, j));
        sharing.admit(n, |i, j| member_sharing(t, i, j));
        let new = &t[n];
        if new.uses_gpu {
            if gpu_users.len() <= new.gpu {
                gpu_users.resize(ts.platform.num_gpus(), 0);
            }
            gpu_users[new.gpu] += 1;
        }
        if !new.best_effort {
            // RT CPU priorities are unique (TaskSet::validate), so this
            // insertion position reproduces the full sort exactly.
            let pos = order
                .iter()
                .position(|&h| t[h].cpu_prio < new.cpu_prio)
                .unwrap_or(order.len());
            order.insert(pos, n);
        }
    }

    /// Delta-update the kernel for the task at index `k` leaving. The
    /// caller removes the task from its `TaskSet` and shifts the ids of
    /// later tasks down by one; this mirrors that reindexing across
    /// every partition. Equivalent to a cold `Prepared::new` on the
    /// shrunken set because membership between two surviving tasks
    /// never depends on the departed one.
    pub fn remove_task(&mut self, k: usize) {
        let gone = self.t.remove(k);
        self.hpp.remove(k);
        self.cross_gpu.remove(k);
        self.sharing.remove(k);
        if gone.uses_gpu {
            self.gpu_users[gone.gpu] -= 1;
        }
        self.order.retain(|&h| h != k);
        for h in &mut self.order {
            if *h > k {
                *h -= 1;
            }
        }
    }

    /// Recompute task `i`'s pre-starred constants after a *demand-only*
    /// mutation (segment WCETs, period, deadline) — the headroom
    /// probe's workhorse. Structural fields (core, priorities, engine,
    /// best-effort, GPU use) must be unchanged: they decide partition
    /// membership, which this deliberately does not touch (asserted in
    /// debug builds).
    pub fn update_task(&mut self, ts: &TaskSet, i: usize) {
        let new = prep_task(ts, &ts.tasks[i]);
        let old = &self.t[i];
        debug_assert!(
            old.core == new.core
                && old.gpu == new.gpu
                && old.cpu_prio == new.cpu_prio
                && old.best_effort == new.best_effort
                && old.uses_gpu == new.uses_gpu,
            "update_task: structural fields changed — use remove_task + admit_task"
        );
        self.t[i] = new;
    }

    /// ν of Lemma 1 for task `i`: GPU-using sharers of its engine.
    #[inline]
    pub fn nu(&self, i: usize) -> usize {
        self.gpu_users[self.t[i].gpu] - usize::from(self.t[i].uses_gpu)
    }

    /// J^g_h = R_h − G^e_h with an explicit response (None ⇒ the D_h
    /// fallback of §6.4) — the one shared definition of the Lemma 10
    /// jitter; every family goes through here.
    #[inline]
    pub fn jitter_g_of(&self, h: usize, r_h: Option<Time>) -> Time {
        let p = &self.t[h];
        r_h.unwrap_or(p.deadline).saturating_sub(p.ge)
    }

    /// J^g_h with the response table (the non-§6.4 path).
    #[inline]
    pub fn jitter_g(&self, h: usize, resp: &[Option<Time>]) -> Time {
        self.jitter_g_of(h, resp[h])
    }

    /// J^c_h = R_h − (C_h + G^m_h) with an explicit response (None ⇒
    /// D_h fallback) — the shared Lemma 7 jitter.
    #[inline]
    pub fn jitter_c_of(&self, h: usize, r_h: Option<Time>) -> Time {
        let p = &self.t[h];
        r_h.unwrap_or(p.deadline).saturating_sub(p.c_gm)
    }

    /// J^c_h with the response table.
    #[inline]
    pub fn jitter_c(&self, h: usize, resp: &[Option<Time>]) -> Time {
        self.jitter_c_of(h, resp[h])
    }
}

/// Reusable buffers: one allocation per analysis run, cleared per
/// analysed task. `engines` is a generic per-engine counter used by the
/// Lemma 4 ν bases.
#[derive(Debug, Clone, Default)]
pub struct Scratch {
    pub terms: Vec<Term>,
    pub engines: Vec<usize>,
}

impl Scratch {
    #[inline]
    pub fn clear(&mut self) {
        self.terms.clear();
    }

    #[inline]
    pub fn push(&mut self, jitter: Time, period: Time, demand: Time) {
        if demand > 0 {
            self.terms.push(Term { jitter, period, demand });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, WaitMode};

    fn task(id: usize, core: usize, gpu: usize, prio: u32, gpu_segs: usize) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(100.0),
            deadline: ms(100.0),
            cpu_segments: vec![ms(1.0); gpu_segs + 1],
            gpu_segments: (0..gpu_segs)
                .map(|_| GpuSegment::new(ms(1.0), ms(5.0)))
                .collect(),
            core,
            gpu,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    fn set() -> TaskSet {
        let p = Platform::single(2, 1024, 200, 1000).with_num_gpus(2);
        TaskSet::new(
            vec![
                task(0, 0, 0, 30, 1),
                task(1, 0, 1, 20, 2),
                task(2, 1, 0, 10, 0),
                task(3, 1, 1, 5, 1),
            ],
            p,
        )
    }

    #[test]
    fn partitions_match_taskset_iterators() {
        let ts = set();
        let prep = Prepared::new(&ts);
        for i in 0..ts.len() {
            let want: Vec<u32> = ts.hpp(i).map(|t| t.id as u32).collect();
            assert_eq!(prep.hpp.get(i), &want[..], "hpp({i})");
            let want: Vec<u32> = ts
                .tasks
                .iter()
                .filter(|t| {
                    !t.best_effort
                        && t.id != i
                        && t.core != ts.tasks[i].core
                        && t.uses_gpu()
                })
                .map(|t| t.id as u32)
                .collect();
            assert_eq!(prep.cross_gpu.get(i), &want[..], "cross_gpu({i})");
            let want: Vec<u32> = ts.sharing_gpu(i).map(|t| t.id as u32).collect();
            assert_eq!(prep.sharing.get(i), &want[..], "sharing({i})");
        }
    }

    #[test]
    fn constants_match_model_accessors() {
        let ts = set();
        let prep = Prepared::new(&ts);
        for (i, task) in ts.tasks.iter().enumerate() {
            let p = &prep.t[i];
            assert_eq!(p.c, task.c());
            assert_eq!(p.g, task.g());
            assert_eq!(p.c_gm, task.c() + task.gm());
            assert_eq!(p.eps, crate::analysis::terms::eps_of(&ts, task));
            assert_eq!(p.ge_star, crate::analysis::terms::ge_star(task, p.eps));
            assert_eq!(p.gm_star, crate::analysis::terms::gm_star(task, p.eps));
            assert_eq!(p.max_gcs, task.max_gpu_segment());
        }
        assert_eq!(prep.gpu_users, vec![1, 2]);
        assert_eq!(prep.nu(0), 0); // alone on engine 0 among GPU users
        assert_eq!(prep.nu(1), 1); // shares engine 1 with task 3
        assert_eq!(prep.nu(2), 1); // CPU-only: all of engine 0's users
        assert_eq!(prep.order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn eval_matches_manual_sum() {
        let terms = [
            Term { jitter: 0, period: 100, demand: 7 },
            Term { jitter: 30, period: 40, demand: 5 },
        ];
        // r = 250: ceil(250/100)·7 + ceil(280/40)·5 = 21 + 35.
        assert_eq!(eval(250, &terms), 21 + 35);
        // r = 0: ceil(0/100)·7 + ceil(30/40)·5 = 0 + 5.
        assert_eq!(eval(0, &terms), 5);
    }

    #[test]
    fn scratch_drops_zero_demand_terms() {
        let mut s = Scratch::default();
        s.push(0, 100, 0);
        s.push(0, 100, 3);
        assert_eq!(s.terms.len(), 1);
    }

    /// Structural equality of two kernels, partition by partition.
    fn assert_prep_eq(inc: &Prepared, cold: &Prepared, ctx: &str) {
        assert_eq!(inc.t.len(), cold.t.len(), "{ctx}: task count");
        for i in 0..cold.t.len() {
            assert_eq!(inc.hpp.get(i), cold.hpp.get(i), "{ctx}: hpp({i})");
            assert_eq!(inc.cross_gpu.get(i), cold.cross_gpu.get(i), "{ctx}: cross_gpu({i})");
            assert_eq!(inc.sharing.get(i), cold.sharing.get(i), "{ctx}: sharing({i})");
            let (a, b) = (&inc.t[i], &cold.t[i]);
            assert_eq!(
                (a.c, a.gm, a.ge, a.ge_star, a.gm_star, a.rounds_sum, a.max_gcs, a.gcs_total),
                (b.c, b.gm, b.ge, b.ge_star, b.gm_star, b.rounds_sum, b.max_gcs, b.gcs_total),
                "{ctx}: constants({i})"
            );
            assert_eq!(a.fmax, b.fmax, "{ctx}: fmax({i})");
            assert_eq!(
                (a.core, a.gpu, a.cpu_prio, a.best_effort, a.uses_gpu, a.period, a.deadline),
                (b.core, b.gpu, b.cpu_prio, b.best_effort, b.uses_gpu, b.period, b.deadline),
                "{ctx}: structure({i})"
            );
        }
        assert_eq!(inc.gpu_users, cold.gpu_users, "{ctx}: gpu_users");
        assert_eq!(inc.order, cold.order, "{ctx}: order");
    }

    /// Reassign ids to match indices (the serve/test admission
    /// convention after splicing tasks in or out).
    fn reindexed(mut tasks: Vec<Task>, p: &Platform) -> TaskSet {
        for (idx, t) in tasks.iter_mut().enumerate() {
            t.id = idx;
        }
        TaskSet::new(tasks, p.clone())
    }

    #[test]
    fn admit_task_matches_cold_rebuild() {
        let full = set();
        let p = full.platform.clone();
        // Grow task by task from empty; after each admission the
        // delta-updated kernel must equal a cold rebuild.
        let mut tasks: Vec<Task> = Vec::new();
        let mut prep = Prepared::new(&TaskSet::new(vec![], p.clone()));
        for add in 0..full.len() {
            tasks.push(full.tasks[add].clone());
            let ts = reindexed(tasks.clone(), &p);
            prep.admit_task(&ts);
            assert_prep_eq(&prep, &Prepared::new(&ts), &format!("after admit {add}"));
        }
    }

    #[test]
    fn remove_task_matches_cold_rebuild() {
        let full = set();
        let p = full.platform.clone();
        // Remove from every position of the 4-task set, including a
        // middle index (exercises the > k index shift).
        for k in 0..full.len() {
            let mut prep = Prepared::new(&full);
            prep.remove_task(k);
            let mut tasks = full.tasks.clone();
            tasks.remove(k);
            let ts = reindexed(tasks, &p);
            assert_prep_eq(&prep, &Prepared::new(&ts), &format!("after remove {k}"));
        }
    }

    #[test]
    fn admit_remove_roundtrip_restores_kernel() {
        let full = set();
        let mut prep = Prepared::new(&full);
        // Admit a new last task, then remove it: back to the original.
        let mut tasks = full.tasks.clone();
        tasks.push(task(4, 1, 0, 40, 1));
        let grown = TaskSet::new(tasks, full.platform.clone());
        prep.admit_task(&grown);
        assert_prep_eq(&prep, &Prepared::new(&grown), "grown");
        prep.remove_task(4);
        assert_prep_eq(&prep, &Prepared::new(&full), "restored");
    }

    #[test]
    fn update_task_restars_constants() {
        let full = set();
        let mut prep = Prepared::new(&full);
        let mut ts = full.clone();
        ts.tasks[1].cpu_segments[0] += ms(3.0);
        ts.tasks[1].gpu_segments[0].exec += ms(2.0);
        prep.update_task(&ts, 1);
        assert_prep_eq(&prep, &Prepared::new(&ts), "after update");
        // Restoring the task restores the kernel (probe rollback path).
        prep.update_task(&full, 1);
        assert_prep_eq(&prep, &Prepared::new(&full), "after rollback");
    }

    #[test]
    fn warm_start_from_previous_lfp_is_bit_equal() {
        // F grows (extra term) between runs; warm-starting from the old
        // least fixed point must land on the new one exactly.
        let deadline = 1_000_000;
        let t1 = [Term { jitter: 0, period: 1000, demand: 70 }];
        let t2 = [
            Term { jitter: 0, period: 1000, demand: 70 },
            Term { jitter: 300, period: 700, demand: 40 },
        ];
        let cold1 = run_fixed_point(deadline, 500, &t1);
        let hint = cold1.time();
        let cold2 = run_fixed_point(deadline, 600, &t2);
        let warm2 = run_fixed_point_warm(deadline, 600, hint, &t2);
        assert_eq!(cold2, warm2);
        // A None hint degrades to the plain cold start.
        assert_eq!(run_fixed_point_warm(deadline, 600, None, &t2), cold2);
    }
}
