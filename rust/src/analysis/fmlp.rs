//! FMLP+ baseline: the GPU as a single shared resource managed by
//! Brandenburg's FMLP+ (ECRTS 2014, ref [10]) — FIFO-ordered requests
//! with priority boosting, suspension-aware analysis.
//!
//! FIFO queueing gives the classic per-request blocking bound: when τ_i
//! issues a GPU request, every *other* task can have at most one request
//! already queued ahead of it, so
//!
//! ```text
//!     W_{i,j} = Σ_{k ≠ i, η^g_k > 0} gcs_max_k
//! ```
//!
//! independent of priorities — which is exactly why FMLP+ behaves well
//! under light GPU load (Fig. 8e, low G/C) and degrades as GPU-using
//! tasks multiply or kernels lengthen. Best-effort tasks enter the same
//! FIFO queue, so they also contribute one gcs each (Fig. 8f).
//!
//! Boost blocking and CPU preemption mirror the MPCP module; the two
//! baselines differ exactly in their queueing discipline, which is the
//! comparison the paper draws.

use crate::analysis::terms::{fixed_point, jitter_c, njobs, njobs_jitter, AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// Per-request FIFO blocking: one longest gcs per other GPU-using task
/// sharing τ_i's engine (RT or best-effort) — each engine is its own
/// FIFO lock, so other engines' queues never delay τ_i.
fn request_blocking(ts: &TaskSet, i: usize) -> Time {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return 0;
    }
    ts.sharing_gpu(i).map(|t| t.max_gpu_segment()).sum()
}

/// Boost blocking: same structure as the MPCP module — every job of a
/// lower-priority (or best-effort) same-core GPU task can execute its
/// critical sections' CPU portions (G^m) at boosted priority when its
/// FIFO grant lands, charged per lower-priority job with D-jitter.
fn boost_blocking(ts: &TaskSet, i: usize, r: Time) -> Time {
    let me = &ts.tasks[i];
    ts.tasks
        .iter()
        .filter(|t| {
            t.id != me.id
                && t.core == me.core
                && t.uses_gpu()
                && (t.best_effort || t.cpu_prio < me.cpu_prio)
        })
        .map(|t| njobs_jitter(r, t.deadline, t.period) * t.gm())
        .sum()
}

/// CPU preemption from same-core higher-priority tasks (suspension-aware
/// jitter; busy-waiting inflates hp demand by its waiting + gcs time).
fn p_c(ts: &TaskSet, i: usize, r: Time, busy: bool, resp: &[Option<Time>]) -> Time {
    ts.hpp(i)
        .map(|h| {
            let n = if h.uses_gpu() {
                // Carry-in jitter, as in the MPCP module.
                njobs_jitter(r, jitter_c(h, resp[h.id]), h.period)
            } else {
                njobs(r, h.period) // CPU-only hp: exact count
            };
            if busy {
                n * (h.c() + h.g() + request_blocking(ts, h.id) * h.eta_g() as Time)
            } else {
                n * (h.c() + h.gm())
            }
        })
        .sum()
}

/// Response time of task i under FMLP+.
pub fn response_time(ts: &TaskSet, i: usize, busy: bool, resp: &[Option<Time>]) -> Rta {
    let me = &ts.tasks[i];
    let remote = request_blocking(ts, i) * me.eta_g() as Time;
    let own = me.c() + me.g() + remote;
    fixed_point(me.deadline, own, |r| {
        own + boost_blocking(ts, i, r) + p_c(ts, i, r, busy, resp)
    })
}

/// Analyse all RT tasks.
pub fn analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    let mut order: Vec<usize> =
        ts.tasks.iter().filter(|t| !t.best_effort).map(|t| t.id).collect();
    order.sort_by(|&a, &b| ts.tasks[b].cpu_prio.cmp(&ts.tasks[a].cpu_prio));
    for i in order {
        resp[i] = response_time(ts, i, busy, &resp).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// [`Analysis`] implementation: the FMLP+ synchronization baseline.
#[derive(Debug, Clone, Copy)]
pub struct FmlpAnalysis {
    pub busy: bool,
}

impl Analysis for FmlpAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "fmlp_busy" } else { "fmlp_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, WaitMode};

    fn platform() -> Platform {
        Platform { num_cpus: 2, ..Default::default() }
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn fifo_queue_is_per_engine() {
        // Spreading the two contenders over a second engine removes
        // their gcs from τ_0's FIFO bound.
        let t0 = gpu_task(0, 0, 3, 2.0, 1.0, 5.0, 200.0);
        let mut t1 = gpu_task(1, 1, 2, 2.0, 1.0, 10.0, 200.0);
        let mut t2 = gpu_task(2, 1, 1, 2.0, 1.0, 20.0, 200.0);
        t1.gpu = 1;
        t2.gpu = 1;
        let p = Platform { num_cpus: 2, ..Default::default() }.with_num_gpus(2);
        let ts = TaskSet::new(vec![t0, t1, t2], p);
        let res = analyze(&ts, false);
        // τ_0 queues alone on engine 0: remote blocking = 0.
        assert_eq!(res.response[0], Some(ms(8.0)));
        // τ_2 still waits for τ_1's gcs on engine 1 (11 ms) and absorbs
        // one same-core preemption of C_1 + G^m_1 = 3 ms.
        assert_eq!(res.response[2], Some(ms(23.0 + 11.0 + 3.0)));
    }

    #[test]
    fn single_task_no_blocking() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        assert_eq!(analyze(&ts, false).response[0], Some(ms(8.0)));
    }

    #[test]
    fn fifo_blocking_one_gcs_per_other_task() {
        let t0 = gpu_task(0, 0, 3, 2.0, 1.0, 5.0, 200.0);
        let t1 = gpu_task(1, 1, 2, 2.0, 1.0, 10.0, 200.0);
        let t2 = gpu_task(2, 1, 1, 2.0, 1.0, 20.0, 200.0);
        let ts = TaskSet::new(vec![t0, t1, t2], platform());
        let res = analyze(&ts, false);
        // τ_0: remote = (11 + 21) per request, one request.
        assert_eq!(res.response[0], Some(ms(8.0 + 32.0)));
    }

    #[test]
    fn fifo_independent_of_priority() {
        // Unlike MPCP, the lowest-priority task's remote blocking is the
        // same single-gcs-per-other-task sum.
        let t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 400.0);
        let t1 = gpu_task(1, 1, 1, 2.0, 1.0, 10.0, 400.0);
        let ts = TaskSet::new(vec![t0, t1], platform());
        let res = analyze(&ts, false);
        // R_1 = C_1 + G_1 + gcs_max_0 = 2 + 11 + 6 = 19 ms.
        assert_eq!(res.response[1], Some(ms(19.0)));
    }

    #[test]
    fn best_effort_joins_fifo() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 300.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let r0 = analyze(&ts, false).response[0].unwrap();
        assert!(r0 >= ms(8.0 + 82.0), "r0 = {r0}");
    }

    #[test]
    fn busy_mode_worse_or_equal() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 150.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(150.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rs = analyze(&ts, false).response[1].unwrap();
        match analyze(&ts, true).response[1] {
            Some(rb) => assert!(rb >= rs),
            None => {}
        }
    }

    #[test]
    fn more_gpu_tasks_more_blocking() {
        let mut tasks = vec![gpu_task(0, 0, 9, 2.0, 1.0, 5.0, 400.0)];
        let mut prev = None;
        for n in 1..5usize {
            tasks.push(gpu_task(n, 1, (9 - n) as u32, 2.0, 1.0, 10.0, 400.0));
            let ts = TaskSet::new(tasks.clone(), platform());
            let r0 = analyze(&ts, false).response[0].unwrap();
            if let Some(p) = prev {
                assert!(r0 > p, "blocking must grow with GPU task count");
            }
            prev = Some(r0);
        }
    }
}
