//! FMLP+ baseline: the GPU as a single shared resource managed by
//! Brandenburg's FMLP+ (ECRTS 2014, ref [10]) — FIFO-ordered requests
//! with priority boosting, suspension-aware analysis.
//!
//! FIFO queueing gives the classic per-request blocking bound: when τ_i
//! issues a GPU request, every *other* task can have at most one request
//! already queued ahead of it, so
//!
//! ```text
//!     W_{i,j} = Σ_{k ≠ i, η^g_k > 0} gcs_max_k
//! ```
//!
//! independent of priorities — which is exactly why FMLP+ behaves well
//! under light GPU load (Fig. 8e, low G/C) and degrades as GPU-using
//! tasks multiply or kernels lengthen. Best-effort tasks enter the same
//! FIFO queue, so they also contribute one gcs each (Fig. 8f).
//!
//! Boost blocking and CPU preemption mirror the MPCP module; the two
//! baselines differ exactly in their queueing discipline, which is the
//! comparison the paper draws.
//!
//! Implementation: the per-task FIFO bound Σ gcs_max over the
//! same-engine sharers is computed **once per analysis run** from
//! [`Prepared`]'s sharer slices (the naive path re-derived it per
//! fixed-point iteration for every busy hp term); the fixed point runs
//! over a flat `Term` slice. The original iterator-chain path lives in
//! [`crate::analysis::reference`].

use crate::analysis::prep::{run_fixed_point, Prepared, Scratch};
use crate::analysis::terms::{AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// Per-request FIFO blocking: one longest gcs per other GPU-using task
/// sharing τ_i's engine (RT or best-effort) — each engine is its own
/// FIFO lock, so other engines' queues never delay τ_i.
fn request_blocking(prep: &Prepared, i: usize) -> Time {
    if !prep.t[i].uses_gpu {
        return 0;
    }
    prep.sharing.get(i).iter().map(|&h| prep.t[h as usize].max_gcs).sum()
}

/// Lower boost blocking + CPU preemption for task `i` into
/// `scratch.terms` (same structure as the MPCP module; `req` carries
/// the precomputed per-task FIFO bounds).
fn build_terms(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    req: &[Time],
    scratch: &mut Scratch,
) {
    scratch.clear();
    let me = prep.t[i];
    for (j, p) in prep.t.iter().enumerate() {
        if j != i
            && p.core == me.core
            && p.uses_gpu
            && (p.best_effort || p.cpu_prio < me.cpu_prio)
        {
            scratch.push(p.deadline, p.period, p.gm);
        }
    }
    for &h32 in prep.hpp.get(i) {
        let h = h32 as usize;
        let p = &prep.t[h];
        let jit = if p.uses_gpu { prep.jitter_c(h, resp) } else { 0 };
        let demand = if busy {
            p.c.saturating_add(p.g).saturating_add(req[h].saturating_mul(p.eta_g))
        } else {
            p.c_gm
        };
        scratch.push(jit, p.period, demand);
    }
}

/// Response time of task i under FMLP+, over a prebuilt kernel. `req`
/// holds the per-task FIFO bounds (from [`request_blocking`]).
pub fn response_time_prepared(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    req: &[Time],
    scratch: &mut Scratch,
) -> Rta {
    let me = prep.t[i];
    let own = me.c.saturating_add(me.g).saturating_add(req[i].saturating_mul(me.eta_g));
    build_terms(prep, i, busy, resp, req, scratch);
    run_fixed_point(me.deadline, own, &scratch.terms)
}

/// Response time of task i under FMLP+ (compatibility entry point:
/// builds a throwaway kernel — use [`response_time_prepared`] in loops).
pub fn response_time(ts: &TaskSet, i: usize, busy: bool, resp: &[Option<Time>]) -> Rta {
    let prep = Prepared::new(ts);
    let req: Vec<Time> = (0..ts.tasks.len()).map(|j| request_blocking(&prep, j)).collect();
    let mut scratch = Scratch::default();
    response_time_prepared(&prep, i, busy, resp, &req, &mut scratch)
}

/// Analyse all RT tasks over an existing kernel.
pub fn analyze_prepared(ts: &TaskSet, prep: &Prepared, busy: bool) -> AnalysisResult {
    let req: Vec<Time> = (0..ts.tasks.len()).map(|j| request_blocking(prep, j)).collect();
    let mut scratch = Scratch::default();
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for &i in &prep.order {
        let r = response_time_prepared(prep, i, busy, &resp, &req, &mut scratch);
        resp[i] = r.time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// Analyse all RT tasks.
pub fn analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let prep = Prepared::new(ts);
    analyze_prepared(ts, &prep, busy)
}

/// [`Analysis`] implementation: the FMLP+ synchronization baseline.
#[derive(Debug, Clone, Copy)]
pub struct FmlpAnalysis {
    pub busy: bool,
}

impl Analysis for FmlpAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "fmlp_busy" } else { "fmlp_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, WaitMode};

    fn platform() -> Platform {
        Platform { num_cpus: 2, ..Default::default() }
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn fifo_queue_is_per_engine() {
        // Spreading the two contenders over a second engine removes
        // their gcs from τ_0's FIFO bound.
        let t0 = gpu_task(0, 0, 3, 2.0, 1.0, 5.0, 200.0);
        let mut t1 = gpu_task(1, 1, 2, 2.0, 1.0, 10.0, 200.0);
        let mut t2 = gpu_task(2, 1, 1, 2.0, 1.0, 20.0, 200.0);
        t1.gpu = 1;
        t2.gpu = 1;
        let p = Platform { num_cpus: 2, ..Default::default() }.with_num_gpus(2);
        let ts = TaskSet::new(vec![t0, t1, t2], p);
        let res = analyze(&ts, false);
        // τ_0 queues alone on engine 0: remote blocking = 0.
        assert_eq!(res.response[0], Some(ms(8.0)));
        // τ_2 still waits for τ_1's gcs on engine 1 (11 ms) and absorbs
        // one same-core preemption of C_1 + G^m_1 = 3 ms.
        assert_eq!(res.response[2], Some(ms(23.0 + 11.0 + 3.0)));
    }

    #[test]
    fn single_task_no_blocking() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        assert_eq!(analyze(&ts, false).response[0], Some(ms(8.0)));
    }

    #[test]
    fn fifo_blocking_one_gcs_per_other_task() {
        let t0 = gpu_task(0, 0, 3, 2.0, 1.0, 5.0, 200.0);
        let t1 = gpu_task(1, 1, 2, 2.0, 1.0, 10.0, 200.0);
        let t2 = gpu_task(2, 1, 1, 2.0, 1.0, 20.0, 200.0);
        let ts = TaskSet::new(vec![t0, t1, t2], platform());
        let res = analyze(&ts, false);
        // τ_0: remote = (11 + 21) per request, one request.
        assert_eq!(res.response[0], Some(ms(8.0 + 32.0)));
    }

    #[test]
    fn fifo_independent_of_priority() {
        // Unlike MPCP, the lowest-priority task's remote blocking is the
        // same single-gcs-per-other-task sum.
        let t0 = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 400.0);
        let t1 = gpu_task(1, 1, 1, 2.0, 1.0, 10.0, 400.0);
        let ts = TaskSet::new(vec![t0, t1], platform());
        let res = analyze(&ts, false);
        // R_1 = C_1 + G_1 + gcs_max_0 = 2 + 11 + 6 = 19 ms.
        assert_eq!(res.response[1], Some(ms(19.0)));
    }

    #[test]
    fn best_effort_joins_fifo() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 300.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let r0 = analyze(&ts, false).response[0].unwrap();
        assert!(r0 >= ms(8.0 + 82.0), "r0 = {r0}");
    }

    #[test]
    fn busy_mode_worse_or_equal() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 150.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(150.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rs = analyze(&ts, false).response[1].unwrap();
        match analyze(&ts, true).response[1] {
            Some(rb) => assert!(rb >= rs),
            None => {}
        }
    }

    #[test]
    fn more_gpu_tasks_more_blocking() {
        let mut tasks = vec![gpu_task(0, 0, 9, 2.0, 1.0, 5.0, 400.0)];
        let mut prev = None;
        for n in 1..5usize {
            tasks.push(gpu_task(n, 1, (9 - n) as u32, 2.0, 1.0, 10.0, 400.0));
            let ts = TaskSet::new(tasks.clone(), platform());
            let r0 = analyze(&ts, false).response[0].unwrap();
            if let Some(p) = prev {
                assert!(r0 > p, "blocking must grow with GPU task count");
            }
            prev = Some(r0);
        }
    }
}
