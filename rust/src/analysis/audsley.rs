//! Separate GPU-segment priority assignment via Audsley's algorithm
//! (paper §5.3, analysed per §6.4).
//!
//! When the GCAPS test fails with default priorities (π^g = π^c), we
//! search for a GPU-priority permutation: levels are handed out from the
//! lowest upward; a task may take the current lowest level if (a) doing
//! so keeps the same-core relative GPU order identical to the CPU order
//! (the paper's deadlock-avoidance constraint) and (b) the task passes
//! its response-time test assuming all still-unassigned tasks have
//! higher GPU priority. Audsley's optimality applies because a task's
//! GCAPS interference depends only on *which* tasks have higher GPU
//! priority, not on their relative order, and §6.4's D-based jitters
//! remove the dependence on higher-priority response times.
//!
//! GPU priorities are a permutation of the candidates' own CPU priority
//! values, so they stay on one scale with the (unchanged) CPU-only tasks.

use crate::analysis::gcaps;
use crate::analysis::prep::{Prepared, Scratch};
use crate::analysis::terms::AnalysisResult;
use crate::model::{TaskSet, Time};

/// Attempt the assignment. Returns the modified taskset (gpu_prio fields
/// rewritten) plus the per-task GPU priority vector, or None if no
/// feasible assignment exists. `busy` selects the analysis variant.
pub fn assign_gpu_priorities(ts: &TaskSet, busy: bool) -> Option<(TaskSet, Vec<u32>)> {
    assign_gpu_priorities_analyzed(ts, busy).map(|(work, prios, _)| (work, prios))
}

/// The assignment plus the final verifying [`AnalysisResult`] — callers
/// that need the analysis of the assigned taskset (the §7.1.1 GCAPS
/// procedure) take it from here instead of re-running the full analysis
/// on the returned taskset.
///
/// The search builds ONE [`Prepared`] kernel up front and reuses it for
/// every candidate test at every level: the kernel caches only
/// assignment-invariant structure (cores, CPU priorities, engines,
/// starred constants), while π^g — the thing the search mutates — is
/// read live from `work` by the gcaps §6.4 path. The pre-kernel code
/// re-derived every interference set per candidate, making the search
/// O(n²) set derivations per level.
pub fn assign_gpu_priorities_analyzed(
    ts: &TaskSet,
    busy: bool,
) -> Option<(TaskSet, Vec<u32>, AnalysisResult)> {
    let mut work = ts.clone();
    let candidates: Vec<usize> = work
        .tasks
        .iter()
        .filter(|t| !t.best_effort && t.uses_gpu())
        .map(|t| t.id)
        .collect();
    if candidates.is_empty() {
        return None;
    }
    // Levels: the candidates' own CPU priority values, lowest first.
    let mut levels: Vec<u32> = candidates.iter().map(|&i| ts.tasks[i].cpu_prio).collect();
    levels.sort_unstable();

    let mut unassigned: Vec<usize> = candidates.clone();
    // While searching, unassigned tasks act as "higher GPU priority".
    const UNASSIGNED: u32 = u32::MAX;
    for &i in &unassigned {
        work.tasks[i].gpu_prio = UNASSIGNED;
    }

    let opts = gcaps::Options { use_gpu_prio: true, ..Default::default() };
    let no_resp: Vec<Option<Time>> = vec![None; work.tasks.len()];
    let prep = Prepared::new(&work);
    let mut scratch = Scratch::default();

    for &level in &levels {
        // Try candidates lowest-CPU-priority first (keeps the search
        // deterministic and biases toward the RM-like order).
        let mut order = unassigned.clone();
        order.sort_by_key(|&i| work.tasks[i].cpu_prio);
        let mut placed = None;
        for &cand in &order {
            // (a) per-(core, engine) order: cand must be the
            // lowest-CPU-priority unassigned candidate among tasks on
            // its core AND its GPU engine (the §5.3 constraint only
            // binds tasks sharing a context queue).
            let core = work.tasks[cand].core;
            let gpu = work.tasks[cand].gpu;
            let violates = unassigned.iter().any(|&d| {
                d != cand
                    && work.tasks[d].core == core
                    && work.tasks[d].gpu == gpu
                    && work.tasks[d].cpu_prio < work.tasks[cand].cpu_prio
            });
            if violates {
                continue;
            }
            // (b) tentative test at this level, over the shared kernel.
            work.tasks[cand].gpu_prio = level;
            let rta = gcaps::response_time_prepared(
                &work, &prep, cand, busy, &no_resp, &opts, &mut scratch,
            );
            if rta.ok() {
                placed = Some(cand);
                break;
            }
            work.tasks[cand].gpu_prio = UNASSIGNED;
        }
        match placed {
            Some(cand) => unassigned.retain(|&i| i != cand),
            None => return None, // no task can take this level
        }
    }
    debug_assert!(unassigned.is_empty());

    // Final full verification (covers CPU-only tasks, whose indirect
    // delay depends on the assignment), over the shared kernel.
    let res = gcaps::analyze_prepared(&work, &prep, busy, &opts);
    if !res.schedulable {
        return None;
    }
    let prios = work.tasks.iter().map(|t| t.gpu_prio).collect();
    Some((work, prios, res))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::gcaps::{analyze, Options};
    use crate::model::{ms, GpuSegment, Platform, Task, WaitMode};
    use crate::taskgen::{generate, GenParams};
    use crate::util::check::forall;

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn preserves_per_core_order() {
        forall("audsley per-core order", 50, |rng| {
            let ts = generate(rng, &GenParams::default());
            if let Some((out, _)) = assign_gpu_priorities(&ts, false) {
                out.validate().map_err(|e| format!("invalid output: {e}"))?;
                for a in out.rt_tasks().filter(|t| t.uses_gpu()) {
                    for b in out.rt_tasks().filter(|t| t.uses_gpu()) {
                        if a.core == b.core
                            && a.cpu_prio > b.cpu_prio
                            && a.gpu_prio <= b.gpu_prio
                        {
                            return Err(format!("order violated: {} vs {}", a.id, b.id));
                        }
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn assignment_is_permutation_of_cpu_prios() {
        forall("audsley permutation", 50, |rng| {
            let ts = generate(rng, &GenParams::default());
            if let Some((out, _)) = assign_gpu_priorities(&ts, false) {
                let mut orig: Vec<u32> = ts
                    .tasks
                    .iter()
                    .filter(|t| !t.best_effort && t.uses_gpu())
                    .map(|t| t.cpu_prio)
                    .collect();
                let mut got: Vec<u32> = out
                    .tasks
                    .iter()
                    .filter(|t| !t.best_effort && t.uses_gpu())
                    .map(|t| t.gpu_prio)
                    .collect();
                orig.sort_unstable();
                got.sort_unstable();
                if orig != got {
                    return Err(format!("{orig:?} != {got:?}"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn success_implies_schedulable() {
        forall("audsley sound", 50, |rng| {
            let ts = generate(rng, &GenParams::default());
            if let Some((out, _)) = assign_gpu_priorities(&ts, false) {
                let opts = Options { use_gpu_prio: true, ..Default::default() };
                if !analyze(&out, false, &opts).schedulable {
                    return Err("assignment accepted but taskset not schedulable".into());
                }
            }
            Ok(())
        });
    }

    #[test]
    fn can_rescue_example2_style_taskset() {
        // A taskset in the spirit of Table 2/Fig. 5: a long-GPU task with
        // higher RM priority starves a shorter, more urgent GPU segment;
        // swapping GPU priorities rescues it. Built so the default
        // assignment fails but an alternative passes.
        let p = Platform::single(2, 1024, 100, 100);
        let tasks = vec![
            // Long GPU segment, long-ish period, higher RM priority.
            gpu_task(0, 0, 2, 4.0, 1.0, 80.0, 190.0),
            // Short GPU segment but needs it promptly.
            gpu_task(1, 1, 1, 8.0, 1.0, 10.0, 100.0),
        ];
        let ts = TaskSet::new(tasks, p);
        let default = analyze(&ts, false, &Options::default());
        if !default.schedulable {
            // Audsley should find the swap (give τ_1's GPU segment the
            // higher priority).
            let got = assign_gpu_priorities(&ts, false);
            assert!(got.is_some(), "Audsley failed to rescue the taskset");
            let (out, _) = got.unwrap();
            assert!(out.tasks[1].gpu_prio > out.tasks[0].gpu_prio);
        }
    }

    #[test]
    fn no_gpu_tasks_returns_none() {
        let tasks = vec![Task::cpu_only(0, 0, 1, ms(5.0), ms(50.0))];
        let ts = TaskSet::new(tasks, Platform::default());
        assert!(assign_gpu_priorities(&ts, false).is_none());
    }
}
