//! End-to-end response time analysis (paper §6).
//!
//! Four first-class approaches, each with busy-waiting and
//! self-suspension variants:
//!
//! - [`rr`] — the **default Tegra driver**'s time-sliced round-robin TSG
//!   scheduling (§6.2, Lemmas 1–7): the first formal analysis of the
//!   unmodified driver.
//! - [`gcaps`] — the paper's **GCAPS** priority-driven preemptive GPU
//!   context scheduling (§6.3, Lemmas 8–15), optionally with the §5.3
//!   separate GPU-segment priority assignment ([`audsley`], §6.4).
//! - [`mpcp`] — synchronization-based baseline: MPCP with
//!   self-suspensions (Patel et al., RTAS 2018 — ref [20]).
//! - [`fmlp`] — synchronization-based baseline: FMLP+ (Brandenburg,
//!   ECRTS 2014 — ref [10]).
//! - [`server`] — server-based GPU access baseline: a dedicated GPU
//!   server task with Kim et al.'s improved request-handling analysis
//!   (arXiv 1709.06613), suspension-only by construction.
//!
//! All analyses walk tasks in decreasing CPU-priority order so that
//! higher-priority response times are available for jitter terms
//! (falling back to D_h when unknown, as in §6.4).
//!
//! Every family evaluates its lemma sums through the precomputed
//! per-taskset interference kernel ([`prep`]): partitions and starred
//! constants are derived once per taskset, and fixed-point iterations
//! reduce to flat term-slice sums. The pre-kernel iterator-chain
//! implementations are retained in [`reference`] as the executable
//! specification (`rust/tests/kernel_equivalence.rs` pins bit-equality).

pub mod audsley;
pub mod fmlp;
pub mod gcaps;
pub mod mpcp;
pub mod prep;
pub mod reference;
pub mod rr;
pub mod server;
pub mod terms;

pub use fmlp::FmlpAnalysis;
pub use gcaps::GcapsAnalysis;
pub use mpcp::MpcpAnalysis;
pub use prep::Prepared;
pub use rr::TsgRrAnalysis;
pub use server::ServerAnalysis;
pub use terms::{AnalysisResult, Rta};

use crate::model::{TaskSet, WaitMode};

/// A first-class response-time analysis: one of the five families in a
/// fixed wait mode. All harnesses (Fig. 8, the multi-GPU sweep, the
/// ablations) dispatch through this trait, so adding an analysis means
/// implementing it and registering the approach — no call-site edits.
///
/// Implementations must honor per-GPU-engine interference sets: GPU
/// blocking / preemption / interleaving terms may only couple tasks
/// sharing a `Task::gpu` engine (CPU-side preemption still couples
/// same-core tasks regardless of engine).
pub trait Analysis: Sync {
    /// Label used in figures and CSVs (matches the paper's legends).
    fn label(&self) -> &'static str;

    /// The wait mode this analysis models during pure GPU execution.
    fn wait_mode(&self) -> WaitMode;

    /// Run the analysis over every RT task of `ts`.
    fn analyze(&self, ts: &TaskSet) -> AnalysisResult;
}

/// The nine analysis configurations the harnesses evaluate (the eight
/// of Fig. 8 plus the server-based baseline) — a thin registry over the
/// [`Analysis`] trait objects, kept as an enum so `Approach::ALL`-driven
/// harnesses, CSV labels and match-based dispatch (e.g. the DES policy
/// mapping) keep working. New approaches append to the END of
/// [`Approach::ALL`]: every CSV is emitted approach-major in this
/// order, so appending keeps the existing columns a byte-exact prefix.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Approach {
    GcapsBusy,
    GcapsSuspend,
    TsgRrBusy,
    TsgRrSuspend,
    MpcpBusy,
    MpcpSuspend,
    FmlpBusy,
    FmlpSuspend,
    ServerSuspend,
}

static GCAPS_BUSY: GcapsAnalysis = GcapsAnalysis { busy: true };
static GCAPS_SUSPEND: GcapsAnalysis = GcapsAnalysis { busy: false };
static TSG_RR_BUSY: TsgRrAnalysis = TsgRrAnalysis { busy: true };
static TSG_RR_SUSPEND: TsgRrAnalysis = TsgRrAnalysis { busy: false };
static MPCP_BUSY: MpcpAnalysis = MpcpAnalysis { busy: true };
static MPCP_SUSPEND: MpcpAnalysis = MpcpAnalysis { busy: false };
static FMLP_BUSY: FmlpAnalysis = FmlpAnalysis { busy: true };
static FMLP_SUSPEND: FmlpAnalysis = FmlpAnalysis { busy: false };
static SERVER_SUSPEND: ServerAnalysis = ServerAnalysis;

impl Approach {
    pub const ALL: [Approach; 9] = [
        Approach::GcapsBusy,
        Approach::GcapsSuspend,
        Approach::TsgRrBusy,
        Approach::TsgRrSuspend,
        Approach::MpcpBusy,
        Approach::MpcpSuspend,
        Approach::FmlpBusy,
        Approach::FmlpSuspend,
        Approach::ServerSuspend,
    ];

    /// The trait object implementing this approach.
    pub fn analysis(&self) -> &'static dyn Analysis {
        match self {
            Approach::GcapsBusy => &GCAPS_BUSY,
            Approach::GcapsSuspend => &GCAPS_SUSPEND,
            Approach::TsgRrBusy => &TSG_RR_BUSY,
            Approach::TsgRrSuspend => &TSG_RR_SUSPEND,
            Approach::MpcpBusy => &MPCP_BUSY,
            Approach::MpcpSuspend => &MPCP_SUSPEND,
            Approach::FmlpBusy => &FMLP_BUSY,
            Approach::FmlpSuspend => &FMLP_SUSPEND,
            Approach::ServerSuspend => &SERVER_SUSPEND,
        }
    }

    /// Label used in figures and CSVs (matches the paper's legends).
    pub fn label(&self) -> &'static str {
        self.analysis().label()
    }

    pub fn from_label(s: &str) -> Option<Approach> {
        Approach::ALL.iter().copied().find(|a| a.label() == s)
    }

    pub fn wait_mode(&self) -> WaitMode {
        self.analysis().wait_mode()
    }

    pub fn is_busy(&self) -> bool {
        self.wait_mode() == WaitMode::BusyWait
    }
}

/// Run an approach's analysis on a taskset. For the GCAPS approaches,
/// `gcaps::Options::default()` is used (paper-faithful, task priorities
/// for GPU segments). Fig. 8's GCAPS curves additionally retry failed
/// tasksets with the Audsley GPU-priority assignment — see
/// [`analyze_with_gpu_prio`].
pub fn analyze(ts: &TaskSet, approach: Approach) -> AnalysisResult {
    approach.analysis().analyze(ts)
}

/// Schedulability under the full per-approach procedure the paper's
/// evaluation uses (§7.1.1): plain analysis for every family, plus the
/// Audsley GPU-priority retry for the GCAPS approaches. Shared by the
/// Fig. 8 panels and the multi-GPU sweep.
pub fn approach_schedulable(ts: &TaskSet, approach: Approach) -> bool {
    match approach {
        Approach::GcapsBusy => analyze_with_gpu_prio(ts, true).0.schedulable,
        Approach::GcapsSuspend => analyze_with_gpu_prio(ts, false).0.schedulable,
        a => a.analysis().analyze(ts).schedulable,
    }
}

/// The full GCAPS schedulability procedure of §7.1.1: run with default
/// (RM) priorities for GPU segments; if that fails, search for a
/// separate GPU-priority assignment with Audsley's algorithm (§5.3).
/// Returns the result plus the assignment used (None = default prios).
pub fn analyze_with_gpu_prio(
    ts: &TaskSet,
    busy: bool,
) -> (AnalysisResult, Option<Vec<u32>>) {
    let base = gcaps::analyze(ts, busy, &gcaps::Options::default());
    if base.schedulable {
        return (base, None);
    }
    // The search's final verification IS the analysis of the assigned
    // taskset — reuse it instead of re-running the full analysis.
    match audsley::assign_gpu_priorities_analyzed(ts, busy) {
        Some((_assigned_ts, prios, res)) => (res, Some(prios)),
        None => (base, None),
    }
}
