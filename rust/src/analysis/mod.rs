//! End-to-end response time analysis (paper §6).
//!
//! Four first-class approaches, each with busy-waiting and
//! self-suspension variants:
//!
//! - [`rr`] — the **default Tegra driver**'s time-sliced round-robin TSG
//!   scheduling (§6.2, Lemmas 1–7): the first formal analysis of the
//!   unmodified driver.
//! - [`gcaps`] — the paper's **GCAPS** priority-driven preemptive GPU
//!   context scheduling (§6.3, Lemmas 8–15), optionally with the §5.3
//!   separate GPU-segment priority assignment ([`audsley`], §6.4).
//! - [`mpcp`] — synchronization-based baseline: MPCP with
//!   self-suspensions (Patel et al., RTAS 2018 — ref [20]).
//! - [`fmlp`] — synchronization-based baseline: FMLP+ (Brandenburg,
//!   ECRTS 2014 — ref [10]).
//!
//! All analyses walk tasks in decreasing CPU-priority order so that
//! higher-priority response times are available for jitter terms
//! (falling back to D_h when unknown, as in §6.4).

pub mod audsley;
pub mod fmlp;
pub mod gcaps;
pub mod mpcp;
pub mod rr;
pub mod terms;

pub use terms::{AnalysisResult, Rta};

use crate::model::TaskSet;

/// The eight analysis configurations evaluated in Fig. 8.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Approach {
    GcapsBusy,
    GcapsSuspend,
    TsgRrBusy,
    TsgRrSuspend,
    MpcpBusy,
    MpcpSuspend,
    FmlpBusy,
    FmlpSuspend,
}

impl Approach {
    pub const ALL: [Approach; 8] = [
        Approach::GcapsBusy,
        Approach::GcapsSuspend,
        Approach::TsgRrBusy,
        Approach::TsgRrSuspend,
        Approach::MpcpBusy,
        Approach::MpcpSuspend,
        Approach::FmlpBusy,
        Approach::FmlpSuspend,
    ];

    /// Label used in figures and CSVs (matches the paper's legends).
    pub fn label(&self) -> &'static str {
        match self {
            Approach::GcapsBusy => "gcaps_busy",
            Approach::GcapsSuspend => "gcaps_suspend",
            Approach::TsgRrBusy => "tsg_rr_busy",
            Approach::TsgRrSuspend => "tsg_rr_suspend",
            Approach::MpcpBusy => "mpcp_busy",
            Approach::MpcpSuspend => "mpcp_suspend",
            Approach::FmlpBusy => "fmlp_busy",
            Approach::FmlpSuspend => "fmlp_suspend",
        }
    }

    pub fn from_label(s: &str) -> Option<Approach> {
        Approach::ALL.iter().copied().find(|a| a.label() == s)
    }

    pub fn is_busy(&self) -> bool {
        matches!(self, Approach::GcapsBusy | Approach::TsgRrBusy | Approach::MpcpBusy | Approach::FmlpBusy)
    }
}

/// Run an approach's analysis on a taskset. For the GCAPS approaches,
/// `gcaps::Options::default()` is used (paper-faithful, task priorities
/// for GPU segments). Fig. 8's GCAPS curves additionally retry failed
/// tasksets with the Audsley GPU-priority assignment — see
/// [`analyze_with_gpu_prio`].
pub fn analyze(ts: &TaskSet, approach: Approach) -> AnalysisResult {
    match approach {
        Approach::GcapsBusy => gcaps::analyze(ts, true, &gcaps::Options::default()),
        Approach::GcapsSuspend => gcaps::analyze(ts, false, &gcaps::Options::default()),
        Approach::TsgRrBusy => rr::analyze(ts, true),
        Approach::TsgRrSuspend => rr::analyze(ts, false),
        Approach::MpcpBusy => mpcp::analyze(ts, true),
        Approach::MpcpSuspend => mpcp::analyze(ts, false),
        Approach::FmlpBusy => fmlp::analyze(ts, true),
        Approach::FmlpSuspend => fmlp::analyze(ts, false),
    }
}

/// The full GCAPS schedulability procedure of §7.1.1: run with default
/// (RM) priorities for GPU segments; if that fails, search for a
/// separate GPU-priority assignment with Audsley's algorithm (§5.3).
/// Returns the result plus the assignment used (None = default prios).
pub fn analyze_with_gpu_prio(
    ts: &TaskSet,
    busy: bool,
) -> (AnalysisResult, Option<Vec<u32>>) {
    let base = gcaps::analyze(ts, busy, &gcaps::Options::default());
    if base.schedulable {
        return (base, None);
    }
    match audsley::assign_gpu_priorities(ts, busy) {
        Some((assigned_ts, prios)) => {
            let opts = gcaps::Options { use_gpu_prio: true, ..Default::default() };
            let res = gcaps::analyze(&assigned_ts, busy, &opts);
            (res, Some(prios))
        }
        None => (base, None),
    }
}
