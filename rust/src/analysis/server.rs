//! Server-based GPU access baseline: Kim et al.'s dedicated GPU-server
//! task with the *improved* request-handling analysis ("A Server-based
//! Approach for Predictable GPU Access Control" / "... with Improved
//! Analysis", arXiv 1709.06613 — the strongest prior-work baseline the
//! GCAPS paper benchmarks against, §7).
//!
//! Model mapping (paper §3): every GPU access of a task is shipped as a
//! *request* to a dedicated server task running on its own core. The
//! server executes the whole GPU segment (G^m miscellaneous operations
//! + G^e kernel) on the requester's behalf while the requester
//! self-suspends; per engine, pending requests are served in task
//! priority order and an executing request is never preempted. Each
//! request costs 2ε of server-side administration (enqueue + wake-up,
//! bracketing the segment like the runlist updates of §6.3).
//!
//! The *improved* analysis bounds all of task i's per-job GPU access
//! delay with **one cumulative request-handling window** `B_i` instead
//! of MPCP's per-request `W_i · η_i` — higher-priority server demand is
//! counted once over the whole window rather than once per request:
//!
//! ```text
//! B_i <- S_i + η_i · max_{lp/BE same-engine l} (gcs_max_l + 2ε)
//!       + Σ_{hp same-engine h} (ceil(B_i / T_h) + 1) · S_h
//! ```
//!
//! with `S_j = gcs_total_j + 2ε·η_j` the server's total service demand
//! for one job of τ_j. The lp term: each of the η_i requests can find
//! one lower-priority (or best-effort) request already in
//! non-preemptive service. The response-time test then runs suspension-
//! aware, with the server off the task cores — no priority boosting, so
//! higher-priority CPU demand is the plain C_h (GPU time is the
//! server's problem) with jitter J_h = R_h − C_h:
//!
//! ```text
//! R_i <- C_i + B_i + Σ_{hpp} ceil((R_i + J_h) / T_h) · C_h
//! ```
//!
//! CPU-only tasks have B_i = 0: with a dedicated server core there is
//! no boost blocking — the structural advantage this approach trades
//! against the cost of serializing all GPU access through one task.
//!
//! Implementation: the same-engine requester sets and per-task gcs
//! bounds come precomputed from [`Prepared`]; both the B iteration and
//! the response fixed point run over flat `Term` slices. The original
//! iterator-chain path lives in [`crate::analysis::reference`] and
//! `rust/tests/kernel_equivalence.rs` pins bit-equality.

use crate::analysis::prep::{eval, run_fixed_point, Prepared, Scratch};
use crate::analysis::terms::{AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// The server's total service demand for one job of task `j`:
/// S_j = Σ gcs + 2ε·η (each request pays the enqueue/wake-up bracket).
#[inline]
fn service(prep: &Prepared, j: usize) -> Time {
    let p = &prep.t[j];
    p.gcs_total.saturating_add(p.eps.saturating_mul(2).saturating_mul(p.eta_g))
}

/// Cumulative request-handling window B_i for task i (the improved
/// bound: one window over all η_i requests). Each GPU engine has its
/// own request queue, so only same-engine requesters contend. Returns
/// None if the iteration diverges past the deadline (treated as
/// unschedulable upstream).
fn request_window(prep: &Prepared, i: usize, scratch: &mut Scratch) -> Option<Time> {
    let me = prep.t[i];
    if !me.uses_gpu {
        return Some(0);
    }
    scratch.clear();
    let mut lp_max: Time = 0;
    let mut hp_const: Time = 0; // the "+1" part: Σ_h S_h
    for &h32 in prep.sharing.get(i) {
        let p = &prep.t[h32 as usize];
        if p.best_effort || p.cpu_prio < me.cpu_prio {
            // One lp/BE request in non-preemptive service per own request.
            lp_max = lp_max.max(p.max_gcs.saturating_add(p.eps.saturating_mul(2)));
        } else if p.cpu_prio > me.cpu_prio {
            let s_h = service(prep, h32 as usize);
            hp_const = hp_const.saturating_add(s_h);
            scratch.push(0, p.period, s_h);
        }
    }
    let own = service(prep, i).saturating_add(me.eta_g.saturating_mul(lp_max));
    // Iterate B = own + Σ_h (ceil(B/T_h)+1) · S_h (saturating so a
    // pathological service demand pins at MAX and fails the deadline
    // check instead of wrapping).
    let base = own.saturating_add(hp_const);
    let mut b = own;
    for _ in 0..10_000 {
        let next = base.saturating_add(eval(b, &scratch.terms));
        if next == b {
            return Some(b);
        }
        if next > me.deadline {
            return None;
        }
        b = next;
    }
    None
}

/// Higher-priority CPU interference terms for task `i` into
/// `scratch.terms`: plain C_h demand (GPU work runs on the server), with
/// self-suspension jitter J_h = R_h − C_h for GPU-using hp tasks.
fn build_terms(prep: &Prepared, i: usize, resp: &[Option<Time>], scratch: &mut Scratch) {
    scratch.clear();
    for &h32 in prep.hpp.get(i) {
        let h = h32 as usize;
        let p = &prep.t[h];
        let jit = if p.uses_gpu {
            resp[h].unwrap_or(p.deadline).saturating_sub(p.c)
        } else {
            0
        };
        scratch.push(jit, p.period, p.c);
    }
}

/// Response time of task i under the server-based approach, over a
/// prebuilt kernel. `b_all` as computed by [`analyze_prepared`].
pub fn response_time_prepared(
    prep: &Prepared,
    i: usize,
    resp: &[Option<Time>],
    b_all: &[Time],
    scratch: &mut Scratch,
) -> Rta {
    let me = prep.t[i];
    let own = me.c.saturating_add(b_all[i]);
    build_terms(prep, i, resp, scratch);
    run_fixed_point(me.deadline, own, &scratch.terms)
}

/// Analyse all RT tasks over an existing kernel.
pub fn analyze_prepared(ts: &TaskSet, prep: &Prepared) -> AnalysisResult {
    let n = ts.tasks.len();
    let mut scratch = Scratch::default();
    let mut b_all = vec![0; n];
    let mut blocked_diverged = vec![false; n];
    for j in 0..n {
        if prep.t[j].best_effort {
            continue;
        }
        match request_window(prep, j, &mut scratch) {
            Some(b) => b_all[j] = b,
            None => blocked_diverged[j] = true,
        }
    }
    let mut resp: Vec<Option<Time>> = vec![None; n];
    for &i in &prep.order {
        if blocked_diverged[i] {
            continue;
        }
        let r = response_time_prepared(prep, i, &resp, &b_all, &mut scratch);
        resp[i] = r.time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// Analyse all RT tasks.
pub fn analyze(ts: &TaskSet) -> AnalysisResult {
    let prep = Prepared::new(ts);
    analyze_prepared(ts, &prep)
}

/// [`Analysis`] implementation: the server-based GPU access baseline.
/// Suspension-only by construction — requesters always self-suspend
/// while the server executes on their behalf.
#[derive(Debug, Clone, Copy)]
pub struct ServerAnalysis;

impl Analysis for ServerAnalysis {
    fn label(&self) -> &'static str {
        "server"
    }

    fn wait_mode(&self) -> WaitMode {
        WaitMode::SelfSuspend
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

    fn platform() -> Platform {
        Platform { num_cpus: 2, ..Default::default() }
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    fn eps(ts: &TaskSet) -> u64 {
        ts.platform.gpus[0].epsilon
    }

    #[test]
    fn single_task_pays_request_overhead_only() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts);
        // R = C + S = C + (G^m + G^e) + 2ε·η.
        assert_eq!(res.response[0], Some(ms(8.0) + 2 * eps(&ts)));
    }

    #[test]
    fn cpu_only_task_has_no_boost_blocking() {
        // The structural win over MPCP/FMLP+: the server lives on its
        // own core, so a CPU-only task never sees boosted G^m demand.
        let hp = Task::cpu_only(0, 0, 2, ms(5.0), ms(50.0));
        let lp = gpu_task(1, 0, 1, 2.0, 3.0, 10.0, 100.0);
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = analyze(&ts);
        assert_eq!(res.response[0], Some(ms(5.0)));
    }

    #[test]
    fn high_priority_request_waits_one_lp_service() {
        // Non-preemptive service: the hp request finds the lp 62 ms gcs
        // (+ 2ε bracket) already running.
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 200.0);
        let lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 400.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts);
        let e = eps(&ts);
        assert_eq!(res.response[0], Some(ms(8.0) + 2 * e + ms(62.0) + 2 * e));
    }

    #[test]
    fn improved_window_beats_per_request_mpcp_bound() {
        // Two requests against one hp sharer inside one window: the
        // cumulative bound charges the hp service once, MPCP's
        // per-request bound (W·η) charges it per request.
        let mut lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 400.0);
        lo.cpu_segments = vec![ms(1.0), ms(1.0), ms(1.0)];
        lo.gpu_segments =
            vec![GpuSegment::new(ms(1.0), ms(5.0)), GpuSegment::new(ms(1.0), ms(5.0))];
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 300.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let server = analyze(&ts).response[1].unwrap();
        let mpcp = crate::analysis::mpcp::analyze(&ts, false).response[1].unwrap();
        assert!(server < mpcp, "server {server} >= mpcp {mpcp}");
    }

    #[test]
    fn cross_engine_requests_do_not_contend() {
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        lo.gpu = 1;
        let p = Platform { num_cpus: 2, ..Default::default() }.with_num_gpus(2);
        let ts = TaskSet::new(vec![hi, lo], p);
        let res = analyze(&ts);
        assert_eq!(res.response[0], Some(ms(8.0) + 2 * eps(&ts)));
    }

    #[test]
    fn best_effort_requests_block_like_lp() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 200.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 300.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = analyze(&ts);
        let e = eps(&ts);
        assert_eq!(res.response[0], Some(ms(8.0) + 2 * e + ms(82.0) + 2 * e));
    }

    #[test]
    fn matches_naive_reference() {
        let hi = gpu_task(0, 0, 3, 2.0, 1.0, 5.0, 100.0);
        let mid = gpu_task(1, 1, 2, 4.0, 1.0, 10.0, 150.0);
        let lo = gpu_task(2, 0, 1, 3.0, 2.0, 8.0, 200.0);
        let cpu = Task::cpu_only(3, 1, 4, ms(2.0), ms(80.0));
        let ts = TaskSet::new(vec![hi, mid, lo, cpu], platform());
        let kernel = analyze(&ts);
        let naive = crate::analysis::reference::server_analyze(&ts);
        assert_eq!(kernel.schedulable, naive.schedulable);
        assert_eq!(kernel.response, naive.response);
    }
}
