//! Shared RTA machinery: ceiling division, the interleaved-execution
//! bound 𝓘(ν, G^e) of Eq. (3), the starred-demand constants of §6.3
//! (G^e*, G^m* — shared by every analysis family and precomputed by
//! [`crate::analysis::prep::Prepared`]), release-jitter arrival bounds,
//! and the fixed-point iteration driver used by every analysis.

use crate::model::{Task, TaskSet, Time};

/// ceil(a / b) over integers (b > 0).
pub fn ceil_div(a: Time, b: Time) -> Time {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Number of jobs of a task with period `t_h` arriving in a window of
/// length `r`: ceil(r / T_h).
pub fn njobs(r: Time, t_h: Time) -> Time {
    ceil_div(r, t_h)
}

/// Number of jobs with a carry-in (release jitter `j`): ceil((r+J)/T_h).
pub fn njobs_jitter(r: Time, jitter: Time, t_h: Time) -> Time {
    ceil_div(r.saturating_add(jitter), t_h)
}

/// Eq. (3): worst-case delay imposed on one pure GPU segment `ge` by the
/// default driver's interleaved execution with `nu` sharing TSGs, slice
/// length `l` and context-switch overhead `theta`:
///
/// ```text
///     I(nu, Ge) = (L + theta) * nu * ceil(Ge / L)  [+ theta * ceil(Ge / L)]
/// ```
///
/// Soundness amendment (bracketed term): Eq. (3) as printed charges ν
/// slices + switches per round but omits the θ paid to switch back INTO
/// the segment's own context each round. Without it the bound is
/// undercut by exactly θ·ceil(G^e/L) on the device model (and on real
/// round-robin hardware). We include it so the analysis dominates the
/// simulator; the delta is ≤ 0.02% of a slice per round and does not
/// change any Fig. 8 trend.
///
/// Saturating: for extreme G^e/ν the product `(L+θ)·ν·rounds` can exceed
/// `Time::MAX`; wrapping there would report a tiny (unsound) bound, so
/// every step saturates — an overflowed bound pins to `Time::MAX` and
/// the fixed point fails the deadline check, which is the sound outcome.
pub fn interleave(nu: usize, ge: Time, l: Time, theta: Time) -> Time {
    if ge == 0 {
        return 0;
    }
    interleave_rounds(nu, ceil_div(ge, l), l, theta)
}

/// The Eq. (3) bound in terms of a precomputed round count
/// `rounds = ceil(G^e / L)`. Factored out so the prepared kernel can
/// evaluate a task's whole-job interleaving from its cached
/// `Σ_j ceil(G^e_{i,j} / L)` without re-walking the segments: the bound
/// is linear in `rounds`, so summing rounds first distributes exactly.
pub fn interleave_rounds(nu: usize, rounds: Time, l: Time, theta: Time) -> Time {
    l.saturating_add(theta)
        .saturating_mul(nu as Time)
        .saturating_mul(rounds)
        .saturating_add(theta.saturating_mul(rounds))
}

/// ε of the engine a task is assigned to (per-GPU overheads: a task's
/// runlist updates go to its own engine's driver lock).
pub fn eps_of(ts: &TaskSet, t: &Task) -> Time {
    ts.platform.gpus[t.gpu].epsilon
}

/// G^e*_i = G^e_i + 2ε·η^g_i: pure GPU execution plus the runlist
/// updates bracketing each segment (§6.3). Saturating for the same
/// reason as [`interleave`]: a wrapped starred demand would report a
/// tiny unsound bound on crafted ε/η inputs, while a pinned one fails
/// the deadline check. (Shared by the kernel and reference paths, so
/// both saturate identically.)
pub fn ge_star(t: &Task, eps: Time) -> Time {
    t.ge().saturating_add(eps.saturating_mul(2).saturating_mul(t.eta_g() as Time))
}

/// G^m*_i = G^m_i + 2ε·η^g_i (saturating, see [`ge_star`]).
pub fn gm_star(t: &Task, eps: Time) -> Time {
    t.gm().saturating_add(eps.saturating_mul(2).saturating_mul(t.eta_g() as Time))
}

/// Result of analysing one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rta {
    /// Converged response time ≤ deadline.
    Schedulable(Time),
    /// Fixed point exceeded the deadline (or diverged).
    Unschedulable,
}

impl Rta {
    pub fn time(&self) -> Option<Time> {
        match self {
            Rta::Schedulable(t) => Some(*t),
            Rta::Unschedulable => None,
        }
    }

    pub fn ok(&self) -> bool {
        matches!(self, Rta::Schedulable(_))
    }
}

/// Iterate R ← demand + interference(R) from `init` until the fixed
/// point, failing as soon as R exceeds `deadline`. `f` must be monotone
/// non-decreasing in R (all our interference terms are: they are sums of
/// ceil((R + J)/T) · const).
pub fn fixed_point(deadline: Time, init: Time, f: impl Fn(Time) -> Time) -> Rta {
    if init > deadline {
        return Rta::Unschedulable;
    }
    let mut r = init;
    // Divergence backstop, derived instead of a magic constant: every
    // non-terminal iteration either converges (next == r), fails
    // (next > deadline), or — f being monotone over the integer µs
    // lattice — strictly advances r by ≥ 1 tick while r ≤ deadline.
    // Only (deadline − init) such advances fit inside [init, deadline],
    // so (deadline − init + 1) iterations reach any fixed point that
    // exists ≤ deadline. Hitting the bound therefore cannot
    // false-negative a schedulable task: that would require a
    // convergent strictly-increasing integer sequence with more steps
    // than there are integers in [init, deadline]. (Inclusive range:
    // `span + 1` could overflow when deadline − init == Time::MAX.)
    //
    // Trade-off vs the old magic 100_000 cap: that cap could (in
    // theory) reject slow-converging schedulable tasks; this bound
    // cannot, but a crafted taskset FILE with a near-MAX deadline and
    // µs-scale hp periods could make convergence take ~deadline/T_min
    // iterations instead of being cut off. Generated tasksets (Table 3
    // periods ≤ 500 ms ⇒ span ≤ 5·10^5) sit at the old cap's scale.
    let span = deadline.saturating_sub(init);
    for _ in 0..=span {
        let next = f(r);
        if next == r {
            return Rta::Schedulable(r);
        }
        if next > deadline {
            return Rta::Unschedulable;
        }
        debug_assert!(next > r, "interference must be monotone");
        r = next;
    }
    Rta::Unschedulable
}

/// Per-taskset analysis output: response time per task (indexed by id).
#[derive(Debug, Clone)]
pub struct AnalysisResult {
    /// One entry per task; `None` for best-effort tasks (not analysed)
    /// and for RT tasks that failed the test.
    pub response: Vec<Option<Time>>,
    /// Whether every RT task passed.
    pub schedulable: bool,
}

impl AnalysisResult {
    pub fn from_responses(tasks: &[Task], response: Vec<Option<Time>>) -> AnalysisResult {
        let schedulable = tasks
            .iter()
            .filter(|t| !t.best_effort)
            .all(|t| response[t.id].is_some());
        AnalysisResult { response, schedulable }
    }
}

/// Jitter of a higher-priority task's GPU execution: J^g = R_h − G_h^e
/// (Lemma 10), or D_h − G_h^e when R_h is unknown (§6.4).
pub fn jitter_g(t: &Task, r_h: Option<Time>) -> Time {
    r_h.unwrap_or(t.deadline).saturating_sub(t.ge())
}

/// Jitter of a higher-priority task's CPU demand under self-suspension:
/// J^c = R_h − (C_h + G_h^m) (Lemma 7), D_h-based fallback.
pub fn jitter_c(t: &Task, r_h: Option<Time>) -> Time {
    r_h.unwrap_or(t.deadline).saturating_sub(t.c().saturating_add(t.gm()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ms;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn interleave_eq3() {
        // L = 1024, θ = 200, ν = 3, G^e = 2500 → 3 rounds:
        // Eq. 3 term (1024+200)*3*3 plus own switch-in θ per round.
        assert_eq!(interleave(3, 2500, 1024, 200), (1024 + 200) * 3 * 3 + 200 * 3);
    }

    #[test]
    fn interleave_zero_cases() {
        // ν = 0 still pays the own switch-in θ per round.
        assert_eq!(interleave(0, 1000, 1024, 200), 200);
        assert_eq!(interleave(5, 0, 1024, 200), 0);
    }

    #[test]
    fn interleave_exact_slice_boundary() {
        assert_eq!(interleave(1, 1024, 1024, 200), 1224 + 200);
        assert_eq!(interleave(1, 1025, 1024, 200), 2448 + 400);
    }

    #[test]
    fn interleave_saturates_instead_of_wrapping() {
        // Regression: (l + θ)·ν·rounds used to wrap Time for large
        // G^e/ν, silently reporting a tiny (unsound) bound. It must pin
        // to Time::MAX instead.
        let huge = Time::MAX / 2;
        assert_eq!(interleave(usize::MAX, huge, 1, 200), Time::MAX);
        assert_eq!(interleave(3, huge, 1, huge), Time::MAX);
        // The saturated bound still dominates every finite input's true
        // value, and small inputs are untouched.
        assert_eq!(interleave(3, 2500, 1024, 200), (1024 + 200) * 3 * 3 + 200 * 3);
    }

    #[test]
    fn interleave_rounds_distributes_over_segments() {
        // Σ_j I(ν, G^e_j) == interleave_rounds(ν, Σ_j rounds_j) — the
        // identity the prepared kernel's cached round sums rely on.
        let (l, theta, nu) = (1024, 200, 4);
        let segs = [100u64, 1024, 5000, 1];
        let per_seg: Time = segs.iter().map(|&g| interleave(nu, g, l, theta)).sum();
        let rounds: Time = segs.iter().map(|&g| ceil_div(g, l)).sum();
        assert_eq!(per_seg, interleave_rounds(nu, rounds, l, theta));
    }

    #[test]
    fn starred_demand_helpers() {
        let t = crate::model::Task {
            id: 0,
            name: "x".into(),
            period: ms(100.0),
            deadline: ms(100.0),
            cpu_segments: vec![ms(2.0), ms(2.0), ms(2.0)],
            gpu_segments: vec![
                crate::model::GpuSegment::new(ms(1.0), ms(5.0)),
                crate::model::GpuSegment::new(ms(2.0), ms(3.0)),
            ],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: crate::model::WaitMode::SelfSuspend,
        };
        // η^g = 2, so each star adds 2ε·2 = 4ε.
        assert_eq!(ge_star(&t, 1000), ms(8.0) + 4000);
        assert_eq!(gm_star(&t, 1000), ms(3.0) + 4000);
        assert_eq!(ge_star(&t, 0), t.ge());
    }

    #[test]
    fn fixed_point_bound_is_iteration_count_not_magic() {
        // A pathological f advancing 1 µs per step must still converge
        // when the fixed point exists ≤ deadline, even past the old
        // 100_000-iteration backstop.
        let deadline = 300_000;
        let target = 250_000;
        let r = fixed_point(deadline, 0, |r| if r < target { r + 1 } else { target });
        assert_eq!(r, Rta::Schedulable(target));
    }

    #[test]
    fn fixed_point_converges() {
        // Classic RTA: C = 2, one hp task C_h = 1, T_h = 4, D = 10.
        let r = fixed_point(10, 2, |r| 2 + njobs(r, 4) * 1);
        assert_eq!(r, Rta::Schedulable(3));
    }

    #[test]
    fn fixed_point_fails_past_deadline() {
        // Overloaded: C = 3, hp C_h = 3, T_h = 4 → diverges past D = 8.
        let r = fixed_point(8, 3, |r| 3 + njobs(r, 4) * 3);
        assert_eq!(r, Rta::Unschedulable);
    }

    #[test]
    fn fixed_point_init_beyond_deadline() {
        assert_eq!(fixed_point(5, 6, |r| r), Rta::Unschedulable);
    }

    #[test]
    fn njobs_jitter_carry_in() {
        assert_eq!(njobs_jitter(10, 0, 4), 3);
        assert_eq!(njobs_jitter(10, 3, 4), 4);
    }

    #[test]
    fn jitters() {
        let t = crate::model::Task {
            id: 0,
            name: "x".into(),
            period: ms(100.0),
            deadline: ms(90.0),
            cpu_segments: vec![ms(2.0), ms(2.0)],
            gpu_segments: vec![crate::model::GpuSegment::new(ms(1.0), ms(5.0))],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: crate::model::WaitMode::SelfSuspend,
        };
        assert_eq!(jitter_g(&t, Some(ms(20.0))), ms(15.0));
        assert_eq!(jitter_g(&t, None), ms(85.0)); // D − G^e
        assert_eq!(jitter_c(&t, Some(ms(20.0))), ms(15.0));
        assert_eq!(jitter_c(&t, None), ms(85.0)); // D − (C + G^m)
    }
}
