//! Shared RTA machinery: ceiling division, the interleaved-execution
//! bound 𝓘(ν, G^e) of Eq. (3), release-jitter arrival bounds, and the
//! fixed-point iteration driver used by every analysis.

use crate::model::{Task, Time};

/// ceil(a / b) over integers (b > 0).
pub fn ceil_div(a: Time, b: Time) -> Time {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// Number of jobs of a task with period `t_h` arriving in a window of
/// length `r`: ceil(r / T_h).
pub fn njobs(r: Time, t_h: Time) -> Time {
    ceil_div(r, t_h)
}

/// Number of jobs with a carry-in (release jitter `j`): ceil((r+J)/T_h).
pub fn njobs_jitter(r: Time, jitter: Time, t_h: Time) -> Time {
    ceil_div(r.saturating_add(jitter), t_h)
}

/// Eq. (3): worst-case delay imposed on one pure GPU segment `ge` by the
/// default driver's interleaved execution with `nu` sharing TSGs, slice
/// length `l` and context-switch overhead `theta`:
///
/// ```text
///     I(nu, Ge) = (L + theta) * nu * ceil(Ge / L)  [+ theta * ceil(Ge / L)]
/// ```
///
/// Soundness amendment (bracketed term): Eq. (3) as printed charges ν
/// slices + switches per round but omits the θ paid to switch back INTO
/// the segment's own context each round. Without it the bound is
/// undercut by exactly θ·ceil(G^e/L) on the device model (and on real
/// round-robin hardware). We include it so the analysis dominates the
/// simulator; the delta is ≤ 0.02% of a slice per round and does not
/// change any Fig. 8 trend.
pub fn interleave(nu: usize, ge: Time, l: Time, theta: Time) -> Time {
    if ge == 0 {
        return 0;
    }
    let rounds = ceil_div(ge, l);
    (l + theta) * nu as Time * rounds + theta * rounds
}

/// Result of analysing one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rta {
    /// Converged response time ≤ deadline.
    Schedulable(Time),
    /// Fixed point exceeded the deadline (or diverged).
    Unschedulable,
}

impl Rta {
    pub fn time(&self) -> Option<Time> {
        match self {
            Rta::Schedulable(t) => Some(*t),
            Rta::Unschedulable => None,
        }
    }

    pub fn ok(&self) -> bool {
        matches!(self, Rta::Schedulable(_))
    }
}

/// Iterate R ← demand + interference(R) from `init` until the fixed
/// point, failing as soon as R exceeds `deadline`. `f` must be monotone
/// non-decreasing in R (all our interference terms are: they are sums of
/// ceil((R + J)/T) · const).
pub fn fixed_point(deadline: Time, init: Time, f: impl Fn(Time) -> Time) -> Rta {
    let mut r = init.min(deadline);
    if init > deadline {
        return Rta::Unschedulable;
    }
    // Bounded iterations as a divergence backstop; monotone f over the
    // integer lattice [init, deadline] converges well before this.
    for _ in 0..100_000 {
        let next = f(r);
        if next == r {
            return Rta::Schedulable(r);
        }
        if next > deadline {
            return Rta::Unschedulable;
        }
        debug_assert!(next > r, "interference must be monotone");
        r = next;
    }
    Rta::Unschedulable
}

/// Per-taskset analysis output: response time per task (indexed by id).
#[derive(Debug, Clone)]
pub struct AnalysisResult {
    /// One entry per task; `None` for best-effort tasks (not analysed)
    /// and for RT tasks that failed the test.
    pub response: Vec<Option<Time>>,
    /// Whether every RT task passed.
    pub schedulable: bool,
}

impl AnalysisResult {
    pub fn from_responses(tasks: &[Task], response: Vec<Option<Time>>) -> AnalysisResult {
        let schedulable = tasks
            .iter()
            .filter(|t| !t.best_effort)
            .all(|t| response[t.id].is_some());
        AnalysisResult { response, schedulable }
    }
}

/// Jitter of a higher-priority task's GPU execution: J^g = R_h − G_h^e
/// (Lemma 10), or D_h − G_h^e when R_h is unknown (§6.4).
pub fn jitter_g(t: &Task, r_h: Option<Time>) -> Time {
    r_h.unwrap_or(t.deadline).saturating_sub(t.ge())
}

/// Jitter of a higher-priority task's CPU demand under self-suspension:
/// J^c = R_h − (C_h + G_h^m) (Lemma 7), D_h-based fallback.
pub fn jitter_c(t: &Task, r_h: Option<Time>) -> Time {
    r_h.unwrap_or(t.deadline).saturating_sub(t.c() + t.gm())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::ms;

    #[test]
    fn ceil_div_basic() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn interleave_eq3() {
        // L = 1024, θ = 200, ν = 3, G^e = 2500 → 3 rounds:
        // Eq. 3 term (1024+200)*3*3 plus own switch-in θ per round.
        assert_eq!(interleave(3, 2500, 1024, 200), (1024 + 200) * 3 * 3 + 200 * 3);
    }

    #[test]
    fn interleave_zero_cases() {
        // ν = 0 still pays the own switch-in θ per round.
        assert_eq!(interleave(0, 1000, 1024, 200), 200);
        assert_eq!(interleave(5, 0, 1024, 200), 0);
    }

    #[test]
    fn interleave_exact_slice_boundary() {
        assert_eq!(interleave(1, 1024, 1024, 200), 1224 + 200);
        assert_eq!(interleave(1, 1025, 1024, 200), 2448 + 400);
    }

    #[test]
    fn fixed_point_converges() {
        // Classic RTA: C = 2, one hp task C_h = 1, T_h = 4, D = 10.
        let r = fixed_point(10, 2, |r| 2 + njobs(r, 4) * 1);
        assert_eq!(r, Rta::Schedulable(3));
    }

    #[test]
    fn fixed_point_fails_past_deadline() {
        // Overloaded: C = 3, hp C_h = 3, T_h = 4 → diverges past D = 8.
        let r = fixed_point(8, 3, |r| 3 + njobs(r, 4) * 3);
        assert_eq!(r, Rta::Unschedulable);
    }

    #[test]
    fn fixed_point_init_beyond_deadline() {
        assert_eq!(fixed_point(5, 6, |r| r), Rta::Unschedulable);
    }

    #[test]
    fn njobs_jitter_carry_in() {
        assert_eq!(njobs_jitter(10, 0, 4), 3);
        assert_eq!(njobs_jitter(10, 3, 4), 4);
    }

    #[test]
    fn jitters() {
        let t = crate::model::Task {
            id: 0,
            name: "x".into(),
            period: ms(100.0),
            deadline: ms(90.0),
            cpu_segments: vec![ms(2.0), ms(2.0)],
            gpu_segments: vec![crate::model::GpuSegment::new(ms(1.0), ms(5.0))],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: crate::model::WaitMode::SelfSuspend,
        };
        assert_eq!(jitter_g(&t, Some(ms(20.0))), ms(15.0));
        assert_eq!(jitter_g(&t, None), ms(85.0)); // D − G^e
        assert_eq!(jitter_c(&t, Some(ms(20.0))), ms(15.0));
        assert_eq!(jitter_c(&t, None), ms(85.0)); // D − (C + G^m)
    }
}
