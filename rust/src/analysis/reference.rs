//! The naive (iterator-chain) response-time analyses, retained verbatim
//! as the executable specification of the prepared kernel
//! ([`crate::analysis::prep`]).
//!
//! These are the pre-kernel implementations of all five families plus
//! the Audsley search: every interference set is re-derived through
//! `TaskSet`'s filter chains inside the fixed-point closure, exactly as
//! the lemmas of §6 read. They are O(n) set derivation per iteration —
//! never call them from a sweep hot path. Their single purpose is the
//! equivalence property in `rust/tests/kernel_equivalence.rs`: the
//! kernel-based family modules must return **bit-identical** responses
//! on every taskset, so any future kernel optimisation is pinned
//! against this spec.

use crate::analysis::gcaps::Options;
use crate::analysis::terms::{
    eps_of, fixed_point, ge_star, gm_star, interleave, jitter_c, jitter_g, njobs,
    njobs_jitter, AnalysisResult, Rta,
};
use crate::analysis::Approach;
use crate::model::{Task, TaskSet, Time};

/// RT ids in decreasing CPU priority — the shared analysis order.
fn analysis_order(ts: &TaskSet) -> Vec<usize> {
    let mut order: Vec<usize> =
        ts.tasks.iter().filter(|t| !t.best_effort).map(|t| t.id).collect();
    order.sort_by(|&a, &b| ts.tasks[b].cpu_prio.cmp(&ts.tasks[a].cpu_prio));
    order
}

// ---------------------------------------------------------------------
// GCAPS (§6.3), reference path
// ---------------------------------------------------------------------

fn jg(t: &Task, resp: &[Option<Time>], opts: &Options) -> Time {
    if opts.use_gpu_prio {
        jitter_g(t, None)
    } else {
        jitter_g(t, resp[t.id])
    }
}

fn jc(t: &Task, resp: &[Option<Time>], opts: &Options) -> Time {
    if opts.use_gpu_prio {
        jitter_c(t, None)
    } else {
        jitter_c(t, resp[t.id])
    }
}

fn hp_gpu_cross<'a>(
    ts: &'a TaskSet,
    i: usize,
    opts: &Options,
) -> Box<dyn Iterator<Item = &'a Task> + 'a> {
    if opts.use_gpu_prio {
        Box::new(ts.hp_gpu_other_core(i).filter(|h| h.uses_gpu()))
    } else {
        Box::new(ts.hp_other_core(i).filter(|h| h.uses_gpu()))
    }
}

/// Naive spec of the fine-grain co-running charge (the kernel's
/// `gcaps::fine_demand`, re-derived from the tasks): a co-runnable hp
/// job's pure G^e deflates to `ceil(fmax_h · G^e_h / (100 − fmax_i))`,
/// the serial ε overhead (`serial − G^e`) rides on top unscaled.
/// Not co-runnable (fmax_h > 100 − fmax_i, which covers every serial
/// pair) keeps the full serial charge.
fn gcaps_fine_demand(me: &Task, h: &Task, serial: Time) -> Time {
    let free = (100 as Time).saturating_sub(me.fmax_pct() as Time);
    if (h.fmax_pct() as Time) > free {
        return serial;
    }
    crate::analysis::terms::ceil_div((h.fmax_pct() as Time).saturating_mul(h.ge()), free)
        .saturating_add(serial.saturating_sub(h.ge()))
        .saturating_add(serial.saturating_sub(h.ge()))
}

fn gcaps_i_dp(
    ts: &TaskSet,
    i: usize,
    r: Time,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
) -> Time {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return 0;
    }
    let mut total = 0;
    for h in ts.hpp(i).filter(|h| h.uses_gpu() && h.gpu == me.gpu) {
        let serial = if busy { ge_star(h, eps_of(ts, h)) } else { h.ge() };
        let demand =
            if opts.fine_grain { gcaps_fine_demand(me, h, serial) } else { serial };
        total = total
            .saturating_add(njobs_jitter(r, jg(h, resp, opts), h.period).saturating_mul(demand));
    }
    for h in hp_gpu_cross(ts, i, opts).filter(|h| h.gpu == me.gpu) {
        let serial = ge_star(h, eps_of(ts, h));
        let demand =
            if opts.fine_grain { gcaps_fine_demand(me, h, serial) } else { serial };
        let n = njobs_jitter(r, jg(h, resp, opts), h.period);
        total = total.saturating_add(n.saturating_mul(demand));
    }
    total
}

fn gcaps_i_id_busy(
    ts: &TaskSet,
    i: usize,
    r: Time,
    resp: &[Option<Time>],
    opts: &Options,
) -> Time {
    let me = &ts.tasks[i];
    if me.uses_gpu() {
        return 0;
    }
    let mut carrier_mask: u64 = 0;
    for h in ts.hpp(i).filter(|h| h.uses_gpu()) {
        carrier_mask |= 1 << (h.gpu & 63);
    }
    if carrier_mask == 0 {
        return 0;
    }
    hp_gpu_cross(ts, i, opts)
        .filter(|h| carrier_mask & (1 << (h.gpu & 63)) != 0)
        .map(|h| {
            let n = njobs_jitter(r, jg(h, resp, opts), h.period);
            n.saturating_mul(ge_star(h, eps_of(ts, h)))
        })
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn gcaps_p_c(
    ts: &TaskSet,
    i: usize,
    r: Time,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
) -> Time {
    let me = &ts.tasks[i];
    let mut total = 0;
    for h in ts.hpp(i) {
        total = total.saturating_add(if busy {
            let mut demand = h.c().saturating_add(h.gm());
            let charged_by_lemma10 = me.uses_gpu() && h.gpu == me.gpu;
            if h.uses_gpu() && !charged_by_lemma10 && !opts.paper_exact_lemma12 {
                demand = demand.saturating_add(ge_star(h, eps_of(ts, h)));
            }
            if h.uses_gpu() {
                njobs_jitter(r, jc(h, resp, opts), h.period).saturating_mul(demand)
            } else {
                njobs(r, h.period).saturating_mul(demand)
            }
        } else if h.uses_gpu() {
            njobs_jitter(r, jc(h, resp, opts), h.period)
                .saturating_mul(h.c().saturating_add(gm_star(h, eps_of(ts, h))))
        } else {
            njobs(r, h.period).saturating_mul(h.c())
        });
    }
    total
}

/// Reference GCAPS response time (Eq. 1 with the §6.3 terms).
pub fn gcaps_response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    opts: &Options,
) -> Rta {
    let me = &ts.tasks[i];
    let eps = eps_of(ts, me);
    let own = me
        .c()
        .saturating_add(me.g())
        .saturating_add(eps.saturating_mul(2).saturating_mul(me.eta_g() as Time));
    let lp_gpu = |t: &&Task| {
        t.id != me.id && t.uses_gpu() && (t.best_effort || t.cpu_prio < me.cpu_prio)
    };
    let blocking = if me.uses_gpu() {
        let same_engine = if ts.tasks.iter().filter(lp_gpu).any(|t| t.gpu == me.gpu) {
            eps
        } else {
            0
        };
        let cross_alpha = ts
            .tasks
            .iter()
            .filter(lp_gpu)
            .filter(|t| t.core == me.core && t.gpu != me.gpu)
            .map(|t| {
                let c = &ts.platform.gpus[t.gpu];
                c.epsilon.saturating_sub(c.theta)
            })
            .max()
            .unwrap_or(0);
        (me.eta_g() as Time).saturating_add(1).saturating_mul(same_engine.max(cross_alpha))
    } else {
        ts.tasks.iter().filter(lp_gpu).map(|t| eps_of(ts, t)).max().unwrap_or(0)
    };
    fixed_point(me.deadline, own.saturating_add(blocking), |r| {
        own.saturating_add(blocking)
            .saturating_add(gcaps_p_c(ts, i, r, busy, resp, opts))
            .saturating_add(gcaps_i_dp(ts, i, r, busy, resp, opts))
            .saturating_add(if busy { gcaps_i_id_busy(ts, i, r, resp, opts) } else { 0 })
    })
}

/// Reference GCAPS analysis over every RT task.
pub fn gcaps_analyze(ts: &TaskSet, busy: bool, opts: &Options) -> AnalysisResult {
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for i in analysis_order(ts) {
        resp[i] = gcaps_response_time(ts, i, busy, &resp, opts).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

// ---------------------------------------------------------------------
// Default-driver TSG round-robin (§6.2), reference path
// ---------------------------------------------------------------------

fn rr_i_ie(ts: &TaskSet, i: usize) -> Time {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return 0;
    }
    let nu = ts.sharing_gpu(i).count();
    let ctx = ts.gpu_ctx(i);
    me.gpu_segments
        .iter()
        .map(|g| interleave(nu, g.exec, ctx.tsg_slice, ctx.theta))
        .sum()
}

fn rr_i_id_busy(ts: &TaskSet, i: usize, r: Time, resp: &[Option<Time>]) -> Time {
    let mut total = 0;
    let hpp_ids: Vec<usize> = ts.hpp(i).map(|t| t.id).collect();
    let mut nu_base = vec![0usize; ts.platform.num_gpus()];
    for k in ts.tasks.iter().filter(|k| k.uses_gpu() && !hpp_ids.contains(&k.id)) {
        nu_base[k.gpu] += 1;
    }
    for h in ts.hpp(i).filter(|h| h.uses_gpu()) {
        let nu = nu_base[h.gpu] + 1;
        let ctx = ts.platform.gpus[h.gpu];
        let per_job: Time = h
            .gpu_segments
            .iter()
            .map(|g| interleave(nu, g.exec, ctx.tsg_slice, ctx.theta))
            .sum();
        let n = njobs_jitter(r, jitter_g(h, resp[h.id]), h.period);
        total = total.saturating_add(n.saturating_mul(per_job));
    }
    total
}

fn rr_p_c(ts: &TaskSet, i: usize, r: Time, resp: &[Option<Time>]) -> Time {
    ts.hpp(i)
        .map(|h: &Task| {
            let demand = h.c().saturating_add(h.gm());
            let n = if h.uses_gpu() {
                njobs_jitter(r, jitter_c(h, resp[h.id]), h.period)
            } else {
                njobs(r, h.period)
            };
            n.saturating_mul(demand)
        })
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

/// Reference default-driver response time (Eq. 1 with the §6.2 terms).
pub fn rr_response_time(ts: &TaskSet, i: usize, busy: bool, resp: &[Option<Time>]) -> Rta {
    let me = &ts.tasks[i];
    let own = me.c().saturating_add(me.g());
    let iie = rr_i_ie(ts, i);
    fixed_point(me.deadline, own.saturating_add(iie), |r| {
        let idle = if busy { rr_i_id_busy(ts, i, r, resp) } else { 0 };
        own.saturating_add(iie).saturating_add(idle).saturating_add(rr_p_c(ts, i, r, resp))
    })
}

/// Reference default-driver analysis.
pub fn rr_analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for i in analysis_order(ts) {
        resp[i] = rr_response_time(ts, i, busy, &resp).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

// ---------------------------------------------------------------------
// MPCP baseline, reference path
// ---------------------------------------------------------------------

fn mpcp_request_blocking(ts: &TaskSet, i: usize) -> Option<Time> {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return Some(0);
    }
    let lp_max: Time = ts
        .sharing_gpu(i)
        .filter(|t| t.best_effort || t.cpu_prio < me.cpu_prio)
        .map(|t| t.max_gpu_segment())
        .max()
        .unwrap_or(0);
    let hp: Vec<&Task> = ts
        .sharing_gpu(i)
        .filter(|t| !t.best_effort && t.cpu_prio > me.cpu_prio)
        .collect();
    let mut w = lp_max;
    for _ in 0..10_000 {
        let next = lp_max.saturating_add(
            hp.iter()
                .map(|h| {
                    let gcs_total: Time = h.gpu_segments.iter().map(|g| g.total()).sum();
                    njobs(w, h.period).saturating_add(1).saturating_mul(gcs_total)
                })
                .fold(0, |acc: Time, x| acc.saturating_add(x)),
        );
        if next == w {
            return Some(w);
        }
        if next > me.deadline {
            return None;
        }
        w = next;
    }
    None
}

fn mpcp_boost_blocking(ts: &TaskSet, i: usize, r: Time) -> Time {
    let me = &ts.tasks[i];
    ts.tasks
        .iter()
        .filter(|t| {
            t.id != me.id
                && t.core == me.core
                && t.uses_gpu()
                && (t.best_effort || t.cpu_prio < me.cpu_prio)
        })
        .map(|t| njobs_jitter(r, t.deadline, t.period).saturating_mul(t.gm()))
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn mpcp_p_c(
    ts: &TaskSet,
    i: usize,
    r: Time,
    busy: bool,
    resp: &[Option<Time>],
    w_h: &[Time],
) -> Time {
    ts.hpp(i)
        .map(|h| {
            let n = if h.uses_gpu() {
                njobs_jitter(r, jitter_c(h, resp[h.id]), h.period)
            } else {
                njobs(r, h.period)
            };
            if busy {
                n.saturating_mul(
                    h.c()
                        .saturating_add(h.g())
                        .saturating_add(w_h[h.id].saturating_mul(h.eta_g() as Time)),
                )
            } else {
                n.saturating_mul(h.c().saturating_add(h.gm()))
            }
        })
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn mpcp_response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    w_all: &[Time],
) -> Rta {
    let me = &ts.tasks[i];
    let remote = w_all[i].saturating_mul(me.eta_g() as Time);
    let own = me.c().saturating_add(me.g()).saturating_add(remote);
    fixed_point(me.deadline, own, |r| {
        own.saturating_add(mpcp_boost_blocking(ts, i, r))
            .saturating_add(mpcp_p_c(ts, i, r, busy, resp, w_all))
    })
}

/// Reference MPCP analysis.
pub fn mpcp_analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let n = ts.tasks.len();
    let mut w_all = vec![0; n];
    let mut blocked_diverged = vec![false; n];
    for t in ts.tasks.iter().filter(|t| !t.best_effort) {
        match mpcp_request_blocking(ts, t.id) {
            Some(w) => w_all[t.id] = w,
            None => blocked_diverged[t.id] = true,
        }
    }
    let mut resp: Vec<Option<Time>> = vec![None; n];
    for i in analysis_order(ts) {
        if blocked_diverged[i] {
            continue;
        }
        if busy && ts.hpp(i).any(|h| blocked_diverged[h.id]) {
            continue;
        }
        resp[i] = mpcp_response_time(ts, i, busy, &resp, &w_all).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

// ---------------------------------------------------------------------
// FMLP+ baseline, reference path
// ---------------------------------------------------------------------

fn fmlp_request_blocking(ts: &TaskSet, i: usize) -> Time {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return 0;
    }
    ts.sharing_gpu(i).map(|t| t.max_gpu_segment()).sum()
}

fn fmlp_boost_blocking(ts: &TaskSet, i: usize, r: Time) -> Time {
    let me = &ts.tasks[i];
    ts.tasks
        .iter()
        .filter(|t| {
            t.id != me.id
                && t.core == me.core
                && t.uses_gpu()
                && (t.best_effort || t.cpu_prio < me.cpu_prio)
        })
        .map(|t| njobs_jitter(r, t.deadline, t.period).saturating_mul(t.gm()))
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn fmlp_p_c(ts: &TaskSet, i: usize, r: Time, busy: bool, resp: &[Option<Time>]) -> Time {
    ts.hpp(i)
        .map(|h| {
            let n = if h.uses_gpu() {
                njobs_jitter(r, jitter_c(h, resp[h.id]), h.period)
            } else {
                njobs(r, h.period)
            };
            if busy {
                let per_req = fmlp_request_blocking(ts, h.id);
                n.saturating_mul(
                    h.c()
                        .saturating_add(h.g())
                        .saturating_add(per_req.saturating_mul(h.eta_g() as Time)),
                )
            } else {
                n.saturating_mul(h.c().saturating_add(h.gm()))
            }
        })
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn fmlp_response_time(ts: &TaskSet, i: usize, busy: bool, resp: &[Option<Time>]) -> Rta {
    let me = &ts.tasks[i];
    let remote = fmlp_request_blocking(ts, i).saturating_mul(me.eta_g() as Time);
    let own = me.c().saturating_add(me.g()).saturating_add(remote);
    fixed_point(me.deadline, own, |r| {
        own.saturating_add(fmlp_boost_blocking(ts, i, r))
            .saturating_add(fmlp_p_c(ts, i, r, busy, resp))
    })
}

/// Reference FMLP+ analysis.
pub fn fmlp_analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let mut resp: Vec<Option<Time>> = vec![None; ts.tasks.len()];
    for i in analysis_order(ts) {
        resp[i] = fmlp_response_time(ts, i, busy, &resp).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

// ---------------------------------------------------------------------
// Server-based GPU access baseline (Kim et al.), reference path
// ---------------------------------------------------------------------

/// S_j = Σ gcs + 2ε·η: the server's service demand for one job of τ_j.
fn server_service(ts: &TaskSet, j: &Task) -> Time {
    let gcs_total: Time = j.gpu_segments.iter().map(|g| g.total()).sum();
    gcs_total.saturating_add(eps_of(ts, j).saturating_mul(2).saturating_mul(j.eta_g() as Time))
}

/// Cumulative request-handling window B_i (the improved bound: hp
/// server demand counted once over the whole window, not per request).
fn server_request_window(ts: &TaskSet, i: usize) -> Option<Time> {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return Some(0);
    }
    let lp_max: Time = ts
        .sharing_gpu(i)
        .filter(|t| t.best_effort || t.cpu_prio < me.cpu_prio)
        .map(|t| t.max_gpu_segment().saturating_add(eps_of(ts, t).saturating_mul(2)))
        .max()
        .unwrap_or(0);
    let hp: Vec<&Task> = ts
        .sharing_gpu(i)
        .filter(|t| !t.best_effort && t.cpu_prio > me.cpu_prio)
        .collect();
    let own = server_service(ts, me).saturating_add((me.eta_g() as Time).saturating_mul(lp_max));
    let mut b = own;
    for _ in 0..10_000 {
        let next = own.saturating_add(
            hp.iter()
                .map(|h| njobs(b, h.period).saturating_add(1).saturating_mul(server_service(ts, h)))
                .fold(0, |acc: Time, x| acc.saturating_add(x)),
        );
        if next == b {
            return Some(b);
        }
        if next > me.deadline {
            return None;
        }
        b = next;
    }
    None
}

fn server_p_c(ts: &TaskSet, i: usize, r: Time, resp: &[Option<Time>]) -> Time {
    ts.hpp(i)
        .map(|h| {
            let n = if h.uses_gpu() {
                // GPU time runs on the server, so hp CPU demand is the
                // plain C_h with suspension jitter J_h = R_h − C_h.
                let jit = resp[h.id].unwrap_or(h.deadline).saturating_sub(h.c());
                njobs_jitter(r, jit, h.period)
            } else {
                njobs(r, h.period)
            };
            n.saturating_mul(h.c())
        })
        .fold(0, |acc: Time, x| acc.saturating_add(x))
}

fn server_response_time(
    ts: &TaskSet,
    i: usize,
    resp: &[Option<Time>],
    b_all: &[Time],
) -> Rta {
    let me = &ts.tasks[i];
    let own = me.c().saturating_add(b_all[i]);
    fixed_point(me.deadline, own, |r| own.saturating_add(server_p_c(ts, i, r, resp)))
}

/// Reference server-based analysis (suspension-only by construction:
/// requesters self-suspend while the server executes on their behalf;
/// no boost blocking — the server has its own core).
pub fn server_analyze(ts: &TaskSet) -> AnalysisResult {
    let n = ts.tasks.len();
    let mut b_all = vec![0; n];
    let mut blocked_diverged = vec![false; n];
    for t in ts.tasks.iter().filter(|t| !t.best_effort) {
        match server_request_window(ts, t.id) {
            Some(b) => b_all[t.id] = b,
            None => blocked_diverged[t.id] = true,
        }
    }
    let mut resp: Vec<Option<Time>> = vec![None; n];
    for i in analysis_order(ts) {
        if blocked_diverged[i] {
            continue;
        }
        resp[i] = server_response_time(ts, i, &resp, &b_all).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

// ---------------------------------------------------------------------
// Dispatch + the Fig. 8 GCAPS procedure, reference path
// ---------------------------------------------------------------------

/// Reference Audsley search (§5.3 / §6.4), using the reference GCAPS
/// response-time test per candidate.
pub fn assign_gpu_priorities(ts: &TaskSet, busy: bool) -> Option<(TaskSet, Vec<u32>)> {
    let mut work = ts.clone();
    let candidates: Vec<usize> = work
        .tasks
        .iter()
        .filter(|t| !t.best_effort && t.uses_gpu())
        .map(|t| t.id)
        .collect();
    if candidates.is_empty() {
        return None;
    }
    let mut levels: Vec<u32> = candidates.iter().map(|&i| ts.tasks[i].cpu_prio).collect();
    levels.sort_unstable();

    let mut unassigned: Vec<usize> = candidates.clone();
    const UNASSIGNED: u32 = u32::MAX;
    for &i in &unassigned {
        work.tasks[i].gpu_prio = UNASSIGNED;
    }

    let opts = Options { use_gpu_prio: true, ..Default::default() };
    let no_resp: Vec<Option<Time>> = vec![None; work.tasks.len()];

    for &level in &levels {
        let mut order = unassigned.clone();
        order.sort_by_key(|&i| work.tasks[i].cpu_prio);
        let mut placed = None;
        for &cand in &order {
            let core = work.tasks[cand].core;
            let gpu = work.tasks[cand].gpu;
            let violates = unassigned.iter().any(|&d| {
                d != cand
                    && work.tasks[d].core == core
                    && work.tasks[d].gpu == gpu
                    && work.tasks[d].cpu_prio < work.tasks[cand].cpu_prio
            });
            if violates {
                continue;
            }
            work.tasks[cand].gpu_prio = level;
            let rta = gcaps_response_time(&work, cand, busy, &no_resp, &opts);
            if rta.ok() {
                placed = Some(cand);
                break;
            }
            work.tasks[cand].gpu_prio = UNASSIGNED;
        }
        match placed {
            Some(cand) => unassigned.retain(|&i| i != cand),
            None => return None,
        }
    }
    debug_assert!(unassigned.is_empty());

    let res = gcaps_analyze(&work, busy, &opts);
    if !res.schedulable {
        return None;
    }
    let prios = work.tasks.iter().map(|t| t.gpu_prio).collect();
    Some((work, prios))
}

/// Reference per-approach analysis dispatch.
pub fn analyze(ts: &TaskSet, approach: Approach) -> AnalysisResult {
    match approach {
        Approach::GcapsBusy => gcaps_analyze(ts, true, &Options::default()),
        Approach::GcapsSuspend => gcaps_analyze(ts, false, &Options::default()),
        Approach::TsgRrBusy => rr_analyze(ts, true),
        Approach::TsgRrSuspend => rr_analyze(ts, false),
        Approach::MpcpBusy => mpcp_analyze(ts, true),
        Approach::MpcpSuspend => mpcp_analyze(ts, false),
        Approach::FmlpBusy => fmlp_analyze(ts, true),
        Approach::FmlpSuspend => fmlp_analyze(ts, false),
        Approach::ServerSuspend => server_analyze(ts),
    }
}

/// Reference §7.1.1 GCAPS procedure: default priorities first, Audsley
/// retry on failure.
pub fn analyze_with_gpu_prio(ts: &TaskSet, busy: bool) -> (AnalysisResult, Option<Vec<u32>>) {
    let base = gcaps_analyze(ts, busy, &Options::default());
    if base.schedulable {
        return (base, None);
    }
    match assign_gpu_priorities(ts, busy) {
        Some((assigned_ts, prios)) => {
            let opts = Options { use_gpu_prio: true, ..Default::default() };
            let res = gcaps_analyze(&assigned_ts, busy, &opts);
            (res, Some(prios))
        }
        None => (base, None),
    }
}

/// Reference full-procedure schedulability (what Fig. 8 cells compute).
pub fn approach_schedulable(ts: &TaskSet, approach: Approach) -> bool {
    match approach {
        Approach::GcapsBusy => analyze_with_gpu_prio(ts, true).0.schedulable,
        Approach::GcapsSuspend => analyze_with_gpu_prio(ts, false).0.schedulable,
        a => analyze(ts, a).schedulable,
    }
}
