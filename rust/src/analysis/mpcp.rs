//! MPCP baseline: the GPU modelled as a single mutually-exclusive
//! resource guarded by the Multiprocessor Priority Ceiling Protocol,
//! with suspension-aware and busy-waiting response-time bounds in the
//! style of Patel et al. (RTAS 2018, ref [20] — "Analytical Enhancements
//! and Practical Insights for MPCP with Self-Suspensions") and
//! Lakshmanan et al.'s original multiprocessor formulation.
//!
//! Model mapping (paper §3): a GPU segment = one global critical section
//! (gcs) of length G^m + G^e, executed non-preemptively w.r.t. the GPU
//! and at boosted priority on the CPU while holding the lock. Requests
//! queue in task-priority order; an executing gcs is never preempted.
//!
//! Per-request remote blocking (priority-ordered queue, iterative):
//!
//! ```text
//! W_i <- max_{pi_l < pi_i, lp requester} gcs_max_l
//!       + sum_{pi_h > pi_i} (ceil(W_i / T_h) + 1) * gcs_total_h
//! ```
//!
//! Best-effort tasks count as lower-priority requesters (they hold the
//! GPU non-preemptively once granted — exactly why Fig. 8f punishes the
//! sync-based approaches). Total blocking B_i = Σ_j W_{i,j} over η^g_i
//! requests; a CPU-only task still incurs one boost-blocking term from
//! lower-priority gcs CPU portions executed at boosted priority.

use crate::analysis::terms::{fixed_point, jitter_c, njobs, njobs_jitter, AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{Task, TaskSet, Time, WaitMode};

/// Per-request remote blocking W_i for task i (same bound reused for
/// each of its η^g requests). Each GPU engine is its own lock, so only
/// requesters sharing τ_i's engine queue against it. Returns None if
/// the iteration diverges past the deadline (treated as unschedulable
/// upstream).
fn request_blocking(ts: &TaskSet, i: usize) -> Option<Time> {
    let me = &ts.tasks[i];
    if !me.uses_gpu() {
        return Some(0);
    }
    // Longest single gcs among same-engine lower-priority (or
    // best-effort) requesters.
    let lp_max: Time = ts
        .sharing_gpu(i)
        .filter(|t| t.best_effort || t.cpu_prio < me.cpu_prio)
        .map(|t| t.max_gpu_segment())
        .max()
        .unwrap_or(0);
    let hp: Vec<&Task> = ts
        .sharing_gpu(i)
        .filter(|t| !t.best_effort && t.cpu_prio > me.cpu_prio)
        .collect();
    // Iterate W = lp_max + Σ_h (ceil(W/T_h)+1) · Σ_j gcs_{h,j}.
    let mut w = lp_max;
    for _ in 0..10_000 {
        let next = lp_max
            + hp.iter()
                .map(|h| {
                    let gcs_total: Time = h.gpu_segments.iter().map(|g| g.total()).sum();
                    (njobs(w, h.period) + 1) * gcs_total
                })
                .sum::<Time>();
        if next == w {
            return Some(w);
        }
        if next > me.deadline {
            return None;
        }
        w = next;
    }
    None
}

/// Boost blocking: lower-priority same-core lock holders execute the
/// CPU-visible portion of their critical sections (G^m — the launch
/// work; during G^e the holder suspends or spins at its own, lower
/// priority) at *boosted* priority, preempting τ_i. A grant can land
/// whenever the GPU frees up, even mid-CPU-segment of τ_i, so every job
/// of every lower-priority GPU task in the window can boost once; the
/// classic "(η_i + 1) issue points" bound undercounts this and is
/// undercut by the device model, so we charge per lower-priority job
/// (with D-jitter for carry-in).
fn boost_blocking(ts: &TaskSet, i: usize, r: Time) -> Time {
    let me = &ts.tasks[i];
    ts.tasks
        .iter()
        .filter(|t| {
            t.id != me.id
                && t.core == me.core
                && t.uses_gpu()
                && (t.best_effort || t.cpu_prio < me.cpu_prio)
        })
        .map(|t| njobs_jitter(r, t.deadline, t.period) * t.gm())
        .sum()
}

/// CPU preemption from same-core higher-priority tasks. Under
/// suspension, hp CPU demand per job is C_h + G^m_h with jitter; under
/// busy-waiting the waiter occupies the CPU for its blocking + gcs too.
fn p_c(ts: &TaskSet, i: usize, r: Time, busy: bool, resp: &[Option<Time>], w_h: &[Time]) -> Time {
    ts.hpp(i)
        .map(|h| {
            let n = if h.uses_gpu() {
                // Carry-in jitter: GPU interference (and suspension) can
                // defer an hp job's CPU occupancy past its release.
                njobs_jitter(r, jitter_c(h, resp[h.id]), h.period)
            } else {
                njobs(r, h.period) // CPU-only hp: exact count
            };
            if busy {
                n * (h.c() + h.g() + w_h[h.id] * h.eta_g() as Time)
            } else {
                n * (h.c() + h.gm())
            }
        })
        .sum()
}

/// Response time of task i under MPCP.
pub fn response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    w_all: &[Time],
) -> Rta {
    let me = &ts.tasks[i];
    let remote = w_all[i] * me.eta_g() as Time;
    let own = me.c() + me.g() + remote;
    fixed_point(me.deadline, own, |r| {
        own + boost_blocking(ts, i, r) + p_c(ts, i, r, busy, resp, w_all)
    })
}

/// Analyse all RT tasks.
pub fn analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let n = ts.tasks.len();
    let mut w_all = vec![0; n];
    let mut blocked_diverged = vec![false; n];
    for t in ts.tasks.iter().filter(|t| !t.best_effort) {
        match request_blocking(ts, t.id) {
            Some(w) => w_all[t.id] = w,
            None => blocked_diverged[t.id] = true,
        }
    }
    let mut resp: Vec<Option<Time>> = vec![None; n];
    let mut order: Vec<usize> =
        ts.tasks.iter().filter(|t| !t.best_effort).map(|t| t.id).collect();
    order.sort_by(|&a, &b| ts.tasks[b].cpu_prio.cmp(&ts.tasks[a].cpu_prio));
    for i in order {
        if blocked_diverged[i] {
            continue;
        }
        // Busy-waiting: a same-core higher-priority task whose remote
        // blocking diverged spins unboundedly on the CPU; no valid bound
        // exists for anything below it.
        if busy && ts.hpp(i).any(|h| blocked_diverged[h.id]) {
            continue;
        }
        resp[i] = response_time(ts, i, busy, &resp, &w_all).time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// [`Analysis`] implementation: the MPCP synchronization baseline.
#[derive(Debug, Clone, Copy)]
pub struct MpcpAnalysis {
    pub busy: bool,
}

impl Analysis for MpcpAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "mpcp_busy" } else { "mpcp_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

    fn platform() -> Platform {
        Platform { num_cpus: 2, ..Default::default() }
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn cross_engine_gcs_does_not_block() {
        // The MPCP structural weakness vanishes across engines: the hp
        // task no longer waits for the lp task's 60 ms critical section
        // when they lock different GPUs.
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        lo.gpu = 1;
        let p = Platform { num_cpus: 2, ..Default::default() }.with_num_gpus(2);
        let ts = TaskSet::new(vec![hi, lo], p);
        let res = analyze(&ts, false);
        assert_eq!(res.response[0], Some(ms(8.0))); // isolated demand
    }

    #[test]
    fn single_task_no_blocking() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts, false);
        assert_eq!(res.response[0], Some(ms(8.0)));
    }

    #[test]
    fn high_priority_blocked_by_lower_gcs() {
        // MPCP's structural weakness vs GCAPS: the hp task waits for the
        // lp task's whole 60 ms critical section.
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false);
        let r0 = res.response[0].unwrap();
        assert!(r0 >= ms(8.0 + 62.0), "r0 = {r0}"); // + lp gcs 62 ms
    }

    #[test]
    fn hp_requests_preempt_queue() {
        // The lower-priority GPU task waits for every hp request in its
        // window (priority-ordered queue).
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 60.0);
        let lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false);
        let r1 = res.response[1].unwrap();
        assert!(r1 >= ms(8.0) + 2 * ms(21.0), "r1 = {r1}");
    }

    #[test]
    fn best_effort_blocks_like_lp() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 300.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = analyze(&ts, false);
        // The 82 ms best-effort gcs blocks the RT task (cf. Fig. 8f:
        // sync-based approaches degrade with best-effort load).
        let r0 = res.response[0].unwrap();
        assert!(r0 >= ms(8.0 + 82.0), "r0 = {r0}");
    }

    #[test]
    fn busy_mode_inflates_hp_cpu_demand() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 100.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rb = analyze(&ts, true).response[1];
        let rs = analyze(&ts, false).response[1].unwrap();
        // busy: hp occupies CPU for C + G = 33 ms per job; R_1 ≥ 43.
        match rb {
            Some(rb) => assert!(rb >= rs + ms(25.0)),
            None => {} // unschedulable is acceptable: even stronger penalty
        }
    }

    #[test]
    fn cpu_only_task_gets_boost_blocking() {
        let hp = Task::cpu_only(0, 0, 2, ms(5.0), ms(50.0));
        let lp = gpu_task(1, 0, 1, 2.0, 3.0, 10.0, 100.0);
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = analyze(&ts, false);
        // Boosted G^m (3 ms) of the lp task blocks the CPU-only hp task;
        // with D-jitter carry-in, up to two lp jobs land in the window.
        assert_eq!(res.response[0], Some(ms(5.0 + 2.0 * 3.0)));
    }
}
