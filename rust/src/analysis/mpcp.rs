//! MPCP baseline: the GPU modelled as a single mutually-exclusive
//! resource guarded by the Multiprocessor Priority Ceiling Protocol,
//! with suspension-aware and busy-waiting response-time bounds in the
//! style of Patel et al. (RTAS 2018, ref [20] — "Analytical Enhancements
//! and Practical Insights for MPCP with Self-Suspensions") and
//! Lakshmanan et al.'s original multiprocessor formulation.
//!
//! Model mapping (paper §3): a GPU segment = one global critical section
//! (gcs) of length G^m + G^e, executed non-preemptively w.r.t. the GPU
//! and at boosted priority on the CPU while holding the lock. Requests
//! queue in task-priority order; an executing gcs is never preempted.
//!
//! Per-request remote blocking (priority-ordered queue, iterative):
//!
//! ```text
//! W_i <- max_{pi_l < pi_i, lp requester} gcs_max_l
//!       + sum_{pi_h > pi_i} (ceil(W_i / T_h) + 1) * gcs_total_h
//! ```
//!
//! Best-effort tasks count as lower-priority requesters (they hold the
//! GPU non-preemptively once granted — exactly why Fig. 8f punishes the
//! sync-based approaches). Total blocking B_i = Σ_j W_{i,j} over η^g_i
//! requests; a CPU-only task still incurs one boost-blocking term from
//! lower-priority gcs CPU portions executed at boosted priority.
//!
//! Implementation: the same-engine requester sets and per-task gcs
//! bounds come precomputed from [`Prepared`]; both the W iteration and
//! the response fixed point run over flat `Term` slices (zero set
//! derivation per iteration). The original iterator-chain path lives in
//! [`crate::analysis::reference`].

use crate::analysis::prep::{eval, run_fixed_point, Prepared, Scratch};
use crate::analysis::terms::{AnalysisResult, Rta};
use crate::analysis::Analysis;
use crate::model::{TaskSet, Time, WaitMode};

/// Per-request remote blocking W_i for task i (same bound reused for
/// each of its η^g requests). Each GPU engine is its own lock, so only
/// requesters sharing τ_i's engine queue against it. Returns None if
/// the iteration diverges past the deadline (treated as unschedulable
/// upstream).
fn request_blocking(prep: &Prepared, i: usize, scratch: &mut Scratch) -> Option<Time> {
    let me = prep.t[i];
    if !me.uses_gpu {
        return Some(0);
    }
    // Longest single gcs among same-engine lower-priority (or
    // best-effort) requesters; higher-priority requesters' gcs totals
    // become the W iteration's terms.
    scratch.clear();
    let mut lp_max: Time = 0;
    let mut hp_const: Time = 0; // the "+1" part: Σ_h gcs_total_h
    for &h32 in prep.sharing.get(i) {
        let p = &prep.t[h32 as usize];
        if p.best_effort || p.cpu_prio < me.cpu_prio {
            lp_max = lp_max.max(p.max_gcs);
        } else if p.cpu_prio > me.cpu_prio {
            // (Best-effort sharers were all consumed by the lp branch.)
            hp_const = hp_const.saturating_add(p.gcs_total);
            scratch.push(0, p.period, p.gcs_total);
        }
    }
    // Iterate W = lp_max + Σ_h (ceil(W/T_h)+1) · gcs_total_h
    // (saturating so a pathological gcs pins at MAX and fails the
    // deadline check instead of wrapping).
    let base = lp_max.saturating_add(hp_const);
    let mut w = lp_max;
    for _ in 0..10_000 {
        let next = base.saturating_add(eval(w, &scratch.terms));
        if next == w {
            return Some(w);
        }
        if next > me.deadline {
            return None;
        }
        w = next;
    }
    None
}

/// Lower boost blocking + CPU preemption for task `i` into
/// `scratch.terms`. Boost: every job of every same-core lower-priority
/// (or best-effort) GPU task can execute its G^m at boosted priority
/// (D-jittered carry-in; see the reference module for why the classic
/// issue-point bound undercounts). P^C: suspension-aware hp demand,
/// inflated under busy-waiting by the waiter's blocking + gcs time.
fn build_terms(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    w_all: &[Time],
    scratch: &mut Scratch,
) {
    scratch.clear();
    let me = prep.t[i];
    for (j, p) in prep.t.iter().enumerate() {
        if j != i
            && p.core == me.core
            && p.uses_gpu
            && (p.best_effort || p.cpu_prio < me.cpu_prio)
        {
            scratch.push(p.deadline, p.period, p.gm);
        }
    }
    for &h32 in prep.hpp.get(i) {
        let h = h32 as usize;
        let p = &prep.t[h];
        let jit = if p.uses_gpu { prep.jitter_c(h, resp) } else { 0 };
        let demand = if busy {
            p.c.saturating_add(p.g).saturating_add(w_all[h].saturating_mul(p.eta_g))
        } else {
            p.c_gm
        };
        scratch.push(jit, p.period, demand);
    }
}

/// Response time of task i under MPCP, over a prebuilt kernel.
pub fn response_time_prepared(
    prep: &Prepared,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    w_all: &[Time],
    scratch: &mut Scratch,
) -> Rta {
    let me = prep.t[i];
    let remote = w_all[i].saturating_mul(me.eta_g);
    let own = me.c.saturating_add(me.g).saturating_add(remote);
    build_terms(prep, i, busy, resp, w_all, scratch);
    run_fixed_point(me.deadline, own, &scratch.terms)
}

/// Response time of task i under MPCP (compatibility entry point —
/// builds a throwaway kernel; `w_all` as computed by [`analyze`]).
pub fn response_time(
    ts: &TaskSet,
    i: usize,
    busy: bool,
    resp: &[Option<Time>],
    w_all: &[Time],
) -> Rta {
    let prep = Prepared::new(ts);
    let mut scratch = Scratch::default();
    response_time_prepared(&prep, i, busy, resp, w_all, &mut scratch)
}

/// Analyse all RT tasks over an existing kernel.
pub fn analyze_prepared(ts: &TaskSet, prep: &Prepared, busy: bool) -> AnalysisResult {
    let n = ts.tasks.len();
    let mut scratch = Scratch::default();
    let mut w_all = vec![0; n];
    let mut blocked_diverged = vec![false; n];
    for j in 0..n {
        if prep.t[j].best_effort {
            continue;
        }
        match request_blocking(prep, j, &mut scratch) {
            Some(w) => w_all[j] = w,
            None => blocked_diverged[j] = true,
        }
    }
    let mut resp: Vec<Option<Time>> = vec![None; n];
    for &i in &prep.order {
        if blocked_diverged[i] {
            continue;
        }
        // Busy-waiting: a same-core higher-priority task whose remote
        // blocking diverged spins unboundedly on the CPU; no valid bound
        // exists for anything below it.
        if busy && prep.hpp.get(i).iter().any(|&h| blocked_diverged[h as usize]) {
            continue;
        }
        let r = response_time_prepared(prep, i, busy, &resp, &w_all, &mut scratch);
        resp[i] = r.time();
    }
    AnalysisResult::from_responses(&ts.tasks, resp)
}

/// Analyse all RT tasks.
pub fn analyze(ts: &TaskSet, busy: bool) -> AnalysisResult {
    let prep = Prepared::new(ts);
    analyze_prepared(ts, &prep, busy)
}

/// [`Analysis`] implementation: the MPCP synchronization baseline.
#[derive(Debug, Clone, Copy)]
pub struct MpcpAnalysis {
    pub busy: bool,
}

impl Analysis for MpcpAnalysis {
    fn label(&self) -> &'static str {
        if self.busy { "mpcp_busy" } else { "mpcp_suspend" }
    }

    fn wait_mode(&self) -> WaitMode {
        if self.busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend }
    }

    fn analyze(&self, ts: &TaskSet) -> AnalysisResult {
        analyze(ts, self.busy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};

    fn platform() -> Platform {
        Platform { num_cpus: 2, ..Default::default() }
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn cross_engine_gcs_does_not_block() {
        // The MPCP structural weakness vanishes across engines: the hp
        // task no longer waits for the lp task's 60 ms critical section
        // when they lock different GPUs.
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        lo.gpu = 1;
        let p = Platform { num_cpus: 2, ..Default::default() }.with_num_gpus(2);
        let ts = TaskSet::new(vec![hi, lo], p);
        let res = analyze(&ts, false);
        assert_eq!(res.response[0], Some(ms(8.0))); // isolated demand
    }

    #[test]
    fn single_task_no_blocking() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = analyze(&ts, false);
        assert_eq!(res.response[0], Some(ms(8.0)));
    }

    #[test]
    fn high_priority_blocked_by_lower_gcs() {
        // MPCP's structural weakness vs GCAPS: the hp task waits for the
        // lp task's whole 60 ms critical section.
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let lo = gpu_task(1, 1, 1, 10.0, 2.0, 60.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false);
        let r0 = res.response[0].unwrap();
        assert!(r0 >= ms(8.0 + 62.0), "r0 = {r0}"); // + lp gcs 62 ms
    }

    #[test]
    fn hp_requests_preempt_queue() {
        // The lower-priority GPU task waits for every hp request in its
        // window (priority-ordered queue).
        let hi = gpu_task(0, 0, 2, 2.0, 1.0, 20.0, 60.0);
        let lo = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 200.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = analyze(&ts, false);
        let r1 = res.response[1].unwrap();
        assert!(r1 >= ms(8.0) + 2 * ms(21.0), "r1 = {r1}");
    }

    #[test]
    fn best_effort_blocks_like_lp() {
        let rt = gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0);
        let mut be = gpu_task(1, 1, 0, 10.0, 2.0, 80.0, 300.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = analyze(&ts, false);
        // The 82 ms best-effort gcs blocks the RT task (cf. Fig. 8f:
        // sync-based approaches degrade with best-effort load).
        let r0 = res.response[0].unwrap();
        assert!(r0 >= ms(8.0 + 82.0), "r0 = {r0}");
    }

    #[test]
    fn busy_mode_inflates_hp_cpu_demand() {
        let hp = gpu_task(0, 0, 2, 2.0, 1.0, 30.0, 100.0);
        let lp = Task::cpu_only(1, 0, 1, ms(10.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let rb = analyze(&ts, true).response[1];
        let rs = analyze(&ts, false).response[1].unwrap();
        // busy: hp occupies CPU for C + G = 33 ms per job; R_1 ≥ 43.
        match rb {
            Some(rb) => assert!(rb >= rs + ms(25.0)),
            None => {} // unschedulable is acceptable: even stronger penalty
        }
    }

    #[test]
    fn cpu_only_task_gets_boost_blocking() {
        let hp = Task::cpu_only(0, 0, 2, ms(5.0), ms(50.0));
        let lp = gpu_task(1, 0, 1, 2.0, 3.0, 10.0, 100.0);
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = analyze(&ts, false);
        // Boosted G^m (3 ms) of the lp task blocks the CPU-only hp task;
        // with D-jitter carry-in, up to two lp jobs land in the window.
        assert_eq!(res.response[0], Some(ms(5.0 + 2.0 * 3.0)));
    }
}
