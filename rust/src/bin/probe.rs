// Smoke-probe: load artifacts, run every workload once, print timings.
use gcaps::runtime::{artifacts_dir, Runtime};

fn main() -> gcaps::util::error::Result<()> {
    let rt = Runtime::load_dir(&artifacts_dir())?;
    for name in rt.workloads() {
        let t = rt.profile(&name, 3)?;
        let vals = rt.exec_values(&name)?;
        println!("{name:12} {:8.3} ms  out[0..3] = {:?}", t.as_secs_f64() * 1e3, &vals[..vals.len().min(3)]);
    }
    Ok(())
}
