use gcaps::analysis::gcaps::{analyze as ganalyze, Options};
use gcaps::model::*;
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::rng::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let seed = u64::from_str_radix(args[1].trim_start_matches("0x"), 16).unwrap();
    let victim: usize = args[2].parse().unwrap();
    let busy = args.get(3).map(|s| s == "busy").unwrap_or(false);
    let policy = match args.get(4).map(|s| s.as_str()) {
        Some("mpcp") => Policy::Mpcp,
        Some("fmlp") => Policy::FmlpPlus,
        Some("tsg_rr") => Policy::TsgRr,
        _ => Policy::Gcaps,
    };
    let mut rng = Pcg32::seeded(seed);
    let p = GenParams {
        mode: if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
        util_per_cpu: (0.25, 0.45),
        ..Default::default()
    };
    let ts = generate(&mut rng, &p);
    for t in &ts.tasks {
        println!("tau{}: core {} prio {} T {} C {:?} G {:?} be={}", t.id, t.core, t.cpu_prio,
            to_ms(t.period), t.cpu_segments.iter().map(|&c| to_ms(c)).collect::<Vec<_>>(),
            t.gpu_segments.iter().map(|g| (to_ms(g.misc), to_ms(g.exec))).collect::<Vec<_>>(), t.best_effort);
    }
    let res = match policy {
        Policy::Mpcp => gcaps::analysis::mpcp::analyze(&ts, busy),
        Policy::FmlpPlus => gcaps::analysis::fmlp::analyze(&ts, busy),
        Policy::TsgRr => gcaps::analysis::rr::analyze(&ts, busy),
        _ => ganalyze(&ts, busy, &Options::default()),
    };
    println!("analysis R[{victim}] = {:?}", res.response[victim].map(to_ms));
    let horizon = ts.tasks.iter().map(|t| t.period).max().unwrap() * 6;
    let offsets: Vec<u64> = std::env::var("OFFSETS").ok().map(|v| v.split(',').map(|x| x.parse().unwrap()).collect()).unwrap_or_default();
    let cfg = SimConfig::new(policy, horizon).with_offsets(offsets).with_trace();
    let sim = simulate(&ts, &cfg);
    let m = &sim.per_task[victim];
    println!("sim responses[{victim}] = {:?}", m.response_times.iter().map(|&t| to_ms(t)).collect::<Vec<_>>());
    // locate worst job
    let tr = sim.trace.unwrap();
    let worst = m.response_times.iter().copied().enumerate().max_by_key(|&(_, r)| r).unwrap();
    println!("worst job #{} R = {}", worst.0, to_ms(worst.1));
    let rels: Vec<u64> = tr.releases.iter().filter(|(t, _)| *t == victim).map(|(_, r)| *r).collect();
    let comps: Vec<u64> = tr.completions.iter().filter(|(t, _)| *t == victim).map(|(_, c)| *c).collect();
    for (k, (r, c)) in rels.iter().zip(&comps).enumerate() {
        println!("job {k}: rel {} comp {} R {}", to_ms(*r), to_ms(*c), to_ms(c - r));
    }
    let rel = rels[worst.0];
    let end = rel + worst.1;
    println!("{}", tr.gantt(ts.platform.num_cpus, ts.len(), rel.saturating_sub(2000), end + 1000, 150));
    let _ = end;
}
