//! RealtimeStats-style service counters for `gcaps serve`: queries
//! served, admits/rejects, and p50/p99 service latency over a bounded
//! ring of recent observations.

use crate::util::stats::percentile;
use std::time::Instant;

/// Most recent service latencies retained for the percentile estimates.
const LATENCY_RING: usize = 4096;

/// Monotonic counters plus a latency ring. With timing disabled
/// (`--no-timing`) every latency reads as exactly 0 so transcripts are
/// byte-stable for the golden-file CI test.
#[derive(Debug)]
pub struct Counters {
    pub queries: u64,
    pub admits: u64,
    pub rejects: u64,
    pub removes: u64,
    pub errors: u64,
    /// Degraded-mode admissions (`admit_best_effort`).
    pub be_admits: u64,
    /// Best-effort tasks shed to make room for an RT admission.
    pub sheds: u64,
    /// Deadline misses reported by a live executive (`report_overload`).
    pub misses: u64,
    /// Job aborts reported by a live executive.
    pub aborts: u64,
    /// Priority boosts reported by a live executive.
    pub boosts: u64,
    timing: bool,
    ring: Vec<f64>,
    next: usize,
}

/// Snapshot of the latency distribution, in microseconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LatencySnapshot {
    pub samples: usize,
    pub p50_us: f64,
    pub p99_us: f64,
}

impl Counters {
    pub fn new(timing: bool) -> Counters {
        Counters {
            queries: 0,
            admits: 0,
            rejects: 0,
            removes: 0,
            errors: 0,
            be_admits: 0,
            sheds: 0,
            misses: 0,
            aborts: 0,
            boosts: 0,
            timing,
            ring: Vec::new(),
            next: 0,
        }
    }

    /// Sum of the overload-related counters. `stats` appends the
    /// overload block only when this is nonzero, keeping legacy
    /// transcripts byte-stable.
    pub fn overload_total(&self) -> u64 {
        self.be_admits + self.sheds + self.misses + self.aborts + self.boosts
    }

    /// Start timing one query; pass the returned token to [`finish`].
    /// Returns `None` when timing is disabled.
    ///
    /// [`finish`]: Counters::finish
    pub fn start(&self) -> Option<Instant> {
        self.timing.then(Instant::now)
    }

    /// Count a served query and file its latency into the ring
    /// (overwriting the oldest once the ring is full).
    pub fn finish(&mut self, started: Option<Instant>) {
        self.queries += 1;
        let us = match started {
            Some(t) => t.elapsed().as_secs_f64() * 1e6,
            None => return,
        };
        if self.ring.len() < LATENCY_RING {
            self.ring.push(us);
        } else {
            self.ring[self.next] = us;
            self.next = (self.next + 1) % LATENCY_RING;
        }
    }

    /// Latency percentiles over the retained ring. All-zero when timing
    /// is disabled or nothing has been recorded yet.
    pub fn latency(&self) -> LatencySnapshot {
        let mut xs = self.ring.clone();
        let p50 = percentile(&mut xs, 50.0).unwrap_or(0.0);
        let p99 = percentile(&mut xs, 99.0).unwrap_or(0.0);
        LatencySnapshot { samples: self.ring.len(), p50_us: p50, p99_us: p99 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_timing_reports_zero_latency() {
        let mut c = Counters::new(false);
        for _ in 0..5 {
            let t = c.start();
            assert!(t.is_none());
            c.finish(t);
        }
        assert_eq!(c.queries, 5);
        assert_eq!(c.latency(), LatencySnapshot { samples: 0, p50_us: 0.0, p99_us: 0.0 });
    }

    #[test]
    fn enabled_timing_records_latencies() {
        let mut c = Counters::new(true);
        let t = c.start();
        assert!(t.is_some());
        c.finish(t);
        assert_eq!(c.queries, 1);
        let snap = c.latency();
        assert_eq!(snap.samples, 1);
        assert!(snap.p50_us >= 0.0 && snap.p50_us.is_finite());
        assert_eq!(snap.p50_us, snap.p99_us);
    }

    #[test]
    fn ring_is_bounded() {
        let mut c = Counters::new(true);
        for _ in 0..(LATENCY_RING + 100) {
            let t = c.start();
            c.finish(t);
        }
        assert_eq!(c.queries, (LATENCY_RING + 100) as u64);
        assert_eq!(c.latency().samples, LATENCY_RING);
    }
}
