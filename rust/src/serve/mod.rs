//! `gcaps serve` — a long-running, zero-dependency admission-control
//! server speaking newline-delimited JSON over stdin/stdout or TCP.
//!
//! The server holds the currently-admitted task set and answers
//! `admit` / `remove` / `check` / `headroom` / `stats` queries against
//! the incrementally-maintained analysis kernel
//! ([`crate::analysis::prep`]): joins and leaves delta-update the
//! prepared partitions instead of rebuilding them, and GCAPS fixed
//! points warm-start from the committed response table — pinned
//! bit-equal to a cold rebuild by `tests/kernel_equivalence.rs`.
//!
//! Front-ends share one [`Session`]: `--stdin` serves the standard
//! streams; `--tcp ADDR` accepts connections sequentially. Commits are
//! serialized by design — concurrent admits against one platform would
//! race the committed state — but pipelined *read-only* queries
//! (`check` / `headroom`) that are already buffered on the stream fan
//! out concurrently through the sharded sweep worker pool
//! ([`Session::answer_reads`]) and answer in submission order, so a
//! client may keep many probes in flight without changing the
//! transcript bytes.
//!
//! Failure policy: malformed JSON, unknown ops, invalid task specs and
//! oversized request lines all produce an `{"ok":false,...}` response
//! line and the server keeps serving. Process exit code 2 is reserved
//! for unrecoverable startup errors (bad flags, unbindable address).

pub mod counters;
pub mod json;
pub mod proto;
pub mod session;

pub use session::Session;

use crate::analysis::Approach;
use crate::model::Platform;
use std::io::{self, BufRead, BufReader, Write};
use std::net::TcpListener;

/// Longest accepted request line, in bytes. Anything longer is drained
/// (so the stream stays line-synchronized) and answered with an error
/// response instead of being buffered without bound.
pub const MAX_LINE: usize = 64 * 1024;

/// Server configuration assembled by the CLI front-end.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub platform: Platform,
    pub approach: Approach,
    /// Measure per-query service latency. `--no-timing` disables it so
    /// transcripts are byte-stable (the golden-file CI test).
    pub timing: bool,
}

impl ServeConfig {
    pub fn session(&self) -> Session {
        Session::new(self.platform.clone(), self.approach, self.timing)
    }
}

enum LineStatus {
    /// Stream ended with no pending data.
    Eof,
    /// A complete (or final, unterminated) line is in the buffer.
    Line,
    /// The line exceeded [`MAX_LINE`]; its bytes were discarded.
    Overlong,
}

/// Read one newline-terminated line into `buf`, capped at [`MAX_LINE`]
/// bytes. An overlong line is consumed to its newline but not stored,
/// so one hostile or corrupt writer cannot balloon server memory or
/// desynchronize subsequent requests.
///
/// The second element of the pair reports whether a *complete* next
/// line is already sitting in the reader's buffer — i.e. whether the
/// next call is guaranteed not to block. That is what lets [`run`]
/// batch pipelined read queries without ever stalling a pending
/// response on a quiet stream (`false` is conservative: a next line
/// split across the buffer boundary reads as "might block").
fn read_line_capped(r: &mut impl BufRead, buf: &mut Vec<u8>) -> io::Result<(LineStatus, bool)> {
    buf.clear();
    let mut overlong = false;
    loop {
        let chunk = r.fill_buf()?;
        if chunk.is_empty() {
            return Ok((
                match (overlong, buf.is_empty()) {
                    (true, _) => LineStatus::Overlong,
                    (false, true) => LineStatus::Eof,
                    (false, false) => LineStatus::Line,
                },
                false,
            ));
        }
        let newline = chunk.iter().position(|&b| b == b'\n');
        let take = newline.unwrap_or(chunk.len());
        if !overlong {
            if buf.len() + take <= MAX_LINE {
                buf.extend_from_slice(&chunk[..take]);
            } else {
                overlong = true;
            }
        }
        if newline.is_some() {
            let more = chunk[take + 1..].contains(&b'\n');
            r.consume(take + 1);
            return Ok((if overlong { LineStatus::Overlong } else { LineStatus::Line }, more));
        }
        r.consume(take);
    }
}

/// Serve one request stream to completion. Returns `true` when the
/// client asked for shutdown (as opposed to just closing the stream).
///
/// Pipelined read-only queries batch up while further complete lines
/// are already buffered; the batch fans out through
/// [`Session::answer_reads`] and flushes — in submission order — before
/// anything that would block or commit.
pub fn run(session: &mut Session, mut input: impl BufRead, mut out: impl Write) -> io::Result<bool> {
    let mut buf = Vec::new();
    let mut reads: Vec<String> = Vec::new();
    loop {
        let (status, more) = read_line_capped(&mut input, &mut buf)?;
        match status {
            LineStatus::Eof => {
                flush_reads(session, &mut reads, &mut out)?;
                return Ok(false);
            }
            LineStatus::Overlong => {
                flush_reads(session, &mut reads, &mut out)?;
                let resp =
                    session.transport_error(&format!("request line exceeds {MAX_LINE} bytes"));
                writeln!(out, "{}", resp.to_json())?;
                out.flush()?;
            }
            LineStatus::Line => {
                let text = String::from_utf8_lossy(&buf);
                let text = text.trim_end_matches('\r');
                if text.trim().is_empty() {
                    // Blank lines are keep-alive noise, not queries —
                    // but never block on the next line with answers
                    // still pending.
                    if !more {
                        flush_reads(session, &mut reads, &mut out)?;
                    }
                    continue;
                }
                if Session::is_read_query(text) {
                    reads.push(text.to_string());
                    if !more {
                        flush_reads(session, &mut reads, &mut out)?;
                    }
                    continue;
                }
                // Commits (and anything unrecognized) serialize: drain
                // the pending reads first so responses keep submission
                // order.
                flush_reads(session, &mut reads, &mut out)?;
                let (resp, quit) = session.handle_line(text);
                writeln!(out, "{}", resp.to_json())?;
                out.flush()?;
                if quit {
                    return Ok(true);
                }
            }
        }
    }
}

/// Drain the pending read-query batch: a single query answers inline on
/// the live session; two or more fan out through the sweep pool.
fn flush_reads(
    session: &mut Session,
    reads: &mut Vec<String>,
    out: &mut impl Write,
) -> io::Result<()> {
    if reads.is_empty() {
        return Ok(());
    }
    let lines = std::mem::take(reads);
    let answers = if lines.len() == 1 {
        vec![session.handle_line(&lines[0]).0]
    } else {
        session.answer_reads(&lines)
    };
    for resp in answers {
        writeln!(out, "{}", resp.to_json())?;
    }
    out.flush()
}

/// Serve stdin→stdout until EOF or a `shutdown` request.
pub fn serve_stdio(cfg: &ServeConfig) -> io::Result<()> {
    let mut session = cfg.session();
    let stdin = io::stdin();
    let stdout = io::stdout();
    run(&mut session, stdin.lock(), stdout.lock())?;
    Ok(())
}

/// Serve TCP connections sequentially on `addr` until a client sends
/// `shutdown`. The admitted set persists across connections. Binding
/// errors propagate (startup failure → exit 2 in the CLI); per-client
/// I/O errors are reported to stderr and the listener keeps accepting.
pub fn serve_tcp(cfg: &ServeConfig, addr: &str) -> io::Result<()> {
    let listener = TcpListener::bind(addr)?;
    eprintln!(
        "gcaps serve: listening on {} ({}, {} cpus, {} gpus)",
        listener.local_addr()?,
        cfg.approach.label(),
        cfg.platform.num_cpus,
        cfg.platform.num_gpus()
    );
    let mut session = cfg.session();
    for stream in listener.incoming() {
        let stream = match stream {
            Ok(s) => s,
            Err(e) => {
                eprintln!("gcaps serve: accept failed: {e}");
                continue;
            }
        };
        let reader = match stream.try_clone() {
            Ok(s) => BufReader::new(s),
            Err(e) => {
                eprintln!("gcaps serve: clone failed: {e}");
                continue;
            }
        };
        match run(&mut session, reader, &stream) {
            Ok(true) => return Ok(()),
            Ok(false) => {}
            Err(e) => eprintln!("gcaps serve: connection error: {e}"),
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn serve_text(input: &str) -> Vec<String> {
        let cfg = ServeConfig {
            platform: Platform::default(),
            approach: Approach::GcapsSuspend,
            timing: false,
        };
        let mut session = cfg.session();
        let mut out = Vec::new();
        run(&mut session, Cursor::new(input.as_bytes()), &mut out).unwrap();
        String::from_utf8(out).unwrap().lines().map(str::to_string).collect()
    }

    #[test]
    fn one_response_line_per_request_line() {
        let out = serve_text(concat!(
            r#"{"op":"admit","task":{"name":"a","period_ms":100,"cpu_ms":[1],"prio":1}}"#,
            "\n",
            "garbage\n",
            r#"{"op":"check"}"#,
            "\n",
        ));
        assert_eq!(out.len(), 3);
        assert!(out[0].contains(r#""admitted":true"#));
        assert!(out[1].starts_with(r#"{"ok":false"#));
        assert!(out[2].contains(r#""schedulable":true"#));
    }

    #[test]
    fn blank_lines_and_crlf_are_tolerated() {
        let out = serve_text("\n  \n{\"op\":\"stats\"}\r\n");
        assert_eq!(out.len(), 1);
        assert!(out[0].contains(r#""queries":0"#), "{}", out[0]);
    }

    #[test]
    fn oversized_line_errors_and_stream_stays_synchronized() {
        let big = format!("{{\"op\":\"admit\",\"pad\":\"{}\"}}\n", "x".repeat(MAX_LINE + 1));
        let input = format!("{big}{}\n", r#"{"op":"stats"}"#);
        let out = serve_text(&input);
        assert_eq!(out.len(), 2);
        assert!(out[0].contains("exceeds"), "{}", out[0]);
        assert!(out[1].contains(r#""errors":1"#), "oversize counts as error: {}", out[1]);
    }

    #[test]
    fn shutdown_stops_before_remaining_lines() {
        let out = serve_text("{\"op\":\"shutdown\"}\n{\"op\":\"stats\"}\n");
        assert_eq!(out.len(), 1);
        assert_eq!(out[0], r#"{"ok":true,"op":"shutdown"}"#);
    }

    #[test]
    fn final_unterminated_line_is_served() {
        let out = serve_text(r#"{"op":"check"}"#);
        assert_eq!(out.len(), 1);
        assert!(out[0].contains(r#""schedulable":true"#));
    }
}
