//! Minimal newline-JSON value type for the admission server's wire
//! protocol. The offline crate set has no `serde`, so this implements
//! the ~150 lines of JSON we actually need: a recursive-descent parser
//! (depth-limited, full string escapes, surrogate pairs) and a
//! deterministic writer (object keys keep insertion order; integral
//! numbers print without a fraction) so golden transcripts are
//! byte-stable.
//!
//! Error handling contract: `parse` never panics on any input — every
//! malformed byte sequence yields `Err(String)` (property-tested in
//! `rust/tests/serve.rs`), which the server turns into an error
//! *response*, not an exit.

use std::fmt::Write as _;

/// A parsed JSON value. Objects preserve key order (Vec, not a map):
/// the writer then emits fields in the order the server built them,
/// keeping transcripts diffable.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (first match).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Serialize to a single line (no trailing newline).
    pub fn to_json(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Value::Null => out.push_str("null"),
            Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Value::Num(n) => write_num(*n, out),
            Value::Str(s) => write_str(s, out),
            Value::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Value::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Integral values print without a fraction ("12", not "12.0"); other
/// finite values use Rust's shortest-roundtrip `Display`. Non-finite
/// numbers (which JSON cannot carry) serialize as `null` — the server
/// never produces them, but the writer must not emit invalid JSON.
fn write_num(n: f64, out: &mut String) {
    if !n.is_finite() {
        out.push_str("null");
    } else if n == n.trunc() && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Nesting bound: a hostile request line cannot recurse the parser off
/// the stack. Protocol messages are ≤ 3 levels deep.
const MAX_DEPTH: usize = 32;

/// Parse one complete JSON value; trailing non-whitespace is an error
/// (a request line is exactly one value).
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing characters at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if b == b' ' || b == b'\t' || b == b'\n' || b == b'\r' {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", b as char, self.pos))
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("invalid literal at byte {}", self.pos))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Value, String> {
        if depth > MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH}"));
        }
        match self.peek() {
            None => Err("unexpected end of input".into()),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(depth),
            Some(b'{') => self.object(depth),
            Some(b'-') | Some(b'0'..=b'9') => self.number(),
            Some(b) => Err(format!("unexpected {:?} at byte {}", b as char, self.pos)),
        }
    }

    fn array(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Value, String> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value(depth + 1)?;
            fields.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Value::Obj(fields));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let start = self.pos;
            // Fast path: raw UTF-8 run up to the next quote/escape.
            while let Some(&b) = self.bytes.get(self.pos) {
                if b == b'"' || b == b'\\' || b < 0x20 {
                    break;
                }
                self.pos += 1;
            }
            if self.pos > start {
                // The input is a &str, so any slice between ASCII
                // delimiters is valid UTF-8.
                out.push_str(
                    std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| "invalid UTF-8 in string".to_string())?,
                );
            }
            match self.peek() {
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    out.push(self.escape()?);
                }
                Some(_) => return Err(format!("control byte in string at {}", self.pos)),
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn escape(&mut self) -> Result<char, String> {
        let c = self.peek().ok_or("unterminated escape")?;
        self.pos += 1;
        Ok(match c {
            b'"' => '"',
            b'\\' => '\\',
            b'/' => '/',
            b'b' => '\u{8}',
            b'f' => '\u{c}',
            b'n' => '\n',
            b'r' => '\r',
            b't' => '\t',
            b'u' => {
                let hi = self.hex4()?;
                let code = if (0xD800..0xDC00).contains(&hi) {
                    // Surrogate pair: require the low half.
                    if self.peek() == Some(b'\\') {
                        self.pos += 1;
                        self.expect(b'u').map_err(|_| "lone surrogate".to_string())?;
                        let lo = self.hex4()?;
                        if !(0xDC00..0xE000).contains(&lo) {
                            return Err("invalid low surrogate".into());
                        }
                        0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00)
                    } else {
                        return Err("lone surrogate".into());
                    }
                } else {
                    hi
                };
                char::from_u32(code).ok_or_else(|| "invalid unicode escape".to_string())?
            }
            c => return Err(format!("invalid escape \\{}", c as char)),
        })
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos.checked_add(4).filter(|&e| e <= self.bytes.len());
        let end = end.ok_or("truncated \\u escape")?;
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "invalid \\u escape".to_string())?;
        let v = u32::from_str_radix(s, 16).map_err(|_| "invalid \\u escape".to_string())?;
        self.pos = end;
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(&b) = self.bytes.get(self.pos) {
            if b.is_ascii_digit() || b == b'.' || b == b'e' || b == b'E' || b == b'+' || b == b'-'
            {
                self.pos += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = s.parse().map_err(|_| format!("invalid number {s:?}"))?;
        if !n.is_finite() {
            return Err(format!("non-finite number {s:?}"));
        }
        Ok(Value::Num(n))
    }
}

/// Object builder shorthand for responses.
pub fn obj(fields: Vec<(&str, Value)>) -> Value {
    Value::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic_values() {
        for text in [
            "null",
            "true",
            "false",
            "0",
            "-3",
            "2.5",
            "\"hi\"",
            "[]",
            "[1,2,3]",
            "{}",
            "{\"a\":1,\"b\":[true,null]}",
        ] {
            let v = parse(text).unwrap();
            assert_eq!(v.to_json(), text, "roundtrip {text}");
        }
    }

    #[test]
    fn parses_whitespace_and_nesting() {
        let v = parse(" { \"op\" : \"admit\" , \"x\" : [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("op").unwrap().as_str(), Some("admit"));
        assert_eq!(v.get("x").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let v = parse(r#""a\"b\\c\nd\u0041\u00e9""#).unwrap();
        assert_eq!(v.as_str(), Some("a\"b\\c\ndAé"));
        let v = parse(r#""\ud83d\ude00""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn malformed_inputs_error_without_panicking() {
        for text in [
            "",
            "{",
            "}",
            "[1,",
            "{\"a\"}",
            "{\"a\":}",
            "\"unterminated",
            "nul",
            "truex",
            "1.2.3",
            "- 5",
            "\"\\q\"",
            "\"\\u12\"",
            "\"\\ud800\"",
            "{\"a\":1} extra",
            "1e999",
            "--1",
        ] {
            assert!(parse(text).is_err(), "{text:?} should fail");
        }
    }

    #[test]
    fn depth_limit_rejects_deep_nesting() {
        let deep = "[".repeat(200) + &"]".repeat(200);
        let e = parse(&deep).unwrap_err();
        assert!(e.contains("nesting"), "{e}");
        let ok = "[".repeat(10) + &"]".repeat(10);
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn integral_numbers_write_without_fraction() {
        assert_eq!(Value::Num(12.0).to_json(), "12");
        assert_eq!(Value::Num(11.6).to_json(), "11.6");
        assert_eq!(Value::Num(f64::NAN).to_json(), "null");
    }

    #[test]
    fn duplicate_keys_first_wins_on_get() {
        let v = parse("{\"a\":1,\"a\":2}").unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.0));
    }
}
