//! Admission-control session state: the currently-admitted task set,
//! its incrementally-maintained [`Prepared`] kernel, the committed
//! warm-start response table, and the service counters.
//!
//! Analysis policy: each query runs the *plain* per-approach analysis
//! (no Audsley GPU-priority retry). The §5.3 Audsley search mutates
//! `gpu_prio` across the whole set, which would churn already-admitted
//! tasks' committed state on every admit — an admission server must
//! answer against a stable configuration, so π^g stays equal to the
//! task's RT priority. GCAPS queries warm-start from the committed
//! response table (sound and bit-equal: an admit — or a headroom probe
//! that only *grows* a WCET parameter — grows every task's iteration
//! map pointwise, so the old least fixed point lower-bounds the new
//! one); after a removal the maps shrink and the analysis restarts
//! cold before re-committing.

use crate::analysis::{fmlp, gcaps, mpcp, rr, server, Approach};
use crate::analysis::{AnalysisResult, Prepared};
use crate::model::{to_ms, Platform, Task, TaskSet, Time};
use crate::serve::counters::Counters;
use crate::serve::json::{obj, parse, Value};
use crate::serve::proto::{parse_request, Param, Request, TaskSpec};
use crate::sweep::{self, SweepConfig};

/// One admission-control session (shared by stdin and TCP front-ends).
pub struct Session {
    approach: Approach,
    ts: TaskSet,
    prep: Prepared,
    /// Committed response table of the admitted set (µs), used to
    /// warm-start GCAPS fixed points. `warm[i]` is task i's response.
    warm: Vec<Option<Time>>,
    pub counters: Counters,
}

impl Session {
    pub fn new(platform: Platform, approach: Approach, timing: bool) -> Session {
        let ts = TaskSet::new(Vec::new(), platform);
        let prep = Prepared::new(&ts);
        Session { approach, ts, prep, warm: Vec::new(), counters: Counters::new(timing) }
    }

    pub fn num_tasks(&self) -> usize {
        self.ts.tasks.len()
    }

    /// Serve one request line. Returns the response value plus whether
    /// the server should shut down after sending it. Never panics on
    /// bad input — every failure becomes an `ok:false` response.
    pub fn handle_line(&mut self, line: &str) -> (Value, bool) {
        let started = self.counters.start();
        let (resp, quit) = match parse(line).and_then(|v| parse_request(&v)) {
            Err(e) => {
                self.counters.errors += 1;
                (error_response(&e), false)
            }
            Ok(Request::Shutdown) => {
                (obj(vec![("ok", Value::Bool(true)), ("op", Value::Str("shutdown".into()))]), true)
            }
            Ok(req) => (self.dispatch(req), false),
        };
        self.counters.finish(started);
        (resp, quit)
    }

    /// True when `line` parses to a query that only *reads* the
    /// committed admission state (`check` / `headroom`). Such queries
    /// are safe to answer from a snapshot, concurrently with other
    /// in-flight reads; everything else (commits, stats, shutdown,
    /// malformed lines) must stay serialized on the live session.
    pub fn is_read_query(line: &str) -> bool {
        matches!(
            parse(line).and_then(|v| parse_request(&v)),
            Ok(Request::Check | Request::Headroom { .. })
        )
    }

    /// Clone of the committed analysis state with fresh counters — the
    /// shadow one concurrent read query runs against. `headroom` probes
    /// mutate-then-restore their session, so every in-flight read needs
    /// its own shadow; `self` is never touched.
    fn read_snapshot(&self) -> Session {
        Session {
            approach: self.approach,
            ts: self.ts.clone(),
            prep: self.prep.clone(),
            warm: self.warm.clone(),
            counters: Counters::new(false),
        }
    }

    /// Answer a batch of pipelined read-only queries (each vetted by
    /// [`Session::is_read_query`]) concurrently through the sharded
    /// sweep worker pool, returning responses in submission order —
    /// [`sweep::run`] reassembles worker results into input order, so
    /// the response bytes are identical to serving the lines one by
    /// one. Only the service counters are folded back into `self`.
    pub fn answer_reads(&mut self, lines: &[String]) -> Vec<Value> {
        let base = self.read_snapshot();
        let answers = sweep::run(&SweepConfig::default(), lines.to_vec(), |_, line| {
            let mut shadow = base.read_snapshot();
            let (v, _) = shadow.handle_line(line);
            (v, shadow.counters.errors)
        });
        answers
            .into_iter()
            .map(|(v, errors)| {
                let started = self.counters.start();
                self.counters.errors += errors;
                self.counters.finish(started);
                v
            })
            .collect()
    }

    fn dispatch(&mut self, req: Request) -> Value {
        match req {
            Request::Admit(spec) => self.admit(spec),
            Request::AdmitBestEffort(spec) => self.admit_best_effort(spec),
            Request::Remove(name) => self.remove(&name),
            Request::Check => self.check(),
            Request::Headroom { task, param } => self.headroom(&task, param),
            Request::Stats => self.stats(),
            Request::ReportOverload { misses, aborts, boosts } => {
                self.report_overload(misses, aborts, boosts)
            }
            Request::Shutdown => unreachable!("handled in handle_line"),
        }
    }

    /// Run the session's analysis over the current kernel. `warm` may
    /// be shorter than the task count (missing entries start cold) and
    /// is only consulted by the GCAPS family — the other families'
    /// prepared analyses are already single-pass over the shared
    /// delta-updated kernel.
    fn analyze(&self, warm: &[Option<Time>]) -> AnalysisResult {
        let busy = self.approach.is_busy();
        match self.approach {
            Approach::GcapsBusy | Approach::GcapsSuspend => gcaps::analyze_prepared_warm(
                &self.ts,
                &self.prep,
                busy,
                &gcaps::Options::default(),
                warm,
            ),
            Approach::TsgRrBusy | Approach::TsgRrSuspend => {
                rr::analyze_prepared(&self.ts, &self.prep, busy)
            }
            Approach::MpcpBusy | Approach::MpcpSuspend => {
                mpcp::analyze_prepared(&self.ts, &self.prep, busy)
            }
            Approach::FmlpBusy | Approach::FmlpSuspend => {
                fmlp::analyze_prepared(&self.ts, &self.prep, busy)
            }
            Approach::ServerSuspend => server::analyze_prepared(&self.ts, &self.prep),
        }
    }

    fn admit(&mut self, spec: TaskSpec) -> Value {
        if self.ts.tasks.iter().any(|t| t.name == spec.name) {
            self.counters.rejects += 1;
            return rejected("admit", &format!("duplicate task name {:?}", spec.name));
        }
        let n = self.ts.tasks.len();
        let task = spec.to_task(n, self.approach.wait_mode());
        self.ts.tasks.push(task);
        if let Err(e) = self.ts.validate() {
            self.ts.tasks.pop();
            self.counters.rejects += 1;
            return rejected("admit", &e);
        }
        self.prep.admit_task(&self.ts);
        let mut warm = self.warm.clone();
        warm.push(None);
        let res = self.analyze(&warm);
        if res.schedulable {
            self.counters.admits += 1;
            let r = res.response[n];
            self.warm = res.response;
            obj(vec![
                ("ok", Value::Bool(true)),
                ("op", Value::Str("admit".into())),
                ("admitted", Value::Bool(true)),
                ("tasks", Value::Num(self.ts.tasks.len() as f64)),
                ("response_ms", r.map_or(Value::Null, |t| Value::Num(to_ms(t)))),
            ])
        } else {
            // Graceful degradation: before rejecting an RT admission,
            // try shedding admitted best-effort tasks (oldest first) to
            // make room. Committed only when the shed set analyses
            // schedulable; otherwise every structure is restored and the
            // legacy reject path runs unchanged. The loop is skipped
            // entirely when no BE task is admitted, so non-degraded
            // sessions keep their exact historical behavior.
            if !spec.best_effort
                && self.ts.tasks.iter().any(|t| t.best_effort && t.name != spec.name)
            {
                let saved_ts = self.ts.clone();
                let saved_prep = self.prep.clone();
                let mut shed: Vec<String> = Vec::new();
                while let Some(k) = self
                    .ts
                    .tasks
                    .iter()
                    .position(|t| t.best_effort && t.name != spec.name)
                {
                    shed.push(self.ts.tasks[k].name.clone());
                    self.ts.tasks.remove(k);
                    for i in k..self.ts.tasks.len() {
                        self.ts.tasks[i].id = i;
                    }
                    self.prep.remove_task(k);
                    // Cold: the maps shrank, warm hints are invalid.
                    let r = self.analyze(&[]);
                    if r.schedulable {
                        self.counters.admits += 1;
                        self.counters.sheds += shed.len() as u64;
                        let id = self
                            .ts
                            .tasks
                            .iter()
                            .position(|t| t.name == spec.name)
                            .expect("candidate survives shedding");
                        let resp = r.response[id];
                        self.warm = r.response;
                        return obj(vec![
                            ("ok", Value::Bool(true)),
                            ("op", Value::Str("admit".into())),
                            ("admitted", Value::Bool(true)),
                            ("tasks", Value::Num(self.ts.tasks.len() as f64)),
                            (
                                "response_ms",
                                resp.map_or(Value::Null, |t| Value::Num(to_ms(t))),
                            ),
                            (
                                "shed",
                                Value::Arr(
                                    shed.into_iter().map(Value::Str).collect(),
                                ),
                            ),
                        ]);
                    }
                }
                self.ts = saved_ts;
                self.prep = saved_prep;
            }
            // Roll the delta back; the roundtrip is pinned bit-equal to
            // never having admitted (tests/kernel_equivalence.rs).
            self.prep.remove_task(n);
            self.ts.tasks.pop();
            self.counters.rejects += 1;
            let culprits: Vec<Value> = self
                .ts
                .tasks
                .iter()
                .filter(|t| !t.best_effort && res.response[t.id].is_none())
                .map(|t| Value::Str(t.name.clone()))
                .chain(res.response.last().and_then(|r| {
                    r.is_none().then(|| Value::Str(spec.name.clone()))
                }))
                .collect();
            obj(vec![
                ("ok", Value::Bool(true)),
                ("op", Value::Str("admit".into())),
                ("admitted", Value::Bool(false)),
                ("reason", Value::Str("unschedulable".into())),
                ("failing", Value::Arr(culprits)),
                ("tasks", Value::Num(self.ts.tasks.len() as f64)),
            ])
        }
    }

    /// Degraded-mode admission: force the spec best-effort and accept
    /// it whenever the committed RT set stays schedulable alongside it
    /// (the BE task itself gets no response bound and is first in line
    /// to be shed by a later RT admission under overload).
    fn admit_best_effort(&mut self, mut spec: TaskSpec) -> Value {
        spec.best_effort = true;
        if self.ts.tasks.iter().any(|t| t.name == spec.name) {
            self.counters.rejects += 1;
            return rejected(
                "admit_best_effort",
                &format!("duplicate task name {:?}", spec.name),
            );
        }
        let n = self.ts.tasks.len();
        let task = spec.to_task(n, self.approach.wait_mode());
        self.ts.tasks.push(task);
        if let Err(e) = self.ts.validate() {
            self.ts.tasks.pop();
            self.counters.rejects += 1;
            return rejected("admit_best_effort", &e);
        }
        self.prep.admit_task(&self.ts);
        let mut warm = self.warm.clone();
        warm.push(None);
        let res = self.analyze(&warm);
        if res.schedulable {
            self.counters.be_admits += 1;
            self.warm = res.response;
            obj(vec![
                ("ok", Value::Bool(true)),
                ("op", Value::Str("admit_best_effort".into())),
                ("admitted", Value::Bool(true)),
                ("best_effort", Value::Bool(true)),
                ("tasks", Value::Num(self.ts.tasks.len() as f64)),
            ])
        } else {
            // Even as pure best-effort load the newcomer's blocking
            // breaks an admitted RT bound — roll back and reject.
            self.prep.remove_task(n);
            self.ts.tasks.pop();
            self.counters.rejects += 1;
            obj(vec![
                ("ok", Value::Bool(true)),
                ("op", Value::Str("admit_best_effort".into())),
                ("admitted", Value::Bool(false)),
                ("reason", Value::Str("breaks admitted RT guarantees".into())),
                ("tasks", Value::Num(self.ts.tasks.len() as f64)),
            ])
        }
    }

    /// Fold a live executive's overload telemetry into the session
    /// counters and echo the running totals.
    fn report_overload(&mut self, misses: u64, aborts: u64, boosts: u64) -> Value {
        self.counters.misses = self.counters.misses.saturating_add(misses);
        self.counters.aborts = self.counters.aborts.saturating_add(aborts);
        self.counters.boosts = self.counters.boosts.saturating_add(boosts);
        obj(vec![
            ("ok", Value::Bool(true)),
            ("op", Value::Str("report_overload".into())),
            ("misses", Value::Num(self.counters.misses as f64)),
            ("aborts", Value::Num(self.counters.aborts as f64)),
            ("boosts", Value::Num(self.counters.boosts as f64)),
        ])
    }

    fn remove(&mut self, name: &str) -> Value {
        let Some(k) = self.ts.tasks.iter().position(|t| t.name == name) else {
            self.counters.errors += 1;
            return error_response(&format!("remove: no admitted task named {name:?}"));
        };
        self.ts.tasks.remove(k);
        for i in k..self.ts.tasks.len() {
            self.ts.tasks[i].id = i;
        }
        self.prep.remove_task(k);
        self.counters.removes += 1;
        // Interference maps only shrank, so the set stays schedulable;
        // re-analyse cold (shrunk maps invalidate warm hints) to
        // refresh the committed response table.
        let res = self.analyze(&[]);
        debug_assert!(res.schedulable, "removal cannot make an admitted set unschedulable");
        self.warm = res.response;
        obj(vec![
            ("ok", Value::Bool(true)),
            ("op", Value::Str("remove".into())),
            ("removed", Value::Bool(true)),
            ("tasks", Value::Num(self.ts.tasks.len() as f64)),
        ])
    }

    fn check(&mut self) -> Value {
        let res = self.analyze(&self.warm);
        let tasks: Vec<Value> = self
            .ts
            .tasks
            .iter()
            .map(|t| {
                obj(vec![
                    ("name", Value::Str(t.name.clone())),
                    (
                        "response_ms",
                        res.response[t.id].map_or(Value::Null, |r| Value::Num(to_ms(r))),
                    ),
                ])
            })
            .collect();
        obj(vec![
            ("ok", Value::Bool(true)),
            ("op", Value::Str("check".into())),
            ("schedulable", Value::Bool(res.schedulable)),
            ("tasks", Value::Arr(tasks)),
        ])
    }

    /// Max additive slack Δ (binary search, µs granularity) on one
    /// parameter of an admitted task such that the whole set stays
    /// schedulable, capped at the task's deadline.
    fn headroom(&mut self, name: &str, param: Param) -> Value {
        let Some(k) = self.ts.tasks.iter().position(|t| t.name == name) else {
            self.counters.errors += 1;
            return error_response(&format!("headroom: no admitted task named {name:?}"));
        };
        if param == Param::Ge && self.ts.tasks[k].gpu_segments.is_empty() {
            self.counters.errors += 1;
            return error_response(&format!(
                "headroom: task {name:?} has no GPU segments (param \"ge\")"
            ));
        }
        let original = self.ts.tasks[k].clone();
        let cap = original.deadline;
        // feasible(0) holds: the committed set is schedulable. Probes
        // only grow a WCET, so warm-starting from the committed table
        // stays sound (see the module doc).
        let mut lo: Time = 0;
        let mut hi: Time = cap;
        if self.probe(k, param, hi, &original) {
            lo = hi;
        } else {
            // Invariant: feasible(lo), !feasible(hi).
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                if self.probe(k, param, mid, &original) {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
        }
        // Restore the committed kernel entry.
        self.ts.tasks[k] = original;
        self.prep.update_task(&self.ts, k);
        obj(vec![
            ("ok", Value::Bool(true)),
            ("op", Value::Str("headroom".into())),
            ("task", Value::Str(name.into())),
            ("param", Value::Str(param.label().into())),
            ("headroom_ms", Value::Num(to_ms(lo))),
            ("capped", Value::Bool(lo == cap)),
        ])
    }

    /// Re-star task k with `delta` added to the searched parameter and
    /// test schedulability of the whole set.
    fn probe(&mut self, k: usize, param: Param, delta: Time, original: &Task) -> bool {
        let mut t = original.clone();
        match param {
            Param::C => t.cpu_segments[0] += delta,
            Param::Ge => t.gpu_segments[0].exec += delta,
        }
        self.ts.tasks[k] = t;
        self.prep.update_task(&self.ts, k);
        self.analyze(&self.warm).schedulable
    }

    /// File a transport-level error (e.g. an oversized request line
    /// whose content was discarded unread) as a served query with an
    /// error response.
    pub fn transport_error(&mut self, msg: &str) -> Value {
        let started = self.counters.start();
        self.counters.errors += 1;
        let v = error_response(msg);
        self.counters.finish(started);
        v
    }

    fn stats(&mut self) -> Value {
        let lat = self.counters.latency();
        let mut fields = vec![
            ("ok", Value::Bool(true)),
            ("op", Value::Str("stats".into())),
            ("approach", Value::Str(self.approach.label().into())),
            ("tasks", Value::Num(self.ts.tasks.len() as f64)),
            ("queries", Value::Num(self.counters.queries as f64)),
            ("admits", Value::Num(self.counters.admits as f64)),
            ("rejects", Value::Num(self.counters.rejects as f64)),
            ("removes", Value::Num(self.counters.removes as f64)),
            ("errors", Value::Num(self.counters.errors as f64)),
            ("latency_samples", Value::Num(lat.samples as f64)),
            ("latency_p50_us", Value::Num(lat.p50_us)),
            ("latency_p99_us", Value::Num(lat.p99_us)),
        ];
        // Overload block: appended only once any overload counter is
        // nonzero, so legacy transcripts (serve_golden.jsonl) stay
        // byte-identical for sessions that never degrade.
        if self.counters.overload_total() > 0 {
            fields.extend([
                ("be_admits", Value::Num(self.counters.be_admits as f64)),
                ("sheds", Value::Num(self.counters.sheds as f64)),
                ("misses", Value::Num(self.counters.misses as f64)),
                ("aborts", Value::Num(self.counters.aborts as f64)),
                ("boosts", Value::Num(self.counters.boosts as f64)),
            ]);
        }
        obj(fields)
    }
}

fn error_response(msg: &str) -> Value {
    obj(vec![("ok", Value::Bool(false)), ("error", Value::Str(msg.into()))])
}

fn rejected(op: &str, reason: &str) -> Value {
    obj(vec![
        ("ok", Value::Bool(true)),
        ("op", Value::Str(op.into())),
        ("admitted", Value::Bool(false)),
        ("reason", Value::Str(reason.into())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn session() -> Session {
        Session::new(Platform::default(), Approach::GcapsSuspend, false)
    }

    fn line(s: &mut Session, text: &str) -> String {
        let (v, _) = s.handle_line(text);
        v.to_json()
    }

    fn admit_line(name: &str, period: f64, prio: u32, core: usize) -> String {
        format!(
            r#"{{"op":"admit","task":{{"name":"{name}","period_ms":{period},"cpu_ms":[1,1],"gpu_ms":[[0.5,2]],"core":{core},"prio":{prio}}}}}"#
        )
    }

    #[test]
    fn admit_check_remove_lifecycle() {
        let mut s = session();
        let r = line(&mut s, &admit_line("cam", 100.0, 10, 0));
        assert!(r.contains(r#""admitted":true"#), "{r}");
        assert!(r.contains(r#""tasks":1"#), "{r}");
        let r = line(&mut s, &admit_line("lidar", 50.0, 20, 1));
        assert!(r.contains(r#""admitted":true"#), "{r}");
        let r = line(&mut s, r#"{"op":"check"}"#);
        assert!(r.contains(r#""schedulable":true"#), "{r}");
        assert!(r.contains("cam") && r.contains("lidar"), "{r}");
        let r = line(&mut s, r#"{"op":"remove","task":"cam"}"#);
        assert!(r.contains(r#""removed":true"#) && r.contains(r#""tasks":1"#), "{r}");
        assert_eq!(s.num_tasks(), 1);
        assert_eq!(s.ts.tasks[0].id, 0, "ids re-pack to indices after removal");
    }

    #[test]
    fn duplicate_name_and_duplicate_prio_reject_without_state_change() {
        let mut s = session();
        line(&mut s, &admit_line("cam", 100.0, 10, 0));
        let r = line(&mut s, &admit_line("cam", 80.0, 11, 1));
        assert!(r.contains(r#""admitted":false"#) && r.contains("duplicate task name"), "{r}");
        // Same RT priority on any core violates TaskSet::validate.
        let r = line(&mut s, &admit_line("dup", 80.0, 10, 1));
        assert!(r.contains(r#""admitted":false"#), "{r}");
        assert_eq!(s.num_tasks(), 1);
        let r = line(&mut s, r#"{"op":"stats"}"#);
        assert!(r.contains(r#""admits":1"#) && r.contains(r#""rejects":2"#), "{r}");
    }

    #[test]
    fn unschedulable_admit_rolls_back() {
        let mut s = session();
        line(&mut s, &admit_line("a", 10.0, 10, 0));
        // 9 ms of CPU on the same core inside a 10 ms period on top of
        // task a's ~3.5 ms demand cannot fit.
        let r = line(
            &mut s,
            r#"{"op":"admit","task":{"name":"hog","period_ms":10,"cpu_ms":[9],"core":0,"prio":5}}"#,
        );
        assert!(r.contains(r#""admitted":false"#) && r.contains("unschedulable"), "{r}");
        assert!(r.contains("hog"), "failing list names the culprit: {r}");
        assert_eq!(s.num_tasks(), 1);
        // The rolled-back kernel still admits a feasible task.
        let r = line(&mut s, &admit_line("b", 100.0, 3, 1));
        assert!(r.contains(r#""admitted":true"#), "{r}");
    }

    #[test]
    fn malformed_and_unknown_requests_are_error_responses() {
        let mut s = session();
        for bad in [
            "",
            "not json",
            "{\"op\":\"admit\"}",
            "{\"op\":\"nope\"}",
            "[1,2,3]",
            "{\"op\":\"remove\",\"task\":\"ghost\"}",
            "{\"op\":\"headroom\",\"task\":\"ghost\",\"param\":\"c\"}",
        ] {
            let (v, quit) = s.handle_line(bad);
            let r = v.to_json();
            assert!(r.starts_with(r#"{"ok":false"#), "{bad} -> {r}");
            assert!(!quit);
        }
        let r = line(&mut s, r#"{"op":"stats"}"#);
        assert!(r.contains(r#""errors":7"#), "{r}");
    }

    #[test]
    fn headroom_binary_search_is_consistent() {
        let mut s = session();
        line(&mut s, &admit_line("cam", 100.0, 10, 0));
        line(&mut s, &admit_line("lidar", 20.0, 20, 0));
        for (param, seg) in [("c", 0usize), ("ge", 1usize)] {
            let r = line(&mut s, &format!(r#"{{"op":"headroom","task":"cam","param":"{param}"}}"#));
            assert!(r.contains(r#""ok":true"#), "{r}");
            let ms_val: f64 = r
                .split("\"headroom_ms\":")
                .nth(1)
                .and_then(|t| t.split([',', '}']).next())
                .unwrap()
                .parse()
                .unwrap();
            assert!(ms_val >= 0.0, "param {param} (seg {seg}): {r}");
            // The probe loop must leave the committed state intact.
            let chk = line(&mut s, r#"{"op":"check"}"#);
            assert!(chk.contains(r#""schedulable":true"#), "{chk}");
        }
        // Headroom + delta admits must agree: admitting a task that
        // consumes more than the remaining headroom must fail.
        let r = line(&mut s, r#"{"op":"headroom","task":"ghost","param":"c"}"#);
        assert!(r.contains(r#""ok":false"#), "{r}");
        let r = line(&mut s, r#"{"op":"headroom","task":"lidar","param":"ge"}"#);
        assert!(r.contains(r#""ok":true"#), "{r}");
    }

    #[test]
    fn headroom_ge_on_cpu_only_task_errors() {
        let mut s = session();
        line(
            &mut s,
            r#"{"op":"admit","task":{"name":"cpu","period_ms":50,"cpu_ms":[1],"prio":1}}"#,
        );
        let r = line(&mut s, r#"{"op":"headroom","task":"cpu","param":"ge"}"#);
        assert!(r.contains(r#""ok":false"#) && r.contains("no GPU segments"), "{r}");
    }

    #[test]
    fn admit_best_effort_accepts_without_guarantee() {
        let mut s = session();
        line(&mut s, &admit_line("cam", 100.0, 10, 0));
        // CPU-only BE task whose priority collides with cam's: BE tasks
        // are exempt from RT priority uniqueness and get no bound.
        let r = line(
            &mut s,
            r#"{"op":"admit_best_effort","task":{"name":"bg","period_ms":50,"cpu_ms":[5],"core":1,"prio":10}}"#,
        );
        assert!(r.contains(r#""op":"admit_best_effort""#), "{r}");
        assert!(r.contains(r#""admitted":true"#) && r.contains(r#""best_effort":true"#), "{r}");
        assert_eq!(s.num_tasks(), 2);
        assert!(s.ts.tasks[1].best_effort);
        let r = line(
            &mut s,
            r#"{"op":"admit_best_effort","task":{"name":"bg","period_ms":50,"cpu_ms":[1],"prio":3}}"#,
        );
        assert!(r.contains(r#""admitted":false"#) && r.contains("duplicate"), "{r}");
        let r = line(&mut s, r#"{"op":"stats"}"#);
        assert!(r.contains(r#""be_admits":1"#) && r.contains(r#""sheds":0"#), "{r}");
    }

    #[test]
    fn rt_admission_sheds_best_effort_load() {
        // TSG RR: best-effort kernels count toward every task's
        // interleaving term, so a huge BE kernel breaks tight RT
        // deadlines — exactly the shape shedding must rescue.
        let mut s = Session::new(Platform::default(), Approach::TsgRrSuspend, false);
        line(&mut s, &admit_line("a", 1000.0, 10, 0));
        let r = line(
            &mut s,
            r#"{"op":"admit_best_effort","task":{"name":"bg","period_ms":1000,"cpu_ms":[1,1],"gpu_ms":[[0.5,400]],"core":1,"prio":1}}"#,
        );
        assert!(r.contains(r#""admitted":true"#), "{r}");
        // A 50 ms deadline cannot absorb bg's 400 ms interleave share;
        // admission succeeds only by shedding bg.
        let r = line(
            &mut s,
            r#"{"op":"admit","task":{"name":"rt2","period_ms":50,"cpu_ms":[1,1],"gpu_ms":[[0.5,10]],"core":2,"prio":20}}"#,
        );
        assert!(r.contains(r#""admitted":true"#), "{r}");
        assert!(r.contains(r#""shed":["bg"]"#), "{r}");
        assert_eq!(s.num_tasks(), 2);
        assert!(s.ts.tasks.iter().all(|t| t.name != "bg"));
        let chk = line(&mut s, r#"{"op":"check"}"#);
        assert!(chk.contains(r#""schedulable":true"#), "{chk}");
        let st = line(&mut s, r#"{"op":"stats"}"#);
        assert!(st.contains(r#""sheds":1"#), "{st}");
    }

    #[test]
    fn failed_shed_restores_best_effort_tasks() {
        let mut s = Session::new(Platform::default(), Approach::TsgRrSuspend, false);
        line(&mut s, &admit_line("a", 10.0, 10, 0));
        let r = line(
            &mut s,
            r#"{"op":"admit_best_effort","task":{"name":"bg","period_ms":100,"cpu_ms":[1],"core":1,"prio":1}}"#,
        );
        assert!(r.contains(r#""admitted":true"#), "{r}");
        // 9.7 ms of CPU inside a 10 ms period on core 0 cannot fit no
        // matter how many BE tasks are shed — bg must survive intact.
        let r = line(
            &mut s,
            r#"{"op":"admit","task":{"name":"hog","period_ms":10,"cpu_ms":[9.7],"core":0,"prio":5}}"#,
        );
        assert!(r.contains(r#""admitted":false"#) && !r.contains(r#""shed""#), "{r}");
        assert_eq!(s.num_tasks(), 2);
        assert!(s.ts.tasks.iter().any(|t| t.name == "bg"), "bg restored after failed shed");
        let chk = line(&mut s, r#"{"op":"check"}"#);
        assert!(chk.contains(r#""schedulable":true"#), "{chk}");
        let st = line(&mut s, r#"{"op":"stats"}"#);
        assert!(st.contains(r#""sheds":0"#) && st.contains(r#""rejects":1"#), "{st}");
    }

    #[test]
    fn report_overload_accumulates_and_surfaces_in_stats() {
        let mut s = session();
        let r = line(&mut s, r#"{"op":"stats"}"#);
        assert!(!r.contains("be_admits"), "clean session hides the overload block: {r}");
        let r = line(&mut s, r#"{"op":"report_overload","misses":3,"aborts":1}"#);
        assert!(
            r.contains(r#""misses":3"#) && r.contains(r#""aborts":1"#) && r.contains(r#""boosts":0"#),
            "{r}"
        );
        let r = line(&mut s, r#"{"op":"report_overload","misses":2,"boosts":4}"#);
        assert!(r.contains(r#""misses":5"#) && r.contains(r#""boosts":4"#), "{r}");
        let r = line(&mut s, r#"{"op":"stats"}"#);
        assert!(
            r.contains(r#""be_admits":0"#)
                && r.contains(r#""misses":5"#)
                && r.contains(r#""aborts":1"#)
                && r.contains(r#""boosts":4"#),
            "{r}"
        );
    }

    #[test]
    fn shutdown_sets_quit_flag() {
        let mut s = session();
        let (v, quit) = s.handle_line(r#"{"op":"shutdown"}"#);
        assert!(quit);
        assert_eq!(v.to_json(), r#"{"ok":true,"op":"shutdown"}"#);
    }

    #[test]
    fn every_family_serves_admissions() {
        for approach in Approach::ALL {
            let mut s = Session::new(Platform::default(), approach, false);
            let r = line(&mut s, &admit_line("cam", 100.0, 10, 0));
            assert!(r.contains(r#""admitted":true"#), "{}: {r}", approach.label());
            let r = line(&mut s, &admit_line("lidar", 50.0, 20, 1));
            assert!(r.contains(r#""admitted":true"#), "{}: {r}", approach.label());
            let r = line(&mut s, r#"{"op":"check"}"#);
            assert!(r.contains(r#""schedulable":true"#), "{}: {r}", approach.label());
            let r = line(&mut s, r#"{"op":"remove","task":"cam"}"#);
            assert!(r.contains(r#""removed":true"#), "{}: {r}", approach.label());
        }
    }
}
