//! Wire protocol of `gcaps serve`: newline-delimited JSON requests and
//! responses.
//!
//! Requests (one object per line; `op` selects the verb):
//!
//! ```text
//! {"op":"admit","task":{"name":"cam","period_ms":100,"cpu_ms":[1,1],
//!                       "gpu_ms":[[0.5,5]],"core":0,"prio":10}}
//! {"op":"remove","task":"cam"}
//! {"op":"check"}
//! {"op":"headroom","task":"cam","param":"c"}
//! {"op":"stats"}
//! {"op":"shutdown"}
//! {"op":"admit_best_effort","task":{...}}
//! {"op":"report_overload","misses":3,"aborts":1,"boosts":0}
//! ```
//!
//! `admit_best_effort` is the degraded-mode admission verb: the task is
//! forced best-effort (no response-time guarantee, exempt from the RT
//! priority-uniqueness rule) and accepted whenever the committed RT set
//! stays schedulable alongside it. `report_overload` lets a live
//! executive feed observed deadline misses / job aborts / priority
//! boosts back into the session's overload counters (surfaced by
//! `stats` once nonzero).
//!
//! Task spec fields: `name` (unique handle), `period_ms`, optional
//! `deadline_ms` (default: period), `cpu_ms` (CPU segment WCETs, ms),
//! optional `gpu_ms` (list of `[misc_ms, exec_ms]` pairs; alternation
//! `η_c = η_g + 1` is required for GPU tasks), optional `par` (one
//! integer SM-fraction percent in `[1, 100]` per `gpu_ms` segment;
//! default all 100 = the serial whole-context model), `core`, optional
//! `gpu` engine (default 0), `prio` (unique RT priority; doubles as
//! π^g), optional `best_effort` (default false).
//!
//! Every response is a single JSON object line. Malformed lines,
//! unknown ops and invalid specs produce `{"ok":false,"error":...}` —
//! never a panic, never an exit (exit code 2 is reserved for
//! unrecoverable *startup* errors such as an unbindable TCP address).

use crate::model::{ms, GpuSegment, Task, WaitMode};
use crate::serve::json::Value;

/// A parsed request.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    Admit(TaskSpec),
    /// Degraded-mode admission: force the spec best-effort and accept
    /// it without a response-time guarantee (sheddable on overload).
    AdmitBestEffort(TaskSpec),
    Remove(String),
    Check,
    Headroom { task: String, param: Param },
    Stats,
    /// Overload telemetry from a live executive: counts accumulate into
    /// the session counters and surface through `stats`.
    ReportOverload { misses: u64, aborts: u64, boosts: u64 },
    Shutdown,
}

/// Which per-task parameter a headroom query searches over.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Param {
    /// First CPU segment WCET (max admissible extra C).
    C,
    /// First GPU segment's pure execution (max admissible extra G^e).
    Ge,
}

impl Param {
    pub fn label(&self) -> &'static str {
        match self {
            Param::C => "c",
            Param::Ge => "ge",
        }
    }
}

/// A task specification from the wire (times in ms, as in the paper's
/// tables; converted to integer µs on materialization).
#[derive(Debug, Clone, PartialEq)]
pub struct TaskSpec {
    pub name: String,
    pub period_ms: f64,
    pub deadline_ms: f64,
    pub cpu_ms: Vec<f64>,
    pub gpu_ms: Vec<(f64, f64)>,
    /// Per-segment SM fraction percents; empty = all segments serial
    /// (100%). Non-empty lists are length-matched to `gpu_ms` at parse
    /// time.
    pub par: Vec<u32>,
    pub core: usize,
    pub gpu: usize,
    pub prio: u32,
    pub best_effort: bool,
}

impl TaskSpec {
    /// Materialize as a model task at index `id` (ids equal indices in
    /// the admitted set) in the server's wait mode.
    pub fn to_task(&self, id: usize, mode: WaitMode) -> Task {
        Task {
            id,
            name: self.name.clone(),
            period: ms(self.period_ms),
            deadline: ms(self.deadline_ms),
            cpu_segments: self.cpu_ms.iter().map(|&c| ms(c)).collect(),
            gpu_segments: self
                .gpu_ms
                .iter()
                .enumerate()
                .map(|(k, &(m, e))| {
                    let seg = GpuSegment::new(ms(m), ms(e));
                    match self.par.get(k) {
                        Some(&p) => seg.with_par(p),
                        None => seg,
                    }
                })
                .collect(),
            core: self.core,
            gpu: self.gpu,
            cpu_prio: self.prio,
            gpu_prio: self.prio,
            best_effort: self.best_effort,
            mode,
        }
    }
}

fn field_str(v: &Value, key: &str) -> Result<String, String> {
    v.get(key)
        .and_then(|f| f.as_str())
        .map(str::to_string)
        .ok_or_else(|| format!("missing or non-string field {key:?}"))
}

fn field_num(v: &Value, key: &str) -> Result<f64, String> {
    let n = v
        .get(key)
        .and_then(|f| f.as_f64())
        .ok_or_else(|| format!("missing or non-numeric field {key:?}"))?;
    if n < 0.0 {
        return Err(format!("field {key:?} must be non-negative"));
    }
    Ok(n)
}

fn field_usize(v: &Value, key: &str, default: usize) -> Result<usize, String> {
    match v.get(key) {
        None => Ok(default),
        Some(f) => {
            let n = f.as_f64().ok_or_else(|| format!("non-numeric field {key:?}"))?;
            if n < 0.0 || n.fract() != 0.0 || n > u32::MAX as f64 {
                return Err(format!("field {key:?} must be a small non-negative integer"));
            }
            Ok(n as usize)
        }
    }
}

/// Largest accepted time value (ms) and segment count. Both bounds
/// keep every µs quantity the analysis derives (sums of segments,
/// starred constants, demand × jobs products) far from u64 overflow —
/// a hostile request must get an error response, not a debug-mode
/// arithmetic panic.
const MAX_TIME_MS: f64 = 1e12;
const MAX_SEGMENTS: usize = 64;

fn parse_task_spec(v: &Value) -> Result<TaskSpec, String> {
    let name = field_str(v, "name")?;
    if name.is_empty() {
        return Err("task name must be non-empty".into());
    }
    let period_ms = field_num(v, "period_ms")?;
    if period_ms <= 0.0 {
        return Err("field \"period_ms\" must be positive".into());
    }
    let deadline_ms = match v.get("deadline_ms") {
        None => period_ms,
        Some(_) => field_num(v, "deadline_ms")?,
    };
    let cpu_ms: Vec<f64> = v
        .get("cpu_ms")
        .and_then(|f| f.as_arr())
        .ok_or("missing or non-array field \"cpu_ms\"")?
        .iter()
        .map(|x| x.as_f64().filter(|n| *n >= 0.0))
        .collect::<Option<_>>()
        .ok_or("field \"cpu_ms\" must hold non-negative numbers")?;
    if cpu_ms.is_empty() {
        return Err("field \"cpu_ms\" must be non-empty".into());
    }
    let gpu_ms: Vec<(f64, f64)> = match v.get("gpu_ms") {
        None => Vec::new(),
        Some(f) => f
            .as_arr()
            .ok_or("field \"gpu_ms\" must be an array of [misc_ms, exec_ms] pairs")?
            .iter()
            .map(|seg| {
                let pair = seg.as_arr().filter(|p| p.len() == 2)?;
                let m = pair[0].as_f64().filter(|n| *n >= 0.0)?;
                let e = pair[1].as_f64().filter(|n| *n >= 0.0)?;
                Some((m, e))
            })
            .collect::<Option<_>>()
            .ok_or("field \"gpu_ms\" must hold [misc_ms, exec_ms] pairs")?,
    };
    if cpu_ms.len() > MAX_SEGMENTS || gpu_ms.len() > MAX_SEGMENTS {
        return Err(format!("at most {MAX_SEGMENTS} segments per task"));
    }
    // Fine-grain SM fractions: strict here (not deferred to
    // Task::validate) so a hostile value names the offending field in
    // the error response instead of a generic taskset rejection.
    let par: Vec<u32> = match v.get("par") {
        None => Vec::new(),
        Some(f) => f
            .as_arr()
            .ok_or("field \"par\" must be an array of integer percents")?
            .iter()
            .map(|x| {
                x.as_f64()
                    .filter(|n| (1.0..=100.0).contains(n) && n.fract() == 0.0)
                    .map(|n| n as u32)
            })
            .collect::<Option<_>>()
            .ok_or("field \"par\" must hold integers in [1, 100]")?,
    };
    if !par.is_empty() && par.len() != gpu_ms.len() {
        return Err("field \"par\" must have one entry per \"gpu_ms\" segment".into());
    }
    let times_ok = period_ms <= MAX_TIME_MS
        && deadline_ms <= MAX_TIME_MS
        && cpu_ms.iter().all(|&c| c <= MAX_TIME_MS)
        && gpu_ms.iter().all(|&(m, e)| m <= MAX_TIME_MS && e <= MAX_TIME_MS);
    if !times_ok {
        return Err(format!("time values must be at most {MAX_TIME_MS} ms"));
    }
    let prio_f = field_num(v, "prio")?;
    if prio_f.fract() != 0.0 || prio_f > u32::MAX as f64 {
        return Err("field \"prio\" must be a non-negative integer".into());
    }
    Ok(TaskSpec {
        name,
        period_ms,
        deadline_ms,
        cpu_ms,
        gpu_ms,
        par,
        core: field_usize(v, "core", 0)?,
        gpu: field_usize(v, "gpu", 0)?,
        prio: prio_f as u32,
        best_effort: v.get("best_effort").and_then(|f| f.as_bool()).unwrap_or(false),
    })
}

/// Parse one request line's JSON value. Any malformed shape is an
/// `Err(message)` — the server answers with an error response.
pub fn parse_request(v: &Value) -> Result<Request, String> {
    let op = v
        .get("op")
        .and_then(|f| f.as_str())
        .ok_or("missing or non-string field \"op\"")?;
    match op {
        "admit" => {
            let spec = v.get("task").ok_or("admit: missing field \"task\"")?;
            Ok(Request::Admit(parse_task_spec(spec).map_err(|e| format!("admit: {e}"))?))
        }
        "remove" => Ok(Request::Remove(field_str(v, "task").map_err(|e| format!("remove: {e}"))?)),
        "check" => Ok(Request::Check),
        "headroom" => {
            let task = field_str(v, "task").map_err(|e| format!("headroom: {e}"))?;
            let param = match v.get("param").and_then(|f| f.as_str()) {
                Some("c") => Param::C,
                Some("ge") => Param::Ge,
                _ => return Err("headroom: field \"param\" must be \"c\" or \"ge\"".into()),
            };
            Ok(Request::Headroom { task, param })
        }
        "stats" => Ok(Request::Stats),
        "admit_best_effort" => {
            let spec = v.get("task").ok_or("admit_best_effort: missing field \"task\"")?;
            Ok(Request::AdmitBestEffort(
                parse_task_spec(spec).map_err(|e| format!("admit_best_effort: {e}"))?,
            ))
        }
        "report_overload" => {
            let count = |key: &str| -> Result<u64, String> {
                match v.get(key) {
                    None => Ok(0),
                    Some(f) => {
                        let n = f.as_f64().ok_or_else(|| {
                            format!("report_overload: non-numeric field {key:?}")
                        })?;
                        if n < 0.0 || n.fract() != 0.0 || n >= u64::MAX as f64 {
                            return Err(format!(
                                "report_overload: field {key:?} must be a non-negative integer"
                            ));
                        }
                        Ok(n as u64)
                    }
                }
            };
            Ok(Request::ReportOverload {
                misses: count("misses")?,
                aborts: count("aborts")?,
                boosts: count("boosts")?,
            })
        }
        "shutdown" => Ok(Request::Shutdown),
        // NOTE: the unknown-op message below is pinned byte-for-byte by
        // tests/data/serve_golden.jsonl — new verbs get arms above, the
        // string stays as shipped.
        other => Err(format!(
            "unknown op {other:?} (expected admit|remove|check|headroom|stats|shutdown)"
        )),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::json::parse;

    fn req(text: &str) -> Result<Request, String> {
        parse_request(&parse(text).map_err(|e| e.to_string())?)
    }

    #[test]
    fn parses_full_admit() {
        let r = req(
            r#"{"op":"admit","task":{"name":"cam","period_ms":100,"deadline_ms":80,
                "cpu_ms":[1,1.5],"gpu_ms":[[0.5,5]],"core":1,"gpu":0,"prio":10,
                "best_effort":false}}"#,
        )
        .unwrap();
        let Request::Admit(spec) = r else { panic!("not admit") };
        assert_eq!(spec.name, "cam");
        assert_eq!(spec.deadline_ms, 80.0);
        assert_eq!(spec.cpu_ms, vec![1.0, 1.5]);
        assert_eq!(spec.gpu_ms, vec![(0.5, 5.0)]);
        assert_eq!((spec.core, spec.gpu, spec.prio), (1, 0, 10));
        let t = spec.to_task(3, WaitMode::SelfSuspend);
        assert_eq!(t.id, 3);
        assert_eq!(t.period, ms(100.0));
        assert_eq!(t.deadline, ms(80.0));
        assert_eq!(t.gpu_segments, vec![GpuSegment::new(ms(0.5), ms(5.0))]);
        t.validate().unwrap();
    }

    #[test]
    fn defaults_fill_in() {
        let r = req(
            r#"{"op":"admit","task":{"name":"t","period_ms":50,"cpu_ms":[2],"prio":1}}"#,
        )
        .unwrap();
        let Request::Admit(spec) = r else { panic!() };
        assert_eq!(spec.deadline_ms, 50.0);
        assert!(spec.gpu_ms.is_empty());
        assert_eq!((spec.core, spec.gpu), (0, 0));
        assert!(!spec.best_effort);
    }

    #[test]
    fn other_ops_parse() {
        assert_eq!(req(r#"{"op":"remove","task":"cam"}"#), Ok(Request::Remove("cam".into())));
        assert_eq!(req(r#"{"op":"check"}"#), Ok(Request::Check));
        assert_eq!(req(r#"{"op":"stats"}"#), Ok(Request::Stats));
        assert_eq!(req(r#"{"op":"shutdown"}"#), Ok(Request::Shutdown));
        assert_eq!(
            req(r#"{"op":"headroom","task":"cam","param":"ge"}"#),
            Ok(Request::Headroom { task: "cam".into(), param: Param::Ge })
        );
    }

    #[test]
    fn overload_ops_parse() {
        let r = req(
            r#"{"op":"admit_best_effort","task":{"name":"bg","period_ms":50,"cpu_ms":[2],"prio":1}}"#,
        )
        .unwrap();
        let Request::AdmitBestEffort(spec) = r else { panic!("not admit_best_effort") };
        assert_eq!(spec.name, "bg");
        assert_eq!(
            req(r#"{"op":"report_overload","misses":3,"aborts":1}"#),
            Ok(Request::ReportOverload { misses: 3, aborts: 1, boosts: 0 })
        );
        assert_eq!(
            req(r#"{"op":"report_overload"}"#),
            Ok(Request::ReportOverload { misses: 0, aborts: 0, boosts: 0 })
        );
        for bad in [
            r#"{"op":"admit_best_effort"}"#,
            r#"{"op":"report_overload","misses":-1}"#,
            r#"{"op":"report_overload","misses":1.5}"#,
            r#"{"op":"report_overload","misses":"many"}"#,
        ] {
            assert!(req(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn par_field_round_trips_and_validates() {
        let r = req(
            r#"{"op":"admit","task":{"name":"cam","period_ms":100,"cpu_ms":[1,1],
                "gpu_ms":[[0.5,5]],"par":[40],"prio":10}}"#,
        )
        .unwrap();
        let Request::Admit(spec) = r else { panic!("not admit") };
        assert_eq!(spec.par, vec![40]);
        let t = spec.to_task(0, WaitMode::SelfSuspend);
        assert_eq!(t.gpu_segments[0].par.pct(), 40);
        assert!(t.has_fine_grain());
        t.validate().unwrap();
        // Omitted par = serial segments.
        let r = req(
            r#"{"op":"admit","task":{"name":"cam","period_ms":100,"cpu_ms":[1,1],
                "gpu_ms":[[0.5,5]],"prio":10}}"#,
        )
        .unwrap();
        let Request::Admit(spec) = r else { panic!("not admit") };
        assert!(spec.par.is_empty());
        assert!(!spec.to_task(0, WaitMode::SelfSuspend).has_fine_grain());
    }

    #[test]
    fn hostile_par_values_error_not_panic() {
        for bad in [
            // wrong type / shape
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":40,"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":["x"],"prio":1}}"#,
            // out of range
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":[0],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":[101],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":[-5],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":[50.5],"prio":1}}"#,
            // length mismatch (both directions)
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1],"gpu_ms":[[1,2]],"par":[50,50],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1,1,1],"gpu_ms":[[1,2],[1,2]],"par":[50],"prio":1}}"#,
            // par without any gpu segment
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1],"par":[50],"prio":1}}"#,
        ] {
            assert!(req(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn malformed_requests_error() {
        for text in [
            r#"{}"#,
            r#"{"op":7}"#,
            r#"{"op":"frobnicate"}"#,
            r#"{"op":"admit"}"#,
            r#"{"op":"admit","task":{}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":0,"cpu_ms":[1],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[-1],"prio":1}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1],"prio":1.5}}"#,
            r#"{"op":"admit","task":{"name":"t","period_ms":10,"cpu_ms":[1],"prio":1,"gpu_ms":[[1]]}}"#,
            r#"{"op":"admit","task":{"name":"","period_ms":10,"cpu_ms":[1],"prio":1}}"#,
            r#"{"op":"remove"}"#,
            r#"{"op":"headroom","task":"x","param":"zz"}"#,
            r#"{"op":"headroom","param":"c"}"#,
        ] {
            assert!(req(text).is_err(), "{text} should fail");
        }
    }
}
