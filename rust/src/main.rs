//! `gcaps` — CLI for the GCAPS reproduction.
//!
//! ```text
//! gcaps exp <name|all> [--tasksets N] [--seed N] [--jobs N]
//!           [--format csv|jsonl|all] [per-experiment flags]
//! gcaps exp --list                    names, descriptions, per-experiment flags
//! gcaps analyze [--seed N]            one random taskset through all 9 analyses
//! gcaps sim --policy <gcaps|tsg_rr|mpcp|fmlp+|server> [--seed N] [--ms N]
//! gcaps bench [--quick] [--out DIR]   pinned RTA/DES wall-clock baseline
//! gcaps live <case|fig12|profile> [--seconds N] [--mode gcaps|tsg_rr|fmlp|mpcp|server] [--busy]
//! gcaps serve [--stdin | --tcp ADDR] [--approach LABEL] [--cpus N] [--gpus N] [--no-timing]
//! gcaps lint [--write-baseline] [--rule NAME] [--format text|jsonl] [--src DIR] [--baseline FILE]
//! ```
//!
//! The `exp` subcommand dispatches through the [`Experiment`] registry
//! (`gcaps::experiments::registry`): every experiment declares its
//! name, description and extra flags there, and `main` knows none of
//! them individually. Results flow through pluggable sinks — CSV under
//! `results/` (default), JSONL (`--format jsonl`), or both (`--format
//! all`) — plus the ASCII report on stdout; one run feeds all formats
//! without re-sweeping.
//!
//! `--jobs N` shards each experiment sweep across N worker threads
//! (default: the host's available parallelism). The sweeps derive every
//! random stream by per-cell seed-splitting, so outputs — including CSV
//! bytes — are identical for every `--jobs` value; see `src/sweep/` and
//! `tests/sweep_determinism.rs` for the guarantee.

use std::time::Duration;

use gcaps::analysis::{analyze, analyze_with_gpu_prio, Approach};
use gcaps::api::{self, SinkSpec};
use gcaps::coordinator::executor::{run as live_run, LiveMode};
use gcaps::coordinator::workload::build_case_study;
use gcaps::experiments::bench as perfbench;
use gcaps::experiments::overhead::fig12_histogram;
use gcaps::experiments::registry::Experiment;
use gcaps::experiments::{ExpConfig, Opts};
use gcaps::lint;
use gcaps::model::{config, ms, to_ms, TaskSet, WaitMode};
use gcaps::runtime::{artifacts_dir, Runtime};
use gcaps::serve;
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::cli::{fail, Args};
use gcaps::util::rng::Pcg32;

fn exp_config(args: &Args) -> ExpConfig {
    ExpConfig {
        tasksets: args.usize_flag("tasksets", 200),
        seed: args.u64_flag("seed", 2024),
        jobs: args.usize_flag("jobs", gcaps::sweep::available_jobs()),
        progress: true,
        opts: Opts::default(),
    }
}

/// Load a taskset from --taskset FILE, or generate one from --seed.
/// Unreadable or unparsable files are usage errors (exit 2), like
/// every other malformed CLI input.
fn load_or_generate(args: &Args, busy: bool, rng: &mut Pcg32) -> TaskSet {
    match args.flag("taskset") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| fail(&format!("read {path}: {e}")));
            config::parse(&text).unwrap_or_else(|e| fail(&format!("parse {path}: {e}")))
        }
        None => {
            let p = GenParams {
                mode: if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
                ..Default::default()
            };
            generate(rng, &p)
        }
    }
}

fn cmd_export(args: &Args) {
    args.reject_unknown("gcaps export", &["seed"]);
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    let ts = generate(&mut rng, &GenParams::default());
    print!("{}", config::to_text(&ts));
}

fn cmd_analyze(args: &Args) {
    args.reject_unknown("gcaps analyze", &["seed", "taskset"]);
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    for mode_busy in [false, true] {
        let ts = load_or_generate(args, mode_busy, &mut rng);
        println!(
            "-- {} taskset: {} tasks, {} GPU-using --",
            if mode_busy { "busy-wait" } else { "self-suspend" },
            ts.len(),
            ts.num_gpu_tasks()
        );
        for a in Approach::ALL.iter().filter(|a| a.is_busy() == mode_busy) {
            let res = match a {
                Approach::GcapsBusy => analyze_with_gpu_prio(&ts, true).0,
                Approach::GcapsSuspend => analyze_with_gpu_prio(&ts, false).0,
                a => analyze(&ts, *a),
            };
            let worst = ts
                .rt_tasks()
                .map(|t| {
                    res.response[t.id]
                        .map(|r| format!("{:.1}", to_ms(r)))
                        .unwrap_or_else(|| "FAIL".into())
                })
                .collect::<Vec<_>>()
                .join(" ");
            println!("  {:16} schedulable = {:5}  R(ms): {worst}", a.label(), res.schedulable);
        }
    }
}

fn cmd_sim(args: &Args) {
    args.reject_unknown(
        "gcaps sim",
        &["policy", "seed", "taskset", "ms", "trace-out", "miss-action"],
    );
    let policy = match args.flag("policy") {
        None => Policy::Gcaps,
        Some(l) => Policy::from_label(l).unwrap_or_else(|| {
            fail(&format!(
                "invalid value {l:?} for --policy (expected gcaps|tsg_rr|mpcp|fmlp+|gcaps_edf|server)"
            ))
        }),
    };
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    let ts = load_or_generate(args, false, &mut rng);
    let horizon = ms(args.u64_flag("ms", 30_000) as f64);
    let mut cfg = SimConfig::new(policy, horizon);
    if args.flag("trace-out").is_some() {
        cfg = cfg.with_trace();
    }
    if let Some(l) = args.flag("miss-action") {
        let action = gcaps::model::DeadlineMissAction::from_label(l).unwrap_or_else(|| {
            fail(&format!(
                "invalid value {l:?} for --miss-action (expected log|boost|abort|drop)"
            ))
        });
        cfg = cfg.with_miss_actions(vec![action; ts.tasks.len()]);
    }
    let res = simulate(&ts, &cfg);
    if let (Some(path), Some(trace)) = (args.flag("trace-out"), &res.trace) {
        let names: Vec<String> = ts.tasks.iter().map(|t| t.name.clone()).collect();
        let json = gcaps::sim::perfetto::to_chrome_json(trace, &names);
        std::fs::write(path, json).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote Perfetto/Chrome trace to {path} (open at ui.perfetto.dev)");
    }
    println!("policy = {}, horizon = {} ms", policy.label(), to_ms(horizon));
    for t in &ts.tasks {
        let m = &res.per_task[t.id];
        println!(
            "  tau{:<2} core {} prio {:>2}{} jobs {:>4} MORT {:>9} misses {}{}",
            t.id,
            t.core,
            t.cpu_prio,
            if t.best_effort { " BE" } else { "   " },
            m.jobs,
            m.mort().map(|v| format!("{:.2} ms", to_ms(v))).unwrap_or_else(|| "-".into()),
            m.deadline_misses,
            if m.aborted > 0 { format!(" aborted {}", m.aborted) } else { String::new() }
        );
    }
    println!(
        "  GPU: busy {:.1} ms, {} context switches ({:.1} ms in θ)",
        to_ms(res.run.gpu_busy),
        res.run.gpu_context_switches,
        to_ms(res.run.gpu_switch_time)
    );
}

fn cmd_bench(args: &Args) {
    args.reject_unknown("gcaps bench", &["quick", "out"]);
    let quick = args.flag("quick").is_some();
    let out = std::path::PathBuf::from(args.flag("out").unwrap_or("."));
    println!(
        "-- gcaps bench{}: pinned fig8b RTA panel + 5-policy DES panel (seed {}) --",
        if quick { " --quick" } else { "" },
        perfbench::BENCH_SEED
    );
    let (rta, des) =
        perfbench::run_all(quick, &out).unwrap_or_else(|e| panic!("write bench artifacts: {e}"));
    println!("{}", rta.report());
    println!("{}", des.report());
    println!(
        "wrote {} and {}",
        out.join("BENCH_rta.json").display(),
        out.join("BENCH_des.json").display()
    );
}

fn live_mode(args: &Args) -> LiveMode {
    match args.flag("mode").unwrap_or("gcaps") {
        "gcaps" => LiveMode::Gcaps,
        "tsg_rr" => LiveMode::TsgRr,
        "fmlp" | "fmlp+" => LiveMode::FmlpPlus,
        "mpcp" => LiveMode::Mpcp,
        "server" => LiveMode::Server,
        other => fail(&format!(
            "invalid value {other:?} for --mode (expected gcaps|tsg_rr|fmlp|mpcp|server)"
        )),
    }
}

fn cmd_live(args: &Args) {
    args.reject_unknown("gcaps live", &["seconds", "mode", "busy"]);
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("case");
    let rt = Runtime::load_dir(&artifacts_dir()).expect("load artifacts (run `make artifacts`)");
    let busy = args.flag("busy").is_some();
    let (tasks, launch_ms) = build_case_study(&rt, busy).expect("build case study");
    match sub {
        "profile" => {
            println!("-- live Table 4 analog (per-launch ms, profiled) --");
            for (t, lm) in tasks.iter().zip(&launch_ms) {
                let g: f64 =
                    t.gpu_segments.iter().map(|s| s.launches as f64 * lm).sum();
                println!(
                    "  {:12} T = {:>6.0} ms  C = {:>5.1} ms  G = {:>6.1} ms  prio {}{}",
                    t.name,
                    t.period.as_secs_f64() * 1e3,
                    t.cpu_segments.iter().map(|d| d.as_secs_f64() * 1e3).sum::<f64>(),
                    g,
                    t.gpu_prio,
                    if t.rt { "" } else { " (best-effort)" }
                );
            }
        }
        "fig12" => {
            let secs = args.u64_flag("seconds", 20);
            let res = live_run(&tasks, &rt, LiveMode::Gcaps, Duration::from_secs(secs));
            let us: Vec<f64> =
                res.eps_samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
            println!("{}", fig12_histogram(&us, "live"));
        }
        _ => {
            let secs = args.u64_flag("seconds", 10);
            let mode = live_mode(args);
            println!("-- live case study: mode {}, {} s --", mode.label(), secs);
            let res = live_run(&tasks, &rt, mode, Duration::from_secs(secs));
            for (t, m) in tasks.iter().zip(&res.per_task) {
                println!(
                    "  {:12} jobs {:>3}  MORT {:>8.1} ms  misses {}",
                    t.name,
                    m.responses.len(),
                    m.mort().map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0),
                    m.misses
                );
            }
            println!("  {} kernel launches, {} ε samples", res.launches, res.eps_samples.len());
        }
    }
}

/// `gcaps serve`: the long-running admission-control server. Flag
/// errors and unbindable addresses are startup failures (exit 2);
/// everything after startup answers on the protocol stream instead.
fn cmd_serve(args: &Args) {
    args.reject_unknown(
        "gcaps serve",
        &["stdin", "tcp", "approach", "cpus", "gpus", "no-timing"],
    );
    let approach = match args.flag("approach") {
        None => Approach::GcapsSuspend,
        Some(l) => Approach::from_label(l).unwrap_or_else(|| {
            fail(&format!(
                "invalid value {l:?} for --approach (expected one of: {})",
                Approach::ALL.map(|a| a.label()).join("|")
            ))
        }),
    };
    let num_gpus = args.usize_flag("gpus", 1);
    if num_gpus == 0 {
        fail("--gpus must be at least 1");
    }
    let mut platform = gcaps::model::Platform::default().with_num_gpus(num_gpus);
    platform.num_cpus = args.usize_flag("cpus", platform.num_cpus);
    if platform.num_cpus == 0 {
        fail("--cpus must be at least 1");
    }
    let cfg = serve::ServeConfig { platform, approach, timing: args.flag("no-timing").is_none() };
    let result = match args.flag("tcp") {
        Some(addr) => serve::serve_tcp(&cfg, addr),
        None => serve::serve_stdio(&cfg), // --stdin is the default front-end
    };
    result.unwrap_or_else(|e| fail(&format!("serve: {e}")));
}

/// The common `gcaps exp` flags every experiment accepts.
const EXP_COMMON_FLAGS: [&str; 5] = ["tasksets", "seed", "jobs", "format", "list"];

/// Map `--format` to the sinks attached to every selected experiment.
fn sink_spec(args: &Args) -> SinkSpec {
    match args.flag("format").unwrap_or("csv") {
        "csv" => SinkSpec { csv: true, ..SinkSpec::default() }.with_ascii(),
        "jsonl" => SinkSpec { jsonl: true, ..SinkSpec::default() }.with_ascii(),
        "all" => SinkSpec { csv: true, jsonl: true, ..SinkSpec::default() }.with_ascii(),
        other => fail(&format!(
            "invalid value {other:?} for --format (expected csv|jsonl|all)"
        )),
    }
}

/// Generic experiment dispatch: every experiment comes from the
/// registry — `main` holds no per-experiment knowledge.
fn cmd_exp(args: &Args) {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let selected: Vec<&'static dyn Experiment> = if which == "all" {
        gcaps::experiments::registry::all_set()
    } else {
        vec![api::find(which).unwrap_or_else(|| {
            fail(&format!(
                "unknown experiment {which:?} (expected one of: {}|all; see `gcaps exp --list`)",
                api::list().iter().map(|e| e.name()).collect::<Vec<_>>().join("|")
            ))
        })]
    };

    // Reject unknown flags against the selected experiments' declared
    // sets (for `all`: the union over the whole registry, since each
    // experiment picks up its own flags from the shared command line).
    // Runs before the --list early exit so a typo'd flag never passes
    // silently.
    let mut allowed: Vec<&str> = EXP_COMMON_FLAGS.to_vec();
    for exp in &selected {
        allowed.extend(exp.flags().iter().map(|f| f.name));
    }
    args.reject_unknown(&format!("gcaps exp {which}"), &allowed);

    if args.flag("list").is_some() {
        print!("experiments (gcaps exp <name>):\n{}", api::render_list());
        return;
    }

    let spec = sink_spec(args);
    let base = exp_config(args);

    // Build and validate EVERY selected experiment's options up front:
    // a bad value must abort before any sweeping starts, not mid-way
    // through an expensive `exp all` run.
    let runs: Vec<(&'static dyn Experiment, ExpConfig)> = selected
        .into_iter()
        .map(|exp| {
            let mut opts = Opts::default();
            for f in exp.flags() {
                if let Some(v) = args.flag(f.name) {
                    opts = opts.set(f.name, v);
                }
            }
            let cfg = ExpConfig { opts, ..base.clone() };
            gcaps::experiments::registry::validate(exp, &cfg)
                .unwrap_or_else(|e| fail(&e.to_string()));
            (exp, cfg)
        })
        .collect();

    for (exp, cfg) in runs {
        if which == "all" {
            println!("\n================ {} ================", exp.name());
        }
        let report =
            api::run_experiment(exp, &cfg, &spec).unwrap_or_else(|e| fail(&e.to_string()));
        print!("{}", report.ascii);
        for path in &report.outputs {
            println!("wrote {}", path.display());
        }
    }
}

/// `gcaps lint`: run the invariant rules over this crate's sources
/// and diff against the committed baseline. Exit 0 when clean, 1 on
/// findings outside the baseline, 2 on usage errors.
fn cmd_lint(args: &Args) {
    args.reject_unknown(
        "gcaps lint",
        &["src", "baseline", "rule", "format", "write-baseline"],
    );
    let src = match args.flag("src") {
        Some(dir) => std::path::PathBuf::from(dir),
        // Default: whichever of rust/src (repo root) or src (crate
        // root) exists from here.
        None => ["rust/src", "src"]
            .into_iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.is_dir())
            .unwrap_or_else(|| fail("no rust/src or src directory here; pass --src DIR")),
    };
    let baseline_path = match (args.flag("baseline"), src.parent()) {
        (Some(p), _) => std::path::PathBuf::from(p),
        (None, Some(dir)) => dir.join("lint_baseline.txt"),
        (None, None) => std::path::PathBuf::from("lint_baseline.txt"),
    };
    let rules: Vec<Box<dyn lint::Rule>> = match args.flag("rule") {
        None => lint::all_rules(),
        Some(id) => {
            let picked: Vec<_> =
                lint::all_rules().into_iter().filter(|r| r.id() == id).collect();
            if picked.is_empty() {
                fail(&format!(
                    "unknown rule {id:?} (expected one of: {})",
                    lint::rule_ids().join("|")
                ));
            }
            picked
        }
    };
    let jsonl = match args.flag("format").unwrap_or("text") {
        "text" => false,
        "jsonl" => true,
        other => fail(&format!("invalid value {other:?} for --format (expected text|jsonl)")),
    };

    let findings = lint::lint_tree(&src, &rules)
        .unwrap_or_else(|e| fail(&format!("lint {}: {e}", src.display())));

    if args.flag("write-baseline").is_some() {
        lint::baseline::write(&baseline_path, &findings)
            .unwrap_or_else(|e| fail(&format!("write {}: {e}", baseline_path.display())));
        eprintln!(
            "wrote {} ({} finding{})",
            baseline_path.display(),
            findings.len(),
            if findings.len() == 1 { "" } else { "s" }
        );
        return;
    }

    let base = lint::baseline::load(&baseline_path)
        .unwrap_or_else(|e| fail(&format!("read {}: {e}", baseline_path.display())));
    let (new, stale) = lint::diff_baseline(&findings, &base);
    for f in &new {
        if jsonl {
            println!("{}", f.render_jsonl());
        } else {
            println!("{}", f.render());
        }
    }
    for line in &stale {
        eprintln!("stale baseline entry (fixed? run --write-baseline): {line}");
    }
    if new.is_empty() {
        eprintln!(
            "lint clean: {} finding{} total, all baselined",
            findings.len(),
            if findings.len() == 1 { "" } else { "s" }
        );
    } else {
        eprintln!(
            "lint: {} new finding{} (fix, add `// gcaps-lint: allow(rule) -- reason`, \
             or --write-baseline)",
            new.len(),
            if new.len() == 1 { "" } else { "s" }
        );
        std::process::exit(1);
    }
}

fn main() {
    let args = Args::from_env();
    match args.positional.first().map(|s| s.as_str()) {
        Some("analyze") => cmd_analyze(&args),
        Some("export") => cmd_export(&args),
        Some("sim") => cmd_sim(&args),
        Some("exp") => cmd_exp(&args),
        Some("bench") => cmd_bench(&args),
        Some("live") => cmd_live(&args),
        Some("serve") => cmd_serve(&args),
        Some("lint") => cmd_lint(&args),
        _ => {
            eprintln!(
                "usage: gcaps <analyze|sim|exp|bench|live|serve|lint> [...]\n\
                 \n\
                 gcaps analyze [--seed N | --taskset FILE]\n\
                 gcaps export [--seed N]                 # dump a generated taskset file\n\
                 gcaps sim --policy <gcaps|tsg_rr|mpcp|fmlp+|gcaps_edf|server> [--seed N | --taskset FILE]\n\
                 \x20         [--ms N] [--trace-out trace.json] [--miss-action log|boost|abort|drop]\n\
                 gcaps exp <name|all> [--tasksets N] [--seed N] [--jobs N]\n\
                 \x20         [--format csv|jsonl|all] [per-experiment flags]\n\
                 gcaps exp --list                        # registered experiments + their flags\n\
                 \x20         (every experiment is dispatched through the Experiment registry;\n\
                 \x20          CSVs land in results/, --format jsonl adds machine-readable\n\
                 \x20          JSONL from the same run; --jobs shards the sweep across N\n\
                 \x20          workers with byte-identical results for every worker count)\n\
                 gcaps bench [--quick] [--out DIR]       # pinned RTA/DES wall-clock baseline\n\
                 \x20         (writes BENCH_rta.json / BENCH_des.json; --quick for CI smoke)\n\
                 gcaps live <case|fig12|profile> [--seconds N] [--mode gcaps|tsg_rr|fmlp|mpcp|server] [--busy]\n\
                 gcaps serve [--stdin | --tcp ADDR] [--approach LABEL] [--cpus N] [--gpus N]\n\
                 \x20         [--no-timing]             # admission-control server (newline-JSON;\n\
                 \x20          ops: admit/admit_best_effort/remove/check/headroom/stats/\n\
                 \x20          report_overload/shutdown; incremental RTA with warm-started fixed\n\
                 \x20          points; admit sheds best-effort tasks under overload; --no-timing\n\
                 \x20          zeroes latency stats for byte-stable transcripts)\n\
                 gcaps lint [--write-baseline] [--rule NAME] [--format text|jsonl]\n\
                 \x20         [--src DIR] [--baseline FILE]  # invariant lint over the sources\n\
                 \x20          (rules: det-iter|lock-hygiene|panic-path|time-arith|wall-clock;\n\
                 \x20          exits 1 on findings not in rust/lint_baseline.txt)"
            );
            std::process::exit(2);
        }
    }
}
