//! `gcaps` — CLI for the GCAPS reproduction.
//!
//! ```text
//! gcaps exp <fig3|fig5|fig6|fig7|examples|fig8|fig9|fig10|fig11|table5|fig12|fig13|ablation|multigpu|scenarios|all>
//!           [--panel a..f] [--board xavier|orin] [--only epstheta|edfvfp|hetero]
//!           [--tasksets N] [--seed N] [--jobs N]
//! gcaps analyze [--seed N]            one random taskset through all 8 analyses
//! gcaps sim --policy <gcaps|tsg_rr|mpcp|fmlp+> [--seed N] [--ms N]
//! gcaps bench [--quick] [--out DIR]   pinned RTA/DES wall-clock baseline
//! gcaps live <case|fig12|profile> [--seconds N] [--mode gcaps|tsg_rr|fmlp|mpcp] [--busy]
//! ```
//!
//! Experiment outputs land in `results/` (CSV) and on stdout (ASCII).
//!
//! `--jobs N` shards each experiment sweep across N worker threads
//! (default: the host's available parallelism). The sweeps derive every
//! random stream by per-cell seed-splitting, so outputs — including CSV
//! bytes — are identical for every `--jobs` value; see `src/sweep/` and
//! `tests/sweep_determinism.rs` for the guarantee.

use std::time::Duration;

use gcaps::analysis::{analyze, analyze_with_gpu_prio, Approach};
use gcaps::coordinator::executor::{run as live_run, LiveMode};
use gcaps::coordinator::workload::build_case_study;
use gcaps::experiments::bench as perfbench;
use gcaps::experiments::casestudy::{run_fig10, run_fig11, run_table5, Board};
use gcaps::experiments::examples_figs::{run_examples, run_fig3, run_fig5, run_fig6, run_fig7};
use gcaps::experiments::fig8::{run_and_report as fig8, Panel};
use gcaps::experiments::fig9::run_and_report as fig9;
use gcaps::experiments::multigpu::run_and_report as run_multigpu;
use gcaps::experiments::ablation::run_and_report as run_ablation;
use gcaps::experiments::scenarios::{self, run_and_report as run_scenarios};
use gcaps::experiments::overhead::{fig12_histogram, run_fig12_sim, run_fig13};
use gcaps::experiments::ExpConfig;
use gcaps::model::{config, ms, to_ms, TaskSet, WaitMode};
use gcaps::runtime::{artifacts_dir, Runtime};
use gcaps::sim::{simulate, Policy, SimConfig};
use gcaps::taskgen::{generate, GenParams};
use gcaps::util::rng::Pcg32;

struct Args {
    positional: Vec<String>,
    flags: std::collections::HashMap<String, String>,
}

fn parse_args() -> Args {
    let mut positional = Vec::new();
    let mut flags = std::collections::HashMap::new();
    let mut it = std::env::args().skip(1).peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let val = if it.peek().is_some_and(|v| !v.starts_with("--")) {
                it.next().unwrap()
            } else {
                "true".to_string()
            };
            flags.insert(name.to_string(), val);
        } else {
            positional.push(a);
        }
    }
    Args { positional, flags }
}

impl Args {
    fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Strict flag parsing: an absent flag yields the default, but a
    /// present-and-malformed value is an error naming the flag — a typo
    /// like `--tasksets 1O0` or `--jobs 4x` must never silently run the
    /// experiment with the default value. (A flag given without a value
    /// parses as the literal "true" and fails the same way.)
    fn parse_flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value {v:?} for --{name}")),
        }
    }

    fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.parse_flag(name, default).unwrap_or_else(|e| fail(&e))
    }

    fn u64_flag(&self, name: &str, default: u64) -> u64 {
        self.parse_flag(name, default).unwrap_or_else(|e| fail(&e))
    }
}

/// Print a CLI error and exit with status 2 (the usage-error status).
fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

fn exp_config(args: &Args) -> ExpConfig {
    ExpConfig {
        tasksets: args.usize_flag("tasksets", 200),
        seed: args.u64_flag("seed", 2024),
        jobs: args.usize_flag("jobs", gcaps::sweep::available_jobs()),
        progress: true,
    }
}

/// Load a taskset from --taskset FILE, or generate one from --seed.
fn load_or_generate(args: &Args, busy: bool, rng: &mut Pcg32) -> TaskSet {
    match args.flag("taskset") {
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .unwrap_or_else(|e| panic!("read {path}: {e}"));
            config::parse(&text).unwrap_or_else(|e| panic!("parse {path}: {e}"))
        }
        None => {
            let p = GenParams {
                mode: if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
                ..Default::default()
            };
            generate(rng, &p)
        }
    }
}

fn cmd_export(args: &Args) {
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    let ts = generate(&mut rng, &GenParams::default());
    print!("{}", config::to_text(&ts));
}

fn cmd_analyze(args: &Args) {
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    for mode_busy in [false, true] {
        let ts = load_or_generate(args, mode_busy, &mut rng);
        println!(
            "-- {} taskset: {} tasks, {} GPU-using --",
            if mode_busy { "busy-wait" } else { "self-suspend" },
            ts.len(),
            ts.num_gpu_tasks()
        );
        for a in Approach::ALL.iter().filter(|a| a.is_busy() == mode_busy) {
            let res = match a {
                Approach::GcapsBusy => analyze_with_gpu_prio(&ts, true).0,
                Approach::GcapsSuspend => analyze_with_gpu_prio(&ts, false).0,
                a => analyze(&ts, *a),
            };
            let worst = ts
                .rt_tasks()
                .map(|t| {
                    res.response[t.id]
                        .map(|r| format!("{:.1}", to_ms(r)))
                        .unwrap_or_else(|| "FAIL".into())
                })
                .collect::<Vec<_>>()
                .join(" ");
            println!("  {:16} schedulable = {:5}  R(ms): {worst}", a.label(), res.schedulable);
        }
    }
}

fn cmd_sim(args: &Args) {
    let policy = match args.flag("policy") {
        None => Policy::Gcaps,
        Some(l) => Policy::from_label(l).unwrap_or_else(|| {
            fail(&format!(
                "invalid value {l:?} for --policy (expected gcaps|tsg_rr|mpcp|fmlp+|gcaps_edf)"
            ))
        }),
    };
    let mut rng = Pcg32::seeded(args.u64_flag("seed", 1));
    let ts = load_or_generate(args, false, &mut rng);
    let horizon = ms(args.u64_flag("ms", 30_000) as f64);
    let mut cfg = SimConfig::new(policy, horizon);
    if args.flag("trace-out").is_some() {
        cfg = cfg.with_trace();
    }
    let res = simulate(&ts, &cfg);
    if let (Some(path), Some(trace)) = (args.flag("trace-out"), &res.trace) {
        let names: Vec<String> = ts.tasks.iter().map(|t| t.name.clone()).collect();
        let json = gcaps::sim::perfetto::to_chrome_json(trace, &names);
        std::fs::write(path, json).unwrap_or_else(|e| panic!("write {path}: {e}"));
        println!("wrote Perfetto/Chrome trace to {path} (open at ui.perfetto.dev)");
    }
    println!("policy = {}, horizon = {} ms", policy.label(), to_ms(horizon));
    for t in &ts.tasks {
        let m = &res.per_task[t.id];
        println!(
            "  tau{:<2} core {} prio {:>2}{} jobs {:>4} MORT {:>9} misses {}",
            t.id,
            t.core,
            t.cpu_prio,
            if t.best_effort { " BE" } else { "   " },
            m.jobs,
            m.mort().map(|v| format!("{:.2} ms", to_ms(v))).unwrap_or_else(|| "-".into()),
            m.deadline_misses
        );
    }
    println!(
        "  GPU: busy {:.1} ms, {} context switches ({:.1} ms in θ)",
        to_ms(res.run.gpu_busy),
        res.run.gpu_context_switches,
        to_ms(res.run.gpu_switch_time)
    );
}

fn cmd_bench(args: &Args) {
    let quick = args.flag("quick").is_some();
    let out = std::path::PathBuf::from(args.flag("out").unwrap_or("."));
    println!(
        "-- gcaps bench{}: pinned fig8b RTA panel + 5-policy DES panel (seed {}) --",
        if quick { " --quick" } else { "" },
        perfbench::BENCH_SEED
    );
    let (rta, des) =
        perfbench::run_all(quick, &out).unwrap_or_else(|e| panic!("write bench artifacts: {e}"));
    println!("{}", rta.report());
    println!("{}", des.report());
    println!(
        "wrote {} and {}",
        out.join("BENCH_rta.json").display(),
        out.join("BENCH_des.json").display()
    );
}

fn live_mode(args: &Args) -> LiveMode {
    match args.flag("mode").unwrap_or("gcaps") {
        "gcaps" => LiveMode::Gcaps,
        "tsg_rr" => LiveMode::TsgRr,
        "fmlp" | "fmlp+" => LiveMode::FmlpPlus,
        "mpcp" => LiveMode::Mpcp,
        other => fail(&format!(
            "invalid value {other:?} for --mode (expected gcaps|tsg_rr|fmlp|mpcp)"
        )),
    }
}

fn cmd_live(args: &Args) {
    let sub = args.positional.get(1).map(|s| s.as_str()).unwrap_or("case");
    let rt = Runtime::load_dir(&artifacts_dir()).expect("load artifacts (run `make artifacts`)");
    let busy = args.flag("busy").is_some();
    let (tasks, launch_ms) = build_case_study(&rt, busy).expect("build case study");
    match sub {
        "profile" => {
            println!("-- live Table 4 analog (per-launch ms, profiled) --");
            for (t, lm) in tasks.iter().zip(&launch_ms) {
                let g: f64 =
                    t.gpu_segments.iter().map(|s| s.launches as f64 * lm).sum();
                println!(
                    "  {:12} T = {:>6.0} ms  C = {:>5.1} ms  G = {:>6.1} ms  prio {}{}",
                    t.name,
                    t.period.as_secs_f64() * 1e3,
                    t.cpu_segments.iter().map(|d| d.as_secs_f64() * 1e3).sum::<f64>(),
                    g,
                    t.gpu_prio,
                    if t.rt { "" } else { " (best-effort)" }
                );
            }
        }
        "fig12" => {
            let secs = args.u64_flag("seconds", 20);
            let res = live_run(&tasks, &rt, LiveMode::Gcaps, Duration::from_secs(secs));
            let us: Vec<f64> =
                res.eps_samples.iter().map(|d| d.as_secs_f64() * 1e6).collect();
            println!("{}", fig12_histogram(&us, "live"));
        }
        _ => {
            let secs = args.u64_flag("seconds", 10);
            let mode = live_mode(args);
            println!("-- live case study: mode {}, {} s --", mode.label(), secs);
            let res = live_run(&tasks, &rt, mode, Duration::from_secs(secs));
            for (t, m) in tasks.iter().zip(&res.per_task) {
                println!(
                    "  {:12} jobs {:>3}  MORT {:>8.1} ms  misses {}",
                    t.name,
                    m.responses.len(),
                    m.mort().map(|d| d.as_secs_f64() * 1e3).unwrap_or(0.0),
                    m.misses
                );
            }
            println!("  {} kernel launches, {} ε samples", res.launches, res.eps_samples.len());
        }
    }
}

fn cmd_exp(args: &Args) {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    let cfg = exp_config(args);
    let board = match args.flag("board") {
        None | Some("xavier") => Board::XavierNx,
        Some("orin") => Board::OrinNano,
        Some(other) => {
            fail(&format!("invalid value {other:?} for --board (expected xavier|orin)"))
        }
    };
    let run_one = |name: &str| match name {
        "fig3" => print!("{}", run_fig3()),
        "fig5" => print!("{}", run_fig5()),
        "fig6" => print!("{}", run_fig6()),
        "fig7" => print!("{}", run_fig7()),
        "fig8" => {
            let panels: Vec<Panel> = match args.flag("panel") {
                Some(l) => vec![Panel::from_letter(l).unwrap_or_else(|| {
                    fail(&format!("invalid value {l:?} for --panel (expected a..f)"))
                })],
                None => Panel::ALL.to_vec(),
            };
            for p in panels {
                print!("{}", fig8(p, &cfg));
            }
        }
        "fig9" => print!("{}", fig9(&cfg)),
        "fig10" => print!("{}", run_fig10(board, &cfg)),
        "fig11" => print!("{}", run_fig11(&cfg)),
        "table5" => print!("{}", run_table5(&cfg)),
        "fig12" => print!("{}", run_fig12_sim()),
        "fig13" => print!("{}", run_fig13(&cfg)),
        "examples" => print!("{}", run_examples(&cfg)),
        "ablation" => print!("{}", run_ablation(&cfg)),
        "multigpu" => print!("{}", run_multigpu(&cfg)),
        "scenarios" => {
            let only = args.flag("only");
            if let Some(o) = only {
                if !scenarios::SCENARIOS.contains(&o) {
                    fail(&format!(
                        "invalid value {o:?} for --only (expected epstheta|edfvfp|hetero)"
                    ));
                }
            }
            print!("{}", run_scenarios(&cfg, only));
        }
        other => fail(&format!(
            "unknown experiment {other:?} (expected fig3|fig5|fig6|fig7|examples|fig8|\
             fig9|fig10|fig11|table5|fig12|fig13|ablation|multigpu|scenarios|all)"
        )),
    };
    if which == "all" {
        for name in [
            "examples", "fig8", "fig9", "fig10", "fig11", "table5", "fig12", "fig13",
            "ablation", "multigpu", "scenarios",
        ] {
            println!("\n================ {name} ================");
            run_one(name);
        }
        // Fig. 10b (Orin) as part of `all`.
        println!("\n================ fig10 (orin) ================");
        print!("{}", run_fig10(Board::OrinNano, &cfg));
    } else {
        run_one(which);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_with(flags: &[(&str, &str)]) -> Args {
        Args {
            positional: vec![],
            flags: flags.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect(),
        }
    }

    #[test]
    fn absent_flag_yields_the_default() {
        let a = args_with(&[]);
        assert_eq!(a.parse_flag("jobs", 7usize), Ok(7));
        assert_eq!(a.parse_flag::<u64>("seed", 2024), Ok(2024));
    }

    #[test]
    fn well_formed_values_parse() {
        let a = args_with(&[("tasksets", "100"), ("seed", "42")]);
        assert_eq!(a.parse_flag("tasksets", 1usize), Ok(100));
        assert_eq!(a.parse_flag::<u64>("seed", 1), Ok(42));
    }

    #[test]
    fn malformed_values_error_naming_the_flag() {
        // Regression: `--tasksets 1O0` / `--jobs 4x` used to silently
        // run the experiment with the default value.
        let a = args_with(&[("tasksets", "1O0"), ("jobs", "4x")]);
        let e = a.parse_flag::<usize>("tasksets", 200).unwrap_err();
        assert!(e.contains("--tasksets") && e.contains("1O0"), "{e}");
        let e = a.parse_flag::<usize>("jobs", 8).unwrap_err();
        assert!(e.contains("--jobs") && e.contains("4x"), "{e}");
    }

    #[test]
    fn valueless_numeric_flag_is_an_error() {
        // `gcaps exp --jobs --seed 5` leaves jobs = "true" (flag with no
        // value): must error, not silently use the default.
        let a = args_with(&[("jobs", "true")]);
        assert!(a.parse_flag::<usize>("jobs", 1).is_err());
    }

    #[test]
    fn negative_and_overflowing_values_are_errors() {
        let a = args_with(&[("tasksets", "-5"), ("seed", "99999999999999999999999999")]);
        assert!(a.parse_flag::<usize>("tasksets", 1).is_err());
        assert!(a.parse_flag::<u64>("seed", 1).is_err());
    }
}

fn main() {
    let args = parse_args();
    match args.positional.first().map(|s| s.as_str()) {
        Some("analyze") => cmd_analyze(&args),
        Some("export") => cmd_export(&args),
        Some("sim") => cmd_sim(&args),
        Some("exp") => cmd_exp(&args),
        Some("bench") => cmd_bench(&args),
        Some("live") => cmd_live(&args),
        _ => {
            eprintln!(
                "usage: gcaps <analyze|sim|exp|bench|live> [...]\n\
                 \n\
                 gcaps analyze [--seed N | --taskset FILE]\n\
                 gcaps export [--seed N]                 # dump a generated taskset file\n\
                 gcaps sim --policy <gcaps|tsg_rr|mpcp|fmlp+|gcaps_edf> [--seed N | --taskset FILE]\n\
                 \x20         [--ms N] [--trace-out trace.json]\n\
                 gcaps exp <fig3|fig5|fig6|fig7|examples|fig8|fig9|fig10|fig11|table5|fig12|fig13|ablation|multigpu|scenarios|all>\n\
                 \x20         [--panel a..f] [--board xavier|orin] [--only epstheta|edfvfp|hetero]\n\
                 \x20         [--tasksets N] [--seed N] [--jobs N]\n\
                 \x20         (--jobs shards the sweep across N workers; results and CSV bytes\n\
                 \x20          are byte-identical for every worker count — per-cell seed-splitting;\n\
                 \x20          `exp multigpu` sweeps the platform over 1/2/4 GPU engines;\n\
                 \x20          `exp scenarios` runs the beyond-the-paper sweeps: per-board ε×θ\n\
                 \x20          grids, EDF vs FP, heterogeneous multi-GPU — --only picks one)\n\
                 gcaps bench [--quick] [--out DIR]       # pinned RTA/DES wall-clock baseline\n\
                 \x20         (writes BENCH_rta.json / BENCH_des.json; --quick for CI smoke)\n\
                 gcaps live <case|fig12|profile> [--seconds N] [--mode gcaps|tsg_rr|fmlp|mpcp] [--busy]"
            );
            std::process::exit(2);
        }
    }
}
