//! Execution traces and ASCII Gantt rendering — used to reproduce the
//! paper's schedule illustrations (Figs. 3, 5, 6, 7) and to debug the
//! policies. The engine emits an interval per (resource, occupant,
//! activity) stretch; the renderer draws one row per task per resource.

use crate::model::{Time, to_ms};

/// A scheduling resource in the simulated platform. GPU rows carry the
/// engine id so multi-GPU traces stay disentangled (single-GPU traces
/// use `Gpu(0)`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Resource {
    Core(usize),
    Gpu(usize),
}

/// What the occupant was doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Activity {
    /// Plain CPU segment execution.
    CpuSeg,
    /// GPU-segment misc CPU work (kernel launches, G^m).
    GpuMisc,
    /// Busy-waiting on the CPU during pure GPU execution.
    BusyWait,
    /// Driver runlist-update call (GCAPS ε, CPU side).
    DriverCall,
    /// Pure GPU execution (G^e).
    GpuExec,
    /// GPU context switch (θ) — occupant is the incoming task.
    CtxSwitch,
    /// GPU-segment misc work (G^m) executed by the GPU server on the
    /// requester's behalf (`Policy::Server`) — occupant is the
    /// requester, resource is the engine the server drives.
    ServerMisc,
    /// A hung GPU segment (injected fault) occupying its engine until
    /// the hang-timeout watchdog aborts the job.
    GpuHang,
}

impl Activity {
    fn glyph(&self) -> char {
        match self {
            Activity::CpuSeg => '#',
            Activity::GpuMisc => 'm',
            Activity::BusyWait => 'w',
            Activity::DriverCall => 'e',
            Activity::GpuExec => 'G',
            Activity::CtxSwitch => 's',
            Activity::ServerMisc => 'S',
            Activity::GpuHang => 'x',
        }
    }
}

/// One contiguous interval of `task` on `resource`.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub resource: Resource,
    pub task: usize,
    pub activity: Activity,
    pub start: Time,
    pub end: Time,
}

/// A full run trace.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    pub releases: Vec<(usize, Time)>,
    pub completions: Vec<(usize, Time)>,
}

impl Trace {
    pub fn push(&mut self, ev: TraceEvent) {
        if ev.end > ev.start {
            self.events.push(ev);
        }
    }

    /// Total time `task` spent on `resource` in [t0, t1).
    pub fn occupancy(&self, resource: Resource, task: usize, t0: Time, t1: Time) -> Time {
        self.events
            .iter()
            .filter(|e| e.resource == resource && e.task == task)
            .map(|e| e.end.min(t1).saturating_sub(e.start.max(t0)))
            .sum()
    }

    /// Render an ASCII Gantt chart of [t0, t1) at `cols` columns.
    /// One row per task per resource it ever occupied.
    pub fn gantt(&self, num_cores: usize, num_tasks: usize, t0: Time, t1: Time, cols: usize) -> String {
        let mut out = String::new();
        let span = (t1 - t0).max(1);
        let col_of = |t: Time| -> usize {
            (((t.saturating_sub(t0)) as u128 * cols as u128) / span as u128) as usize
        };
        let mut resources: Vec<Resource> =
            (0..num_cores).map(Resource::Core).collect();
        // One GPU row per engine seen in the trace (at least engine 0).
        let mut gpu_ids: Vec<usize> = self
            .events
            .iter()
            .filter_map(|e| match e.resource {
                Resource::Gpu(g) => Some(g),
                _ => None,
            })
            .collect();
        gpu_ids.sort_unstable();
        gpu_ids.dedup();
        if gpu_ids.is_empty() {
            gpu_ids.push(0);
        }
        // Single-GPU traces keep the legacy "GPU " row label; as soon
        // as any engine other than 0 appears, every row is numbered
        // (incl. engine 0) so "GPU1" cannot be misread as the first
        // engine. Keyed on the ids present — matching the Chrome
        // export's detection — not on their count, so a trace whose
        // only GPU work ran on engine 1 still renders "GPU1".
        let multi_gpu = gpu_ids.iter().any(|&g| g > 0);
        resources.extend(gpu_ids.into_iter().map(Resource::Gpu));
        for res in resources {
            let res_label = match res {
                Resource::Core(k) => format!("CPU{k}"),
                Resource::Gpu(g) if multi_gpu => format!("GPU{g}"),
                Resource::Gpu(_) => "GPU ".to_string(),
            };
            for task in 0..num_tasks {
                let evs: Vec<&TraceEvent> = self
                    .events
                    .iter()
                    .filter(|e| e.resource == res && e.task == task && e.start < t1 && e.end > t0)
                    .collect();
                if evs.is_empty() {
                    continue;
                }
                let mut row = vec![' '; cols];
                for e in evs {
                    let a = col_of(e.start.max(t0));
                    let b = col_of(e.end.min(t1)).min(cols.saturating_sub(1));
                    for c in row.iter_mut().take(b + 1).skip(a) {
                        *c = e.activity.glyph();
                    }
                }
                out.push_str(&format!(
                    "{res_label} tau{task:<2} |{}|\n",
                    row.iter().collect::<String>()
                ));
            }
        }
        out.push_str(&format!(
            "time: {:.1} .. {:.1} ms   (# cpu, m misc, w busy-wait, e driver, G gpu, s ctx-switch, S server-misc, x hang)\n",
            to_ms(t0),
            to_ms(t1)
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_drops_empty_intervals() {
        let mut t = Trace::default();
        t.push(TraceEvent {
            resource: Resource::Gpu(0),
            task: 0,
            activity: Activity::GpuExec,
            start: 5,
            end: 5,
        });
        assert!(t.events.is_empty());
    }

    #[test]
    fn occupancy_clips_to_window() {
        let mut t = Trace::default();
        t.push(TraceEvent {
            resource: Resource::Core(0),
            task: 1,
            activity: Activity::CpuSeg,
            start: 0,
            end: 100,
        });
        assert_eq!(t.occupancy(Resource::Core(0), 1, 50, 80), 30);
        assert_eq!(t.occupancy(Resource::Core(0), 2, 0, 100), 0);
        assert_eq!(t.occupancy(Resource::Gpu(0), 1, 0, 100), 0);
    }

    #[test]
    fn gantt_renders_rows() {
        let mut t = Trace::default();
        t.push(TraceEvent {
            resource: Resource::Core(0),
            task: 0,
            activity: Activity::CpuSeg,
            start: 0,
            end: 1000,
        });
        t.push(TraceEvent {
            resource: Resource::Gpu(0),
            task: 0,
            activity: Activity::GpuExec,
            start: 1000,
            end: 2000,
        });
        let s = t.gantt(1, 1, 0, 2000, 40);
        assert!(s.contains("CPU0 tau0"));
        assert!(s.contains("GPU  tau0"));
        assert!(s.contains('#') && s.contains('G'));
    }
}
