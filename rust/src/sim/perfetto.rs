//! Chrome-trace / Perfetto export of simulator traces.
//!
//! Emits the (legacy, universally-supported) Chrome Trace Event JSON
//! format: one "process" per resource (CPU cores + the GPU), one
//! "thread" per task, complete events (`ph: "X"`) per interval. Open
//! the file at <https://ui.perfetto.dev> to inspect schedules
//! interactively — the supported way to eyeball Figs. 3-7 at scale.

use crate::sim::trace::{Activity, Resource, Trace};

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn activity_name(a: Activity) -> &'static str {
    match a {
        Activity::CpuSeg => "cpu_segment",
        Activity::GpuMisc => "gpu_misc (G^m)",
        Activity::BusyWait => "busy_wait",
        Activity::DriverCall => "runlist_update (ε)",
        Activity::GpuExec => "gpu_exec (G^e)",
        Activity::CtxSwitch => "ctx_switch (θ)",
        Activity::ServerMisc => "server_misc (G^m via server)",
        Activity::GpuHang => "gpu_hang (injected)",
    }
}

fn resource_ids(r: Resource) -> (u64, &'static str) {
    match r {
        Resource::Core(k) => (k as u64, "CPU"),
        // One Chrome "process" per GPU engine, offset well past the
        // CPU pids.
        Resource::Gpu(g) => (1000 + g as u64, "GPU"),
    }
}

/// Serialize a trace (with task names) to Chrome Trace Event JSON.
pub fn to_chrome_json(trace: &Trace, task_names: &[String]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |s: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        out.push_str(&s);
        *first = false;
    };

    // Process metadata: names for the resource rows. A single-GPU
    // trace keeps the legacy bare "GPU" process name; multi-GPU traces
    // number every engine, including engine 0.
    let multi_gpu = trace
        .events
        .iter()
        .any(|e| matches!(e.resource, Resource::Gpu(g) if g > 0));
    let mut seen: Vec<u64> = Vec::new();
    for ev in &trace.events {
        let (pid, kind) = resource_ids(ev.resource);
        if !seen.contains(&pid) {
            seen.push(pid);
            let name = match ev.resource {
                Resource::Core(k) => format!("{kind}{k}"),
                Resource::Gpu(g) if multi_gpu => format!("{kind}{g}"),
                Resource::Gpu(_) => kind.to_string(),
            };
            push(
                format!(
                    "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid},\"args\":{{\"name\":\"{}\"}}}}",
                    esc(&name)
                ),
                &mut first,
            );
        }
    }
    // Thread metadata: task names within each resource.
    for &pid in &seen {
        for (tid, name) in task_names.iter().enumerate() {
            push(
                format!(
                    "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\"{}\"}}}}",
                    esc(name)
                ),
                &mut first,
            );
        }
    }
    // Interval events (timestamps already in µs — Chrome's unit).
    for ev in &trace.events {
        let (pid, _) = resource_ids(ev.resource);
        push(
            format!(
                "{{\"ph\":\"X\",\"name\":\"{}\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"dur\":{}}}",
                activity_name(ev.activity),
                ev.task,
                ev.start,
                ev.end - ev.start
            ),
            &mut first,
        );
    }
    // Release/completion instant markers.
    for &(task, t) in &trace.releases {
        push(
            format!(
                "{{\"ph\":\"i\",\"name\":\"release\",\"pid\":0,\"tid\":{task},\"ts\":{t},\"s\":\"g\"}}"
            ),
            &mut first,
        );
    }
    for &(task, t) in &trace.completions {
        push(
            format!(
                "{{\"ph\":\"i\",\"name\":\"complete\",\"pid\":0,\"tid\":{task},\"ts\":{t},\"s\":\"g\"}}"
            ),
            &mut first,
        );
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
    use crate::sim::{simulate, Policy, SimConfig};

    fn sample_trace() -> (Trace, Vec<String>) {
        let t = Task {
            id: 0,
            name: "cam".into(),
            period: ms(50.0),
            deadline: ms(50.0),
            cpu_segments: vec![ms(1.0), ms(1.0)],
            gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![t], Platform::default());
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(100.0)).with_trace());
        (sim.trace.unwrap(), vec!["cam".into()])
    }

    #[test]
    fn emits_valid_shape() {
        let (tr, names) = sample_trace();
        let json = to_chrome_json(&tr, &names);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("gpu_exec"));
        assert!(json.contains("runlist_update"));
        assert!(json.contains("\"name\":\"release\""));
        // Balanced braces (cheap structural check).
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn escapes_names() {
        let (tr, _) = sample_trace();
        let json = to_chrome_json(&tr, &vec!["we\"ird\\name".into()]);
        assert!(json.contains("we\\\"ird\\\\name"));
    }

    #[test]
    fn durations_nonnegative() {
        let (tr, names) = sample_trace();
        let json = to_chrome_json(&tr, &names);
        assert!(!json.contains("\"dur\":-"));
    }
}
