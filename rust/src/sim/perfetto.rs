//! Chrome-trace / Perfetto export of simulator traces.
//!
//! Emits the (legacy, universally-supported) Chrome Trace Event JSON
//! format: one "process" per resource (CPU cores + the GPU), one
//! "thread" per task, complete events (`ph: "X"`) per interval. Open
//! the file at <https://ui.perfetto.dev> to inspect schedules
//! interactively — the supported way to eyeball Figs. 3-7 at scale.

use crate::sim::trace::{Activity, Resource, Trace};

/// Per-event co-residency flags: `flags[i]` is true iff GPU event `i`
/// overlaps in time with another task's event on the same engine —
/// i.e. the fine-grain model had both contexts resident at once. A
/// serial trace keeps one context per engine at any instant, so no
/// flag is ever set there and everything gated on the flags leaves
/// legacy output byte-identical.
fn co_resident_flags(trace: &Trace) -> Vec<bool> {
    let mut flags = vec![false; trace.events.len()];
    // Per-engine index lists sorted by start for a near-linear sweep.
    let mut by_engine: Vec<(usize, Vec<usize>)> = Vec::new();
    for (i, ev) in trace.events.iter().enumerate() {
        if let Resource::Gpu(g) = ev.resource {
            match by_engine.iter_mut().find(|(e, _)| *e == g) {
                Some((_, v)) => v.push(i),
                None => by_engine.push((g, vec![i])),
            }
        }
    }
    for (_, mut idx) in by_engine {
        idx.sort_by_key(|&i| trace.events[i].start);
        for (k, &i) in idx.iter().enumerate() {
            let a = &trace.events[i];
            for &j in &idx[k + 1..] {
                let b = &trace.events[j];
                if b.start >= a.end {
                    break;
                }
                if b.task != a.task {
                    flags[i] = true;
                    flags[j] = true;
                }
            }
        }
    }
    flags
}

fn esc(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

fn activity_name(a: Activity) -> &'static str {
    match a {
        Activity::CpuSeg => "cpu_segment",
        Activity::GpuMisc => "gpu_misc (G^m)",
        Activity::BusyWait => "busy_wait",
        Activity::DriverCall => "runlist_update (ε)",
        Activity::GpuExec => "gpu_exec (G^e)",
        Activity::CtxSwitch => "ctx_switch (θ)",
        Activity::ServerMisc => "server_misc (G^m via server)",
        Activity::GpuHang => "gpu_hang (injected)",
    }
}

fn resource_ids(r: Resource) -> (u64, &'static str) {
    match r {
        Resource::Core(k) => (k as u64, "CPU"),
        // One Chrome "process" per GPU engine, offset well past the
        // CPU pids.
        Resource::Gpu(g) => (1000 + g as u64, "GPU"),
    }
}

/// Serialize a trace (with task names) to Chrome Trace Event JSON.
pub fn to_chrome_json(trace: &Trace, task_names: &[String]) -> String {
    let mut out = String::from("{\"traceEvents\":[\n");
    let mut first = true;
    let mut push = |s: String, first: &mut bool| {
        if !*first {
            out.push_str(",\n");
        }
        out.push_str(&s);
        *first = false;
    };

    let co = co_resident_flags(trace);

    // Process metadata: names for the resource rows. A single-GPU
    // trace keeps the legacy bare "GPU" process name; multi-GPU traces
    // number every engine, including engine 0.
    let multi_gpu = trace
        .events
        .iter()
        .any(|e| matches!(e.resource, Resource::Gpu(g) if g > 0));
    let mut seen: Vec<u64> = Vec::new();
    for ev in &trace.events {
        let (pid, kind) = resource_ids(ev.resource);
        if !seen.contains(&pid) {
            seen.push(pid);
            let name = match ev.resource {
                Resource::Core(k) => format!("{kind}{k}"),
                Resource::Gpu(g) if multi_gpu => format!("{kind}{g}"),
                Resource::Gpu(_) => kind.to_string(),
            };
            push(
                format!(
                    "{{\"ph\":\"M\",\"name\":\"process_name\",\"pid\":{pid},\"args\":{{\"name\":\"{}\"}}}}",
                    esc(&name)
                ),
                &mut first,
            );
        }
    }
    // Engines that ever co-ran two contexts get a "fine-grain" label
    // on their process row; each resident already renders on its own
    // per-task sub-track (thread) inside the engine process.
    let mut labeled: Vec<u64> = Vec::new();
    for (i, ev) in trace.events.iter().enumerate() {
        if co[i] {
            let (pid, _) = resource_ids(ev.resource);
            if !labeled.contains(&pid) {
                labeled.push(pid);
                push(
                    format!(
                        "{{\"ph\":\"M\",\"name\":\"process_labels\",\"pid\":{pid},\"args\":{{\"labels\":\"fine-grain co-running\"}}}}"
                    ),
                    &mut first,
                );
            }
        }
    }
    // Thread metadata: task names within each resource.
    for &pid in &seen {
        for (tid, name) in task_names.iter().enumerate() {
            push(
                format!(
                    "{{\"ph\":\"M\",\"name\":\"thread_name\",\"pid\":{pid},\"tid\":{tid},\"args\":{{\"name\":\"{}\"}}}}",
                    esc(name)
                ),
                &mut first,
            );
        }
    }
    // Interval events (timestamps already in µs — Chrome's unit).
    // Co-resident stretches carry an args marker so they can be
    // queried/highlighted in the Perfetto UI; serial traces never set
    // the flag and keep the legacy event bytes.
    for (i, ev) in trace.events.iter().enumerate() {
        let (pid, _) = resource_ids(ev.resource);
        let args = if co[i] { ",\"args\":{\"co_resident\":true}" } else { "" };
        push(
            format!(
                "{{\"ph\":\"X\",\"name\":\"{}\",\"pid\":{pid},\"tid\":{},\"ts\":{},\"dur\":{}{args}}}",
                activity_name(ev.activity),
                ev.task,
                ev.start,
                ev.end - ev.start
            ),
            &mut first,
        );
    }
    // Release/completion instant markers.
    for &(task, t) in &trace.releases {
        push(
            format!(
                "{{\"ph\":\"i\",\"name\":\"release\",\"pid\":0,\"tid\":{task},\"ts\":{t},\"s\":\"g\"}}"
            ),
            &mut first,
        );
    }
    for &(task, t) in &trace.completions {
        push(
            format!(
                "{{\"ph\":\"i\",\"name\":\"complete\",\"pid\":0,\"tid\":{task},\"ts\":{t},\"s\":\"g\"}}"
            ),
            &mut first,
        );
    }
    out.push_str("\n]}\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
    use crate::sim::{simulate, Policy, SimConfig};

    fn sample_trace() -> (Trace, Vec<String>) {
        let t = Task {
            id: 0,
            name: "cam".into(),
            period: ms(50.0),
            deadline: ms(50.0),
            cpu_segments: vec![ms(1.0), ms(1.0)],
            gpu_segments: vec![GpuSegment::new(ms(0.5), ms(5.0))],
            core: 0,
            gpu: 0,
            cpu_prio: 1,
            gpu_prio: 1,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![t], Platform::default());
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(100.0)).with_trace());
        (sim.trace.unwrap(), vec!["cam".into()])
    }

    #[test]
    fn emits_valid_shape() {
        let (tr, names) = sample_trace();
        let json = to_chrome_json(&tr, &names);
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.trim_end().ends_with("]}"));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("gpu_exec"));
        assert!(json.contains("runlist_update"));
        assert!(json.contains("\"name\":\"release\""));
        // Balanced braces (cheap structural check).
        let open = json.matches('{').count();
        let close = json.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn escapes_names() {
        let (tr, _) = sample_trace();
        let json = to_chrome_json(&tr, &vec!["we\"ird\\name".into()]);
        assert!(json.contains("we\\\"ird\\\\name"));
    }

    #[test]
    fn durations_nonnegative() {
        let (tr, names) = sample_trace();
        let json = to_chrome_json(&tr, &names);
        assert!(!json.contains("\"dur\":-"));
    }

    #[test]
    fn serial_traces_carry_no_co_resident_markers() {
        let (tr, names) = sample_trace();
        let json = to_chrome_json(&tr, &names);
        assert!(!json.contains("co_resident"));
        assert!(!json.contains("process_labels"));
    }

    #[test]
    fn fine_grain_co_residents_render_as_marked_sub_tracks() {
        let mk = |id: usize, core: usize, prio: u32| Task {
            id,
            name: format!("t{id}"),
            period: ms(100.0),
            deadline: ms(100.0),
            cpu_segments: vec![ms(0.5), ms(0.5)],
            gpu_segments: vec![GpuSegment::new(ms(0.5), ms(8.0)).with_par(50)],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        };
        let ts = TaskSet::new(vec![mk(0, 0, 2), mk(1, 1, 1)], Platform::default());
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(100.0)).with_trace());
        let tr = sim.trace.unwrap();
        let json = to_chrome_json(&tr, &["t0".into(), "t1".into()]);
        // Both residents overlap on the engine → marked events on two
        // distinct tids within the GPU process, plus the engine label.
        assert!(json.contains("\"co_resident\":true"));
        assert!(json.contains("fine-grain co-running"));
        let gpu_exec_tids: Vec<usize> = tr
            .events
            .iter()
            .filter(|e| {
                matches!(e.resource, Resource::Gpu(_))
                    && matches!(e.activity, Activity::GpuExec)
            })
            .map(|e| e.task)
            .collect();
        assert!(gpu_exec_tids.contains(&0) && gpu_exec_tids.contains(&1));
        let open = json.matches('{').count();
        assert_eq!(open, json.matches('}').count());
    }
}
