//! The discrete-event engine.
//!
//! Recompute-on-event design: at every event timestamp the engine
//! (1) handles releases and phase transitions, (2) recomputes the CPU
//! allocation per core and the GPU context per policy, (3) finds the
//! next event horizon and advances all running work by that quantum.
//! All arithmetic is integer µs, so runs are exactly reproducible.
//!
//! Task lifecycle (one job):
//!
//! ```text
//! release → Cpu(0) → for k in 0..η_g:
//!     [gcaps]      DrvBegin(k): runlist-update call, α on CPU
//!     [mpcp/fmlp+] LockWait(k): queue per protocol
//!     [server]     LockWait(k): request queued to the engine's server
//!                   (priority-ordered, RT before BE, FIFO tiebreak)
//!     GpuActive(k): G^m on CPU ∥ G^e on GPU (async mode, §4 of the
//!                   paper: misc launch work and kernel execution
//!                   overlap); busy-wait keeps the CPU through G^e,
//!                   self-suspension yields it once G^m is done.
//!                   Under [server] the segment is instead executed
//!                   serially BY the server on the engine row (G^m then
//!                   G^e, non-preemptively) while the requester
//!                   self-suspends (or spins, in busy-wait mode).
//!     [gcaps]      DrvEnd(k)
//!     [mpcp/fmlp+] release lock
//!     [server]     server completes the request
//!     → Cpu(k+1)
//! → complete
//! ```
//!
//! The GCAPS driver state (`task_running` / `task_pending`) follows
//! Alg. 1 of the paper, with the §5.2 clarification that a preempting
//! real-time task displaces *all* lower-priority TSGs from the runlist
//! ("the new runlist only contains the TSGs of τ_h"). Driver calls are
//! short non-preemptible kernel sections; the real rt-mutex contention
//! is exercised and measured by the live arbiter (coordinator/), so in
//! the DES Lemma 8's (η+1)ε blocking term is pure safety margin.
//!
//! # Event-calendar hot path
//!
//! The seed engine re-scanned every task per settle round for due
//! releases, re-derived the release horizon by another full scan, and
//! checked settle quiescence with a full-state FNV fingerprint per
//! round. This engine replaces all three:
//!
//! - **Release calendar**: a min-heap of `(release time, task)` keyed
//!   so same-instant releases pop in task order — due-release handling
//!   and the release horizon are heap peeks, O(log n) per release
//!   instead of O(n) per round.
//! - **Change-tracked settle**: every transition handler reports
//!   whether it mutated scheduler-visible state; a round with no
//!   mutation is quiescent. The tracked set is a superset of what the
//!   fingerprint hashed (it additionally flags backlog-only releases,
//!   costing at most one extra no-op round), so the exit point is
//!   never earlier than the seed engine's.
//! - **Dirty completion set**: GPU-segment completions are drained
//!   from a candidate list maintained where remaining work reaches
//!   zero (`advance`, `begin_gpu_segment`) instead of an O(n) phase
//!   scan per round; candidates re-check their condition on pop.
//! - Ring refreshes iterate a per-engine task list and reuse the ring
//!   in place (the seed path allocated an eligibility `Vec` per engine
//!   per round).
//!
//! The seed engine is retained in [`crate::sim::reference`];
//! `rust/tests/kernel_equivalence.rs` pins both engines bit-identical
//! — every trace interval, release, completion and metric — across
//! random tasksets, policies and offset patterns.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::model::fault::{self, AdaptivePolicy, DeadlineMissAction, Fault, FaultPlan};
use crate::model::{TaskSet, Time, WaitMode};
use crate::sim::metrics::{RunMetrics, TaskMetrics};
use crate::sim::trace::{Activity, Resource, Trace, TraceEvent};
use crate::sim::Policy;

/// Simulation configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub policy: Policy,
    /// Simulated horizon in µs.
    pub duration: Time,
    /// Per-task initial release offsets (defaults to all-zero =
    /// synchronous release, the classic critical instant).
    pub offsets: Vec<Time>,
    /// Capture a trace (Gantt) — costs memory, off for sweeps.
    pub trace: bool,
    /// Injected faults (WCET overruns, GPU hangs, mode changes).
    /// Empty by default: steady-state behavior is bit-identical to
    /// pre-fault engines.
    pub faults: FaultPlan,
    /// Per-task deadline-miss actions (indexed by task id; missing
    /// entries default to [`DeadlineMissAction::Log`], the legacy
    /// count-only behavior).
    pub miss_actions: Vec<DeadlineMissAction>,
    /// Load-adaptive RR↔EDF policy switching (None = fixed policy).
    /// Only meaningful when `policy` is `TsgRr` or `GcapsEdf`; other
    /// start policies never switch.
    pub adaptive: Option<AdaptivePolicy>,
}

impl SimConfig {
    pub fn new(policy: Policy, duration: Time) -> SimConfig {
        SimConfig {
            policy,
            duration,
            offsets: vec![],
            trace: false,
            faults: FaultPlan::default(),
            miss_actions: vec![],
            adaptive: None,
        }
    }

    pub fn with_offsets(mut self, offsets: Vec<Time>) -> SimConfig {
        self.offsets = offsets;
        self
    }

    pub fn with_trace(mut self) -> SimConfig {
        self.trace = true;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> SimConfig {
        self.faults = faults;
        self
    }

    pub fn with_miss_actions(mut self, actions: Vec<DeadlineMissAction>) -> SimConfig {
        self.miss_actions = actions;
        self
    }

    pub fn with_adaptive(mut self, adaptive: AdaptivePolicy) -> SimConfig {
        self.adaptive = Some(adaptive);
        self
    }

    /// The miss action for task `i` (`Log` when unspecified).
    pub fn action(&self, i: usize) -> DeadlineMissAction {
        self.miss_actions.get(i).copied().unwrap_or_default()
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub per_task: Vec<TaskMetrics>,
    pub run: RunMetrics,
    pub trace: Option<Trace>,
}

impl SimResult {
    /// True iff no RT task missed a deadline.
    pub fn no_rt_misses(&self, ts: &TaskSet) -> bool {
        ts.rt_tasks().all(|t| self.per_task[t.id].deadline_misses == 0)
    }
}

// ---------------------------------------------------------------------
// Internal state
// ---------------------------------------------------------------------

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    /// No active job.
    Idle,
    /// Executing cpu_segments[seg].
    Cpu,
    /// GCAPS: executing the driver runlist-update call (α CPU work).
    /// Calls are non-preemptible on their core (the update polls the
    /// runlist submission registers in a tight kernel loop, §5.2); the
    /// rt-mutex contention of the real driver is exercised by the live
    /// arbiter (`coordinator/`), not the DES — Lemma 8's (η+1)ε blocking
    /// is pure safety margin here.
    DrvCall { ending: bool },
    /// MPCP/FMLP+: waiting in the GPU lock queue.
    LockWait,
    /// GPU segment active: cpu_rem = G^m left, gpu_rem = G^e left.
    GpuActive,
}

#[derive(Debug, Clone)]
struct TState {
    phase: Phase,
    /// Current segment index: CPU segment `seg`, GPU segment `seg` next.
    seg: usize,
    /// Remaining µs of the current CPU-side work (Cpu/DrvCall/G^m).
    cpu_rem: Time,
    /// Remaining µs of the current pure GPU execution.
    gpu_rem: Time,
    release: Time,
    abs_deadline: Time,
    /// Backlogged releases (job arrived while previous still running).
    backlog: VecDeque<Time>,
    /// Timestamp the current driver call (incl. mutex wait) started.
    drv_started: Time,
    /// Lock-policy FIFO ticket (FMLP+ ordering).
    ticket: u64,
    /// Index of the NEXT job to start (0-based; the current job's
    /// index is `job - 1`). Keys `FaultPlan` lookups.
    job: u64,
    /// WCET scaling of the current job (percent; 100 = nominal).
    cpu_pct: u32,
    gpu_pct: u32,
    /// The current job's hung GPU segment, if one is injected.
    hang_seg: Option<usize>,
    /// The currently-running GPU segment is the hung one (its
    /// `gpu_rem` counts down the hang timeout, not real work).
    hanging: bool,
    /// `DeadlineMissAction::Boost` applied to the current job.
    boosted: bool,
    /// The current job's deadline miss has been acted on (non-Log
    /// actions fire at most once per job).
    miss_handled: bool,
}

/// One fine-grain co-resident context on an engine's SMs (fine mode
/// only: some task fraction < 100% and the policy can host partial
/// contexts). Each resident progresses at FULL rate — the engine's SMs
/// are capacity-partitioned between residents (the RTGPU fine-grain
/// premise, arXiv 2101.10463) — and carries its own θ-switch and
/// TSG-slice state, preserving per-context preemption-boundary and
/// slice semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Resident {
    task: usize,
    /// Remaining θ of this resident's admission context switch.
    switch_rem: Time,
    /// Remaining time slice (RR rotation happens per resident).
    slice_rem: Time,
}

/// GCAPS driver state (Alg. 1) + the device state of ONE GPU engine.
/// Multi-GPU platforms hold one `GpuState` per engine: runlists, TSG
/// rings and driver/lock queues are fully independent across engines
/// (tasks are statically assigned via `Task::gpu`).
#[derive(Debug, Clone, Default)]
struct GpuState {
    /// Alg. 1 task_running (TSGs on the runlist).
    running: Vec<usize>,
    /// Alg. 1 task_pending.
    pending: Vec<usize>,
    /// Context currently executing on the GPU.
    context: Option<usize>,
    /// Remaining θ of an in-progress switch (charged to the incoming).
    switch_rem: Time,
    /// Remaining time slice of the current context.
    slice_rem: Time,
    /// FIFO ring of time-shared TSGs (all tasks under tsg_rr; the
    /// best-effort group under gcaps). Front = next/current to run.
    ring: VecDeque<usize>,
    /// Lock-policy: GPU lock holder.
    lock_holder: Option<usize>,
    /// Lock-policy: waiting (task, ticket).
    lock_queue: Vec<(usize, u64)>,
    ticket_counter: u64,
    /// Fine mode: co-resident contexts, kept sorted by task id. Always
    /// empty in serial mode (every fraction 100%), so every legacy code
    /// path is untouched then.
    residents: Vec<Resident>,
    /// Server fine mode: requests granted alongside `lock_holder` while
    /// the resident fractions (holder + co-holders) sum to ≤ 100%.
    co_holders: Vec<usize>,
}

struct Engine<'a> {
    ts: &'a TaskSet,
    cfg: &'a SimConfig,
    now: Time,
    st: Vec<TState>,
    /// One device/driver state per GPU engine (index = `Task::gpu`).
    gpus: Vec<GpuState>,
    /// Release calendar: min-heap of (next release, task). Exactly one
    /// outstanding entry per task; ties pop in task order, matching the
    /// seed engine's index-order release scan.
    calendar: BinaryHeap<Reverse<(Time, usize)>>,
    /// Tasks assigned to each engine (ascending), for ring refreshes.
    on_engine: Vec<Vec<usize>>,
    /// Dirty GPU-completion candidates: tasks whose remaining segment
    /// work reached zero; re-checked when drained in settle().
    gpu_done: Vec<usize>,
    metrics: Vec<TaskMetrics>,
    run: RunMetrics,
    trace: Option<Trace>,
    cpu_alloc: Vec<Option<usize>>,
    /// The ACTIVE policy — equals `cfg.policy` unless the
    /// load-adaptive governor has switched it (RR↔EDF).
    pol: Policy,
    /// Dropped tasks (`DropTask` miss action / mode-change disable):
    /// releases are discarded while set.
    paused: Vec<bool>,
    /// Injected mode changes, sorted by time (stable, so equal-time
    /// changes apply in plan order): (at, disable, enable).
    mode_changes: Vec<(Time, Vec<usize>, Vec<usize>)>,
    mode_idx: usize,
    /// Sliding miss-ratio window for the adaptive governor:
    /// (completion/abort time, missed).
    mwin: VecDeque<(Time, bool)>,
    win_jobs: u64,
    win_misses: u64,
    /// Any non-Log deadline-miss action configured? (Gates the
    /// per-round miss scan so Log-only runs skip it entirely.)
    has_miss_actions: bool,
    /// Fine-grain co-running engaged: some GPU segment declares an SM
    /// fraction < 100% AND the policy can host partial contexts. The
    /// mutex baselines (MPCP/FMLP+) serialize whole contexts by
    /// construction — a fine taskset under them runs the serial engine
    /// unchanged (documented pessimism). Constant per run: the adaptive
    /// governor only flips TsgRr↔GcapsEdf, both fine-capable.
    fine: bool,
}

impl<'a> Engine<'a> {
    fn new(ts: &'a TaskSet, cfg: &'a SimConfig) -> Engine<'a> {
        let n = ts.tasks.len();
        let st = (0..n)
            .map(|_| TState {
                phase: Phase::Idle,
                seg: 0,
                cpu_rem: 0,
                gpu_rem: 0,
                release: 0,
                abs_deadline: 0,
                backlog: Default::default(),
                drv_started: 0,
                ticket: 0,
                job: 0,
                cpu_pct: 100,
                gpu_pct: 100,
                hang_seg: None,
                hanging: false,
                boosted: false,
                miss_handled: false,
            })
            .collect();
        let mut calendar = BinaryHeap::with_capacity(n);
        for i in 0..n {
            calendar.push(Reverse((cfg.offsets.get(i).copied().unwrap_or(0), i)));
        }
        let mut on_engine = vec![Vec::new(); ts.platform.num_gpus()];
        for (i, t) in ts.tasks.iter().enumerate() {
            on_engine[t.gpu].push(i);
        }
        let mut mode_changes: Vec<(Time, Vec<usize>, Vec<usize>)> = cfg
            .faults
            .faults
            .iter()
            .filter_map(|f| match f {
                Fault::ModeChange { at, disable, enable } => {
                    Some((*at, disable.clone(), enable.clone()))
                }
                _ => None,
            })
            .collect();
        mode_changes.sort_by_key(|m| m.0);
        let has_miss_actions =
            cfg.miss_actions.iter().any(|a| *a != DeadlineMissAction::Log);
        let fine = ts.has_fine_grain()
            && !matches!(cfg.policy, Policy::Mpcp | Policy::FmlpPlus);
        Engine {
            ts,
            cfg,
            now: 0,
            st,
            gpus: vec![GpuState::default(); ts.platform.num_gpus()],
            calendar,
            on_engine,
            gpu_done: Vec::new(),
            metrics: vec![TaskMetrics::default(); n],
            run: RunMetrics::default(),
            trace: cfg.trace.then(Trace::default),
            cpu_alloc: vec![None; ts.platform.num_cpus],
            pol: cfg.policy,
            paused: vec![false; n],
            mode_changes,
            mode_idx: 0,
            mwin: VecDeque::new(),
            win_jobs: 0,
            win_misses: 0,
            has_miss_actions,
            fine,
        }
    }

    /// The engine id task `i` is assigned to.
    fn gpu_of(&self, i: usize) -> usize {
        self.ts.tasks[i].gpu
    }

    /// SM fraction (percent) of task `i`'s CURRENT GPU segment
    /// (`st[i].seg`): 100 = whole-context serial, and the fallback for
    /// any state outside a GPU segment. Only meaningful in fine mode.
    fn frac(&self, i: usize) -> Time {
        self.ts.tasks[i]
            .gpu_segments
            .get(self.st[i].seg)
            .map(|g| g.par.pct() as Time)
            .unwrap_or(100)
    }

    /// α = ε − θ (Def. 2): the CPU-side driver-call cost on task `i`'s
    /// engine.
    fn alpha_of(&self, i: usize) -> Time {
        let ctx = self.ts.platform.gpus[self.gpu_of(i)];
        ctx.epsilon.saturating_sub(ctx.theta)
    }

    /// GPU urgency ranking: fixed π^g under GCAPS, earliest absolute job
    /// deadline under the EDF extension (higher rank = more urgent).
    /// A `Boost`-ed job outranks everything.
    fn gpu_rank(&self, i: usize) -> u64 {
        if self.st[i].boosted {
            return u64::MAX;
        }
        match self.pol {
            Policy::GcapsEdf => u64::MAX.saturating_sub(self.st[i].abs_deadline),
            _ => self.ts.tasks[i].gpu_prio as u64,
        }
    }

    // -- job lifecycle ---------------------------------------------------

    fn start_job(&mut self, i: usize, release: Time) {
        let t = &self.ts.tasks[i];
        let job = self.st[i].job;
        let (cpu_pct, gpu_pct) = self.cfg.faults.overrun(i, job);
        let hang_seg = self.cfg.faults.hang(i, job);
        let s = &mut self.st[i];
        s.job = job + 1;
        s.cpu_pct = cpu_pct;
        s.gpu_pct = gpu_pct;
        s.hang_seg = hang_seg;
        s.hanging = false;
        s.boosted = false;
        s.miss_handled = false;
        s.release = release;
        // Saturating: at long horizons (or near-MAX release offsets) the
        // unchecked sum wraps, silently inverting the EDF rank
        // (`u64::MAX - abs_deadline`) and flagging bogus misses. A
        // saturated deadline is "never" — rank 0, never missed.
        s.abs_deadline = release.saturating_add(t.deadline);
        s.seg = 0;
        s.phase = Phase::Cpu;
        s.cpu_rem = fault::scale(t.cpu_segments[0], cpu_pct);
        if let Some(tr) = &mut self.trace {
            tr.releases.push((i, release));
        }
    }

    /// Transition after cpu_segments[seg] completes.
    fn finish_cpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        let seg = self.st[i].seg;
        if seg < t.eta_g() {
            match self.pol {
                Policy::Gcaps | Policy::GcapsEdf => {
                    self.st[i].phase = Phase::DrvCall { ending: false };
                    self.st[i].cpu_rem = self.alpha_of(i);
                    self.st[i].drv_started = self.now;
                }
                Policy::Mpcp | Policy::FmlpPlus | Policy::Server => {
                    let g = self.gpu_of(i);
                    self.st[i].phase = Phase::LockWait;
                    self.gpus[g].ticket_counter += 1;
                    self.st[i].ticket = self.gpus[g].ticket_counter;
                    let ticket = self.st[i].ticket;
                    self.gpus[g].lock_queue.push((i, ticket));
                }
                Policy::TsgRr => self.begin_gpu_segment(i),
            }
        } else {
            self.complete_job(i);
        }
    }

    /// Start GPU segment `seg`: G^m on the CPU in parallel with G^e on
    /// the GPU (asynchronous launch model, paper §4). An injected hang
    /// replaces G^e with the hang timeout: the segment occupies the
    /// engine until the watchdog detects and aborts it. G^m stays
    /// nominal (CPU-side launch work); G^e scales with the overrun.
    fn begin_gpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        let seg = self.st[i].seg;
        self.st[i].phase = Phase::GpuActive;
        self.st[i].cpu_rem = t.gpu_segments[seg].misc;
        self.st[i].gpu_rem = if self.st[i].hang_seg == Some(seg) {
            self.st[i].hanging = true;
            self.cfg.faults.hang_timeout
        } else {
            fault::scale(t.gpu_segments[seg].exec, self.st[i].gpu_pct)
        };
        // Zero-length segment: completion-ready the instant it starts.
        if self.st[i].cpu_rem == 0 && self.st[i].gpu_rem == 0 {
            self.gpu_done.push(i);
        }
    }

    /// Both halves of the GPU segment are done.
    fn finish_gpu_segment(&mut self, i: usize) {
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                self.st[i].phase = Phase::DrvCall { ending: true };
                self.st[i].cpu_rem = self.alpha_of(i);
                self.st[i].drv_started = self.now;
            }
            Policy::Mpcp | Policy::FmlpPlus | Policy::Server => {
                let g = self.gpu_of(i);
                if self.fine && self.gpus[g].lock_holder != Some(i) {
                    // Server fine mode: a co-holder finished its
                    // service; the primary grant is untouched.
                    self.gpus[g].co_holders.retain(|&k| k != i);
                } else {
                    debug_assert_eq!(self.gpus[g].lock_holder, Some(i));
                    self.gpus[g].lock_holder = None;
                    // Server fine mode: the oldest co-holder becomes
                    // the primary so a fresh (capacity-unchecked)
                    // primary grant can never overcommit the SMs.
                    if self.fine && !self.gpus[g].co_holders.is_empty() {
                        let k = self.gpus[g].co_holders.remove(0);
                        self.gpus[g].lock_holder = Some(k);
                    }
                }
                self.next_cpu_segment(i);
            }
            Policy::TsgRr => self.next_cpu_segment(i),
        }
    }

    fn next_cpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        self.st[i].seg += 1;
        self.st[i].phase = Phase::Cpu;
        self.st[i].cpu_rem =
            fault::scale(t.cpu_segments[self.st[i].seg], self.st[i].cpu_pct);
    }

    fn complete_job(&mut self, i: usize) {
        let s = &mut self.st[i];
        let resp = self.now.saturating_sub(s.release);
        let missed = self.now > s.abs_deadline;
        self.metrics[i].response_times.push(resp);
        self.metrics[i].jobs += 1;
        if missed {
            self.metrics[i].deadline_misses += 1;
            self.run.last_tardy = self.now;
        }
        if self.cfg.adaptive.is_some() {
            self.mwin.push_back((self.now, missed));
            self.win_jobs += 1;
            if missed {
                self.win_misses += 1;
            }
        }
        if let Some(tr) = &mut self.trace {
            tr.completions.push((i, self.now));
        }
        let s = &mut self.st[i];
        s.phase = Phase::Idle;
        if let Some(next) = s.backlog.pop_front() {
            self.start_job(i, next);
        }
    }

    /// Abort task `i`'s in-flight job: discard partial work, release
    /// every engine/lock structure it occupies, count it in `aborted`,
    /// and start the next backlogged release (unless the task is
    /// paused). Used by `AbortJob`/`DropTask` miss actions, the GPU
    /// hang watchdog, and mode-change disables.
    fn abort_job(&mut self, i: usize) {
        let g = self.gpu_of(i);
        self.gpus[g].running.retain(|&k| k != i);
        self.gpus[g].pending.retain(|&k| k != i);
        self.gpus[g].ring.retain(|&k| k != i);
        self.gpus[g].lock_queue.retain(|&(k, _)| k != i);
        self.gpus[g].residents.retain(|r| r.task != i);
        self.gpus[g].co_holders.retain(|&k| k != i);
        if self.gpus[g].lock_holder == Some(i) {
            self.gpus[g].lock_holder = None;
            // Server fine mode: promote the oldest co-holder (see
            // finish_gpu_segment) so capacity accounting stays closed.
            if !self.gpus[g].co_holders.is_empty() {
                let k = self.gpus[g].co_holders.remove(0);
                self.gpus[g].lock_holder = Some(k);
            }
        }
        self.metrics[i].aborted += 1;
        self.run.last_tardy = self.now;
        if self.cfg.adaptive.is_some() {
            self.mwin.push_back((self.now, true));
            self.win_jobs += 1;
            self.win_misses += 1;
        }
        let s = &mut self.st[i];
        s.phase = Phase::Idle;
        s.cpu_rem = 0;
        s.gpu_rem = 0;
        s.hanging = false;
        if self.paused[i] {
            self.st[i].backlog.clear();
        } else if let Some(next) = self.st[i].backlog.pop_front() {
            self.start_job(i, next);
        }
    }

    // -- GCAPS driver (Alg. 1) --------------------------------------------

    /// Alg. 1 body, executed when the driver call's α completes. Acts
    /// on the runlist of τ_i's OWN engine only.
    fn finish_driver_call(&mut self, i: usize) {
        let g = self.gpu_of(i);
        let ending = matches!(self.st[i].phase, Phase::DrvCall { ending: true });
        if std::env::var_os("GCAPS_SIM_DEBUG").is_some() {
            eprintln!(
                "[{}] drv {} tau{} | gpu {} running {:?} pending {:?} ctx {:?}",
                self.now,
                if ending { "END" } else { "BEGIN" },
                i,
                g,
                self.gpus[g].running,
                self.gpus[g].pending,
                self.gpus[g].context
            );
        }
        let theta = self.ts.platform.gpus[g].theta;
        self.metrics[i]
            .runlist_updates
            .push(self.now.saturating_sub(self.st[i].drv_started).saturating_add(theta));
        let me = &self.ts.tasks[i];
        if !ending {
            // --- TSG_SCHEDULER(τ_i, add) ---
            if me.best_effort {
                let rt_running =
                    self.gpus[g].running.iter().any(|&k| !self.ts.tasks[k].best_effort);
                if rt_running {
                    self.gpus[g].pending.push(i);
                } else {
                    self.gpus[g].running.push(i);
                }
            } else {
                let tau_h = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .max_by_key(|&k| self.gpu_rank(k));
                let preempt = match tau_h {
                    None => true,
                    Some(h) => self.gpu_rank(i) > self.gpu_rank(h),
                };
                if preempt {
                    // §5.2: the new runlist contains only τ_i's TSGs.
                    let displaced: Vec<usize> = self.gpus[g].running.drain(..).collect();
                    self.gpus[g].pending.extend(displaced);
                    self.gpus[g].running.push(i);
                } else {
                    self.gpus[g].pending.push(i);
                }
            }
            self.begin_gpu_segment(i);
        } else {
            // --- TSG_SCHEDULER(τ_i, remove) ---
            self.gpus[g].running.retain(|&k| k != i);
            self.gpus[g].pending.retain(|&k| k != i);
            let tau_k = self.gpus[g]
                .pending
                .iter()
                .copied()
                .filter(|&k| !self.ts.tasks[k].best_effort)
                .max_by_key(|&k| self.gpu_rank(k));
            if let Some(k) = tau_k {
                self.gpus[g].pending.retain(|&x| x != k);
                self.gpus[g].running.push(k);
            } else {
                let all: Vec<usize> = self.gpus[g].pending.drain(..).collect();
                self.gpus[g].running.extend(all);
            }
            self.next_cpu_segment(i);
        }
    }

    // -- lock-based policies -----------------------------------------------

    /// Returns whether a grant happened.
    fn try_grant_lock(&mut self, g: usize) -> bool {
        let mut granted = false;
        if self.gpus[g].lock_holder.is_none() && !self.gpus[g].lock_queue.is_empty() {
            granted = self.grant_primary_lock(g);
        }
        // Server fine mode: admit further queued requests as co-holders
        // while the engine's SM capacity holds.
        if self.fine && self.pol == Policy::Server {
            granted |= self.grant_server_co_holders(g);
        }
        granted
    }

    fn grant_primary_lock(&mut self, g: usize) -> bool {
        let idx = match self.pol {
            Policy::Mpcp => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .max_by_key(|(_, &(t, tk))| {
                    (self.ts.tasks[t].cpu_prio, std::cmp::Reverse(tk))
                })
                .map(|(j, _)| j)
                .unwrap(),
            Policy::FmlpPlus => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .min_by_key(|(_, &(_, tk))| tk)
                .map(|(j, _)| j)
                .unwrap(),
            // Server: RT requests before best-effort, then by CPU
            // priority, FIFO within a priority level (Kim et al.).
            Policy::Server => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .max_by_key(|(_, &(t, tk))| {
                    (
                        !self.ts.tasks[t].best_effort,
                        self.ts.tasks[t].cpu_prio,
                        std::cmp::Reverse(tk),
                    )
                })
                .map(|(j, _)| j)
                .unwrap(),
            _ => unreachable!(),
        };
        let (task, _) = self.gpus[g].lock_queue.swap_remove(idx);
        self.gpus[g].lock_holder = Some(task);
        self.begin_gpu_segment(task);
        true
    }

    /// Server fine mode: after the primary grant, the server dispatches
    /// additional queued requests concurrently — co-holders — while the
    /// resident fractions sum to ≤ 100%, in the same RT-first /
    /// priority / FIFO order as the primary grant, skipping requests
    /// that do not fit. Each co-running service progresses at full rate
    /// on its SM partition. Returns whether any grant happened.
    fn grant_server_co_holders(&mut self, g: usize) -> bool {
        let Some(primary) = self.gpus[g].lock_holder else { return false };
        let mut cap = self.frac(primary);
        for idx in 0..self.gpus[g].co_holders.len() {
            let h = self.gpus[g].co_holders[idx];
            cap = cap.saturating_add(self.frac(h));
        }
        let mut granted = false;
        loop {
            let next = self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .filter(|(_, &(t, _))| {
                    cap.saturating_add(self.frac(t)) <= 100
                })
                .max_by_key(|(_, &(t, tk))| {
                    (
                        !self.ts.tasks[t].best_effort,
                        self.ts.tasks[t].cpu_prio,
                        std::cmp::Reverse(tk),
                    )
                })
                .map(|(j, _)| j);
            let Some(j) = next else { break };
            let (task, _) = self.gpus[g].lock_queue.swap_remove(j);
            cap = cap.saturating_add(self.frac(task));
            self.gpus[g].co_holders.push(task);
            self.begin_gpu_segment(task);
            granted = true;
        }
        granted
    }

    // -- allocation ----------------------------------------------------------

    /// Does task `i` occupy a CPU slot in its current phase?
    fn wants_cpu(&self, i: usize) -> bool {
        match self.st[i].phase {
            Phase::Cpu | Phase::DrvCall { .. } => true,
            Phase::GpuActive => {
                // Server: the server executes G^m on the requester's
                // behalf (on its own dedicated core, modelled on the
                // engine row) — the requester holds a CPU only to spin.
                if self.pol == Policy::Server {
                    self.ts.tasks[i].mode == WaitMode::BusyWait
                } else {
                    self.st[i].cpu_rem > 0 || self.ts.tasks[i].mode == WaitMode::BusyWait
                }
            }
            Phase::LockWait => self.ts.tasks[i].mode == WaitMode::BusyWait,
            Phase::Idle => false,
        }
    }

    /// Effective CPU priority: lock holders executing their critical
    /// section's CPU work are boosted (MPCP/FMLP+ priority boosting);
    /// the GCAPS driver call runs as a non-preemptible kernel section
    /// (the update spins polling the runlist hardware registers, §5.2),
    /// which also subsumes rt-mutex priority inheritance — the holder
    /// cannot be preempted, so ε-blocking stays within Lemma 8's bound.
    fn eff_prio(&self, i: usize) -> u64 {
        let base = self.ts.tasks[i].cpu_prio as u64;
        // Boosting is a lock-protocol mechanism only: the server model
        // has no critical-section CPU work on the requester's core (the
        // server owns a dedicated core), so nothing to boost.
        let boosted = matches!(self.pol, Policy::Mpcp | Policy::FmlpPlus)
            && self.gpus[self.gpu_of(i)].lock_holder == Some(i)
            && matches!(self.st[i].phase, Phase::GpuActive)
            && self.st[i].cpu_rem > 0;
        if boosted {
            return (1 << 40) | base;
        }
        // Driver-call non-preemptibility applies only once the call has
        // begun executing (the task competes at its own priority to
        // *enter* the kernel section; cpu_rem < α ⇔ it has run).
        if matches!(self.st[i].phase, Phase::DrvCall { .. })
            && self.st[i].cpu_rem < self.alpha_of(i)
        {
            return (1 << 41) | base;
        }
        // Deadline-miss Boost: the late job preempts everything on its
        // core (below kernel sections and lock boosts, which model
        // non-preemptible hardware/protocol state).
        if self.st[i].boosted {
            return (1 << 39) | base;
        }
        base
    }

    fn compute_cpu_alloc(&self) -> Vec<Option<usize>> {
        let mut alloc = vec![None::<usize>; self.ts.platform.num_cpus];
        for (i, t) in self.ts.tasks.iter().enumerate() {
            if !self.wants_cpu(i) {
                continue;
            }
            let p = self.eff_prio(i);
            match alloc[t.core] {
                None => alloc[t.core] = Some(i),
                Some(cur) => {
                    let pc = self.eff_prio(cur);
                    if (p, std::cmp::Reverse(i)) > (pc, std::cmp::Reverse(cur)) {
                        alloc[t.core] = Some(i);
                    }
                }
            }
        }
        alloc
    }

    /// Is task i's TSG eligible for its engine's time-shared ring?
    fn ring_eligible(&self, i: usize) -> bool {
        if !(matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0) {
            return false;
        }
        match self.pol {
            Policy::TsgRr => true,
            Policy::Gcaps | Policy::GcapsEdf => {
                self.ts.tasks[i].best_effort
                    && self.gpus[self.gpu_of(i)].running.contains(&i)
            }
            _ => false,
        }
    }

    /// Sync engine `g`'s ring membership with eligibility, preserving
    /// FIFO order. Allocation-free: retains in place and appends newly
    /// eligible TSGs in task order (the seed path collected an
    /// eligibility Vec per call). Returns whether membership changed.
    fn refresh_ring(&mut self, g: usize) -> bool {
        let mut ring = std::mem::take(&mut self.gpus[g].ring);
        let before = ring.len();
        ring.retain(|&i| self.ring_eligible(i));
        let mut changed = ring.len() != before;
        for &i in &self.on_engine[g] {
            if self.ring_eligible(i) && !ring.contains(&i) {
                ring.push_back(i);
                changed = true;
            }
        }
        self.gpus[g].ring = ring;
        changed
    }

    /// Which task should engine `g` execute now (pre-θ)?
    fn desired_gpu_context(&self, g: usize) -> Option<usize> {
        let execing = |i: usize| {
            matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
        };
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                // At most one RT task occupies the runlist; it runs
                // exclusively. Otherwise the BE ring time-shares.
                let rt = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .filter(|&i| !self.ts.tasks[i].best_effort && execing(i))
                    .max_by_key(|&i| self.gpu_rank(i));
                rt.or_else(|| self.gpus[g].ring.front().copied())
            }
            Policy::TsgRr => self.gpus[g].ring.front().copied(),
            Policy::Mpcp | Policy::FmlpPlus => {
                self.gpus[g].lock_holder.filter(|&i| execing(i))
            }
            // Server: the engine row models the server's service of the
            // whole request — it stays occupied through the G^m part
            // too, not just while a kernel executes.
            Policy::Server => self.gpus[g].lock_holder.filter(|&i| {
                matches!(self.st[i].phase, Phase::GpuActive)
                    && (self.st[i].cpu_rem > 0 || self.st[i].gpu_rem > 0)
            }),
        }
    }

    /// Apply engine `g`'s desired context: start a θ switch if it
    /// changed. Returns whether it did.
    fn update_gpu_context(&mut self, g: usize) -> bool {
        let want = self.desired_gpu_context(g);
        if want == self.gpus[g].context {
            return false;
        }
        match want {
            None => {
                self.gpus[g].context = None;
                self.gpus[g].switch_rem = 0;
            }
            Some(i) => {
                // θ per context switch for the driver-level policies
                // (GCAPS folds it into ε = α + θ; TSG RR pays it per
                // rotation). The sync baselines and the server are
                // modelled overhead-free, as their analyses assume (the
                // server RTA's 2ε per request is pure safety margin).
                let charge = match self.pol {
                    Policy::Mpcp | Policy::FmlpPlus | Policy::Server => 0,
                    Policy::Gcaps | Policy::GcapsEdf | Policy::TsgRr => {
                        self.ts.platform.gpus[g].theta
                    }
                };
                self.gpus[g].context = Some(i);
                self.gpus[g].switch_rem = charge;
                self.gpus[g].slice_rem = self.ts.platform.gpus[g].tsg_slice;
                if charge > 0 {
                    self.run.gpu_context_switches += 1;
                }
            }
        }
        true
    }

    // -- fine-grain co-running (fine mode only) ---------------------------
    //
    // RTGPU-style fractional SM utilization: an engine hosts several
    // resident contexts at once while their declared fractions sum to
    // ≤ 100%, and every resident progresses at FULL rate on its SM
    // partition. Admission is a greedy pack in policy order (GCAPS
    // rank / ring FIFO / server queue order) that SKIPS entries that do
    // not fit. The skip (bypass) is what keeps the RTA's fine-grain
    // charge sound in both directions:
    //
    //  - While τ_i is pending, the residents that outrank it alone
    //    occupy more than 100 − frac_i (τ_i was rejected against
    //    exactly their sum), each draining its job's G^e at full rate —
    //    the capacity-work argument behind `analysis::gcaps`'s deflated
    //    charge.
    //  - Lower-ranked tasks pack only into capacity τ_i cannot use, and
    //    the per-round repack considers τ_i before them — they are
    //    demoted the instant τ_i fits, so they never extend its wait
    //    (no-bypass packing would: a small-fraction task could stall
    //    behind a large-fraction one for a whole residency).

    /// Which tasks should engine `g`'s SMs host now (pre-θ)?
    /// Capacity-packed in policy order; empty in serial mode.
    fn desired_residents(&self, g: usize) -> Vec<usize> {
        let execing = |i: usize| {
            matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
        };
        let mut out = Vec::new();
        let mut cap: Time = 0;
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                // RT members of the runlist pack by GPU rank; the BE
                // ring packs only when no RT wants the engine (GCAPS
                // shielding, as in the serial `desired_gpu_context`).
                let mut rts: Vec<usize> = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .filter(|&i| !self.ts.tasks[i].best_effort && execing(i))
                    .collect();
                rts.sort_by(|&a, &b| {
                    self.gpu_rank(b).cmp(&self.gpu_rank(a)).then(a.cmp(&b))
                });
                for i in rts {
                    let f = self.frac(i);
                    if cap.saturating_add(f) <= 100 {
                        cap += f;
                        out.push(i);
                    }
                }
                if out.is_empty() {
                    for &i in &self.gpus[g].ring {
                        if !execing(i) {
                            continue;
                        }
                        let f = self.frac(i);
                        if cap.saturating_add(f) <= 100 {
                            cap += f;
                            out.push(i);
                        }
                    }
                }
            }
            Policy::TsgRr => {
                for &i in &self.gpus[g].ring {
                    if !execing(i) {
                        continue;
                    }
                    let f = self.frac(i);
                    if cap.saturating_add(f) <= 100 {
                        cap += f;
                        out.push(i);
                    }
                }
            }
            // Unreachable in fine mode (gated off in `new`), kept
            // equivalent to the serial selection for robustness.
            Policy::Mpcp | Policy::FmlpPlus => {
                if let Some(h) = self.gpus[g].lock_holder {
                    if execing(h) {
                        out.push(h);
                    }
                }
            }
            // Server: the primary grant plus co-holders, each occupying
            // the engine row through its whole service (G^m included).
            Policy::Server => {
                let serving = |i: usize| {
                    matches!(self.st[i].phase, Phase::GpuActive)
                        && (self.st[i].cpu_rem > 0 || self.st[i].gpu_rem > 0)
                };
                if let Some(h) = self.gpus[g].lock_holder {
                    if serving(h) {
                        out.push(h);
                    }
                }
                for &h in &self.gpus[g].co_holders {
                    if serving(h) {
                        out.push(h);
                    }
                }
            }
        }
        out
    }

    /// Apply engine `g`'s desired resident set: kept residents carry
    /// their θ/slice state over, entrants pay θ (driver policies) and
    /// start a fresh slice. Residents are stored sorted by task id so
    /// advancement and tracing are deterministic. Returns whether the
    /// membership changed.
    fn update_gpu_residents(&mut self, g: usize) -> bool {
        let mut want = self.desired_residents(g);
        want.sort_unstable();
        let same = self.gpus[g].residents.len() == want.len()
            && self.gpus[g].residents.iter().zip(&want).all(|(r, &t)| r.task == t);
        if same {
            return false;
        }
        let charge = match self.pol {
            Policy::Mpcp | Policy::FmlpPlus | Policy::Server => 0,
            Policy::Gcaps | Policy::GcapsEdf | Policy::TsgRr => {
                self.ts.platform.gpus[g].theta
            }
        };
        let slice = self.ts.platform.gpus[g].tsg_slice;
        let old = std::mem::take(&mut self.gpus[g].residents);
        let mut new = Vec::with_capacity(want.len());
        for &t in &want {
            if let Some(r) = old.iter().find(|r| r.task == t) {
                new.push(*r);
            } else {
                if charge > 0 {
                    self.run.gpu_context_switches += 1;
                }
                new.push(Resident { task: t, switch_rem: charge, slice_rem: slice });
            }
        }
        self.gpus[g].residents = new;
        true
    }

    /// Fine-mode replacement for the GCAPS completion-aware promotion:
    /// repack the execing RT segments of running ∪ pending onto the SMs
    /// greedily in rank order (with bypass — see the module comment
    /// above `desired_residents`), moving tasks between the two Alg. 1
    /// lists to match. Work-conserving: the engine never idles capacity
    /// behind a stalled or oversized task. Returns whether any task
    /// moved.
    fn rebalance_fine(&mut self, g: usize) -> bool {
        let execing = |st: &TState| {
            matches!(st.phase, Phase::GpuActive) && st.gpu_rem > 0
        };
        let mut pool: Vec<usize> = self.gpus[g]
            .running
            .iter()
            .chain(self.gpus[g].pending.iter())
            .copied()
            .filter(|&k| !self.ts.tasks[k].best_effort && execing(&self.st[k]))
            .collect();
        pool.sort_by(|&a, &b| {
            self.gpu_rank(b).cmp(&self.gpu_rank(a)).then(a.cmp(&b))
        });
        let mut cap: Time = 0;
        let mut promote = Vec::new();
        let mut demote = Vec::new();
        for &k in &pool {
            let f = self.frac(k);
            if cap.saturating_add(f) <= 100 {
                cap += f;
                if !self.gpus[g].running.contains(&k) {
                    promote.push(k);
                }
            } else if self.gpus[g].running.contains(&k) {
                demote.push(k);
            }
        }
        let changed = !promote.is_empty() || !demote.is_empty();
        for k in demote {
            self.gpus[g].running.retain(|&x| x != k);
            self.gpus[g].pending.push(k);
        }
        for k in promote {
            self.gpus[g].pending.retain(|&x| x != k);
            self.gpus[g].running.push(k);
        }
        changed
    }

    /// Fine-mode slice handling: a resident whose slice expired yields
    /// its ring position to a waiting non-resident (its entry moves to
    /// the ring's back; the next repack admits the waiter). Without a
    /// waiter — or for RT residents, which are not ring-scheduled — the
    /// slice refills quietly (not scheduler-visible, like the serial
    /// lone-TSG refill). Returns whether the ring changed.
    fn rotate_expired_residents(&mut self, g: usize) -> bool {
        let mut changed = false;
        for idx in 0..self.gpus[g].residents.len() {
            let r = self.gpus[g].residents[idx];
            if r.switch_rem != 0 || r.slice_rem != 0 {
                continue;
            }
            let in_ring = self.gpus[g].ring.contains(&r.task);
            let waiter = self.gpus[g].ring.iter().any(|&k| {
                !self.gpus[g].residents.iter().any(|x| x.task == k)
            });
            let at_back = self.gpus[g].ring.back() == Some(&r.task);
            if in_ring && waiter && !at_back {
                self.gpus[g].ring.retain(|&k| k != r.task);
                self.gpus[g].ring.push_back(r.task);
                changed = true;
            } else {
                self.gpus[g].residents[idx].slice_rem =
                    self.ts.platform.gpus[g].tsg_slice;
            }
        }
        changed
    }

    // -- main loop -------------------------------------------------------------

    /// Pop and handle every due release from the calendar. Ties pop in
    /// task order (heap keyed on `(time, task)`), matching the seed
    /// engine's index-order scan. Returns whether any release fired.
    fn release_due(&mut self) -> bool {
        let mut any = false;
        while let Some(&Reverse((t, i))) = self.calendar.peek() {
            if t > self.now {
                break;
            }
            self.calendar.pop();
            // Saturating: a next-release past u64::MAX means "never"
            // (now can only reach it after the run loop has exited).
            self.calendar.push(Reverse((t.saturating_add(self.ts.tasks[i].period), i)));
            if self.paused[i] {
                // Dropped task: discard the release (the calendar still
                // advances, so a later mode-change re-enable resumes on
                // the task's own period grid).
                continue;
            }
            if self.st[i].phase == Phase::Idle && self.st[i].backlog.is_empty() {
                self.start_job(i, t);
            } else {
                self.st[i].backlog.push_back(t);
            }
            any = true;
        }
        any
    }

    /// Apply due mode changes and evaluate the load-adaptive policy
    /// governor. Runs once per event timestamp, before `settle()` —
    /// mirrored at the same sequence point in the reference engine.
    fn fault_tick(&mut self) {
        while self.mode_idx < self.mode_changes.len()
            && self.mode_changes[self.mode_idx].0 <= self.now
        {
            let (_, disable, enable) = self.mode_changes[self.mode_idx].clone();
            for &i in &disable {
                if i >= self.st.len() {
                    continue;
                }
                self.paused[i] = true;
                if self.st[i].phase != Phase::Idle {
                    self.abort_job(i);
                } else {
                    self.st[i].backlog.clear();
                }
            }
            for &i in &enable {
                if i < self.st.len() {
                    self.paused[i] = false;
                }
            }
            self.mode_idx += 1;
        }
        if let Some(ap) = self.cfg.adaptive {
            // Evict window entries older than `window`.
            while let Some(&(t, missed)) = self.mwin.front() {
                if t.saturating_add(ap.window) < self.now {
                    self.mwin.pop_front();
                    self.win_jobs -= 1;
                    if missed {
                        self.win_misses -= 1;
                    }
                } else {
                    break;
                }
            }
            // RR→EDF when the windowed miss ratio crosses up_pct;
            // EDF→RR when it falls to down_pct (or the window empties).
            // Start policies other than TsgRr/GcapsEdf never switch.
            if self.pol == Policy::TsgRr
                && self.win_jobs >= ap.min_jobs
                && self.win_misses * 100 >= ap.up_pct as u64 * self.win_jobs
            {
                self.switch_policy(Policy::GcapsEdf);
            } else if self.pol == Policy::GcapsEdf
                && (self.win_jobs == 0
                    || (self.win_jobs >= ap.min_jobs
                        && self.win_misses * 100 <= ap.down_pct as u64 * self.win_jobs))
            {
                self.switch_policy(Policy::TsgRr);
            }
        }
    }

    /// Switch the active GPU policy, migrating per-engine structures:
    /// to EDF every in-flight GPU segment joins the runlist (the next
    /// settle round picks the earliest deadline); to RR the runlists
    /// clear (the ring, refreshed every round, takes over — stale
    /// driver-call pushes into `running` are inert under RR).
    fn switch_policy(&mut self, to: Policy) {
        if self.pol == to {
            return;
        }
        self.pol = to;
        self.run.policy_switches += 1;
        for g in 0..self.gpus.len() {
            self.gpus[g].running.clear();
            self.gpus[g].pending.clear();
            if to == Policy::GcapsEdf {
                for idx in 0..self.on_engine[g].len() {
                    let i = self.on_engine[g][idx];
                    if matches!(self.st[i].phase, Phase::GpuActive) {
                        self.gpus[g].running.push(i);
                    }
                }
            }
        }
    }

    fn next_horizon(&self) -> Time {
        let mut h = self.cfg.duration;
        // Release horizon: the calendar keeps the global minimum at its
        // root — one peek instead of the seed engine's O(n) scan.
        if let Some(&Reverse((t, _))) = self.calendar.peek() {
            h = h.min(t);
        }
        // Saturating sums: a remaining-work horizon past u64::MAX clamps
        // to MAX (≥ duration, so it never wins the min) instead of
        // wrapping to a bogus past instant.
        for &slot in &self.cpu_alloc {
            if let Some(i) = slot {
                if self.st[i].cpu_rem > 0 {
                    match self.st[i].phase {
                        Phase::Cpu | Phase::DrvCall { .. } | Phase::GpuActive => {
                            h = h.min(self.now.saturating_add(self.st[i].cpu_rem))
                        }
                        _ => {}
                    }
                }
            }
        }
        for gs in &self.gpus {
            if self.fine {
                // Fine mode: every resident contributes its own θ /
                // service / kernel horizon, plus a slice boundary when a
                // non-resident TSG is waiting on the ring.
                let contested = gs.ring.iter().any(|&k| {
                    !gs.residents.iter().any(|x| x.task == k)
                });
                for r in &gs.residents {
                    let i = r.task;
                    if r.switch_rem > 0 {
                        h = h.min(self.now.saturating_add(r.switch_rem));
                    } else if self.pol == Policy::Server
                        && matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].cpu_rem > 0
                    {
                        h = h.min(self.now.saturating_add(self.st[i].cpu_rem));
                    } else if matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].gpu_rem > 0
                    {
                        h = h.min(self.now.saturating_add(self.st[i].gpu_rem));
                        if contested && gs.ring.contains(&i) {
                            h = h.min(self.now.saturating_add(r.slice_rem));
                        }
                    }
                }
                continue;
            }
            if let Some(i) = gs.context {
                if gs.switch_rem > 0 {
                    h = h.min(self.now.saturating_add(gs.switch_rem));
                } else if self.pol == Policy::Server
                    && matches!(self.st[i].phase, Phase::GpuActive)
                    && self.st[i].cpu_rem > 0
                {
                    // Server serving the request's G^m part on the
                    // engine row (the requester may be suspended, so no
                    // CPU slot covers this work).
                    h = h.min(self.now.saturating_add(self.st[i].cpu_rem));
                } else if matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
                {
                    h = h.min(self.now.saturating_add(self.st[i].gpu_rem));
                    if gs.ring.len() > 1 && gs.ring.front() == Some(&i) {
                        h = h.min(self.now.saturating_add(gs.slice_rem));
                    }
                }
            }
        }
        // Fault/overload horizons: the next mode change, the first
        // actionable deadline miss (detected at D + 1, the first
        // instant strictly past the deadline), and the next adaptive-
        // window eviction.
        if self.mode_idx < self.mode_changes.len() {
            h = h.min(self.mode_changes[self.mode_idx].0);
        }
        if self.has_miss_actions {
            for i in 0..self.st.len() {
                if self.st[i].phase != Phase::Idle
                    && !self.st[i].miss_handled
                    && self.cfg.action(i) != DeadlineMissAction::Log
                {
                    h = h.min(self.st[i].abs_deadline.saturating_add(1));
                }
            }
        }
        if let Some(ap) = self.cfg.adaptive {
            if let Some(&(t, _)) = self.mwin.front() {
                h = h.min(t.saturating_add(ap.window).saturating_add(1));
            }
        }
        h.max(self.now)
    }

    fn advance(&mut self, dt: Time) {
        if dt == 0 {
            return;
        }
        for core in 0..self.cpu_alloc.len() {
            if let Some(i) = self.cpu_alloc[core] {
                let (act, progresses) = match self.st[i].phase {
                    Phase::Cpu => (Activity::CpuSeg, true),
                    Phase::DrvCall { .. } => (Activity::DriverCall, true),
                    Phase::GpuActive => {
                        // Server: the requester never executes G^m
                        // itself — it only spins here (busy-wait mode);
                        // the engine row drains cpu_rem.
                        if self.pol == Policy::Server {
                            (Activity::BusyWait, false)
                        } else if self.st[i].cpu_rem > 0 {
                            (Activity::GpuMisc, true)
                        } else {
                            (Activity::BusyWait, false)
                        }
                    }
                    Phase::LockWait => (Activity::BusyWait, false),
                    Phase::Idle => (Activity::CpuSeg, false),
                };
                if progresses {
                    self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(dt);
                    // G^m drained with the kernel already done: the
                    // segment is completion-ready.
                    if self.st[i].cpu_rem == 0
                        && matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].gpu_rem == 0
                    {
                        self.gpu_done.push(i);
                    }
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Core(core),
                        task: i,
                        activity: act,
                        start: self.now,
                        end: self.now.saturating_add(dt),
                    });
                }
            }
        }
        for g in 0..self.gpus.len() {
            if self.fine {
                self.advance_residents(g, dt);
                continue;
            }
            let Some(i) = self.gpus[g].context else { continue };
            if self.gpus[g].switch_rem > 0 {
                let d = dt.min(self.gpus[g].switch_rem);
                self.gpus[g].switch_rem = self.gpus[g].switch_rem.saturating_sub(d);
                self.run.gpu_switch_time += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::CtxSwitch,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if self.pol == Policy::Server
                && matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].cpu_rem > 0
            {
                // Server service, part 1: the server executes the
                // request's G^m on the requester's behalf. Serialized
                // before G^e (the server is a single thread driving the
                // engine), and not counted as gpu_busy — it is the
                // server's CPU work, rendered on the engine row.
                let d = dt.min(self.st[i].cpu_rem);
                self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(d);
                if self.st[i].cpu_rem == 0 && self.st[i].gpu_rem == 0 {
                    self.gpu_done.push(i);
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::ServerMisc,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0 {
                let d = dt.min(self.st[i].gpu_rem);
                self.st[i].gpu_rem = self.st[i].gpu_rem.saturating_sub(d);
                self.gpus[g].slice_rem = self.gpus[g].slice_rem.saturating_sub(dt);
                self.run.gpu_busy += d;
                // Kernel drained with G^m already done.
                if self.st[i].gpu_rem == 0 && self.st[i].cpu_rem == 0 {
                    self.gpu_done.push(i);
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: if self.st[i].hanging {
                            Activity::GpuHang
                        } else {
                            Activity::GpuExec
                        },
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            }
        }
        self.now = self.now.saturating_add(dt);
    }

    /// Fine-mode engine advancement: every resident progresses at FULL
    /// rate on its SM partition (capacity-partitioned SMs), in task-id
    /// order (residents are kept sorted) for deterministic traces.
    fn advance_residents(&mut self, g: usize, dt: Time) {
        for idx in 0..self.gpus[g].residents.len() {
            let r = self.gpus[g].residents[idx];
            let i = r.task;
            if r.switch_rem > 0 {
                let d = dt.min(r.switch_rem);
                self.gpus[g].residents[idx].switch_rem =
                    r.switch_rem.saturating_sub(d);
                self.run.gpu_switch_time += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::CtxSwitch,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if self.pol == Policy::Server
                && matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].cpu_rem > 0
            {
                // Server service, part 1 (see the serial branch): G^m
                // executed by the server on the requester's behalf.
                let d = dt.min(self.st[i].cpu_rem);
                self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(d);
                if self.st[i].cpu_rem == 0 && self.st[i].gpu_rem == 0 {
                    self.gpu_done.push(i);
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::ServerMisc,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].gpu_rem > 0
            {
                let d = dt.min(self.st[i].gpu_rem);
                self.st[i].gpu_rem = self.st[i].gpu_rem.saturating_sub(d);
                self.gpus[g].residents[idx].slice_rem =
                    r.slice_rem.saturating_sub(dt);
                self.run.gpu_busy += d;
                if self.st[i].gpu_rem == 0 && self.st[i].cpu_rem == 0 {
                    self.gpu_done.push(i);
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: if self.st[i].hanging {
                            Activity::GpuHang
                        } else {
                            Activity::GpuExec
                        },
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            }
        }
    }

    /// Handle all zero-time transitions at `now` until quiescent.
    ///
    /// Quiescence is change-tracked: every handler reports whether it
    /// mutated scheduler-visible state, and the loop exits as soon as a
    /// full round performs no transition — replacing the seed engine's
    /// per-round full-state FNV fingerprint. The tracked mutation set
    /// is a superset of what the fingerprint hashed (backlog-only
    /// releases additionally flag, costing at most one extra no-op
    /// round), so this never exits earlier than the seed engine;
    /// `sim::reference` + the trace-equivalence suite pin the schedules
    /// bit-identical.
    fn settle(&mut self) {
        for _round in 0..10_000 {
            let mut changed = self.release_due();

            // Deadline-miss actions: react to any active job past its
            // absolute deadline. Log-only configurations skip the scan
            // entirely (misses stay count-at-completion, the legacy
            // path). Non-Log actions fire at most once per job
            // (`miss_handled`).
            if self.has_miss_actions {
                for i in 0..self.st.len() {
                    if self.st[i].phase == Phase::Idle
                        || self.st[i].miss_handled
                        || self.now <= self.st[i].abs_deadline
                    {
                        continue;
                    }
                    match self.cfg.action(i) {
                        DeadlineMissAction::Log => {}
                        DeadlineMissAction::Boost => {
                            self.st[i].miss_handled = true;
                            self.st[i].boosted = true;
                            self.metrics[i].boosts += 1;
                            changed = true;
                        }
                        DeadlineMissAction::AbortJob => {
                            self.st[i].miss_handled = true;
                            self.abort_job(i);
                            changed = true;
                        }
                        DeadlineMissAction::DropTask => {
                            self.st[i].miss_handled = true;
                            self.paused[i] = true;
                            self.abort_job(i);
                            changed = true;
                        }
                    }
                }
            }

            // CPU-side completions (task must hold its CPU to finish
            // CPU-bound work).
            self.cpu_alloc = self.compute_cpu_alloc();
            for core in 0..self.cpu_alloc.len() {
                if let Some(i) = self.cpu_alloc[core] {
                    if self.st[i].cpu_rem == 0 {
                        match self.st[i].phase {
                            Phase::Cpu => {
                                self.finish_cpu_segment(i);
                                changed = true;
                            }
                            Phase::DrvCall { .. } => {
                                self.finish_driver_call(i);
                                changed = true;
                            }
                            _ => {}
                        }
                    }
                }
            }

            // GPU-segment completions: drained from the dirty candidate
            // list (maintained where remaining work hits zero) instead
            // of an O(n) phase scan; candidates re-check on pop and are
            // processed in ascending task order like the seed scan.
            if !self.gpu_done.is_empty() {
                let mut done = std::mem::take(&mut self.gpu_done);
                done.sort_unstable();
                done.dedup();
                for i in done {
                    if matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].cpu_rem == 0
                        && self.st[i].gpu_rem == 0
                    {
                        if self.st[i].hanging {
                            // Hang watchdog: the timeout elapsed with
                            // the segment still "running" — detect and
                            // abort the job instead of completing it.
                            self.metrics[i].hangs += 1;
                            self.abort_job(i);
                        } else {
                            self.finish_gpu_segment(i);
                        }
                        changed = true;
                    }
                }
            }

            // Lock/server grants (one lock, or one serving request, per
            // engine).
            if matches!(self.pol, Policy::Mpcp | Policy::FmlpPlus | Policy::Server) {
                for g in 0..self.gpus.len() {
                    changed |= self.try_grant_lock(g);
                }
            }

            // GCAPS completion-aware promotion (work-conserving runlist):
            // when every TSG on the runlist has drained its queued GPU
            // work (the holder is finishing trailing G^m or waiting to
            // issue gcapsGpuSegEnd), the driver — which observes channel
            // idle interrupts — promotes the highest-priority pending RT
            // task so the GPU never idles behind a stalled holder. This
            // is required for Lemma 10/13's G^e*-only preemption charge
            // to hold (see DESIGN.md §1: the printed Alg. 1 would let a
            // CPU-starved holder idle the GPU unboundedly).
            if matches!(self.pol, Policy::Gcaps | Policy::GcapsEdf) && self.fine {
                // Fine mode: the capacity repack subsumes the serial
                // completion-aware promotion below (a stalled holder
                // frees its fraction; pending RTs pack into it by rank).
                for g in 0..self.gpus.len() {
                    changed |= self.rebalance_fine(g);
                }
            } else if matches!(self.pol, Policy::Gcaps | Policy::GcapsEdf) {
                let execing = |st: &TState| {
                    matches!(st.phase, Phase::GpuActive) && st.gpu_rem > 0
                };
                for g in 0..self.gpus.len() {
                    let any_running_exec =
                        self.gpus[g].running.iter().any(|&k| execing(&self.st[k]));
                    if !any_running_exec {
                        let promote = self.gpus[g]
                            .pending
                            .iter()
                            .copied()
                            .filter(|&k| {
                                !self.ts.tasks[k].best_effort && execing(&self.st[k])
                            })
                            .max_by_key(|&k| self.gpu_rank(k));
                        if let Some(k) = promote {
                            self.gpus[g].pending.retain(|&x| x != k);
                            self.gpus[g].running.push(k);
                            changed = true;
                        }
                    }
                }
            }

            // Ring upkeep + slice rotation, per engine.
            for g in 0..self.gpus.len() {
                changed |= self.refresh_ring(g);
                if self.fine {
                    changed |= self.rotate_expired_residents(g);
                    changed |= self.update_gpu_residents(g);
                    continue;
                }
                if let Some(i) = self.gpus[g].context {
                    if self.gpus[g].switch_rem == 0
                        && self.gpus[g].slice_rem == 0
                        && self.gpus[g].ring.len() > 1
                        && self.gpus[g].ring.front() == Some(&i)
                    {
                        self.gpus[g].ring.rotate_left(1);
                        changed = true;
                    } else if self.gpus[g].ring.len() == 1 && self.gpus[g].slice_rem == 0 {
                        // Slice refill of a lone TSG: not scheduler-
                        // visible (the seed fingerprint ignored
                        // slice_rem too) — deliberately unflagged.
                        self.gpus[g].slice_rem = self.ts.platform.gpus[g].tsg_slice;
                    }
                }
                changed |= self.update_gpu_context(g);
            }
            self.cpu_alloc = self.compute_cpu_alloc();

            if !changed {
                return;
            }
        }
        panic!("settle() did not quiesce at t = {} µs", self.now);
    }

    fn run(mut self) -> SimResult {
        while self.now < self.cfg.duration {
            self.fault_tick();
            self.settle();
            let h = self.next_horizon();
            let dt = h.saturating_sub(self.now);
            if dt == 0 {
                let next = self
                    .calendar
                    .peek()
                    .map(|&Reverse((t, _))| t)
                    .unwrap_or(self.cfg.duration);
                if next <= self.now {
                    break; // safety: nothing can advance
                }
                self.advance(next.min(self.cfg.duration).saturating_sub(self.now));
            } else {
                self.advance(dt);
            }
        }
        self.run.horizon = self.now;
        SimResult { per_task: self.metrics, run: self.run, trace: self.trace }
    }
}

/// Simulate `ts` under `cfg`.
pub fn simulate(ts: &TaskSet, cfg: &SimConfig) -> SimResult {
    debug_assert!(ts.validate().is_ok(), "invalid taskset: {:?}", ts.validate());
    Engine::new(ts, cfg).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::{ms, GpuSegment, Platform, Task, TaskSet};

    fn platform() -> Platform {
        Platform::single(2, 1024, 200, 1000)
    }

    fn gpu_task(id: usize, core: usize, prio: u32, c: f64, gm: f64, ge: f64, t: f64) -> Task {
        Task {
            id,
            name: format!("t{id}"),
            period: ms(t),
            deadline: ms(t),
            cpu_segments: vec![ms(c / 2.0), ms(c / 2.0)],
            gpu_segments: vec![GpuSegment::new(ms(gm), ms(ge))],
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: false,
            mode: WaitMode::SelfSuspend,
        }
    }

    #[test]
    fn lone_task_tsg_rr_exact_response() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let cfg = SimConfig::new(Policy::TsgRr, ms(1000.0));
        let res = simulate(&ts, &cfg);
        // Alone: R = C + max(G^m, θ + G^e) = 2 + 5.2 = 7.2 ms
        assert_eq!(res.per_task[0].jobs, 10);
        assert_eq!(res.per_task[0].mort(), Some(ms(7.2)));
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn lone_task_gcaps_charges_epsilon() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let cfg = SimConfig::new(Policy::Gcaps, ms(1000.0));
        let res = simulate(&ts, &cfg);
        // R = C + 2α + max(G^m, θ + G^e) = 2 + 1.6 + 5.2 = 8.8 ms
        assert_eq!(res.per_task[0].mort(), Some(ms(8.8)));
        assert_eq!(
            res.per_task[0].runlist_updates.len() as u64,
            2 * res.per_task[0].jobs
        );
    }

    #[test]
    fn lone_task_lock_policies_zero_overhead() {
        for policy in [Policy::Mpcp, Policy::FmlpPlus] {
            let ts =
                TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
            let res = simulate(&ts, &SimConfig::new(policy, ms(500.0)));
            // R = C + max(G^m, G^e) = 7 ms
            assert_eq!(res.per_task[0].mort(), Some(ms(7.0)), "{policy:?}");
        }
    }

    #[test]
    fn gcaps_preempts_lower_priority_gpu() {
        let hi = gpu_task(0, 0, 2, 1.0, 0.5, 4.0, 50.0);
        let lo = gpu_task(1, 1, 1, 1.0, 0.5, 40.0, 100.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(1000.0)));
        let mort0 = res.per_task[0].mort().unwrap();
        // hp bound: C + 2α + θ + G^e + blocking ε ≈ 7.4 ms ≪ lo's 40 ms kernel
        assert!(mort0 <= ms(8.0), "hp MORT = {mort0} µs");
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn mpcp_blocks_high_priority_for_whole_gcs() {
        let hi = gpu_task(0, 0, 2, 1.0, 0.5, 4.0, 50.0);
        let lo = gpu_task(1, 1, 1, 1.0, 0.5, 40.0, 100.0);
        let ts = TaskSet::new(vec![hi, lo], platform());
        // Offset hp so its request lands mid-gcs of the low-priority task.
        let res = simulate(
            &ts,
            &SimConfig::new(Policy::Mpcp, ms(1000.0)).with_offsets(vec![ms(10.0), 0]),
        );
        let mort0 = res.per_task[0].mort().unwrap();
        assert!(mort0 >= ms(30.0), "hp MORT = {mort0} µs under MPCP");
    }

    #[test]
    fn tsg_rr_interleaves_fairly() {
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 10.0, 100.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 10.0, 100.0);
        let ts = TaskSet::new(vec![a, b], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(2000.0)));
        for i in [0, 1] {
            let mort = res.per_task[i].mort().unwrap();
            assert!(
                mort >= ms(18.0) && mort <= ms(27.0),
                "tau{i} MORT = {mort} µs"
            );
        }
        assert!(res.run.gpu_context_switches > 10);
    }

    #[test]
    fn busy_wait_blocks_lower_priority_cpu() {
        let mut hp = gpu_task(0, 0, 2, 1.0, 0.5, 20.0, 100.0);
        hp.mode = WaitMode::BusyWait;
        let lp = Task::cpu_only(1, 0, 1, ms(5.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let busy = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(1000.0)));
        let mut ts2 = ts.clone();
        ts2.tasks[0].mode = WaitMode::SelfSuspend;
        let susp = simulate(&ts2, &SimConfig::new(Policy::Gcaps, ms(1000.0)));
        let rb = busy.per_task[1].mort().unwrap();
        let rs = susp.per_task[1].mort().unwrap();
        assert!(rb >= rs + ms(15.0), "busy {rb} vs suspend {rs}");
    }

    #[test]
    fn self_suspension_frees_cpu() {
        let hp = gpu_task(0, 0, 2, 1.0, 0.5, 20.0, 100.0);
        let lp = Task::cpu_only(1, 0, 1, ms(5.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(500.0)));
        let r = res.per_task[1].mort().unwrap();
        assert!(r <= ms(12.0), "lp MORT = {r}");
    }

    #[test]
    fn deadline_misses_counted() {
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 60.0, 100.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 60.0, 100.0);
        let ts = TaskSet::new(vec![a, b], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(2000.0)));
        assert!(res.per_task[1].deadline_misses > 0);
        assert!(!res.no_rt_misses(&ts));
    }

    #[test]
    fn near_max_deadlines_saturate_instead_of_wrapping() {
        // Regression: `abs_deadline = release + t.deadline` wrapped when
        // a job was released near u64::MAX, inverting the EDF rank
        // (`u64::MAX - abs_deadline`) and flagging every such job as
        // missed. With saturation the deadline pins to MAX: rank 0 and
        // never missed. Release offsets near MAX are the crafted input
        // (deadlines themselves are constrained to ≤ T by validate()).
        let a = gpu_task(0, 0, 2, 2.0, 0.5, 5.0, 100.0);
        let b = gpu_task(1, 0, 1, 2.0, 0.5, 5.0, 120.0);
        let ts = TaskSet::new(vec![a, b], platform());
        let offsets = vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)];
        for policy in [Policy::GcapsEdf, Policy::Gcaps] {
            let cfg = SimConfig::new(policy, u64::MAX).with_offsets(offsets.clone());
            let res = simulate(&ts, &cfg);
            for i in [0, 1] {
                assert!(res.per_task[i].jobs >= 1, "{policy:?}: tau{i} never ran");
                assert_eq!(
                    res.per_task[i].deadline_misses, 0,
                    "{policy:?}: tau{i} flagged a bogus wrap-around miss"
                );
            }
        }
    }

    #[test]
    fn offsets_shift_releases() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let cfg = SimConfig::new(Policy::TsgRr, ms(250.0)).with_offsets(vec![ms(60.0)]);
        let res = simulate(&ts, &cfg);
        assert_eq!(res.per_task[0].jobs, 2);
    }

    #[test]
    fn trace_records_gpu_intervals() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let cfg = SimConfig::new(Policy::Gcaps, ms(100.0)).with_trace();
        let res = simulate(&ts, &cfg);
        let tr = res.trace.unwrap();
        let gpu_time = tr.occupancy(Resource::Gpu(0), 0, 0, ms(100.0));
        assert_eq!(gpu_time, ms(5.0) + 200); // G^e + θ switch
        assert_eq!(tr.releases.len(), 1);
        assert_eq!(tr.completions.len(), 1);
    }

    #[test]
    fn best_effort_runs_only_when_gpu_free_gcaps() {
        let rt = gpu_task(0, 0, 1, 1.0, 0.5, 5.0, 50.0);
        let mut be = gpu_task(1, 1, 0, 1.0, 0.5, 200.0, 400.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(2000.0)));
        let mort_rt = res.per_task[0].mort().unwrap();
        assert!(mort_rt <= ms(11.0), "RT MORT = {mort_rt} µs with BE hog");
        assert!(res.per_task[1].jobs >= 1);
    }

    #[test]
    fn tsg_rr_does_not_prioritise() {
        let rt = gpu_task(0, 0, 1, 1.0, 0.5, 5.0, 50.0);
        let mut be = gpu_task(1, 1, 0, 1.0, 0.5, 200.0, 400.0);
        be.best_effort = true;
        let ts = TaskSet::new(vec![rt, be], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(2000.0)));
        let mort_rt = res.per_task[0].mort().unwrap();
        assert!(mort_rt >= ms(10.0), "RT MORT = {mort_rt} µs should inflate");
    }

    #[test]
    fn gcaps_three_way_contention_progresses() {
        let tasks = vec![
            gpu_task(0, 0, 3, 1.0, 0.5, 8.0, 40.0),
            gpu_task(1, 1, 2, 1.0, 0.5, 8.0, 60.0),
            gpu_task(2, 0, 1, 1.0, 0.5, 8.0, 80.0),
        ];
        let ts = TaskSet::new(tasks, platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(2000.0)));
        for i in 0..3 {
            assert!(res.per_task[i].jobs > 0, "task {i} starved");
        }
    }

    #[test]
    fn gm_overlaps_ge_async() {
        // G^m = 4 ms ∥ G^e = 4 ms: the segment takes ~max(4, θ+4) not 8.
        let t = gpu_task(0, 0, 1, 2.0, 4.0, 4.0, 100.0);
        let ts = TaskSet::new(vec![t], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(300.0)));
        assert_eq!(res.per_task[0].mort(), Some(ms(2.0 + 4.2)));
    }

    // -- edge cases: all must settle without tripping the quiescence
    //    panic, across every policy ------------------------------------

    const ALL_POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];

    #[test]
    fn zero_length_cpu_segments_settle() {
        // A GPU task whose CPU segments are all zero-length: every job is
        // a pure chain of zero-time transitions around the GPU segment.
        let mut t = gpu_task(0, 0, 2, 1.0, 0.5, 2.0, 20.0);
        t.cpu_segments = vec![0, 0];
        let rival = gpu_task(1, 1, 1, 1.0, 0.5, 2.0, 20.0);
        let ts = TaskSet::new(vec![t, rival], platform());
        for policy in ALL_POLICIES {
            let res = simulate(&ts, &SimConfig::new(policy, ms(200.0)));
            assert_eq!(res.per_task[0].jobs, 10, "{policy:?}: wrong job count");
            assert!(res.per_task[1].jobs > 0, "{policy:?}: rival starved");
        }
    }

    #[test]
    fn zero_length_gpu_segments_settle() {
        // G^m = G^e = 0: the GPU segment completes the instant it starts
        // (begin → active → end with no time passing), including the
        // driver-call / lock bracket around it.
        let mut t = gpu_task(0, 0, 2, 2.0, 0.5, 2.0, 20.0);
        t.gpu_segments = vec![GpuSegment::new(0, 0)];
        let lp = Task::cpu_only(1, 0, 1, ms(1.0), ms(20.0));
        let ts = TaskSet::new(vec![t, lp], platform());
        for policy in ALL_POLICIES {
            let res = simulate(&ts, &SimConfig::new(policy, ms(200.0)));
            assert_eq!(res.per_task[0].jobs, 10, "{policy:?}: wrong job count");
            assert_eq!(res.per_task[0].deadline_misses, 0, "{policy:?}");
            assert!(res.per_task[1].jobs > 0, "{policy:?}: lp starved");
        }
    }

    #[test]
    fn epsilon_equals_theta_alpha_zero_settles() {
        // ε = θ ⇒ α = 0: GCAPS driver calls are zero-length CPU work, the
        // harshest zero-time-transition case (two per GPU segment). The
        // response collapses to C + max(G^m, θ + G^e).
        let p = Platform::single(2, 1024, 200, 200);
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], p.clone());
        for policy in [Policy::Gcaps, Policy::GcapsEdf] {
            let res = simulate(&ts, &SimConfig::new(policy, ms(1000.0)));
            assert_eq!(res.per_task[0].jobs, 10, "{policy:?}");
            assert_eq!(res.per_task[0].mort(), Some(ms(7.2)), "{policy:?}");
        }
        // Contended variant: two tasks hammering zero-α driver calls.
        let hi = gpu_task(0, 0, 2, 1.0, 0.5, 4.0, 50.0);
        let lo = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 50.0);
        let ts2 = TaskSet::new(vec![hi, lo], p);
        let res = simulate(&ts2, &SimConfig::new(Policy::Gcaps, ms(1000.0)));
        assert!(res.per_task[0].jobs > 0 && res.per_task[1].jobs > 0);
    }

    #[test]
    fn tsg_slice_larger_than_every_kernel_settles() {
        // L ≫ every G^e: no kernel ever exhausts its slice, so the RR
        // ring must still rotate (at segment completion) rather than
        // deadlock on a never-expiring slice.
        let p = Platform::single(2, ms(500.0), 200, 1000);
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 10.0, 100.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 10.0, 100.0);
        let ts = TaskSet::new(vec![a, b], p);
        for policy in ALL_POLICIES {
            let res = simulate(&ts, &SimConfig::new(policy, ms(1000.0)));
            for i in [0, 1] {
                assert!(
                    res.per_task[i].jobs >= 9,
                    "{policy:?}: tau{i} ran {} jobs",
                    res.per_task[i].jobs
                );
                assert_eq!(res.per_task[i].deadline_misses, 0, "{policy:?}: tau{i}");
            }
        }
    }

    #[test]
    fn two_engines_execute_in_parallel() {
        // Two identical GPU tasks on separate engines behave exactly as
        // if each ran alone — no interleaving, preemption or queueing
        // couples them — under every policy.
        let p = platform().with_num_gpus(2);
        let a = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut b = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        b.gpu = 1;
        let ts = TaskSet::new(vec![a, b], p);
        for policy in ALL_POLICIES {
            let expect = match policy {
                // Alone: R = C + max(G^m, θ + G^e).
                Policy::TsgRr => ms(7.2),
                // + 2α runlist updates.
                Policy::Gcaps | Policy::GcapsEdf => ms(8.8),
                // Lock policies are overhead-free when uncontended.
                Policy::Mpcp | Policy::FmlpPlus => ms(7.0),
                // Server service serializes G^m and G^e: R = C + G^m + G^e.
                Policy::Server => ms(8.0),
            };
            let res = simulate(&ts, &SimConfig::new(policy, ms(1000.0)));
            for i in [0, 1] {
                assert_eq!(res.per_task[i].mort(), Some(expect), "{policy:?} tau{i}");
                assert_eq!(res.per_task[i].deadline_misses, 0, "{policy:?} tau{i}");
            }
        }
    }

    #[test]
    fn shared_engine_interferes_where_split_engines_do_not() {
        // The same pair forced onto one engine must interleave under
        // the RR driver (slower than the isolated 7.2 ms).
        let a = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let b = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        let shared = TaskSet::new(vec![a.clone(), b.clone()], platform());
        let res = simulate(&shared, &SimConfig::new(Policy::TsgRr, ms(1000.0)));
        let worst = res.per_task[0].mort().unwrap().max(res.per_task[1].mort().unwrap());
        assert!(worst > ms(7.2), "shared engine must interleave: {worst}");
    }

    #[test]
    fn multi_gpu_traces_tagged_by_engine() {
        let p = platform().with_num_gpus(2);
        let a = gpu_task(0, 0, 2, 2.0, 1.0, 5.0, 100.0);
        let mut b = gpu_task(1, 1, 1, 2.0, 1.0, 5.0, 100.0);
        b.gpu = 1;
        let ts = TaskSet::new(vec![a, b], p);
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(100.0)).with_trace());
        let tr = res.trace.unwrap();
        // Each task's G^e lands on its own engine's trace row, θ incl.
        assert_eq!(tr.occupancy(Resource::Gpu(0), 0, 0, ms(100.0)), ms(5.0) + 200);
        assert_eq!(tr.occupancy(Resource::Gpu(1), 1, 0, ms(100.0)), ms(5.0) + 200);
        assert_eq!(tr.occupancy(Resource::Gpu(1), 0, 0, ms(100.0)), 0);
        assert_eq!(tr.occupancy(Resource::Gpu(0), 1, 0, ms(100.0)), 0);
    }

    #[test]
    fn driver_calls_bounded_by_epsilon() {
        // Three GPU tasks hammering the driver: every measured runlist
        // update stays within ~2ε (own α + θ plus at most one same-core
        // non-preemptible call stall).
        let tasks = vec![
            gpu_task(0, 0, 3, 1.0, 0.2, 3.0, 30.0),
            gpu_task(1, 1, 2, 1.0, 0.2, 3.0, 40.0),
            gpu_task(2, 0, 1, 1.0, 0.2, 3.0, 50.0),
        ];
        let ts = TaskSet::new(tasks, platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(3000.0)));
        let eps = ts.platform.gpus[0].epsilon;
        // Highest-priority task: blocked by at most one in-flight call.
        for &d in &res.per_task[0].runlist_updates {
            assert!(d <= 2 * eps, "hp runlist update took {d} µs");
        }
    }

    // -- server-based GPU access (Policy::Server) ----------------------

    #[test]
    fn lone_task_server_serializes_segment() {
        // The server executes G^m then G^e back to back on the engine,
        // overhead-free: R = C + G^m + G^e (no async overlap — the
        // server is a single thread driving the engine).
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Server, ms(1000.0)));
        assert_eq!(res.per_task[0].jobs, 10);
        assert_eq!(res.per_task[0].mort(), Some(ms(8.0)));
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn server_frees_requester_cpu_during_service() {
        // While the server executes hp's whole segment (G^m included),
        // the self-suspended requester's core is free for lp CPU work —
        // the structural advantage over MPCP-style boost blocking.
        let hp = gpu_task(0, 0, 2, 1.0, 0.5, 20.0, 100.0);
        let lp = Task::cpu_only(1, 0, 1, ms(5.0), ms(100.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::Server, ms(500.0)));
        // lp only contends with hp's two 0.5 ms CPU halves.
        let r = res.per_task[1].mort().unwrap();
        assert!(r <= ms(6.0), "lp MORT = {r} µs");
        // hp itself: C + G^m + G^e serialized.
        assert_eq!(res.per_task[0].mort(), Some(ms(21.5)));
    }

    #[test]
    fn server_orders_queued_requests_by_priority() {
        // lo's request is in service when mid then hi arrive; on
        // completion the server must pick hi (priority order), not mid
        // (FIFO order).
        let lo = gpu_task(0, 0, 1, 1.0, 0.5, 10.0, 100.0);
        let mid = gpu_task(1, 1, 2, 1.0, 0.5, 4.0, 100.0);
        let hi = gpu_task(2, 1, 3, 1.0, 0.5, 4.0, 100.0);
        let ts = TaskSet::new(vec![lo, mid, hi], platform());
        let cfg = SimConfig::new(Policy::Server, ms(100.0))
            .with_offsets(vec![0, ms(1.0), ms(2.0)]);
        let res = simulate(&ts, &cfg);
        // lo: 0.5 C + (0.5 + 10) service + 0.5 C = 11.5 ms.
        assert_eq!(res.per_task[0].mort(), Some(ms(11.5)));
        // hi requests at 2.5, served 11.0-15.5, final C to 16.0.
        assert_eq!(res.per_task[2].mort(), Some(ms(14.0)));
        // mid requests at 1.5 but is served after hi: done at 20.5.
        assert_eq!(res.per_task[1].mort(), Some(ms(19.5)));
    }

    #[test]
    fn server_trace_tags_service_on_engine_row() {
        // G^m served by the server shows up on the engine row as
        // ServerMisc — distinguishable from direct-execution GpuMisc —
        // and never on the requester's core.
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let res =
            simulate(&ts, &SimConfig::new(Policy::Server, ms(100.0)).with_trace());
        let tr = res.trace.unwrap();
        let misc: Time = tr
            .events
            .iter()
            .filter(|e| e.activity == Activity::ServerMisc)
            .map(|e| e.end - e.start)
            .sum();
        assert_eq!(misc, ms(1.0));
        assert!(tr.events.iter().all(|e| e.activity != Activity::GpuMisc));
        // Engine row carries the full serialized service; the core only
        // the task's own CPU segments.
        assert_eq!(tr.occupancy(Resource::Gpu(0), 0, 0, ms(100.0)), ms(6.0));
        assert_eq!(tr.occupancy(Resource::Core(0), 0, 0, ms(100.0)), ms(2.0));
    }

    #[test]
    fn server_rt_requests_precede_best_effort() {
        // A queued best-effort request must wait for a later-arriving
        // RT request, regardless of raw priority values.
        let lo = gpu_task(0, 0, 5, 1.0, 0.5, 10.0, 100.0);
        let mut be = gpu_task(1, 1, 9, 1.0, 0.5, 4.0, 100.0);
        be.best_effort = true;
        let rt = gpu_task(2, 1, 1, 1.0, 0.5, 4.0, 100.0);
        let ts = TaskSet::new(vec![lo, be, rt], platform());
        let cfg = SimConfig::new(Policy::Server, ms(100.0))
            .with_offsets(vec![0, ms(1.0), ms(2.0)]);
        let res = simulate(&ts, &cfg);
        // rt (arrived last, lowest prio, but RT) is served before be.
        assert_eq!(res.per_task[2].mort(), Some(ms(14.0)));
        assert_eq!(res.per_task[1].mort(), Some(ms(19.5)));
    }

    // -- fault injection, miss actions, adaptive switching --------------

    #[test]
    fn empty_fault_plan_is_bit_identical_to_baseline() {
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 8.0, 40.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 60.0);
        let ts = TaskSet::new(vec![a, b], platform());
        for policy in ALL_POLICIES {
            let plain = simulate(&ts, &SimConfig::new(policy, ms(500.0)).with_trace());
            let cfg = SimConfig::new(policy, ms(500.0))
                .with_trace()
                .with_faults(FaultPlan::default())
                .with_miss_actions(vec![DeadlineMissAction::Log; 2]);
            let faulted = simulate(&ts, &cfg);
            assert_eq!(plain.per_task, faulted.per_task, "{policy:?}");
            assert_eq!(plain.run, faulted.run, "{policy:?}");
            assert_eq!(plain.trace, faulted.trace, "{policy:?}");
        }
    }

    #[test]
    fn wcet_overrun_scales_the_faulted_job_exactly() {
        // Nominal: R = C + max(G^m, θ + G^e) = 2 + 5.2 = 7.2 ms. Job 1
        // at 200%/200%: C = 4, G^e = 10 (G^m stays 1) → R = 4 + 10.2.
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let plan = FaultPlan {
            faults: vec![Fault::WcetOverrun { task: 0, job: 1, cpu_pct: 200, gpu_pct: 200 }],
            ..Default::default()
        };
        let cfg = SimConfig::new(Policy::TsgRr, ms(1000.0)).with_faults(plan);
        let res = simulate(&ts, &cfg);
        assert_eq!(res.per_task[0].jobs, 10);
        assert_eq!(res.per_task[0].response_times[1], ms(14.2));
        assert_eq!(res.per_task[0].response_times[0], ms(7.2));
        assert_eq!(res.per_task[0].response_times[2], ms(7.2));
        assert_eq!(res.per_task[0].mort(), Some(ms(14.2)));
    }

    #[test]
    fn gpu_hang_is_detected_and_aborted() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let plan = FaultPlan {
            faults: vec![Fault::GpuHang { task: 0, job: 0, seg: 0 }],
            ..Default::default() // 10 ms hang timeout
        };
        let cfg = SimConfig::new(Policy::Gcaps, ms(1000.0)).with_faults(plan).with_trace();
        let res = simulate(&ts, &cfg);
        // Job 0 aborts at the watchdog bound; jobs 1..9 run clean.
        assert_eq!(res.per_task[0].hangs, 1);
        assert_eq!(res.per_task[0].aborted, 1);
        assert_eq!(res.per_task[0].jobs, 9);
        assert_eq!(res.per_task[0].deadline_misses, 0);
        assert_eq!(res.per_task[0].mort(), Some(ms(8.8)));
        // The hang renders as GpuHang on the engine row, for exactly
        // the timeout.
        let tr = res.trace.unwrap();
        let hang: Time = tr
            .events
            .iter()
            .filter(|e| e.activity == Activity::GpuHang)
            .map(|e| e.end - e.start)
            .sum();
        assert_eq!(hang, ms(10.0));
    }

    #[test]
    fn abort_job_miss_action_discards_the_late_job() {
        // Persistent CPU overload on lp: every lp job misses. AbortJob
        // caps the damage per job instead of letting backlog snowball.
        let hp = Task::cpu_only(0, 0, 2, ms(7.0), ms(10.0));
        let lp = Task::cpu_only(1, 0, 1, ms(8.0), ms(20.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let log = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(2000.0)));
        let cfg = SimConfig::new(Policy::Gcaps, ms(2000.0)).with_miss_actions(vec![
            DeadlineMissAction::Log,
            DeadlineMissAction::AbortJob,
        ]);
        let res = simulate(&ts, &cfg);
        assert!(res.per_task[1].aborted > 10, "aborted {}", res.per_task[1].aborted);
        assert_eq!(res.per_task[1].jobs + res.per_task[1].aborted, 100);
        // Log alone lets responses grow without bound under overload.
        assert!(log.per_task[1].mort().unwrap() > ms(100.0));
        // hp is untouched in both runs.
        assert_eq!(res.per_task[0].jobs, log.per_task[0].jobs);
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn boost_miss_action_rescues_the_late_job() {
        let hp = Task::cpu_only(0, 0, 2, ms(7.0), ms(10.0));
        let lp = Task::cpu_only(1, 0, 1, ms(8.0), ms(20.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let log = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(2000.0)));
        let cfg = SimConfig::new(Policy::Gcaps, ms(2000.0)).with_miss_actions(vec![
            DeadlineMissAction::Log,
            DeadlineMissAction::Boost,
        ]);
        let res = simulate(&ts, &cfg);
        assert!(res.per_task[1].boosts > 0);
        // Boosted late jobs preempt hp and finish promptly: the MORT
        // stays bounded where Log's grows with the backlog.
        assert!(
            res.per_task[1].mort().unwrap() < log.per_task[1].mort().unwrap(),
            "boost {} vs log {}",
            res.per_task[1].mort().unwrap(),
            log.per_task[1].mort().unwrap()
        );
    }

    #[test]
    fn drop_task_miss_action_stops_future_releases() {
        let hp = Task::cpu_only(0, 0, 2, ms(7.0), ms(10.0));
        let lp = Task::cpu_only(1, 0, 1, ms(8.0), ms(20.0));
        let ts = TaskSet::new(vec![hp, lp], platform());
        let cfg = SimConfig::new(Policy::Gcaps, ms(2000.0)).with_miss_actions(vec![
            DeadlineMissAction::Log,
            DeadlineMissAction::DropTask,
        ]);
        let res = simulate(&ts, &cfg);
        // First lp job misses, aborts, and the task is dropped for the
        // rest of the run.
        assert_eq!(res.per_task[1].aborted, 1);
        assert_eq!(res.per_task[1].jobs, 0);
        // hp owns the core afterwards: all 200 jobs, no misses.
        assert_eq!(res.per_task[0].jobs, 200);
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn mode_change_disables_and_reenables_a_task() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let plan = FaultPlan {
            faults: vec![
                Fault::ModeChange { at: ms(250.0), disable: vec![0], enable: vec![] },
                Fault::ModeChange { at: ms(650.0), disable: vec![], enable: vec![0] },
            ],
            ..Default::default()
        };
        let cfg = SimConfig::new(Policy::TsgRr, ms(1000.0)).with_faults(plan);
        let res = simulate(&ts, &cfg);
        // Releases at 0,100,200 ran; 300..600 dropped; 700,800,900 ran.
        assert_eq!(res.per_task[0].jobs, 6);
        assert_eq!(res.per_task[0].aborted, 0); // idle at 250 ms
        assert_eq!(res.per_task[0].mort(), Some(ms(7.2)));
    }

    #[test]
    fn mode_change_mid_job_aborts_it() {
        let ts = TaskSet::new(vec![gpu_task(0, 0, 1, 2.0, 1.0, 5.0, 100.0)], platform());
        let plan = FaultPlan {
            faults: vec![Fault::ModeChange { at: ms(103.0), disable: vec![0], enable: vec![] }],
            ..Default::default()
        };
        let cfg = SimConfig::new(Policy::TsgRr, ms(1000.0)).with_faults(plan);
        let res = simulate(&ts, &cfg);
        // The job released at 100 ms is 3 ms in when disabled.
        assert_eq!(res.per_task[0].jobs, 1);
        assert_eq!(res.per_task[0].aborted, 1);
    }

    #[test]
    fn adaptive_governor_switches_up_and_back() {
        // Two RR-interleaved 10 ms kernels at T = 30 ms: fine nominally,
        // overloaded at 200% G^e during the ramp. The governor must flip
        // RR→EDF when misses cross 10% of the window and return once
        // the overload clears.
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 10.0, 30.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 10.0, 30.0);
        let ts = TaskSet::new(vec![a, b], platform());
        let plan = FaultPlan::ramp(&ts, ms(300.0), ms(600.0), 100, 200);
        assert!(!plan.is_empty());
        let cfg = SimConfig::new(Policy::TsgRr, ms(3000.0))
            .with_faults(plan.clone())
            .with_adaptive(AdaptivePolicy::default());
        let res = simulate(&ts, &cfg);
        assert!(
            res.run.policy_switches >= 2,
            "expected up+down switches, got {}",
            res.run.policy_switches
        );
        let total_misses: u64 =
            res.per_task.iter().map(|m| m.deadline_misses).sum();
        assert!(total_misses > 0, "the ramp never overloaded the engine");
        // Fixed-policy run with the same plan: no governor, no switches.
        let fixed =
            simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(3000.0)).with_faults(plan));
        assert_eq!(fixed.run.policy_switches, 0);
        // Recovery is recorded: the last tardy instant precedes the
        // horizon by a healthy margin (the system settled again).
        assert!(res.run.last_tardy > 0);
        assert!(res.run.last_tardy < ms(2500.0), "never recovered: {}", res.run.last_tardy);
    }

    // -- fine-grain co-running ------------------------------------------

    /// Every GPU segment of `t` declared at `pct`% of the SMs.
    fn with_par(mut t: Task, pct: u32) -> Task {
        t.gpu_segments = t.gpu_segments.into_iter().map(|g| g.with_par(pct)).collect();
        t
    }

    #[test]
    fn fine_grain_all_full_fractions_bit_identical_to_serial() {
        // par = 100 everywhere is the serial model: `has_fine_grain` is
        // false, so the fine code paths never engage and every policy
        // reproduces the serial run bit for bit.
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 8.0, 40.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 60.0);
        let plain = TaskSet::new(vec![a.clone(), b.clone()], platform());
        let full =
            TaskSet::new(vec![with_par(a, 100), with_par(b, 100)], platform());
        assert!(!full.has_fine_grain());
        for policy in ALL_POLICIES {
            let cfg = SimConfig::new(policy, ms(500.0)).with_trace();
            let x = simulate(&plain, &cfg);
            let y = simulate(&full, &cfg);
            assert_eq!(x.per_task, y.per_task, "{policy:?}");
            assert_eq!(x.run, y.run, "{policy:?}");
            assert_eq!(x.trace, y.trace, "{policy:?}");
        }
    }

    #[test]
    fn fine_grain_gcaps_co_runs_half_fraction_kernels() {
        // Two 50% kernels fit the engine together: under fine-grain
        // GCAPS the lp task no longer serializes behind the hp 8 ms
        // kernel, while the serial run makes it wait.
        let hp = gpu_task(0, 0, 2, 1.0, 0.5, 8.0, 100.0);
        let lp = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 100.0);
        let serial = TaskSet::new(vec![hp.clone(), lp.clone()], platform());
        let fine =
            TaskSet::new(vec![with_par(hp, 50), with_par(lp, 50)], platform());
        assert!(fine.has_fine_grain());
        let cfg = SimConfig::new(Policy::Gcaps, ms(1000.0));
        let rs = simulate(&serial, &cfg);
        let rf = simulate(&fine, &cfg);
        // hp is unharmed by co-running (full-rate partition).
        assert!(
            rf.per_task[0].mort().unwrap() <= rs.per_task[0].mort().unwrap() + 200,
            "hp fine {} vs serial {}",
            rf.per_task[0].mort().unwrap(),
            rs.per_task[0].mort().unwrap()
        );
        // lp gains: no longer waits out hp's whole kernel.
        assert!(
            rf.per_task[1].mort().unwrap() + ms(4.0) <= rs.per_task[1].mort().unwrap(),
            "lp fine {} vs serial {}",
            rf.per_task[1].mort().unwrap(),
            rs.per_task[1].mort().unwrap()
        );
        // Same total kernel work either way.
        assert_eq!(rf.run.gpu_busy, rs.run.gpu_busy);
        for i in [0, 1] {
            assert_eq!(rf.per_task[i].deadline_misses, 0, "tau{i}");
        }
    }

    #[test]
    fn fine_grain_oversized_fractions_still_serialize() {
        // 60% + 60% > 100%: the pair can never co-run, so the lp task
        // still waits out the hp kernel — fine mode must not leak
        // optimism past the declared capacity.
        let hp = gpu_task(0, 0, 2, 1.0, 0.5, 8.0, 100.0);
        let lp = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 100.0);
        let fine =
            TaskSet::new(vec![with_par(hp, 60), with_par(lp, 60)], platform());
        let res = simulate(&fine, &SimConfig::new(Policy::Gcaps, ms(1000.0)));
        // lp's segment sits behind hp's 8 ms kernel.
        assert!(
            res.per_task[1].mort().unwrap() >= ms(8.0),
            "lp MORT = {} µs",
            res.per_task[1].mort().unwrap()
        );
        assert_eq!(res.per_task[0].deadline_misses, 0);
    }

    #[test]
    fn fine_grain_bypass_packs_small_fraction_past_oversized_waiter() {
        // Engine busy with a 50% resident; a 60% task cannot fit, but a
        // lower-ranked 10% task must still pack (bypass) instead of
        // queueing behind the 60% request for the whole residency.
        let top = gpu_task(0, 0, 3, 1.0, 0.5, 20.0, 100.0);
        let mid = gpu_task(1, 1, 2, 1.0, 0.5, 4.0, 100.0);
        let tiny = gpu_task(2, 1, 1, 1.0, 0.5, 4.0, 100.0);
        let ts = TaskSet::new(
            vec![with_par(top, 50), with_par(mid, 60), with_par(tiny, 10)],
            platform(),
        );
        let cfg = SimConfig::new(Policy::Gcaps, ms(1000.0))
            .with_offsets(vec![0, ms(2.0), ms(2.0)]);
        let res = simulate(&ts, &cfg);
        // tiny finishes its 4 ms kernel long before top's 20 ms kernel
        // drains — it did not wait for mid's turn.
        assert!(
            res.per_task[2].mort().unwrap() <= ms(12.0),
            "tiny MORT = {} µs (stuck behind the oversized waiter?)",
            res.per_task[2].mort().unwrap()
        );
        // mid genuinely has to wait for capacity.
        assert!(
            res.per_task[1].mort().unwrap() >= ms(15.0),
            "mid MORT = {} µs",
            res.per_task[1].mort().unwrap()
        );
    }

    #[test]
    fn fine_grain_tsg_rr_co_residents_skip_interleaving() {
        // The serial RR pair (10 ms kernels) interleaves to ~2× MORT;
        // at 50% each they co-reside and finish near the alone time.
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 10.0, 100.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 10.0, 100.0);
        let ts = TaskSet::new(vec![with_par(a, 50), with_par(b, 50)], platform());
        let res = simulate(&ts, &SimConfig::new(Policy::TsgRr, ms(2000.0)));
        for i in [0, 1] {
            let mort = res.per_task[i].mort().unwrap();
            assert!(mort <= ms(12.0), "tau{i} MORT = {mort} µs");
            assert_eq!(res.per_task[i].deadline_misses, 0, "tau{i}");
        }
    }

    #[test]
    fn fine_grain_server_co_grants_requests() {
        // Server fine mode dispatches both 50% requests concurrently:
        // each sees its alone service time C + G^m + G^e = 8 ms.
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 10.0, 100.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 10.0, 100.0);
        let serial = TaskSet::new(vec![a.clone(), b.clone()], platform());
        let fine = TaskSet::new(vec![with_par(a, 50), with_par(b, 50)], platform());
        let cfg = SimConfig::new(Policy::Server, ms(1000.0));
        let rs = simulate(&serial, &cfg);
        let rf = simulate(&fine, &cfg);
        let worst_serial =
            rs.per_task[0].mort().unwrap().max(rs.per_task[1].mort().unwrap());
        let worst_fine =
            rf.per_task[0].mort().unwrap().max(rf.per_task[1].mort().unwrap());
        // Serial: one request waits out the other's 10.5 ms service.
        assert!(worst_serial >= ms(20.0), "serial worst {worst_serial} µs");
        assert!(worst_fine <= ms(12.0), "fine worst {worst_fine} µs");
        assert_eq!(rf.run.gpu_busy, rs.run.gpu_busy);
    }

    #[test]
    fn fine_grain_mutex_policies_keep_the_serial_engine() {
        // MPCP/FMLP+ serialize whole contexts: declared fractions are
        // deliberately inert there (documented pessimism) — the run is
        // bit-identical to the serial taskset's.
        let a = gpu_task(0, 0, 2, 1.0, 0.5, 8.0, 50.0);
        let b = gpu_task(1, 1, 1, 1.0, 0.5, 8.0, 80.0);
        let serial = TaskSet::new(vec![a.clone(), b.clone()], platform());
        let fine = TaskSet::new(vec![with_par(a, 40), with_par(b, 40)], platform());
        for policy in [Policy::Mpcp, Policy::FmlpPlus] {
            let cfg = SimConfig::new(policy, ms(500.0)).with_trace();
            let x = simulate(&serial, &cfg);
            let y = simulate(&fine, &cfg);
            assert_eq!(x.per_task, y.per_task, "{policy:?}");
            assert_eq!(x.run, y.run, "{policy:?}");
            assert_eq!(x.trace, y.trace, "{policy:?}");
        }
    }

    #[test]
    fn near_max_deadlines_with_miss_actions_stay_wrap_free() {
        // Companion to near_max_deadlines_saturate_instead_of_wrapping:
        // the miss-action scan and its D + 1 horizon must also saturate
        // rather than firing on wrapped deadlines.
        let a = gpu_task(0, 0, 2, 2.0, 0.5, 5.0, 100.0);
        let b = gpu_task(1, 0, 1, 2.0, 0.5, 5.0, 120.0);
        let ts = TaskSet::new(vec![a, b], platform());
        let offsets = vec![u64::MAX - ms(30.0), u64::MAX - ms(29.0)];
        for action in [DeadlineMissAction::Boost, DeadlineMissAction::AbortJob] {
            let cfg = SimConfig::new(Policy::GcapsEdf, u64::MAX)
                .with_offsets(offsets.clone())
                .with_miss_actions(vec![action; 2]);
            let res = simulate(&ts, &cfg);
            for i in [0, 1] {
                assert!(res.per_task[i].jobs >= 1, "{action:?}: tau{i} never ran");
                assert_eq!(res.per_task[i].aborted, 0, "{action:?}: bogus abort");
                assert_eq!(res.per_task[i].boosts, 0, "{action:?}: bogus boost");
                assert_eq!(res.per_task[i].deadline_misses, 0, "{action:?}");
            }
        }
    }
}
