//! The seed (pre-calendar) discrete-event engine, retained verbatim as
//! the executable specification of [`crate::sim::engine`].
//!
//! This is the recompute-on-event engine exactly as it shipped before
//! the event-calendar optimisation: O(n) release scans per settle
//! round, an FNV-1a full-state fingerprint per round for the
//! quiescence check, and allocating ring refreshes. Its only purpose is
//! the trace-for-trace equivalence property in
//! `rust/tests/kernel_equivalence.rs` — the optimised engine must
//! reproduce every release, completion, trace interval and metric of
//! this one, bit for bit. Never call it from a sweep hot path.
//!
//! One deliberate divergence from the seed bytes: `Time` additions that
//! could wrap (absolute deadlines, release advance, horizon sums) are
//! saturating here exactly as in `sim::engine` — correctness fixes are
//! applied to both engines so the bit-equality contract keeps holding.
//! The fault-injection/overload features (WCET overruns, GPU hangs,
//! mode changes, deadline-miss actions, the adaptive RR↔EDF governor)
//! are likewise mirrored at the exact same sequence points, keeping
//! the contract intact for faulted runs too. The only fingerprint
//! extension is the per-task `boosted` bit: `Boost` changes the CPU
//! allocation without touching any hashed field, so leaving it out
//! could quiesce a round early; hashing it is invisible to no-fault
//! runs (a constant bit perturbs `prev` and `cur` identically).

use std::collections::VecDeque;

use crate::model::fault::{self, DeadlineMissAction, Fault};
use crate::model::{TaskSet, Time, WaitMode};
use crate::sim::engine::{SimConfig, SimResult};
use crate::sim::metrics::{RunMetrics, TaskMetrics};
use crate::sim::trace::{Activity, Resource, Trace, TraceEvent};
use crate::sim::Policy;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Phase {
    Idle,
    Cpu,
    DrvCall { ending: bool },
    LockWait,
    GpuActive,
}

#[derive(Debug, Clone)]
struct TState {
    phase: Phase,
    seg: usize,
    cpu_rem: Time,
    gpu_rem: Time,
    release: Time,
    abs_deadline: Time,
    backlog: VecDeque<Time>,
    next_release: Time,
    drv_started: Time,
    ticket: u64,
    job: u64,
    cpu_pct: u32,
    gpu_pct: u32,
    hang_seg: Option<usize>,
    hanging: bool,
    boosted: bool,
    miss_handled: bool,
}

/// Fine-grain co-resident context (mirrors `sim::engine::Resident`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Resident {
    task: usize,
    switch_rem: Time,
    slice_rem: Time,
}

#[derive(Debug, Clone, Default)]
struct GpuState {
    running: Vec<usize>,
    pending: Vec<usize>,
    context: Option<usize>,
    switch_rem: Time,
    slice_rem: Time,
    ring: VecDeque<usize>,
    lock_holder: Option<usize>,
    lock_queue: Vec<(usize, u64)>,
    ticket_counter: u64,
    /// Fine mode only (see `sim::engine::GpuState`): empty in serial
    /// mode, so the serial hash stream and code paths are untouched.
    residents: Vec<Resident>,
    co_holders: Vec<usize>,
}

struct Engine<'a> {
    ts: &'a TaskSet,
    cfg: &'a SimConfig,
    now: Time,
    st: Vec<TState>,
    gpus: Vec<GpuState>,
    metrics: Vec<TaskMetrics>,
    run: RunMetrics,
    trace: Option<Trace>,
    cpu_alloc: Vec<Option<usize>>,
    pol: Policy,
    paused: Vec<bool>,
    mode_changes: Vec<(Time, Vec<usize>, Vec<usize>)>,
    mode_idx: usize,
    mwin: VecDeque<(Time, bool)>,
    win_jobs: u64,
    win_misses: u64,
    has_miss_actions: bool,
    /// Fine-grain co-running engaged (mirrors `sim::engine::Engine`).
    fine: bool,
}

impl<'a> Engine<'a> {
    fn new(ts: &'a TaskSet, cfg: &'a SimConfig) -> Engine<'a> {
        let n = ts.tasks.len();
        let st = (0..n)
            .map(|i| TState {
                phase: Phase::Idle,
                seg: 0,
                cpu_rem: 0,
                gpu_rem: 0,
                release: 0,
                abs_deadline: 0,
                backlog: Default::default(),
                next_release: cfg.offsets.get(i).copied().unwrap_or(0),
                drv_started: 0,
                ticket: 0,
                job: 0,
                cpu_pct: 100,
                gpu_pct: 100,
                hang_seg: None,
                hanging: false,
                boosted: false,
                miss_handled: false,
            })
            .collect();
        let mut mode_changes: Vec<(Time, Vec<usize>, Vec<usize>)> = cfg
            .faults
            .faults
            .iter()
            .filter_map(|f| match f {
                Fault::ModeChange { at, disable, enable } => {
                    Some((*at, disable.clone(), enable.clone()))
                }
                _ => None,
            })
            .collect();
        mode_changes.sort_by_key(|m| m.0);
        let has_miss_actions =
            cfg.miss_actions.iter().any(|a| *a != DeadlineMissAction::Log);
        let fine = ts.has_fine_grain()
            && !matches!(cfg.policy, Policy::Mpcp | Policy::FmlpPlus);
        Engine {
            ts,
            cfg,
            now: 0,
            st,
            gpus: vec![GpuState::default(); ts.platform.num_gpus()],
            metrics: vec![TaskMetrics::default(); n],
            run: RunMetrics::default(),
            trace: cfg.trace.then(Trace::default),
            cpu_alloc: vec![None; ts.platform.num_cpus],
            pol: cfg.policy,
            paused: vec![false; n],
            mode_changes,
            mode_idx: 0,
            mwin: VecDeque::new(),
            win_jobs: 0,
            win_misses: 0,
            has_miss_actions,
            fine,
        }
    }

    fn gpu_of(&self, i: usize) -> usize {
        self.ts.tasks[i].gpu
    }

    /// SM fraction (percent) of task `i`'s current GPU segment.
    fn frac(&self, i: usize) -> Time {
        self.ts.tasks[i]
            .gpu_segments
            .get(self.st[i].seg)
            .map(|g| g.par.pct() as Time)
            .unwrap_or(100)
    }

    fn alpha_of(&self, i: usize) -> Time {
        let ctx = self.ts.platform.gpus[self.gpu_of(i)];
        ctx.epsilon.saturating_sub(ctx.theta)
    }

    fn gpu_rank(&self, i: usize) -> u64 {
        if self.st[i].boosted {
            return u64::MAX;
        }
        match self.pol {
            Policy::GcapsEdf => u64::MAX.saturating_sub(self.st[i].abs_deadline),
            _ => self.ts.tasks[i].gpu_prio as u64,
        }
    }

    fn start_job(&mut self, i: usize, release: Time) {
        let t = &self.ts.tasks[i];
        let job = self.st[i].job;
        let (cpu_pct, gpu_pct) = self.cfg.faults.overrun(i, job);
        let hang_seg = self.cfg.faults.hang(i, job);
        let s = &mut self.st[i];
        s.job = job + 1;
        s.cpu_pct = cpu_pct;
        s.gpu_pct = gpu_pct;
        s.hang_seg = hang_seg;
        s.hanging = false;
        s.boosted = false;
        s.miss_handled = false;
        s.release = release;
        // Saturating, mirroring sim::engine bit-for-bit: a wrapped sum
        // inverts the EDF rank and miss detection.
        s.abs_deadline = release.saturating_add(t.deadline);
        s.seg = 0;
        s.phase = Phase::Cpu;
        s.cpu_rem = fault::scale(t.cpu_segments[0], cpu_pct);
        if let Some(tr) = &mut self.trace {
            tr.releases.push((i, release));
        }
    }

    fn finish_cpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        let seg = self.st[i].seg;
        if seg < t.eta_g() {
            match self.pol {
                Policy::Gcaps | Policy::GcapsEdf => {
                    self.st[i].phase = Phase::DrvCall { ending: false };
                    self.st[i].cpu_rem = self.alpha_of(i);
                    self.st[i].drv_started = self.now;
                }
                Policy::Mpcp | Policy::FmlpPlus | Policy::Server => {
                    let g = self.gpu_of(i);
                    self.st[i].phase = Phase::LockWait;
                    self.gpus[g].ticket_counter += 1;
                    self.st[i].ticket = self.gpus[g].ticket_counter;
                    let ticket = self.st[i].ticket;
                    self.gpus[g].lock_queue.push((i, ticket));
                }
                Policy::TsgRr => self.begin_gpu_segment(i),
            }
        } else {
            self.complete_job(i);
        }
    }

    fn begin_gpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        let seg = self.st[i].seg;
        self.st[i].phase = Phase::GpuActive;
        self.st[i].cpu_rem = t.gpu_segments[seg].misc;
        self.st[i].gpu_rem = if self.st[i].hang_seg == Some(seg) {
            self.st[i].hanging = true;
            self.cfg.faults.hang_timeout
        } else {
            fault::scale(t.gpu_segments[seg].exec, self.st[i].gpu_pct)
        };
    }

    fn finish_gpu_segment(&mut self, i: usize) {
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                self.st[i].phase = Phase::DrvCall { ending: true };
                self.st[i].cpu_rem = self.alpha_of(i);
                self.st[i].drv_started = self.now;
            }
            Policy::Mpcp | Policy::FmlpPlus | Policy::Server => {
                let g = self.gpu_of(i);
                if self.fine && self.gpus[g].lock_holder != Some(i) {
                    self.gpus[g].co_holders.retain(|&k| k != i);
                } else {
                    debug_assert_eq!(self.gpus[g].lock_holder, Some(i));
                    self.gpus[g].lock_holder = None;
                    if self.fine && !self.gpus[g].co_holders.is_empty() {
                        let k = self.gpus[g].co_holders.remove(0);
                        self.gpus[g].lock_holder = Some(k);
                    }
                }
                self.next_cpu_segment(i);
            }
            Policy::TsgRr => self.next_cpu_segment(i),
        }
    }

    fn next_cpu_segment(&mut self, i: usize) {
        let t = &self.ts.tasks[i];
        self.st[i].seg += 1;
        self.st[i].phase = Phase::Cpu;
        self.st[i].cpu_rem =
            fault::scale(t.cpu_segments[self.st[i].seg], self.st[i].cpu_pct);
    }

    fn complete_job(&mut self, i: usize) {
        let s = &mut self.st[i];
        let resp = self.now.saturating_sub(s.release);
        let missed = self.now > s.abs_deadline;
        self.metrics[i].response_times.push(resp);
        self.metrics[i].jobs += 1;
        if missed {
            self.metrics[i].deadline_misses += 1;
            self.run.last_tardy = self.now;
        }
        if self.cfg.adaptive.is_some() {
            self.mwin.push_back((self.now, missed));
            self.win_jobs += 1;
            if missed {
                self.win_misses += 1;
            }
        }
        if let Some(tr) = &mut self.trace {
            tr.completions.push((i, self.now));
        }
        let s = &mut self.st[i];
        s.phase = Phase::Idle;
        if let Some(next) = s.backlog.pop_front() {
            self.start_job(i, next);
        }
    }

    fn abort_job(&mut self, i: usize) {
        let g = self.gpu_of(i);
        self.gpus[g].running.retain(|&k| k != i);
        self.gpus[g].pending.retain(|&k| k != i);
        self.gpus[g].ring.retain(|&k| k != i);
        self.gpus[g].lock_queue.retain(|&(k, _)| k != i);
        self.gpus[g].residents.retain(|r| r.task != i);
        self.gpus[g].co_holders.retain(|&k| k != i);
        if self.gpus[g].lock_holder == Some(i) {
            self.gpus[g].lock_holder = None;
            if !self.gpus[g].co_holders.is_empty() {
                let k = self.gpus[g].co_holders.remove(0);
                self.gpus[g].lock_holder = Some(k);
            }
        }
        self.metrics[i].aborted += 1;
        self.run.last_tardy = self.now;
        if self.cfg.adaptive.is_some() {
            self.mwin.push_back((self.now, true));
            self.win_jobs += 1;
            self.win_misses += 1;
        }
        let s = &mut self.st[i];
        s.phase = Phase::Idle;
        s.cpu_rem = 0;
        s.gpu_rem = 0;
        s.hanging = false;
        if self.paused[i] {
            self.st[i].backlog.clear();
        } else if let Some(next) = self.st[i].backlog.pop_front() {
            self.start_job(i, next);
        }
    }

    fn finish_driver_call(&mut self, i: usize) {
        let g = self.gpu_of(i);
        let ending = matches!(self.st[i].phase, Phase::DrvCall { ending: true });
        let theta = self.ts.platform.gpus[g].theta;
        self.metrics[i]
            .runlist_updates
            .push(self.now.saturating_sub(self.st[i].drv_started).saturating_add(theta));
        let me = &self.ts.tasks[i];
        if !ending {
            if me.best_effort {
                let rt_running =
                    self.gpus[g].running.iter().any(|&k| !self.ts.tasks[k].best_effort);
                if rt_running {
                    self.gpus[g].pending.push(i);
                } else {
                    self.gpus[g].running.push(i);
                }
            } else {
                let tau_h = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .max_by_key(|&k| self.gpu_rank(k));
                let preempt = match tau_h {
                    None => true,
                    Some(h) => self.gpu_rank(i) > self.gpu_rank(h),
                };
                if preempt {
                    let displaced: Vec<usize> = self.gpus[g].running.drain(..).collect();
                    self.gpus[g].pending.extend(displaced);
                    self.gpus[g].running.push(i);
                } else {
                    self.gpus[g].pending.push(i);
                }
            }
            self.begin_gpu_segment(i);
        } else {
            self.gpus[g].running.retain(|&k| k != i);
            self.gpus[g].pending.retain(|&k| k != i);
            let tau_k = self.gpus[g]
                .pending
                .iter()
                .copied()
                .filter(|&k| !self.ts.tasks[k].best_effort)
                .max_by_key(|&k| self.gpu_rank(k));
            if let Some(k) = tau_k {
                self.gpus[g].pending.retain(|&x| x != k);
                self.gpus[g].running.push(k);
            } else {
                let all: Vec<usize> = self.gpus[g].pending.drain(..).collect();
                self.gpus[g].running.extend(all);
            }
            self.next_cpu_segment(i);
        }
    }

    fn try_grant_lock(&mut self, g: usize) {
        if self.gpus[g].lock_holder.is_none() && !self.gpus[g].lock_queue.is_empty() {
            self.grant_primary_lock(g);
        }
        if self.fine && self.pol == Policy::Server {
            self.grant_server_co_holders(g);
        }
    }

    fn grant_primary_lock(&mut self, g: usize) {
        let idx = match self.pol {
            Policy::Mpcp => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .max_by_key(|(_, &(t, tk))| {
                    (self.ts.tasks[t].cpu_prio, std::cmp::Reverse(tk))
                })
                .map(|(j, _)| j)
                .unwrap(),
            Policy::FmlpPlus => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .min_by_key(|(_, &(_, tk))| tk)
                .map(|(j, _)| j)
                .unwrap(),
            Policy::Server => self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .max_by_key(|(_, &(t, tk))| {
                    (
                        !self.ts.tasks[t].best_effort,
                        self.ts.tasks[t].cpu_prio,
                        std::cmp::Reverse(tk),
                    )
                })
                .map(|(j, _)| j)
                .unwrap(),
            _ => unreachable!(),
        };
        let (task, _) = self.gpus[g].lock_queue.swap_remove(idx);
        self.gpus[g].lock_holder = Some(task);
        self.begin_gpu_segment(task);
    }

    /// Server fine mode (mirrors `sim::engine`): co-grant queued
    /// requests while the resident fractions sum to ≤ 100%.
    fn grant_server_co_holders(&mut self, g: usize) {
        let Some(primary) = self.gpus[g].lock_holder else { return };
        let mut cap = self.frac(primary);
        for idx in 0..self.gpus[g].co_holders.len() {
            let h = self.gpus[g].co_holders[idx];
            cap = cap.saturating_add(self.frac(h));
        }
        loop {
            let next = self.gpus[g]
                .lock_queue
                .iter()
                .enumerate()
                .filter(|(_, &(t, _))| {
                    cap.saturating_add(self.frac(t)) <= 100
                })
                .max_by_key(|(_, &(t, tk))| {
                    (
                        !self.ts.tasks[t].best_effort,
                        self.ts.tasks[t].cpu_prio,
                        std::cmp::Reverse(tk),
                    )
                })
                .map(|(j, _)| j);
            let Some(j) = next else { break };
            let (task, _) = self.gpus[g].lock_queue.swap_remove(j);
            cap = cap.saturating_add(self.frac(task));
            self.gpus[g].co_holders.push(task);
            self.begin_gpu_segment(task);
        }
    }

    fn wants_cpu(&self, i: usize) -> bool {
        match self.st[i].phase {
            Phase::Cpu | Phase::DrvCall { .. } => true,
            Phase::GpuActive => {
                if self.pol == Policy::Server {
                    self.ts.tasks[i].mode == WaitMode::BusyWait
                } else {
                    self.st[i].cpu_rem > 0 || self.ts.tasks[i].mode == WaitMode::BusyWait
                }
            }
            Phase::LockWait => self.ts.tasks[i].mode == WaitMode::BusyWait,
            Phase::Idle => false,
        }
    }

    fn eff_prio(&self, i: usize) -> u64 {
        let base = self.ts.tasks[i].cpu_prio as u64;
        let boosted = matches!(self.pol, Policy::Mpcp | Policy::FmlpPlus)
            && self.gpus[self.gpu_of(i)].lock_holder == Some(i)
            && matches!(self.st[i].phase, Phase::GpuActive)
            && self.st[i].cpu_rem > 0;
        if boosted {
            return (1 << 40) | base;
        }
        if matches!(self.st[i].phase, Phase::DrvCall { .. })
            && self.st[i].cpu_rem < self.alpha_of(i)
        {
            return (1 << 41) | base;
        }
        if self.st[i].boosted {
            return (1 << 39) | base;
        }
        base
    }

    fn compute_cpu_alloc(&self) -> Vec<Option<usize>> {
        let mut alloc = vec![None::<usize>; self.ts.platform.num_cpus];
        for (i, t) in self.ts.tasks.iter().enumerate() {
            if !self.wants_cpu(i) {
                continue;
            }
            let p = self.eff_prio(i);
            match alloc[t.core] {
                None => alloc[t.core] = Some(i),
                Some(cur) => {
                    let pc = self.eff_prio(cur);
                    if (p, std::cmp::Reverse(i)) > (pc, std::cmp::Reverse(cur)) {
                        alloc[t.core] = Some(i);
                    }
                }
            }
        }
        alloc
    }

    fn ring_eligible(&self, i: usize) -> bool {
        if !(matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0) {
            return false;
        }
        match self.pol {
            Policy::TsgRr => true,
            Policy::Gcaps | Policy::GcapsEdf => {
                self.ts.tasks[i].best_effort
                    && self.gpus[self.gpu_of(i)].running.contains(&i)
            }
            _ => false,
        }
    }

    fn refresh_ring(&mut self, g: usize) {
        let eligible: Vec<usize> = (0..self.st.len())
            .filter(|&i| self.gpu_of(i) == g && self.ring_eligible(i))
            .collect();
        self.gpus[g].ring.retain(|i| eligible.contains(i));
        for i in eligible {
            if !self.gpus[g].ring.contains(&i) {
                self.gpus[g].ring.push_back(i);
            }
        }
    }

    fn desired_gpu_context(&self, g: usize) -> Option<usize> {
        let execing = |i: usize| {
            matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
        };
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                let rt = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .filter(|&i| !self.ts.tasks[i].best_effort && execing(i))
                    .max_by_key(|&i| self.gpu_rank(i));
                rt.or_else(|| self.gpus[g].ring.front().copied())
            }
            Policy::TsgRr => self.gpus[g].ring.front().copied(),
            Policy::Mpcp | Policy::FmlpPlus => {
                self.gpus[g].lock_holder.filter(|&i| execing(i))
            }
            Policy::Server => self.gpus[g].lock_holder.filter(|&i| {
                matches!(self.st[i].phase, Phase::GpuActive)
                    && (self.st[i].cpu_rem > 0 || self.st[i].gpu_rem > 0)
            }),
        }
    }

    fn update_gpu_context(&mut self, g: usize) {
        let want = self.desired_gpu_context(g);
        if want == self.gpus[g].context {
            return;
        }
        match want {
            None => {
                self.gpus[g].context = None;
                self.gpus[g].switch_rem = 0;
            }
            Some(i) => {
                let charge = match self.pol {
                    Policy::Mpcp | Policy::FmlpPlus | Policy::Server => 0,
                    Policy::Gcaps | Policy::GcapsEdf | Policy::TsgRr => {
                        self.ts.platform.gpus[g].theta
                    }
                };
                self.gpus[g].context = Some(i);
                self.gpus[g].switch_rem = charge;
                self.gpus[g].slice_rem = self.ts.platform.gpus[g].tsg_slice;
                if charge > 0 {
                    self.run.gpu_context_switches += 1;
                }
            }
        }
    }

    // -- fine-grain co-running, mirroring `sim::engine` exactly (see
    //    the soundness discussion there) ---------------------------------

    fn desired_residents(&self, g: usize) -> Vec<usize> {
        let execing = |i: usize| {
            matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
        };
        let mut out = Vec::new();
        let mut cap: Time = 0;
        match self.pol {
            Policy::Gcaps | Policy::GcapsEdf => {
                let mut rts: Vec<usize> = self.gpus[g]
                    .running
                    .iter()
                    .copied()
                    .filter(|&i| !self.ts.tasks[i].best_effort && execing(i))
                    .collect();
                rts.sort_by(|&a, &b| {
                    self.gpu_rank(b).cmp(&self.gpu_rank(a)).then(a.cmp(&b))
                });
                for i in rts {
                    let f = self.frac(i);
                    if cap.saturating_add(f) <= 100 {
                        cap += f;
                        out.push(i);
                    }
                }
                if out.is_empty() {
                    for &i in &self.gpus[g].ring {
                        if !execing(i) {
                            continue;
                        }
                        let f = self.frac(i);
                        if cap.saturating_add(f) <= 100 {
                            cap += f;
                            out.push(i);
                        }
                    }
                }
            }
            Policy::TsgRr => {
                for &i in &self.gpus[g].ring {
                    if !execing(i) {
                        continue;
                    }
                    let f = self.frac(i);
                    if cap.saturating_add(f) <= 100 {
                        cap += f;
                        out.push(i);
                    }
                }
            }
            Policy::Mpcp | Policy::FmlpPlus => {
                if let Some(h) = self.gpus[g].lock_holder {
                    if execing(h) {
                        out.push(h);
                    }
                }
            }
            Policy::Server => {
                let serving = |i: usize| {
                    matches!(self.st[i].phase, Phase::GpuActive)
                        && (self.st[i].cpu_rem > 0 || self.st[i].gpu_rem > 0)
                };
                if let Some(h) = self.gpus[g].lock_holder {
                    if serving(h) {
                        out.push(h);
                    }
                }
                for &h in &self.gpus[g].co_holders {
                    if serving(h) {
                        out.push(h);
                    }
                }
            }
        }
        out
    }

    fn update_gpu_residents(&mut self, g: usize) {
        let mut want = self.desired_residents(g);
        want.sort_unstable();
        let same = self.gpus[g].residents.len() == want.len()
            && self.gpus[g].residents.iter().zip(&want).all(|(r, &t)| r.task == t);
        if same {
            return;
        }
        let charge = match self.pol {
            Policy::Mpcp | Policy::FmlpPlus | Policy::Server => 0,
            Policy::Gcaps | Policy::GcapsEdf | Policy::TsgRr => {
                self.ts.platform.gpus[g].theta
            }
        };
        let slice = self.ts.platform.gpus[g].tsg_slice;
        let old = std::mem::take(&mut self.gpus[g].residents);
        let mut new = Vec::with_capacity(want.len());
        for &t in &want {
            if let Some(r) = old.iter().find(|r| r.task == t) {
                new.push(*r);
            } else {
                if charge > 0 {
                    self.run.gpu_context_switches += 1;
                }
                new.push(Resident { task: t, switch_rem: charge, slice_rem: slice });
            }
        }
        self.gpus[g].residents = new;
    }

    fn rebalance_fine(&mut self, g: usize) {
        let execing = |st: &TState| {
            matches!(st.phase, Phase::GpuActive) && st.gpu_rem > 0
        };
        let mut pool: Vec<usize> = self.gpus[g]
            .running
            .iter()
            .chain(self.gpus[g].pending.iter())
            .copied()
            .filter(|&k| !self.ts.tasks[k].best_effort && execing(&self.st[k]))
            .collect();
        pool.sort_by(|&a, &b| {
            self.gpu_rank(b).cmp(&self.gpu_rank(a)).then(a.cmp(&b))
        });
        let mut cap: Time = 0;
        let mut promote = Vec::new();
        let mut demote = Vec::new();
        for &k in &pool {
            let f = self.frac(k);
            if cap.saturating_add(f) <= 100 {
                cap += f;
                if !self.gpus[g].running.contains(&k) {
                    promote.push(k);
                }
            } else if self.gpus[g].running.contains(&k) {
                demote.push(k);
            }
        }
        for k in demote {
            self.gpus[g].running.retain(|&x| x != k);
            self.gpus[g].pending.push(k);
        }
        for k in promote {
            self.gpus[g].pending.retain(|&x| x != k);
            self.gpus[g].running.push(k);
        }
    }

    fn rotate_expired_residents(&mut self, g: usize) {
        for idx in 0..self.gpus[g].residents.len() {
            let r = self.gpus[g].residents[idx];
            if r.switch_rem != 0 || r.slice_rem != 0 {
                continue;
            }
            let in_ring = self.gpus[g].ring.contains(&r.task);
            let waiter = self.gpus[g].ring.iter().any(|&k| {
                !self.gpus[g].residents.iter().any(|x| x.task == k)
            });
            let at_back = self.gpus[g].ring.back() == Some(&r.task);
            if in_ring && waiter && !at_back {
                self.gpus[g].ring.retain(|&k| k != r.task);
                self.gpus[g].ring.push_back(r.task);
            } else {
                self.gpus[g].residents[idx].slice_rem =
                    self.ts.platform.gpus[g].tsg_slice;
            }
        }
    }

    fn release_due(&mut self) {
        for i in 0..self.st.len() {
            while self.st[i].next_release <= self.now {
                let rel = self.st[i].next_release;
                // Saturating (mirrors the engine's release calendar):
                // wrapped, the next release lands in the past and this
                // loop releases forever.
                self.st[i].next_release = rel.saturating_add(self.ts.tasks[i].period);
                if self.paused[i] {
                    continue;
                }
                if self.st[i].phase == Phase::Idle && self.st[i].backlog.is_empty() {
                    self.start_job(i, rel);
                } else {
                    self.st[i].backlog.push_back(rel);
                }
            }
        }
    }

    fn fault_tick(&mut self) {
        while self.mode_idx < self.mode_changes.len()
            && self.mode_changes[self.mode_idx].0 <= self.now
        {
            let (_, disable, enable) = self.mode_changes[self.mode_idx].clone();
            for &i in &disable {
                if i >= self.st.len() {
                    continue;
                }
                self.paused[i] = true;
                if self.st[i].phase != Phase::Idle {
                    self.abort_job(i);
                } else {
                    self.st[i].backlog.clear();
                }
            }
            for &i in &enable {
                if i < self.st.len() {
                    self.paused[i] = false;
                }
            }
            self.mode_idx += 1;
        }
        if let Some(ap) = self.cfg.adaptive {
            while let Some(&(t, missed)) = self.mwin.front() {
                if t.saturating_add(ap.window) < self.now {
                    self.mwin.pop_front();
                    self.win_jobs -= 1;
                    if missed {
                        self.win_misses -= 1;
                    }
                } else {
                    break;
                }
            }
            if self.pol == Policy::TsgRr
                && self.win_jobs >= ap.min_jobs
                && self.win_misses * 100 >= ap.up_pct as u64 * self.win_jobs
            {
                self.switch_policy(Policy::GcapsEdf);
            } else if self.pol == Policy::GcapsEdf
                && (self.win_jobs == 0
                    || (self.win_jobs >= ap.min_jobs
                        && self.win_misses * 100 <= ap.down_pct as u64 * self.win_jobs))
            {
                self.switch_policy(Policy::TsgRr);
            }
        }
    }

    fn switch_policy(&mut self, to: Policy) {
        if self.pol == to {
            return;
        }
        self.pol = to;
        self.run.policy_switches += 1;
        for g in 0..self.gpus.len() {
            self.gpus[g].running.clear();
            self.gpus[g].pending.clear();
            if to == Policy::GcapsEdf {
                // Ascending task order, matching sim::engine's
                // per-engine task list.
                for i in 0..self.st.len() {
                    if self.gpu_of(i) == g && matches!(self.st[i].phase, Phase::GpuActive) {
                        self.gpus[g].running.push(i);
                    }
                }
            }
        }
    }

    fn next_horizon(&self) -> Time {
        let mut h = self.cfg.duration;
        for s in &self.st {
            h = h.min(s.next_release);
        }
        // Saturating sums, mirroring sim::engine.
        for &slot in &self.cpu_alloc {
            if let Some(i) = slot {
                if self.st[i].cpu_rem > 0 {
                    match self.st[i].phase {
                        Phase::Cpu | Phase::DrvCall { .. } | Phase::GpuActive => {
                            h = h.min(self.now.saturating_add(self.st[i].cpu_rem))
                        }
                        _ => {}
                    }
                }
            }
        }
        for gs in &self.gpus {
            if self.fine {
                let contested = gs.ring.iter().any(|&k| {
                    !gs.residents.iter().any(|x| x.task == k)
                });
                for r in &gs.residents {
                    let i = r.task;
                    if r.switch_rem > 0 {
                        h = h.min(self.now.saturating_add(r.switch_rem));
                    } else if self.pol == Policy::Server
                        && matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].cpu_rem > 0
                    {
                        h = h.min(self.now.saturating_add(self.st[i].cpu_rem));
                    } else if matches!(self.st[i].phase, Phase::GpuActive)
                        && self.st[i].gpu_rem > 0
                    {
                        h = h.min(self.now.saturating_add(self.st[i].gpu_rem));
                        if contested && gs.ring.contains(&i) {
                            h = h.min(self.now.saturating_add(r.slice_rem));
                        }
                    }
                }
                continue;
            }
            if let Some(i) = gs.context {
                if gs.switch_rem > 0 {
                    h = h.min(self.now.saturating_add(gs.switch_rem));
                } else if self.pol == Policy::Server
                    && matches!(self.st[i].phase, Phase::GpuActive)
                    && self.st[i].cpu_rem > 0
                {
                    h = h.min(self.now.saturating_add(self.st[i].cpu_rem));
                } else if matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0
                {
                    h = h.min(self.now.saturating_add(self.st[i].gpu_rem));
                    if gs.ring.len() > 1 && gs.ring.front() == Some(&i) {
                        h = h.min(self.now.saturating_add(gs.slice_rem));
                    }
                }
            }
        }
        if self.mode_idx < self.mode_changes.len() {
            h = h.min(self.mode_changes[self.mode_idx].0);
        }
        if self.has_miss_actions {
            for i in 0..self.st.len() {
                if self.st[i].phase != Phase::Idle
                    && !self.st[i].miss_handled
                    && self.cfg.action(i) != DeadlineMissAction::Log
                {
                    h = h.min(self.st[i].abs_deadline.saturating_add(1));
                }
            }
        }
        if let Some(ap) = self.cfg.adaptive {
            if let Some(&(t, _)) = self.mwin.front() {
                h = h.min(t.saturating_add(ap.window).saturating_add(1));
            }
        }
        h.max(self.now)
    }

    fn advance(&mut self, dt: Time) {
        if dt == 0 {
            return;
        }
        for core in 0..self.cpu_alloc.len() {
            if let Some(i) = self.cpu_alloc[core] {
                let (act, progresses) = match self.st[i].phase {
                    Phase::Cpu => (Activity::CpuSeg, true),
                    Phase::DrvCall { .. } => (Activity::DriverCall, true),
                    Phase::GpuActive => {
                        if self.pol == Policy::Server {
                            (Activity::BusyWait, false)
                        } else if self.st[i].cpu_rem > 0 {
                            (Activity::GpuMisc, true)
                        } else {
                            (Activity::BusyWait, false)
                        }
                    }
                    Phase::LockWait => (Activity::BusyWait, false),
                    Phase::Idle => (Activity::CpuSeg, false),
                };
                if progresses {
                    self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(dt);
                }
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Core(core),
                        task: i,
                        activity: act,
                        start: self.now,
                        end: self.now.saturating_add(dt),
                    });
                }
            }
        }
        for g in 0..self.gpus.len() {
            if self.fine {
                self.advance_residents(g, dt);
                continue;
            }
            let Some(i) = self.gpus[g].context else { continue };
            if self.gpus[g].switch_rem > 0 {
                let d = dt.min(self.gpus[g].switch_rem);
                self.gpus[g].switch_rem = self.gpus[g].switch_rem.saturating_sub(d);
                self.run.gpu_switch_time += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::CtxSwitch,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if self.pol == Policy::Server
                && matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].cpu_rem > 0
            {
                let d = dt.min(self.st[i].cpu_rem);
                self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(d);
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::ServerMisc,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if matches!(self.st[i].phase, Phase::GpuActive) && self.st[i].gpu_rem > 0 {
                let d = dt.min(self.st[i].gpu_rem);
                self.st[i].gpu_rem = self.st[i].gpu_rem.saturating_sub(d);
                self.gpus[g].slice_rem = self.gpus[g].slice_rem.saturating_sub(dt);
                self.run.gpu_busy += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: if self.st[i].hanging {
                            Activity::GpuHang
                        } else {
                            Activity::GpuExec
                        },
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            }
        }
        self.now = self.now.saturating_add(dt);
    }

    fn advance_residents(&mut self, g: usize, dt: Time) {
        for idx in 0..self.gpus[g].residents.len() {
            let r = self.gpus[g].residents[idx];
            let i = r.task;
            if r.switch_rem > 0 {
                let d = dt.min(r.switch_rem);
                self.gpus[g].residents[idx].switch_rem =
                    r.switch_rem.saturating_sub(d);
                self.run.gpu_switch_time += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::CtxSwitch,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if self.pol == Policy::Server
                && matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].cpu_rem > 0
            {
                let d = dt.min(self.st[i].cpu_rem);
                self.st[i].cpu_rem = self.st[i].cpu_rem.saturating_sub(d);
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: Activity::ServerMisc,
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            } else if matches!(self.st[i].phase, Phase::GpuActive)
                && self.st[i].gpu_rem > 0
            {
                let d = dt.min(self.st[i].gpu_rem);
                self.st[i].gpu_rem = self.st[i].gpu_rem.saturating_sub(d);
                self.gpus[g].residents[idx].slice_rem =
                    r.slice_rem.saturating_sub(dt);
                self.run.gpu_busy += d;
                if let Some(tr) = &mut self.trace {
                    tr.push(TraceEvent {
                        resource: Resource::Gpu(g),
                        task: i,
                        activity: if self.st[i].hanging {
                            Activity::GpuHang
                        } else {
                            Activity::GpuExec
                        },
                        start: self.now,
                        end: self.now.saturating_add(d),
                    });
                }
            }
        }
    }

    fn fingerprint(&self) -> u64 {
        const FNV_PRIME: u64 = 0x100000001b3;
        let mut h = 0xcbf29ce484222325u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(FNV_PRIME);
        };
        for s in &self.st {
            let phase = match s.phase {
                Phase::Idle => 0u64,
                Phase::Cpu => 1,
                Phase::DrvCall { ending: false } => 2,
                Phase::DrvCall { ending: true } => 3,
                Phase::LockWait => 4,
                Phase::GpuActive => 5,
            };
            mix(phase);
            mix(s.seg as u64);
            mix(s.cpu_rem);
            mix(s.gpu_rem);
            // The one post-seed fingerprint extension: Boost changes the
            // CPU allocation (eff_prio) without touching any field above,
            // so it must be hashed for quiescence to track it. Constant
            // `false` in unfaulted runs — prev and cur shift identically,
            // leaving the equality check (all this hash feeds) unchanged.
            mix(s.boosted as u64);
        }
        for gs in &self.gpus {
            mix(gs.context.map_or(u64::MAX, |c| c as u64));
            mix(gs.switch_rem);
            for &r in &gs.ring {
                mix(r as u64);
            }
            mix(gs.running.len() as u64);
            mix(gs.pending.len() as u64);
            // Fine-mode extension: resident membership and their θ
            // state, plus server co-holders. Empty vectors in serial
            // mode, so the loops mix nothing and the serial hash stream
            // is byte-identical to the seed's. `slice_rem` is excluded
            // like the serial `slice_rem` — slice expiry only becomes
            // scheduler-visible through the (hashed) ring order.
            for r in &gs.residents {
                mix(r.task as u64);
                mix(r.switch_rem);
            }
            for &c in &gs.co_holders {
                mix(c as u64);
            }
            // Fine mode also hashes runlist MEMBERSHIP (not just the
            // lengths): `rebalance_fine` can swap a task between
            // running and pending without changing either length,
            // which the serial len-only hash would miss.
            if self.fine {
                for &k in &gs.running {
                    mix(k as u64);
                }
                for &k in &gs.pending {
                    mix(k as u64);
                }
            }
        }
        h
    }

    fn settle(&mut self) {
        let mut prev = self.fingerprint();
        for _round in 0..10_000 {
            self.release_due();

            if self.has_miss_actions {
                for i in 0..self.st.len() {
                    if self.st[i].phase == Phase::Idle
                        || self.st[i].miss_handled
                        || self.now <= self.st[i].abs_deadline
                    {
                        continue;
                    }
                    match self.cfg.action(i) {
                        DeadlineMissAction::Log => {}
                        DeadlineMissAction::Boost => {
                            self.st[i].miss_handled = true;
                            self.st[i].boosted = true;
                            self.metrics[i].boosts += 1;
                        }
                        DeadlineMissAction::AbortJob => {
                            self.st[i].miss_handled = true;
                            self.abort_job(i);
                        }
                        DeadlineMissAction::DropTask => {
                            self.st[i].miss_handled = true;
                            self.paused[i] = true;
                            self.abort_job(i);
                        }
                    }
                }
            }

            self.cpu_alloc = self.compute_cpu_alloc();
            for core in 0..self.cpu_alloc.len() {
                if let Some(i) = self.cpu_alloc[core] {
                    if self.st[i].cpu_rem == 0 {
                        match self.st[i].phase {
                            Phase::Cpu => self.finish_cpu_segment(i),
                            Phase::DrvCall { .. } => self.finish_driver_call(i),
                            _ => {}
                        }
                    }
                }
            }

            for i in 0..self.st.len() {
                if matches!(self.st[i].phase, Phase::GpuActive)
                    && self.st[i].cpu_rem == 0
                    && self.st[i].gpu_rem == 0
                {
                    if self.st[i].hanging {
                        self.metrics[i].hangs += 1;
                        self.abort_job(i);
                    } else {
                        self.finish_gpu_segment(i);
                    }
                }
            }

            if matches!(self.pol, Policy::Mpcp | Policy::FmlpPlus | Policy::Server) {
                for g in 0..self.gpus.len() {
                    self.try_grant_lock(g);
                }
            }

            if matches!(self.pol, Policy::Gcaps | Policy::GcapsEdf) && self.fine {
                for g in 0..self.gpus.len() {
                    self.rebalance_fine(g);
                }
            } else if matches!(self.pol, Policy::Gcaps | Policy::GcapsEdf) {
                let execing = |st: &TState| {
                    matches!(st.phase, Phase::GpuActive) && st.gpu_rem > 0
                };
                for g in 0..self.gpus.len() {
                    let any_running_exec =
                        self.gpus[g].running.iter().any(|&k| execing(&self.st[k]));
                    if !any_running_exec {
                        let promote = self.gpus[g]
                            .pending
                            .iter()
                            .copied()
                            .filter(|&k| {
                                !self.ts.tasks[k].best_effort && execing(&self.st[k])
                            })
                            .max_by_key(|&k| self.gpu_rank(k));
                        if let Some(k) = promote {
                            self.gpus[g].pending.retain(|&x| x != k);
                            self.gpus[g].running.push(k);
                        }
                    }
                }
            }

            for g in 0..self.gpus.len() {
                self.refresh_ring(g);
                if self.fine {
                    self.rotate_expired_residents(g);
                    self.update_gpu_residents(g);
                    continue;
                }
                if let Some(i) = self.gpus[g].context {
                    if self.gpus[g].switch_rem == 0
                        && self.gpus[g].slice_rem == 0
                        && self.gpus[g].ring.len() > 1
                        && self.gpus[g].ring.front() == Some(&i)
                    {
                        self.gpus[g].ring.rotate_left(1);
                    } else if self.gpus[g].ring.len() == 1 && self.gpus[g].slice_rem == 0 {
                        self.gpus[g].slice_rem = self.ts.platform.gpus[g].tsg_slice;
                    }
                }
                self.update_gpu_context(g);
            }
            self.cpu_alloc = self.compute_cpu_alloc();

            let cur = self.fingerprint();
            if cur == prev {
                return;
            }
            prev = cur;
        }
        panic!("settle() did not quiesce at t = {} µs", self.now);
    }

    fn run(mut self) -> SimResult {
        while self.now < self.cfg.duration {
            self.fault_tick();
            self.settle();
            let h = self.next_horizon();
            let dt = h.saturating_sub(self.now);
            if dt == 0 {
                let next = self
                    .st
                    .iter()
                    .map(|s| s.next_release)
                    .min()
                    .unwrap_or(self.cfg.duration);
                if next <= self.now {
                    break;
                }
                self.advance(next.min(self.cfg.duration).saturating_sub(self.now));
            } else {
                self.advance(dt);
            }
        }
        self.run.horizon = self.now;
        SimResult { per_task: self.metrics, run: self.run, trace: self.trace }
    }
}

/// Simulate `ts` under `cfg` with the seed engine.
pub fn simulate_reference(ts: &TaskSet, cfg: &SimConfig) -> SimResult {
    debug_assert!(ts.validate().is_ok(), "invalid taskset: {:?}", ts.validate());
    Engine::new(ts, cfg).run()
}
