//! Discrete-event simulator of the multi-core CPU + Tegra-style GPU
//! platform (the paper's testbed, rebuilt in software — see DESIGN.md §1).
//!
//! The simulator models, cycle-exactly at µs granularity:
//!
//! - partitioned preemptive fixed-priority CPU scheduling (§4);
//! - the GPU device driver's runlist with one TSG per process (§2),
//!   under four interchangeable policies:
//!   - [`Policy::TsgRr`] — the default driver's work-conserving
//!     time-sliced round-robin with slice L and context-switch cost θ;
//!   - [`Policy::Gcaps`] — Alg. 1: priority-driven preemptive context
//!     scheduling with runlist-update delay ε = α + θ, the rt-mutex
//!     serialized driver calls issued by `gcapsGpuSegBegin/End`;
//!   - [`Policy::Mpcp`] — GPU as a priority-queued mutex with priority
//!     boosting (zero protocol overhead, as the paper's analysis assumes);
//!   - [`Policy::FmlpPlus`] — same but FIFO-ordered;
//!   - [`Policy::Server`] — server-based GPU access (Kim et al.): GPU
//!     segments are enqueued to a per-engine priority-ordered server
//!     queue, the requester self-suspends, and the server executes the
//!     whole segment (G^m + G^e) non-preemptively on its behalf.
//! - busy-waiting and self-suspension during pure GPU execution
//!   (per-task [`crate::model::WaitMode`]).
//!
//! The engine is "recompute-on-event": at each event timestamp the CPU
//! and GPU allocations are recomputed from scratch, the next event
//! horizon is derived, and all running work advances by that quantum.

pub mod engine;
pub mod metrics;
pub mod perfetto;
pub mod reference;
pub mod trace;

pub use engine::{simulate, SimConfig, SimResult};
pub use reference::simulate_reference;
pub use metrics::TaskMetrics;
pub use trace::{Trace, TraceEvent};

/// GPU scheduling policy under simulation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Default Nvidia Tegra driver: time-sliced round-robin TSGs.
    TsgRr,
    /// The paper's contribution: Alg. 1 preemptive priority scheduling.
    Gcaps,
    /// Extension (paper §8 future work): Alg. 1 with dynamic priorities —
    /// GPU contexts are preempted by earliest absolute job deadline (EDF)
    /// instead of fixed task priority.
    GcapsEdf,
    /// Synchronization baseline: MPCP (priority-ordered GPU mutex).
    Mpcp,
    /// Synchronization baseline: FMLP+ (FIFO-ordered GPU mutex).
    FmlpPlus,
    /// Server-based baseline (Kim et al.): a dedicated server executes
    /// whole GPU segments on requesters' behalf, priority-ordered per
    /// engine, non-preemptive per request; requesters self-suspend.
    Server,
}

impl Policy {
    pub fn label(&self) -> &'static str {
        match self {
            Policy::TsgRr => "tsg_rr",
            Policy::Gcaps => "gcaps",
            Policy::GcapsEdf => "gcaps_edf",
            Policy::Mpcp => "mpcp",
            Policy::FmlpPlus => "fmlp+",
            Policy::Server => "server",
        }
    }

    pub fn from_label(s: &str) -> Option<Policy> {
        match s {
            "tsg_rr" => Some(Policy::TsgRr),
            "gcaps" => Some(Policy::Gcaps),
            "gcaps_edf" => Some(Policy::GcapsEdf),
            "mpcp" => Some(Policy::Mpcp),
            "fmlp+" | "fmlp" => Some(Policy::FmlpPlus),
            "server" => Some(Policy::Server),
            _ => None,
        }
    }
}
