//! Per-task simulation metrics: response times (→ MORT, Fig. 10/11 and
//! Table 5), deadline misses, and the ε / context-switch overhead
//! samples behind Figs. 12–13.

use crate::model::Time;
use crate::util::stats::Summary;

/// Metrics collected for one task over a simulation run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TaskMetrics {
    /// Response time of every completed job (µs).
    pub response_times: Vec<Time>,
    /// Jobs that completed after their absolute deadline.
    pub deadline_misses: u64,
    /// Jobs completed.
    pub jobs: u64,
    /// Measured runlist-update delays (GCAPS driver calls: wait + α + θ),
    /// two per GPU segment (begin/end). Empty under other policies.
    pub runlist_updates: Vec<Time>,
    /// Jobs aborted before completion (`AbortJob`/`DropTask` miss
    /// actions, GPU-hang watchdog aborts, mode-change disables). Not
    /// counted in `jobs` or `deadline_misses`.
    pub aborted: u64,
    /// Jobs that received the `Boost` miss action.
    pub boosts: u64,
    /// Injected GPU hangs detected (and aborted) for this task.
    pub hangs: u64,
}

impl TaskMetrics {
    /// Maximum observed response time (the paper's MORT metric).
    pub fn mort(&self) -> Option<Time> {
        self.response_times.iter().copied().max()
    }

    /// Per-job tardiness against a relative deadline: `max(0, R − D)`
    /// for every completed job. Saturating — a near-`u64::MAX` response
    /// or deadline must clamp to 0/finite instead of wrapping.
    pub fn tardiness(&self, deadline: Time) -> Vec<Time> {
        self.response_times.iter().map(|&r| r.saturating_sub(deadline)).collect()
    }

    pub fn summary_ms(&self) -> Option<Summary> {
        let xs: Vec<f64> =
            self.response_times.iter().map(|&t| t as f64 / 1000.0).collect();
        Summary::of(&xs)
    }
}

/// Whole-run aggregates.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct RunMetrics {
    /// GPU context switches performed (entries × θ charged).
    pub gpu_context_switches: u64,
    /// Total GPU busy time (µs, excluding θ).
    pub gpu_busy: Time,
    /// Total θ overhead time on the GPU (µs).
    pub gpu_switch_time: Time,
    /// Simulated horizon (µs).
    pub horizon: Time,
    /// Load-adaptive RR↔EDF policy switches performed.
    pub policy_switches: u64,
    /// Timestamp of the last deadline miss or job abort (µs; 0 when
    /// none) — the recovery-time metric's raw material.
    pub last_tardy: Time,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mort_is_max() {
        let m = TaskMetrics {
            response_times: vec![5, 9, 3],
            ..Default::default()
        };
        assert_eq!(m.mort(), Some(9));
    }

    #[test]
    fn empty_metrics() {
        let m = TaskMetrics::default();
        assert_eq!(m.mort(), None);
        assert!(m.summary_ms().is_none());
    }

    #[test]
    fn tardiness_saturates_instead_of_wrapping() {
        let m = TaskMetrics {
            response_times: vec![5, 1000, Time::MAX - 3],
            ..Default::default()
        };
        assert_eq!(m.tardiness(100), vec![0, 900, Time::MAX - 103]);
        // Near-MAX deadline (wrapped absolute deadlines in saturating
        // engines): every job clamps to 0, never to a huge wrapped value.
        assert_eq!(m.tardiness(Time::MAX), vec![0, 0, 0]);
    }

    #[test]
    fn summary_in_ms() {
        let m = TaskMetrics { response_times: vec![1000, 3000], ..Default::default() };
        let s = m.summary_ms().unwrap();
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }
}
