//! # GCAPS: GPU Context-Aware Preemptive Priority-based Scheduling
//!
//! A full reproduction of Wang et al., "GCAPS: GPU Context-Aware
//! Preemptive Priority-based Scheduling for Real-Time Tasks" (ECRTS
//! 2024), built as a three-layer Rust + JAX + Pallas stack:
//!
//! - **L3 (this crate)** — the paper's system: the GCAPS runlist
//!   scheduler (Alg. 1), the complete response-time analysis (§6), a
//!   discrete-event model of the Tegra GPU driver's time-sliced TSG
//!   scheduling (§2), lock-based baselines (MPCP, FMLP+), the taskset
//!   generator (Table 3), a live executive that schedules real GPU
//!   segments, and the experiment harnesses for every figure/table.
//! - **L2/L1 (build-time Python)** — the case-study GPU workloads as
//!   JAX functions calling Pallas kernels, AOT-lowered to HLO text in
//!   `artifacts/`, executed from Rust via the PJRT CPU client.
//!
//! See DESIGN.md for the module inventory and the per-experiment index,
//! and EXPERIMENTS.md for paper-vs-measured results.
//!
//! ## Running experiments as a library
//!
//! Every experiment harness is a first-class
//! [`experiments::registry::Experiment`] in a static registry; the
//! [`api`] facade lists and runs them in-process with pluggable result
//! sinks (CSV, JSONL, ASCII) and a structured report — no shelling out
//! to the `gcaps` binary:
//!
//! ```no_run
//! use gcaps::api::{self, Experiment};
//! use gcaps::experiments::ExpConfig;
//!
//! for exp in api::list() {
//!     println!("{:<10} {}", exp.name(), exp.about());
//! }
//! let cfg = ExpConfig { tasksets: 100, ..ExpConfig::default() };
//! let report = api::run("multigpu", &cfg, &api::SinkSpec::csv_jsonl("results")).unwrap();
//! println!("{} rows in {:?}; wrote {:?}", report.rows(), report.wall, report.outputs);
//! ```

// The whole stack is safe Rust — raw FFI stays inside the vendored
// `xla` crate behind the `pjrt` feature, never in this tree.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod api;
pub mod coordinator;
pub mod experiments;
pub mod lint;
pub mod model;
pub mod runtime;
pub mod serve;
pub mod sim;
pub mod sweep;
pub mod taskgen;
pub mod util;
