//! The GPU device thread: owns the PJRT [`Runtime`] and serialises
//! kernel launches — the single GPU of the platform.
//!
//! Tasks submit launch requests over a channel; the server picks the
//! next request according to the live scheduling mode:
//!
//! - `Gcaps` / lock-based modes: requests arrive pre-arbitrated (tasks
//!   only submit while admitted / holding the lock), so FIFO service is
//!   correct — there is at most one RT requester at a time.
//! - `TsgRr`: all tasks submit freely; the server round-robins across
//!   requesters at kernel granularity, the userspace analog of the
//!   driver's time-sliced TSG scheduling.
//! - `PriorityQueue`: all tasks submit freely; the server itself is the
//!   arbiter, serving the highest-priority pending request (RT before
//!   best-effort, FIFO among equals) — the live analog of the
//!   server-based approach of Kim et al. (arXiv 1709.06613) and of the
//!   DES `Policy::Server`.

use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::time::Duration;

use crate::runtime::Runtime;

/// One kernel-launch request.
pub struct LaunchReq {
    pub task: usize,
    /// GPU priority of the submitting task (higher = more urgent);
    /// consulted only under [`ServiceMode::PriorityQueue`].
    pub prio: u32,
    /// Real-time task? RT requests always precede best-effort ones
    /// under [`ServiceMode::PriorityQueue`].
    pub rt: bool,
    pub workload: String,
    /// Reply channel: launch wall time.
    pub reply: SyncSender<Duration>,
}

/// Service discipline of the GPU thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceMode {
    /// FIFO (arbitration happened upstream: GCAPS arbiter or a lock).
    Fifo,
    /// Round-robin across requesting tasks (default-driver analog).
    RoundRobin,
    /// Priority-ordered service at the server (Kim et al. analog):
    /// RT before best-effort, then GPU priority, then arrival order.
    PriorityQueue,
}

/// Run the GPU server until the request channel closes.
/// Returns the number of launches served.
pub fn serve(runtime: &Runtime, rx: Receiver<LaunchReq>, mode: ServiceMode) -> u64 {
    serve_with(rx, mode, |workload| {
        runtime
            .exec(workload)
            .unwrap_or_else(|e| panic!("launch {workload} failed: {e}"))
    })
}

/// [`serve`] with the kernel-execution step injected, so the service
/// disciplines are unit-testable without a PJRT runtime.
pub fn serve_with(
    rx: Receiver<LaunchReq>,
    mode: ServiceMode,
    mut exec: impl FnMut(&str) -> Duration,
) -> u64 {
    let mut served = 0u64;
    // Pending requests in arrival order (index order IS arrival order:
    // `Vec::remove` preserves the relative order of the rest).
    let mut queue: Vec<LaunchReq> = Vec::new();
    // RoundRobin: persistent cursor — the smallest task id eligible for
    // the next dispatch. Restarting the scan from task 0 each dispatch
    // would let low-index requesters starve high-index ones under
    // sustained load; instead the cursor advances past each served task
    // and wraps only when no pending task id is at or above it.
    let mut cursor = 0usize;
    loop {
        // Block for at least one request (unless draining the queue).
        if queue.is_empty() {
            match rx.recv() {
                Ok(r) => queue.push(r),
                Err(_) => return served,
            }
        }
        // Opportunistically drain whatever else is waiting.
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }
        let idx = match mode {
            ServiceMode::Fifo => 0,
            ServiceMode::RoundRobin => {
                // Smallest pending task id at or above the cursor; wrap
                // to the smallest overall when the tail is exhausted.
                let pick = |min: usize| {
                    queue
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| r.task >= min)
                        .min_by_key(|(_, r)| r.task)
                        .map(|(i, _)| i)
                };
                pick(cursor).or_else(|| pick(0)).unwrap()
            }
            ServiceMode::PriorityQueue => {
                // RT before best-effort, then priority, then FIFO
                // (earliest arrival = lowest index wins ties).
                queue
                    .iter()
                    .enumerate()
                    .max_by_key(|&(i, r)| (r.rt, r.prio, std::cmp::Reverse(i)))
                    .map(|(i, _)| i)
                    .unwrap()
            }
        };
        let req = queue.remove(idx);
        cursor = req.task + 1;
        let dt = exec(&req.workload);
        served += 1;
        // Receiver may have given up (executive shutting down).
        let _ = req.reply.send(dt);
    }
}

/// Convenience: a client-side handle for submitting launches.
#[derive(Clone)]
pub struct GpuClient {
    pub tx: Sender<LaunchReq>,
}

impl GpuClient {
    /// Submit one launch and wait for completion; returns the exec time.
    /// The blocking wait is the live self-suspension: the submitting
    /// thread sleeps until the server has run its kernel.
    pub fn launch(&self, task: usize, prio: u32, rt: bool, workload: &str) -> Option<Duration> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(LaunchReq { task, prio, rt, workload: workload.to_string(), reply })
            .ok()?;
        rx.recv().ok()
    }

    /// [`launch`] with a watchdog: wait at most `timeout` for the server
    /// to run the kernel. Returns `None` when the deadline passes (hung
    /// or wedged GPU server) instead of blocking the executive forever —
    /// the live analog of the DES θ hang-detection bound. The abandoned
    /// reply is dropped harmlessly: the server's eventual `send` fails
    /// and it moves on.
    ///
    /// [`launch`]: GpuClient::launch
    pub fn launch_bounded(
        &self,
        task: usize,
        prio: u32,
        rt: bool,
        workload: &str,
        timeout: Duration,
    ) -> Option<Duration> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(LaunchReq { task, prio, rt, workload: workload.to_string(), reply })
            .ok()?;
        rx.recv_timeout(timeout).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    /// Pre-load requests, run the server to drain, return service order
    /// as workload names.
    fn service_order(mode: ServiceMode, reqs: Vec<(usize, u32, bool, &str)>) -> Vec<String> {
        let (tx, rx) = channel();
        for (task, prio, rt, workload) in reqs {
            let (reply, _keep) = std::sync::mpsc::sync_channel(1);
            // Nobody awaits the reply; the server tolerates that.
            tx.send(LaunchReq { task, prio, rt, workload: workload.to_string(), reply })
                .unwrap();
        }
        drop(tx);
        let mut order = Vec::new();
        let served = serve_with(rx, mode, |w| {
            order.push(w.to_string());
            Duration::ZERO
        });
        assert_eq!(served as usize, order.len());
        order
    }

    #[test]
    fn fifo_serves_in_arrival_order() {
        let order = service_order(
            ServiceMode::Fifo,
            vec![(2, 0, true, "a"), (0, 9, true, "b"), (1, 5, false, "c")],
        );
        assert_eq!(order, ["a", "b", "c"]);
    }

    #[test]
    fn round_robin_rotates_a_persistent_cursor() {
        // Three requesters, three requests each, submitted bursty
        // (all of task 0 first). A scan restarting at index/task 0
        // every dispatch would serve 0,0,0 before touching 1 or 2;
        // the rotating cursor interleaves them.
        let order = service_order(
            ServiceMode::RoundRobin,
            vec![
                (0, 0, true, "t0"),
                (0, 0, true, "t0"),
                (0, 0, true, "t0"),
                (1, 0, true, "t1"),
                (1, 0, true, "t1"),
                (1, 0, true, "t1"),
                (2, 0, true, "t2"),
                (2, 0, true, "t2"),
                (2, 0, true, "t2"),
            ],
        );
        assert_eq!(
            order,
            ["t0", "t1", "t2", "t0", "t1", "t2", "t0", "t1", "t2"],
            "round-robin must rotate across requesters, not drain task 0 first"
        );
    }

    #[test]
    fn round_robin_wraps_past_missing_task_ids() {
        // Sparse ids {1, 4, 7}: the cursor must skip gaps and wrap.
        let order = service_order(
            ServiceMode::RoundRobin,
            vec![
                (4, 0, true, "t4"),
                (4, 0, true, "t4"),
                (1, 0, true, "t1"),
                (7, 0, true, "t7"),
            ],
        );
        assert_eq!(order, ["t1", "t4", "t7", "t4"]);
    }

    #[test]
    fn bounded_launch_times_out_on_a_hung_server() {
        // A server whose kernel hangs (sleeps far past the watchdog
        // bound) must not wedge the client: launch_bounded returns None
        // within the timeout, and the server survives the dropped reply.
        let (tx, rx) = channel();
        let client = GpuClient { tx };
        std::thread::scope(|s| {
            let server = s.spawn(move || {
                serve_with(rx, ServiceMode::Fifo, |w| {
                    if w == "hang" {
                        std::thread::sleep(Duration::from_millis(200));
                    }
                    Duration::from_micros(1)
                })
            });
            let t0 = std::time::Instant::now();
            let r = client.launch_bounded(0, 1, true, "hang", Duration::from_millis(10));
            assert!(r.is_none(), "watchdog must fire on a hung kernel");
            assert!(
                t0.elapsed() < Duration::from_millis(150),
                "watchdog returned only after the hang finished"
            );
            // The server keeps serving after the abandoned reply.
            let r = client.launch_bounded(0, 1, true, "ok", Duration::from_secs(5));
            assert_eq!(r, Some(Duration::from_micros(1)));
            drop(client);
            assert_eq!(server.join().unwrap(), 2);
        });
    }

    #[test]
    fn priority_queue_orders_rt_prio_then_fifo() {
        let order = service_order(
            ServiceMode::PriorityQueue,
            vec![
                (0, 1, true, "rt_lo"),
                (1, 9, false, "be_hi"),
                (2, 5, true, "rt_mid_first"),
                (3, 5, true, "rt_mid_second"),
            ],
        );
        // RT before best-effort (even at higher prio); among equal
        // (rt, prio) the earlier arrival wins.
        assert_eq!(order, ["rt_mid_first", "rt_mid_second", "rt_lo", "be_hi"]);
    }
}
