//! The GPU device thread: owns the PJRT [`Runtime`] and serialises
//! kernel launches — the single GPU of the platform.
//!
//! Tasks submit launch requests over a channel; the server picks the
//! next request according to the live scheduling mode:
//!
//! - `Gcaps` / lock-based modes: requests arrive pre-arbitrated (tasks
//!   only submit while admitted / holding the lock), so FIFO service is
//!   correct — there is at most one RT requester at a time.
//! - `TsgRr`: all tasks submit freely; the server round-robins across
//!   requesters at kernel granularity, the userspace analog of the
//!   driver's time-sliced TSG scheduling.

use std::sync::mpsc::{Receiver, Sender, SyncSender};
use std::time::Duration;

use crate::runtime::Runtime;

/// One kernel-launch request.
pub struct LaunchReq {
    pub task: usize,
    pub workload: String,
    /// Reply channel: launch wall time.
    pub reply: SyncSender<Duration>,
}

/// Service discipline of the GPU thread.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceMode {
    /// FIFO (arbitration happened upstream: GCAPS arbiter or a lock).
    Fifo,
    /// Round-robin across requesting tasks (default-driver analog).
    RoundRobin,
}

/// Run the GPU server until the request channel closes.
/// Returns the number of launches served.
pub fn serve(runtime: &Runtime, rx: Receiver<LaunchReq>, mode: ServiceMode) -> u64 {
    let mut served = 0u64;
    let mut queue: Vec<LaunchReq> = Vec::new();
    let mut last_task: Option<usize> = None;
    loop {
        // Block for at least one request (unless draining the queue).
        if queue.is_empty() {
            match rx.recv() {
                Ok(r) => queue.push(r),
                Err(_) => return served,
            }
        }
        // Opportunistically drain whatever else is waiting.
        while let Ok(r) = rx.try_recv() {
            queue.push(r);
        }
        let idx = match mode {
            ServiceMode::Fifo => 0,
            ServiceMode::RoundRobin => {
                // Next task id strictly after last_task, wrapping.
                let pick = |min_excl: Option<usize>| {
                    queue
                        .iter()
                        .enumerate()
                        .filter(|(_, r)| min_excl.map_or(true, |m| r.task > m))
                        .min_by_key(|(_, r)| r.task)
                        .map(|(i, _)| i)
                };
                pick(last_task).or_else(|| pick(None)).unwrap_or(0)
            }
        };
        let req = queue.remove(idx);
        last_task = Some(req.task);
        let dt = runtime
            .exec(&req.workload)
            .unwrap_or_else(|e| panic!("launch {} failed: {e}", req.workload));
        served += 1;
        // Receiver may have given up (executive shutting down).
        let _ = req.reply.send(dt);
    }
}

/// Convenience: a client-side handle for submitting launches.
#[derive(Clone)]
pub struct GpuClient {
    pub tx: Sender<LaunchReq>,
}

impl GpuClient {
    /// Submit one launch and wait for completion; returns the exec time.
    pub fn launch(&self, task: usize, workload: &str) -> Option<Duration> {
        let (reply, rx) = std::sync::mpsc::sync_channel(1);
        self.tx
            .send(LaunchReq { task, workload: workload.to_string(), reply })
            .ok()?;
        rx.recv().ok()
    }
}
