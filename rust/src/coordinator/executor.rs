//! The periodic executive: releases jobs, runs CPU segments (calibrated
//! spin work), and drives GPU segments through the arbiter + GPU server
//! — the live analog of the paper's case study (§7.2).
//!
//! Scheduling modes mirror the evaluation's approaches:
//! - `Gcaps`: segments bracketed by `seg_begin`/`seg_end` (Alg. 1);
//!   launches wait for admission, so preemption lands at kernel
//!   boundaries.
//! - `TsgRr`: no arbitration; the GPU server round-robins across
//!   requesters (default-driver behaviour).
//! - `FmlpPlus`: a FIFO ticket lock held for the whole segment.
//! - `Mpcp`: a priority-ordered lock held for the whole segment.
//! - `Server`: no locks and no arbiter — tasks submit launches freely
//!   and the GPU server itself picks the highest-priority pending
//!   request (`ServiceMode::PriorityQueue`), the live analog of the
//!   server-based approach of Kim et al. (arXiv 1709.06613). Each
//!   submitting thread blocks in `launch` until served, i.e. it
//!   self-suspends, matching the analysis's suspension-based model.
//!
//! The container exposes a single hardware core, so CPU-side
//! partitioning fidelity comes from the DES (`sim/`); the live
//! executive's purpose is to prove the full stack composes — real AOT
//! kernels, real arbitration, real preemption — and to measure ε
//! (Fig. 12) and response-time distributions on real compute.

use std::sync::mpsc::channel;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::arbiter::{Arbiter, TaskReg};
use crate::coordinator::gpu_server::{serve, GpuClient, ServiceMode};
use crate::runtime::Runtime;
use crate::util::sync::lock_or_recover;

/// One GPU segment of a live task: `launches` kernel launches of the
/// named artifact workload.
#[derive(Debug, Clone)]
pub struct LiveGpuSegment {
    pub workload: String,
    pub launches: usize,
}

/// A live periodic task (case-study Table 4 analog).
#[derive(Debug, Clone)]
pub struct LiveTask {
    pub name: String,
    pub period: Duration,
    /// Spin durations of the η_g + 1 CPU segments.
    pub cpu_segments: Vec<Duration>,
    pub gpu_segments: Vec<LiveGpuSegment>,
    pub gpu_prio: u32,
    pub rt: bool,
    /// Busy-wait (spin on admission/completion) vs self-suspend.
    pub busy: bool,
}

/// Live scheduling approach.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LiveMode {
    Gcaps,
    TsgRr,
    FmlpPlus,
    Mpcp,
    Server,
}

impl LiveMode {
    pub fn label(&self) -> &'static str {
        match self {
            LiveMode::Gcaps => "gcaps",
            LiveMode::TsgRr => "tsg_rr",
            LiveMode::FmlpPlus => "fmlp+",
            LiveMode::Mpcp => "mpcp",
            LiveMode::Server => "server",
        }
    }
}

/// Per-task outcome.
#[derive(Debug, Clone, Default)]
pub struct LiveMetrics {
    pub responses: Vec<Duration>,
    pub misses: u64,
    /// Launches abandoned by the watchdog (GPU server did not answer
    /// within the task's period) — unarbitrated modes only.
    pub hangs: u64,
}

impl LiveMetrics {
    pub fn mort(&self) -> Option<Duration> {
        self.responses.iter().copied().max()
    }
    pub fn response_ms(&self) -> Vec<f64> {
        self.responses.iter().map(|d| d.as_secs_f64() * 1e3).collect()
    }
}

/// Whole-run outcome.
#[derive(Debug, Clone, Default)]
pub struct LiveResult {
    pub per_task: Vec<LiveMetrics>,
    /// Measured runlist-update delays (GCAPS mode only) — Fig. 12.
    pub eps_samples: Vec<Duration>,
    pub launches: u64,
}

/// A simple FIFO/priority lock for the sync-based baselines.
struct SegmentLock {
    state: Mutex<LockState>,
    cv: Condvar,
}

#[derive(Default)]
struct LockState {
    held: bool,
    queue: Vec<(usize, u32, u64)>, // (task, prio, ticket)
    next_ticket: u64,
}

impl SegmentLock {
    fn new() -> SegmentLock {
        SegmentLock { state: Mutex::new(LockState::default()), cv: Condvar::new() }
    }

    fn acquire(&self, task: usize, prio: u32, fifo: bool) {
        let mut st = lock_or_recover(&self.state);
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.queue.push((task, prio, ticket));
        loop {
            if !st.held {
                let head = if fifo {
                    st.queue.iter().min_by_key(|&&(_, _, t)| t).copied()
                } else {
                    st.queue.iter().max_by_key(|&&(_, p, t)| (p, u64::MAX - t)).copied()
                };
                if let Some((h, _, ht)) = head {
                    if h == task && ht == ticket {
                        st.queue.retain(|&(_, _, t)| t != ticket);
                        st.held = true;
                        return;
                    }
                }
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn release(&self) {
        let mut st = lock_or_recover(&self.state);
        st.held = false;
        self.cv.notify_all();
    }
}

/// Calibrated spin: burn wall-clock time without syscalls.
pub fn spin_for(d: Duration) {
    let end = Instant::now() + d; // gcaps-lint: allow(wall-clock) -- spin burns real time
    while Instant::now() < end { // gcaps-lint: allow(wall-clock) -- spin burns real time
        std::hint::spin_loop();
    }
}

/// Run the executive for `duration`. Tasks release synchronously at t=0.
pub fn run(
    tasks: &[LiveTask],
    runtime: &Runtime,
    mode: LiveMode,
    duration: Duration,
) -> LiveResult {
    let regs: Vec<TaskReg> = tasks
        .iter()
        .map(|t| TaskReg { name: t.name.clone(), gpu_prio: t.gpu_prio, rt: t.rt })
        .collect();
    let arbiter = Arc::new(Arbiter::new(regs));
    let lock = Arc::new(SegmentLock::new());
    let (tx, rx) = channel();
    let client = GpuClient { tx };
    let service = match mode {
        LiveMode::TsgRr => ServiceMode::RoundRobin,
        LiveMode::Server => ServiceMode::PriorityQueue,
        _ => ServiceMode::Fifo,
    };

    let metrics: Vec<Mutex<LiveMetrics>> =
        tasks.iter().map(|_| Mutex::new(LiveMetrics::default())).collect();

    // The PJRT handles are !Send (Rc + raw pointers), so the GPU device
    // runs on THIS thread — it owns the Runtime — while the periodic
    // tasks run on spawned threads and submit launches over the channel.
    let launches = std::thread::scope(|scope| {
        // gcaps-lint: allow(wall-clock) -- one real-time release anchor shared by all tasks
        let t0 = Instant::now() + Duration::from_millis(50); // sync release
        for (id, task) in tasks.iter().enumerate() {
            let arbiter = Arc::clone(&arbiter);
            let lock = Arc::clone(&lock);
            let client = client.clone();
            let metrics = &metrics[id];
            scope.spawn(move || {
                let mut k = 0u64;
                loop {
                    let release = t0 + task.period.mul_f64(k as f64);
                    // gcaps-lint: allow(wall-clock) -- live release timing
                    let now = Instant::now();
                    if now + Duration::from_micros(50) >= t0 + duration {
                        break;
                    }
                    if release > now {
                        std::thread::sleep(release - now);
                    }
                    // --- one job ---
                    spin_for(task.cpu_segments[0]);
                    for (s, seg) in task.gpu_segments.iter().enumerate() {
                        match mode {
                            LiveMode::Gcaps => {
                                arbiter.seg_begin(id);
                                for _ in 0..seg.launches {
                                    arbiter.wait_admitted(id, task.busy);
                                    client.launch(id, task.gpu_prio, task.rt, &seg.workload);
                                }
                                arbiter.seg_end(id);
                            }
                            LiveMode::TsgRr | LiveMode::Server => {
                                // No upstream arbitration: under Server
                                // the priority-queue service picks the
                                // winner; each launch self-suspends,
                                // but never past the task's own period
                                // — a hung GPU server must not wedge
                                // the executive (DES θ-bound analog).
                                for _ in 0..seg.launches {
                                    let served = client.launch_bounded(
                                        id,
                                        task.gpu_prio,
                                        task.rt,
                                        &seg.workload,
                                        task.period,
                                    );
                                    if served.is_none() {
                                        lock_or_recover(metrics).hangs += 1;
                                        break; // abandon the rest of the segment
                                    }
                                }
                            }
                            LiveMode::FmlpPlus | LiveMode::Mpcp => {
                                lock.acquire(id, task.gpu_prio, mode == LiveMode::FmlpPlus);
                                for _ in 0..seg.launches {
                                    client.launch(id, task.gpu_prio, task.rt, &seg.workload);
                                }
                                lock.release();
                            }
                        }
                        spin_for(task.cpu_segments[s + 1]);
                    }
                    // gcaps-lint: allow(wall-clock) -- measures real response time
                    let resp = Instant::now().duration_since(release.min(Instant::now()));
                    {
                        let mut m = lock_or_recover(metrics);
                        if resp > task.period {
                            m.misses += 1;
                        }
                        m.responses.push(resp);
                    }
                    k += 1;
                }
            });
        }
        drop(client); // executive threads hold clones; close when they exit
        serve(runtime, rx, service)
    });

    LiveResult {
        per_task: metrics
            .into_iter()
            .map(|m| m.into_inner().unwrap_or_else(|e| e.into_inner()))
            .collect(),
        eps_samples: arbiter.take_eps_samples(),
        launches,
    }
}
