//! L3 coordinator: the paper's system contribution, live.
//!
//! - [`arbiter`] — Alg. 1 (the GCAPS driver patch) in userspace, with
//!   ε measurement (Fig. 12).
//! - [`gpu_server`] — the single-GPU device thread executing AOT
//!   kernels via PJRT; FIFO, round-robin, or priority-queue service
//!   (the latter is the Kim et al. server-based approach, live).
//! - [`executor`] — the periodic executive driving the case-study
//!   taskset (Table 4 analog) under gcaps / tsg_rr / fmlp+ / mpcp /
//!   server.
//! - [`workload`] — the Table 4 taskset builder, calibrated against the
//!   profiled artifact launch times.

pub mod arbiter;
pub mod executor;
pub mod gpu_server;
pub mod workload;

pub use arbiter::{Arbiter, TaskReg};
pub use executor::{run, LiveGpuSegment, LiveMetrics, LiveMode, LiveResult, LiveTask};
