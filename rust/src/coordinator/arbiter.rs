//! The live GCAPS arbiter: Alg. 1 of the paper in userspace.
//!
//! This is the analog of the ~300-line driver patch: a mutex-protected
//! `task_running` / `task_pending` pair updated by `seg_begin()` /
//! `seg_end()` (the `gcapsGpuSegBegin/End` IOCTLs of Listing 1), with a
//! condvar standing in for the runlist-swap hardware submission. Tasks
//! may only launch kernels while *admitted* (their entry is on the
//! runlist); a preempted task stops launching at its next kernel
//! boundary — the userspace analog of thread-block-granularity
//! preemption, folded into θ by Def. 1 exactly as the paper does.
//!
//! Every call measures its own duration (lock wait + state update +
//! wakeups): these are the ε samples behind Fig. 12.

use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::util::sync::lock_or_recover;

/// Static per-task registration.
#[derive(Debug, Clone)]
pub struct TaskReg {
    pub name: String,
    /// GPU segment priority (π^g); higher = more urgent.
    pub gpu_prio: u32,
    /// Real-time task (rt_priority set)? Best-effort tasks only hold the
    /// runlist when no RT task wants it.
    pub rt: bool,
}

#[derive(Debug, Default)]
struct DrvState {
    running: Vec<usize>,
    pending: Vec<usize>,
}

/// The arbiter (one per "GPU").
///
/// Locking goes through [`lock_or_recover`]: a panicking executive
/// thread must not wedge every other task's `seg_begin`/`seg_end`
/// (poison cascade). Recovery is sound here — `running`/`pending` are
/// plain id lists with no cross-field invariant a torn critical
/// section could break; at worst a crashed task's id lingers until its
/// next `seg_end`, which `retain`s it out.
pub struct Arbiter {
    tasks: Vec<TaskReg>,
    state: Mutex<DrvState>,
    cv: Condvar,
    eps: Mutex<Vec<Duration>>,
}

impl Arbiter {
    pub fn new(tasks: Vec<TaskReg>) -> Arbiter {
        Arbiter {
            tasks,
            state: Mutex::new(DrvState::default()),
            cv: Condvar::new(),
            eps: Mutex::new(Vec::new()),
        }
    }

    fn highest_running(&self, st: &DrvState) -> Option<usize> {
        st.running.iter().copied().max_by_key(|&k| (self.tasks[k].rt, self.tasks[k].gpu_prio))
    }

    /// Alg. 1, add path (`gcapsGpuSegBegin`). Returns once the runlist
    /// update is performed (the task may still be pending — launches must
    /// go through [`Arbiter::wait_admitted`]).
    pub fn seg_begin(&self, id: usize) {
        let t0 = Instant::now(); // gcaps-lint: allow(wall-clock) -- real arbiter overhead (fig12)
        {
            let mut st = lock_or_recover(&self.state);
            debug_assert!(!st.running.contains(&id) && !st.pending.contains(&id));
            if !self.tasks[id].rt {
                let rt_running = st.running.iter().any(|&k| self.tasks[k].rt);
                if rt_running {
                    st.pending.push(id);
                } else {
                    st.running.push(id);
                }
            } else {
                let tau_h = self.highest_running(&st);
                let preempt = match tau_h {
                    None => true,
                    Some(h) => {
                        !self.tasks[h].rt
                            || self.tasks[id].gpu_prio > self.tasks[h].gpu_prio
                    }
                };
                if preempt {
                    // §5.2: the new runlist holds only τ_i's TSGs.
                    let displaced: Vec<usize> = st.running.drain(..).collect();
                    st.pending.extend(displaced);
                    st.running.push(id);
                } else {
                    st.pending.push(id);
                }
            }
            self.cv.notify_all();
        }
        lock_or_recover(&self.eps).push(t0.elapsed());
    }

    /// Alg. 1, remove path (`gcapsGpuSegEnd`).
    pub fn seg_end(&self, id: usize) {
        let t0 = Instant::now(); // gcaps-lint: allow(wall-clock) -- real arbiter overhead (fig12)
        {
            let mut st = lock_or_recover(&self.state);
            st.running.retain(|&k| k != id);
            st.pending.retain(|&k| k != id);
            let tau_k = st
                .pending
                .iter()
                .copied()
                .filter(|&k| self.tasks[k].rt)
                .max_by_key(|&k| self.tasks[k].gpu_prio);
            if let Some(k) = tau_k {
                st.pending.retain(|&x| x != k);
                st.running.push(k);
            } else {
                // Only best-effort waiters: resume them all, time-shared.
                let all: Vec<usize> = st.pending.drain(..).collect();
                st.running.extend(all);
            }
            self.cv.notify_all();
        }
        lock_or_recover(&self.eps).push(t0.elapsed());
    }

    /// Is `id`'s TSG currently on the runlist?
    pub fn admitted(&self, id: usize) -> bool {
        lock_or_recover(&self.state).running.contains(&id)
    }

    /// Block (condvar; self-suspension mode) or spin (busy-wait mode)
    /// until `id` is admitted.
    pub fn wait_admitted(&self, id: usize, busy: bool) {
        if busy {
            while !self.admitted(id) {
                std::hint::spin_loop();
            }
        } else {
            let mut st = lock_or_recover(&self.state);
            while !st.running.contains(&id) {
                st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
            }
        }
    }

    /// Drain the measured runlist-update delays (Fig. 12 ε samples).
    pub fn take_eps_samples(&self) -> Vec<Duration> {
        std::mem::take(&mut *lock_or_recover(&self.eps))
    }

    /// Invariant check (tests): running ∩ pending = ∅, ≤ 1 RT running.
    pub fn check_invariants(&self) -> Result<(), String> {
        let st = lock_or_recover(&self.state);
        for r in &st.running {
            if st.pending.contains(r) {
                return Err(format!("task {r} in both running and pending"));
            }
        }
        let rt_running = st.running.iter().filter(|&&k| self.tasks[k].rt).count();
        if rt_running > 1 {
            return Err(format!("{rt_running} RT tasks on the runlist"));
        }
        // RT running excludes BE running (displacement on preemption).
        if rt_running == 1 && st.running.len() > 1 {
            return Err("BE task sharing the runlist with an RT task".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn regs(n: usize) -> Vec<TaskReg> {
        (0..n)
            .map(|i| TaskReg { name: format!("t{i}"), gpu_prio: i as u32 + 1, rt: true })
            .collect()
    }

    #[test]
    fn lone_task_admitted_immediately() {
        let a = Arbiter::new(regs(1));
        a.seg_begin(0);
        assert!(a.admitted(0));
        a.seg_end(0);
        assert!(!a.admitted(0));
        a.check_invariants().unwrap();
    }

    #[test]
    fn higher_priority_preempts() {
        let a = Arbiter::new(regs(2));
        a.seg_begin(0); // prio 1
        assert!(a.admitted(0));
        a.seg_begin(1); // prio 2 preempts
        assert!(a.admitted(1));
        assert!(!a.admitted(0));
        a.check_invariants().unwrap();
        a.seg_end(1); // 0 must be re-admitted
        assert!(a.admitted(0));
        a.seg_end(0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn lower_priority_queues() {
        let a = Arbiter::new(regs(2));
        a.seg_begin(1);
        a.seg_begin(0);
        assert!(a.admitted(1) && !a.admitted(0));
        a.seg_end(1);
        assert!(a.admitted(0));
        a.seg_end(0);
    }

    #[test]
    fn best_effort_yields_to_rt() {
        let mut r = regs(3);
        r[0].rt = false;
        r[1].rt = false;
        let a = Arbiter::new(r);
        a.seg_begin(0); // BE
        a.seg_begin(1); // BE: shares the runlist with 0
        assert!(a.admitted(0) && a.admitted(1));
        a.seg_begin(2); // RT: displaces both
        assert!(a.admitted(2) && !a.admitted(0) && !a.admitted(1));
        a.check_invariants().unwrap();
        a.seg_end(2); // both BE tasks resume time-shared
        assert!(a.admitted(0) && a.admitted(1));
        a.seg_end(0);
        a.seg_end(1);
    }

    #[test]
    fn be_waits_while_rt_running() {
        let mut r = regs(2);
        r[0].rt = false;
        let a = Arbiter::new(r);
        a.seg_begin(1); // RT
        a.seg_begin(0); // BE must pend
        assert!(!a.admitted(0));
        a.seg_end(1);
        assert!(a.admitted(0));
        a.seg_end(0);
    }

    #[test]
    fn eps_samples_collected() {
        let a = Arbiter::new(regs(1));
        a.seg_begin(0);
        a.seg_end(0);
        assert_eq!(a.take_eps_samples().len(), 2);
        assert!(a.take_eps_samples().is_empty());
    }

    #[test]
    fn concurrent_begin_end_storm_keeps_invariants() {
        let a = Arc::new(Arbiter::new(regs(8)));
        let mut handles = vec![];
        for id in 0..8 {
            let a = Arc::clone(&a);
            handles.push(std::thread::spawn(move || {
                for _ in 0..200 {
                    a.seg_begin(id);
                    a.wait_admitted(id, false);
                    a.seg_end(id);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        a.check_invariants().unwrap();
        // Everyone finished: both lists empty.
        assert!(!a.admitted(0));
    }

    #[test]
    fn wait_admitted_busy_spin() {
        let a = Arc::new(Arbiter::new(regs(2)));
        a.seg_begin(1);
        let a2 = Arc::clone(&a);
        let h = std::thread::spawn(move || {
            a2.seg_begin(0);
            a2.wait_admitted(0, true); // spins until 1 ends
            a2.seg_end(0);
        });
        std::thread::sleep(Duration::from_millis(20));
        a.seg_end(1);
        h.join().unwrap();
        a.check_invariants().unwrap();
    }
}
