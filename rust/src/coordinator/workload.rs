//! The live case-study taskset: the Table 4 analog, built from the AOT
//! workloads and scaled to the host.
//!
//! The paper's Table 4 was profiled on a Jetson Xavier NX; our "GPU" is
//! the PJRT CPU backend, so absolute per-launch times differ. We keep
//! the paper's *structure* — the same workloads, the same priority
//! order, utilization per task in the same 0.05–0.35 band — by
//! profiling each artifact once and choosing launch counts and periods
//! to hit the target G_i budget. `gcaps exp profile` prints the derived
//! table (the Table 4 analog recorded in EXPERIMENTS.md).

use std::time::Duration;

use crate::util::error::Result;

use crate::coordinator::executor::{LiveGpuSegment, LiveTask};
use crate::runtime::Runtime;

/// Target structure of one case-study task (mirrors a Table 4 row).
pub struct CaseRow {
    pub name: &'static str,
    pub workload: &'static str,
    /// Target pure-GPU time per job (G_i), in multiples of the profiled
    /// launch time — i.e. launch count per GPU segment.
    pub launches: usize,
    pub gpu_segments: usize,
    pub cpu_ms: f64,
    pub period_ms: f64,
    pub rt: bool,
    pub busy: bool,
}

/// The Table 4 task structure. Priorities descend with the row index
/// (task 1 = histogram has the highest), tasks 6–7 are best-effort —
/// exactly as in the paper. Periods are scaled up ~4× (the CPU PJRT
/// launches are slower than Jetson kernels) keeping utilizations in the
/// paper's 0.05–0.35 band.
pub fn case_rows() -> Vec<CaseRow> {
    vec![
        CaseRow { name: "histogram", workload: "histogram", launches: 2, gpu_segments: 1, cpu_ms: 1.0, period_ms: 400.0, rt: true, busy: false },
        CaseRow { name: "mmul_gpu_1", workload: "mmul_large", launches: 8, gpu_segments: 1, cpu_ms: 2.0, period_ms: 600.0, rt: true, busy: false },
        CaseRow { name: "mmul_cpu", workload: "", launches: 0, gpu_segments: 0, cpu_ms: 40.0, period_ms: 800.0, rt: true, busy: false },
        CaseRow { name: "projection", workload: "projection", launches: 6, gpu_segments: 2, cpu_ms: 6.0, period_ms: 1200.0, rt: true, busy: false },
        CaseRow { name: "dxtc", workload: "dxtc", launches: 4, gpu_segments: 1, cpu_ms: 2.0, period_ms: 1600.0, rt: true, busy: false },
        CaseRow { name: "mmul_gpu_2", workload: "mmul_large", launches: 30, gpu_segments: 1, cpu_ms: 4.0, period_ms: 800.0, rt: false, busy: false },
        CaseRow { name: "texture3d", workload: "texture3d", launches: 8, gpu_segments: 1, cpu_ms: 4.0, period_ms: 250.0, rt: false, busy: false },
    ]
}

/// Build the live taskset, profiling each workload to report its
/// per-launch cost. Returns (tasks, per-task profiled launch ms).
pub fn build_case_study(runtime: &Runtime, busy: bool) -> Result<(Vec<LiveTask>, Vec<f64>)> {
    let rows = case_rows();
    let n = rows.len();
    let mut tasks = Vec::with_capacity(n);
    let mut launch_ms = Vec::with_capacity(n);
    for (i, row) in rows.into_iter().enumerate() {
        let per_launch = if row.workload.is_empty() {
            Duration::ZERO
        } else {
            runtime.profile(row.workload, 5)?
        };
        launch_ms.push(per_launch.as_secs_f64() * 1e3);
        // Split the CPU budget evenly across the η_g + 1 segments.
        let nseg = row.gpu_segments + 1;
        let seg = Duration::from_secs_f64(row.cpu_ms / 1e3 / nseg as f64);
        let gpu_segments = (0..row.gpu_segments)
            .map(|_| LiveGpuSegment {
                workload: row.workload.to_string(),
                launches: row.launches,
            })
            .collect();
        tasks.push(LiveTask {
            name: row.name.to_string(),
            period: Duration::from_secs_f64(row.period_ms / 1e3),
            cpu_segments: vec![seg; nseg],
            gpu_segments,
            // Descending priority with row order; BE tasks get prio 0.
            gpu_prio: if row.rt { (n - i) as u32 } else { 0 },
            rt: row.rt,
            busy: busy && row.rt,
        });
    }
    Ok((tasks, launch_ms))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_mirror_table4_structure() {
        let rows = case_rows();
        assert_eq!(rows.len(), 7);
        // Task 3 is CPU-only; tasks 6 and 7 are best-effort.
        assert_eq!(rows[2].gpu_segments, 0);
        assert!(!rows[5].rt && !rows[6].rt);
        assert!(rows[..5].iter().all(|r| r.rt));
        // Workload names match Table 4's benchmarks.
        assert_eq!(rows[0].workload, "histogram");
        assert_eq!(rows[4].workload, "dxtc");
    }

    #[test]
    fn utilizations_stay_in_paper_band_structurally() {
        // CPU-side utilization alone must stay well under 1 in total so
        // the single-core container can keep up.
        let rows = case_rows();
        let cpu_util: f64 = rows.iter().map(|r| r.cpu_ms / r.period_ms).sum();
        assert!(cpu_util < 0.25, "cpu util {cpu_util}");
    }
}
