//! Fig. 8: schedulability of the eight approaches under six parameter
//! sweeps (paper §7.1.1). Each point = fraction of random tasksets
//! (Table 3 parameters, one knob swept) that pass the respective
//! response-time test. The GCAPS curves use the §7.1.1 procedure:
//! default RM priorities first, then the Audsley GPU-priority
//! assignment on failure.

use crate::analysis::{analyze, analyze_with_gpu_prio, Approach};
use crate::experiments::{results_dir, ExpConfig};
use crate::model::WaitMode;
use crate::taskgen::{generate, GenParams};
use crate::util::ascii::line_chart;
use crate::util::csv::CsvTable;
use crate::util::rng::Pcg32;

/// One Fig. 8 panel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Panel {
    /// (a) number of tasks per CPU ∈ {2..7}.
    TasksPerCpu,
    /// (b) utilization per CPU ∈ {0.3..0.8}.
    UtilPerCpu,
    /// (c) number of CPUs ∈ {2, 4, 6, 8}.
    NumCpus,
    /// (d) ratio of GPU-using tasks ∈ {20%..80%}.
    GpuRatio,
    /// (e) ratio of GPU exec to CPU exec (G/C) ∈ {0.1..2.5}.
    GcRatio,
    /// (f) ratio of best-effort tasks ∈ {0..60%}.
    BestEffortRatio,
}

impl Panel {
    pub const ALL: [Panel; 6] = [
        Panel::TasksPerCpu,
        Panel::UtilPerCpu,
        Panel::NumCpus,
        Panel::GpuRatio,
        Panel::GcRatio,
        Panel::BestEffortRatio,
    ];

    pub fn from_letter(s: &str) -> Option<Panel> {
        match s {
            "a" => Some(Panel::TasksPerCpu),
            "b" => Some(Panel::UtilPerCpu),
            "c" => Some(Panel::NumCpus),
            "d" => Some(Panel::GpuRatio),
            "e" => Some(Panel::GcRatio),
            "f" => Some(Panel::BestEffortRatio),
            _ => None,
        }
    }

    pub fn letter(&self) -> &'static str {
        match self {
            Panel::TasksPerCpu => "a",
            Panel::UtilPerCpu => "b",
            Panel::NumCpus => "c",
            Panel::GpuRatio => "d",
            Panel::GcRatio => "e",
            Panel::BestEffortRatio => "f",
        }
    }

    pub fn xlabel(&self) -> &'static str {
        match self {
            Panel::TasksPerCpu => "tasks per CPU",
            Panel::UtilPerCpu => "utilization per CPU",
            Panel::NumCpus => "number of CPUs",
            Panel::GpuRatio => "ratio of GPU-using tasks",
            Panel::GcRatio => "G/C ratio",
            Panel::BestEffortRatio => "ratio of best-effort tasks",
        }
    }

    /// Sweep points: (tick label, GenParams patch).
    pub fn points(&self) -> Vec<(String, Box<dyn Fn(&mut GenParams)>)> {
        match self {
            Panel::TasksPerCpu => (2..=7usize)
                .map(|n| {
                    (
                        n.to_string(),
                        Box::new(move |p: &mut GenParams| p.tasks_per_cpu = (n, n)) as _,
                    )
                })
                .collect(),
            Panel::UtilPerCpu => [0.3, 0.4, 0.5, 0.6, 0.7, 0.8]
                .iter()
                .map(|&u| {
                    (
                        format!("{u:.1}"),
                        Box::new(move |p: &mut GenParams| {
                            p.util_per_cpu = (u - 0.05, u + 0.05)
                        }) as _,
                    )
                })
                .collect(),
            Panel::NumCpus => [2usize, 4, 6, 8]
                .iter()
                .map(|&n| {
                    (
                        n.to_string(),
                        Box::new(move |p: &mut GenParams| {
                            p.num_cpus = n;
                            p.platform.num_cpus = n;
                        }) as _,
                    )
                })
                .collect(),
            Panel::GpuRatio => [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]
                .iter()
                .map(|&r| {
                    (
                        format!("{:.0}%", r * 100.0),
                        Box::new(move |p: &mut GenParams| p.gpu_task_ratio = (r, r)) as _,
                    )
                })
                .collect(),
            Panel::GcRatio => [0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 2.5]
                .iter()
                .map(|&g| {
                    (
                        format!("{g:.2}"),
                        Box::new(move |p: &mut GenParams| p.g_to_c_ratio = (g, g)) as _,
                    )
                })
                .collect(),
            Panel::BestEffortRatio => [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6]
                .iter()
                .map(|&r| {
                    (
                        format!("{:.0}%", r * 100.0),
                        Box::new(move |p: &mut GenParams| p.best_effort_ratio = r) as _,
                    )
                })
                .collect(),
        }
    }
}

/// Schedulability ratio for one approach at one parameter point.
pub fn schedulability(
    approach: Approach,
    patch: &dyn Fn(&mut GenParams),
    cfg: &ExpConfig,
) -> f64 {
    let mut rng = Pcg32::seeded(cfg.seed);
    let mut ok = 0usize;
    for _ in 0..cfg.tasksets {
        let mut p = GenParams {
            mode: if approach.is_busy() { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
            ..Default::default()
        };
        patch(&mut p);
        let ts = generate(&mut rng, &p);
        let schedulable = match approach {
            Approach::GcapsBusy => analyze_with_gpu_prio(&ts, true).0.schedulable,
            Approach::GcapsSuspend => analyze_with_gpu_prio(&ts, false).0.schedulable,
            a => analyze(&ts, a).schedulable,
        };
        ok += schedulable as usize;
    }
    ok as f64 / cfg.tasksets as f64
}

/// Run one panel; returns (xticks, per-approach series).
pub fn run_panel(panel: Panel, cfg: &ExpConfig) -> (Vec<String>, Vec<(String, Vec<f64>)>) {
    let points = panel.points();
    let xticks: Vec<String> = points.iter().map(|(l, _)| l.clone()).collect();
    let mut series = Vec::new();
    for approach in Approach::ALL {
        let ys: Vec<f64> = points
            .iter()
            .map(|(_, patch)| schedulability(approach, patch.as_ref(), cfg))
            .collect();
        series.push((approach.label().to_string(), ys));
    }
    (xticks, series)
}

/// Run + persist one panel.
pub fn run_and_report(panel: Panel, cfg: &ExpConfig) -> String {
    let (xticks, series) = run_panel(panel, cfg);
    let mut csv = CsvTable::new(vec!["approach".to_string(), panel.xlabel().to_string(), "schedulable_ratio".to_string()]);
    for (label, ys) in &series {
        for (x, y) in xticks.iter().zip(ys) {
            csv.row(vec![label.clone(), x.clone(), format!("{y:.4}")]);
        }
    }
    let path = results_dir().join(format!("fig8{}.csv", panel.letter()));
    csv.write(&path).expect("write csv");
    let chart = line_chart(
        &format!("Fig. 8{}: schedulability vs {}", panel.letter(), panel.xlabel()),
        panel.xlabel(),
        &xticks,
        &series,
        1.0,
        16,
    );
    format!("{chart}\nwrote {}\n", path.display())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { tasksets: 15, seed: 7 }
    }

    #[test]
    fn panel_letters_roundtrip() {
        for p in Panel::ALL {
            assert_eq!(Panel::from_letter(p.letter()), Some(p));
        }
        assert_eq!(Panel::from_letter("z"), None);
    }

    #[test]
    fn schedulability_in_unit_interval() {
        for a in [Approach::GcapsSuspend, Approach::FmlpSuspend] {
            let r = schedulability(a, &|_| {}, &tiny());
            assert!((0.0..=1.0).contains(&r));
        }
    }

    #[test]
    fn gcaps_dominates_mpcp_at_default_point() {
        // The paper's headline: GCAPS ≥ sync-based at Table 3 defaults.
        let cfg = ExpConfig { tasksets: 40, seed: 11 };
        let g = schedulability(Approach::GcapsSuspend, &|_| {}, &cfg);
        let m = schedulability(Approach::MpcpSuspend, &|_| {}, &cfg);
        assert!(g >= m, "gcaps {g} < mpcp {m}");
    }

    #[test]
    fn utilization_sweep_is_monotone_decreasing_for_gcaps() {
        let cfg = ExpConfig { tasksets: 30, seed: 3 };
        let lo = schedulability(
            Approach::GcapsSuspend,
            &|p| p.util_per_cpu = (0.25, 0.35),
            &cfg,
        );
        let hi = schedulability(
            Approach::GcapsSuspend,
            &|p| p.util_per_cpu = (0.65, 0.75),
            &cfg,
        );
        assert!(lo >= hi, "lo {lo} < hi {hi}");
    }

    #[test]
    fn fig8f_best_effort_hurts_sync_more_than_gcaps() {
        // The Fig. 8f claim: with 40% best-effort tasks, GCAPS retains a
        // large margin over the lock-based baselines.
        let cfg = ExpConfig { tasksets: 40, seed: 5 };
        let patch = |p: &mut GenParams| {
            p.best_effort_ratio = 0.4;
            p.util_per_cpu = (0.3, 0.4);
        };
        let g = schedulability(Approach::GcapsSuspend, &patch, &cfg);
        let f = schedulability(Approach::FmlpSuspend, &patch, &cfg);
        assert!(g >= f, "gcaps {g} < fmlp {f} under best-effort load");
    }
}
