//! Fig. 8: schedulability of the nine approaches under six parameter
//! sweeps (paper §7.1.1). Each point = fraction of random tasksets
//! (Table 3 parameters, one knob swept) that pass the respective
//! response-time test. The GCAPS curves use the §7.1.1 procedure:
//! default RM priorities first, then the Audsley GPU-priority
//! assignment on failure.

use crate::analysis::{approach_schedulable, Approach};
use crate::err;
use crate::experiments::registry::{Experiment, FlagSpec};
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::WaitMode;
use crate::sweep::{self, memo};
use crate::taskgen::GenParams;
use crate::util::ascii::line_chart;
use crate::util::csv::CsvTable;
use crate::util::error::Result;

/// One Fig. 8 panel.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Panel {
    /// (a) number of tasks per CPU ∈ {2..7}.
    TasksPerCpu,
    /// (b) utilization per CPU ∈ {0.3..0.8}.
    UtilPerCpu,
    /// (c) number of CPUs ∈ {2, 4, 6, 8}.
    NumCpus,
    /// (d) ratio of GPU-using tasks ∈ {20%..80%}.
    GpuRatio,
    /// (e) ratio of GPU exec to CPU exec (G/C) ∈ {0.1..2.5}.
    GcRatio,
    /// (f) ratio of best-effort tasks ∈ {0..60%}.
    BestEffortRatio,
}

impl Panel {
    pub const ALL: [Panel; 6] = [
        Panel::TasksPerCpu,
        Panel::UtilPerCpu,
        Panel::NumCpus,
        Panel::GpuRatio,
        Panel::GcRatio,
        Panel::BestEffortRatio,
    ];

    pub fn from_letter(s: &str) -> Option<Panel> {
        match s {
            "a" => Some(Panel::TasksPerCpu),
            "b" => Some(Panel::UtilPerCpu),
            "c" => Some(Panel::NumCpus),
            "d" => Some(Panel::GpuRatio),
            "e" => Some(Panel::GcRatio),
            "f" => Some(Panel::BestEffortRatio),
            _ => None,
        }
    }

    pub fn letter(&self) -> &'static str {
        match self {
            Panel::TasksPerCpu => "a",
            Panel::UtilPerCpu => "b",
            Panel::NumCpus => "c",
            Panel::GpuRatio => "d",
            Panel::GcRatio => "e",
            Panel::BestEffortRatio => "f",
        }
    }

    pub fn xlabel(&self) -> &'static str {
        match self {
            Panel::TasksPerCpu => "tasks per CPU",
            Panel::UtilPerCpu => "utilization per CPU",
            Panel::NumCpus => "number of CPUs",
            Panel::GpuRatio => "ratio of GPU-using tasks",
            Panel::GcRatio => "G/C ratio",
            Panel::BestEffortRatio => "ratio of best-effort tasks",
        }
    }

    /// Sweep points: (tick label, GenParams patch).
    pub fn points(&self) -> Vec<(String, Box<dyn Fn(&mut GenParams)>)> {
        match self {
            Panel::TasksPerCpu => (2..=7usize)
                .map(|n| {
                    (
                        n.to_string(),
                        Box::new(move |p: &mut GenParams| p.tasks_per_cpu = (n, n)) as _,
                    )
                })
                .collect(),
            Panel::UtilPerCpu => [0.3, 0.4, 0.5, 0.6, 0.7, 0.8]
                .iter()
                .map(|&u| {
                    (
                        format!("{u:.1}"),
                        Box::new(move |p: &mut GenParams| {
                            p.util_per_cpu = (u - 0.05, u + 0.05)
                        }) as _,
                    )
                })
                .collect(),
            Panel::NumCpus => [2usize, 4, 6, 8]
                .iter()
                .map(|&n| {
                    (
                        n.to_string(),
                        Box::new(move |p: &mut GenParams| {
                            p.num_cpus = n;
                            p.platform.num_cpus = n;
                        }) as _,
                    )
                })
                .collect(),
            Panel::GpuRatio => [0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8]
                .iter()
                .map(|&r| {
                    (
                        format!("{:.0}%", r * 100.0),
                        Box::new(move |p: &mut GenParams| p.gpu_task_ratio = (r, r)) as _,
                    )
                })
                .collect(),
            Panel::GcRatio => [0.1, 0.25, 0.5, 1.0, 1.5, 2.0, 2.5]
                .iter()
                .map(|&g| {
                    (
                        format!("{g:.2}"),
                        Box::new(move |p: &mut GenParams| p.g_to_c_ratio = (g, g)) as _,
                    )
                })
                .collect(),
            Panel::BestEffortRatio => [0.0, 0.1, 0.2, 0.3, 0.4, 0.5, 0.6]
                .iter()
                .map(|&r| {
                    (
                        format!("{:.0}%", r * 100.0),
                        Box::new(move |p: &mut GenParams| p.best_effort_ratio = r) as _,
                    )
                })
                .collect(),
        }
    }
}

/// Schedulability ratio for one approach at one parameter point. Cells
/// (one per taskset) are sharded across the sweep worker pool; the
/// memoized generator means the per-index tasksets are shared with every
/// other approach evaluated at this point.
pub fn schedulability(
    approach: Approach,
    patch: &dyn Fn(&mut GenParams),
    cfg: &ExpConfig,
) -> f64 {
    let mut p = GenParams {
        mode: if approach.is_busy() { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
        ..Default::default()
    };
    patch(&mut p);
    let seed = cfg.seed;
    let oks = sweep::run_indexed(&cfg.sweep(), cfg.tasksets, |i| {
        let ts = memo::taskset(seed, &p, i);
        approach_schedulable(&ts, approach)
    });
    oks.iter().filter(|&&ok| ok).count() as f64 / cfg.tasksets.max(1) as f64
}

/// Run one panel; returns (xticks, per-approach series).
///
/// The grid is (sweep point × taskset index); each cell generates its
/// taskset once (suspend + busy variants of the same draws) and
/// evaluates every approach on it, so a panel costs one generation —
/// not one per approach — per (point, index) regardless of worker count.
pub fn run_panel(panel: Panel, cfg: &ExpConfig) -> (Vec<String>, Vec<(String, Vec<f64>)>) {
    let points = panel.points();
    let xticks: Vec<String> = points.iter().map(|(l, _)| l.clone()).collect();
    let params: Vec<GenParams> = points
        .iter()
        .map(|(_, patch)| {
            let mut p = GenParams::default();
            patch(&mut p);
            p
        })
        .collect();

    // Canonical cell order: point-major, taskset-index-minor.
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<[bool; Approach::ALL.len()]> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            crate::experiments::approaches(seed, &params[pi], ti)
        });

    let mut series: Vec<(String, Vec<f64>)> = Approach::ALL
        .iter()
        .map(|a| (a.label().to_string(), vec![0.0; points.len()]))
        .collect();
    for (cell_idx, oks) in per_cell.iter().enumerate() {
        let pi = cell_idx / cfg.tasksets.max(1);
        for (k, &ok) in oks.iter().enumerate() {
            series[k].1[pi] += ok as usize as f64;
        }
    }
    for (_, ys) in &mut series {
        for y in ys.iter_mut() {
            *y /= cfg.tasksets.max(1) as f64;
        }
    }
    (xticks, series)
}

/// Format a panel's merged results as its CSV table (pure — the
/// determinism suite compares these bytes across worker counts).
pub fn panel_csv(
    panel: Panel,
    xticks: &[String],
    series: &[(String, Vec<f64>)],
) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "approach".to_string(),
        panel.xlabel().to_string(),
        "schedulable_ratio".to_string(),
    ]);
    for (label, ys) in series {
        for (x, y) in xticks.iter().zip(ys) {
            csv.row(vec![label.clone(), x.clone(), format!("{y:.4}")]);
        }
    }
    csv
}

/// Render one panel's ASCII chart.
pub fn panel_chart(panel: Panel, xticks: &[String], series: &[(String, Vec<f64>)]) -> String {
    line_chart(
        &format!("Fig. 8{}: schedulability vs {}", panel.letter(), panel.xlabel()),
        panel.xlabel(),
        xticks,
        series,
        1.0,
        16,
    )
}

fn panel_value_ok(v: &str) -> bool {
    Panel::from_letter(v).is_some()
}

/// Registry face: `gcaps exp fig8 [--panel a..f]` — all six panels
/// when no panel is selected, one table per panel (`fig8a`..`fig8f`).
pub struct Fig8Exp;

impl Experiment for Fig8Exp {
    fn name(&self) -> &'static str {
        "fig8"
    }

    fn about(&self) -> &'static str {
        "Schedulability of 9 approaches over six parameter sweeps"
    }

    fn flags(&self) -> &'static [FlagSpec] {
        static FLAGS: [FlagSpec; 1] =
            [FlagSpec { name: "panel", values: "a..f", check: panel_value_ok }];
        &FLAGS
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let panels: Vec<Panel> = match cfg.opts.get("panel") {
            Some(l) => vec![Panel::from_letter(l)
                .ok_or_else(|| err!("invalid value {l:?} for --panel (expected a..f)"))?],
            None => Panel::ALL.to_vec(),
        };
        for panel in panels {
            let (xticks, series) = run_panel(panel, cfg);
            sink.table(&format!("fig8{}", panel.letter()), &panel_csv(panel, &xticks, &series));
            sink.text(&format!("{}\n", panel_chart(panel, &xticks, &series)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { tasksets: 15, seed: 7, ..ExpConfig::default() }
    }

    #[test]
    fn panel_letters_roundtrip() {
        for p in Panel::ALL {
            assert_eq!(Panel::from_letter(p.letter()), Some(p));
        }
        assert_eq!(Panel::from_letter("z"), None);
    }

    #[test]
    fn schedulability_in_unit_interval() {
        for a in [Approach::GcapsSuspend, Approach::FmlpSuspend] {
            let r = schedulability(a, &|_| {}, &tiny());
            assert!((0.0..=1.0).contains(&r));
        }
    }

    #[test]
    fn gcaps_dominates_mpcp_at_default_point() {
        // The paper's headline: GCAPS ≥ sync-based at Table 3 defaults.
        let cfg = ExpConfig { tasksets: 40, seed: 11, ..ExpConfig::default() };
        let g = schedulability(Approach::GcapsSuspend, &|_| {}, &cfg);
        let m = schedulability(Approach::MpcpSuspend, &|_| {}, &cfg);
        assert!(g >= m, "gcaps {g} < mpcp {m}");
    }

    #[test]
    fn utilization_sweep_is_monotone_decreasing_for_gcaps() {
        let cfg = ExpConfig { tasksets: 30, seed: 3, ..ExpConfig::default() };
        let lo = schedulability(
            Approach::GcapsSuspend,
            &|p| p.util_per_cpu = (0.25, 0.35),
            &cfg,
        );
        let hi = schedulability(
            Approach::GcapsSuspend,
            &|p| p.util_per_cpu = (0.65, 0.75),
            &cfg,
        );
        assert!(lo >= hi, "lo {lo} < hi {hi}");
    }

    #[test]
    fn fig8f_best_effort_hurts_sync_more_than_gcaps() {
        // The Fig. 8f claim: with 40% best-effort tasks, GCAPS retains a
        // large margin over the lock-based baselines.
        let cfg = ExpConfig { tasksets: 40, seed: 5, ..ExpConfig::default() };
        let patch = |p: &mut GenParams| {
            p.best_effort_ratio = 0.4;
            p.util_per_cpu = (0.3, 0.4);
        };
        let g = schedulability(Approach::GcapsSuspend, &patch, &cfg);
        let f = schedulability(Approach::FmlpSuspend, &patch, &cfg);
        assert!(g >= f, "gcaps {g} < fmlp {f} under best-effort load");
    }

    #[test]
    fn run_panel_agrees_with_standalone_schedulability() {
        // The batched (all-approaches-per-cell) path and the standalone
        // per-approach path must land on identical memoized tasksets and
        // therefore identical ratios.
        let cfg = ExpConfig { tasksets: 10, seed: 21, ..ExpConfig::default() };
        let panel = Panel::GpuRatio;
        let (_, series) = run_panel(panel, &cfg);
        let points = panel.points();
        for (k, a) in Approach::ALL.iter().enumerate() {
            for (pi, (_, patch)) in points.iter().enumerate() {
                let lone = schedulability(*a, patch.as_ref(), &cfg);
                assert_eq!(
                    series[k].1[pi],
                    lone,
                    "{} point {pi} diverged",
                    a.label()
                );
            }
        }
    }
}
