//! Fig. 9: schedulability gain from the §5.3 separate GPU-priority
//! assignment — gcaps_busy / gcaps_suspend with and without Audsley,
//! swept over utilization per CPU (the knob that stresses the GPU
//! priority choice most; the paper reports busy-waiting benefits more).

use crate::analysis::{analyze_with_gpu_prio, gcaps};
use crate::experiments::registry::Experiment;
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::WaitMode;
use crate::sweep::{self, memo};
use crate::taskgen::GenParams;
use crate::util::ascii::line_chart;
use crate::util::csv::CsvTable;
use crate::util::error::Result;

/// (ratio without assignment, ratio with assignment) at one point.
/// Sharded across the sweep pool, one cell per taskset; both variants
/// run on the same memoized taskset, so "with assignment" can never
/// trail "without" on any sample.
pub fn point(busy: bool, util: f64, cfg: &ExpConfig) -> (f64, f64) {
    let p = GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        mode: if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend },
        ..Default::default()
    };
    let seed = cfg.seed;
    let cells = sweep::run_indexed(&cfg.sweep(), cfg.tasksets, |i| {
        let ts = memo::taskset(seed, &p, i);
        let base = gcaps::analyze(&ts, busy, &gcaps::Options::default());
        // Full procedure (§7.1.1): retry with Audsley on failure.
        let with =
            base.schedulable || analyze_with_gpu_prio(&ts, busy).0.schedulable;
        (base.schedulable, with)
    });
    let base_ok = cells.iter().filter(|&&(b, _)| b).count();
    let auds_ok = cells.iter().filter(|&&(_, w)| w).count();
    let n = cfg.tasksets.max(1) as f64;
    (base_ok as f64 / n, auds_ok as f64 / n)
}

/// Run the utilization sweep; returns (xticks, the four series).
pub fn sweep(cfg: &ExpConfig) -> (Vec<String>, Vec<(String, Vec<f64>)>) {
    let utils = [0.3, 0.4, 0.5, 0.6, 0.7];
    let xticks: Vec<String> = utils.iter().map(|u| format!("{u:.1}")).collect();
    let mut series: Vec<(String, Vec<f64>)> = vec![
        ("gcaps_busy".into(), vec![]),
        ("gcaps_busy+gpu_prio".into(), vec![]),
        ("gcaps_suspend".into(), vec![]),
        ("gcaps_suspend+gpu_prio".into(), vec![]),
    ];
    for &u in &utils {
        let (b0, b1) = point(true, u, cfg);
        let (s0, s1) = point(false, u, cfg);
        series[0].1.push(b0);
        series[1].1.push(b1);
        series[2].1.push(s0);
        series[3].1.push(s1);
    }
    (xticks, series)
}

/// Format the merged results as the `fig9` CSV table (pure — byte
/// schema pinned by the registry goldens).
pub fn fig9_csv(xticks: &[String], series: &[(String, Vec<f64>)]) -> CsvTable {
    let mut csv = CsvTable::new(vec!["series", "util_per_cpu", "schedulable_ratio"]);
    for (label, ys) in series {
        for (x, y) in xticks.iter().zip(ys) {
            csv.row(vec![label.clone(), x.clone(), format!("{y:.4}")]);
        }
    }
    csv
}

/// Registry face: `gcaps exp fig9`.
pub struct Fig9Exp;

impl Experiment for Fig9Exp {
    fn name(&self) -> &'static str {
        "fig9"
    }

    fn about(&self) -> &'static str {
        "Schedulability gain from Audsley GPU-priority assignment"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (xticks, series) = sweep(cfg);
        sink.table("fig9", &fig9_csv(&xticks, &series));
        let chart = line_chart(
            "Fig. 9: schedulability gain from GPU priority assignment",
            "utilization per CPU",
            &xticks,
            &series,
            1.0,
            16,
        );
        sink.text(&format!("{chart}\n"));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assignment_never_hurts() {
        let cfg = ExpConfig { tasksets: 25, seed: 13, ..ExpConfig::default() };
        for busy in [false, true] {
            let (base, with) = point(busy, 0.5, &cfg);
            assert!(with >= base, "busy={busy}: {with} < {base}");
        }
    }
}
