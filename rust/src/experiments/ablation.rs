//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Lemma 12 as printed vs the sound amendment** — how much
//!    schedulability the paper-exact (optimistic, unsound on the device
//!    model) busy-waiting analysis would claim vs our amended bound.
//! 2. **Fixed-priority GCAPS vs the EDF extension** (paper §8 future
//!    work, "dynamic priority"): simulated deadline-miss ratios under
//!    increasing load. EDF's optimality on a single resource shows up
//!    as fewer misses near/over saturation.
//! 3. **Runlist-update cost sensitivity** — gcaps schedulability as ε
//!    grows (the design's key overhead knob, cf. Fig. 8e's discussion).

use crate::analysis::gcaps::{analyze as gcaps_rta, Options};
use crate::experiments::registry::Experiment;
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::{ms, Platform, WaitMode};
use crate::sim::{simulate, Policy, SimConfig};
use crate::sweep::{self, memo};
use crate::taskgen::GenParams;
use crate::util::csv::CsvTable;
use crate::util::error::Result;

/// (sound ratio, paper-exact ratio) of gcaps_busy schedulability. Both
/// variants run on the same memoized taskset per cell, so the exact
/// (optimistic) bound can never score below the sound one.
pub fn lemma12_ablation(cfg: &ExpConfig, util: f64) -> (f64, f64) {
    let p = GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        mode: WaitMode::BusyWait,
        ..Default::default()
    };
    let seed = cfg.seed;
    let cells = sweep::run_indexed(&cfg.sweep(), cfg.tasksets, |i| {
        let ts = memo::taskset(seed, &p, i);
        let sound = gcaps_rta(&ts, true, &Options::default()).schedulable;
        let exact = gcaps_rta(
            &ts,
            true,
            &Options { paper_exact_lemma12: true, ..Default::default() },
        )
        .schedulable;
        (sound, exact)
    });
    let n = cfg.tasksets.max(1) as f64;
    (
        cells.iter().filter(|&&(s, _)| s).count() as f64 / n,
        cells.iter().filter(|&&(_, e)| e).count() as f64 / n,
    )
}

/// Simulated RT deadline-miss ratio under a policy at one load level.
/// One DES run per cell — the heaviest sweep in the ablation suite and
/// the biggest winner from sharding.
pub fn miss_ratio(policy: Policy, util: f64, cfg: &ExpConfig) -> f64 {
    let p = GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        ..Default::default()
    };
    let n = cfg.tasksets.max(1).min(60);
    let seed = cfg.seed;
    let cells = sweep::run_indexed(&cfg.sweep(), n, |i| {
        let ts = memo::taskset(seed, &p, i);
        let sim = simulate(&ts, &SimConfig::new(policy, ms(10_000.0)));
        let mut misses = 0u64;
        let mut jobs = 0u64;
        for t in ts.rt_tasks() {
            misses += sim.per_task[t.id].deadline_misses;
            jobs += sim.per_task[t.id].jobs;
        }
        (misses, jobs)
    });
    let misses: u64 = cells.iter().map(|&(m, _)| m).sum();
    let jobs: u64 = cells.iter().map(|&(_, j)| j).sum();
    misses as f64 / jobs.max(1) as f64
}

/// gcaps_suspend schedulability as ε varies (sensitivity). The memo's
/// platform-normalized key means every ε value analyses the *same*
/// tasksets — the sweep isolates the overhead term exactly.
pub fn epsilon_sensitivity(cfg: &ExpConfig, eps_us: u64) -> f64 {
    let p = GenParams {
        platform: Platform::default().with_epsilon(eps_us),
        ..Default::default()
    };
    let seed = cfg.seed;
    let oks = sweep::run_indexed(&cfg.sweep(), cfg.tasksets, |i| {
        let ts = memo::taskset(seed, &p, i);
        gcaps_rta(&ts, false, &Options::default()).schedulable
    });
    oks.iter().filter(|&&ok| ok).count() as f64 / cfg.tasksets.max(1) as f64
}

/// Run all three ablations. Pure render: (CSV, ASCII).
pub fn ablation_render(cfg: &ExpConfig) -> (CsvTable, String) {
    let mut out = String::from("== Ablations ==\n");
    let mut csv = CsvTable::new(vec!["ablation", "x", "value"]);

    out.push_str("\n(1) Lemma 12: sound amendment vs paper-exact (gcaps_busy schedulability)\n");
    for util in [0.3, 0.4, 0.5] {
        let (sound, exact) = lemma12_ablation(cfg, util);
        out.push_str(&format!(
            "    util {util:.1}: sound {sound:.2}  paper-exact {exact:.2}  (optimism {:+.2})\n",
            exact - sound
        ));
        csv.row(vec!["lemma12_sound".into(), format!("{util}"), format!("{sound:.4}")]);
        csv.row(vec!["lemma12_exact".into(), format!("{util}"), format!("{exact:.4}")]);
    }

    out.push_str("\n(2) Fixed-priority GCAPS vs EDF extension (simulated RT miss ratio)\n");
    for util in [0.5, 0.6, 0.7] {
        let fp = miss_ratio(Policy::Gcaps, util, cfg);
        let edf = miss_ratio(Policy::GcapsEdf, util, cfg);
        out.push_str(&format!(
            "    util {util:.1}: gcaps_fp {fp:.4}  gcaps_edf {edf:.4}\n"
        ));
        csv.row(vec!["miss_fp".into(), format!("{util}"), format!("{fp:.5}")]);
        csv.row(vec!["miss_edf".into(), format!("{util}"), format!("{edf:.5}")]);
    }

    out.push_str("\n(3) ε sensitivity (gcaps_suspend schedulability)\n");
    for eps in [0u64, 250, 500, 1000, 2000, 4000] {
        let v = epsilon_sensitivity(cfg, eps);
        out.push_str(&format!("    ε = {eps:>4} µs: {v:.2}\n"));
        csv.row(vec!["epsilon".into(), format!("{eps}"), format!("{v:.4}")]);
    }

    (csv, out)
}

/// Registry face: `gcaps exp ablation`.
pub struct AblationExp;

impl Experiment for AblationExp {
    fn name(&self) -> &'static str {
        "ablation"
    }

    fn about(&self) -> &'static str {
        "Lemma 12 soundness, FP-vs-EDF misses, eps sensitivity"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (csv, text) = ablation_render(cfg);
        sink.table("ablations", &csv);
        sink.text(&text);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { tasksets: 15, seed: 9, ..ExpConfig::default() }
    }

    #[test]
    fn paper_exact_is_never_less_schedulable() {
        // Dropping an interference term can only accept more tasksets.
        let (sound, exact) = lemma12_ablation(&tiny(), 0.4);
        assert!(exact >= sound);
    }

    #[test]
    fn epsilon_sensitivity_monotone() {
        let cfg = tiny();
        let a = epsilon_sensitivity(&cfg, 0);
        let b = epsilon_sensitivity(&cfg, 2000);
        assert!(a >= b, "schedulability must not grow with ε: {a} vs {b}");
    }

    #[test]
    fn edf_not_worse_at_high_load() {
        // EDF is optimal on a single resource: across a small sample its
        // aggregate miss ratio at high load must not exceed FP's by more
        // than noise.
        let cfg = ExpConfig { tasksets: 10, seed: 4, ..ExpConfig::default() };
        let fp = miss_ratio(Policy::Gcaps, 0.7, &cfg);
        let edf = miss_ratio(Policy::GcapsEdf, 0.7, &cfg);
        assert!(edf <= fp + 0.02, "edf {edf} much worse than fp {fp}");
    }
}
