//! Schedule-example reproductions: the paper's illustrative timelines
//! (Fig. 3 sync vs GCAPS, Fig. 5 separate GPU priorities, Fig. 6
//! interference taxonomy, Fig. 7 runlist-update delays), rendered as
//! ASCII Gantt charts from real simulator traces.

use crate::analysis::gcaps::{analyze, Options};
use crate::experiments::registry::Experiment;
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::{ms, to_ms, GpuSegment, Platform, Task, TaskSet, WaitMode};
use crate::sim::{simulate, Policy, SimConfig};
use crate::sweep;
use crate::util::error::Result;

fn mk(
    id: usize,
    name: &str,
    core: usize,
    prio: u32,
    cpu: Vec<f64>,
    gpu: Vec<(f64, f64)>,
    period: f64,
    mode: WaitMode,
) -> Task {
    Task {
        id,
        name: name.into(),
        period: ms(period),
        deadline: ms(period),
        cpu_segments: cpu.into_iter().map(ms).collect(),
        gpu_segments: gpu.into_iter().map(|(m, e)| GpuSegment::new(ms(m), ms(e))).collect(),
        core,
        gpu: 0,
        cpu_prio: prio,
        gpu_prio: prio,
        best_effort: false,
        mode,
    }
}

/// Fig. 3 (Example 1): three tasks, sync-based vs GCAPS. τ1 (highest
/// priority, core 0) arrives while τ3's GPU segment runs; the sync
/// approach serves queued lower-priority segments first, GCAPS preempts.
pub fn run_fig3() -> String {
    let p = Platform::single(2, 1024, 50, 250);
    let tasks = vec![
        mk(0, "tau1", 0, 3, vec![1.0, 1.0], vec![(0.25, 1.5)], 20.0, WaitMode::SelfSuspend),
        mk(1, "tau2", 1, 2, vec![0.5, 0.5], vec![(0.25, 2.0)], 20.0, WaitMode::SelfSuspend),
        mk(2, "tau3", 1, 1, vec![0.2, 0.5], vec![(0.25, 2.5)], 20.0, WaitMode::SelfSuspend),
    ];
    let ts = TaskSet::new(tasks, p);
    let offsets = vec![0, ms(0.1), 0];
    let mut out = String::new();
    for (label, policy) in [("synchronization-based (MPCP)", Policy::Mpcp), ("GCAPS", Policy::Gcaps)] {
        let cfg = SimConfig::new(policy, ms(12.0)).with_offsets(offsets.clone()).with_trace();
        let sim = simulate(&ts, &cfg);
        let r1 = sim.per_task[0].mort().unwrap();
        out.push_str(&format!("\n--- Fig. 3, {label}: R(tau1) = {:.2} ms ---\n", to_ms(r1)));
        out.push_str(&sim.trace.unwrap().gantt(2, 3, 0, ms(10.0), 120));
    }
    out
}

/// Fig. 5 (Example 2): the Table 2 taskset. With π^g = π^c, τ4 misses
/// its deadline; swapping the GPU priorities of τ3/τ4 fixes it.
pub fn table2_taskset() -> TaskSet {
    let p = Platform::single(2, 1024, 200, 1000);
    let tasks = vec![
        mk(0, "tau1", 0, 4, vec![2.0, 4.0, 3.0],
           vec![(2.0, 4.0), (2.0, 2.0)], 80.0, WaitMode::SelfSuspend),
        mk(1, "tau2", 0, 3, vec![40.0], vec![], 150.0, WaitMode::SelfSuspend),
        mk(2, "tau3", 1, 2, vec![4.0, 30.0], vec![(5.0, 80.0)], 190.0, WaitMode::SelfSuspend),
        mk(3, "tau4", 0, 1, vec![16.0, 2.0], vec![(2.0, 10.0)], 200.0, WaitMode::SelfSuspend),
    ];
    TaskSet::new(tasks, p)
}

pub fn run_fig5() -> String {
    let ts = table2_taskset();
    let mut out = String::new();

    // (a) default priorities: the analysis fails τ4.
    let def = analyze(&ts, false, &Options::default());
    out.push_str("--- Fig. 5a: default GPU priorities (π^g = π^c) ---\n");
    for t in &ts.tasks {
        out.push_str(&format!(
            "  {}: WCRT = {}, D = {} ms\n",
            t.name,
            def.response[t.id].map(|r| format!("{:.1} ms", to_ms(r))).unwrap_or("FAILED".into()),
            to_ms(t.deadline)
        ));
    }
    // Simulated confirmation with the paper's release pattern (τ3 at 70).
    let offsets = vec![0, 0, ms(70.0), 0];
    let sim = simulate(
        &ts,
        &SimConfig::new(Policy::Gcaps, ms(400.0)).with_offsets(offsets.clone()).with_trace(),
    );
    out.push_str(&format!(
        "  simulated: tau4 misses = {} (MORT {:.1} ms)\n",
        sim.per_task[3].deadline_misses,
        sim.per_task[3].mort().map(to_ms).unwrap_or(0.0),
    ));

    // (b) swapped GPU priorities for τ3/τ4.
    let mut swapped = ts.clone();
    swapped.tasks[2].gpu_prio = 1;
    swapped.tasks[3].gpu_prio = 2;
    let opts = Options { use_gpu_prio: true, ..Default::default() };
    let fixed = analyze(&swapped, false, &opts);
    out.push_str("--- Fig. 5b: swapped GPU priorities (π^g_4 > π^g_3) ---\n");
    for t in &swapped.tasks {
        out.push_str(&format!(
            "  {}: WCRT = {}\n",
            t.name,
            fixed.response[t.id].map(|r| format!("{:.1} ms", to_ms(r))).unwrap_or("FAILED".into()),
        ));
    }
    let sim_b = simulate(
        &swapped,
        &SimConfig::new(Policy::Gcaps, ms(400.0)).with_offsets(offsets).with_trace(),
    );
    out.push_str(&format!(
        "  simulated: tau4 misses = {} (MORT {:.1} ms)\n",
        sim_b.per_task[3].deadline_misses,
        sim_b.per_task[3].mort().map(to_ms).unwrap_or(0.0),
    ));
    out.push_str("\nGantt (b), first 200 ms:\n");
    out.push_str(&sim_b.trace.unwrap().gantt(2, 4, 0, ms(200.0), 130));
    out
}

/// Fig. 6: interference taxonomy under busy-waiting (direct preemption,
/// indirect delay) — three tasks, τ1 on core 0, τ2/τ3 on core 1.
pub fn run_fig6() -> String {
    let p = Platform::single(2, 1024, 50, 250);
    let tasks = vec![
        mk(0, "tau1", 0, 3, vec![0.5, 0.5], vec![(0.2, 3.0)], 30.0, WaitMode::BusyWait),
        mk(1, "tau2", 1, 2, vec![0.5, 0.5], vec![(0.2, 4.0)], 30.0, WaitMode::BusyWait),
        mk(2, "tau3", 1, 1, vec![3.0], vec![], 30.0, WaitMode::BusyWait),
    ];
    let ts = TaskSet::new(tasks, p);
    let offsets = vec![ms(1.0), 0, 0];
    let mut out = String::new();
    for (label, policy) in [("GCAPS (a)", Policy::Gcaps), ("default round-robin (b)", Policy::TsgRr)] {
        let sim = simulate(
            &ts,
            &SimConfig::new(policy, ms(30.0)).with_offsets(offsets.clone()).with_trace(),
        );
        out.push_str(&format!(
            "\n--- Fig. 6, {label}: R(tau3) = {:.2} ms (busy-waiting τ2 carries τ1's GPU preemption into core 1) ---\n",
            to_ms(sim.per_task[2].mort().unwrap())
        ));
        out.push_str(&sim.trace.unwrap().gantt(2, 3, 0, ms(14.0), 120));
    }
    out
}

/// Fig. 7: runlist-update delays (①–③): ε-blocking at job start, driver
/// calls serialized, and the removal update delaying the next start.
pub fn run_fig7() -> String {
    let p = Platform::single(2, 1024, 300, 1500);
    let tasks = vec![
        mk(0, "tau1", 0, 3, vec![0.5, 0.5], vec![(0.3, 4.0)], 40.0, WaitMode::SelfSuspend),
        mk(1, "tau2", 0, 2, vec![0.5, 0.5], vec![(0.3, 3.0)], 40.0, WaitMode::SelfSuspend),
        mk(2, "tau3", 1, 1, vec![0.3, 0.3], vec![(0.3, 5.0)], 40.0, WaitMode::SelfSuspend),
    ];
    let ts = TaskSet::new(tasks, p);
    // τ3 (lowest) fires first and triggers the first update; τ1/τ2 land on it.
    let offsets = vec![ms(0.6), ms(0.8), 0];
    let sim = simulate(
        &ts,
        &SimConfig::new(Policy::Gcaps, ms(40.0)).with_offsets(offsets).with_trace(),
    );
    let mut out = format!(
        "--- Fig. 7: runlist update delay (ε = 1.5 ms): R(tau2) = {:.2} ms ---\n",
        to_ms(sim.per_task[1].mort().unwrap())
    );
    out.push_str(&sim.trace.unwrap().gantt(2, 3, 0, ms(18.0), 130));
    out
}

/// All four schedule-example figures, rendered via the sweep engine (one
/// cell per figure — they are independent trace simulations) and
/// concatenated in canonical figure order.
pub fn run_examples(cfg: &ExpConfig) -> String {
    let figs: Vec<(&str, fn() -> String)> = vec![
        ("fig3", run_fig3),
        ("fig5", run_fig5),
        ("fig6", run_fig6),
        ("fig7", run_fig7),
    ];
    let rendered = sweep::run(&cfg.sweep(), figs, |_, &(_, f)| f());
    rendered.concat()
}

/// Registry face of one schedule-example figure: pure ASCII (the
/// illustrative timelines have no tabular artifact), parameter-free.
macro_rules! example_experiment {
    ($exp:ident, $name:literal, $about:literal, $run:expr) => {
        pub struct $exp;

        impl Experiment for $exp {
            fn name(&self) -> &'static str {
                $name
            }

            fn about(&self) -> &'static str {
                $about
            }

            /// Covered by the `examples` aggregate in `exp all`.
            fn in_all(&self) -> bool {
                false
            }

            fn run(&self, _cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
                sink.text(&$run());
                Ok(())
            }
        }
    };
}

example_experiment!(
    Fig3Exp,
    "fig3",
    "Example 1 timeline: sync-based (MPCP) vs GCAPS Gantt",
    run_fig3
);
example_experiment!(
    Fig5Exp,
    "fig5",
    "Example 2 (Table 2): separate GPU priorities fix tau4",
    run_fig5
);
example_experiment!(
    Fig6Exp,
    "fig6",
    "Busy-waiting interference taxonomy timeline",
    run_fig6
);
example_experiment!(
    Fig7Exp,
    "fig7",
    "Runlist-update delay timeline (eps-blocking 1-3)",
    run_fig7
);

/// All four schedule examples, concatenated in figure order.
pub struct ExamplesExp;

impl Experiment for ExamplesExp {
    fn name(&self) -> &'static str {
        "examples"
    }

    fn about(&self) -> &'static str {
        "All schedule-example figures (fig3/fig5/fig6/fig7)"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        sink.text(&run_examples(cfg));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::trace::Resource;

    #[test]
    fn run_examples_concatenates_all_figs_in_order() {
        let out = run_examples(&ExpConfig { jobs: 4, ..ExpConfig::default() });
        let p3 = out.find("Fig. 3").expect("fig3 missing");
        let p5 = out.find("Fig. 5").expect("fig5 missing");
        let p6 = out.find("Fig. 6").expect("fig6 missing");
        let p7 = out.find("Fig. 7").expect("fig7 missing");
        assert!(p3 < p5 && p5 < p6 && p6 < p7, "figures out of order");
    }

    #[test]
    fn fig3_gcaps_beats_sync() {
        let out = run_fig3();
        assert!(out.contains("GCAPS") && out.contains("MPCP"));
    }

    #[test]
    fn fig5_reproduces_example2() {
        let ts = table2_taskset();
        ts.validate().unwrap();
        let def = analyze(&ts, false, &Options::default());
        assert!(!def.schedulable, "default priorities must fail (paper Ex. 2)");
        assert!(def.response[3].is_none(), "tau4 is the failing task");
        let mut swapped = ts.clone();
        swapped.tasks[2].gpu_prio = 1;
        swapped.tasks[3].gpu_prio = 2;
        let opts = Options { use_gpu_prio: true, ..Default::default() };
        assert!(analyze(&swapped, false, &opts).schedulable, "swap must pass");
    }

    #[test]
    fn fig6_busy_indirect_delay_visible() {
        // τ3 (CPU-only) must be delayed beyond its own 3 ms by τ2's
        // busy-wait, which τ1's GPU preemption prolongs.
        let out = run_fig6();
        assert!(out.contains("tau3"));
    }

    #[test]
    fn fig7_trace_contains_driver_calls() {
        let p = Platform::single(2, 1024, 300, 1500);
        let tasks = vec![
            mk(0, "tau1", 0, 2, vec![0.5, 0.5], vec![(0.3, 4.0)], 40.0, WaitMode::SelfSuspend),
            mk(1, "tau3", 1, 1, vec![0.3, 0.3], vec![(0.3, 5.0)], 40.0, WaitMode::SelfSuspend),
        ];
        let ts = TaskSet::new(tasks, p);
        let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(40.0)).with_trace());
        let tr = sim.trace.unwrap();
        // Driver-call time on some core equals 2α per segment per task.
        let drv_time: u64 = (0..2)
            .map(|core| {
                tr.events
                    .iter()
                    .filter(|e| {
                        e.resource == Resource::Core(core)
                            && e.activity == crate::sim::trace::Activity::DriverCall
                    })
                    .map(|e| e.end - e.start)
                    .sum::<u64>()
            })
            .sum();
        assert_eq!(drv_time, 2 * 2 * 1200); // 2 tasks × 2 calls × α
    }
}
