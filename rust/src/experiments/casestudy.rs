//! The §7.2 case study: the Table 4 taskset on the simulated Jetson
//! platforms (Fig. 10a/b, Fig. 11, Table 5) and, separately, live on
//! the PJRT runtime (`run_live`) with real AOT kernels.

use crate::analysis::{gcaps, rr};
use crate::err;
use crate::experiments::registry::{Experiment, FlagSpec};
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::{ms, to_ms, GpuSegment, Platform, Task, TaskSet, Time, WaitMode};
use crate::sim::{simulate, Policy, SimConfig};
use crate::sweep;
use crate::util::ascii::bar_chart;
use crate::util::csv::CsvTable;
use crate::util::error::Result;
use crate::util::stats::Summary;

/// Simulated platform presets (Fig. 10a vs 10b). ε and θ follow the
/// paper's measurements: both boards show ε up to ~1 ms (Orin ~10%
/// higher despite half the GPU clock, §7.2) while Orin's TSG context
/// switch θ is *lower* (Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Board {
    XavierNx,
    OrinNano,
}

impl Board {
    /// The registered `[gpu]` profile name (`model::config::GPU_PROFILES`)
    /// this board preset is built from.
    pub fn profile_name(&self) -> &'static str {
        match self {
            Board::XavierNx => "xavier_nx",
            Board::OrinNano => "orin_nano",
        }
    }

    pub fn platform(&self) -> Platform {
        let ctx = crate::model::config::gpu_profile(self.profile_name())
            .expect("board profile registered");
        Platform { num_cpus: 6, gpus: vec![ctx] }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Board::XavierNx => "Jetson Xavier NX",
            Board::OrinNano => "Jetson Orin Nano",
        }
    }
}

/// Table 4 of the paper, as a model taskset. WCETs in ms as published;
/// the G^m/G^e split is not given in the paper — we use G^m ≈ 0.12·G
/// (the launch-overhead fraction we measured on the live runtime).
pub fn table4_taskset(platform: &Platform, mode: WaitMode) -> TaskSet {
    let gm_frac = 0.12;
    let mk = |id: usize,
              name: &str,
              c: f64,
              g: f64,
              t: f64,
              core: usize,
              prio: u32,
              be: bool| {
        let gpu_segments = if g > 0.0 {
            let gm = ms(g * gm_frac);
            vec![GpuSegment::new(gm, ms(g) - gm)]
        } else {
            vec![]
        };
        let cpu_segments = if g > 0.0 {
            vec![ms(c / 2.0), ms(c) - ms(c / 2.0)]
        } else {
            vec![ms(c)]
        };
        Task {
            id,
            name: name.into(),
            period: ms(t),
            deadline: ms(t),
            cpu_segments,
            gpu_segments,
            core,
            gpu: 0,
            cpu_prio: prio,
            gpu_prio: prio,
            best_effort: be,
            mode,
        }
    };
    // Table 4 rows: (workload, C, G, T=D, CPU, priority). CPUs renumbered
    // to 0-based; task 7 pinned to core 4 (partitioned model).
    let tasks = vec![
        mk(0, "histogram", 1.0, 10.0, 100.0, 0, 70, false),
        mk(1, "mmul_gpu_1", 2.0, 12.0, 150.0, 1, 69, false),
        mk(2, "mmul_cpu", 67.0, 0.0, 200.0, 1, 68, false),
        mk(3, "projection", 12.0, 15.0, 300.0, 0, 67, false),
        mk(4, "dxtc", 2.0, 16.0, 400.0, 0, 66, false),
        mk(5, "mmul_gpu_2", 4.0, 44.0, 200.0, 3, 0, true),
        mk(6, "simpleTexture3D", 4.0, 27.0, 67.0, 4, 0, true),
    ];
    TaskSet::new(tasks, platform.clone())
}

/// The approaches shown in Fig. 10 / Table 5.
pub const CASE_APPROACHES: [(&str, Policy, WaitMode); 5] = [
    ("tsg_rr_suspend", Policy::TsgRr, WaitMode::SelfSuspend),
    ("tsg_rr_busy", Policy::TsgRr, WaitMode::BusyWait),
    ("fmlp_suspend", Policy::FmlpPlus, WaitMode::SelfSuspend),
    ("gcaps_suspend", Policy::Gcaps, WaitMode::SelfSuspend),
    ("gcaps_busy", Policy::Gcaps, WaitMode::BusyWait),
];

/// Domain-separation tags for the case study's per-replica offset
/// streams (folded into the cell hash so Fig. 10 and Fig. 11 replicas
/// never alias).
const TAG_FIG10: u64 = 0x10aa;
const TAG_FIG11: u64 = 0x11bb;

fn board_key(board: Board) -> u64 {
    match board {
        Board::XavierNx => 0,
        Board::OrinNano => 1,
    }
}

/// Release offsets for replica `rep`: synchronous for rep 0 (the classic
/// critical instant), otherwise drawn from a per-cell split RNG so the
/// sweep is worker-count-invariant. The tag must NOT include the
/// approach index: every approach sees the same offsets per replica (a
/// paired comparison — the Table 4 periods are identical across
/// approaches), so figure deltas isolate the scheduling policy.
fn replica_offsets(ts: &TaskSet, seed: u64, tag: &[u64], rep: usize) -> Vec<Time> {
    if rep == 0 {
        return vec![0; ts.len()];
    }
    let mut parts = tag.to_vec();
    parts.push(rep as u64);
    let mut rng = sweep::cell_rng(seed, sweep::cell_hash(&parts));
    ts.tasks.iter().map(|t| rng.range_u64(0, t.period)).collect()
}

/// Simulate 30 s (paper duration) + randomized-offset replicas; returns
/// MORT (ms) per task per approach. The (approach × replica) grid is
/// sharded across the sweep pool — 25 independent 30 s DES runs.
pub fn morts(board: Board, cfg: &ExpConfig) -> Vec<(String, Vec<f64>)> {
    const REPS: usize = 5;
    let platform = board.platform();
    let seed = cfg.seed;
    let cells = sweep::grid2(CASE_APPROACHES.len(), REPS);
    let per_cell: Vec<Vec<Time>> = sweep::run(&cfg.sweep(), cells, |_, &(ai, rep)| {
        let (_, policy, mode) = CASE_APPROACHES[ai];
        let ts = table4_taskset(&platform, mode);
        let offsets =
            replica_offsets(&ts, seed, &[TAG_FIG10, board_key(board)], rep);
        let sim =
            simulate(&ts, &SimConfig::new(policy, ms(30_000.0)).with_offsets(offsets));
        ts.tasks.iter().map(|t| sim.per_task[t.id].mort().unwrap_or(0)).collect()
    });

    // Merge in canonical order: per approach, max over replicas.
    let mut out = Vec::new();
    for (ai, (label, _, _)) in CASE_APPROACHES.iter().enumerate() {
        let n_tasks = per_cell[ai * REPS].len();
        let mut mort = vec![0u64; n_tasks];
        for rep in 0..REPS {
            for (t, &m) in per_cell[ai * REPS + rep].iter().enumerate() {
                mort[t] = mort[t].max(m);
            }
        }
        out.push((label.to_string(), mort.iter().map(|&m| to_ms(m)).collect()));
    }
    out
}

/// Fig. 10: MORT bars per task per approach on one board.
/// Pure render: (table stem, CSV, ASCII) — the registry goldens pin
/// the CSV bytes against the pre-redesign harness.
pub fn fig10_render(board: Board, cfg: &ExpConfig) -> (String, CsvTable, String) {
    let results = morts(board, cfg);
    let ts = table4_taskset(&board.platform(), WaitMode::SelfSuspend);
    let mut csv = CsvTable::new(vec!["approach", "task", "mort_ms"]);
    let mut out = String::new();
    for (label, ms_per_task) in &results {
        let rows: Vec<(String, f64)> = ts
            .tasks
            .iter()
            .map(|t| (format!("{} ({})", t.id + 1, t.name), ms_per_task[t.id]))
            .collect();
        out.push_str(&bar_chart(
            &format!("Fig. 10 ({}): MORT under {label}", board.label()),
            &rows,
            "ms",
        ));
        for t in &ts.tasks {
            csv.row(vec![label.clone(), t.name.clone(), format!("{:.3}", ms_per_task[t.id])]);
        }
    }
    let stem = format!(
        "fig10_{}",
        if board == Board::XavierNx { "xavier" } else { "orin" }
    );
    (stem, csv, out)
}

fn board_value_ok(v: &str) -> bool {
    matches!(v, "xavier" | "orin")
}

/// Registry face: `gcaps exp fig10 [--board xavier|orin]` — both
/// boards (Fig. 10a then 10b) when none is selected.
pub struct Fig10Exp;

impl Experiment for Fig10Exp {
    fn name(&self) -> &'static str {
        "fig10"
    }

    fn about(&self) -> &'static str {
        "Case-study MORT per task per approach (simulated boards)"
    }

    fn flags(&self) -> &'static [FlagSpec] {
        static FLAGS: [FlagSpec; 1] =
            [FlagSpec { name: "board", values: "xavier|orin", check: board_value_ok }];
        &FLAGS
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let boards: Vec<Board> = match cfg.opts.get("board") {
            None => vec![Board::XavierNx, Board::OrinNano],
            Some("xavier") => vec![Board::XavierNx],
            Some("orin") => vec![Board::OrinNano],
            Some(other) => {
                return Err(err!("invalid value {other:?} for --board (expected xavier|orin)"))
            }
        };
        for board in boards {
            let (stem, csv, text) = fig10_render(board, cfg);
            sink.table(&stem, &csv);
            sink.text(&text);
        }
        Ok(())
    }
}

/// Fig. 11: response-time variability (max-mean / mean-min error bars,
/// average relative range) across randomized-offset runs.
/// Pure render: (CSV, ASCII).
pub fn fig11_render(cfg: &ExpConfig) -> (CsvTable, String) {
    const REPS: usize = 8;
    let platform = Board::XavierNx.platform();
    let seed = cfg.seed;
    let mut csv = CsvTable::new(vec![
        "approach", "task", "mean_ms", "above_ms", "below_ms", "relative_range",
    ]);
    let mut out = String::from("== Fig. 11: response-time variability (Xavier) ==\n");

    // (approach × replica) cells, each a 15 s DES run returning the
    // per-task response samples of that replica.
    let cells = sweep::grid2(CASE_APPROACHES.len(), REPS);
    let per_cell: Vec<Vec<Vec<f64>>> = sweep::run(&cfg.sweep(), cells, |_, &(ai, rep)| {
        let (_, policy, mode) = CASE_APPROACHES[ai];
        let ts = table4_taskset(&platform, mode);
        let offsets = replica_offsets(&ts, seed, &[TAG_FIG11], rep);
        let sim =
            simulate(&ts, &SimConfig::new(policy, ms(15_000.0)).with_offsets(offsets));
        ts.tasks
            .iter()
            .map(|t| sim.per_task[t.id].response_times.iter().map(|&r| to_ms(r)).collect())
            .collect()
    });

    for (ai, (label, _, mode)) in CASE_APPROACHES.iter().enumerate() {
        let ts = table4_taskset(&platform, *mode);
        // Merge replica samples in canonical replica order.
        let mut samples: Vec<Vec<f64>> = vec![vec![]; ts.len()];
        for rep in 0..REPS {
            for (t, s) in per_cell[ai * REPS + rep].iter().enumerate() {
                samples[t].extend_from_slice(s);
            }
        }
        let mut rel_ranges = Vec::new();
        for t in ts.tasks.iter().filter(|t| !t.best_effort) {
            if let Some(s) = Summary::of(&samples[t.id]) {
                csv.row(vec![
                    label.to_string(),
                    t.name.clone(),
                    format!("{:.3}", s.mean),
                    format!("{:.3}", s.above()),
                    format!("{:.3}", s.below()),
                    format!("{:.4}", s.relative_range()),
                ]);
                rel_ranges.push(s.relative_range());
            }
        }
        let avg_rel = rel_ranges.iter().sum::<f64>() / rel_ranges.len().max(1) as f64;
        out.push_str(&format!("{label:16} average relative range = {avg_rel:.3}\n"));
    }
    (csv, out)
}

/// Registry face: `gcaps exp fig11`.
pub struct Fig11Exp;

impl Experiment for Fig11Exp {
    fn name(&self) -> &'static str {
        "fig11"
    }

    fn about(&self) -> &'static str {
        "Case-study response-time variability across offsets"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (csv, text) = fig11_render(cfg);
        sink.table("fig11", &csv);
        sink.text(&text);
        Ok(())
    }
}

/// Table 5: MORT vs analytic WCRT per RT task, for the default driver
/// and GCAPS (busy + suspend). Pure render: (CSV, ASCII).
pub fn table5_render(cfg: &ExpConfig) -> (CsvTable, String) {
    let platform = Board::XavierNx.platform();
    let mut out = String::from(
        "== Table 5: MORT vs WCRT (ms) on simulated Xavier ==\n\
         task              | tsg_rr_susp      | tsg_rr_busy      | gcaps_susp       | gcaps_busy\n\
                           | MORT     WCRT    | MORT     WCRT    | MORT     WCRT    | MORT     WCRT\n",
    );
    let mut csv = CsvTable::new(vec!["task", "approach", "mort_ms", "wcrt_ms"]);

    // MORTs per approach.
    let mort_map: std::collections::HashMap<String, Vec<f64>> =
        morts(Board::XavierNx, cfg).into_iter().collect();
    // WCRTs per approach.
    let wcrt = |busy: bool, is_gcaps: bool| -> Vec<Option<Time>> {
        let mode = if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend };
        let ts = table4_taskset(&platform, mode);
        if is_gcaps {
            gcaps::analyze(&ts, busy, &gcaps::Options::default()).response
        } else {
            rr::analyze(&ts, busy).response
        }
    };
    let combos: Vec<(&str, Vec<Option<Time>>)> = vec![
        ("tsg_rr_suspend", wcrt(false, false)),
        ("tsg_rr_busy", wcrt(true, false)),
        ("gcaps_suspend", wcrt(false, true)),
        ("gcaps_busy", wcrt(true, true)),
    ];
    let ts = table4_taskset(&platform, WaitMode::SelfSuspend);
    for t in ts.tasks.iter().filter(|t| !t.best_effort) {
        out.push_str(&format!("{:17} |", format!("{} ({})", t.id + 1, t.name)));
        for (label, resp) in &combos {
            let mort = mort_map[*label][t.id];
            let w = resp[t.id].map(to_ms);
            let wstr = w.map(|v| format!("{v:8.2}")).unwrap_or_else(|| "  Failed".into());
            out.push_str(&format!(" {mort:8.2}{wstr} |"));
            csv.row(vec![
                t.name.clone(),
                label.to_string(),
                format!("{mort:.3}"),
                w.map(|v| format!("{v:.3}")).unwrap_or_else(|| "failed".into()),
            ]);
        }
        out.push('\n');
    }
    (csv, out)
}

/// Registry face: `gcaps exp table5`.
pub struct Table5Exp;

impl Experiment for Table5Exp {
    fn name(&self) -> &'static str {
        "table5"
    }

    fn about(&self) -> &'static str {
        "Case-study MORT vs analytic WCRT (simulated Xavier)"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (csv, text) = table5_render(cfg);
        sink.table("table5", &csv);
        sink.text(&text);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn board_platforms_pin_the_measured_values() {
        // Golden: the boards must keep producing the pre-profile bytes
        // (Fig. 10/11/Table 5 CSVs depend on these constants).
        assert_eq!(Board::XavierNx.platform(), Platform::single(6, 1024, 250, 1000));
        assert_eq!(Board::OrinNano.platform(), Platform::single(6, 1024, 160, 1100));
    }

    #[test]
    fn table4_taskset_valid() {
        for board in [Board::XavierNx, Board::OrinNano] {
            let ts = table4_taskset(&board.platform(), WaitMode::SelfSuspend);
            ts.validate().unwrap();
            assert_eq!(ts.len(), 7);
            assert_eq!(ts.be_tasks().count(), 2);
            assert_eq!(ts.tasks[2].eta_g(), 0); // mmul_cpu
        }
    }

    #[test]
    fn table4_utilizations_in_band() {
        // Paper: per-task utilization between 0.05 and 0.35.
        let ts = table4_taskset(&Board::XavierNx.platform(), WaitMode::SelfSuspend);
        for t in &ts.tasks {
            let u = t.utilization();
            assert!((0.04..=0.50).contains(&u), "{}: {u}", t.name);
        }
    }

    #[test]
    fn gcaps_beats_tsg_rr_for_high_priority_tasks() {
        // The Fig. 10 headline: tasks 1-2 see much lower MORT under GCAPS.
        let cfg = ExpConfig { tasksets: 0, seed: 1, ..ExpConfig::default() };
        let m: std::collections::HashMap<String, Vec<f64>> =
            morts(Board::XavierNx, &cfg).into_iter().collect();
        assert!(m["gcaps_suspend"][0] < m["tsg_rr_suspend"][0]);
        assert!(m["gcaps_suspend"][1] < m["tsg_rr_suspend"][1]);
    }

    #[test]
    fn wcrt_bounds_dominate_simulated_morts() {
        // Table 5 internal consistency: WCRT ≥ MORT wherever the test passes.
        let cfg = ExpConfig { tasksets: 0, seed: 2, ..ExpConfig::default() };
        let platform = Board::XavierNx.platform();
        let mort_map: std::collections::HashMap<String, Vec<f64>> =
            morts(Board::XavierNx, &cfg).into_iter().collect();
        let combos: Vec<(&str, bool, bool)> = vec![
            ("tsg_rr_suspend", false, false),
            ("tsg_rr_busy", true, false),
            ("gcaps_suspend", false, true),
            ("gcaps_busy", true, true),
        ];
        for (label, busy, is_gcaps) in combos {
            let mode = if busy { WaitMode::BusyWait } else { WaitMode::SelfSuspend };
            let ts = table4_taskset(&platform, mode);
            let resp = if is_gcaps {
                gcaps::analyze(&ts, busy, &gcaps::Options::default()).response
            } else {
                rr::analyze(&ts, busy).response
            };
            for t in ts.tasks.iter().filter(|t| !t.best_effort) {
                if let Some(w) = resp[t.id] {
                    let mort = mort_map[label][t.id];
                    assert!(
                        mort <= to_ms(w) + 1e-6,
                        "{label} task {}: MORT {mort} > WCRT {}",
                        t.name,
                        to_ms(w)
                    );
                }
            }
        }
    }
}
