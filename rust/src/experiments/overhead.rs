//! Overhead measurements: Fig. 12 (runlist-update delay ε) and Fig. 13
//! (TSG context-switch overhead θ via the Eq. 15 slowdown method).
//!
//! Fig. 12's live variant measures the in-process arbiter (the analog of
//! the IOCTL+driver path). Our updates are µs-scale rather than the
//! paper's ~1 ms (no kernel crossing, no hardware runlist poll), but the
//! *bimodal* shape — cheap non-contended calls vs full updates with
//! wakeups — reproduces.
//!
//! Fig. 13 runs ν identical GPU-only tasks under the round-robin driver
//! model, measures the completion inflation E_ν vs ν·E_1 and recovers
//!
//! ```text
//!     θ = (E_ν − ν·E_1) / (ν·E_1) · L            (Eq. 15)
//! ```
//!
//! On the DES this is a *validation*: the estimator must recover the
//! configured θ. The live variant applies the same estimator to real
//! concurrent PJRT launch streams.

use crate::experiments::registry::Experiment;
use crate::experiments::sink::Sink;
use crate::experiments::{results_dir, ExpConfig};
use crate::model::{ms, GpuSegment, Platform, Task, TaskSet, Time, WaitMode};
use crate::sim::{simulate, Policy, SimConfig};
use crate::sweep;
use crate::util::ascii::{bar_chart, histogram_chart};
use crate::util::csv::CsvTable;
use crate::util::error::Result;
use crate::util::stats::Histogram;

/// A GPU-only task running one `ge`-long kernel once (period padded so
/// exactly one job runs).
fn kernel_task(id: usize, core: usize, ge: Time, horizon: Time) -> Task {
    Task {
        id,
        name: format!("k{id}"),
        period: horizon,
        deadline: horizon,
        cpu_segments: vec![1, 1],
        gpu_segments: vec![GpuSegment::new(1, ge)],
        core,
        gpu: 0,
        cpu_prio: (id + 1) as u32,
        gpu_prio: (id + 1) as u32,
        best_effort: false,
        mode: WaitMode::SelfSuspend,
    }
}

/// Eq. 15 estimation on the DES for one kernel length and ν instances.
/// Returns (slowdown factor, estimated θ in µs).
pub fn estimate_theta_sim(platform: &Platform, ge: Time, nu: usize) -> (f64, f64) {
    let horizon = ge * (nu as Time + 2) * 4 + ms(100.0);
    // E_1: a single instance.
    let ts1 = TaskSet::new(vec![kernel_task(0, 0, ge, horizon)], platform.clone());
    let r1 = simulate(&ts1, &SimConfig::new(Policy::TsgRr, horizon));
    let e1 = r1.per_task[0].response_times[0];
    // E_ν: ν concurrent instances (one per core, wrapping).
    let tasks: Vec<Task> = (0..nu)
        .map(|i| kernel_task(i, i % platform.num_cpus, ge, horizon))
        .collect();
    let tsn = TaskSet::new(tasks, platform.clone());
    let rn = simulate(&tsn, &SimConfig::new(Policy::TsgRr, horizon));
    let en = (0..nu)
        .map(|i| rn.per_task[i].response_times[0])
        .max()
        .unwrap();
    let slowdown = en as f64 / e1 as f64;
    let theta_est = (en as f64 - nu as f64 * e1 as f64) / (nu as f64 * e1 as f64)
        * platform.gpus[0].tsg_slice as f64;
    (slowdown, theta_est)
}

/// Fig. 13 (DES): θ estimation across kernel lengths and ν values. Each
/// (board, kernel, ν) cell runs two DES instances; the grid is sharded
/// across the sweep pool and merged in canonical board-major order.
/// Pure render: (CSV, ASCII).
pub fn fig13_render(cfg: &ExpConfig) -> (CsvTable, String) {
    use crate::experiments::casestudy::Board;
    // Board presets come from the case study so Fig. 10/13 cannot drift
    // apart. ε is irrelevant here (the Eq. 15 runs use Policy::TsgRr,
    // which never issues GCAPS driver calls).
    let boards: [(&str, Platform); 2] = [
        ("xavier", Board::XavierNx.platform()),
        ("orin", Board::OrinNano.platform()),
    ];
    const KERNELS_MS: [f64; 3] = [20.0, 40.0, 80.0];
    const NUS: [usize; 3] = [2, 4, 6];

    let cells = sweep::grid3(boards.len(), KERNELS_MS.len(), NUS.len());
    let per_cell: Vec<(f64, f64)> = sweep::run(&cfg.sweep(), cells, |_, &(bi, ki, ni)| {
        estimate_theta_sim(&boards[bi].1, ms(KERNELS_MS[ki]), NUS[ni])
    });

    let mut csv = CsvTable::new(vec!["board", "kernel_ms", "nu", "slowdown", "theta_est_us"]);
    let mut rows = Vec::new();
    let per_board = KERNELS_MS.len() * NUS.len();
    for (bi, (board, platform)) in boards.iter().enumerate() {
        let mut ests = Vec::new();
        for (j, &(slow, theta)) in
            per_cell[bi * per_board..(bi + 1) * per_board].iter().enumerate()
        {
            let ge_ms = KERNELS_MS[j / NUS.len()];
            let nu = NUS[j % NUS.len()];
            csv.row(vec![
                board.to_string(),
                format!("{ge_ms}"),
                nu.to_string(),
                format!("{slow:.3}"),
                format!("{theta:.1}"),
            ]);
            ests.push(theta);
        }
        let avg = ests.iter().sum::<f64>() / ests.len() as f64;
        rows.push((format!("{board} (θ_config = {} µs)", platform.gpus[0].theta), avg));
    }
    let out = bar_chart("Fig. 13: estimated TSG context-switch overhead (Eq. 15)", &rows, "µs");
    (csv, out)
}

/// Registry face: `gcaps exp fig13`.
pub struct Fig13Exp;

impl Experiment for Fig13Exp {
    fn name(&self) -> &'static str {
        "fig13"
    }

    fn about(&self) -> &'static str {
        "TSG context-switch overhead estimation (Eq. 15, DES)"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (csv, text) = fig13_render(cfg);
        sink.table("fig13", &csv);
        sink.text(&text);
        Ok(())
    }
}

/// Fig. 12: histogram table + chart from ε samples (µs). Pure render:
/// `None` table when there are no samples.
pub fn fig12_parts(samples_us: &[f64], label: &str) -> (Option<CsvTable>, String) {
    if samples_us.is_empty() {
        return (None, format!("== Fig. 12 ({label}): no samples ==\n"));
    }
    let max = samples_us.iter().cloned().fold(0.0f64, f64::max);
    let mut h = Histogram::new(0.0, (max * 1.1).max(1.0), 20);
    for &s in samples_us {
        h.add(s);
    }
    let mut csv = CsvTable::new(vec!["bin_lo_us", "bin_hi_us", "count"]);
    for (k, &c) in h.bins.iter().enumerate() {
        let (lo, hi) = h.bin_edges(k);
        csv.row(vec![format!("{lo:.3}"), format!("{hi:.3}"), c.to_string()]);
    }
    let out = histogram_chart(
        &format!("Fig. 12 ({label}): runlist update overhead"),
        &h,
        "µs",
    );
    (Some(csv), out)
}

/// Fig. 12 histogram from ε samples (µs), written straight to the
/// results dir — the live executive's entry point (`gcaps live fig12`),
/// which runs outside the experiment registry.
pub fn fig12_histogram(samples_us: &[f64], label: &str) -> String {
    let (csv, mut out) = fig12_parts(samples_us, label);
    if let Some(csv) = csv {
        let path = results_dir().join(format!("fig12_{label}.csv"));
        csv.write(&path).expect("write csv");
        out.push_str(&format!("wrote {}\n", path.display()));
    }
    out
}

/// ε samples (µs) of the simulated case study — the Fig. 12 DES input.
pub fn fig12_sim_samples() -> Vec<f64> {
    use crate::experiments::casestudy::{table4_taskset, Board};
    let ts = table4_taskset(&Board::XavierNx.platform(), WaitMode::SelfSuspend);
    let sim = simulate(&ts, &SimConfig::new(Policy::Gcaps, ms(30_000.0)));
    sim.per_task
        .iter()
        .flat_map(|m| m.runlist_updates.iter().map(|&d| d as f64))
        .collect()
}

/// Registry face: `gcaps exp fig12` (the DES variant; the live variant
/// is `gcaps live fig12`).
pub struct Fig12Exp;

impl Experiment for Fig12Exp {
    fn name(&self) -> &'static str {
        "fig12"
    }

    fn about(&self) -> &'static str {
        "Runlist-update delay histogram (simulated case study)"
    }

    fn run(&self, _cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let samples = fig12_sim_samples();
        let (csv, text) = fig12_parts(&samples, "sim");
        if let Some(csv) = csv {
            sink.table("fig12_sim", &csv);
        }
        sink.text(&text);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq15_recovers_configured_theta() {
        // The estimator applied to the device model must recover θ
        // within ~20% (quantisation from ceil(G^e/L) slices).
        let p = Platform::single(4, 1024, 200, 1000);
        let (slow, theta) = estimate_theta_sim(&p, ms(40.0), 4);
        assert!(slow > 3.5 && slow < 5.0, "slowdown {slow}");
        assert!(
            (theta - 200.0).abs() < 60.0,
            "estimated θ = {theta} vs configured 200"
        );
    }

    #[test]
    fn slowdown_grows_with_nu() {
        let p = Platform::single(6, 1024, 200, 1000);
        let (s2, _) = estimate_theta_sim(&p, ms(20.0), 2);
        let (s4, _) = estimate_theta_sim(&p, ms(20.0), 4);
        assert!(s4 > s2, "s4 {s4} <= s2 {s2}");
    }

    #[test]
    fn orin_estimates_below_xavier() {
        // Fig. 13's cross-board observation.
        let x = Platform::single(6, 1024, 250, 1000);
        let o = Platform::single(6, 1024, 160, 1000);
        let (_, tx) = estimate_theta_sim(&x, ms(40.0), 4);
        let (_, to_) = estimate_theta_sim(&o, ms(40.0), 4);
        assert!(to_ < tx, "orin {to_} >= xavier {tx}");
    }

    #[test]
    fn fig12_histogram_renders() {
        let out = fig12_histogram(&[1.0, 2.0, 800.0, 950.0], "test");
        assert!(out.contains("Fig. 12"));
        let _ = std::fs::remove_file(results_dir().join("fig12_test.csv"));
    }
}
