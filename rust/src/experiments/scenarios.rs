//! Scenario sweeps beyond the paper (the ROADMAP's "scenario sweeps"
//! item): three harness families probing the design space where §7
//! fixes a single board's ε/θ and a single GPU engine.
//!
//! 1. **ε×θ overhead grids per board** (`scenarios_epstheta.csv`):
//!    schedulability of all 9 approaches at every cell of an ε×θ grid
//!    scaled around each registered board profile
//!    ([`crate::model::config::GPU_PROFILES`]). Overhead constants
//!    dominate schedulability comparisons between preemptive and
//!    server/lock-based approaches (Kim et al.'s server-based analysis
//!    makes the same point), so the grid shows *where* each approach's
//!    lead survives. The memo's platform-normalized key means every
//!    grid cell analyses the **same** tasksets — the grid isolates the
//!    overhead terms exactly.
//! 2. **EDF vs FP** (`scenarios_edfvfp.csv`): the §8 EDF extension
//!    (`Policy::GcapsEdf` in the DES) against fixed-priority GCAPS
//!    (analysis + DES) across utilization × GPU-task-ratio — the
//!    priority-policy axis the authors' follow-up work argues is the
//!    decisive lever.
//! 3. **Heterogeneous multi-GPU** (`scenarios_hetero.csv`): 2-engine
//!    platforms whose engines carry *different* ε/θ/L (one fast + one
//!    slow) against a uniform platform with the same **mean** per-engine
//!    overheads, across utilization. Exercises the first-class
//!    heterogeneous-platform path end-to-end: `Platform::heterogeneous`
//!    → taskgen (WFD over engines) → per-engine analysis sets → DES.
//! 4. **Overload survival** (`scenarios_overload.csv`): a deterministic
//!    WCET-overrun ramp ([`FaultPlan::ramp`]) over the middle third of
//!    the horizon, crossed with every [`DeadlineMissAction`] — miss
//!    ratio, pooled tardiness p50/p99, abort ratio, and recovery time
//!    (how long past the ramp's end the last miss/abort lands).
//! 5. **Load-adaptive policy switching** (`scenarios_adaptive.csv`):
//!    fixed RR, fixed EDF, and the windowed-miss-ratio RR↔EDF governor
//!    ([`AdaptivePolicy`]) under the same overrun ramp.
//! 6. **Fine-grain co-running** (`scenarios_finegrain.csv`): per-segment
//!    SM-fraction bands (serial control, wide, small) × utilization ×
//!    GPU-task ratio — paired serial-vs-fine GCAPS acceptance on the
//!    same tasksets plus the co-running gcaps DES miss ratio.
//!
//! All six run through the sharded `sweep/` worker pool; results and
//! CSV bytes are identical for every `--jobs` value
//! (`rust/tests/scenarios.rs` pins it, plus per-sub-sweep anchors).
//!
//! Sampling note (same as the multi-GPU sweep): distinct platforms /
//! generator knobs hash to distinct memo keys, so cross-point deltas
//! compare independent taskset draws — distribution-level, not paired.

use crate::analysis::{approach_schedulable, Approach};
use crate::experiments::registry::{Experiment, FlagSpec};
use crate::experiments::sink::Sink;
use crate::experiments::{approaches, ExpConfig};
use crate::model::{
    config, ms, AdaptivePolicy, DeadlineMissAction, FaultPlan, GpuContext, Platform, Time,
};
use crate::sim::{simulate, Policy, SimConfig};
use crate::sweep::{self, memo};
use crate::taskgen::GenParams;
use crate::util::csv::CsvTable;
use crate::util::error::Result;
use crate::util::stats::percentile;

/// The sub-sweep names accepted by `gcaps exp scenarios --only <name>`.
pub const SCENARIOS: [&str; 6] =
    ["epstheta", "edfvfp", "hetero", "overload", "adaptive", "finegrain"];

/// DES horizon per replica (µs as ms input): 6–100 jobs per task at
/// Table 3 periods (30–500 ms) — enough for aggregate miss ratios
/// (long-period tasks contribute few jobs each, so per-point tails are
/// noisier than the short-period mass), short enough for CI smoke
/// grids.
const DES_HORIZON_MS: f64 = 3_000.0;

/// DES replica cap per sweep point — the simulation dominates the cell
/// cost (the ablation harness bounds its miss-ratio sweep identically).
const MAX_SIM_TASKSETS: usize = 60;

/// RT deadline misses and jobs of one simulation run.
fn rt_misses(ts: &crate::model::TaskSet, policy: Policy) -> (u64, u64) {
    let res = simulate(ts, &SimConfig::new(policy, ms(DES_HORIZON_MS)));
    let mut misses = 0u64;
    let mut jobs = 0u64;
    for t in ts.rt_tasks() {
        misses += res.per_task[t.id].deadline_misses;
        jobs += res.per_task[t.id].jobs;
    }
    (misses, jobs)
}

// ---------------------------------------------------------------------
// (a) ε×θ grid per board profile
// ---------------------------------------------------------------------

/// Multipliers applied to each board profile's measured ε (rows) and θ
/// (columns). Chosen so ε ≥ θ holds at every cell of both boards (α =
/// ε − θ saturates at 0 otherwise).
pub const EPS_FACTORS: [f64; 4] = [0.5, 1.0, 2.0, 4.0];
pub const THETA_FACTORS: [f64; 3] = [0.5, 1.0, 2.0];

/// Number of analysis approaches in every per-approach result array
/// (tracks `Approach::ALL` — appended-at-end, so CSV prefixes stay
/// byte-stable).
pub const N_APPROACHES: usize = Approach::ALL.len();

/// One ε×θ result row: (board, scaled engine context, per-approach
/// schedulable ratios in `Approach::ALL` order).
pub type EpsThetaRow = ((&'static str, GpuContext), [f64; N_APPROACHES]);

fn scale(base: Time, f: f64) -> Time {
    (base as f64 * f).round() as Time
}

/// The (board, scaled context) grid points — board-major, then ε-major,
/// then θ-minor: the canonical cell and CSV row order.
pub fn epstheta_points() -> Vec<(&'static str, GpuContext)> {
    let mut pts = Vec::new();
    for (board, base) in config::GPU_PROFILES {
        for &fe in EPS_FACTORS.iter() {
            for &ft in THETA_FACTORS.iter() {
                pts.push((
                    board,
                    GpuContext {
                        tsg_slice: base.tsg_slice,
                        theta: scale(base.theta, ft),
                        epsilon: scale(base.epsilon, fe),
                    },
                ));
            }
        }
    }
    pts
}

/// Sweep (a): all 9 approaches at every (board, ε, θ) grid cell.
pub fn epstheta_sweep(cfg: &ExpConfig) -> Vec<EpsThetaRow> {
    let points = epstheta_points();
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<[bool; N_APPROACHES]> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            let (_, ctx) = points[pi];
            let p = GenParams {
                platform: Platform::default().with_gpu(0, ctx),
                ..GenParams::default()
            };
            approaches(seed, &p, ti)
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pi, &point)| {
            let slice = &per_cell[pi * n..(pi + 1) * n];
            let mut ys = [0.0f64; N_APPROACHES];
            for oks in slice {
                for (k, &ok) in oks.iter().enumerate() {
                    ys[k] += ok as usize as f64;
                }
            }
            for y in ys.iter_mut() {
                *y /= n.max(1) as f64;
            }
            (point, ys)
        })
        .collect()
}

/// Format sweep (a) as its CSV (pure — the determinism suite compares
/// these bytes across worker counts).
pub fn epstheta_csv(rows: &[EpsThetaRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "board",
        "epsilon_us",
        "theta_us",
        "approach",
        "schedulable_ratio",
    ]);
    for ((board, ctx), ys) in rows {
        for (a, y) in Approach::ALL.iter().zip(ys) {
            csv.row(vec![
                board.to_string(),
                ctx.epsilon.to_string(),
                ctx.theta.to_string(),
                a.label().to_string(),
                format!("{y:.4}"),
            ]);
        }
    }
    csv
}

fn epstheta_report(rows: &[EpsThetaRow]) -> String {
    let k = Approach::ALL
        .iter()
        .position(|a| *a == Approach::GcapsSuspend)
        .unwrap();
    let mut out = String::from(
        "== Scenarios (a): ε×θ overhead grids (gcaps_suspend ratio shown; \
         all 9 approaches in the CSV) ==\n",
    );
    for (board, _) in config::GPU_PROFILES {
        let mut thetas: Vec<Time> = rows
            .iter()
            .filter(|((b, _), _)| *b == board)
            .map(|((_, c), _)| c.theta)
            .collect();
        thetas.sort_unstable();
        thetas.dedup();
        let mut epss: Vec<Time> = rows
            .iter()
            .filter(|((b, _), _)| *b == board)
            .map(|((_, c), _)| c.epsilon)
            .collect();
        epss.sort_unstable();
        epss.dedup();
        out.push_str(&format!("  [{board}]\n        ε\\θ(µs)"));
        for t in &thetas {
            out.push_str(&format!("{t:>7}"));
        }
        out.push('\n');
        for e in &epss {
            out.push_str(&format!("    {e:>11}"));
            for t in &thetas {
                let v = rows
                    .iter()
                    .find(|((b, c), _)| *b == board && c.epsilon == *e && c.theta == *t)
                    .map(|(_, ys)| ys[k])
                    .unwrap_or(0.0);
                out.push_str(&format!("{v:>7.2}"));
            }
            out.push('\n');
        }
    }
    out
}

// ---------------------------------------------------------------------
// (b) EDF vs FP across utilization × GPU-task ratio
// ---------------------------------------------------------------------

pub const EDF_UTILS: [f64; 4] = [0.4, 0.5, 0.6, 0.7];
pub const EDF_GPU_RATIOS: [f64; 4] = [0.2, 0.4, 0.6, 0.8];

/// One EDF-vs-FP result row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct EdfVsFpRow {
    pub util: f64,
    pub gpu_ratio: f64,
    /// GCAPS fixed-priority analysis acceptance (self-suspending,
    /// §7.1.1 Audsley retry). No EDF response-time analysis exists —
    /// the paper leaves it as future work — so the analysis column is
    /// FP-only and the DES columns carry the comparison.
    pub sched_fp: f64,
    /// Simulated RT deadline-miss ratio, fixed-priority GCAPS.
    pub miss_fp: f64,
    /// Simulated RT deadline-miss ratio, the §8 EDF extension.
    pub miss_edf: f64,
}

/// The generator knobs for one (utilization, GPU-ratio) point — shared
/// by the sweep and its test anchor so both hash to the same memo key
/// (bit-identical float expressions matter).
pub fn edfvfp_params(util: f64, gpu_ratio: f64) -> GenParams {
    GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        gpu_task_ratio: (gpu_ratio, gpu_ratio),
        ..GenParams::default()
    }
}

/// Sweep (b): FP analysis acceptance + FP/EDF DES miss ratios at every
/// utilization × GPU-ratio point. The DES runs are capped at
/// [`MAX_SIM_TASKSETS`] replicas per point.
pub fn edfvfp_sweep(cfg: &ExpConfig) -> Vec<EdfVsFpRow> {
    let points: Vec<(f64, f64)> = EDF_UTILS
        .iter()
        .flat_map(|&u| EDF_GPU_RATIOS.iter().map(move |&r| (u, r)))
        .collect();
    let n_sim = cfg.tasksets.min(MAX_SIM_TASKSETS);
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<(bool, Option<(u64, u64, u64, u64)>)> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            let (util, ratio) = points[pi];
            let p = edfvfp_params(util, ratio);
            let ts = memo::taskset(seed, &p, ti);
            let sched = approach_schedulable(&ts, Approach::GcapsSuspend);
            let sim = (ti < n_sim).then(|| {
                let (mf, jf) = rt_misses(&ts, Policy::Gcaps);
                let (me, je) = rt_misses(&ts, Policy::GcapsEdf);
                (mf, jf, me, je)
            });
            (sched, sim)
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pi, &(util, gpu_ratio))| {
            let slice = &per_cell[pi * n..(pi + 1) * n];
            let sched = slice.iter().filter(|&&(s, _)| s).count() as f64 / n.max(1) as f64;
            let (mut mf, mut jf, mut me, mut je) = (0u64, 0u64, 0u64, 0u64);
            for (_, sim) in slice {
                if let Some((a, b, c, d)) = *sim {
                    mf += a;
                    jf += b;
                    me += c;
                    je += d;
                }
            }
            EdfVsFpRow {
                util,
                gpu_ratio,
                sched_fp: sched,
                miss_fp: mf as f64 / jf.max(1) as f64,
                miss_edf: me as f64 / je.max(1) as f64,
            }
        })
        .collect()
}

/// Format sweep (b) as its CSV.
pub fn edfvfp_csv(rows: &[EdfVsFpRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "util_per_cpu",
        "gpu_task_ratio",
        "gcaps_fp_sched_ratio",
        "miss_ratio_fp",
        "miss_ratio_edf",
    ]);
    for r in rows {
        csv.row(vec![
            format!("{:.1}", r.util),
            format!("{:.1}", r.gpu_ratio),
            format!("{:.4}", r.sched_fp),
            format!("{:.5}", r.miss_fp),
            format!("{:.5}", r.miss_edf),
        ]);
    }
    csv
}

fn edfvfp_report(rows: &[EdfVsFpRow]) -> String {
    let mut out = String::from(
        "== Scenarios (b): EDF extension vs fixed-priority GCAPS ==\n\
         \x20   util  gpu%   FP sched   miss(FP)   miss(EDF)\n",
    );
    for r in rows {
        out.push_str(&format!(
            "    {:>4.1}  {:>3.0}%     {:>6.2}    {:>7.4}     {:>7.4}\n",
            r.util,
            r.gpu_ratio * 100.0,
            r.sched_fp,
            r.miss_fp,
            r.miss_edf
        ));
    }
    out
}

// ---------------------------------------------------------------------
// (c) heterogeneous multi-GPU platforms
// ---------------------------------------------------------------------

pub const HETERO_UTILS: [f64; 4] = [0.4, 0.5, 0.6, 0.7];

/// The compared 2-engine platforms. All three carry the same engine
/// count and the same **mean** per-engine overheads (ε̄ = 1 ms, θ̄ =
/// 200 µs — the Table 3 defaults), so the sweep isolates the *spread*
/// of the overheads across engines at equal total overhead budget;
/// `hetero_wide` additionally doubles the slow engine's TSG slice so a
/// distinct per-engine L flows through end-to-end.
pub fn hetero_platforms() -> Vec<(&'static str, Platform)> {
    vec![
        ("uniform_g2", Platform::uniform(4, 2, GpuContext::default())),
        (
            "hetero_mild",
            Platform::heterogeneous(
                4,
                vec![
                    GpuContext { tsg_slice: 1024, theta: 150, epsilon: 750 },
                    GpuContext { tsg_slice: 1024, theta: 250, epsilon: 1250 },
                ],
            ),
        ),
        (
            "hetero_wide",
            Platform::heterogeneous(
                4,
                vec![
                    GpuContext { tsg_slice: 1024, theta: 50, epsilon: 250 },
                    GpuContext { tsg_slice: 2048, theta: 350, epsilon: 1750 },
                ],
            ),
        ),
    ]
}

/// One hetero sweep row: (platform name, utilization, per-approach
/// ratios in `Approach::ALL` order, simulated gcaps DES miss ratio).
pub type HeteroRow = (&'static str, f64, [f64; N_APPROACHES], f64);

/// The generator knobs for one (platform, utilization) point (shared
/// with the test anchors; see [`edfvfp_params`]).
pub fn hetero_params(platform: &Platform, util: f64) -> GenParams {
    GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        platform: platform.clone(),
        ..GenParams::default()
    }
}

/// Sweep (c): all 9 approaches + the gcaps DES at every (platform,
/// utilization) point. Heterogeneous platforms hash to their own memo
/// keys (`memo::params_hash` folds the per-engine contexts when the
/// engines differ), so every point draws its own tasksets.
pub fn hetero_sweep(cfg: &ExpConfig) -> Vec<HeteroRow> {
    let platforms = hetero_platforms();
    let points: Vec<(usize, f64)> = (0..platforms.len())
        .flat_map(|pi| HETERO_UTILS.iter().map(move |&u| (pi, u)))
        .collect();
    let n_sim = cfg.tasksets.min(MAX_SIM_TASKSETS);
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<([bool; N_APPROACHES], Option<(u64, u64)>)> =
        sweep::run(&cfg.sweep(), cells, |_, &(pt, ti)| {
            let (pi, util) = points[pt];
            let p = hetero_params(&platforms[pi].1, util);
            let oks = approaches(seed, &p, ti);
            let sim = (ti < n_sim).then(|| {
                let ts = memo::taskset(seed, &p, ti);
                rt_misses(&ts, Policy::Gcaps)
            });
            (oks, sim)
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pt, &(pi, util))| {
            let slice = &per_cell[pt * n..(pt + 1) * n];
            let mut ys = [0.0f64; N_APPROACHES];
            for (oks, _) in slice {
                for (k, &ok) in oks.iter().enumerate() {
                    ys[k] += ok as usize as f64;
                }
            }
            for y in ys.iter_mut() {
                *y /= n.max(1) as f64;
            }
            let (mut misses, mut jobs) = (0u64, 0u64);
            for (_, sim) in slice {
                if let Some((m, j)) = *sim {
                    misses += m;
                    jobs += j;
                }
            }
            (platforms[pi].0, util, ys, misses as f64 / jobs.max(1) as f64)
        })
        .collect()
}

/// Format sweep (c) as its CSV (long format: one metric per row —
/// `sched_<approach>` ratios plus `miss_ratio_gcaps_des`).
pub fn hetero_csv(rows: &[HeteroRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec!["platform", "util_per_cpu", "metric", "value"]);
    for (name, util, ys, miss) in rows {
        for (a, y) in Approach::ALL.iter().zip(ys) {
            csv.row(vec![
                name.to_string(),
                format!("{util:.1}"),
                format!("sched_{}", a.label()),
                format!("{y:.4}"),
            ]);
        }
        csv.row(vec![
            name.to_string(),
            format!("{util:.1}"),
            "miss_ratio_gcaps_des".to_string(),
            format!("{miss:.5}"),
        ]);
    }
    csv
}

fn hetero_report(rows: &[HeteroRow]) -> String {
    let k = Approach::ALL
        .iter()
        .position(|a| *a == Approach::GcapsSuspend)
        .unwrap();
    let mut out = String::from(
        "== Scenarios (c): heterogeneous 2-engine platforms (equal mean overheads) ==\n\
         \x20   platform      util   gcaps_susp sched   miss(gcaps DES)\n",
    );
    for (name, util, ys, miss) in rows {
        out.push_str(&format!(
            "    {name:<12}  {util:>4.1}        {:>6.2}          {miss:>7.4}\n",
            ys[k]
        ));
    }
    out
}

// ---------------------------------------------------------------------
// (d) overload survival: overrun ramp × deadline-miss action
// ---------------------------------------------------------------------

/// WCET multipliers (percent) of the injected ramp; 100 is the
/// fault-free control row.
pub const OVERRUN_PCTS: [u32; 3] = [100, 200, 300];

/// The generator knobs for the overload and adaptive sweeps — the same
/// expression as the edfvfp (0.6, 0.4) point, so the memoized tasksets
/// are shared with that sweep.
pub fn overload_params() -> GenParams {
    edfvfp_params(0.6, 0.4)
}

/// The ramp window: the middle third of the DES horizon, so every run
/// has a clean pre-fault prefix and a post-fault recovery suffix.
pub fn ramp_window() -> (Time, Time) {
    let third = ms(DES_HORIZON_MS) / 3;
    (third, 2 * third)
}

/// Pooled per-run overload observations (RT tasks only).
#[derive(Debug, Clone, Default)]
struct OverloadCell {
    misses: u64,
    jobs: u64,
    aborted: u64,
    /// Tardiness (ms) of every completed RT job.
    tardy_ms: Vec<f64>,
    /// µs past the ramp's end of the last miss/abort (0 = quiet or
    /// recovered before the ramp ended).
    recovery_us: Time,
    /// Adaptive RR↔EDF switches performed.
    switches: u64,
}

/// One DES run under an overrun ramp; `pct == 100` runs with an empty
/// fault plan (pinned bit-identical to the no-fault baseline).
fn overload_run(
    ts: &crate::model::TaskSet,
    policy: Policy,
    action: DeadlineMissAction,
    pct: u32,
    adaptive: Option<AdaptivePolicy>,
) -> OverloadCell {
    let (start, end) = ramp_window();
    let mut cfg = SimConfig::new(policy, ms(DES_HORIZON_MS));
    if pct != 100 {
        cfg = cfg.with_faults(FaultPlan::ramp(ts, start, end, pct, pct));
    }
    if action != DeadlineMissAction::Log {
        cfg = cfg.with_miss_actions(vec![action; ts.tasks.len()]);
    }
    if let Some(a) = adaptive {
        cfg = cfg.with_adaptive(a);
    }
    let res = simulate(ts, &cfg);
    let mut cell = OverloadCell::default();
    for t in ts.rt_tasks() {
        let m = &res.per_task[t.id];
        cell.misses += m.deadline_misses;
        cell.jobs += m.jobs;
        cell.aborted += m.aborted;
        cell.tardy_ms.extend(m.tardiness(t.deadline).iter().map(|&x| x as f64 / 1000.0));
    }
    cell.recovery_us = res.run.last_tardy.saturating_sub(end);
    cell.switches = res.run.policy_switches;
    cell
}

/// One overload result row (policy fixed at GCAPS — the preemptive
/// core the miss actions are designed around).
#[derive(Debug, Clone, PartialEq)]
pub struct OverloadRow {
    pub overrun_pct: u32,
    pub action: DeadlineMissAction,
    /// (misses + aborts) / (completed + aborted) over RT jobs.
    pub miss_ratio: f64,
    pub tardy_p50_ms: f64,
    pub tardy_p99_ms: f64,
    /// aborted / (completed + aborted) over RT jobs.
    pub abort_ratio: f64,
    /// Worst per-replica time past the ramp's end of the last
    /// miss/abort (ms).
    pub recovery_ms: f64,
}

fn fold_cells(
    slice: &[Option<OverloadCell>],
) -> (u64, u64, u64, Vec<f64>, Time, u64) {
    let (mut m, mut j, mut a, mut rec, mut sw) = (0u64, 0u64, 0u64, 0u64, 0u64);
    let mut tardy = Vec::new();
    for cell in slice.iter().flatten() {
        m += cell.misses;
        j += cell.jobs;
        a += cell.aborted;
        tardy.extend_from_slice(&cell.tardy_ms);
        rec = rec.max(cell.recovery_us);
        sw += cell.switches;
    }
    (m, j, a, tardy, rec, sw)
}

/// Sweep (d): GCAPS DES under the overrun ramp, every overrun level ×
/// every miss action. DES replicas are capped at [`MAX_SIM_TASKSETS`].
pub fn overload_sweep(cfg: &ExpConfig) -> Vec<OverloadRow> {
    let points: Vec<(u32, DeadlineMissAction)> = OVERRUN_PCTS
        .iter()
        .flat_map(|&pct| DeadlineMissAction::ALL.iter().map(move |&a| (pct, a)))
        .collect();
    let n_sim = cfg.tasksets.min(MAX_SIM_TASKSETS);
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<Option<OverloadCell>> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            let (pct, action) = points[pi];
            (ti < n_sim).then(|| {
                let ts = memo::taskset(seed, &overload_params(), ti);
                overload_run(&ts, Policy::Gcaps, action, pct, None)
            })
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pi, &(pct, action))| {
            let (m, j, a, mut tardy, rec, _) = fold_cells(&per_cell[pi * n..(pi + 1) * n]);
            let done = (j + a).max(1) as f64;
            OverloadRow {
                overrun_pct: pct,
                action,
                miss_ratio: (m + a) as f64 / done,
                tardy_p50_ms: percentile(&mut tardy, 50.0).unwrap_or(0.0),
                tardy_p99_ms: percentile(&mut tardy, 99.0).unwrap_or(0.0),
                abort_ratio: a as f64 / done,
                recovery_ms: rec as f64 / 1000.0,
            }
        })
        .collect()
}

/// Format sweep (d) as its CSV.
pub fn overload_csv(rows: &[OverloadRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "overrun_pct",
        "miss_action",
        "miss_ratio",
        "tardiness_p50_ms",
        "tardiness_p99_ms",
        "abort_ratio",
        "recovery_ms",
    ]);
    for r in rows {
        csv.row(vec![
            r.overrun_pct.to_string(),
            r.action.label().to_string(),
            format!("{:.5}", r.miss_ratio),
            format!("{:.3}", r.tardy_p50_ms),
            format!("{:.3}", r.tardy_p99_ms),
            format!("{:.5}", r.abort_ratio),
            format!("{:.3}", r.recovery_ms),
        ]);
    }
    csv
}

fn overload_report(rows: &[OverloadRow]) -> String {
    let mut out = String::from(
        "== Scenarios (d): overload survival (gcaps DES, WCET ramp over the \
         middle third) ==\n\
         \x20   wcet%  action   miss    tardy p99   abort    recovery\n",
    );
    for r in rows {
        out.push_str(&format!(
            "    {:>4}%  {:<6}  {:>6.4}  {:>8.2}ms  {:>6.4}  {:>8.2}ms\n",
            r.overrun_pct,
            r.action.label(),
            r.miss_ratio,
            r.tardy_p99_ms,
            r.abort_ratio,
            r.recovery_ms
        ));
    }
    out
}

// ---------------------------------------------------------------------
// (e) load-adaptive RR↔EDF switching under the same ramp
// ---------------------------------------------------------------------

/// The compared execution modes: both fixed endpoints plus the governor.
pub const ADAPTIVE_MODES: [&str; 3] = ["rr_fixed", "edf_fixed", "adaptive"];

/// One adaptive result row.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptiveRow {
    pub mode: &'static str,
    pub overrun_pct: u32,
    pub miss_ratio: f64,
    pub tardy_p99_ms: f64,
    /// Total governor switches across the point's replicas (always 0
    /// for the fixed modes).
    pub policy_switches: u64,
    pub recovery_ms: f64,
}

/// Sweep (e): fixed RR vs fixed EDF vs the adaptive governor at every
/// overrun level of the ramp.
pub fn adaptive_sweep(cfg: &ExpConfig) -> Vec<AdaptiveRow> {
    let points: Vec<(usize, u32)> = (0..ADAPTIVE_MODES.len())
        .flat_map(|mi| OVERRUN_PCTS.iter().map(move |&pct| (mi, pct)))
        .collect();
    let n_sim = cfg.tasksets.min(MAX_SIM_TASKSETS);
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<Option<OverloadCell>> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            let (mi, pct) = points[pi];
            (ti < n_sim).then(|| {
                let ts = memo::taskset(seed, &overload_params(), ti);
                let (policy, adaptive) = match ADAPTIVE_MODES[mi] {
                    "rr_fixed" => (Policy::TsgRr, None),
                    "edf_fixed" => (Policy::GcapsEdf, None),
                    _ => (Policy::TsgRr, Some(AdaptivePolicy::default())),
                };
                overload_run(&ts, policy, DeadlineMissAction::Log, pct, adaptive)
            })
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pi, &(mi, pct))| {
            let (m, j, a, mut tardy, rec, sw) = fold_cells(&per_cell[pi * n..(pi + 1) * n]);
            AdaptiveRow {
                mode: ADAPTIVE_MODES[mi],
                overrun_pct: pct,
                miss_ratio: (m + a) as f64 / (j + a).max(1) as f64,
                tardy_p99_ms: percentile(&mut tardy, 99.0).unwrap_or(0.0),
                policy_switches: sw,
                recovery_ms: rec as f64 / 1000.0,
            }
        })
        .collect()
}

/// Format sweep (e) as its CSV.
pub fn adaptive_csv(rows: &[AdaptiveRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "mode",
        "overrun_pct",
        "miss_ratio",
        "tardiness_p99_ms",
        "policy_switches",
        "recovery_ms",
    ]);
    for r in rows {
        csv.row(vec![
            r.mode.to_string(),
            r.overrun_pct.to_string(),
            format!("{:.5}", r.miss_ratio),
            format!("{:.3}", r.tardy_p99_ms),
            r.policy_switches.to_string(),
            format!("{:.3}", r.recovery_ms),
        ]);
    }
    csv
}

fn adaptive_report(rows: &[AdaptiveRow]) -> String {
    let mut out = String::from(
        "== Scenarios (e): load-adaptive RR<->EDF governor under the ramp ==\n\
         \x20   mode       wcet%   miss    tardy p99   switches   recovery\n",
    );
    for r in rows {
        out.push_str(&format!(
            "    {:<9}  {:>4}%  {:>6.4}  {:>8.2}ms  {:>8}  {:>8.2}ms\n",
            r.mode, r.overrun_pct, r.miss_ratio, r.tardy_p99_ms, r.policy_switches, r.recovery_ms
        ));
    }
    out
}

// ---------------------------------------------------------------------
// (f) fine-grain co-running: serial vs fractional SM model
// ---------------------------------------------------------------------

pub const FINEGRAIN_UTILS: [f64; 3] = [0.4, 0.5, 0.6];
pub const FINEGRAIN_GPU_RATIOS: [f64; 2] = [0.4, 0.6];

/// The compared per-segment SM-fraction bands. `serial` is the control
/// (the whole-context model — the fine analysis and DES are pinned
/// bit-identical to the serial ones there); the others draw each GPU
/// segment's fraction uniformly from the band, so `small` makes most
/// hp/lp pairs co-runnable while `wide` mixes co-runnable and
/// engine-filling segments.
pub const FINEGRAIN_BANDS: [(&str, (u32, u32)); 3] =
    [("serial", (100, 100)), ("wide", (25, 75)), ("small", (20, 45))];

/// One fine-grain result row.
#[derive(Debug, Clone, PartialEq)]
pub struct FineGrainRow {
    pub band: &'static str,
    pub util: f64,
    pub gpu_ratio: f64,
    /// GCAPS (self-suspending) acceptance with the serial whole-context
    /// charge — fractions present but charged as full serialization.
    pub sched_serial: f64,
    /// Acceptance with the fine-grain inflation charge
    /// ([`crate::analysis::gcaps::analyze_fine`]) on the same tasksets.
    pub sched_fine: f64,
    /// Simulated RT deadline-miss ratio under the gcaps DES (which
    /// co-runs fractional segments whenever they fit).
    pub miss_des: f64,
}

/// The generator knobs for one (band, utilization, GPU-ratio) point
/// (shared with the test anchors; see [`edfvfp_params`]).
pub fn finegrain_params(util: f64, gpu_ratio: f64, par: (u32, u32)) -> GenParams {
    GenParams {
        util_per_cpu: (util - 0.05, util + 0.05),
        gpu_task_ratio: (gpu_ratio, gpu_ratio),
        par_range: par,
        ..GenParams::default()
    }
}

/// Sweep (f): serial vs fine-grain acceptance plus the gcaps DES miss
/// ratio at every band × utilization × GPU-ratio point. The serial and
/// fine analyses run on the *same* memoized tasksets, so the acceptance
/// delta is paired; DES replicas are capped at [`MAX_SIM_TASKSETS`].
pub fn finegrain_sweep(cfg: &ExpConfig) -> Vec<FineGrainRow> {
    use crate::analysis::gcaps;
    let points: Vec<(usize, f64, f64)> = (0..FINEGRAIN_BANDS.len())
        .flat_map(|bi| {
            FINEGRAIN_UTILS.iter().flat_map(move |&u| {
                FINEGRAIN_GPU_RATIOS.iter().map(move |&r| (bi, u, r))
            })
        })
        .collect();
    let n_sim = cfg.tasksets.min(MAX_SIM_TASKSETS);
    let cells = sweep::grid2(points.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<(bool, bool, Option<(u64, u64)>)> =
        sweep::run(&cfg.sweep(), cells, |_, &(pi, ti)| {
            let (bi, util, ratio) = points[pi];
            let p = finegrain_params(util, ratio, FINEGRAIN_BANDS[bi].1);
            let ts = memo::taskset(seed, &p, ti);
            let serial = gcaps::analyze(&ts, false, &gcaps::Options::default());
            let fine = gcaps::analyze_fine(&ts, false);
            let sim = (ti < n_sim).then(|| rt_misses(&ts, Policy::Gcaps));
            (serial.schedulable, fine.schedulable, sim)
        });
    let n = cfg.tasksets;
    points
        .iter()
        .enumerate()
        .map(|(pi, &(bi, util, gpu_ratio))| {
            let slice = &per_cell[pi * n..(pi + 1) * n];
            let sched_serial =
                slice.iter().filter(|&&(s, _, _)| s).count() as f64 / n.max(1) as f64;
            let sched_fine =
                slice.iter().filter(|&&(_, f, _)| f).count() as f64 / n.max(1) as f64;
            let (mut misses, mut jobs) = (0u64, 0u64);
            for &(_, _, sim) in slice {
                if let Some((m, j)) = sim {
                    misses += m;
                    jobs += j;
                }
            }
            FineGrainRow {
                band: FINEGRAIN_BANDS[bi].0,
                util,
                gpu_ratio,
                sched_serial,
                sched_fine,
                miss_des: misses as f64 / jobs.max(1) as f64,
            }
        })
        .collect()
}

/// Format sweep (f) as its CSV.
pub fn finegrain_csv(rows: &[FineGrainRow]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "par_band",
        "par_lo",
        "par_hi",
        "util_per_cpu",
        "gpu_task_ratio",
        "gcaps_serial_sched_ratio",
        "gcaps_fine_sched_ratio",
        "miss_ratio_gcaps_des",
    ]);
    for r in rows {
        let (lo, hi) = FINEGRAIN_BANDS
            .iter()
            .find(|(name, _)| *name == r.band)
            .map(|&(_, band)| band)
            .unwrap();
        csv.row(vec![
            r.band.to_string(),
            lo.to_string(),
            hi.to_string(),
            format!("{:.1}", r.util),
            format!("{:.1}", r.gpu_ratio),
            format!("{:.4}", r.sched_serial),
            format!("{:.4}", r.sched_fine),
            format!("{:.5}", r.miss_des),
        ]);
    }
    csv
}

fn finegrain_report(rows: &[FineGrainRow]) -> String {
    let mut out = String::from(
        "== Scenarios (f): fine-grain co-running vs serial whole-context ==\n\
         \x20   band     util  gpu%   sched(serial)  sched(fine)   miss(DES)\n",
    );
    for r in rows {
        out.push_str(&format!(
            "    {:<7}  {:>4.1}  {:>3.0}%       {:>6.2}       {:>6.2}     {:>7.4}\n",
            r.band,
            r.util,
            r.gpu_ratio * 100.0,
            r.sched_serial,
            r.sched_fine,
            r.miss_des
        ));
    }
    out
}

// ---------------------------------------------------------------------
// driver
// ---------------------------------------------------------------------

fn only_value_ok(v: &str) -> bool {
    SCENARIOS.contains(&v)
}

/// Registry face: `gcaps exp scenarios [--only <sub-sweep>]` — all
/// five sub-sweeps (see [`SCENARIOS`]) when none is selected, one
/// table each (`scenarios_<name>`).
pub struct ScenariosExp;

impl Experiment for ScenariosExp {
    fn name(&self) -> &'static str {
        "scenarios"
    }

    fn about(&self) -> &'static str {
        "Beyond-the-paper sweeps: eps x theta, EDF vs FP, hetero GPUs, overload"
    }

    fn flags(&self) -> &'static [FlagSpec] {
        static FLAGS: [FlagSpec; 1] = [FlagSpec {
            name: "only",
            values: "epstheta|edfvfp|hetero|overload|adaptive|finegrain",
            check: only_value_ok,
        }];
        &FLAGS
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let only = cfg.opts.get("only");
        let selected = |name: &str| only.is_none_or(|o| o == name);
        if selected("epstheta") {
            let rows = epstheta_sweep(cfg);
            sink.table("scenarios_epstheta", &epstheta_csv(&rows));
            sink.text(&epstheta_report(&rows));
        }
        if selected("edfvfp") {
            let rows = edfvfp_sweep(cfg);
            sink.table("scenarios_edfvfp", &edfvfp_csv(&rows));
            sink.text(&edfvfp_report(&rows));
        }
        if selected("hetero") {
            let rows = hetero_sweep(cfg);
            sink.table("scenarios_hetero", &hetero_csv(&rows));
            sink.text(&hetero_report(&rows));
        }
        if selected("overload") {
            let rows = overload_sweep(cfg);
            sink.table("scenarios_overload", &overload_csv(&rows));
            sink.text(&overload_report(&rows));
        }
        if selected("adaptive") {
            let rows = adaptive_sweep(cfg);
            sink.table("scenarios_adaptive", &adaptive_csv(&rows));
            sink.text(&adaptive_report(&rows));
        }
        if selected("finegrain") {
            let rows = finegrain_sweep(cfg);
            sink.table("scenarios_finegrain", &finegrain_csv(&rows));
            sink.text(&finegrain_report(&rows));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> ExpConfig {
        ExpConfig { tasksets: 5, seed: 19, ..ExpConfig::default() }
    }

    #[test]
    fn epstheta_grid_shape_and_ranges() {
        let rows = epstheta_sweep(&tiny());
        assert_eq!(
            rows.len(),
            config::GPU_PROFILES.len() * EPS_FACTORS.len() * THETA_FACTORS.len()
        );
        for ((board, ctx), ys) in &rows {
            assert!(
                ctx.epsilon >= ctx.theta,
                "{board}: grid cell ε {} < θ {} (α would clamp)",
                ctx.epsilon,
                ctx.theta
            );
            for &y in ys {
                assert!((0.0..=1.0).contains(&y), "{board}: ratio {y}");
            }
        }
    }

    #[test]
    fn epstheta_schedulability_declines_with_epsilon() {
        // Same memoized tasksets at every cell (uniform platforms of
        // identical slice), so growing ε alone can only remove
        // schedulable sets for the ε-sensitive gcaps analyses.
        let rows = epstheta_sweep(&tiny());
        let k = Approach::ALL
            .iter()
            .position(|a| *a == Approach::GcapsSuspend)
            .unwrap();
        let base = config::gpu_profile("xavier_nx").unwrap();
        let at = |fe: f64| {
            rows.iter()
                .find(|((b, c), _)| {
                    *b == "xavier_nx"
                        && c.epsilon == scale(base.epsilon, fe)
                        && c.theta == base.theta
                })
                .map(|(_, ys)| ys[k])
                .unwrap()
        };
        assert!(at(0.5) >= at(4.0), "gcaps ratio grew with ε: {} < {}", at(0.5), at(4.0));
    }

    #[test]
    fn edfvfp_rows_cover_the_grid() {
        let rows = edfvfp_sweep(&ExpConfig { tasksets: 3, ..tiny() });
        assert_eq!(rows.len(), EDF_UTILS.len() * EDF_GPU_RATIOS.len());
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.sched_fp));
            assert!((0.0..=1.0).contains(&r.miss_fp));
            assert!((0.0..=1.0).contains(&r.miss_edf));
        }
    }

    #[test]
    fn hetero_platforms_share_mean_overheads() {
        // The design premise of sweep (c): equal total overhead budget.
        for (name, p) in hetero_platforms() {
            assert_eq!(p.num_gpus(), 2, "{name}");
            let eps: u64 = p.gpus.iter().map(|g| g.epsilon).sum();
            let theta: u64 = p.gpus.iter().map(|g| g.theta).sum();
            assert_eq!(eps, 2000, "{name}: mean ε moved");
            assert_eq!(theta, 400, "{name}: mean θ moved");
            for g in &p.gpus {
                assert!(g.epsilon >= g.theta, "{name}: ε < θ");
            }
        }
        assert!(!hetero_platforms()[2].1.is_uniform());
    }

    #[test]
    fn hetero_sweep_rows_cover_the_grid() {
        let rows = hetero_sweep(&ExpConfig { tasksets: 3, ..tiny() });
        assert_eq!(rows.len(), hetero_platforms().len() * HETERO_UTILS.len());
        for (_, _, ys, miss) in &rows {
            for &y in ys {
                assert!((0.0..=1.0).contains(&y));
            }
            assert!((0.0..=1.0).contains(miss));
        }
    }

    #[test]
    fn overload_rows_cover_the_grid_and_stress_shows() {
        let rows = overload_sweep(&tiny());
        assert_eq!(rows.len(), OVERRUN_PCTS.len() * DeadlineMissAction::ALL.len());
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.miss_ratio), "{r:?}");
            assert!((0.0..=1.0).contains(&r.abort_ratio), "{r:?}");
            assert!(r.tardy_p99_ms >= r.tardy_p50_ms, "{r:?}");
            assert!(r.recovery_ms >= 0.0 && r.recovery_ms.is_finite(), "{r:?}");
        }
        let at = |pct: u32, a: DeadlineMissAction| {
            rows.iter().find(|r| r.overrun_pct == pct && r.action == a).unwrap()
        };
        // A 3x WCET ramp on ~0.6 utilization must hurt: some overload
        // symptom (late or aborted jobs) appears at 300%, and the Log
        // rows degrade monotonically with the overrun level.
        let worst = at(300, DeadlineMissAction::Log);
        assert!(
            worst.miss_ratio >= at(100, DeadlineMissAction::Log).miss_ratio,
            "ramp reduced the miss ratio"
        );
        assert!(rows.iter().any(|r| r.miss_ratio > 0.0), "no overload symptom at any cell");
        // Aborting actions are the only source of aborts; Log never aborts.
        assert_eq!(at(300, DeadlineMissAction::Log).abort_ratio, 0.0);
        assert_eq!(at(100, DeadlineMissAction::Boost).abort_ratio, 0.0);
    }

    #[test]
    fn adaptive_rows_cover_the_grid_and_fixed_modes_never_switch() {
        let rows = adaptive_sweep(&tiny());
        assert_eq!(rows.len(), ADAPTIVE_MODES.len() * OVERRUN_PCTS.len());
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.miss_ratio), "{r:?}");
            assert!(r.tardy_p99_ms >= 0.0 && r.tardy_p99_ms.is_finite(), "{r:?}");
            if r.mode != "adaptive" {
                assert_eq!(r.policy_switches, 0, "{r:?}: fixed mode switched policy");
            }
        }
    }

    #[test]
    fn finegrain_rows_cover_the_grid_and_serial_band_pairs_exactly() {
        let rows = finegrain_sweep(&ExpConfig { tasksets: 3, ..tiny() });
        assert_eq!(
            rows.len(),
            FINEGRAIN_BANDS.len() * FINEGRAIN_UTILS.len() * FINEGRAIN_GPU_RATIOS.len()
        );
        for r in &rows {
            assert!((0.0..=1.0).contains(&r.sched_serial), "{r:?}");
            assert!((0.0..=1.0).contains(&r.sched_fine), "{r:?}");
            assert!((0.0..=1.0).contains(&r.miss_des), "{r:?}");
            // The fine charge never exceeds the serial one, so paired
            // acceptance can only gain tasksets.
            assert!(r.sched_fine >= r.sched_serial, "{r:?}");
            // On the serial control band the two analyses are pinned
            // bit-identical — the acceptance ratios must agree exactly.
            if r.band == "serial" {
                assert_eq!(r.sched_serial, r.sched_fine, "{r:?}");
            }
        }
    }

    #[test]
    fn only_filter_selects_a_single_sub_sweep() {
        use crate::experiments::registry::{self, Experiment};
        use crate::experiments::sink::NullSink;
        let cfg = ExpConfig {
            tasksets: 2,
            opts: crate::experiments::Opts::default().set("only", "epstheta"),
            ..tiny()
        };
        let report = registry::run(&ScenariosExp, &cfg, &mut NullSink).unwrap();
        let names: Vec<&str> = report.tables.iter().map(|t| t.name.as_str()).collect();
        assert_eq!(names, vec!["scenarios_epstheta"]);
        assert_eq!(ScenariosExp.flags().len(), 1);
    }
}
