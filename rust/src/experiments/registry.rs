//! The first-class [`Experiment`] trait and its static registry — the
//! experiment-layer analog of the `Analysis` trait (PR 2): every paper
//! figure/table and every beyond-the-paper sweep is one registered
//! trait object, dispatched generically by the CLI (`gcaps exp <name>`,
//! `gcaps exp --list`, `gcaps exp all`) and by the library facade
//! [`crate::api`].
//!
//! An experiment declares its stable `name`, a one-line `about`, and
//! the extra flags it accepts beyond the common scale knobs
//! ([`FlagSpec`] — the registry validates option names AND values
//! before dispatch, so a typo like `--panle a` or `--panel z` is a
//! usage error, never a silent default run). Its `run` emits typed
//! tables and ASCII blocks into a caller-supplied
//! [`Sink`](crate::experiments::sink::Sink); [`run`] wraps dispatch
//! with bookkeeping and returns an [`ExpReport`] — structured table
//! stats, written output paths, and wall-clock.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::err;
use crate::experiments::sink::Sink;
use crate::experiments::{
    ablation, casestudy, examples_figs, fig8, fig9, multigpu, overhead, scenarios, ExpConfig,
};
use crate::util::csv::CsvTable;
use crate::util::error::Result;

/// One extra flag accepted by an experiment (beyond the common
/// `--tasksets/--seed/--jobs/--format`).
#[derive(Debug, Clone, Copy)]
pub struct FlagSpec {
    /// Flag name without the `--` prefix.
    pub name: &'static str,
    /// Human-readable accepted values, e.g. `"a..f"` — shown by
    /// `gcaps exp --list` and embedded in rejection messages.
    pub values: &'static str,
    /// Value validator, applied before dispatch.
    pub check: fn(&str) -> bool,
}

/// A first-class experiment harness.
pub trait Experiment: Sync {
    /// Stable CLI / registry name (`gcaps exp <name>`).
    fn name(&self) -> &'static str;

    /// One-line description for `gcaps exp --list`.
    fn about(&self) -> &'static str;

    /// Extra flags this experiment accepts (validated by [`validate`]).
    fn flags(&self) -> &'static [FlagSpec] {
        &[]
    }

    /// Whether `gcaps exp all` includes this experiment (false for the
    /// single figures an aggregate like `examples` already covers).
    fn in_all(&self) -> bool {
        true
    }

    /// Run at the given scale, emitting every typed table and ASCII
    /// block into `sink` exactly once. Use [`run`] for dispatch with
    /// validation, timing and the structured [`ExpReport`].
    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()>;
}

/// Registry order = `--list` order; the `in_all` subset, in this
/// order, is the canonical `gcaps exp all` sequence.
static EXPERIMENTS: [&dyn Experiment; 15] = [
    &examples_figs::Fig3Exp,
    &examples_figs::Fig5Exp,
    &examples_figs::Fig6Exp,
    &examples_figs::Fig7Exp,
    &examples_figs::ExamplesExp,
    &fig8::Fig8Exp,
    &fig9::Fig9Exp,
    &casestudy::Fig10Exp,
    &casestudy::Fig11Exp,
    &casestudy::Table5Exp,
    &overhead::Fig12Exp,
    &overhead::Fig13Exp,
    &ablation::AblationExp,
    &multigpu::MultigpuExp,
    &scenarios::ScenariosExp,
];

/// All registered experiments, in `--list` order.
pub fn all() -> &'static [&'static dyn Experiment] {
    &EXPERIMENTS
}

/// Look an experiment up by its stable name.
pub fn find(name: &str) -> Option<&'static dyn Experiment> {
    EXPERIMENTS.iter().copied().find(|e| e.name() == name)
}

/// The `gcaps exp all` subset, in canonical order.
pub fn all_set() -> Vec<&'static dyn Experiment> {
    EXPERIMENTS.iter().copied().filter(|e| e.in_all()).collect()
}

/// Shape of one emitted table (stable schema per experiment).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TableStat {
    /// Artifact stem (`results/<name>.csv` / `.jsonl`).
    pub name: String,
    /// Column schema, in emission order.
    pub columns: Vec<String>,
    /// Data rows emitted (header excluded).
    pub rows: usize,
}

/// Structured result of one registry dispatch.
#[derive(Debug, Clone)]
pub struct ExpReport {
    /// The experiment's registry name.
    pub name: &'static str,
    /// Every table emitted, in emission order.
    pub tables: Vec<TableStat>,
    /// Files written by the sinks (CSV/JSONL), in emission order.
    pub outputs: Vec<PathBuf>,
    /// Wall-clock of the `run` call (sweep + sink emission).
    pub wall: Duration,
    /// The collected ASCII report, when an ASCII sink was requested
    /// (filled by [`crate::api::run`]; empty otherwise).
    pub ascii: String,
}

impl ExpReport {
    /// Total data rows across all emitted tables.
    pub fn rows(&self) -> usize {
        self.tables.iter().map(|t| t.rows).sum()
    }
}

/// Validate `cfg.opts` against the experiment's declared flags:
/// unknown option names and invalid values are usage errors (the CLI
/// maps them to exit status 2).
pub fn validate(exp: &dyn Experiment, cfg: &ExpConfig) -> Result<()> {
    for (name, value) in cfg.opts.iter() {
        match exp.flags().iter().find(|f| f.name == name) {
            None => {
                return Err(err!(
                    "unknown option {name:?} for experiment {} (accepted: {})",
                    exp.name(),
                    if exp.flags().is_empty() {
                        "none".to_string()
                    } else {
                        exp.flags()
                            .iter()
                            .map(|f| format!("--{}", f.name))
                            .collect::<Vec<_>>()
                            .join(", ")
                    }
                ))
            }
            Some(f) => {
                if !(f.check)(value) {
                    return Err(err!(
                        "invalid value {value:?} for --{name} (expected {})",
                        f.values
                    ));
                }
            }
        }
    }
    Ok(())
}

/// Counting wrapper: forwards to the caller's sink while tallying the
/// per-table stats for the [`ExpReport`].
struct Recorder<'a> {
    inner: &'a mut dyn Sink,
    tables: Vec<TableStat>,
}

impl Sink for Recorder<'_> {
    fn table(&mut self, name: &str, table: &CsvTable) {
        self.tables.push(TableStat {
            name: name.to_string(),
            columns: table.header.clone(),
            rows: table.rows.len(),
        });
        self.inner.table(name, table);
    }

    fn text(&mut self, text: &str) {
        self.inner.text(text);
    }
}

/// Dispatch one experiment: validate its options, run it against
/// `sink`, finish the sink, and return the structured report.
pub fn run(exp: &dyn Experiment, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<ExpReport> {
    validate(exp, cfg)?;
    let start = Instant::now(); // gcaps-lint: allow(wall-clock) -- report wall time
    let mut rec = Recorder { inner: &mut *sink, tables: Vec::new() };
    exp.run(cfg, &mut rec)?;
    let tables = rec.tables;
    let outputs = sink.finish()?;
    Ok(ExpReport {
        name: exp.name(),
        tables,
        outputs,
        wall: start.elapsed(),
        ascii: String::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::experiments::sink::NullSink;
    use crate::experiments::Opts;

    #[test]
    fn registry_names_are_unique_and_complete() {
        let names: Vec<&str> = all().iter().map(|e| e.name()).collect();
        assert_eq!(
            names,
            vec![
                "fig3", "fig5", "fig6", "fig7", "examples", "fig8", "fig9", "fig10",
                "fig11", "table5", "fig12", "fig13", "ablation", "multigpu", "scenarios",
            ]
        );
        for n in &names {
            assert!(find(n).is_some(), "{n} not findable");
        }
        assert!(find("nope").is_none());
    }

    #[test]
    fn all_set_matches_the_legacy_exp_all_sequence() {
        let names: Vec<&str> = all_set().iter().map(|e| e.name()).collect();
        assert_eq!(
            names,
            vec![
                "examples", "fig8", "fig9", "fig10", "fig11", "table5", "fig12", "fig13",
                "ablation", "multigpu", "scenarios",
            ]
        );
    }

    #[test]
    fn unknown_option_is_rejected() {
        let exp = find("fig8").unwrap();
        let cfg = ExpConfig {
            opts: Opts::default().set("panle", "a"),
            ..ExpConfig::default()
        };
        let e = run(exp, &cfg, &mut NullSink).unwrap_err().to_string();
        assert!(e.contains("panle") && e.contains("fig8"), "{e}");
    }

    #[test]
    fn invalid_option_value_is_rejected() {
        let exp = find("fig8").unwrap();
        let cfg = ExpConfig {
            opts: Opts::default().set("panel", "z"),
            ..ExpConfig::default()
        };
        let e = run(exp, &cfg, &mut NullSink).unwrap_err().to_string();
        assert!(e.contains("--panel") && e.contains("a..f"), "{e}");
    }

    #[test]
    fn report_counts_tables_rows_and_wall_clock() {
        let exp = find("fig9").unwrap();
        let cfg = ExpConfig { tasksets: 2, seed: 5, ..ExpConfig::default() };
        let report = run(exp, &cfg, &mut NullSink).unwrap();
        assert_eq!(report.name, "fig9");
        assert_eq!(report.tables.len(), 1);
        assert_eq!(report.tables[0].name, "fig9");
        assert_eq!(report.tables[0].rows, 4 * 5, "4 series × 5 utilization points");
        assert_eq!(report.rows(), 20);
        assert!(report.outputs.is_empty(), "NullSink writes nothing");
    }
}
