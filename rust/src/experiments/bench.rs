//! `gcaps bench` — the repo's tracked wall-clock performance baseline.
//!
//! Times two pinned workloads with `std::time::Instant` (no external
//! deps) and writes machine-readable artifacts:
//!
//! - **RTA panel** (`BENCH_rta.json`): the Fig. 8b utilization panel —
//!   6 sweep points × N tasksets × 9 analyses plus the Audsley retry —
//!   at `--jobs 1`, i.e. the raw single-thread analysis kernel cost
//!   that PR 1's sharding multiplies across workers.
//! - **DES panel** (`BENCH_des.json`): all 6 simulator policies over N
//!   pinned Table 3 tasksets at a fixed horizon — the event-calendar
//!   engine's cost.
//!
//! Both are fully pinned (seed 2024, fixed panel/params/horizon) so
//! successive runs on one machine are comparable; the JSON carries a
//! result checksum so a "fast" run that silently computed different
//! numbers is caught. `--quick` shrinks the workload for CI smoke runs
//! (artifact shape identical; timings advisory on shared runners).
//!
//! EXPERIMENTS.md §Performance records the measurement protocol and the
//! before/after numbers for each optimisation PR.

use std::path::Path;
use std::time::Instant;

use crate::experiments::fig8::{run_panel, Panel};
use crate::experiments::ExpConfig;
use crate::model::ms;
use crate::sim::{simulate, Policy, SimConfig};
use crate::taskgen::{generate, GenParams};
use crate::util::rng::Pcg32;

/// The pinned base seed of both panels.
pub const BENCH_SEED: u64 = 2024;

/// One timed workload.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Workload id (stable across PRs — the perf trajectory key).
    pub bench: &'static str,
    /// Artifact schema version.
    pub schema: u32,
    /// Whether this was a `--quick` (CI smoke) run.
    pub quick: bool,
    /// Worker threads used (RTA panel is pinned to 1).
    pub jobs: usize,
    /// Work units completed (RTA: analysis cells; DES: simulations).
    pub units: u64,
    /// Wall-clock time for the whole workload.
    pub wall_ms: f64,
    /// Throughput derived from the two above.
    pub units_per_s: f64,
    /// Result checksum: identical across machines for one code version;
    /// a changed checksum means the timing compares different work.
    pub checksum: f64,
}

impl BenchResult {
    /// Hand-rolled JSON (fixed keys, numeric values — nothing to
    /// escape; the crate is dependency-free by design).
    pub fn to_json(&self) -> String {
        format!(
            "{{\n  \"bench\": \"{}\",\n  \"schema\": {},\n  \"quick\": {},\n  \
             \"jobs\": {},\n  \"seed\": {},\n  \"units\": {},\n  \
             \"wall_ms\": {:.3},\n  \"units_per_s\": {:.3},\n  \"checksum\": {:.6}\n}}\n",
            self.bench,
            self.schema,
            self.quick,
            self.jobs,
            BENCH_SEED,
            self.units,
            self.wall_ms,
            self.units_per_s,
            self.checksum
        )
    }

    /// One-line human summary for the CLI.
    pub fn report(&self) -> String {
        format!(
            "bench {:<18} {:>8} units in {:>10.1} ms ({:>9.1} units/s, checksum {:.4})",
            self.bench, self.units, self.wall_ms, self.units_per_s, self.checksum
        )
    }
}

fn finish(
    bench: &'static str,
    quick: bool,
    jobs: usize,
    units: u64,
    start: Instant,
    checksum: f64,
) -> BenchResult {
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    BenchResult {
        bench,
        schema: 1,
        quick,
        jobs,
        units,
        wall_ms,
        units_per_s: units as f64 / (wall_ms / 1e3).max(1e-9),
        checksum,
    }
}

/// Time the pinned Fig. 8b RTA panel at `--jobs 1`.
pub fn run_rta(quick: bool) -> BenchResult {
    let tasksets = if quick { 8 } else { 100 };
    let cfg = ExpConfig { tasksets, seed: BENCH_SEED, jobs: 1, ..ExpConfig::default() };
    let panel = Panel::UtilPerCpu;
    let start = Instant::now(); // gcaps-lint: allow(wall-clock) -- bench measures wall time
    let (xticks, series) = run_panel(panel, &cfg);
    let units = (xticks.len() * tasksets) as u64; // cells (9 analyses each)
    let checksum: f64 = series.iter().flat_map(|(_, ys)| ys.iter()).sum();
    finish("rta_fig8_panel_b", quick, 1, units, start, checksum)
}

/// Time the pinned DES panel: all 6 policies over N Table 3 tasksets.
pub fn run_des(quick: bool) -> BenchResult {
    let (n_sets, horizon) = if quick { (4, ms(300.0)) } else { (16, ms(2000.0)) };
    let mut rng = Pcg32::seeded(BENCH_SEED);
    let sets: Vec<_> = (0..n_sets).map(|_| generate(&mut rng, &GenParams::default())).collect();
    const POLICIES: [Policy; 6] = [
        Policy::Gcaps,
        Policy::GcapsEdf,
        Policy::TsgRr,
        Policy::Mpcp,
        Policy::FmlpPlus,
        Policy::Server,
    ];
    let start = Instant::now(); // gcaps-lint: allow(wall-clock) -- bench measures wall time
    let mut units = 0u64;
    let mut checksum = 0.0f64;
    for ts in &sets {
        for policy in POLICIES {
            let res = simulate(ts, &SimConfig::new(policy, horizon));
            units += 1;
            checksum += res.per_task.iter().map(|m| m.jobs as f64).sum::<f64>()
                + res.run.gpu_context_switches as f64;
        }
    }
    finish("des_all_policies", quick, 1, units, start, checksum)
}

/// Run both panels and write `BENCH_rta.json` / `BENCH_des.json` into
/// `out_dir`. Returns the two results (RTA first).
pub fn run_all(quick: bool, out_dir: &Path) -> std::io::Result<(BenchResult, BenchResult)> {
    let rta = run_rta(quick);
    let des = run_des(quick);
    std::fs::create_dir_all(out_dir)?;
    std::fs::write(out_dir.join("BENCH_rta.json"), rta.to_json())?;
    std::fs::write(out_dir.join("BENCH_des.json"), des.to_json())?;
    Ok((rta, des))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_rta_bench_runs_and_serializes() {
        let r = run_rta(true);
        assert_eq!(r.bench, "rta_fig8_panel_b");
        assert_eq!(r.units, 6 * 8); // 6 utilization points × 8 tasksets
        assert!(r.wall_ms >= 0.0 && r.units_per_s > 0.0);
        let json = r.to_json();
        let keys = [
            "\"bench\"",
            "\"schema\"",
            "\"units\"",
            "\"wall_ms\"",
            "\"units_per_s\"",
            "\"checksum\"",
        ];
        for key in keys {
            assert!(json.contains(key), "missing {key} in {json}");
        }
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'));
    }

    #[test]
    fn quick_des_bench_counts_all_policy_runs() {
        let r = run_des(true);
        assert_eq!(r.units, 4 * 6);
        assert!(r.checksum > 0.0, "simulations ran no jobs?");
    }

    #[test]
    fn bench_checksum_is_deterministic() {
        // Same pinned inputs → same checksum (the timing varies, the
        // work must not).
        let a = run_des(true);
        let b = run_des(true);
        assert_eq!(a.checksum, b.checksum);
        assert_eq!(a.units, b.units);
    }
}
