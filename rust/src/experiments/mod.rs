//! Experiment harnesses: one module per paper figure/table (see
//! DESIGN.md §4 for the index). Every harness writes a CSV under
//! `results/` and prints an ASCII rendition; EXPERIMENTS.md records the
//! paper-vs-measured comparison.

pub mod ablation;
pub mod bench;
pub mod casestudy;
pub mod examples_figs;
pub mod fig8;
pub mod fig9;
pub mod multigpu;
pub mod overhead;

use std::path::PathBuf;

/// Results directory: `$GCAPS_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var_os("GCAPS_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|| "results".into())
}

/// Shared experiment scale knobs (CLI-settable).
#[derive(Debug, Clone, Copy)]
pub struct ExpConfig {
    /// Tasksets per data point (paper: 1000).
    pub tasksets: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Sweep worker threads (`--jobs`; default: available parallelism).
    /// Results are byte-identical for every value — see `crate::sweep`.
    pub jobs: usize,
    /// Print sweep progress/throughput to stderr (CLI runs only).
    pub progress: bool,
}

impl Default for ExpConfig {
    fn default() -> ExpConfig {
        ExpConfig {
            tasksets: 200,
            seed: 2024,
            jobs: crate::sweep::available_jobs(),
            progress: false,
        }
    }
}

impl ExpConfig {
    /// The sweep-engine view of these knobs.
    pub fn sweep(&self) -> crate::sweep::SweepConfig {
        crate::sweep::SweepConfig { jobs: self.jobs, progress: self.progress }
    }
}
