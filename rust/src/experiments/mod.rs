//! Experiment harnesses: one module per paper figure/table (see
//! DESIGN.md §4 for the index). Every harness is a first-class
//! [`registry::Experiment`] emitting typed rows into pluggable
//! [`sink::Sink`]s (CSV under `results/`, JSONL, ASCII); EXPERIMENTS.md
//! records the paper-vs-measured comparison. Dispatch via
//! [`crate::api`] or `gcaps exp <name>`.

pub mod ablation;
pub mod bench;
pub mod casestudy;
pub mod examples_figs;
pub mod fig8;
pub mod fig9;
pub mod multigpu;
pub mod overhead;
pub mod registry;
pub mod scenarios;
pub mod sink;

use std::path::PathBuf;

use crate::analysis::{approach_schedulable, Approach};
use crate::model::WaitMode;
use crate::sweep::memo;
use crate::taskgen::GenParams;

/// Evaluate all analysis approaches on taskset `index` of `p`:
/// suspend + busy variants of the same memoized draws, with the §7.1.1
/// Audsley GPU-priority retry for the GCAPS rows. The shared per-cell
/// recipe of the Fig. 8 panels, the multi-GPU sweep and the scenario
/// sweeps — one definition so the harnesses cannot silently diverge.
/// Results are in `Approach::ALL` order; the array length tracks
/// `Approach::ALL` (new approaches are appended at the end, keeping
/// every CSV's leading columns byte-stable across releases).
pub fn approaches(seed: u64, p: &GenParams, index: usize) -> [bool; Approach::ALL.len()] {
    let susp = GenParams { mode: WaitMode::SelfSuspend, ..p.clone() };
    let busy = GenParams { mode: WaitMode::BusyWait, ..p.clone() };
    let suspend_ts = memo::taskset(seed, &susp, index);
    let busy_ts = memo::taskset(seed, &busy, index);
    let mut out = [false; Approach::ALL.len()];
    for (k, a) in Approach::ALL.iter().enumerate() {
        let ts = if a.is_busy() { &busy_ts } else { &suspend_ts };
        out[k] = approach_schedulable(ts, *a);
    }
    out
}

/// Results directory: `$GCAPS_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var_os("GCAPS_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|| "results".into())
}

/// Per-experiment option values (`--panel a` → `("panel", "a")`),
/// validated against the experiment's declared [`registry::FlagSpec`]s
/// before dispatch. Raw strings by design: each experiment parses its
/// own options, the registry guarantees the names and values are legal.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Opts(Vec<(String, String)>);

impl Opts {
    /// Builder-style insert (later values win on duplicate names).
    pub fn set(mut self, name: &str, value: &str) -> Opts {
        self.0.retain(|(n, _)| n != name);
        self.0.push((name.to_string(), value.to_string()));
        self
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.0.iter().find(|(n, _)| n == name).map(|(_, v)| v.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &str)> {
        self.0.iter().map(|(n, v)| (n.as_str(), v.as_str()))
    }

    pub fn is_empty(&self) -> bool {
        self.0.is_empty()
    }
}

/// Shared experiment scale knobs (CLI-settable).
#[derive(Debug, Clone)]
pub struct ExpConfig {
    /// Tasksets per data point (paper: 1000).
    pub tasksets: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Sweep worker threads (`--jobs`; default: available parallelism).
    /// Results are byte-identical for every value — see `crate::sweep`.
    pub jobs: usize,
    /// Print sweep progress/throughput to stderr (CLI runs only).
    pub progress: bool,
    /// Validated per-experiment options (`--panel`, `--board`, `--only`).
    pub opts: Opts,
}

impl Default for ExpConfig {
    fn default() -> ExpConfig {
        ExpConfig {
            tasksets: 200,
            seed: 2024,
            jobs: crate::sweep::available_jobs(),
            progress: false,
            opts: Opts::default(),
        }
    }
}

impl ExpConfig {
    /// The sweep-engine view of these knobs.
    pub fn sweep(&self) -> crate::sweep::SweepConfig {
        crate::sweep::SweepConfig { jobs: self.jobs, progress: self.progress }
    }
}
