//! Experiment harnesses: one module per paper figure/table (see
//! DESIGN.md §4 for the index). Every harness writes a CSV under
//! `results/` and prints an ASCII rendition; EXPERIMENTS.md records the
//! paper-vs-measured comparison.

pub mod ablation;
pub mod bench;
pub mod casestudy;
pub mod examples_figs;
pub mod fig8;
pub mod fig9;
pub mod multigpu;
pub mod overhead;
pub mod scenarios;

use std::path::PathBuf;

use crate::analysis::{approach_schedulable, Approach};
use crate::model::WaitMode;
use crate::sweep::memo;
use crate::taskgen::GenParams;

/// Evaluate the eight Fig. 8 approaches on taskset `index` of `p`:
/// suspend + busy variants of the same memoized draws, with the §7.1.1
/// Audsley GPU-priority retry for the GCAPS rows. The shared per-cell
/// recipe of the Fig. 8 panels, the multi-GPU sweep and the scenario
/// sweeps — one definition so the harnesses cannot silently diverge.
/// Results are in `Approach::ALL` order.
pub fn eight_approaches(seed: u64, p: &GenParams, index: usize) -> [bool; 8] {
    let susp = GenParams { mode: WaitMode::SelfSuspend, ..p.clone() };
    let busy = GenParams { mode: WaitMode::BusyWait, ..p.clone() };
    let suspend_ts = memo::taskset(seed, &susp, index);
    let busy_ts = memo::taskset(seed, &busy, index);
    let mut out = [false; 8];
    for (k, a) in Approach::ALL.iter().enumerate() {
        let ts = if a.is_busy() { &busy_ts } else { &suspend_ts };
        out[k] = approach_schedulable(ts, *a);
    }
    out
}

/// Results directory: `$GCAPS_RESULTS` or `./results`.
pub fn results_dir() -> PathBuf {
    std::env::var_os("GCAPS_RESULTS")
        .map(Into::into)
        .unwrap_or_else(|| "results".into())
}

/// Shared experiment scale knobs (CLI-settable).
#[derive(Debug, Clone, Copy)]
pub struct ExpConfig {
    /// Tasksets per data point (paper: 1000).
    pub tasksets: usize,
    /// Base RNG seed.
    pub seed: u64,
    /// Sweep worker threads (`--jobs`; default: available parallelism).
    /// Results are byte-identical for every value — see `crate::sweep`.
    pub jobs: usize,
    /// Print sweep progress/throughput to stderr (CLI runs only).
    pub progress: bool,
}

impl Default for ExpConfig {
    fn default() -> ExpConfig {
        ExpConfig {
            tasksets: 200,
            seed: 2024,
            jobs: crate::sweep::available_jobs(),
            progress: false,
        }
    }
}

impl ExpConfig {
    /// The sweep-engine view of these knobs.
    pub fn sweep(&self) -> crate::sweep::SweepConfig {
        crate::sweep::SweepConfig { jobs: self.jobs, progress: self.progress }
    }
}
