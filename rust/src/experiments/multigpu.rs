//! Multi-GPU platform sweep (Fig. 8-style, beyond the paper): the
//! schedulability of all nine approaches as the platform grows from
//! the paper's single GPU engine to g ∈ {1, 2, 4} engines, at Table 3
//! defaults. Tasks are spread over engines by the generator's WFD
//! assignment; per-engine interference sets mean every approach — not
//! just GCAPS — benefits from the extra engines, but by structurally
//! different amounts (the FIFO/priority-queue bounds shrink with the
//! per-engine requester count, the RR interleaving bound with the
//! per-engine ν).
//!
//! Dispatch goes through the first-class [`Analysis`] trait registry
//! (`Approach::analysis()`), with the §7.1.1 Audsley retry for the
//! GCAPS rows — the same procedure as the Fig. 8 panels, so g = 1
//! reproduces the fig8 default point exactly.

use crate::analysis::Approach;
use crate::experiments::registry::Experiment;
use crate::experiments::sink::Sink;
use crate::experiments::ExpConfig;
use crate::model::{Platform, WaitMode};
use crate::sweep;
use crate::taskgen::GenParams;
use crate::util::ascii::line_chart;
use crate::util::csv::CsvTable;
use crate::util::error::Result;

/// The swept GPU-engine counts.
pub const GPU_COUNTS: [usize; 3] = [1, 2, 4];

fn params_for(num_gpus: usize, mode: WaitMode) -> GenParams {
    GenParams {
        mode,
        platform: Platform::default().with_num_gpus(num_gpus),
        ..GenParams::default()
    }
}

/// Run the sweep; returns (xticks, per-approach schedulability series).
///
/// The grid is (GPU-count point × taskset index), sharded across the
/// sweep worker pool; each cell generates its suspend/busy taskset pair
/// once (memoized per engine count — see `memo::params_hash`) and
/// evaluates every approach on it.
pub fn run_sweep(cfg: &ExpConfig) -> (Vec<String>, Vec<(String, Vec<f64>)>) {
    let xticks: Vec<String> = GPU_COUNTS.iter().map(|g| g.to_string()).collect();
    let cells = sweep::grid2(GPU_COUNTS.len(), cfg.tasksets);
    let seed = cfg.seed;
    let per_cell: Vec<[bool; Approach::ALL.len()]> =
        sweep::run(&cfg.sweep(), cells, |_, &(gi, ti)| {
            let p = params_for(GPU_COUNTS[gi], WaitMode::SelfSuspend);
            crate::experiments::approaches(seed, &p, ti)
        });

    let mut series: Vec<(String, Vec<f64>)> = Approach::ALL
        .iter()
        .map(|a| (a.label().to_string(), vec![0.0; GPU_COUNTS.len()]))
        .collect();
    for (cell_idx, oks) in per_cell.iter().enumerate() {
        let gi = cell_idx / cfg.tasksets.max(1);
        for (k, &ok) in oks.iter().enumerate() {
            series[k].1[gi] += ok as usize as f64;
        }
    }
    for (_, ys) in &mut series {
        for y in ys.iter_mut() {
            *y /= cfg.tasksets.max(1) as f64;
        }
    }
    (xticks, series)
}

/// Format the merged results as the CSV table (pure — the determinism
/// suite compares these bytes across worker counts).
pub fn sweep_csv(xticks: &[String], series: &[(String, Vec<f64>)]) -> CsvTable {
    let mut csv = CsvTable::new(vec![
        "approach".to_string(),
        "num_gpus".to_string(),
        "schedulable_ratio".to_string(),
    ]);
    for (label, ys) in series {
        for (x, y) in xticks.iter().zip(ys) {
            csv.row(vec![label.clone(), x.clone(), format!("{y:.4}")]);
        }
    }
    csv
}

/// Registry face: `gcaps exp multigpu`.
pub struct MultigpuExp;

impl Experiment for MultigpuExp {
    fn name(&self) -> &'static str {
        "multigpu"
    }

    fn about(&self) -> &'static str {
        "Schedulability of 9 approaches over 1/2/4 GPU engines"
    }

    fn run(&self, cfg: &ExpConfig, sink: &mut dyn Sink) -> Result<()> {
        let (xticks, series) = run_sweep(cfg);
        sink.table("multigpu", &sweep_csv(&xticks, &series));
        let chart = line_chart(
            "Multi-GPU: schedulability vs GPU engine count (Table 3 defaults)",
            "num_gpus",
            &xticks,
            &series,
            1.0,
            16,
        );
        sink.text(&format!("{chart}\n"));
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::analyze;
    use crate::taskgen::generate;
    use crate::util::rng::Pcg32;

    fn tiny() -> ExpConfig {
        ExpConfig { tasksets: 10, seed: 17, ..ExpConfig::default() }
    }

    #[test]
    fn sweep_shape_and_ranges() {
        let (xticks, series) = run_sweep(&tiny());
        assert_eq!(xticks, vec!["1", "2", "4"]);
        assert_eq!(series.len(), Approach::ALL.len());
        for (label, ys) in &series {
            assert_eq!(ys.len(), 3, "{label}");
            for &y in ys {
                assert!((0.0..=1.0).contains(&y), "{label}: {y}");
            }
        }
    }

    #[test]
    fn g1_point_matches_fig8_procedure() {
        // The g = 1 column must agree with the Fig. 8 default-point
        // schedulability (same memoized tasksets, same procedure).
        let cfg = tiny();
        let (_, series) = run_sweep(&cfg);
        for (k, a) in Approach::ALL.iter().enumerate() {
            let lone = crate::experiments::fig8::schedulability(*a, &|_| {}, &cfg);
            assert_eq!(series[k].1[0], lone, "{} g=1 diverged", a.label());
        }
    }

    #[test]
    fn extra_engines_never_hurt_a_fixed_taskset_under_suspension() {
        // Paired comparison on a fixed structure: spreading a taskset's
        // GPU tasks over 2 engines must not increase any WCRT under the
        // four suspension analyses, whose per-engine terms are all
        // set-monotone. (The busy variants are not pointwise monotone:
        // a same-core task moved off-engine migrates its busy-wait
        // charge from Lemma 10's J^g-jittered term to Lemma 12's
        // J^c-jittered one, which can count one extra carry-in job.)
        let mut rng = Pcg32::seeded(42);
        let one = generate(&mut rng, &params_for(1, WaitMode::SelfSuspend));
        let mut two = one.clone();
        two.platform = two.platform.clone().with_num_gpus(2);
        crate::taskgen::wfd_assign_gpus(&mut two.tasks, 2);
        two.validate().unwrap();
        for a in [
            Approach::GcapsSuspend,
            Approach::TsgRrSuspend,
            Approach::MpcpSuspend,
            Approach::FmlpSuspend,
        ] {
            let r1 = analyze(&one, a);
            let r2 = analyze(&two, a);
            for t in one.rt_tasks() {
                match (r1.response[t.id], r2.response[t.id]) {
                    (Some(x), Some(y)) => assert!(
                        y <= x,
                        "{}: task {} got worse with 2 engines ({y} > {x})",
                        a.label(),
                        t.id
                    ),
                    (None, _) => {} // unschedulable on 1 GPU may pass on 2
                    (Some(_), None) => panic!(
                        "{}: task {} became unschedulable with 2 engines",
                        a.label(),
                        t.id
                    ),
                }
            }
        }
    }
}
