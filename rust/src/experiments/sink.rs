//! Pluggable result sinks for the experiment registry.
//!
//! Every [`Experiment`](crate::experiments::registry::Experiment) emits
//! its results exactly once — typed tables (a stable column schema per
//! experiment, the legacy `results/<name>.csv` stem as the table name)
//! plus rendered ASCII blocks — into a `&mut dyn Sink`. The sink
//! decides the output format, so one run can feed CSV, JSONL and the
//! ASCII report simultaneously ([`Tee`]) without re-sweeping:
//!
//! - [`CsvSink`] — writes `<dir>/<name>.csv`, byte-identical to the
//!   pre-registry harness output (the `tests/registry.rs` goldens pin
//!   this).
//! - [`JsonlSink`] — writes `<dir>/<name>.jsonl`, one self-describing
//!   JSON object per row (`{"table":..., "<column>":...}`), numeric
//!   cells emitted verbatim as JSON numbers — the machine-readable
//!   face for batch/service ingestion.
//! - [`AsciiSink`] — collects the rendered text blocks (charts,
//!   gantts, report tables) for the CLI.
//! - [`Tee`] — fans every call out to several sinks.
//! - [`NullSink`] — drops everything (compute-only runs).
//!
//! File sinks defer I/O errors to [`Sink::finish`] so experiment code
//! stays infallible on the emission path.

use std::path::{Path, PathBuf};

use crate::util::csv::CsvTable;
use crate::util::error::{Error, Result};

/// A consumer of one experiment run's typed tables and ASCII blocks.
pub trait Sink {
    /// A completed table of typed rows. `name` is the stable artifact
    /// stem (`results/<name>.csv` before the redesign); `table.header`
    /// is the experiment's column schema.
    fn table(&mut self, name: &str, table: &CsvTable);

    /// A rendered human-readable block (chart, gantt, report section).
    fn text(&mut self, text: &str);

    /// Flush, surface deferred I/O errors, and report written paths.
    fn finish(&mut self) -> Result<Vec<PathBuf>> {
        Ok(Vec::new())
    }
}

/// Shared core of the file sinks: one rendered artifact per table
/// under `<dir>/<name>.<ext>`, with the first I/O error deferred to
/// [`Sink::finish`].
#[derive(Debug)]
struct FileSink {
    dir: PathBuf,
    ext: &'static str,
    render: fn(&str, &CsvTable) -> String,
    written: Vec<PathBuf>,
    error: Option<String>,
}

impl FileSink {
    fn new(
        dir: impl Into<PathBuf>,
        ext: &'static str,
        render: fn(&str, &CsvTable) -> String,
    ) -> FileSink {
        FileSink { dir: dir.into(), ext, render, written: Vec::new(), error: None }
    }
}

impl Sink for FileSink {
    fn table(&mut self, name: &str, table: &CsvTable) {
        let path = self.dir.join(format!("{name}.{}", self.ext));
        let write = |path: &Path| -> std::io::Result<()> {
            if let Some(dir) = path.parent() {
                std::fs::create_dir_all(dir)?;
            }
            std::fs::write(path, (self.render)(name, table))
        };
        match write(&path) {
            Ok(()) => self.written.push(path),
            Err(e) => {
                self.error.get_or_insert(format!("write {}: {e}", path.display()));
            }
        }
    }

    fn text(&mut self, _text: &str) {}

    fn finish(&mut self) -> Result<Vec<PathBuf>> {
        match self.error.take() {
            Some(e) => Err(Error::msg(e)),
            None => Ok(std::mem::take(&mut self.written)),
        }
    }
}

/// Writes each table as `<dir>/<name>.csv` — the same bytes
/// [`CsvTable::write`] produced before the registry (pinned by the
/// `tests/registry.rs` goldens).
#[derive(Debug)]
pub struct CsvSink(FileSink);

impl CsvSink {
    pub fn new(dir: impl Into<PathBuf>) -> CsvSink {
        CsvSink(FileSink::new(dir, "csv", |_, t| t.to_string()))
    }
}

impl Sink for CsvSink {
    fn table(&mut self, name: &str, table: &CsvTable) {
        self.0.table(name, table);
    }

    fn text(&mut self, _text: &str) {}

    fn finish(&mut self) -> Result<Vec<PathBuf>> {
        self.0.finish()
    }
}

/// Writes each table as `<dir>/<name>.jsonl` — one self-describing
/// JSON object per row ([`to_jsonl`]).
#[derive(Debug)]
pub struct JsonlSink(FileSink);

impl JsonlSink {
    pub fn new(dir: impl Into<PathBuf>) -> JsonlSink {
        JsonlSink(FileSink::new(dir, "jsonl", to_jsonl))
    }
}

impl Sink for JsonlSink {
    fn table(&mut self, name: &str, table: &CsvTable) {
        self.0.table(name, table);
    }

    fn text(&mut self, _text: &str) {}

    fn finish(&mut self) -> Result<Vec<PathBuf>> {
        self.0.finish()
    }
}

/// Collects the rendered ASCII blocks in emission order.
#[derive(Debug, Default)]
pub struct AsciiSink {
    out: String,
}

impl AsciiSink {
    pub fn new() -> AsciiSink {
        AsciiSink::default()
    }

    pub fn into_string(self) -> String {
        self.out
    }
}

impl Sink for AsciiSink {
    fn table(&mut self, _name: &str, _table: &CsvTable) {}

    fn text(&mut self, text: &str) {
        self.out.push_str(text);
    }
}

/// Fans every call out to several sinks (one sweep, all formats).
pub struct Tee<'a>(pub Vec<&'a mut dyn Sink>);

impl Sink for Tee<'_> {
    fn table(&mut self, name: &str, table: &CsvTable) {
        for s in &mut self.0 {
            s.table(name, table);
        }
    }

    fn text(&mut self, text: &str) {
        for s in &mut self.0 {
            s.text(text);
        }
    }

    fn finish(&mut self) -> Result<Vec<PathBuf>> {
        let mut out = Vec::new();
        for s in &mut self.0 {
            out.extend(s.finish()?);
        }
        Ok(out)
    }
}

/// Drops everything — compute-only dispatch (tests, dry runs).
#[derive(Debug, Default)]
pub struct NullSink;

impl Sink for NullSink {
    fn table(&mut self, _name: &str, _table: &CsvTable) {}
    fn text(&mut self, _text: &str) {}
}

/// Render a table as JSON Lines: one flat object per row, keyed by the
/// table name plus the column schema, in header order. Cells that are
/// valid JSON number literals are emitted verbatim (so `0.1200` keeps
/// its trailing zeros and stays a number); everything else becomes a
/// JSON string. No table uses `table` as a column name — the
/// self-description key cannot collide.
pub fn to_jsonl(table_name: &str, t: &CsvTable) -> String {
    let mut s = String::new();
    for row in &t.rows {
        s.push_str("{\"table\":");
        s.push_str(&json_string(table_name));
        for (k, v) in t.header.iter().zip(row) {
            s.push(',');
            s.push_str(&json_string(k));
            s.push(':');
            if is_json_number(v) {
                s.push_str(v);
            } else {
                s.push_str(&json_string(v));
            }
        }
        s.push_str("}\n");
    }
    s
}

/// Quote and escape `s` as a JSON string literal.
pub fn json_string(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

/// Is `s` a valid JSON number literal, verbatim?
/// (`-?(0|[1-9][0-9]*)(\.[0-9]+)?([eE][+-]?[0-9]+)?` — notably `01`,
/// `1.`, `.5`, `+3`, `nan` and `inf` are not.)
pub fn is_json_number(s: &str) -> bool {
    let b = s.as_bytes();
    let mut i = 0;
    if i < b.len() && b[i] == b'-' {
        i += 1;
    }
    let int_start = i;
    while i < b.len() && b[i].is_ascii_digit() {
        i += 1;
    }
    if i == int_start || (b[int_start] == b'0' && i - int_start > 1) {
        return false;
    }
    if i < b.len() && b[i] == b'.' {
        i += 1;
        let frac_start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == frac_start {
            return false;
        }
    }
    if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
        i += 1;
        if i < b.len() && (b[i] == b'+' || b[i] == b'-') {
            i += 1;
        }
        let exp_start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == exp_start {
            return false;
        }
    }
    i == b.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> CsvTable {
        let mut t = CsvTable::new(vec!["approach", "ratio"]);
        t.row(vec!["gcaps_suspend", "0.1200"]);
        t.row(vec!["say \"hi\"", "20%"]);
        t
    }

    #[test]
    fn json_number_recognition() {
        for ok in ["0", "7", "-5", "0.1200", "1e3", "-2.5E-2", "100"] {
            assert!(is_json_number(ok), "{ok} should be a JSON number");
        }
        for bad in ["", "-", "01", "1.", ".5", "+3", "nan", "inf", "4x", "1O0", "1e", "0x1"] {
            assert!(!is_json_number(bad), "{bad} should NOT be a JSON number");
        }
    }

    #[test]
    fn jsonl_is_self_describing_and_typed() {
        let s = to_jsonl("fig9", &sample());
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            "{\"table\":\"fig9\",\"approach\":\"gcaps_suspend\",\"ratio\":0.1200}"
        );
        // Quotes escaped; non-numeric cell stays a string.
        assert_eq!(
            lines[1],
            "{\"table\":\"fig9\",\"approach\":\"say \\\"hi\\\"\",\"ratio\":\"20%\"}"
        );
    }

    #[test]
    fn json_string_escapes_controls() {
        assert_eq!(json_string("a\"b\\c\nd\re\tf\u{1}"), "\"a\\\"b\\\\c\\nd\\re\\tf\\u0001\"");
    }

    #[test]
    fn csv_sink_writes_legacy_bytes() {
        let dir = std::env::temp_dir().join("gcaps_sink_test_csv");
        let _ = std::fs::remove_dir_all(&dir);
        let mut sink = CsvSink::new(&dir);
        let t = sample();
        sink.table("demo", &t);
        let outputs = sink.finish().unwrap();
        assert_eq!(outputs, vec![dir.join("demo.csv")]);
        assert_eq!(std::fs::read_to_string(&outputs[0]).unwrap(), t.to_string());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn jsonl_sink_writes_one_object_per_row() {
        let dir = std::env::temp_dir().join("gcaps_sink_test_jsonl");
        let _ = std::fs::remove_dir_all(&dir);
        let mut sink = JsonlSink::new(&dir);
        sink.table("demo", &sample());
        let outputs = sink.finish().unwrap();
        assert_eq!(outputs, vec![dir.join("demo.jsonl")]);
        let body = std::fs::read_to_string(&outputs[0]).unwrap();
        assert_eq!(body.lines().count(), 2);
        assert!(body.lines().all(|l| l.starts_with("{\"table\":\"demo\",")));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn tee_fans_out_and_merges_outputs() {
        let dir = std::env::temp_dir().join("gcaps_sink_test_tee");
        let _ = std::fs::remove_dir_all(&dir);
        let mut csv = CsvSink::new(&dir);
        let mut jsonl = JsonlSink::new(&dir);
        let mut ascii = AsciiSink::new();
        {
            let mut tee = Tee(vec![&mut csv, &mut jsonl, &mut ascii]);
            tee.table("demo", &sample());
            tee.text("chart\n");
            let outputs = tee.finish().unwrap();
            assert_eq!(outputs, vec![dir.join("demo.csv"), dir.join("demo.jsonl")]);
        }
        assert_eq!(ascii.into_string(), "chart\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn file_sink_errors_surface_in_finish() {
        // A directory path that cannot be created (a file is in the way).
        let base = std::env::temp_dir().join("gcaps_sink_test_err");
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&base).unwrap();
        let blocker = base.join("blocked");
        std::fs::write(&blocker, "not a dir").unwrap();
        let mut sink = CsvSink::new(blocker.join("sub"));
        sink.table("demo", &sample());
        assert!(sink.finish().is_err());
        let _ = std::fs::remove_dir_all(&base);
    }
}
