//! Library facade over the experiment registry: list and run any
//! registered experiment in-process — no shelling out to the `gcaps`
//! binary — with pluggable output formats and a structured report.
//!
//! ```no_run
//! use gcaps::api;
//! use gcaps::experiments::ExpConfig;
//!
//! let cfg = ExpConfig { tasksets: 100, seed: 2024, ..ExpConfig::default() };
//! let report = api::run("fig9", &cfg, &api::SinkSpec::csv_jsonl("results")).unwrap();
//! println!(
//!     "{}: {} rows in {} tables, {:?} -> {:?}",
//!     report.name,
//!     report.rows(),
//!     report.tables.len(),
//!     report.wall,
//!     report.outputs,
//! );
//! ```
//!
//! Experiment-specific options ride in [`ExpConfig::opts`] and are
//! validated (names and values) before any sweeping starts:
//!
//! ```no_run
//! use gcaps::api;
//! use gcaps::experiments::{ExpConfig, Opts};
//!
//! let cfg = ExpConfig {
//!     tasksets: 50,
//!     opts: Opts::default().set("panel", "b"),
//!     ..ExpConfig::default()
//! };
//! let report = api::run("fig8", &cfg, &api::SinkSpec::jsonl_only("out")).unwrap();
//! assert_eq!(report.tables[0].name, "fig8b");
//! ```

use std::path::PathBuf;

use crate::err;
use crate::experiments::registry;
use crate::experiments::sink::{AsciiSink, CsvSink, JsonlSink, Sink, Tee};
use crate::experiments::{results_dir, ExpConfig};
use crate::util::error::Result;

pub use crate::experiments::registry::{Experiment, ExpReport, TableStat};

/// Which sinks [`run`] attaches, and where file sinks write.
#[derive(Debug, Clone, Default)]
pub struct SinkSpec {
    /// Write `<dir>/<table>.csv` (the legacy byte-pinned artifacts).
    pub csv: bool,
    /// Write `<dir>/<table>.jsonl` (one self-describing object/row).
    pub jsonl: bool,
    /// Collect the rendered ASCII report into [`ExpReport::ascii`].
    pub ascii: bool,
    /// Output directory for the file sinks; `None` = the default
    /// results directory (`$GCAPS_RESULTS` or `./results`).
    pub dir: Option<PathBuf>,
}

impl SinkSpec {
    /// CSV files only.
    pub fn csv_only(dir: impl Into<PathBuf>) -> SinkSpec {
        SinkSpec { csv: true, dir: Some(dir.into()), ..SinkSpec::default() }
    }

    /// JSONL files only.
    pub fn jsonl_only(dir: impl Into<PathBuf>) -> SinkSpec {
        SinkSpec { jsonl: true, dir: Some(dir.into()), ..SinkSpec::default() }
    }

    /// CSV + JSONL side by side from one run.
    pub fn csv_jsonl(dir: impl Into<PathBuf>) -> SinkSpec {
        SinkSpec { csv: true, jsonl: true, dir: Some(dir.into()), ..SinkSpec::default() }
    }

    /// No files — ASCII report only (compute + render).
    pub fn ascii_only() -> SinkSpec {
        SinkSpec { ascii: true, ..SinkSpec::default() }
    }

    /// Also collect the ASCII report.
    pub fn with_ascii(mut self) -> SinkSpec {
        self.ascii = true;
        self
    }
}

/// All registered experiments, in `gcaps exp --list` order.
pub fn list() -> &'static [&'static dyn Experiment] {
    registry::all()
}

/// Look an experiment up by its stable name.
pub fn find(name: &str) -> Option<&'static dyn Experiment> {
    registry::find(name)
}

/// Run the named experiment at the given scale through the sinks the
/// spec asks for. Unknown names, unknown/invalid options
/// ([`ExpConfig::opts`]) and sink I/O failures are `Err`; on success
/// the report carries per-table row counts, every written path, the
/// wall-clock, and (when requested) the ASCII rendition.
pub fn run(name: &str, cfg: &ExpConfig, spec: &SinkSpec) -> Result<ExpReport> {
    let exp = registry::find(name).ok_or_else(|| {
        err!(
            "unknown experiment {name:?} (expected one of: {})",
            registry::all().iter().map(|e| e.name()).collect::<Vec<_>>().join("|")
        )
    })?;
    run_experiment(exp, cfg, spec)
}

/// [`run`] for a trait object you already hold (e.g. from [`list`]).
pub fn run_experiment(
    exp: &dyn Experiment,
    cfg: &ExpConfig,
    spec: &SinkSpec,
) -> Result<ExpReport> {
    let dir = spec.dir.clone().unwrap_or_else(results_dir);
    let mut csv = spec.csv.then(|| CsvSink::new(&dir));
    let mut jsonl = spec.jsonl.then(|| JsonlSink::new(&dir));
    let mut ascii = spec.ascii.then(AsciiSink::new);
    let mut fanout: Vec<&mut dyn Sink> = Vec::new();
    if let Some(s) = csv.as_mut() {
        fanout.push(s);
    }
    if let Some(s) = jsonl.as_mut() {
        fanout.push(s);
    }
    if let Some(s) = ascii.as_mut() {
        fanout.push(s);
    }
    let mut report = {
        let mut tee = Tee(fanout);
        registry::run(exp, cfg, &mut tee)?
    };
    if let Some(a) = ascii {
        report.ascii = a.into_string();
    }
    Ok(report)
}

/// One line per experiment: name, description, extra flags — the body
/// of `gcaps exp --list`.
pub fn render_list() -> String {
    let mut out = String::new();
    for e in registry::all() {
        let flags: String = e
            .flags()
            .iter()
            .map(|f| format!(" [--{} {}]", f.name, f.values))
            .collect();
        let tag = if e.in_all() { "" } else { " (not in `exp all`)" };
        out.push_str(&format!("  {:<10} {}{flags}{tag}\n", e.name(), e.about()));
    }
    out.push_str("  all        every experiment above not marked otherwise\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unknown_name_is_an_error() {
        let e = run("nope", &ExpConfig::default(), &SinkSpec::default())
            .unwrap_err()
            .to_string();
        assert!(e.contains("nope") && e.contains("fig8"), "{e}");
    }

    #[test]
    fn ascii_only_run_fills_the_report() {
        let cfg = ExpConfig { tasksets: 2, seed: 3, ..ExpConfig::default() };
        let report = run("fig9", &cfg, &SinkSpec::ascii_only()).unwrap();
        assert!(report.ascii.contains("Fig. 9"), "{}", report.ascii);
        assert!(report.outputs.is_empty());
        assert_eq!(report.rows(), 20);
    }

    #[test]
    fn csv_jsonl_spec_writes_both_artifacts() {
        let dir = std::env::temp_dir().join("gcaps_api_test_both");
        let _ = std::fs::remove_dir_all(&dir);
        let cfg = ExpConfig { tasksets: 2, seed: 3, ..ExpConfig::default() };
        let report = run("fig9", &cfg, &SinkSpec::csv_jsonl(&dir)).unwrap();
        assert_eq!(report.outputs, vec![dir.join("fig9.csv"), dir.join("fig9.jsonl")]);
        assert!(report.outputs.iter().all(|p| p.exists()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_list_covers_every_experiment() {
        let out = render_list();
        for e in list() {
            assert!(out.contains(e.name()), "{} missing from list", e.name());
        }
        assert!(out.contains("--panel a..f"), "{out}");
        assert!(out.contains("--only epstheta|edfvfp|hetero"), "{out}");
    }
}
