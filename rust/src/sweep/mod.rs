//! Sharded parallel sweep engine for the experiment harnesses.
//!
//! The paper's evaluation (§7, Figs. 8–9) runs ~1000 random tasksets per
//! utilization point across 9 analysis approaches plus DES replicas.
//! Every harness in `experiments/` expresses that work as a flat grid of
//! **cells** (e.g. sweep-point × taskset-index) and hands it to
//! [`run`], which shards the cells across a worker pool and merges the
//! per-cell results back **in canonical cell order**.
//!
//! # Determinism guarantee
//!
//! Results are byte-identical regardless of the worker count (`--jobs`)
//! and of OS scheduling order, because:
//!
//! 1. no random state is shared between cells — each cell derives its
//!    own PRNG by seed-splitting ([`cell_rng`]): the experiment's base
//!    seed is folded with a *stable* hash of the cell's coordinates
//!    ([`cell_hash`], and [`memo::params_hash`] for generator
//!    parameters), never with an execution-order-dependent value;
//! 2. workers communicate only `(cell index, result)` pairs; [`run`]
//!    reassembles them into the input order before returning, so
//!    downstream CSV emission sees the same sequence a serial run
//!    produces.
//!
//! `rust/tests/sweep_determinism.rs` locks this guarantee: a Fig. 8
//! panel at `jobs = 1 / 2 / 8` must produce identical merged results
//! and identical CSV bytes.
//!
//! # Sharding
//!
//! Workers are `std::thread`s pulling cell indices from a shared atomic
//! cursor (a lock-free work queue — cheap dynamic load balancing, since
//! cell costs vary wildly between schedulable and unschedulable
//! tasksets) and sending results back over an mpsc channel. The
//! collector thread streams them into a slot table and prints an
//! optional progress/throughput line (cells/s).

pub mod memo;

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::Instant;

use crate::util::rng::Pcg32;

/// Worker count and progress reporting for one sweep.
#[derive(Debug, Clone, Copy)]
pub struct SweepConfig {
    /// Worker threads; 1 = run inline on the calling thread.
    pub jobs: usize,
    /// Print a progress/throughput line to stderr.
    pub progress: bool,
}

impl SweepConfig {
    /// Single-threaded, silent (the reference execution).
    pub fn serial() -> SweepConfig {
        SweepConfig { jobs: 1, progress: false }
    }

    /// Silent sweep with an explicit worker count.
    pub fn with_jobs(jobs: usize) -> SweepConfig {
        SweepConfig { jobs: jobs.max(1), progress: false }
    }
}

impl Default for SweepConfig {
    fn default() -> SweepConfig {
        SweepConfig { jobs: available_jobs(), progress: false }
    }
}

/// Default worker count: the host's available parallelism (1 if unknown).
pub fn available_jobs() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// SplitMix64 finalizer — the standard 64-bit mixer used to decorrelate
/// seed-split streams (Steele et al., OOPSLA 2014).
pub fn splitmix64(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9e3779b97f4a7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Stable FNV-1a fold of cell coordinates. Inputs must be derived from
/// the cell's *identity* (indices, parameter bits), never from execution
/// order — that is what makes sweeps worker-count-invariant.
pub fn cell_hash(parts: &[u64]) -> u64 {
    const FNV_PRIME: u64 = 0x100000001b3;
    let mut h = 0xcbf29ce484222325u64;
    for &p in parts {
        for shift in [0u32, 8, 16, 24, 32, 40, 48, 56] {
            h ^= (p >> shift) & 0xff;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

/// Per-cell PRNG via seed-splitting: `seed ⊕ stable cell hash`, run
/// through SplitMix64 for state and stream so nearby cells land on
/// uncorrelated PCG32 streams.
pub fn cell_rng(seed: u64, hash: u64) -> Pcg32 {
    Pcg32::new(splitmix64(seed ^ hash), splitmix64(hash ^ 0x5851f42d4c957f2d))
}

/// Run `f` over every cell, sharded across `cfg.jobs` workers, and
/// return the results in canonical (input) cell order. `f(i, &cells[i])`
/// must be a pure function of the cell — see the module docs for the
/// determinism contract. Panics in `f` propagate to the caller.
pub fn run<C, R, F>(cfg: &SweepConfig, cells: Vec<C>, f: F) -> Vec<R>
where
    C: Send + Sync,
    R: Send,
    F: Fn(usize, &C) -> R + Send + Sync,
{
    let n = cells.len();
    let jobs = cfg.jobs.max(1).min(n.max(1));
    let start = Instant::now(); // gcaps-lint: allow(wall-clock) -- progress reporting only
    if jobs <= 1 {
        let out: Vec<R> = cells.iter().enumerate().map(|(i, c)| f(i, c)).collect();
        if cfg.progress {
            report_progress(n, n, start, true);
        }
        return out;
    }

    let cursor = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();
    let cells_ref = &cells;
    let f_ref = &f;
    let mut slots: Vec<Option<R>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);

    std::thread::scope(|scope| {
        for _ in 0..jobs {
            let tx = tx.clone();
            let cursor = &cursor;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let r = f_ref(i, &cells_ref[i]);
                if tx.send((i, r)).is_err() {
                    break;
                }
            });
        }
        drop(tx); // collectors below hold the only receiver

        let mut done = 0usize;
        // gcaps-lint: allow(wall-clock) -- progress reporting only
        let mut last_report = Instant::now();
        for (i, r) in rx {
            slots[i] = Some(r);
            done += 1;
            if cfg.progress
                && (done == n || last_report.elapsed().as_millis() >= 500)
            {
                report_progress(done, n, start, done == n);
                // gcaps-lint: allow(wall-clock) -- progress reporting only
                last_report = Instant::now();
            }
        }
    });

    slots
        .into_iter()
        .map(|s| s.expect("sweep worker dropped a cell result"))
        .collect()
}

/// Convenience wrapper for index-only grids (`0..n`).
pub fn run_indexed<R, F>(cfg: &SweepConfig, n: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Send + Sync,
{
    run(cfg, (0..n).collect::<Vec<usize>>(), move |i, _| f(i))
}

/// Canonical 2-D cross-product index grid: i-major, j-minor. Result
/// index `c` of a sweep over this grid decodes as `(c / n1, c % n1)`.
pub fn grid2(n0: usize, n1: usize) -> Vec<(usize, usize)> {
    let mut cells = Vec::with_capacity(n0 * n1);
    for i in 0..n0 {
        for j in 0..n1 {
            cells.push((i, j));
        }
    }
    cells
}

/// Canonical 3-D cross-product index grid (row-major).
pub fn grid3(n0: usize, n1: usize, n2: usize) -> Vec<(usize, usize, usize)> {
    let mut cells = Vec::with_capacity(n0 * n1 * n2);
    for i in 0..n0 {
        for j in 0..n1 {
            for k in 0..n2 {
                cells.push((i, j, k));
            }
        }
    }
    cells
}

fn report_progress(done: usize, total: usize, start: Instant, finished: bool) {
    let secs = start.elapsed().as_secs_f64().max(1e-9);
    eprint!("\r  sweep: {done}/{total} cells ({:.0} cells/s)", done as f64 / secs);
    if finished {
        eprintln!();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize as Counter;

    #[test]
    fn preserves_canonical_order() {
        let cells: Vec<usize> = (0..257).collect();
        for jobs in [1, 2, 3, 8, 64] {
            let out = run(&SweepConfig::with_jobs(jobs), cells.clone(), |i, &c| {
                assert_eq!(i, c);
                c * 3 + 1
            });
            let expect: Vec<usize> = (0..257).map(|c| c * 3 + 1).collect();
            assert_eq!(out, expect, "jobs = {jobs}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let f = |i: usize, _: &u8| {
            let mut rng = cell_rng(42, cell_hash(&[7, i as u64]));
            rng.next_u64()
        };
        let cells = vec![0u8; 100];
        let serial = run(&SweepConfig::serial(), cells.clone(), f);
        let par = run(&SweepConfig::with_jobs(8), cells, f);
        assert_eq!(serial, par);
    }

    #[test]
    fn every_cell_runs_exactly_once() {
        static HITS: Counter = Counter::new(0);
        HITS.store(0, Ordering::SeqCst);
        let out = run_indexed(&SweepConfig::with_jobs(4), 500, |i| {
            HITS.fetch_add(1, Ordering::SeqCst);
            i
        });
        assert_eq!(out.len(), 500);
        assert_eq!(HITS.load(Ordering::SeqCst), 500);
    }

    #[test]
    fn empty_and_tiny_grids() {
        let none: Vec<u32> = run(&SweepConfig::with_jobs(8), Vec::<u8>::new(), |_, _| 1);
        assert!(none.is_empty());
        let one = run(&SweepConfig::with_jobs(8), vec![5u8], |_, &c| c as u32);
        assert_eq!(one, vec![5]);
    }

    #[test]
    fn cell_rng_is_stable_and_split() {
        let mut a = cell_rng(1, cell_hash(&[0, 3]));
        let mut b = cell_rng(1, cell_hash(&[0, 3]));
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
        // Adjacent cells must not share a stream.
        let mut c = cell_rng(1, cell_hash(&[0, 4]));
        let same = (0..64).filter(|_| a.next_u32() == c.next_u32()).count();
        assert!(same < 4, "adjacent cells correlated ({same}/64)");
    }

    #[test]
    fn cell_hash_sensitive_to_order_and_value() {
        assert_ne!(cell_hash(&[1, 2]), cell_hash(&[2, 1]));
        assert_ne!(cell_hash(&[0]), cell_hash(&[]));
        assert_ne!(cell_hash(&[u64::MAX]), cell_hash(&[u64::MAX - 1]));
    }

    #[test]
    fn grids_are_row_major_and_decode_by_divmod() {
        let g = grid2(3, 4);
        assert_eq!(g.len(), 12);
        for (c, &(i, j)) in g.iter().enumerate() {
            assert_eq!((i, j), (c / 4, c % 4));
        }
        let g = grid3(2, 3, 4);
        assert_eq!(g.len(), 24);
        assert_eq!(g[0], (0, 0, 0));
        assert_eq!(g[23], (1, 2, 3));
        assert!(grid2(0, 5).is_empty() && grid3(2, 0, 2).is_empty());
    }
}
