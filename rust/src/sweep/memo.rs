//! Sweep-level taskset memoization.
//!
//! One Fig. 8 data point evaluates the same random tasksets under 8
//! analysis approaches. The generator's random draws depend only on the
//! structural [`GenParams`] fields — **not** on the wait mode or the
//! platform overhead constants (those are stamped onto the finished
//! tasks/taskset) — so the taskset for `(seed, params, index)` can be
//! generated once and shared across every approach, wait mode, and
//! ε/θ variant at that point.
//!
//! The cache key is `(base seed, mode- and platform-normalized params
//! hash, taskset index)`; the cached value is the canonical
//! self-suspending taskset, and [`taskset`] re-stamps the requested
//! mode/platform on the way out. When the cache grows past a bound,
//! entries belonging to *other* `(seed, params-hash)` generations are
//! evicted — never the generation currently being inserted (a sweep
//! larger than the bound would otherwise clear its own entries on every
//! store and re-generate its whole grid).

use std::collections::HashMap;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::model::{Platform, TaskSet, WaitMode};
use crate::sweep::{cell_hash, cell_rng};
use crate::taskgen::{generate, GenParams};

/// Re-exported so callers outside `sweep` reach the shared
/// poison-recovery helper through the module that pioneered it.
pub use crate::util::sync::lock_or_recover;

type Key = (u64, u64, usize);

/// Process-wide cache. `Mutex<Option<..>>` rather than a lazy cell so a
/// const initializer suffices (no external once-cell machinery).
static CACHE: Mutex<Option<HashMap<Key, Arc<TaskSet>>>> = Mutex::new(None);

/// Eviction bound: ~a full Fig. 8 panel at paper scale (7 points ×
/// 1000 tasksets) before other-generation entries are evicted. The map
/// may temporarily exceed this when a single sweep generation alone is
/// larger than the cap — growth then stays bounded by that one sweep's
/// own size, and the surplus is dropped as soon as a different
/// generation overflows.
const CACHE_CAP: usize = 8192;

/// Lock the cache, recovering from poisoning. A sweep worker that
/// panics while holding the guard (e.g. out-of-memory inside
/// `HashMap::insert`, or a panicking assertion in test code) poisons
/// the mutex; without recovery every later [`taskset`]/[`clear`] call
/// in the process would panic too — fatal for a long-running
/// `gcaps serve`. Recovery is sound here because the map carries no
/// cross-entry invariant a partial critical section could break: each
/// operation is a single `HashMap` call (`get`/`insert`/`clear`/
/// `retain`), the values are immutable `Arc`s, and the key fully
/// determines the (deterministically re-generable) value — any state
/// the map can be observed in is a valid cache, at worst missing or
/// still holding some entries.
fn lock() -> MutexGuard<'static, Option<HashMap<Key, Arc<TaskSet>>>> {
    lock_or_recover(&CACHE)
}

/// Stable hash of every [`GenParams`] field that influences the
/// generated task structure. Deliberately excludes `mode` (copied onto
/// tasks after the draws) and the platform's per-engine *overheads*
/// (ε/θ/L — copied onto the taskset), so e.g. the busy/suspend variants
/// of one approach pair and an ε sensitivity sweep all share identical
/// task structure — which is also what the paper's evaluation does.
///
/// The GPU-engine COUNT, however, does shape generation (the WFD
/// task-to-GPU assignment), so it is part of the key — a staleness fix:
/// normalizing it away would hand a 2-GPU sweep point the cached 1-GPU
/// assignment. It is appended only when > 1 so every legacy single-GPU
/// key (and therefore every legacy CSV byte) is unchanged.
///
/// Per-engine overheads normalize away only while the platform is
/// **uniform**. A heterogeneous platform additionally folds every
/// engine's full (ε, θ, L) context into the key: today the generator's
/// WFD assignment ignores engine parameters, so equal-count hetero
/// platforms *would* be safe to share — but that is an accident of the
/// current assignment policy, and a future overhead-aware placement
/// would silently corrupt the cache through such a collision. Making
/// the hetero digest part of the key states the invariant explicitly
/// ("sharing requires uniformity") instead of leaning on it. Uniform
/// keys — including every pre-existing sweep — are byte-unchanged
/// (`single_gpu_hash_is_pinned` pins the legacy constant).
pub fn params_hash(p: &GenParams) -> u64 {
    let mut parts = vec![
        p.num_cpus as u64,
        p.tasks_per_cpu.0 as u64,
        p.tasks_per_cpu.1 as u64,
        p.gpu_task_ratio.0.to_bits(),
        p.gpu_task_ratio.1.to_bits(),
        p.util_per_cpu.0.to_bits(),
        p.util_per_cpu.1.to_bits(),
        p.period_ms.0.to_bits(),
        p.period_ms.1.to_bits(),
        p.gpu_segments.0 as u64,
        p.gpu_segments.1 as u64,
        p.g_to_c_ratio.0.to_bits(),
        p.g_to_c_ratio.1.to_bits(),
        p.gm_in_g_ratio.0.to_bits(),
        p.gm_in_g_ratio.1.to_bits(),
        p.best_effort_ratio.to_bits(),
    ];
    if p.platform.num_gpus() > 1 {
        parts.push(p.platform.num_gpus() as u64);
    }
    // Fine-grain fraction band: folded only when it actually shapes
    // generation, so the serial default keeps the pinned legacy key
    // (`single_gpu_hash_is_pinned`) and every existing CSV byte. The
    // tag disambiguates from the hetero-platform suffix below.
    if p.par_range != (100, 100) {
        parts.push(0x6669_6e65); // "fine"
        parts.push(p.par_range.0 as u64);
        parts.push(p.par_range.1 as u64);
    }
    if !p.platform.is_uniform() {
        for g in &p.platform.gpus {
            parts.push(g.epsilon);
            parts.push(g.theta);
            parts.push(g.tsg_slice);
        }
    }
    cell_hash(&parts)
}

/// The `index`-th random taskset for `(seed, params)`, memoized.
///
/// Deterministic in `(seed, params, index)` alone — independent of call
/// order, worker count, and cache state — because the per-taskset PRNG
/// is derived by seed-splitting, not drawn from a shared stream.
pub fn taskset(seed: u64, p: &GenParams, index: usize) -> Arc<TaskSet> {
    let h = params_hash(p);
    let key = (seed, h, index);
    let cached = lookup(&key);
    let canon = match cached {
        Some(ts) => ts,
        None => {
            let canon_params = GenParams { mode: WaitMode::SelfSuspend, ..p.clone() };
            let mut rng = cell_rng(seed, cell_hash(&[h, index as u64]));
            let ts = Arc::new(generate(&mut rng, &canon_params));
            store(key, Arc::clone(&ts));
            ts
        }
    };
    adapt(canon, p)
}

/// Re-stamp the requested wait mode and platform onto a cached taskset.
/// Safe for the per-engine overheads only — the engine COUNT (and, for
/// heterogeneous platforms, the full per-engine context set) is part of
/// the cache key, so the cached WFD task-to-GPU assignment always
/// matches `p.platform`.
fn adapt(ts: Arc<TaskSet>, p: &GenParams) -> Arc<TaskSet> {
    let platform = Platform { num_cpus: p.num_cpus, gpus: p.platform.gpus.clone() };
    debug_assert_eq!(ts.platform.num_gpus(), platform.num_gpus());
    if p.mode == WaitMode::SelfSuspend && ts.platform == platform {
        return ts;
    }
    let mut out = (*ts).clone();
    out.platform = platform;
    for t in &mut out.tasks {
        t.mode = p.mode;
    }
    Arc::new(out)
}

/// Drop every cached taskset. Sweeps never need this (results are
/// cache-state-independent); benchmarks use it to measure the cold
/// generation path instead of Arc-clone cache hits.
pub fn clear() {
    let mut guard = lock();
    if let Some(m) = guard.as_mut() {
        m.clear();
    }
}

fn lookup(key: &Key) -> Option<Arc<TaskSet>> {
    let guard = lock();
    guard.as_ref().and_then(|m| m.get(key).cloned())
}

/// At the cap, evict other `(seed, params-hash)` generations only. The
/// entry about to be inserted belongs to the sweep currently running;
/// clearing its generation too (the old wholesale `map.clear()`) meant
/// a sweep larger than the cap evicted its own cells on every store and
/// re-generated its whole grid.
fn evict_if_full(map: &mut HashMap<Key, Arc<TaskSet>>, key: &Key) {
    if map.len() >= CACHE_CAP {
        let generation = (key.0, key.1);
        map.retain(|k, _| (k.0, k.1) == generation);
    }
}

fn store(key: Key, ts: Arc<TaskSet>) {
    let mut guard = lock();
    let map = guard.get_or_insert_with(HashMap::new);
    evict_if_full(map, &key);
    map.insert(key, ts);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// A cheap synthetic cache value for eviction-policy tests (the
    /// policy only looks at keys, never at the stored taskset).
    fn dummy() -> Arc<TaskSet> {
        Arc::new(TaskSet::new(vec![], Platform::default()))
    }

    #[test]
    fn poisoned_cache_recovers_and_serves_hits() {
        // Warm one entry, then poison the mutex the way a panicking
        // sweep worker would: die while holding the guard (the panic of
        // a cached-generation closure propagates through `store`'s
        // critical section). The panic is caught via the thread join.
        let p = GenParams::default();
        let warm = taskset(0x9054_0001, &p, 0);
        let poisoner = std::thread::spawn(|| {
            let _g = CACHE.lock().expect("not yet poisoned");
            panic!("sweep worker dies while holding the cache lock");
        });
        assert!(poisoner.join().is_err(), "the poisoning panic must fire");
        // Regression: these used to propagate the poison panic forever.
        let hit = taskset(0x9054_0001, &p, 0);
        assert!(Arc::ptr_eq(&warm, &hit), "cache must still serve hits");
        let fresh = taskset(0x9054_0002, &p, 0);
        assert_eq!(fresh.tasks.len(), taskset(0x9054_0002, &p, 0).tasks.len());
    }

    // The two eviction-policy tests below drive `evict_if_full` on a
    // local map rather than the process-global cache: lib tests run in
    // parallel, and filling the shared cache to `CACHE_CAP` would race
    // the sweep tests in `experiments/` that store into it. `store`
    // wires the same helper in front of its insert, so the policy under
    // test is exactly the production one.

    #[test]
    fn overflow_evicts_only_other_generations() {
        let mut map: HashMap<Key, Arc<TaskSet>> = HashMap::new();
        // Cache at the cap, holding only a foreign generation.
        let foreign = (0xF0F0_F0F0u64, 0xBADC_0FFEu64);
        for i in 0..CACHE_CAP {
            map.insert((foreign.0, foreign.1, i), dummy());
        }
        // One store from a live sweep overflows the cap: every foreign
        // entry goes, the new entry stays.
        let own = (0x9054_0003u64, 0x0DD5_EED5u64, 0usize);
        evict_if_full(&mut map, &own);
        map.insert(own, dummy());
        assert_eq!(map.len(), 1, "every foreign entry evicted");
        assert!(map.contains_key(&own));
    }

    #[test]
    fn own_generation_survives_cap_overflow() {
        // Regression: a sweep of CACHE_CAP + 1 cells used to wholesale-
        // clear its OWN first CACHE_CAP cells when cell CAP + 1 stored,
        // re-generating the whole grid on every later pass. With
        // generation-aware eviction the re-generated remainder is
        // exactly the evicted foreign entries — here zero.
        let mut map: HashMap<Key, Arc<TaskSet>> = HashMap::new();
        let generation = (0x9054_0004u64, 0xABCD_1234u64);
        for i in 0..=CACHE_CAP {
            let key = (generation.0, generation.1, i);
            evict_if_full(&mut map, &key);
            map.insert(key, dummy());
        }
        assert_eq!(map.len(), CACHE_CAP + 1, "no own-generation cell was dropped");
        for i in 0..=CACHE_CAP {
            assert!(map.contains_key(&(generation.0, generation.1, i)));
        }
    }

    #[test]
    fn memoized_equals_fresh_generation() {
        let p = GenParams::default();
        let a = taskset(2024, &p, 5);
        // A fresh (uncached, different key path) generation with the same
        // derived rng must agree byte-for-byte in structure.
        let mut rng = cell_rng(2024, cell_hash(&[params_hash(&p), 5]));
        let fresh = generate(&mut rng, &p);
        assert_eq!(a.tasks, fresh.tasks);
        // And a second lookup returns the same cached value.
        let b = taskset(2024, &p, 5);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn mode_variants_share_structure() {
        let susp = GenParams::default();
        let busy = GenParams { mode: WaitMode::BusyWait, ..GenParams::default() };
        let a = taskset(7, &susp, 0);
        let b = taskset(7, &busy, 0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.cpu_segments, y.cpu_segments);
            assert_eq!(x.gpu_segments, y.gpu_segments);
            assert_eq!(x.core, y.core);
            assert_eq!(x.cpu_prio, y.cpu_prio);
            assert_eq!(y.mode, WaitMode::BusyWait);
            assert_eq!(x.mode, WaitMode::SelfSuspend);
        }
    }

    #[test]
    fn platform_variants_share_structure() {
        let base = GenParams::default();
        let eps = GenParams {
            platform: Platform::default().with_epsilon(4000),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&base), params_hash(&eps));
        let a = taskset(9, &base, 2);
        let b = taskset(9, &eps, 2);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(b.platform.gpus[0].epsilon, 4000);
        assert_eq!(a.platform.gpus[0].epsilon, 1000);
    }

    #[test]
    fn gpu_count_is_part_of_the_key() {
        // Regression (PR 2 satellite): the key normalization used to
        // drop every platform field; with the WFD task-to-GPU
        // assignment, the engine count now shapes generation and two
        // sweeps differing only in it must NOT share cached tasksets.
        let g1 = GenParams::default();
        let g2 = GenParams {
            platform: Platform::default().with_num_gpus(2),
            ..GenParams::default()
        };
        let g4 = GenParams {
            platform: Platform::default().with_num_gpus(4),
            ..GenParams::default()
        };
        assert_ne!(params_hash(&g1), params_hash(&g2));
        assert_ne!(params_hash(&g2), params_hash(&g4));
        // And the cached values really carry distinct assignments: the
        // 2-GPU taskset populates engine 1, the 1-GPU one cannot.
        let a = taskset(31, &g1, 0);
        let b = taskset(31, &g2, 0);
        assert!(a.tasks.iter().all(|t| t.gpu == 0));
        if b.num_gpu_tasks() >= 2 {
            assert!(b.tasks.iter().any(|t| t.gpu == 1), "engine 1 never used");
        }
        // Per-engine overheads still normalize away WITHIN a count.
        let g2_eps = GenParams {
            platform: Platform::default().with_num_gpus(2).with_epsilon(123),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&g2), params_hash(&g2_eps));
    }

    #[test]
    fn heterogeneous_platforms_get_distinct_keys() {
        use crate::model::GpuContext;
        let ctx = |eps: u64| GpuContext { epsilon: eps, ..GpuContext::default() };
        let uni = GenParams {
            platform: Platform::default().with_num_gpus(2),
            ..GenParams::default()
        };
        let het_a = GenParams {
            platform: Platform::default().with_num_gpus(2).with_gpu(1, ctx(400)),
            ..GenParams::default()
        };
        let het_b = GenParams {
            platform: Platform::default().with_num_gpus(2).with_gpu(1, ctx(500)),
            ..GenParams::default()
        };
        // Equal engine counts no longer collide once the engines differ:
        // uniform vs hetero, and hetero variants among themselves.
        assert_ne!(params_hash(&uni), params_hash(&het_a));
        assert_ne!(params_hash(&het_a), params_hash(&het_b));
        // Uniform multi-GPU keys keep normalizing the overheads away
        // (the legacy behavior every existing CSV depends on).
        let uni_eps = GenParams {
            platform: Platform::default().with_num_gpus(2).with_epsilon(123),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&uni), params_hash(&uni_eps));
        // The memoized taskset carries the requested hetero platform
        // end-to-end and stays valid (engine bounds, priority order).
        let ts = taskset(3, &het_a, 0);
        assert_eq!(ts.platform, het_a.platform);
        ts.validate().unwrap();
        // Cache round-trip returns the same draws.
        let again = taskset(3, &het_a, 0);
        assert_eq!(ts.tasks, again.tasks);
    }

    #[test]
    fn single_gpu_hash_is_pinned() {
        // Golden pin: the default (single-GPU) key must never move —
        // it seeds `cell_rng` for every legacy sweep, so this constant
        // is what keeps pre-redesign CSV bytes reproducible. Recompute
        // it only if the key schema deliberately changes.
        assert_eq!(params_hash(&GenParams::default()), 0x35a4b0478165014b);
    }

    #[test]
    fn par_range_is_part_of_the_key_only_when_fine() {
        let serial = GenParams { par_range: (100, 100), ..GenParams::default() };
        assert_eq!(params_hash(&GenParams::default()), params_hash(&serial));
        let fine = GenParams { par_range: (30, 70), ..GenParams::default() };
        let finer = GenParams { par_range: (30, 60), ..GenParams::default() };
        assert_ne!(params_hash(&GenParams::default()), params_hash(&fine));
        assert_ne!(params_hash(&fine), params_hash(&finer));
        // The memoized fine taskset really carries fractions, and the
        // serial one stays clean (distinct keys → distinct cache rows).
        let a = taskset(17, &fine, 0);
        let b = taskset(17, &serial, 0);
        assert!(a.has_fine_grain());
        assert!(!b.has_fine_grain());
    }

    #[test]
    fn distinct_params_and_indices_diverge() {
        let p = GenParams::default();
        let q = GenParams { util_per_cpu: (0.25, 0.35), ..GenParams::default() };
        assert_ne!(params_hash(&p), params_hash(&q));
        let a = taskset(3, &p, 0);
        let b = taskset(3, &p, 1);
        // Same params, different index: different draws (periods differ
        // with overwhelming probability).
        let pa: Vec<u64> = a.tasks.iter().map(|t| t.period).collect();
        let pb: Vec<u64> = b.tasks.iter().map(|t| t.period).collect();
        assert_ne!(pa, pb);
    }

    #[test]
    fn generation_is_mode_independent() {
        // The memo's core assumption, checked directly: generate() draws
        // identically under both wait modes.
        let mut r1 = Pcg32::seeded(11);
        let mut r2 = Pcg32::seeded(11);
        let a = generate(&mut r1, &GenParams::default());
        let b = generate(
            &mut r2,
            &GenParams { mode: WaitMode::BusyWait, ..GenParams::default() },
        );
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng streams diverged");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.period, y.period);
            assert_eq!(x.cpu_segments, y.cpu_segments);
        }
    }
}
