//! Sweep-level taskset memoization.
//!
//! One Fig. 8 data point evaluates the same random tasksets under 8
//! analysis approaches. The generator's random draws depend only on the
//! structural [`GenParams`] fields — **not** on the wait mode or the
//! platform overhead constants (those are stamped onto the finished
//! tasks/taskset) — so the taskset for `(seed, params, index)` can be
//! generated once and shared across every approach, wait mode, and
//! ε/θ variant at that point.
//!
//! The cache key is `(base seed, mode- and platform-normalized params
//! hash, taskset index)`; the cached value is the canonical
//! self-suspending taskset, and [`taskset`] re-stamps the requested
//! mode/platform on the way out. Entries are evicted wholesale when the
//! cache grows past a bound (sweeps re-generate cheaply on miss).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::model::{Platform, TaskSet, WaitMode};
use crate::sweep::{cell_hash, cell_rng};
use crate::taskgen::{generate, GenParams};

type Key = (u64, u64, usize);

/// Process-wide cache. `Mutex<Option<..>>` rather than a lazy cell so a
/// const initializer suffices (no external once-cell machinery).
static CACHE: Mutex<Option<HashMap<Key, Arc<TaskSet>>>> = Mutex::new(None);

/// Wholesale-eviction bound: ~a full Fig. 8 panel at paper scale
/// (7 points × 1000 tasksets) before the map is cleared.
const CACHE_CAP: usize = 8192;

/// Stable hash of every [`GenParams`] field that influences the
/// generated task structure. Deliberately excludes `mode` (copied onto
/// tasks after the draws) and the platform's per-engine *overheads*
/// (ε/θ/L — copied onto the taskset), so e.g. the busy/suspend variants
/// of one approach pair and an ε sensitivity sweep all share identical
/// task structure — which is also what the paper's evaluation does.
///
/// The GPU-engine COUNT, however, does shape generation (the WFD
/// task-to-GPU assignment), so it is part of the key — a staleness fix:
/// normalizing it away would hand a 2-GPU sweep point the cached 1-GPU
/// assignment. It is appended only when > 1 so every legacy single-GPU
/// key (and therefore every legacy CSV byte) is unchanged.
///
/// Per-engine overheads normalize away only while the platform is
/// **uniform**. A heterogeneous platform additionally folds every
/// engine's full (ε, θ, L) context into the key: today the generator's
/// WFD assignment ignores engine parameters, so equal-count hetero
/// platforms *would* be safe to share — but that is an accident of the
/// current assignment policy, and a future overhead-aware placement
/// would silently corrupt the cache through such a collision. Making
/// the hetero digest part of the key states the invariant explicitly
/// ("sharing requires uniformity") instead of leaning on it. Uniform
/// keys — including every pre-existing sweep — are byte-unchanged
/// (`single_gpu_hash_is_pinned` pins the legacy constant).
pub fn params_hash(p: &GenParams) -> u64 {
    let mut parts = vec![
        p.num_cpus as u64,
        p.tasks_per_cpu.0 as u64,
        p.tasks_per_cpu.1 as u64,
        p.gpu_task_ratio.0.to_bits(),
        p.gpu_task_ratio.1.to_bits(),
        p.util_per_cpu.0.to_bits(),
        p.util_per_cpu.1.to_bits(),
        p.period_ms.0.to_bits(),
        p.period_ms.1.to_bits(),
        p.gpu_segments.0 as u64,
        p.gpu_segments.1 as u64,
        p.g_to_c_ratio.0.to_bits(),
        p.g_to_c_ratio.1.to_bits(),
        p.gm_in_g_ratio.0.to_bits(),
        p.gm_in_g_ratio.1.to_bits(),
        p.best_effort_ratio.to_bits(),
    ];
    if p.platform.num_gpus() > 1 {
        parts.push(p.platform.num_gpus() as u64);
    }
    if !p.platform.is_uniform() {
        for g in &p.platform.gpus {
            parts.push(g.epsilon);
            parts.push(g.theta);
            parts.push(g.tsg_slice);
        }
    }
    cell_hash(&parts)
}

/// The `index`-th random taskset for `(seed, params)`, memoized.
///
/// Deterministic in `(seed, params, index)` alone — independent of call
/// order, worker count, and cache state — because the per-taskset PRNG
/// is derived by seed-splitting, not drawn from a shared stream.
pub fn taskset(seed: u64, p: &GenParams, index: usize) -> Arc<TaskSet> {
    let h = params_hash(p);
    let key = (seed, h, index);
    let cached = lookup(&key);
    let canon = match cached {
        Some(ts) => ts,
        None => {
            let canon_params = GenParams { mode: WaitMode::SelfSuspend, ..p.clone() };
            let mut rng = cell_rng(seed, cell_hash(&[h, index as u64]));
            let ts = Arc::new(generate(&mut rng, &canon_params));
            store(key, Arc::clone(&ts));
            ts
        }
    };
    adapt(canon, p)
}

/// Re-stamp the requested wait mode and platform onto a cached taskset.
/// Safe for the per-engine overheads only — the engine COUNT (and, for
/// heterogeneous platforms, the full per-engine context set) is part of
/// the cache key, so the cached WFD task-to-GPU assignment always
/// matches `p.platform`.
fn adapt(ts: Arc<TaskSet>, p: &GenParams) -> Arc<TaskSet> {
    let platform = Platform { num_cpus: p.num_cpus, gpus: p.platform.gpus.clone() };
    debug_assert_eq!(ts.platform.num_gpus(), platform.num_gpus());
    if p.mode == WaitMode::SelfSuspend && ts.platform == platform {
        return ts;
    }
    let mut out = (*ts).clone();
    out.platform = platform;
    for t in &mut out.tasks {
        t.mode = p.mode;
    }
    Arc::new(out)
}

/// Drop every cached taskset. Sweeps never need this (results are
/// cache-state-independent); benchmarks use it to measure the cold
/// generation path instead of Arc-clone cache hits.
pub fn clear() {
    let mut guard = CACHE.lock().unwrap();
    if let Some(m) = guard.as_mut() {
        m.clear();
    }
}

fn lookup(key: &Key) -> Option<Arc<TaskSet>> {
    let guard = CACHE.lock().unwrap();
    guard.as_ref().and_then(|m| m.get(key).cloned())
}

fn store(key: Key, ts: Arc<TaskSet>) {
    let mut guard = CACHE.lock().unwrap();
    let map = guard.get_or_insert_with(HashMap::new);
    if map.len() >= CACHE_CAP {
        map.clear();
    }
    map.insert(key, ts);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    #[test]
    fn memoized_equals_fresh_generation() {
        let p = GenParams::default();
        let a = taskset(2024, &p, 5);
        // A fresh (uncached, different key path) generation with the same
        // derived rng must agree byte-for-byte in structure.
        let mut rng = cell_rng(2024, cell_hash(&[params_hash(&p), 5]));
        let fresh = generate(&mut rng, &p);
        assert_eq!(a.tasks, fresh.tasks);
        // And a second lookup returns the same cached value.
        let b = taskset(2024, &p, 5);
        assert_eq!(a.tasks, b.tasks);
    }

    #[test]
    fn mode_variants_share_structure() {
        let susp = GenParams::default();
        let busy = GenParams { mode: WaitMode::BusyWait, ..GenParams::default() };
        let a = taskset(7, &susp, 0);
        let b = taskset(7, &busy, 0);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.cpu_segments, y.cpu_segments);
            assert_eq!(x.gpu_segments, y.gpu_segments);
            assert_eq!(x.core, y.core);
            assert_eq!(x.cpu_prio, y.cpu_prio);
            assert_eq!(y.mode, WaitMode::BusyWait);
            assert_eq!(x.mode, WaitMode::SelfSuspend);
        }
    }

    #[test]
    fn platform_variants_share_structure() {
        let base = GenParams::default();
        let eps = GenParams {
            platform: Platform::default().with_epsilon(4000),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&base), params_hash(&eps));
        let a = taskset(9, &base, 2);
        let b = taskset(9, &eps, 2);
        assert_eq!(a.tasks, b.tasks);
        assert_eq!(b.platform.gpus[0].epsilon, 4000);
        assert_eq!(a.platform.gpus[0].epsilon, 1000);
    }

    #[test]
    fn gpu_count_is_part_of_the_key() {
        // Regression (PR 2 satellite): the key normalization used to
        // drop every platform field; with the WFD task-to-GPU
        // assignment, the engine count now shapes generation and two
        // sweeps differing only in it must NOT share cached tasksets.
        let g1 = GenParams::default();
        let g2 = GenParams {
            platform: Platform::default().with_num_gpus(2),
            ..GenParams::default()
        };
        let g4 = GenParams {
            platform: Platform::default().with_num_gpus(4),
            ..GenParams::default()
        };
        assert_ne!(params_hash(&g1), params_hash(&g2));
        assert_ne!(params_hash(&g2), params_hash(&g4));
        // And the cached values really carry distinct assignments: the
        // 2-GPU taskset populates engine 1, the 1-GPU one cannot.
        let a = taskset(31, &g1, 0);
        let b = taskset(31, &g2, 0);
        assert!(a.tasks.iter().all(|t| t.gpu == 0));
        if b.num_gpu_tasks() >= 2 {
            assert!(b.tasks.iter().any(|t| t.gpu == 1), "engine 1 never used");
        }
        // Per-engine overheads still normalize away WITHIN a count.
        let g2_eps = GenParams {
            platform: Platform::default().with_num_gpus(2).with_epsilon(123),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&g2), params_hash(&g2_eps));
    }

    #[test]
    fn heterogeneous_platforms_get_distinct_keys() {
        use crate::model::GpuContext;
        let ctx = |eps: u64| GpuContext { epsilon: eps, ..GpuContext::default() };
        let uni = GenParams {
            platform: Platform::default().with_num_gpus(2),
            ..GenParams::default()
        };
        let het_a = GenParams {
            platform: Platform::default().with_num_gpus(2).with_gpu(1, ctx(400)),
            ..GenParams::default()
        };
        let het_b = GenParams {
            platform: Platform::default().with_num_gpus(2).with_gpu(1, ctx(500)),
            ..GenParams::default()
        };
        // Equal engine counts no longer collide once the engines differ:
        // uniform vs hetero, and hetero variants among themselves.
        assert_ne!(params_hash(&uni), params_hash(&het_a));
        assert_ne!(params_hash(&het_a), params_hash(&het_b));
        // Uniform multi-GPU keys keep normalizing the overheads away
        // (the legacy behavior every existing CSV depends on).
        let uni_eps = GenParams {
            platform: Platform::default().with_num_gpus(2).with_epsilon(123),
            ..GenParams::default()
        };
        assert_eq!(params_hash(&uni), params_hash(&uni_eps));
        // The memoized taskset carries the requested hetero platform
        // end-to-end and stays valid (engine bounds, priority order).
        let ts = taskset(3, &het_a, 0);
        assert_eq!(ts.platform, het_a.platform);
        ts.validate().unwrap();
        // Cache round-trip returns the same draws.
        let again = taskset(3, &het_a, 0);
        assert_eq!(ts.tasks, again.tasks);
    }

    #[test]
    fn single_gpu_hash_is_pinned() {
        // Golden pin: the default (single-GPU) key must never move —
        // it seeds `cell_rng` for every legacy sweep, so this constant
        // is what keeps pre-redesign CSV bytes reproducible. Recompute
        // it only if the key schema deliberately changes.
        assert_eq!(params_hash(&GenParams::default()), 0x35a4b0478165014b);
    }

    #[test]
    fn distinct_params_and_indices_diverge() {
        let p = GenParams::default();
        let q = GenParams { util_per_cpu: (0.25, 0.35), ..GenParams::default() };
        assert_ne!(params_hash(&p), params_hash(&q));
        let a = taskset(3, &p, 0);
        let b = taskset(3, &p, 1);
        // Same params, different index: different draws (periods differ
        // with overwhelming probability).
        let pa: Vec<u64> = a.tasks.iter().map(|t| t.period).collect();
        let pb: Vec<u64> = b.tasks.iter().map(|t| t.period).collect();
        assert_ne!(pa, pb);
    }

    #[test]
    fn generation_is_mode_independent() {
        // The memo's core assumption, checked directly: generate() draws
        // identically under both wait modes.
        let mut r1 = Pcg32::seeded(11);
        let mut r2 = Pcg32::seeded(11);
        let a = generate(&mut r1, &GenParams::default());
        let b = generate(
            &mut r2,
            &GenParams { mode: WaitMode::BusyWait, ..GenParams::default() },
        );
        assert_eq!(r1.next_u64(), r2.next_u64(), "rng streams diverged");
        assert_eq!(a.len(), b.len());
        for (x, y) in a.tasks.iter().zip(&b.tasks) {
            assert_eq!(x.period, y.period);
            assert_eq!(x.cpu_segments, y.cpu_segments);
        }
    }
}
