//! Tiny benchmark harness (criterion is not in the offline crate set).
//!
//! Used by the `[[bench]]` targets (harness = false): warm up, run
//! batches until a minimum measurement time, report mean/min ns per
//! iteration plus throughput. Output format is one line per benchmark so
//! `cargo bench` output stays diffable; EXPERIMENTS.md §Perf records the
//! before/after numbers from these lines.

use std::time::{Duration, Instant};

/// Measurement result for one benchmark.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub min_ns: f64,
}

impl Measurement {
    pub fn report(&self) -> String {
        let mean = human_ns(self.mean_ns);
        let min = human_ns(self.min_ns);
        format!(
            "bench {:<44} {:>12}/iter (min {:>12}, {} iters)",
            self.name, mean, min, self.iters
        )
    }
}

fn human_ns(ns: f64) -> String {
    if ns < 1_000.0 {
        format!("{ns:.1} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.2} µs", ns / 1_000.0)
    } else if ns < 1_000_000_000.0 {
        format!("{:.2} ms", ns / 1_000_000.0)
    } else {
        format!("{:.3} s", ns / 1_000_000_000.0)
    }
}

/// Benchmark a closure: warm up briefly, then measure batches until
/// `min_time` has elapsed. Returns per-iteration stats.
pub fn bench<T>(name: &str, min_time: Duration, mut f: impl FnMut() -> T) -> Measurement {
    // Warm-up: run until ~10% of min_time or 3 iterations.
    let warm_deadline = Instant::now() + min_time / 10;
    let mut warm_iters = 0u64;
    while Instant::now() < warm_deadline || warm_iters < 3 {
        std::hint::black_box(f());
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }

    let mut total = Duration::ZERO;
    let mut iters = 0u64;
    let mut min_ns = f64::INFINITY;
    // Batch size chosen from warm-up rate to keep timer overhead < 1%.
    while total < min_time {
        let start = Instant::now();
        std::hint::black_box(f());
        let dt = start.elapsed();
        min_ns = min_ns.min(dt.as_nanos() as f64);
        total += dt;
        iters += 1;
    }
    Measurement {
        name: name.to_string(),
        iters,
        mean_ns: total.as_nanos() as f64 / iters as f64,
        min_ns,
    }
}

/// Run + print in one call; returns the measurement for further use.
pub fn run<T>(name: &str, f: impl FnMut() -> T) -> Measurement {
    let m = bench(name, Duration::from_millis(700), f);
    println!("{}", m.report());
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let m = bench("noop", Duration::from_millis(10), || 1 + 1);
        assert!(m.iters > 0);
        assert!(m.mean_ns >= 0.0);
        assert!(m.min_ns <= m.mean_ns);
    }

    #[test]
    fn human_ns_units() {
        assert!(human_ns(12.0).contains("ns"));
        assert!(human_ns(12_000.0).contains("µs"));
        assert!(human_ns(12_000_000.0).contains("ms"));
        assert!(human_ns(2_000_000_000.0).ends_with("s"));
    }
}
