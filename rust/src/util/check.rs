//! Lightweight property-testing harness.
//!
//! `proptest`/`quickcheck` are not in the offline crate set, so this
//! module provides the 10% we need: run a property over many seeded
//! random cases and report the failing seed. Failures reproduce exactly
//! (`Pcg32::seeded(seed)` is fully deterministic), which is what matters
//! for the scheduling invariants checked in `rust/tests/`.

use crate::util::rng::Pcg32;

/// Run `prop` over `cases` deterministic seeds. The property receives a
/// seeded PRNG and returns `Err(msg)` to signal a violation; the panic
/// message includes the seed for reproduction.
pub fn forall(name: &str, cases: u64, mut prop: impl FnMut(&mut Pcg32) -> Result<(), String>) {
    for case in 0..cases {
        let seed = 0x9e3779b97f4a7c15u64.wrapping_mul(case + 1);
        let mut rng = Pcg32::seeded(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {msg}");
        }
    }
}

/// Assert-like helper producing `Result` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)*) => {
        if !$cond {
            return Err(format!($($fmt)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("trivial", 50, |rng| {
            let v = rng.range_u64(0, 10);
            if v <= 10 { Ok(()) } else { Err(format!("v = {v}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must-fail'")]
    fn forall_reports_failures_with_seed() {
        forall("must-fail", 10, |rng| {
            let v = rng.range_u64(0, 1);
            if v == 2 { Ok(()) } else { Err("always".into()) }
        });
    }
}
