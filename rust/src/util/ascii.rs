//! ASCII rendering of experiment outputs: line charts for the Fig. 8/9
//! schedulability curves, bar charts for Fig. 10/13, histograms for
//! Fig. 12, and Gantt charts for the schedule examples (Figs. 3-7).
//! All experiment binaries print these next to the CSVs they write.

/// Render a multi-series line chart: `series` = (label, points(x, y)).
/// Y is assumed to be in [0, y_max]; x values are the category labels.
pub fn line_chart(
    title: &str,
    xlabel: &str,
    xticks: &[String],
    series: &[(String, Vec<f64>)],
    y_max: f64,
    height: usize,
) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let width = xticks.len();
    let glyphs = ['o', '+', 'x', '*', '#', '@', '%', '&', '$', '~'];
    // Raster: rows from top (y_max) to bottom (0).
    let mut raster = vec![vec![' '; width * 6]; height + 1];
    for (si, (_, ys)) in series.iter().enumerate() {
        let g = glyphs[si % glyphs.len()];
        for (xi, &y) in ys.iter().enumerate() {
            let frac = (y / y_max).clamp(0.0, 1.0);
            let row = height - (frac * height as f64).round() as usize;
            let col = xi * 6 + 2;
            if raster[row][col] == ' ' {
                raster[row][col] = g;
            } else {
                // overlap marker
                raster[row][col] = '?';
            }
        }
    }
    for (ri, row) in raster.iter().enumerate() {
        let yv = y_max * (height - ri) as f64 / height as f64;
        out.push_str(&format!("{yv:6.2} |"));
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:6} +{}\n", "", "-".repeat(width * 6)));
    out.push_str(&format!("{:8}", ""));
    for t in xticks {
        out.push_str(&format!("{t:<6}"));
    }
    out.push('\n');
    out.push_str(&format!("        ({xlabel})\n"));
    for (si, (label, _)) in series.iter().enumerate() {
        out.push_str(&format!("   {} {label}\n", glyphs[si % glyphs.len()]));
    }
    out
}

/// Horizontal bar chart (Fig. 10 MORT per task, Fig. 13 overheads).
pub fn bar_chart(title: &str, rows: &[(String, f64)], unit: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} ==\n"));
    let max = rows.iter().map(|r| r.1).fold(f64::MIN, f64::max).max(1e-12);
    let label_w = rows.iter().map(|r| r.0.len()).max().unwrap_or(4);
    for (label, v) in rows {
        let n = ((v / max) * 50.0).round() as usize;
        out.push_str(&format!(
            "{label:>label_w$} | {} {v:.3} {unit}\n",
            "#".repeat(n)
        ));
    }
    out
}

/// Histogram rendering (Fig. 12).
pub fn histogram_chart(title: &str, h: &crate::util::stats::Histogram, unit: &str) -> String {
    let mut out = String::new();
    out.push_str(&format!("== {title} (n = {}) ==\n", h.total()));
    let max = h.bins.iter().copied().max().unwrap_or(1).max(1);
    for (k, &c) in h.bins.iter().enumerate() {
        let (lo, hi) = h.bin_edges(k);
        let n = (c * 50 / max).min(50);
        out.push_str(&format!(
            "[{lo:9.3}, {hi:9.3}) {unit} | {:<50} {c}\n",
            "#".repeat(n)
        ));
    }
    if h.underflow > 0 {
        out.push_str(&format!("underflow: {}\n", h.underflow));
    }
    if h.overflow > 0 {
        out.push_str(&format!("overflow: {}\n", h.overflow));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::Histogram;

    #[test]
    fn line_chart_contains_series_labels() {
        let s = line_chart(
            "t",
            "x",
            &["a".into(), "b".into()],
            &[("one".into(), vec![0.5, 1.0]), ("two".into(), vec![0.1, 0.2])],
            1.0,
            10,
        );
        assert!(s.contains("one") && s.contains("two") && s.contains("== t =="));
    }

    #[test]
    fn bar_chart_scales_to_max() {
        let s = bar_chart("b", &[("x".into(), 1.0), ("y".into(), 2.0)], "ms");
        let lines: Vec<&str> = s.lines().collect();
        let xhash = lines[1].matches('#').count();
        let yhash = lines[2].matches('#').count();
        assert_eq!(yhash, 50);
        assert_eq!(xhash, 25);
    }

    #[test]
    fn histogram_chart_renders_counts() {
        let mut h = Histogram::new(0.0, 2.0, 2);
        h.add(0.5);
        h.add(1.5);
        h.add(1.6);
        let s = histogram_chart("h", &h, "ms");
        assert!(s.contains("n = 3"));
    }
}
