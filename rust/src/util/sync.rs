//! Poison-recovering mutex access shared by every long-running path.
//!
//! `Mutex::lock().unwrap()` turns one panicking sibling thread into a
//! cascade: the poisoned mutex makes every later locker panic too,
//! which is fatal for `gcaps serve` and for the sharded sweep pool
//! (PR 6 postmortem — a panicking sweep worker wedged every subsequent
//! `gcaps exp` in the process). [`lock_or_recover`] takes the guard out
//! of the [`PoisonError`] instead.
//!
//! Recovery is only sound when the protected state carries no
//! cross-field invariant a partially-completed critical section could
//! break — i.e. when any observable state is a *valid* (if stale or
//! partial) state. Every call site documents why that holds; the sweep
//! memo cache is the canonical example (`sweep/memo.rs`). The
//! `lock-hygiene` rule of `gcaps lint` flags bare `.lock().unwrap()`
//! so new sites opt in deliberately rather than by default.

use std::sync::{Mutex, MutexGuard};

/// Lock `m`, recovering the guard if a previous holder panicked.
pub fn lock_or_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn plain_lock_works() {
        let m = Mutex::new(41);
        *lock_or_recover(&m) += 1;
        assert_eq!(*lock_or_recover(&m), 42);
    }

    #[test]
    fn recovers_after_a_panicking_holder() {
        let m = Arc::new(Mutex::new(vec![1, 2, 3]));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _guard = m2.lock().expect("not yet poisoned");
            panic!("poison the mutex");
        })
        .join();
        assert!(m.is_poisoned());
        let guard = lock_or_recover(&m);
        assert_eq!(*guard, vec![1, 2, 3]);
    }
}
