//! Strict CLI argument plumbing shared by `main.rs` and the experiment
//! registry's per-experiment flag validation.
//!
//! The rules (enforced everywhere, not per-subcommand):
//!
//! - an **absent** flag yields its default;
//! - a **present-and-malformed** value is a usage error naming the flag
//!   (a typo like `--tasksets 1O0` must never silently run with the
//!   default);
//! - an **unknown** flag name is a usage error naming the flag and the
//!   accepted set (a typo like `--panle a` must never run silently with
//!   default options) — see [`Args::reject_unknown`] and the registry's
//!   per-experiment validation
//!   ([`crate::experiments::registry::validate`]).
//!
//! Usage errors exit with status 2 via [`fail`].

use std::collections::HashMap;

/// Parsed command line: positional words plus `--name value` flags.
/// A `--flag` with no following value parses as the literal `"true"`.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    /// Parse a token stream (the program name already stripped).
    pub fn parse(tokens: impl Iterator<Item = String>) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut it = tokens.peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                let val = if it.peek().is_some_and(|v| !v.starts_with("--")) {
                    it.next().unwrap()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                positional.push(a);
            }
        }
        Args { positional, flags }
    }

    /// Parse the process's own command line.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// The given flag names, sorted (for deterministic error messages).
    pub fn flag_names(&self) -> Vec<&str> {
        let mut names: Vec<&str> = self.flags.keys().map(|s| s.as_str()).collect();
        names.sort_unstable();
        names
    }

    /// Strict flag parsing: an absent flag yields the default, but a
    /// present-and-malformed value is an error naming the flag — a typo
    /// like `--tasksets 1O0` or `--jobs 4x` must never silently run the
    /// experiment with the default value. (A flag given without a value
    /// parses as the literal "true" and fails the same way.)
    pub fn parse_flag<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("invalid value {v:?} for --{name}")),
        }
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> usize {
        self.parse_flag(name, default).unwrap_or_else(|e| fail(&e))
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> u64 {
        self.parse_flag(name, default).unwrap_or_else(|e| fail(&e))
    }

    /// Exit with a usage error if any given flag is not in `allowed`.
    /// `context` names the subcommand for the message.
    pub fn reject_unknown(&self, context: &str, allowed: &[&str]) {
        for name in self.flag_names() {
            if !allowed.contains(&name) {
                let mut accepted: Vec<&str> = allowed.to_vec();
                accepted.sort_unstable();
                fail(&format!(
                    "unknown flag --{name} for `{context}` (accepted: {})",
                    accepted
                        .iter()
                        .map(|a| format!("--{a}"))
                        .collect::<Vec<_>>()
                        .join(", ")
                ));
            }
        }
    }
}

/// Print a CLI error and exit with status 2 (the usage-error status).
pub fn fail(msg: &str) -> ! {
    eprintln!("error: {msg}");
    std::process::exit(2);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args_with(flags: &[(&str, &str)]) -> Args {
        Args {
            positional: vec![],
            flags: flags.iter().map(|&(k, v)| (k.to_string(), v.to_string())).collect(),
        }
    }

    #[test]
    fn parse_splits_positionals_and_flags() {
        let a = Args::parse(
            ["exp", "fig8", "--panel", "b", "--jobs", "4", "--quick"]
                .iter()
                .map(|s| s.to_string()),
        );
        assert_eq!(a.positional, vec!["exp", "fig8"]);
        assert_eq!(a.flag("panel"), Some("b"));
        assert_eq!(a.flag("jobs"), Some("4"));
        assert_eq!(a.flag("quick"), Some("true"), "valueless flag parses as true");
        assert_eq!(a.flag_names(), vec!["jobs", "panel", "quick"]);
    }

    #[test]
    fn absent_flag_yields_the_default() {
        let a = args_with(&[]);
        assert_eq!(a.parse_flag("jobs", 7usize), Ok(7));
        assert_eq!(a.parse_flag::<u64>("seed", 2024), Ok(2024));
    }

    #[test]
    fn well_formed_values_parse() {
        let a = args_with(&[("tasksets", "100"), ("seed", "42")]);
        assert_eq!(a.parse_flag("tasksets", 1usize), Ok(100));
        assert_eq!(a.parse_flag::<u64>("seed", 1), Ok(42));
    }

    #[test]
    fn malformed_values_error_naming_the_flag() {
        // Regression: `--tasksets 1O0` / `--jobs 4x` used to silently
        // run the experiment with the default value.
        let a = args_with(&[("tasksets", "1O0"), ("jobs", "4x")]);
        let e = a.parse_flag::<usize>("tasksets", 200).unwrap_err();
        assert!(e.contains("--tasksets") && e.contains("1O0"), "{e}");
        let e = a.parse_flag::<usize>("jobs", 8).unwrap_err();
        assert!(e.contains("--jobs") && e.contains("4x"), "{e}");
    }

    #[test]
    fn valueless_numeric_flag_is_an_error() {
        // `gcaps exp --jobs --seed 5` leaves jobs = "true" (flag with no
        // value): must error, not silently use the default.
        let a = args_with(&[("jobs", "true")]);
        assert!(a.parse_flag::<usize>("jobs", 1).is_err());
    }

    #[test]
    fn negative_and_overflowing_values_are_errors() {
        let a = args_with(&[("tasksets", "-5"), ("seed", "99999999999999999999999999")]);
        assert!(a.parse_flag::<usize>("tasksets", 1).is_err());
        assert!(a.parse_flag::<u64>("seed", 1).is_err());
    }
}
