//! Shared infrastructure: PRNG, statistics, CSV output, ASCII plots and
//! the lightweight property-testing harness (`check`).

pub mod ascii;
pub mod bench;
pub mod check;
pub mod cli;
pub mod csv;
pub mod error;
pub mod rng;
pub mod stats;
pub mod sync;
